//! Integration tests: the fault-tolerance layer (DESIGN.md §9).
//!
//! Deterministic single-xbar unwind paths — a multicast fork leg that
//! times out mid-stream, a request stuck behind a dead slave's
//! backed-up channels, a partially-forwarded no-commit fork, a read
//! whose R burst never arrives — plus SoC-level recovery: a reduction
//! contributor that never shows up, a dying LLC, the full
//! mixed-traffic acceptance scenario, and the watchdog post-mortem
//! when the deadlines are left unarmed.

mod common;

use axi_mcast::axi::golden::FaultPlan;
use axi_mcast::axi::mcast::AddrSet;
use axi_mcast::axi::reduce::ReduceOp;
use axi_mcast::axi::types::Resp;
use axi_mcast::axi::xbar::{Xbar, XbarCfg};
use axi_mcast::occamy::config::{FaultSite, LLC_BASE};
use axi_mcast::occamy::{Cmd, NopCompute, Soc, SocConfig};
use axi_mcast::sim::engine::{SimError, Watchdog};
use axi_mcast::workloads::faults::{
    assert_fault_run_invariants, run_fault_scenario, FaultKind, TAG_RED_V,
};
use common::*;

/// A single-xbar fixture with both deadlines armed.
fn timed_fixture(
    n_m: usize,
    n_s: usize,
    reqt: u32,
    cplt: u32,
    commit: bool,
    scripts: Vec<Vec<Xfer>>,
) -> Fixture {
    let mut cfg = XbarCfg::new("t", n_m, n_s, cluster_map(n_s, false));
    cfg.req_timeout = Some(reqt);
    cfg.cpl_timeout = Some(cplt);
    cfg.commit_protocol = commit;
    let (xbar, pool) = Xbar::with_pool(cfg, 2);
    Fixture::new(xbar, pool, scripts)
}

#[test]
fn hung_fork_leg_times_out_and_join_merges_slverr() {
    // 1 master multicasts an 8-beat burst to 4 slaves; slave 2 accepts
    // the AW handshake and then hangs. Its W FIFO backs up, stalling
    // the fork for everyone — the completion deadline must evict the
    // hung leg so the healthy legs finish, and the B join must carry
    // the SLVERR of the synthesised leg response.
    let mut f = timed_fixture(
        1,
        4,
        10_000,
        60,
        true,
        vec![vec![Xfer::write(clusters_set(4, 0x100), 8, 0)]],
    );
    f.slaves[2].fault = FaultPlan::GrantThenHang;
    f.run(10_000).expect("timeout engine must complete the run");
    assert_eq!(f.masters[0].completed_b.len(), 1);
    assert_eq!(f.masters[0].completed_b[0].1, Resp::SlvErr);
    for i in [0usize, 1, 3] {
        assert_eq!(f.slaves[i].writes.len(), 1, "healthy slave {i}");
        assert_eq!(f.slaves[i].writes[0].beats, 8);
    }
    assert!(f.slaves[2].writes.is_empty(), "hung slave completed a burst");
    assert_eq!(f.xbar.stats.cpl_timeouts, 1);
    assert_eq!(f.xbar.stats.req_timeouts, 0);
    // the beats the evicted leg never streamed are accounted as dropped
    assert!(f.xbar.stats.w_dropped > 0);
    assert_eq!(
        f.xbar.stats.w_beats_out,
        f.xbar.stats.w_beats_in + f.xbar.stats.w_fork_extra - f.xbar.stats.w_dropped
    );
}

#[test]
fn request_stuck_behind_dead_slave_retires_decerr() {
    // Slave 0 is dead from reset: two unicasts fill its AW FIFO
    // (depth 2) and then a multicast including it can never commit.
    // The request deadline must retire the whole multicast DECERR (no
    // leg ever forked) and the completion deadline must SLVERR the two
    // forwarded-but-unacknowledged unicasts.
    let script = vec![
        Xfer::write(AddrSet::unicast(cluster_addr(0, 0)), 1, 0),
        Xfer::write(AddrSet::unicast(cluster_addr(0, 0x40)), 1, 1),
        Xfer::write(clusters_set(4, 0x100), 2, 2),
    ];
    let mut f = timed_fixture(1, 4, 200, 40, true, vec![script]);
    f.slaves[0].fault = FaultPlan::StallAfter { bursts: 0 };
    f.run(10_000).expect("timeout engine must complete the run");
    assert_eq!(f.masters[0].completed_b.len(), 3);
    let resp_of = |i: usize| {
        let txn = f.masters[0].issued[i].0;
        f.masters[0]
            .completed_b
            .iter()
            .find(|(t, _)| *t == txn)
            .expect("missing B")
            .1
    };
    assert_eq!(resp_of(0), Resp::SlvErr, "forwarded unicast 0");
    assert_eq!(resp_of(1), Resp::SlvErr, "forwarded unicast 1");
    assert_eq!(resp_of(2), Resp::DecErr, "never-forked multicast");
    assert_eq!(f.xbar.stats.req_timeouts, 1);
    assert_eq!(f.xbar.stats.cpl_timeouts, 2);
    // the multicast never touched the healthy slaves
    for i in 1..4 {
        assert!(f.slaves[i].writes.is_empty(), "slave {i}");
    }
}

#[test]
fn partial_no_commit_fork_evicts_stuck_legs() {
    // commit_protocol = false: the fork proceeds leg by leg, so a dead
    // slave leaves the entry *partially* forwarded — a state the
    // all-or-nothing commit can never reach. The request deadline must
    // evict the unforwarded leg (poisoning the join), let the
    // forwarded legs accept, and keep the fabric live.
    let script = vec![
        Xfer::write(AddrSet::unicast(cluster_addr(2, 0)), 1, 0),
        Xfer::write(AddrSet::unicast(cluster_addr(2, 0x40)), 1, 1),
        Xfer::write(clusters_set(4, 0x100), 4, 2),
    ];
    let mut f = timed_fixture(1, 4, 200, 40, false, vec![script]);
    f.slaves[2].fault = FaultPlan::StallAfter { bursts: 0 };
    f.run(10_000).expect("partial-fork eviction must complete the run");
    assert_eq!(f.masters[0].completed_b.len(), 3);
    let mcast_txn = f.masters[0].issued[2].0;
    let mcast_b = f.masters[0]
        .completed_b
        .iter()
        .find(|(t, _)| *t == mcast_txn)
        .unwrap()
        .1;
    // DECERR folded into the join demotes to SLVERR (any error mix)
    assert_eq!(mcast_b, Resp::SlvErr);
    assert!(f.xbar.stats.req_timeouts >= 1, "no request deadline fired");
    // the forwarded legs delivered the burst despite the dead sibling
    for i in [0usize, 1, 3] {
        assert_eq!(
            f.slaves[i]
                .writes
                .iter()
                .filter(|w| w.txn == mcast_txn)
                .count(),
            1,
            "slave {i} must receive the multicast burst"
        );
    }
    assert!(f.slaves[2].writes.is_empty());
}

#[test]
fn read_from_dead_slave_synthesises_full_slverr_burst() {
    let mut f = timed_fixture(
        1,
        2,
        10_000,
        50,
        true,
        vec![vec![Xfer::read(cluster_addr(1, 0), 4, 0)]],
    );
    f.slaves[1].fault = FaultPlan::StallAfter { bursts: 0 };
    f.run(10_000).expect("read timeout must complete the run");
    // exactly the requested beat count, all SLVERR, RLAST terminated
    assert_eq!(f.masters[0].completed_r.len(), 1);
    let (_, resp, beats) = f.masters[0].completed_r[0];
    assert_eq!(resp, Resp::SlvErr);
    assert_eq!(beats, 4);
    assert_eq!(f.xbar.stats.cpl_timeouts, 1);
}

#[test]
fn missing_reduction_contributor_is_evicted_and_group_completes() {
    // All four clusters are members of the reduce group, but cluster 3
    // never issues its contribution. The collecting-state deadline
    // must evict it so the combined burst still issues, with the
    // poisoned B fanned back to the contributors that did show up.
    let mut cfg = SocConfig::tiny(4);
    cfg.wide_mcast = true;
    cfg.e2e_mcast_order = true;
    cfg.fabric_reduce = true;
    cfg.req_timeout = Some(5_000);
    cfg.cpl_timeout = Some(1_000);
    let mut soc = Soc::new(cfg.clone());
    soc.open_reduce_group(0, ReduceOp::Sum, &[0, 1, 2, 3], cfg.cluster_base(0) + 0x8000);
    let mut progs: Vec<Vec<Cmd>> = vec![Vec::new(); 4];
    for (r, p) in progs.iter_mut().enumerate().take(3) {
        p.push(Cmd::DmaReduce {
            src: cfg.cluster_base(r),
            dst: cfg.cluster_base(0) + 0x8000,
            bytes: 512,
            tag: TAG_RED_V + r as u64,
            group: 0,
            op: ReduceOp::Sum,
        });
        p.push(Cmd::WaitDma);
    }
    soc.load_programs(progs);
    soc.run(
        &mut NopCompute,
        Watchdog {
            stall_cycles: 50_000,
            max_cycles: 10_000_000,
        },
    )
    .expect("evicted contributor must not wedge the group");
    let stats = soc.wide.stats_sum();
    assert!(stats.red_evictions >= 1, "missing contributor not evicted");
    assert!(stats.cpl_timeouts >= 1);
    // the fabric contributors that did arrive see the poisoned B
    for r in 1..3 {
        assert!(
            soc.clusters[r].dma_error_tags.contains(&(TAG_RED_V + r as u64)),
            "cluster {r} must observe the poisoned reduction B"
        );
    }
    // nothing is left open
    let report = soc.deadlock_report();
    assert_eq!(report.open_reductions, 0);
    assert_eq!(report.open_cpl_legs, 0);
    assert_eq!(report.resv_live_tickets, 0);
}

#[test]
fn llc_dying_mid_run_errors_only_the_late_jobs() {
    // The LLC dies after serving one write burst (its B is swallowed):
    // every LLC job errors — the first via its swallowed B, the queued
    // write via the request path, the read via the synthesised R burst
    // — while a cluster-to-cluster job stays clean.
    let cfg = {
        let mut c = SocConfig::tiny(4);
        c.req_timeout = Some(5_000);
        c.cpl_timeout = Some(1_000);
        c.faults = vec![(FaultSite::Llc, FaultPlan::StallAfter { bursts: 1 })];
        c
    };
    let mut soc = Soc::new(cfg.clone());
    let mut progs: Vec<Vec<Cmd>> = vec![Vec::new(); 4];
    progs[0] = vec![
        Cmd::Dma {
            src: cfg.cluster_base(0),
            dst: AddrSet::unicast(LLC_BASE),
            bytes: 512,
            tag: 1,
        },
        Cmd::Dma {
            src: cfg.cluster_base(0),
            dst: AddrSet::unicast(LLC_BASE + 0x1000),
            bytes: 512,
            tag: 2,
        },
        Cmd::Dma {
            src: LLC_BASE,
            dst: AddrSet::unicast(cfg.cluster_base(0) + 0x4000),
            bytes: 512,
            tag: 3,
        },
        Cmd::Dma {
            src: cfg.cluster_base(0),
            dst: AddrSet::unicast(cfg.cluster_base(1) + 0x4000),
            bytes: 512,
            tag: 4,
        },
        Cmd::WaitDma,
    ];
    soc.load_programs(progs);
    soc.run(
        &mut NopCompute,
        Watchdog {
            stall_cycles: 50_000,
            max_cycles: 10_000_000,
        },
    )
    .expect("LLC fault must not wedge the run");
    let mut tags = soc.clusters[0].dma_error_tags.clone();
    tags.sort_unstable();
    assert_eq!(tags, vec![1, 2, 3], "exactly the LLC jobs must error");
    assert!(soc.wide.stats_sum().cpl_timeouts >= 1);
}

#[test]
fn acceptance_mixed_traffic_recovers_across_two_groups() {
    // The headline acceptance scenario at 8 clusters / 2 groups: a
    // stalled endpoint under concurrent global multicast, two
    // in-network reductions and unicast cross-traffic. Errors must hit
    // exactly the victim-touching transactions (including the SLVERR
    // fan-back through the cross-group combine chain) and every fabric
    // ledger must drain.
    let r = run_fault_scenario(&SocConfig::tiny(8), Some(FaultKind::Stall), 5, 512);
    assert_fault_run_invariants(&r);
    assert_eq!(r.error_tags, r.expected_tags);
    assert!(r.wide.cpl_timeouts > 0);
}

#[test]
fn fault_scenario_is_bit_identical_across_threads() {
    // The `faults`/`qos` benches and CLI commands forward the global
    // `--threads` option into `SocConfig::threads` exactly like the
    // toposweep and collectives harnesses — sound only because a
    // timeout-recovering run is bit-identical under the parallel
    // engine. Pinned here so the forwarding can't silently regress the
    // published BENCH_faults.json numbers.
    let base = SocConfig::tiny(8);
    let golden = run_fault_scenario(&base, Some(FaultKind::Stall), 5, 512);
    assert_fault_run_invariants(&golden);
    for threads in [2usize, 4] {
        let mut cfg = base.clone();
        cfg.threads = threads;
        let r = run_fault_scenario(&cfg, Some(FaultKind::Stall), 5, 512);
        assert_eq!(r.cycles, golden.cycles, "threads={threads}: cycle divergence");
        assert_eq!(r.wide, golden.wide, "threads={threads}: stats divergence");
        assert_eq!(
            r.error_tags, golden.error_tags,
            "threads={threads}: error tags diverged"
        );
        assert_eq!(r.err_resps, golden.err_resps, "threads={threads}: error responses");
    }
}

#[test]
fn unarmed_timeouts_wedge_with_diagnosable_report() {
    // Same fault, deadlines off: the watchdog must fire and the
    // post-mortem must name the undrained state.
    let mut cfg = SocConfig::tiny(4);
    cfg.faults = vec![(FaultSite::ClusterL1(1), FaultPlan::GrantThenHang)];
    let mut soc = Soc::new(cfg.clone());
    let mut progs: Vec<Vec<Cmd>> = vec![Vec::new(); 4];
    progs[0] = vec![
        Cmd::Dma {
            src: cfg.cluster_base(0),
            dst: AddrSet::unicast(cfg.cluster_base(1) + 0x4000),
            bytes: 512,
            tag: 1,
        },
        Cmd::WaitDma,
    ];
    soc.load_programs(progs);
    let err = soc
        .run(
            &mut NopCompute,
            Watchdog {
                stall_cycles: 2_000,
                max_cycles: 10_000_000,
            },
        )
        .expect_err("a hung endpoint without deadlines must deadlock");
    match err {
        SimError::Deadlock { report, .. } => {
            let report = report.expect("Soc must attach a post-mortem");
            assert!(
                !report.busy.is_empty(),
                "report must name the wedged components"
            );
            let text = format!("{report}");
            assert!(text.contains("busy:"), "unexpected report shape: {text}");
        }
        other => panic!("expected a deadlock, got {other}"),
    }
}

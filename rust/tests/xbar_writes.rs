//! Integration tests: write path of the multicast crossbar (fig. 2b/2d
//! behaviours end to end through a single XBAR).

mod common;

use axi_mcast::axi::mcast::AddrSet;
use axi_mcast::axi::types::Resp;
use axi_mcast::axi::xbar::{Xbar, XbarCfg};
use common::*;

fn fixture(n_m: usize, n_s: usize, scripts: Vec<Vec<Xfer>>) -> Fixture {
    let cfg = XbarCfg::new("t", n_m, n_s, cluster_map(n_s, false));
    let (xbar, pool) = Xbar::with_pool(cfg, 2);
    Fixture::new(xbar, pool, scripts)
}

#[test]
fn unicast_write_roundtrip() {
    let mut f = fixture(
        1,
        2,
        vec![vec![Xfer::write(AddrSet::unicast(cluster_addr(1, 0x40)), 4, 0)]],
    );
    f.run(10_000).expect("no deadlock");
    f.assert_protocol_clean();
    assert_eq!(f.masters[0].completed_b.len(), 1);
    assert_eq!(f.masters[0].completed_b[0].1, Resp::Okay);
    assert_eq!(f.slaves[0].writes.len(), 0);
    assert_eq!(f.slaves[1].writes.len(), 1);
    assert_eq!(f.slaves[1].writes[0].beats, 4);
    assert_eq!(f.slaves[1].writes[0].base, cluster_addr(1, 0x40));
}

#[test]
fn mcast_write_forks_to_all_and_joins_one_b() {
    let mut f = fixture(2, 4, vec![vec![Xfer::write(clusters_set(4, 0x100), 8, 3)], vec![]]);
    f.run(10_000).expect("no deadlock");
    f.assert_protocol_clean();
    // exactly one B at the master
    assert_eq!(f.masters[0].completed_b.len(), 1);
    assert_eq!(f.masters[0].completed_b[0].1, Resp::Okay);
    let txn = f.masters[0].issued[0].0;
    // every slave got the burst exactly once, at its own base address
    for (i, s) in f.slaves.iter().enumerate() {
        assert_eq!(s.delivered_txns(), vec![txn], "slave {i}");
        assert_eq!(s.writes[0].base, cluster_addr(i, 0x100));
        assert_eq!(s.writes[0].beats, 8);
    }
    assert_eq!(f.xbar.stats.aw_mcast, 1);
    assert_eq!(f.xbar.stats.aw_forks, 4);
    // W source bandwidth used once, fabric replicated 4x
    assert_eq!(f.xbar.stats.w_beats_in, 8);
    assert_eq!(f.xbar.stats.w_beats_out, 32);
}

#[test]
fn mcast_b_join_waits_for_slowest_slave() {
    let mut f = fixture(1, 2, vec![vec![Xfer::write(clusters_set(2, 0), 2, 0)]]);
    f.slaves[1].b_lat = 40; // slow slave
    f.run(10_000).unwrap();
    f.assert_protocol_clean();
    assert_eq!(f.masters[0].completed_b.len(), 1);
    // the join can only complete after the slow slave's B latency
    let done = f.slaves[1].writes[0].done_at;
    assert!(f.xbar.stats.b_joined == 1);
    assert!(done + 40 <= 10_000);
}

#[test]
fn mcast_b_join_merges_slverr() {
    let mut f = fixture(1, 4, vec![vec![Xfer::write(clusters_set(4, 0), 2, 0)]]);
    f.slaves[2].wresp = Resp::SlvErr;
    f.run(10_000).unwrap();
    assert_eq!(f.masters[0].completed_b.len(), 1);
    assert_eq!(
        f.masters[0].completed_b[0].1,
        Resp::SlvErr,
        "any error leg must SLVERR the joined response"
    );
}

#[test]
fn mcast_subset_of_slaves() {
    // clusters 2..3 only (fix bit 19, mask bit 18)
    let set = AddrSet::new(cluster_addr(2, 0), CLUSTER_STRIDE);
    let mut f = fixture(1, 4, vec![vec![Xfer::write(set, 4, 0)]]);
    f.run(10_000).unwrap();
    f.assert_protocol_clean();
    assert!(f.slaves[0].writes.is_empty());
    assert!(f.slaves[1].writes.is_empty());
    assert_eq!(f.slaves[2].writes.len(), 1);
    assert_eq!(f.slaves[3].writes.len(), 1);
}

#[test]
fn concurrent_mcasts_two_masters_no_deadlock() {
    // Both masters multicast to all 4 slaves repeatedly — the commit
    // protocol must serialise them without deadlock.
    let script = |id| {
        (0..8)
            .map(|_| Xfer::write(clusters_set(4, 0x40 * id as u64), 4, id))
            .collect::<Vec<_>>()
    };
    let mut f = fixture(2, 4, vec![script(0), script(1)]);
    f.run(20_000).expect("commit protocol must prevent deadlock");
    f.assert_protocol_clean();
    assert_eq!(f.masters[0].completed_b.len(), 8);
    assert_eq!(f.masters[1].completed_b.len(), 8);
    for s in &f.slaves {
        assert_eq!(s.writes.len(), 16);
    }
}

#[test]
fn overlapping_target_sets_no_deadlock() {
    // M0 → slaves {0,1}, M1 → slaves {2,3}, M2 → all 4: partial overlap
    // exercises grant stealing by the priority encoder.
    let m0: Vec<Xfer> = (0..6)
        .map(|_| Xfer::write(AddrSet::new(CLUSTER_BASE, CLUSTER_STRIDE), 4, 0))
        .collect();
    let m2_set = AddrSet::new(cluster_addr(2, 0), CLUSTER_STRIDE);
    let m1: Vec<Xfer> = (0..6).map(|_| Xfer::write(m2_set, 4, 1)).collect();
    let m2: Vec<Xfer> = (0..6).map(|_| Xfer::write(clusters_set(4, 0), 4, 2)).collect();
    let mut f = fixture(3, 4, vec![m0, m1, m2]);
    f.run(30_000).expect("no deadlock under overlapping mcasts");
    f.assert_protocol_clean();
    assert_eq!(f.slaves[0].writes.len(), 12); // 6 from m0 + 6 from m2
    assert_eq!(f.slaves[2].writes.len(), 12); // 6 from m1 + 6 from m2
}

#[test]
fn unicast_and_mcast_mix_orders_cleanly() {
    let mut script = Vec::new();
    for i in 0..4 {
        script.push(Xfer::write(AddrSet::unicast(cluster_addr(i % 4, 0)), 2, 0));
        script.push(Xfer::write(clusters_set(4, 0x80), 2, 0));
    }
    let mut f = fixture(2, 4, vec![script.clone(), script]);
    f.run(30_000).unwrap();
    f.assert_protocol_clean();
    assert_eq!(f.masters[0].completed_b.len(), 8);
    assert_eq!(f.masters[1].completed_b.len(), 8);
}

#[test]
fn mcast_disabled_returns_decerr() {
    let mut cfg = XbarCfg::new("t", 1, 4, cluster_map(4, false));
    cfg.mcast_enabled = false;
    let (xbar, pool) = Xbar::with_pool(cfg, 2);
    let mut f = Fixture::new(xbar, pool, vec![vec![Xfer::write(clusters_set(4, 0), 2, 0)]]);
    f.run(10_000).unwrap();
    assert_eq!(f.masters[0].completed_b.len(), 1);
    assert_eq!(f.masters[0].completed_b[0].1, Resp::DecErr);
    for s in &f.slaves {
        assert!(s.writes.is_empty(), "baseline xbar must not deliver mcast");
    }
}

#[test]
fn unroutable_unicast_decerr() {
    let mut f = fixture(1, 2, vec![vec![Xfer::write(AddrSet::unicast(0xDEAD_0000), 3, 0)]]);
    f.run(10_000).unwrap();
    assert_eq!(f.masters[0].completed_b.len(), 1);
    assert_eq!(f.masters[0].completed_b[0].1, Resp::DecErr);
}

#[test]
fn same_id_different_slave_serialises() {
    // two writes, same AXI ID, different slaves: the second must wait
    // for the first B (fig. 2d ordering table)
    let script = vec![
        Xfer::write(AddrSet::unicast(cluster_addr(0, 0)), 2, 7),
        Xfer::write(AddrSet::unicast(cluster_addr(1, 0)), 2, 7),
    ];
    let mut f = fixture(1, 2, vec![script]);
    f.slaves[0].b_lat = 30;
    f.run(10_000).unwrap();
    f.assert_protocol_clean();
    assert_eq!(f.masters[0].completed_b.len(), 2);
    assert!(f.xbar.stats.stall_id_conflict > 0, "must have stalled on ID");
    // slave 1's write can only *finish* after slave 0's B was returned
    let d0 = f.slaves[0].writes[0].done_at;
    let d1 = f.slaves[1].writes[0].done_at;
    assert!(d1 > d0 + 30, "d0={d0} d1={d1}");
}

#[test]
fn mcast_throughput_half_rate_registered_fork() {
    // One master multicasting a long burst to 4 slaves: the registered
    // all-ready fork sustains ~1 beat per 2 cycles (fig. 3b calibration).
    let mut f = fixture(1, 4, vec![vec![Xfer::write(clusters_set(4, 0), 64, 0)]]);
    let cycles = f.run(10_000).unwrap();
    f.assert_protocol_clean();
    assert!(
        (2 * 64..2 * 64 + 40).contains(&(cycles as usize)),
        "expected ~half line rate, took {cycles} cycles"
    );
}

#[test]
fn mcast_throughput_full_rate_with_ideal_fork() {
    // Ablation: cooldown 0 restores a single-cycle fork at line rate.
    let mut cfg = XbarCfg::new("t", 1, 4, cluster_map(4, false));
    cfg.mcast_w_cooldown = 0;
    let (xbar, pool) = Xbar::with_pool(cfg, 2);
    let mut f = Fixture::new(xbar, pool, vec![vec![Xfer::write(clusters_set(4, 0), 64, 0)]]);
    let cycles = f.run(10_000).unwrap();
    f.assert_protocol_clean();
    assert!(
        cycles < 64 + 40,
        "ideal fork should be near line rate, took {cycles} cycles"
    );
}

//! Differential fuzz across the whole crossbar fabric.
//!
//! One random per-cluster workload — unicast writes, mask-form
//! multicasts, remote/LLC reads and in-network reduction groups
//! interleaved — is run end-to-end on every wide-network shape
//! (groups / flat / 3-level tree / mesh) in every fabric configuration
//! (optimised vs `force_naive`, end-to-end multicast ordering on/off,
//! fabric-side combining on/off) and checked **bit-exactly** against a
//! scalar golden memory model built directly from the generated job
//! list. The generator keeps every destination slot disjoint per
//! source (copies) or per group (commutative reductions), so the final
//! memory image is schedule-independent and the golden is exact.
//!
//! On top of memory equality the suite checks:
//!
//! * opt vs `force_naive` **cycle parity** per configuration (the
//!   §Perf contract, now covering the combine phase),
//! * the fork/join beat accounting on every run
//!   (`w_beats_out == w_beats_in + w_fork_extra − red_beats_saved`),
//! * the reduction invariant on reduce-only traffic:
//!   `red_beats_saved > 0 ⇒ w_beats_out < w_beats_in`,
//! * `fabric_reduce` and `e2e_mcast_order` never change memory — they
//!   are timing/beat optimisations only.
//!
//! Seeds are fixed (CI runs this with a short budget on every push);
//! concurrent *global* multicasts are generated only for the
//! `e2e`-armed configurations — on the RTL-faithful fabric they can
//! hit the documented inter-level W-order deadlock, which is a feature
//! of the model, not a fuzz bug (DESIGN.md §1).
//!
//! The chiplet cells rerun the same differential on multi-die packages
//! (DESIGN.md §10): {2,4} dies joined by D2D links of asymmetric width
//! ratio and latency, with the same scalar golden (the package must
//! deliver exactly the single-die bytes), opt/naive *and*
//! sequential/threaded cycle+stats parity per cell, and every cross-die
//! ledger drained. A `chiplets: 1` armed-but-unused guard cell pins the
//! flag-off path bit-identical to the plain fabric.

use axi_mcast::axi::mcast::AddrSet;
use axi_mcast::axi::reduce::ReduceOp;
use axi_mcast::axi::xbar::XbarStats;
use axi_mcast::occamy::config::{CLUSTER_BASE, CLUSTER_STRIDE, LLC_BASE};
use axi_mcast::occamy::{Cmd, NopCompute, Soc, SocConfig, SocMem, WideShape};
use axi_mcast::util::prng::Pcg;

const N: usize = 8;
/// Per-cluster L1 region map (l1_bytes = 128 KiB = 0x2_0000):
/// sources are seeded once and never written; every write destination
/// is a per-source or per-group slot, so the outcome is order-free.
const SRC_OFF: u64 = 0x0000; // 16 KiB of seeded source data
const UNI_OFF: u64 = 0x8000; // unicast dst slots, 1 KiB per source
const MC_OFF: u64 = 0xC000; // multicast dst slots, 1 KiB per source
const RED_OFF: u64 = 0x1_0000; // reduction dst slots, 1 KiB per group
const RD_OFF: u64 = 0x1_8000; // read-back dst slots, 1 KiB per source
const SLOT: u64 = 0x400;

fn l1(c: usize, off: u64) -> u64 {
    CLUSTER_BASE + c as u64 * CLUSTER_STRIDE + off
}

/// One generated job, in a form both the simulator programs and the
/// scalar golden can be built from.
#[derive(Debug, Clone)]
enum Job {
    /// Copy `bytes` from `src` (absolute, inside a seeded region) to
    /// every address of `dst`.
    Copy { src: u64, dst: AddrSet, bytes: u64 },
    /// Reduction contribution: `dst op= src` over `bytes / 8` lanes.
    Reduce {
        src: u64,
        dst: u64,
        bytes: u64,
        group: u32,
        op: ReduceOp,
    },
    /// Pure read (remote L1 / LLC → own RD slot): a copy whose source
    /// side exercises AR/R through the fabric.
    Read { src: u64, dst: u64, bytes: u64 },
}

#[derive(Debug, Clone)]
struct Workload {
    /// Per cluster, in issue order.
    jobs: Vec<Vec<Job>>,
    /// (group, op, members, dst) — opened on the membership oracle.
    groups: Vec<(u32, ReduceOp, Vec<usize>, u64)>,
}

/// Deterministic f64 seed value for lane `i` of cluster `c`'s source
/// region (integer-valued, so reductions are exact in any order).
fn seed_val(c: usize, i: usize) -> f64 {
    (((c * 1_000 + i) % 997) as i64 - 498) as f64
}

fn seed_mem(mem: &mut SocMem) {
    for c in 0..N {
        let vals: Vec<f64> = (0..(0x4000 / 8)).map(|i| seed_val(c, i)).collect();
        mem.write_f64(l1(c, SRC_OFF), &vals);
    }
    // LLC source window: reuse a distinct pattern
    let vals: Vec<f64> = (0..(0x1000 / 8)).map(|i| seed_val(N, i)).collect();
    mem.write_f64(LLC_BASE, &vals);
}

/// Generate one workload. `global_mcasts` additionally sprinkles
/// all-cluster multicasts (only legal under e2e ordering);
/// `with_reduce` includes reduction groups.
fn gen_workload(seed: u64, global_mcasts: bool, with_reduce: bool) -> Workload {
    let mut rng = Pcg::new(seed);
    let mut jobs: Vec<Vec<Job>> = vec![Vec::new(); N];
    let mut groups = Vec::new();

    if with_reduce {
        let n_groups = 2 + rng.below(2) as usize; // 2..=3
        for g in 0..n_groups {
            let dst_cluster = rng.below(N as u64) as usize;
            let op = match rng.below(3) {
                0 => ReduceOp::Sum,
                1 => ReduceOp::Max,
                _ => ReduceOp::Min,
            };
            // at least 2 fabric members besides the destination
            let mut members = Vec::new();
            for c in 0..N {
                if c != dst_cluster && (members.len() < 2 || rng.below(2) == 0) {
                    members.push(c);
                }
            }
            let bytes = 64 * (1 + rng.below(8)); // 64..512 B
            let dst = l1(dst_cluster, RED_OFF + g as u64 * SLOT);
            for &m in &members {
                jobs[m].push(Job::Reduce {
                    src: l1(m, SRC_OFF + (g as u64) * 0x800),
                    dst,
                    bytes,
                    group: g as u32,
                    op,
                });
            }
            groups.push((g as u32, op, members, dst));
        }
    }

    for c in 0..N {
        let n_jobs = 1 + rng.below(4);
        for _ in 0..n_jobs {
            let bytes = 64 * (1 + rng.below(8));
            let src_off = SRC_OFF + rng.below(24) * 0x200;
            match rng.below(10) {
                0..=3 => {
                    // unicast write into the target's per-source slot
                    let t = rng.below(N as u64) as usize;
                    jobs[c].push(Job::Copy {
                        src: l1(c, src_off),
                        dst: AddrSet::unicast(l1(t, UNI_OFF + c as u64 * SLOT)),
                        bytes,
                    });
                }
                4..=6 => {
                    // multicast: an aligned pair containing c is legal
                    // on every fabric; global sets only under e2e
                    let (first, count) = if global_mcasts && rng.below(3) == 0 {
                        (0, N)
                    } else {
                        (c & !1, 2)
                    };
                    let mask = (count as u64 - 1) * CLUSTER_STRIDE;
                    jobs[c].push(Job::Copy {
                        src: l1(c, src_off),
                        dst: AddrSet::new(
                            l1(first, MC_OFF + c as u64 * SLOT),
                            mask,
                        ),
                        bytes,
                    });
                }
                7..=8 => {
                    // remote L1 read into the own RD slot
                    let t = rng.below(N as u64) as usize;
                    jobs[c].push(Job::Read {
                        src: l1(t, src_off),
                        dst: l1(c, RD_OFF + c as u64 * SLOT),
                        bytes,
                    });
                }
                _ => {
                    // LLC read
                    jobs[c].push(Job::Read {
                        src: LLC_BASE + rng.below(8) * 0x200,
                        dst: l1(c, RD_OFF + c as u64 * SLOT),
                        bytes: bytes.min(0x400),
                    });
                }
            }
        }
    }
    Workload { jobs, groups }
}

/// Lower a workload to per-cluster command programs.
fn programs(w: &Workload) -> Vec<Vec<Cmd>> {
    w.jobs
        .iter()
        .map(|jobs| {
            let mut p = Vec::new();
            for (t, j) in jobs.iter().enumerate() {
                match j {
                    Job::Copy { src, dst, bytes } => p.push(Cmd::Dma {
                        src: *src,
                        dst: *dst,
                        bytes: *bytes,
                        tag: t as u64,
                    }),
                    Job::Reduce {
                        src,
                        dst,
                        bytes,
                        group,
                        op,
                    } => p.push(Cmd::DmaReduce {
                        src: *src,
                        dst: *dst,
                        bytes: *bytes,
                        tag: t as u64,
                        group: *group,
                        op: *op,
                    }),
                    Job::Read { src, dst, bytes } => p.push(Cmd::Dma {
                        src: *src,
                        dst: AddrSet::unicast(*dst),
                        bytes: *bytes,
                        tag: t as u64,
                    }),
                }
            }
            if !p.is_empty() {
                p.push(Cmd::WaitDma);
            }
            p
        })
        .collect()
}

/// The scalar golden: seed an identical memory image, then apply every
/// job functionally — per cluster in issue order (matches per-cluster
/// DMA serialisation); cross-cluster order is irrelevant because all
/// destination slots are disjoint per source and reductions commute.
fn golden(cfg: &SocConfig, w: &Workload) -> Vec<Vec<u8>> {
    let mut mem = SocMem::new(cfg);
    seed_mem(&mut mem);
    for jobs in &w.jobs {
        for j in jobs {
            match j {
                Job::Copy { src, dst, bytes } => {
                    let dsts = dst.enumerate();
                    mem.dma_copy(*src, &dsts, *bytes);
                }
                Job::Reduce {
                    src,
                    dst,
                    bytes,
                    op,
                    ..
                } => mem.reduce_f64(*op, *dst, *src, (*bytes / 8) as usize),
                Job::Read { src, dst, bytes } => {
                    mem.dma_copy(*src, &[*dst], *bytes);
                }
            }
        }
    }
    mem.l1
}

struct RunOut {
    cycles: u64,
    wide: XbarStats,
    l1: Vec<Vec<u8>>,
}

fn run(shape: &WideShape, w: &Workload, force_naive: bool, e2e: bool, red: bool) -> RunOut {
    let mut cfg = SocConfig::tiny(N);
    cfg.wide_shape = shape.clone();
    cfg.force_naive = force_naive;
    cfg.e2e_mcast_order = e2e;
    cfg.fabric_reduce = red;
    let mut soc = Soc::new(cfg.clone());
    seed_mem(&mut soc.mem);
    for (g, op, members, dst) in &w.groups {
        soc.open_reduce_group(*g, *op, members, *dst);
    }
    soc.load_programs(programs(w));
    soc.run_default(&mut NopCompute).unwrap_or_else(|e| {
        panic!(
            "fuzz run on {} (naive={force_naive} e2e={e2e} red={red}): {e}",
            shape.label()
        )
    });
    RunOut {
        cycles: soc.cycles,
        wide: soc.wide.stats_sum(),
        l1: soc.mem.l1.clone(),
    }
}

fn shapes() -> Vec<WideShape> {
    vec![
        WideShape::Groups,
        WideShape::Flat,
        WideShape::Tree(vec![2, 2, 2]),
        WideShape::Mesh(2),
        WideShape::Ring(4),
        WideShape::Torus(2, 2),
        WideShape::RingMesh(2, 2),
    ]
}

fn assert_accounting(s: &XbarStats, ctx: &str) {
    assert_eq!(
        s.w_beats_out,
        s.w_beats_in + s.w_fork_extra - s.red_beats_saved,
        "{ctx}: W fork/join accounting broken: {s:?}"
    );
    assert_eq!(s.decerr, 0, "{ctx}: unexpected DECERR");
}

/// The main differential matrix: every shape × {opt, naive} ×
/// {e2e off, on} × {reduce off, on}, one fixed-seed workload each,
/// memory checked against the scalar golden in every cell and cycle
/// parity checked between the opt/naive halves of each cell.
/// (~128 full SoC runs — release-only, like the fig3c paper points,
/// so the debug `cargo test -q` tier stays fast.)
#[test]
#[cfg_attr(debug_assertions, ignore)]
fn differential_matrix_against_scalar_golden() {
    for seed in [0xFAB1u64, 0xFAB2] {
        // e2e-off runs get only pair multicasts (safe everywhere); the
        // golden covers both since memory is mcast-set independent...
        // but the *job lists* differ, so each flavor has its own golden.
        let base = gen_workload(seed, false, true);
        let rich = gen_workload(seed ^ 0x9E37, true, true);
        let cfg = SocConfig::tiny(N);
        let base_golden = golden(&cfg, &base);
        let rich_golden = golden(&cfg, &rich);
        for shape in shapes() {
            for red in [false, true] {
                // RTL-faithful ordering: pair multicasts only
                let opt = run(&shape, &base, false, false, red);
                let naive = run(&shape, &base, true, false, red);
                let ctx = format!("seed {seed:#x} {} e2e=off red={red}", shape.label());
                assert_eq!(opt.l1, base_golden, "{ctx}: memory diverged from golden");
                assert_eq!(naive.l1, base_golden, "{ctx}: naive memory diverged");
                assert_eq!(opt.cycles, naive.cycles, "{ctx}: cycle parity broken");
                assert_eq!(opt.wide, naive.wide, "{ctx}: stats parity broken");
                assert_accounting(&opt.wide, &ctx);

                // reservation fabric armed: global multicasts join in
                let opt = run(&shape, &rich, false, true, red);
                let naive = run(&shape, &rich, true, true, red);
                let ctx = format!("seed {seed:#x} {} e2e=on red={red}", shape.label());
                assert_eq!(opt.l1, rich_golden, "{ctx}: memory diverged from golden");
                assert_eq!(naive.l1, rich_golden, "{ctx}: naive memory diverged");
                assert_eq!(opt.cycles, naive.cycles, "{ctx}: cycle parity broken");
                assert_eq!(opt.wide, naive.wide, "{ctx}: stats parity broken");
                assert_accounting(&opt.wide, &ctx);
            }
        }
    }
}

/// `fabric_reduce` is a pure timing/beat optimisation: with the flag
/// off the tagged bursts travel individually, with it on they combine
/// at the join points — the memory image must be identical, and the
/// combining runs must actually have combined.
#[test]
fn fabric_reduce_changes_beats_not_memory() {
    let w = gen_workload(0xD0D0, false, true);
    for shape in shapes() {
        let off = run(&shape, &w, false, false, false);
        let on = run(&shape, &w, false, false, true);
        assert_eq!(
            on.l1,
            off.l1,
            "{}: fabric_reduce changed memory",
            shape.label()
        );
        assert_eq!(off.wide.red_joins, 0);
        assert_eq!(off.wide.red_beats_saved, 0);
        assert!(
            on.wide.red_joins > 0,
            "{}: converging groups never combined",
            shape.label()
        );
        // joins absorb beats: the combining fabric moves strictly
        // fewer W beats hop-for-hop than the endpoint-resolved one
        assert!(
            on.wide.w_beats_out < off.wide.w_beats_out,
            "{}: combining saved nothing ({} vs {})",
            shape.label(),
            on.wide.w_beats_out,
            off.wide.w_beats_out
        );
    }
}

/// Per-channel deadlines armed on a healthy fabric must be
/// bit-identical to the unarmed fabric: the timeout machinery only
/// *observes* until a deadline actually fires, so cycles, memory and
/// every statistic (including the zeroed timeout counters) match.
#[test]
fn armed_but_unfired_timeouts_are_bit_identical() {
    let w = gen_workload(0xA7ED, true, true);
    for shape in [WideShape::Groups, WideShape::Flat] {
        let plain = run(&shape, &w, false, true, true);
        let armed = {
            let mut cfg = SocConfig::tiny(N);
            cfg.wide_shape = shape.clone();
            cfg.e2e_mcast_order = true;
            cfg.fabric_reduce = true;
            cfg.req_timeout = Some(5_000);
            cfg.cpl_timeout = Some(2_000);
            run_cfg(cfg, &w)
        };
        let ctx = format!("{} armed-vs-off", shape.label());
        assert_eq!(armed.out.cycles, plain.cycles, "{ctx}: cycle divergence");
        assert_eq!(armed.out.l1, plain.l1, "{ctx}: memory divergence");
        assert_eq!(armed.out.wide, plain.wide, "{ctx}: stats divergence");
        assert_eq!(armed.out.wide.req_timeouts, 0, "{ctx}");
        assert_eq!(armed.out.wide.cpl_timeouts, 0, "{ctx}");
    }
}

struct FaultedOut {
    out: RunOut,
    open_cpl_legs: usize,
    open_reductions: usize,
    resv_live: usize,
}

/// Run a prepared config (fault plans and deadlines already set) over a
/// workload, asserting completion and returning the drained-state
/// snapshot alongside the usual outputs.
fn run_cfg(cfg: SocConfig, w: &Workload) -> FaultedOut {
    let mut soc = Soc::new(cfg.clone());
    seed_mem(&mut soc.mem);
    for (g, op, members, dst) in &w.groups {
        soc.open_reduce_group(*g, *op, members, *dst);
    }
    soc.load_programs(programs(w));
    soc.run_default(&mut NopCompute)
        .unwrap_or_else(|e| panic!("faulted fuzz run must recover, got: {e}"));
    let report = soc.deadlock_report();
    FaultedOut {
        out: RunOut {
            cycles: soc.cycles,
            wide: soc.wide.stats_sum(),
            l1: soc.mem.l1.clone(),
        },
        open_cpl_legs: report.open_cpl_legs,
        open_reductions: report.open_reductions,
        resv_live: report.resv_live_tickets,
    }
}

/// Fault-injecting differential cells: every `FaultKind` on a random
/// victim's L1 port under the full feature stack (global multicasts +
/// e2e ordering + in-network reduction) with deadlines armed. Each
/// cell must (1) run to completion without the watchdog, (2) drain
/// every fabric ledger, (3) satisfy the extended fork/join accounting
/// `w_beats_out == w_beats_in + w_fork_extra − red_beats_saved −
/// w_dropped`, and (4) hold opt-vs-naive *and* sequential-vs-threaded
/// bit parity — the timeout engine replays exactly under the event
/// horizon and the parallel stepper.
#[test]
#[cfg_attr(debug_assertions, ignore)]
fn faulted_cells_recover_with_engine_parity() {
    use axi_mcast::workloads::faults::FaultKind;
    use axi_mcast::occamy::config::FaultSite;

    for (i, kind) in FaultKind::ALL.iter().enumerate() {
        let seed = 0xFA17 + i as u64;
        let mut w = gen_workload(seed, true, true);
        let mut rng = Pcg::new(seed ^ 0xBAD);
        let victim = rng.below(N as u64) as usize;
        // pin victim-touching traffic so every kind deterministically
        // bites: a global multicast (first B at the victim — Stall,
        // GrantHang, DropB) and a read of the victim's L1 (first R
        // burst — DropR), issued by a healthy neighbour
        let nb = (victim + 1) % N;
        w.jobs[nb].push(Job::Copy {
            src: l1(nb, SRC_OFF),
            dst: AddrSet::new(
                l1(0, MC_OFF + nb as u64 * SLOT),
                (N as u64 - 1) * CLUSTER_STRIDE,
            ),
            bytes: 64,
        });
        w.jobs[nb].push(Job::Read {
            src: l1(victim, SRC_OFF),
            dst: l1(nb, RD_OFF + nb as u64 * SLOT),
            bytes: 64,
        });
        let mk_cfg = |naive: bool, threads: usize| {
            let mut cfg = SocConfig::tiny(N);
            cfg.e2e_mcast_order = true;
            cfg.fabric_reduce = true;
            cfg.req_timeout = Some(5_000);
            cfg.cpl_timeout = Some(2_000);
            cfg.faults = vec![(FaultSite::ClusterL1(victim), kind.plan())];
            cfg.force_naive = naive;
            cfg.threads = threads;
            cfg
        };
        let ctx = format!("kind {} victim {victim}", kind.name());
        let opt = run_cfg(mk_cfg(false, 1), &w);
        let naive = run_cfg(mk_cfg(true, 1), &w);
        let par = run_cfg(mk_cfg(false, 2), &w);

        for (r, eng) in [(&opt, "opt"), (&naive, "naive"), (&par, "par")] {
            assert_eq!(r.open_cpl_legs, 0, "{ctx} {eng}: undrained cpl legs");
            assert_eq!(r.open_reductions, 0, "{ctx} {eng}: undrained reductions");
            assert_eq!(r.resv_live, 0, "{ctx} {eng}: leaked resv tickets");
            let s = &r.out.wide;
            assert_eq!(
                s.w_beats_out,
                s.w_beats_in + s.w_fork_extra - s.red_beats_saved - s.w_dropped,
                "{ctx} {eng}: faulted fork/join accounting broken: {s:?}"
            );
            assert!(
                s.req_timeouts + s.cpl_timeouts > 0,
                "{ctx} {eng}: the injected fault must trip at least one deadline"
            );
        }
        assert_eq!(opt.out.cycles, naive.out.cycles, "{ctx}: opt/naive cycle parity");
        assert_eq!(opt.out.wide, naive.out.wide, "{ctx}: opt/naive stats parity");
        assert_eq!(opt.out.l1, naive.out.l1, "{ctx}: opt/naive memory parity");
        assert_eq!(opt.out.cycles, par.out.cycles, "{ctx}: thread cycle parity");
        assert_eq!(opt.out.wide, par.out.wide, "{ctx}: thread stats parity");
        assert_eq!(opt.out.l1, par.out.l1, "{ctx}: thread memory parity");
    }
}

/// Package config for the chiplet cells: `tiny(8)` split into
/// `chiplets` dies joined by D2D links of the given width ratio and
/// latency. The leader span is clamped to one die so the per-die trees
/// stay well-formed at every count.
fn pkg_cfg(chiplets: usize, width: u32, latency: u32) -> SocConfig {
    let mut cfg = SocConfig::tiny(N);
    cfg.clusters_per_group = cfg.clusters_per_group.min(N / chiplets);
    cfg.package.chiplets = chiplets;
    cfg.package.d2d_width_ratio = width;
    cfg.package.d2d_latency = latency;
    cfg.validate()
        .unwrap_or_else(|e| panic!("{chiplets}-die fuzz cfg: {e}"));
    cfg
}

/// Chiplet differential cells: random unicast + multicast + reduction
/// interleavings on {2,4}-die packages with asymmetric D2D link
/// parameters, memory bit-exact against the *single-die* scalar golden
/// (the fabric of fabrics must deliver exactly the same bytes), with
/// opt/naive and sequential/threaded cycle+stats parity per cell and
/// the cross-die ledgers drained. The e2e-armed flavour sends global
/// multicasts (and their reservation tickets) through the D2D
/// gateways; the e2e-off flavour keeps multicast pairs die-local and
/// crosses the gateways with unicasts, reads and reductions.
#[test]
#[cfg_attr(debug_assertions, ignore)]
fn chiplet_cells_against_scalar_golden() {
    let ref_cfg = SocConfig::tiny(N);
    for (chiplets, width, latency) in [(2usize, 4u32, 8u32), (2, 8, 2), (4, 2, 12)] {
        let seed = 0xC41F ^ ((chiplets as u64) << 8) ^ ((width as u64) << 4) ^ latency as u64;
        let base = gen_workload(seed, false, true);
        let base_golden = golden(&ref_cfg, &base);
        let rich = gen_workload(seed ^ 0x9E37, true, true);
        let rich_golden = golden(&ref_cfg, &rich);
        for (w, gold, e2e) in [(&base, &base_golden, false), (&rich, &rich_golden, true)] {
            for red in [false, true] {
                let ctx =
                    format!("{chiplets} dies d2d {width}:1/{latency}cy e2e={e2e} red={red}");
                let mk = |naive: bool, threads: usize| {
                    let mut cfg = pkg_cfg(chiplets, width, latency);
                    cfg.e2e_mcast_order = e2e;
                    cfg.fabric_reduce = red;
                    cfg.force_naive = naive;
                    cfg.threads = threads;
                    cfg
                };
                let opt = run_cfg(mk(false, 1), w);
                let naive = run_cfg(mk(true, 1), w);
                let par = run_cfg(mk(false, 4), w);
                for (r, eng) in [(&opt, "opt"), (&naive, "naive"), (&par, "par")] {
                    assert_eq!(
                        r.out.l1, *gold,
                        "{ctx} {eng}: memory diverged from the single-die scalar golden"
                    );
                    assert_eq!(r.open_cpl_legs, 0, "{ctx} {eng}: undrained cpl legs");
                    assert_eq!(r.open_reductions, 0, "{ctx} {eng}: undrained reductions");
                    assert_eq!(r.resv_live, 0, "{ctx} {eng}: leaked resv tickets");
                    assert_accounting(&r.out.wide, &format!("{ctx} {eng}"));
                }
                assert_eq!(opt.out.cycles, naive.out.cycles, "{ctx}: opt/naive cycle parity");
                assert_eq!(opt.out.wide, naive.out.wide, "{ctx}: opt/naive stats parity");
                assert_eq!(opt.out.cycles, par.out.cycles, "{ctx}: thread cycle parity");
                assert_eq!(opt.out.wide, par.out.wide, "{ctx}: thread stats parity");
            }
        }
    }
}

/// `chiplets: 1` armed-but-unused guard cell: a package config with
/// non-default D2D parameters but a single die is the plain single-die
/// fabric, bit for bit — cycles, statistics and memory.
#[test]
fn single_chiplet_package_is_bit_identical() {
    let w = gen_workload(0x1D1E, true, true);
    let mk = |armed: bool| {
        let mut cfg = SocConfig::tiny(N);
        cfg.e2e_mcast_order = true;
        cfg.fabric_reduce = true;
        if armed {
            cfg.package.chiplets = 1;
            cfg.package.d2d_width_ratio = 8;
            cfg.package.d2d_latency = 16;
            cfg.validate().unwrap();
        }
        cfg
    };
    let plain = run_cfg(mk(false), &w);
    let armed = run_cfg(mk(true), &w);
    assert_eq!(armed.out.cycles, plain.out.cycles, "chiplets=1: cycle divergence");
    assert_eq!(armed.out.wide, plain.out.wide, "chiplets=1: stats divergence");
    assert_eq!(armed.out.l1, plain.out.l1, "chiplets=1: memory divergence");
}

/// The ISSUE invariant on reduce-only traffic (no multicast forks to
/// mask the saving): `red_beats_saved > 0 ⇒ w_beats_out < w_beats_in`.
#[test]
fn reduce_only_traffic_shrinks_upstream() {
    for seed in [0x5EED1u64, 0x5EED2, 0x5EED3] {
        let mut rng = Pcg::new(seed);
        let dst_cluster = rng.below(N as u64) as usize;
        let members: Vec<usize> = (0..N).filter(|&c| c != dst_cluster).collect();
        let bytes = 64 * (2 + rng.below(6));
        let dst = l1(dst_cluster, RED_OFF);
        let w = Workload {
            jobs: (0..N)
                .map(|c| {
                    if members.contains(&c) {
                        vec![Job::Reduce {
                            src: l1(c, SRC_OFF),
                            dst,
                            bytes,
                            group: 0,
                            op: ReduceOp::Sum,
                        }]
                    } else {
                        Vec::new()
                    }
                })
                .collect(),
            groups: vec![(0, ReduceOp::Sum, members.clone(), dst)],
        };
        let cfg = SocConfig::tiny(N);
        let gold = golden(&cfg, &w);
        for shape in shapes() {
            let out = run(&shape, &w, false, false, true);
            assert_eq!(out.l1, gold, "seed {seed:#x} {}: memory", shape.label());
            assert!(
                out.wide.red_beats_saved > 0,
                "seed {seed:#x} {}: 7 converging members must combine somewhere",
                shape.label()
            );
            assert!(
                out.wide.w_beats_out < out.wide.w_beats_in,
                "seed {seed:#x} {}: saved {} beats but out ({}) >= in ({})",
                shape.label(),
                out.wide.red_beats_saved,
                out.wide.w_beats_out,
                out.wide.w_beats_in
            );
            assert_accounting(&out.wide, &format!("seed {seed:#x} {}", shape.label()));
        }
    }
}

//! Shared integration-test harness: scripted AXI masters, golden
//! slaves, and a run loop with the deadlock watchdog.

// Compiled once per test binary; no single binary uses every helper.
#![allow(dead_code)]

use std::collections::{HashMap, VecDeque};

use axi_mcast::axi::golden::SimSlave;
use axi_mcast::axi::mcast::AddrSet;
use axi_mcast::axi::types::{ArBeat, AwBeat, AxiId, AxiLink, LinkId, LinkPool, Resp, Txn, WBeat};
use axi_mcast::axi::xbar::Xbar;
use axi_mcast::sim::engine::{Engine, SimError, StepResult, Watchdog};

/// One scripted transfer.
#[derive(Debug, Clone)]
pub struct Xfer {
    pub dest: AddrSet,
    pub beats: u32,
    pub id: AxiId,
    pub is_mcast: bool,
    pub read: bool,
}

impl Xfer {
    pub fn write(dest: AddrSet, beats: u32, id: AxiId) -> Xfer {
        let is_mcast = !dest.is_singleton();
        Xfer {
            dest,
            beats,
            id,
            is_mcast,
            read: false,
        }
    }

    pub fn read(addr: u64, beats: u32, id: AxiId) -> Xfer {
        Xfer {
            dest: AddrSet::unicast(addr),
            beats,
            id,
            is_mcast: false,
            read: true,
        }
    }
}

#[derive(Debug)]
enum MState {
    Idle,
    SendW { txn: Txn, left: u32 },
}

/// A scripted AXI master attached to one link.
pub struct TestMaster {
    pub idx: usize,
    pub link: LinkId,
    pub script: VecDeque<Xfer>,
    state: MState,
    pub issued: Vec<(Txn, Xfer)>,
    pub completed_b: Vec<(Txn, Resp)>,
    pub completed_r: Vec<(Txn, Resp, u32)>,
    r_progress: HashMap<Txn, u32>,
    pub inflight: usize,
    pub max_inflight: usize,
}

impl TestMaster {
    pub fn new(idx: usize, link: LinkId, script: Vec<Xfer>) -> TestMaster {
        TestMaster {
            idx,
            link,
            script: script.into(),
            state: MState::Idle,
            issued: Vec::new(),
            completed_b: Vec::new(),
            completed_r: Vec::new(),
            r_progress: HashMap::new(),
            inflight: 0,
            max_inflight: 4,
        }
    }

    pub fn done(&self) -> bool {
        self.script.is_empty() && matches!(self.state, MState::Idle) && self.inflight == 0
    }

    pub fn step(&mut self, link: &mut AxiLink, next_txn: &mut Txn) {
        // collect responses
        while let Some(b) = link.b.pop() {
            self.completed_b.push((b.txn, b.resp));
            self.inflight -= 1;
        }
        while let Some(r) = link.r.pop() {
            let cnt = self.r_progress.entry(r.txn).or_insert(0);
            *cnt += 1;
            if r.last {
                let beats = *cnt;
                self.r_progress.remove(&r.txn);
                self.completed_r.push((r.txn, r.resp, beats));
                self.inflight -= 1;
            }
        }
        // W streaming
        if let MState::SendW { txn, left } = self.state {
            if link.w.can_push() {
                link.w.push(WBeat {
                    last: left == 1,
                    src: self.idx,
                    txn,
                });
                if left == 1 {
                    self.state = MState::Idle;
                } else {
                    self.state = MState::SendW {
                        txn,
                        left: left - 1,
                    };
                }
            }
            return;
        }
        // issue next transfer
        if self.inflight >= self.max_inflight {
            return;
        }
        let Some(x) = self.script.front() else {
            return;
        };
        if x.read {
            if link.ar.can_push() {
                let x = self.script.pop_front().unwrap();
                let txn = *next_txn;
                *next_txn += 1;
                link.ar.push(ArBeat {
                    id: x.id,
                    addr: x.dest.addr,
                    beats: x.beats,
                    beat_bytes: 64,
                    src: self.idx,
                    txn,
                });
                self.issued.push((txn, x));
                self.inflight += 1;
            }
        } else if link.aw.can_push() {
            let x = self.script.pop_front().unwrap();
            let txn = *next_txn;
            *next_txn += 1;
            link.aw.push(AwBeat {
                id: x.id,
                dest: x.dest,
                beats: x.beats,
                beat_bytes: 64,
                is_mcast: x.is_mcast,
                exclude: None,
                window: None,
                src: self.idx,
                txn,
                ticket: None,
                reduce: None,
            });
            self.state = MState::SendW {
                txn,
                left: x.beats,
            };
            self.issued.push((txn, x));
            self.inflight += 1;
        }
    }
}

/// A complete single-xbar test fixture.
pub struct Fixture {
    pub xbar: Xbar,
    pub pool: LinkPool,
    pub masters: Vec<TestMaster>,
    pub slaves: Vec<SimSlave>,
    pub next_txn: Txn,
}

impl Fixture {
    /// Masters on the xbar's master-side links, slaves on its
    /// slave-side links (the `Xbar::with_pool` layout).
    pub fn new(xbar: Xbar, pool: LinkPool, scripts: Vec<Vec<Xfer>>) -> Fixture {
        let n_m = xbar.cfg.n_masters;
        let n_s = xbar.cfg.n_slaves;
        assert_eq!(scripts.len(), n_m);
        let masters = scripts
            .into_iter()
            .enumerate()
            .map(|(i, s)| TestMaster::new(i, xbar.m_links[i], s))
            .collect();
        let slaves = (0..n_s).map(SimSlave::new).collect();
        Fixture {
            xbar,
            pool,
            masters,
            slaves,
            next_txn: 1,
        }
    }

    /// Run until all masters are done and the fabric drains.
    pub fn run(&mut self, stall_cycles: u64) -> Result<u64, SimError> {
        let mut eng = Engine::new(Watchdog {
            stall_cycles,
            max_cycles: 50_000_000,
        });
        let xbar = &mut self.xbar;
        let pool = &mut self.pool;
        let masters = &mut self.masters;
        let slaves = &mut self.slaves;
        let next_txn = &mut self.next_txn;
        let s_links: Vec<LinkId> = xbar.s_links.clone();
        eng.run(|cy| {
            for m in masters.iter_mut() {
                m.step(&mut pool[m.link], next_txn);
            }
            xbar.step(pool);
            for (i, s) in slaves.iter_mut().enumerate() {
                s.step(cy, &mut pool[s_links[i]]);
            }
            pool.tick_all();
            let progress = pool.moved_total();
            let all_done = masters.iter().all(|m| m.done())
                && !xbar.busy()
                && slaves.iter().all(|s| s.idle());
            if all_done {
                StepResult::Done
            } else {
                StepResult::Running { progress }
            }
        })
    }

    pub fn assert_protocol_clean(&self) {
        for s in &self.slaves {
            s.assert_clean();
        }
    }
}

/// Occamy-style address map over `n` cluster slaves (+ optional extra
/// non-mcast "llc" slave at index `n`): cluster i at
/// `0x0100_0000 + i*0x4_0000`.
pub const CLUSTER_BASE: u64 = 0x0100_0000;
pub const CLUSTER_STRIDE: u64 = 0x4_0000;

pub fn cluster_map(n: usize, with_llc: bool) -> axi_mcast::axi::addr_map::AddrMap {
    use axi_mcast::axi::addr_map::{AddrMap, AddrRule};
    let mut rules: Vec<AddrRule> = (0..n)
        .map(|i| {
            AddrRule::new(
                CLUSTER_BASE + i as u64 * CLUSTER_STRIDE,
                CLUSTER_BASE + (i as u64 + 1) * CLUSTER_STRIDE,
                i,
                &format!("cluster{i}"),
            )
            .with_mcast()
        })
        .collect();
    let n_slaves = if with_llc {
        rules.push(AddrRule::new(0x8000_0000, 0x8040_0000, n, "llc"));
        n + 1
    } else {
        n
    };
    AddrMap::new(rules, n_slaves).unwrap()
}

/// Address of cluster `i` plus offset.
pub fn cluster_addr(i: usize, off: u64) -> u64 {
    CLUSTER_BASE + i as u64 * CLUSTER_STRIDE + off
}

/// Mask-form set covering clusters `[0, count)` at `off`; count must be
/// a power of two.
pub fn clusters_set(count: usize, off: u64) -> AddrSet {
    assert!(count.is_power_of_two());
    let mask = (count as u64 - 1) * CLUSTER_STRIDE;
    AddrSet::new(CLUSTER_BASE + off, mask)
}

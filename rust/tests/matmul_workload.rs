//! Fig. 3c workload integration tests. The full 32-cluster runs are
//! release-only (they simulate ~300k SoC cycles each); `make test` runs
//! the suite with `--release`.

use axi_mcast::occamy::SocConfig;
use axi_mcast::workloads::matmul::{run_matmul, MatmulMode, RustTileExec};

#[test]
#[cfg_attr(debug_assertions, ignore)]
fn baseline_matches_paper_point() {
    let r = run_matmul(&SocConfig::default(), MatmulMode::Baseline, &mut RustTileExec);
    assert!(r.numerics_ok);
    // paper: 114.4 GFLOPS at OI 1.9 — accept ±8%
    assert!((r.gflops - 114.4).abs() / 114.4 < 0.08, "gflops {}", r.gflops);
    assert!((r.oi_read - 1.9).abs() < 0.15, "oi {}", r.oi_read);
}

#[test]
#[cfg_attr(debug_assertions, ignore)]
fn sw_mcast_matches_paper_point() {
    let base = run_matmul(&SocConfig::default(), MatmulMode::Baseline, &mut RustTileExec);
    let r = run_matmul(&SocConfig::default(), MatmulMode::SwMcast, &mut RustTileExec);
    assert!(r.numerics_ok);
    let oi_gain = r.oi_read / base.oi_read;
    let perf_gain = r.gflops / base.gflops;
    // paper: OI x3.7, perf x2.6
    assert!((oi_gain - 3.7).abs() < 0.3, "oi gain {oi_gain}");
    assert!((perf_gain - 2.6).abs() < 0.3, "perf gain {perf_gain}");
}

#[test]
#[cfg_attr(debug_assertions, ignore)]
fn hw_mcast_matches_paper_point() {
    let base = run_matmul(&SocConfig::default(), MatmulMode::Baseline, &mut RustTileExec);
    let r = run_matmul(&SocConfig::default(), MatmulMode::HwMcast, &mut RustTileExec);
    assert!(r.numerics_ok);
    let oi_gain = r.oi_read / base.oi_read;
    let perf_gain = r.gflops / base.gflops;
    // paper: OI x16.5, perf x3.4, 391.4 GFLOPS
    assert!((oi_gain - 16.5).abs() < 0.8, "oi gain {oi_gain}");
    assert!((perf_gain - 3.4).abs() < 0.25, "perf gain {perf_gain}");
    assert!((r.gflops - 391.4).abs() / 391.4 < 0.08, "gflops {}", r.gflops);
}

#[test]
#[cfg_attr(debug_assertions, ignore)]
fn headline_hw_over_sw_about_29pct() {
    let sw = run_matmul(&SocConfig::default(), MatmulMode::SwMcast, &mut RustTileExec);
    let hw = run_matmul(&SocConfig::default(), MatmulMode::HwMcast, &mut RustTileExec);
    let pct = (hw.gflops / sw.gflops - 1.0) * 100.0;
    assert!((20.0..40.0).contains(&pct), "headline {pct}% outside band");
}

#[test]
#[cfg_attr(debug_assertions, ignore)]
fn llc_read_bytes_accounting() {
    // baseline reads B 32x; hw reads it once — LLC byte accounting must
    // reflect exactly that (B = 512 KiB, A = 512 KiB total)
    let base = run_matmul(&SocConfig::default(), MatmulMode::Baseline, &mut RustTileExec);
    let hw = run_matmul(&SocConfig::default(), MatmulMode::HwMcast, &mut RustTileExec);
    let mib = 1024.0 * 1024.0;
    let base_mib = base.llc_read_bytes as f64 / mib;
    let hw_mib = hw.llc_read_bytes as f64 / mib;
    assert!((base_mib - 16.5).abs() < 0.1, "baseline reads {base_mib} MiB");
    assert!((hw_mib - 1.0).abs() < 0.05, "hw reads {hw_mib} MiB");
    // both write C once (0.5 MiB)
    assert!((base.llc_write_bytes as f64 / mib - 0.5).abs() < 0.05);
    assert!((hw.llc_write_bytes as f64 / mib - 0.5).abs() < 0.05);
}

/// Debug-friendly smoke: a small geometry exercises all three modes'
/// program generation and numerics quickly.
#[test]
fn small_geometry_all_modes_validate() {
    use axi_mcast::occamy::config::LLC_BASE;
    use axi_mcast::occamy::{Soc, SocConfig};
    use axi_mcast::workloads::matmul::{programs, MatmulCompute, MatmulLayout};

    for mode in [MatmulMode::Baseline, MatmulMode::SwMcast, MatmulMode::HwMcast] {
        let mut cfg = SocConfig::tiny(8);
        cfg.clusters_per_group = 4;
        match mode {
            MatmulMode::HwMcast => {}
            _ => {
                cfg.wide_mcast = false;
                cfg.narrow_mcast = false;
            }
        }
        let l = MatmulLayout::new(64, 8, 16);
        let mut soc = Soc::new(cfg.clone());
        let n = l.n;
        let a: Vec<f64> = (0..n * n).map(|i| ((i % 9) as f64) - 4.0).collect();
        let b: Vec<f64> = (0..n * n).map(|i| ((i % 11) as f64) - 5.0).collect();
        soc.mem.write_f64(LLC_BASE + l.a_off, &a);
        for k in 0..l.n_tiles() {
            let mut tile = Vec::new();
            for row in 0..n {
                for col in 0..l.tile_cols {
                    tile.push(b[row * n + k * l.tile_cols + col]);
                }
            }
            soc.mem
                .write_f64(LLC_BASE + l.b_off + k as u64 * l.tile_bytes(), &tile);
        }
        soc.load_programs(programs(&cfg, &l, mode));
        let mut exec = RustTileExec;
        let mut handler = MatmulCompute::new(l.clone(), &mut exec);
        soc.run_default(&mut handler)
            .unwrap_or_else(|e| panic!("{mode:?}: {e}"));
        let c = soc.mem.read_f64(LLC_BASE + l.c_off, n * n);
        for i in 0..n {
            for j in 0..n {
                let want: f64 = (0..n).map(|kk| a[i * n + kk] * b[kk * n + j]).sum();
                assert!(
                    (c[i * n + j] - want).abs() < 1e-9,
                    "{mode:?}: C[{i}][{j}]"
                );
            }
        }
    }
}

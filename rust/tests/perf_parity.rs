//! §Perf parity suite: the optimised simulation core (worklist
//! bitmasks + dense txn table in the crossbar, event-horizon cycle
//! skipping in `Soc::run`) must be **bit-identical** in simulated time
//! and statistics to the `force_naive` reference mode — only wall-clock
//! throughput may differ. Property-tested across random crossbar
//! scripts and random SoC workloads from `util::proptest_mini`.

mod common;

use axi_mcast::axi::mcast::AddrSet;
use axi_mcast::axi::types::Resp;
use axi_mcast::axi::xbar::{Xbar, XbarCfg, XbarStats};
use axi_mcast::occamy::{Cmd, NopCompute, Soc, SocConfig};
use axi_mcast::util::proptest_mini::{check, Config, Gen};
use common::{cluster_addr, cluster_map, clusters_set, Fixture, Xfer};

// ---------------------------------------------------------------- xbar

/// Random mixed read/write/multicast scripts (including unroutable
/// addresses, exercising the DECERR paths).
fn random_scripts(g: &mut Gen, n_masters: usize, n_slaves: usize) -> Vec<Vec<Xfer>> {
    (0..n_masters)
        .map(|m| {
            let len = g.len(10);
            (0..len)
                .map(|i| {
                    let beats = 1 + g.u64_below(8) as u32;
                    let id = (g.u64_below(3)) as u16;
                    match g.u64_below(10) {
                        0..=3 => {
                            // unicast write
                            let s = g.u64_below(n_slaves as u64) as usize;
                            Xfer::write(AddrSet::unicast(cluster_addr(s, 0x40 * i as u64)), beats, id)
                        }
                        4..=6 => {
                            // multicast write over an aligned power-of-two set
                            let max_log = (n_slaves as u64).trailing_zeros().max(1) as u64;
                            let log = 1 + g.u64_below(max_log);
                            let count = (1usize << log).min(n_slaves);
                            Xfer::write(clusters_set(count, 0x80 * (m as u64 + 1)), beats, id)
                        }
                        7..=8 => {
                            // unicast read
                            let s = g.u64_below(n_slaves as u64) as usize;
                            Xfer::read(cluster_addr(s, 0x100), beats, id)
                        }
                        _ => {
                            // unroutable (DECERR write or read)
                            if g.bool(0.5) {
                                Xfer::write(AddrSet::unicast(0x9000_0000), beats, id)
                            } else {
                                Xfer::read(0x9000_0000, beats, id)
                            }
                        }
                    }
                })
                .collect()
        })
        .collect()
}

struct XbarOutcome {
    cycles: u64,
    stats: XbarStats,
    delivered: Vec<Vec<u64>>,
    responses: Vec<Vec<(u64, Resp)>>,
}

fn run_xbar(
    n_masters: usize,
    n_slaves: usize,
    scripts: &[Vec<Xfer>],
    force_naive: bool,
) -> XbarOutcome {
    let mut cfg = XbarCfg::new("parity", n_masters, n_slaves, cluster_map(n_slaves, false));
    cfg.force_naive = force_naive;
    let (xbar, pool) = Xbar::with_pool(cfg, 2);
    let mut f = Fixture::new(xbar, pool, scripts.to_vec());
    let cycles = f.run(100_000).expect("parity fixture deadlocked");
    f.assert_protocol_clean();
    XbarOutcome {
        cycles,
        stats: f.xbar.stats.clone(),
        delivered: f.slaves.iter().map(|s| s.delivered_txns()).collect(),
        responses: f
            .masters
            .iter()
            .map(|m| m.completed_b.clone())
            .collect(),
    }
}

#[test]
fn xbar_worklists_match_naive_reference() {
    check(
        "xbar-perf-parity",
        Config {
            cases: 48,
            ..Config::default()
        },
        |g| {
            let n_masters = 2 + g.u64_below(4) as usize;
            // power-of-two slave counts so multicast sets stay aligned
            let n_slaves = 1usize << (1 + g.u64_below(3));
            let scripts = random_scripts(g, n_masters, n_slaves);
            (n_masters, n_slaves, scripts)
        },
        |(n_masters, n_slaves, scripts)| {
            let opt = run_xbar(*n_masters, *n_slaves, scripts, false);
            let naive = run_xbar(*n_masters, *n_slaves, scripts, true);
            if opt.cycles != naive.cycles {
                return Err(format!(
                    "cycle divergence: opt {} vs naive {}",
                    opt.cycles, naive.cycles
                ));
            }
            if opt.stats != naive.stats {
                return Err(format!(
                    "stats divergence:\nopt   {:?}\nnaive {:?}",
                    opt.stats, naive.stats
                ));
            }
            if opt.delivered != naive.delivered {
                return Err("per-slave delivery order diverged".into());
            }
            if opt.responses != naive.responses {
                return Err("master response streams diverged".into());
            }
            if opt.stats.w_beats_out != opt.stats.w_beats_in + opt.stats.w_fork_extra {
                return Err("W fork invariant broken".into());
            }
            Ok(())
        },
    );
}

#[test]
fn xbar_parity_holds_without_commit_protocol() {
    // disjoint-set no-commit traffic (the fig. 2e configuration minus
    // the deadlock): the per-leg forward path must also be identical
    let scripts = vec![
        vec![Xfer::write(clusters_set(2, 0x0), 8, 0); 4],
        vec![Xfer::write(AddrSet::unicast(cluster_addr(3, 0x40)), 8, 1); 4],
    ];
    let run = |force_naive: bool| {
        let mut cfg = XbarCfg::new("nc", 2, 4, cluster_map(4, false));
        cfg.commit_protocol = false;
        cfg.force_naive = force_naive;
        let (xbar, pool) = Xbar::with_pool(cfg, 2);
        let mut f = Fixture::new(xbar, pool, scripts.clone());
        let cycles = f.run(100_000).expect("disjoint no-commit deadlocked");
        (cycles, f.xbar.stats.clone())
    };
    let (c_opt, s_opt) = run(false);
    let (c_naive, s_naive) = run(true);
    assert_eq!(c_opt, c_naive, "no-commit cycle divergence");
    assert_eq!(s_opt, s_naive, "no-commit stats divergence");
}

// ----------------------------------------------------------------- soc

/// Random per-cluster programs: delays, computes, unicast/multicast
/// DMAs and globally-consistent barrier rounds.
fn random_soc_programs(g: &mut Gen, cfg: &SocConfig) -> Vec<Vec<Cmd>> {
    let n = cfg.n_clusters;
    let barriers = g.u64_below(3) as usize;
    (0..n)
        .map(|c| {
            let mut prog = Vec::new();
            for round in 0..=barriers {
                let work = g.u64_below(3);
                for w in 0..work {
                    match g.u64_below(4) {
                        0 => prog.push(Cmd::Delay {
                            cycles: 1 + g.u64_below(200),
                        }),
                        1 => prog.push(Cmd::Compute {
                            macs: 1 + g.u64_below(512),
                            op: 0,
                            arg: 0,
                        }),
                        _ => {
                            let bytes = 64 * (1 + g.u64_below(16));
                            let dst = if g.bool(0.4) {
                                // aligned multicast set
                                let count = (1usize << (1 + g.u64_below(2))).min(n);
                                let first = (c / count) * count;
                                cfg.cluster_set(first, count, 0x8000)
                            } else {
                                let t = g.u64_below(n as u64) as usize;
                                AddrSet::unicast(cfg.cluster_base(t) + 0xC000)
                            };
                            let src = if g.bool(0.5) {
                                cfg.cluster_base(c)
                            } else {
                                axi_mcast::occamy::config::LLC_BASE + 0x100 * c as u64
                            };
                            prog.push(Cmd::Dma {
                                src,
                                dst,
                                bytes,
                                tag: round as u64 * 10 + w,
                            });
                            prog.push(Cmd::WaitDma);
                        }
                    }
                }
                if round < barriers {
                    prog.push(Cmd::Barrier);
                }
            }
            prog
        })
        .collect()
}

struct SocOutcome {
    cycles: u64,
    /// Horizon engagement (not compared: wall-clock-side observability).
    skipped: u64,
    wide: XbarStats,
    narrow: XbarStats,
    releases: u64,
    progress: Vec<u64>,
    compute_busy: Vec<u64>,
    done_at: Vec<Option<u64>>,
    dma_stats: Vec<axi_mcast::occamy::dma::DmaStats>,
    dma_tags: Vec<Vec<u64>>,
    l1: Vec<Vec<u8>>,
}

fn run_soc(cfg: &SocConfig, progs: Vec<Vec<Cmd>>, force_naive: bool) -> SocOutcome {
    run_soc_with(cfg, progs, force_naive, &[])
}

/// Like [`run_soc`], additionally opening in-network reduction groups
/// (`(group, members, dst)`, all `Sum`) before the programs load.
fn run_soc_with(
    cfg: &SocConfig,
    progs: Vec<Vec<Cmd>>,
    force_naive: bool,
    groups: &[(u32, Vec<usize>, u64)],
) -> SocOutcome {
    let cfg = SocConfig {
        force_naive,
        ..cfg.clone()
    };
    let mut soc = Soc::new(cfg);
    for (g, members, dst) in groups {
        soc.open_reduce_group(*g, axi_mcast::axi::reduce::ReduceOp::Sum, members, *dst);
    }
    soc.load_programs(progs);
    let cycles = soc.run_default(&mut NopCompute).expect("soc parity run");
    SocOutcome {
        cycles,
        skipped: soc.skipped_cycles,
        wide: soc.wide.stats_sum(),
        narrow: soc.narrow.stats_sum(),
        releases: soc.barrier.releases,
        progress: soc.clusters.iter().map(|c| c.progress).collect(),
        compute_busy: soc.clusters.iter().map(|c| c.compute_busy_cycles).collect(),
        done_at: soc.clusters.iter().map(|c| c.done_at).collect(),
        dma_stats: soc.clusters.iter().map(|c| c.dma.stats.clone()).collect(),
        dma_tags: soc.clusters.iter().map(|c| c.dma_done_tags.clone()).collect(),
        l1: soc.mem.l1.clone(),
    }
}

fn compare_soc(opt: &SocOutcome, naive: &SocOutcome) -> Result<(), String> {
    if opt.cycles != naive.cycles {
        return Err(format!(
            "cycle divergence: opt {} vs naive {}",
            opt.cycles, naive.cycles
        ));
    }
    if opt.wide != naive.wide || opt.narrow != naive.narrow {
        return Err(format!(
            "xbar stats divergence:\nopt  wide {:?} narrow {:?}\nnaive wide {:?} narrow {:?}",
            opt.wide, opt.narrow, naive.wide, naive.narrow
        ));
    }
    if opt.releases != naive.releases {
        return Err("barrier release divergence".into());
    }
    if opt.progress != naive.progress {
        return Err("cluster progress counters diverged".into());
    }
    if opt.compute_busy != naive.compute_busy {
        return Err("compute busy-cycle counters diverged".into());
    }
    if opt.done_at != naive.done_at {
        return Err(format!(
            "done_at diverged: opt {:?} vs naive {:?}",
            opt.done_at, naive.done_at
        ));
    }
    if opt.dma_stats != naive.dma_stats {
        return Err(format!(
            "dma stats diverged:\nopt   {:?}\nnaive {:?}",
            opt.dma_stats, naive.dma_stats
        ));
    }
    if opt.dma_tags != naive.dma_tags {
        return Err("dma completion tag order diverged".into());
    }
    if opt.l1 != naive.l1 {
        return Err("functional memory diverged".into());
    }
    Ok(())
}

#[test]
fn soc_event_horizon_matches_naive_reference() {
    let cfg = SocConfig::tiny(8);
    check(
        "soc-perf-parity",
        Config {
            cases: 10,
            ..Config::default()
        },
        |g| random_soc_programs(g, &cfg),
        |progs| {
            let opt = run_soc(&cfg, progs.clone(), false);
            let naive = run_soc(&cfg, progs.clone(), true);
            compare_soc(&opt, &naive)
        },
    );
}

#[test]
fn barrier_stagger_horizon_parity() {
    // the event-horizon showcase workload: long staggered delays +
    // barrier + compute, where skipping covers most simulated time
    let cfg = SocConfig::tiny(8);
    let progs: Vec<Vec<Cmd>> = (0..8)
        .map(|i| {
            vec![
                Cmd::Delay {
                    cycles: 100 + (i as u64) * 500,
                },
                Cmd::Barrier,
                Cmd::Compute {
                    macs: 4096,
                    op: 1,
                    arg: 0,
                },
            ]
        })
        .collect();
    let opt = run_soc(&cfg, progs.clone(), false);
    let naive = run_soc(&cfg, progs, true);
    compare_soc(&opt, &naive).unwrap();
    // the run is latency-dominated: the final delay alone is 3600
    assert!(opt.cycles > 3_600, "stagger run suspiciously short");
    // the horizon must actually engage (and naive must never skip)
    assert!(
        opt.skipped > opt.cycles / 2,
        "horizon barely engaged: skipped {} of {} cycles",
        opt.skipped,
        opt.cycles
    );
    assert_eq!(naive.skipped, 0, "force_naive must never fast-forward");
}

#[test]
fn llc_roundtrip_horizon_parity() {
    // LLC-latency-dominated reads: DMA pulls from the LLC while
    // everything else idles, exercising the SimSlave schedule horizon
    let mut cfg = SocConfig::tiny(4);
    cfg.llc_lat = 40; // exaggerate the round-trip
    let mut progs = vec![Vec::new(); 4];
    progs[0] = vec![
        Cmd::Dma {
            src: axi_mcast::occamy::config::LLC_BASE,
            dst: AddrSet::unicast(cfg.cluster_base(0) + 0x100),
            bytes: 4 * 1024,
            tag: 1,
        },
        Cmd::WaitDma,
        Cmd::Delay { cycles: 300 },
    ];
    let opt = run_soc(&cfg, progs.clone(), false);
    let naive = run_soc(&cfg, progs, true);
    compare_soc(&opt, &naive).unwrap();
    // LLC round-trips and the DMA wait must be skippable (a blocked
    // WaitDma is a pure no-op step — cluster.rs next_event)
    assert!(
        opt.skipped > 0,
        "horizon never engaged on the LLC round-trip workload"
    );
}

#[test]
fn e2e_reservation_counters_match_naive_reference() {
    // Concurrent global multicasts on the fabric-wide reservation
    // protocol: the new resv_* counters — including the `resv_waits`
    // per-cycle stall accounting and its `skip(k)` replay — must be
    // bit-identical between the optimised and force_naive modes.
    let mut cfg = SocConfig::tiny(8);
    cfg.e2e_mcast_order = true;
    let mut progs = vec![Vec::new(); 8];
    for (c, prog) in progs.iter_mut().enumerate() {
        *prog = vec![
            Cmd::Dma {
                src: cfg.cluster_base(c),
                dst: cfg.cluster_set(0, 8, 0x8000 + c as u64 * 0x800),
                bytes: 1024,
                tag: c as u64,
            },
            Cmd::WaitDma,
        ];
    }
    let opt = run_soc(&cfg, progs.clone(), false);
    let naive = run_soc(&cfg, progs, true);
    compare_soc(&opt, &naive).unwrap();
    assert!(
        opt.wide.resv_tickets >= 8,
        "every broadcast must take a ticket: {:?}",
        opt.wide
    );
    assert!(
        opt.wide.resv_waits > 0,
        "eight concurrent global multicasts must contend on the ledger"
    );
    assert!(
        opt.skipped > 0,
        "the horizon must engage around the reservation handshakes"
    );
}

#[test]
fn e2e_reservation_parity_property() {
    // random workloads (multicasts, delays, barriers) with the
    // reservation protocol armed: still bit-identical vs force_naive
    let mut cfg = SocConfig::tiny(8);
    cfg.e2e_mcast_order = true;
    check(
        "e2e-resv-parity",
        Config {
            cases: 6,
            ..Config::default()
        },
        |g| random_soc_programs(g, &cfg),
        |progs| {
            let opt = run_soc(&cfg, progs.clone(), false);
            let naive = run_soc(&cfg, progs.clone(), true);
            compare_soc(&opt, &naive)
        },
    );
}

#[test]
fn fabric_reduce_counters_match_naive_reference() {
    // In-network reduction: the new red_joins / red_beats_saved
    // counters — and every other statistic around the combine phase —
    // must be bit-identical between the optimised and force_naive
    // modes (the combine acts only on beat arrivals and channel
    // pushes, so `skip(k)` has nothing to replay; this pins that).
    let mut cfg = SocConfig::tiny(8);
    cfg.fabric_reduce = true;
    let dst = cfg.cluster_base(0) + 0x8000;
    let members: Vec<usize> = (1..8).collect();
    let groups = vec![(1u32, members.clone(), dst)];
    let mut progs = vec![Vec::new(); 8];
    for (c, prog) in progs.iter_mut().enumerate().skip(1) {
        *prog = vec![
            Cmd::DmaReduce {
                src: cfg.cluster_base(c),
                dst,
                bytes: 512,
                tag: c as u64,
                group: 1,
                op: axi_mcast::axi::reduce::ReduceOp::Sum,
            },
            Cmd::WaitDma,
        ];
    }
    let opt = run_soc_with(&cfg, progs.clone(), false, &groups);
    let naive = run_soc_with(&cfg, progs, true, &groups);
    compare_soc(&opt, &naive).unwrap();
    assert!(
        opt.wide.red_joins >= 2,
        "7 converging members on the group tree must join twice: {:?}",
        opt.wide
    );
    assert!(opt.wide.red_beats_saved > 0);
    assert!(
        opt.wide.w_beats_out < opt.wide.w_beats_in,
        "combining must shrink upstream traffic: {:?}",
        opt.wide
    );
    assert!(
        opt.skipped > 0,
        "the horizon must engage around the combine handshakes"
    );
    assert_eq!(naive.skipped, 0);
}

#[test]
fn fabric_reduce_parity_property() {
    // random reduction groups + background copy/compute/delay traffic
    // with the combining fabric armed: still bit-identical vs naive
    let mut cfg = SocConfig::tiny(8);
    cfg.fabric_reduce = true;
    check(
        "fabric-reduce-parity",
        Config {
            cases: 6,
            ..Config::default()
        },
        |g| {
            let mut progs = random_soc_programs(g, &cfg);
            // overlay 1-2 reduction groups on top of the random base
            let n_groups = 1 + g.u64_below(2) as usize;
            let mut groups = Vec::new();
            for gi in 0..n_groups {
                let dst_cluster = g.u64_below(8) as usize;
                let members: Vec<usize> =
                    (0..8).filter(|&c| c != dst_cluster).collect();
                let dst = cfg.cluster_base(dst_cluster) + 0x10000 + gi as u64 * 0x1000;
                let bytes = 64 * (1 + g.u64_below(8));
                for &m in &members {
                    progs[m].push(Cmd::DmaReduce {
                        src: cfg.cluster_base(m),
                        dst,
                        bytes,
                        tag: 90 + gi as u64,
                        group: gi as u32,
                        op: axi_mcast::axi::reduce::ReduceOp::Sum,
                    });
                    progs[m].push(Cmd::WaitDma);
                }
                groups.push((gi as u32, members, dst));
            }
            (progs, groups)
        },
        |(progs, groups)| {
            let opt = run_soc_with(&cfg, progs.clone(), false, groups);
            let naive = run_soc_with(&cfg, progs.clone(), true, groups);
            compare_soc(&opt, &naive)
        },
    );
}

#[test]
fn fabric_reduce_off_is_bit_identical_without_reduce_traffic() {
    // the acceptance guard: with no tagged traffic, arming
    // fabric_reduce must leave every observable bit unchanged
    let cfg_off = SocConfig::tiny(8);
    let mut cfg_on = SocConfig::tiny(8);
    cfg_on.fabric_reduce = true;
    check(
        "fabric-reduce-off-identical",
        Config {
            cases: 4,
            ..Config::default()
        },
        |g| random_soc_programs(g, &cfg_off),
        |progs| {
            let off = run_soc(&cfg_off, progs.clone(), false);
            let on = run_soc(&cfg_on, progs.clone(), false);
            compare_soc(&off, &on)
        },
    );
}

#[test]
fn dma_overlap_horizon_parity() {
    // DMA running while the sequencer delays: exercises the DMA
    // setup/local/wait classification and its bulk skip accounting
    let cfg = SocConfig::tiny(4);
    let mut progs = vec![Vec::new(); 4];
    progs[0] = vec![
        Cmd::Dma {
            src: cfg.cluster_base(0),
            dst: cfg.cluster_set(0, 4, 0x4000),
            bytes: 8 * 1024,
            tag: 1,
        },
        Cmd::Delay { cycles: 900 },
        Cmd::WaitDma,
        // local L1→L1 copy: pure LocalCopy countdown
        Cmd::Dma {
            src: cfg.cluster_base(0),
            dst: AddrSet::unicast(cfg.cluster_base(0) + 0x10000),
            bytes: 4 * 1024,
            tag: 2,
        },
        Cmd::WaitDma,
    ];
    let opt = run_soc(&cfg, progs.clone(), false);
    let naive = run_soc(&cfg, progs, true);
    compare_soc(&opt, &naive).unwrap();
    assert!(opt.skipped > 0, "horizon never engaged on the DMA overlap");
}

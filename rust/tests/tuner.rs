//! Integration suite for the cost-model collective auto-tuner
//! (`CollMode::Auto` + `axi::costmodel` + the `tunesweep` experiment):
//!
//! * the hard floor — the model's pick is never slower than the
//!   software baseline, on any reference shape at any swept size;
//! * bit-exactness — an `Auto` run produces exactly the cycles, beat
//!   accounting and (bit-exact) result buffers of its resolved
//!   concrete schedule;
//! * the plan scoreboard — every concrete mode scored, sorted by
//!   predicted cost, the winner in front;
//! * bounded regret — the model lands on a measured-best schedule for
//!   a healthy majority of `(op, shape, size)` cells.

use axi_mcast::coordinator::experiments::{assert_coll_row_invariants, tunesweep};
use axi_mcast::occamy::{SocConfig, WideShape};
use axi_mcast::util::json::Json;
use axi_mcast::workloads::collectives::{
    auto_plan, run_collective, run_collective_chunked, CollMode, CollOp,
};

fn cfg8() -> SocConfig {
    SocConfig::tiny(8) // 2 groups of 4
}

/// The reference shapes of the bounded-regret property (the paper's
/// hierarchy, the flat crossbar, the deep tree and the tile mesh).
fn reference_shapes() -> Vec<WideShape> {
    vec![
        WideShape::Groups,
        WideShape::Flat,
        WideShape::Tree(vec![2, 2, 2]),
        WideShape::Mesh(2),
    ]
}

/// Hard acceptance floor: `Auto` never loses to `Sw`, for every op on
/// every reference shape at small and medium sizes (the invariant
/// checker also enforces this per row).
#[test]
fn auto_never_loses_to_the_software_baseline() {
    let (rows, _table, json) =
        tunesweep(&cfg8(), &CollOp::ALL, &reference_shapes(), &[1024, 4096]);
    assert_eq!(rows.len(), CollOp::ALL.len() * reference_shapes().len() * 2);
    for r in &rows {
        assert_coll_row_invariants(r);
        assert!(
            r.auto.cycles <= r.sw.cycles,
            "{} on {} @{}: auto ({}) slower than sw ({})",
            r.auto.op.name(),
            r.auto.shape,
            r.auto.bytes,
            r.auto.cycles,
            r.sw.cycles
        );
    }
    let o = json.as_obj().unwrap();
    assert_eq!(o["never_worse_than_sw"], Json::Bool(true));
    assert_eq!(o["n_skipped"].as_f64().unwrap() as u64, 0);
}

/// Bounded regret: the model picks a measured-best schedule on the
/// majority of cells of the reference sweep. (The acceptance target is
/// higher; this floor keeps the suite robust to small timing shifts
/// while still failing loudly if the model degenerates.)
#[test]
fn model_hits_the_measured_best_on_most_reference_cells() {
    let (rows, _table, json) =
        tunesweep(&cfg8(), &CollOp::ALL, &reference_shapes(), &[1024, 4096]);
    let o = json.as_obj().unwrap();
    let frac = o["zero_regret_fraction"].as_f64().unwrap();
    let losses: Vec<String> = rows
        .iter()
        .filter(|r| r.regret > 0.0)
        .map(|r| {
            format!(
                "{} on {} @{}: regret {:.3}",
                r.auto.op.name(),
                r.auto.shape,
                r.auto.bytes,
                r.regret
            )
        })
        .collect();
    assert!(
        frac >= 0.5,
        "model hit only {:.0}% of cells; misses:\n{}",
        frac * 100.0,
        losses.join("\n")
    );
}

/// An `Auto` run is its resolved concrete schedule, bit for bit: same
/// cycle count, same injected beats, bit-exact numerics, and the plan
/// scoreboard is complete and sorted.
#[test]
fn auto_is_bit_exact_against_its_resolved_schedule() {
    let cfg = cfg8();
    for shape in reference_shapes() {
        let mut cfg = cfg.clone();
        cfg.wide_shape = shape.clone();
        for op in CollOp::ALL {
            let auto = run_collective(&cfg, op, CollMode::Auto, 4096);
            assert!(auto.numerics_ok, "{} on {:?}: numerics", op.name(), shape);
            assert_eq!(auto.mode, CollMode::Auto);
            let plan = auto.plan.as_ref().expect("auto records its plan");
            assert_ne!(plan.mode, CollMode::Auto, "plan must be concrete");
            // scoreboard: every concrete mode present, costs ascending
            assert!(plan.scored.len() >= CollMode::ALL.len());
            for pair in plan.scored.windows(2) {
                assert!(pair[0].2 <= pair[1].2, "scoreboard out of order");
            }
            assert_eq!((plan.mode, plan.chunks), (plan.scored[0].0, plan.scored[0].1));
            // replaying the pick concretely reproduces the run exactly
            let direct = run_collective_chunked(&cfg, op, plan.mode, 4096, plan.chunks);
            assert_eq!(auto.cycles, direct.cycles, "{} on {:?}", op.name(), shape);
            assert_eq!(auto.dma_w_beats, direct.dma_w_beats);
            assert_eq!(auto.wide, direct.wide);
        }
    }
}

/// `auto_plan` follows the configured fabric: the plan for a deep ring
/// differs in predicted cost from the flat crossbar's (the shape term
/// is live), and multi-die packages raise every fabric schedule.
#[test]
fn plans_respond_to_shape_and_package() {
    let mut flat = cfg8();
    flat.wide_shape = WideShape::Flat;
    let mut ring = cfg8();
    ring.wide_shape = WideShape::Ring(4);
    let pf = auto_plan(&flat, CollOp::Broadcast, 4096);
    let pr = auto_plan(&ring, CollOp::Broadcast, 4096);
    assert!(
        pr.cost > pf.cost,
        "ring broadcast must be predicted slower than flat ({} <= {})",
        pr.cost,
        pf.cost
    );

    let single = cfg8();
    let mut dies = cfg8();
    dies.package.chiplets = 2;
    dies.validate().unwrap();
    let p1 = auto_plan(&single, CollOp::AllGather, 4096);
    let p2 = auto_plan(&dies, CollOp::AllGather, 4096);
    assert!(
        p2.cost > p1.cost,
        "a 2-die package must raise the predicted all-gather cost ({} <= {})",
        p2.cost,
        p1.cost
    );
}

//! Package-level differential suite for the multi-chiplet fabric of
//! fabrics (DESIGN.md §10): N dies of the single-die fabric joined by
//! width-converting, latency-bearing D2D links must deliver exactly
//! the bytes the single-die golden delivers — final functional memory
//! and per-cluster DMA completion streams bit-identical on race-free
//! random workloads — while the package runs stay bit-identical to
//! themselves across {1,2,4,8} threads, the optimised and naive
//! engines, and with the cross-die reservation and reduction ledgers
//! armed. All four collectives stay bit-exact against the scalar
//! reference in all four strategies on a package, with the
//! `dma_w_beats_red <= dma_w_beats_conc <= dma_w_beats_sw` injection
//! chain and the package-wide W fork/join accounting holding, and a
//! `chiplets: 1` config with non-default D2D parameters armed is
//! bit-identical to the plain single-die fabric.

use axi_mcast::axi::mcast::AddrSet;
use axi_mcast::axi::reduce::ReduceOp;
use axi_mcast::axi::xbar::XbarStats;
use axi_mcast::occamy::{Cmd, NopCompute, Soc, SocConfig};
use axi_mcast::util::proptest_mini::{check, Config, Gen};
use axi_mcast::workloads::collectives::{run_collective, CollMode, CollOp};

/// `tiny(clusters)` partitioned into `chiplets` dies. The leader span
/// (`clusters_per_group`) is clamped to one die so the per-die trees
/// stay well-formed at every count used here.
fn package_cfg(clusters: usize, chiplets: usize) -> SocConfig {
    let mut cfg = SocConfig::tiny(clusters);
    cfg.clusters_per_group = cfg.clusters_per_group.min(clusters / chiplets);
    cfg.package.chiplets = chiplets;
    cfg.validate()
        .unwrap_or_else(|e| panic!("{chiplets}-die package of {clusters}: {e}"));
    cfg
}

// ------------------------------------------------------------ outcome

/// Everything the package engines must reproduce bit-for-bit when only
/// the thread count / engine flavour changes (the `parallel_parity`
/// observable set).
#[derive(Debug, PartialEq)]
struct SocOutcome {
    cycles: u64,
    wide: XbarStats,
    narrow: XbarStats,
    releases: u64,
    progress: Vec<u64>,
    done_at: Vec<Option<u64>>,
    dma_tags: Vec<Vec<u64>>,
    l1: Vec<Vec<u8>>,
}

fn run_soc(
    cfg: &SocConfig,
    progs: &[Vec<Cmd>],
    force_naive: bool,
    threads: usize,
    groups: &[(u32, Vec<usize>, u64)],
) -> SocOutcome {
    let cfg = SocConfig {
        force_naive,
        threads,
        ..cfg.clone()
    };
    let mut soc = Soc::new(cfg);
    for (g, members, dst) in groups {
        soc.open_reduce_group(*g, ReduceOp::Sum, members, *dst);
    }
    soc.load_programs(progs.to_vec());
    let cycles = soc
        .run_default(&mut NopCompute)
        .unwrap_or_else(|e| panic!("package run (threads={}): {e:?}", soc.cfg.threads));
    SocOutcome {
        cycles,
        wide: soc.wide.stats_sum(),
        narrow: soc.narrow.stats_sum(),
        releases: soc.barrier.releases,
        progress: soc.clusters.iter().map(|c| c.progress).collect(),
        done_at: soc.clusters.iter().map(|c| c.done_at).collect(),
        dma_tags: soc.clusters.iter().map(|c| c.dma_done_tags.clone()).collect(),
        l1: soc.mem.l1.clone(),
    }
}

/// Package-wide beat conservation on both networks: every W beat
/// leaving a crossbar entered one, was forked there, or was absorbed
/// by an in-network join — across die boundaries too, because the D2D
/// links neither create nor drop beats.
fn assert_beat_conservation(what: &str, out: &SocOutcome) {
    for (net, s) in [("wide", &out.wide), ("narrow", &out.narrow)] {
        assert_eq!(
            s.w_beats_out,
            s.w_beats_in + s.w_fork_extra - s.red_beats_saved,
            "{what}: {net} package-wide fork/join accounting broke: {s:?}"
        );
        assert!(
            s.resv_commits >= s.resv_tickets,
            "{what}: {net} reservation ledger not drained: {s:?}"
        );
        assert_eq!(s.decerr, 0, "{what}: {net} decode errors: {s:?}");
    }
}

// --------------------------------------- package vs single-die golden

/// Race-free random programs: every destination slot is keyed by the
/// *source* cluster, so the final memory image is independent of
/// arrival order — and therefore of the topology the beats crossed.
/// (The shared-slot races of the `parallel_parity` generator are fine
/// there because both runs use the same fabric; here the golden is a
/// different — single-die — fabric, so only order-free workloads can
/// demand bit-identical memory.)
fn race_free_programs(g: &mut Gen, cfg: &SocConfig) -> Vec<Vec<Cmd>> {
    let n = cfg.n_clusters;
    let barriers = g.u64_below(3) as usize;
    (0..n)
        .map(|c| {
            let mut prog = Vec::new();
            for round in 0..=barriers {
                let work = g.u64_below(3);
                for w in 0..work {
                    match g.u64_below(4) {
                        0 => prog.push(Cmd::Delay {
                            cycles: 1 + g.u64_below(200),
                        }),
                        1 => prog.push(Cmd::Compute {
                            macs: 1 + g.u64_below(512),
                            op: 0,
                            arg: 0,
                        }),
                        _ => {
                            let bytes = 64 * (1 + g.u64_below(8));
                            let dst = if g.bool(0.4) {
                                // aligned multicast into this source's slot;
                                // global sets are legal because every run of
                                // this property arms the e2e reservation
                                // protocol (concurrent global multicasts
                                // deadlock the bare fabric — DESIGN.md §1)
                                let (first, count) = if g.bool(0.3) {
                                    (0, n)
                                } else {
                                    let count = (1usize << (1 + g.u64_below(2))).min(n);
                                    ((c / count) * count, count)
                                };
                                cfg.cluster_set(first, count, 0x8000 + c as u64 * 0x400)
                            } else {
                                let t = g.u64_below(n as u64) as usize;
                                AddrSet::unicast(
                                    cfg.cluster_base(t) + 0xC000 + c as u64 * 0x200,
                                )
                            };
                            prog.push(Cmd::Dma {
                                src: cfg.cluster_base(c),
                                dst,
                                bytes,
                                tag: round as u64 * 10 + w,
                            });
                            prog.push(Cmd::WaitDma);
                        }
                    }
                }
                if round < barriers {
                    prog.push(Cmd::Barrier);
                }
            }
            prog
        })
        .collect()
}

#[test]
fn package_delivers_what_the_single_die_delivers() {
    // e2e armed everywhere: it makes the generator's concurrent global
    // multicasts legal on every fabric, and it routes the property
    // straight through the package-global reservation ledger
    let mut golden_cfg = SocConfig::tiny(8);
    golden_cfg.e2e_mcast_order = true;
    check(
        "chiplet-vs-single-die",
        Config {
            cases: 4,
            ..Config::default()
        },
        |g| race_free_programs(g, &golden_cfg),
        |progs| {
            let golden = run_soc(&golden_cfg, progs, false, 1, &[]);
            for chiplets in [2usize, 4] {
                let mut cfg = package_cfg(8, chiplets);
                cfg.e2e_mcast_order = true;
                let pkg = run_soc(&cfg, progs, false, 1, &[]);
                assert_beat_conservation(&format!("{chiplets} dies"), &pkg);
                // cycles legitimately differ (D2D latency + serialization);
                // delivered bytes and completion streams may not
                if pkg.l1 != golden.l1 {
                    return Err(format!(
                        "{chiplets} dies: final memory diverged from single-die golden"
                    ));
                }
                if pkg.dma_tags != golden.dma_tags || pkg.releases != golden.releases {
                    return Err(format!(
                        "{chiplets} dies: DMA completion / barrier streams diverged"
                    ));
                }
            }
            Ok(())
        },
    );
}

// --------------------------------- thread x engine x ledger bit-identity

/// A fixed deterministic cross-die workload in three barrier-separated
/// phases: aligned-pair multicasts (legal on every fabric), global
/// broadcasts (concurrent from every rank only when the e2e
/// reservation protocol makes that deadlock-free, a lone rank-0
/// broadcast otherwise), and cross-die unicasts — every beat class
/// crosses a gateway.
fn cross_die_progs(cfg: &SocConfig, concurrent_global: bool) -> Vec<Vec<Cmd>> {
    let n = cfg.n_clusters;
    (0..n)
        .map(|c| {
            let peer = (c + cfg.clusters_per_die()) % n;
            let mut prog = vec![
                Cmd::Dma {
                    src: cfg.cluster_base(c),
                    dst: cfg.cluster_set(c & !1, 2, 0x8000 + c as u64 * 0x400),
                    bytes: 512,
                    tag: c as u64,
                },
                Cmd::WaitDma,
                Cmd::Barrier,
            ];
            if concurrent_global || c == 0 {
                prog.push(Cmd::Dma {
                    src: cfg.cluster_base(c),
                    dst: cfg.cluster_set(0, n, 0xA000 + c as u64 * 0x200),
                    bytes: 256,
                    tag: 50 + c as u64,
                });
                prog.push(Cmd::WaitDma);
            }
            prog.extend([
                Cmd::Barrier,
                Cmd::Dma {
                    src: cfg.cluster_base(c),
                    dst: AddrSet::unicast(cfg.cluster_base(peer) + 0xC000 + c as u64 * 0x200),
                    bytes: 256,
                    tag: 100 + c as u64,
                },
                Cmd::WaitDma,
                Cmd::Barrier,
            ]);
            prog
        })
        .collect()
}

/// {1,2,4,8} threads x {opt, force_naive} x {plain, e2e reservation,
/// fabric reduce}: on a 2-die package every combination is bit-identical
/// to the sequential optimised run — the lookahead-1 engine shards by
/// die, and the cross-die ledgers impose one package-global order that
/// partitioning must not perturb.
#[test]
fn package_bit_identical_across_threads_engines_and_ledgers() {
    let base = package_cfg(8, 2);

    let mut e2e = base.clone();
    e2e.e2e_mcast_order = true;

    let mut red = base.clone();
    red.fabric_reduce = true;
    let red_dst = red.cluster_base(0) + 0xE000;
    let red_members: Vec<usize> = (1..8).collect();
    let red_groups = vec![(1u32, red_members, red_dst)];
    let red_progs: Vec<Vec<Cmd>> = (0..8)
        .map(|c| {
            if c == 0 {
                Vec::new()
            } else {
                vec![
                    Cmd::DmaReduce {
                        src: red.cluster_base(c),
                        dst: red_dst,
                        bytes: 512,
                        tag: c as u64,
                        group: 1,
                        op: ReduceOp::Sum,
                    },
                    Cmd::WaitDma,
                ]
            }
        })
        .collect();

    let variants: [(&str, &SocConfig, Vec<Vec<Cmd>>, &[(u32, Vec<usize>, u64)]); 3] = [
        ("plain", &base, cross_die_progs(&base, false), &[]),
        ("e2e", &e2e, cross_die_progs(&e2e, true), &[]),
        ("reduce", &red, red_progs, &red_groups),
    ];
    for (name, cfg, progs, groups) in &variants {
        let golden = run_soc(cfg, progs, false, 1, groups);
        assert_beat_conservation(name, &golden);
        if *name == "e2e" {
            assert!(
                golden.wide.resv_tickets >= 8,
                "{name}: every cross-die broadcast must take a ticket: {:?}",
                golden.wide
            );
        }
        if *name == "reduce" {
            assert!(
                golden.wide.red_joins >= 1 && golden.wide.red_beats_saved > 0,
                "{name}: the cross-die combining path must engage: {:?}",
                golden.wide
            );
        }
        for force_naive in [false, true] {
            for threads in [1usize, 2, 4, 8] {
                let out = run_soc(cfg, progs, force_naive, threads, groups);
                assert_eq!(
                    out, golden,
                    "{name}: naive={force_naive} threads={threads} diverged from \
                     the sequential optimised golden"
                );
            }
        }
    }
}

// ----------------------------------------- collectives on the package

fn assert_collective_modes(cfg: &SocConfig, op: CollOp, bytes: u64) {
    let what = format!("{} on {} dies", op.name(), cfg.package.chiplets);
    let sw = run_collective(cfg, op, CollMode::Sw, bytes);
    let hw = run_collective(cfg, op, CollMode::Hw, bytes);
    let conc = run_collective(cfg, op, CollMode::HwConc, bytes);
    let red = run_collective(cfg, op, CollMode::HwReduce, bytes);
    for r in [&sw, &hw, &conc, &red] {
        assert!(r.numerics_ok, "{what} ({}): scalar reference broke", r.mode.name());
        assert_eq!(
            r.wide.w_beats_out,
            r.wide.w_beats_in + r.wide.w_fork_extra - r.wide.red_beats_saved,
            "{what} ({}): package-wide fork/join accounting",
            r.mode.name()
        );
        assert!(
            r.wide.resv_commits >= r.wide.resv_tickets,
            "{what} ({}): reservation ledger not drained",
            r.mode.name()
        );
    }
    assert!(
        red.dma_w_beats <= conc.dma_w_beats && conc.dma_w_beats <= sw.dma_w_beats,
        "{what}: injected-beat chain red ({}) <= conc ({}) <= sw ({}) broke",
        red.dma_w_beats,
        conc.dma_w_beats,
        sw.dma_w_beats
    );
    assert_eq!(sw.wide.aw_mcast, 0, "{what}: sw baseline multicasted");
}

/// ISSUE acceptance: a 2-die package runs all four collectives
/// bit-exact against the scalar reference in all four strategies, with
/// the injection chain and package-wide accounting holding.
#[test]
fn two_die_package_collectives_bit_exact_all_modes() {
    let cfg = package_cfg(8, 2);
    for op in CollOp::ALL {
        assert_collective_modes(&cfg, op, 2048);
    }
}

/// Same at 4 and 8 dies (16 clusters). Release-tier: the D2D
/// serialization makes these runs long for the debug profile.
#[test]
#[cfg_attr(debug_assertions, ignore)]
fn wide_package_collectives_bit_exact_all_modes() {
    for chiplets in [4usize, 8] {
        let cfg = package_cfg(16, chiplets);
        for op in CollOp::ALL {
            assert_collective_modes(&cfg, op, 4096);
        }
    }
}

/// The hierarchical all-gather schedule (intra-die gather to the die
/// leaders, one contiguous block per die over the D2D links, a single
/// multicast forked per-die at the gateways) engages on packages and
/// injects no more W beats than the flat ring.
#[test]
fn hierarchical_all_gather_engages_on_packages() {
    let cfg = package_cfg(8, 2);
    let sw = run_collective(&cfg, CollOp::AllGather, CollMode::Sw, 2048);
    let hw = run_collective(&cfg, CollOp::AllGather, CollMode::Hw, 2048);
    assert!(sw.numerics_ok && hw.numerics_ok);
    assert!(
        hw.wide.aw_mcast >= 1,
        "the gather-down phase must be one multicast: {:?}",
        hw.wide
    );
    assert!(
        hw.dma_w_beats < sw.dma_w_beats,
        "hierarchical all-gather ({}) must inject fewer beats than the \
         unicast ring ({})",
        hw.dma_w_beats,
        sw.dma_w_beats
    );
}

// ---------------------------------------- event horizon over D2D links

/// Latency replay under `skip(k)`: with long pure-wait gaps between
/// cross-die transfers the optimised engine fast-forwards over the
/// idle spans, and must land on exactly the per-cycle cycle counts and
/// statistics. The scheduler refuses to skip while any D2D pipe beat
/// or serializer cooldown is live (`AxiLink::is_idle` folds the D2D
/// state in), so the armed link state never needs replay — this pins
/// that contract end-to-end on a 2-die package.
#[test]
fn event_horizon_replays_d2d_latency_exactly() {
    let base = package_cfg(8, 2);
    let progs: Vec<Vec<Cmd>> = (0..8usize)
        .map(|c| {
            let peer = (c + 4) % 8;
            vec![
                Cmd::Delay {
                    cycles: 300 + 97 * c as u64,
                },
                Cmd::Dma {
                    src: base.cluster_base(c),
                    dst: AddrSet::unicast(base.cluster_base(peer) + 0xC000 + c as u64 * 0x200),
                    bytes: 512,
                    tag: c as u64,
                },
                Cmd::WaitDma,
                Cmd::Delay {
                    cycles: 5_000 + 500 * c as u64,
                },
                Cmd::Dma {
                    src: base.cluster_base(c),
                    dst: base.cluster_set(c & !1, 2, 0x8000 + c as u64 * 0x400),
                    bytes: 256,
                    tag: 10 + c as u64,
                },
                Cmd::WaitDma,
            ]
        })
        .collect();
    let run = |force_naive: bool| {
        let cfg = SocConfig {
            force_naive,
            ..base.clone()
        };
        let mut soc = Soc::new(cfg);
        soc.load_programs(progs.clone());
        let cycles = soc
            .run_default(&mut NopCompute)
            .unwrap_or_else(|e| panic!("horizon run (naive={force_naive}): {e:?}"));
        (
            cycles,
            soc.skipped_cycles,
            soc.wide.stats_sum(),
            soc.narrow.stats_sum(),
            soc.mem.l1.clone(),
        )
    };
    let opt = run(false);
    let naive = run(true);
    assert!(
        opt.1 > 0,
        "the event horizon must engage across the staggered delay gaps"
    );
    assert_eq!(naive.1, 0, "force_naive must step every cycle");
    assert_eq!(opt.0, naive.0, "skipped vs per-cycle cycle divergence");
    assert_eq!(opt.2, naive.2, "skipped vs per-cycle wide stats divergence");
    assert_eq!(opt.3, naive.3, "skipped vs per-cycle narrow stats divergence");
    assert_eq!(opt.4, naive.4, "skipped vs per-cycle memory divergence");
}

// ------------------------------------------- chiplets: 1 bit-identity

/// Armed-but-unused guard: `chiplets: 1` with non-default D2D
/// parameters is the plain single-die fabric, bit for bit, across the
/// engines and thread counts.
#[test]
fn single_chiplet_is_bit_identical_to_default() {
    let plain = SocConfig::tiny(8);
    let mut armed = plain.clone();
    armed.package.chiplets = 1;
    armed.package.d2d_width_ratio = 8;
    armed.package.d2d_latency = 16;
    armed.validate().unwrap();
    let progs = cross_die_progs(&plain, false);
    let golden = run_soc(&plain, &progs, false, 1, &[]);
    for (force_naive, threads) in [(false, 1usize), (false, 4), (true, 1), (true, 4)] {
        let out = run_soc(&armed, &progs, force_naive, threads, &[]);
        assert_eq!(
            out, golden,
            "chiplets=1 (naive={force_naive}, threads={threads}) must be \
             bit-identical to the single-die fabric"
        );
    }
}

//! Parallel-engine parity suite: the multi-threaded stepping mode
//! (`SocConfig::threads` / `FabricParams::threads`, see `sim::parallel`
//! and DESIGN.md §8) must be **bit-identical** to the sequential golden
//! engine in simulated cycles, crossbar statistics (including the
//! reservation and reduction counters), functional memory, DMA
//! completion streams and endpoint deliveries — across thread counts,
//! with and without the `force_naive` reference mode, and with the
//! end-to-end reservation protocol and in-network reduction armed or
//! not. Only wall-clock throughput may differ.

mod common;

use axi_mcast::axi::mcast::AddrSet;
use axi_mcast::axi::reduce::ReduceOp;
use axi_mcast::axi::topology::{FabricParams, TopoShape};
use axi_mcast::axi::xbar::XbarStats;
use axi_mcast::occamy::{Cmd, NopCompute, Soc, SocConfig};
use axi_mcast::util::proptest_mini::{check, Config, Gen};
use axi_mcast::workloads::topo_sweep::{run_topo_script_with, TOPO_DST_OFF};
use common::{cluster_addr, CLUSTER_STRIDE};

// ----------------------------------------------------------------- soc

/// Random per-cluster programs: delays, computes, unicast/multicast
/// DMAs and globally-consistent barrier rounds (the `perf_parity`
/// generator shape).
fn random_soc_programs(g: &mut Gen, cfg: &SocConfig) -> Vec<Vec<Cmd>> {
    let n = cfg.n_clusters;
    let barriers = g.u64_below(3) as usize;
    (0..n)
        .map(|c| {
            let mut prog = Vec::new();
            for round in 0..=barriers {
                let work = g.u64_below(3);
                for w in 0..work {
                    match g.u64_below(4) {
                        0 => prog.push(Cmd::Delay {
                            cycles: 1 + g.u64_below(200),
                        }),
                        1 => prog.push(Cmd::Compute {
                            macs: 1 + g.u64_below(512),
                            op: 0,
                            arg: 0,
                        }),
                        _ => {
                            let bytes = 64 * (1 + g.u64_below(16));
                            let dst = if g.bool(0.4) {
                                let count = (1usize << (1 + g.u64_below(2))).min(n);
                                let first = (c / count) * count;
                                cfg.cluster_set(first, count, 0x8000)
                            } else {
                                let t = g.u64_below(n as u64) as usize;
                                AddrSet::unicast(cfg.cluster_base(t) + 0xC000)
                            };
                            let src = if g.bool(0.5) {
                                cfg.cluster_base(c)
                            } else {
                                axi_mcast::occamy::config::LLC_BASE + 0x100 * c as u64
                            };
                            prog.push(Cmd::Dma {
                                src,
                                dst,
                                bytes,
                                tag: round as u64 * 10 + w,
                            });
                            prog.push(Cmd::WaitDma);
                        }
                    }
                }
                if round < barriers {
                    prog.push(Cmd::Barrier);
                }
            }
            prog
        })
        .collect()
}

/// Every observable the parallel engine must reproduce bit-for-bit.
/// (`skipped_cycles` is deliberately absent: horizon engagement is a
/// wall-clock-side observable, compared nowhere in the repo.)
#[derive(Debug, PartialEq)]
struct SocOutcome {
    cycles: u64,
    wide: XbarStats,
    narrow: XbarStats,
    releases: u64,
    progress: Vec<u64>,
    compute_busy: Vec<u64>,
    done_at: Vec<Option<u64>>,
    dma_stats: Vec<axi_mcast::occamy::dma::DmaStats>,
    dma_tags: Vec<Vec<u64>>,
    l1: Vec<Vec<u8>>,
}

fn run_soc(
    cfg: &SocConfig,
    progs: &[Vec<Cmd>],
    force_naive: bool,
    threads: usize,
    groups: &[(u32, Vec<usize>, u64)],
) -> SocOutcome {
    let cfg = SocConfig {
        force_naive,
        threads,
        ..cfg.clone()
    };
    let mut soc = Soc::new(cfg);
    for (g, members, dst) in groups {
        soc.open_reduce_group(*g, ReduceOp::Sum, members, *dst);
    }
    soc.load_programs(progs.to_vec());
    let cycles = soc
        .run_default(&mut NopCompute)
        .unwrap_or_else(|e| panic!("parity run (threads={}): {e:?}", soc.cfg.threads));
    SocOutcome {
        cycles,
        wide: soc.wide.stats_sum(),
        narrow: soc.narrow.stats_sum(),
        releases: soc.barrier.releases,
        progress: soc.clusters.iter().map(|c| c.progress).collect(),
        compute_busy: soc.clusters.iter().map(|c| c.compute_busy_cycles).collect(),
        done_at: soc.clusters.iter().map(|c| c.done_at).collect(),
        dma_stats: soc.clusters.iter().map(|c| c.dma.stats.clone()).collect(),
        dma_tags: soc.clusters.iter().map(|c| c.dma_done_tags.clone()).collect(),
        l1: soc.mem.l1.clone(),
    }
}

fn compare(what: &str, par: &SocOutcome, golden: &SocOutcome) -> Result<(), String> {
    if par.cycles != golden.cycles {
        return Err(format!(
            "{what}: cycle divergence: parallel {} vs sequential {}",
            par.cycles, golden.cycles
        ));
    }
    if par.wide != golden.wide || par.narrow != golden.narrow {
        return Err(format!(
            "{what}: xbar stats divergence:\npar    wide {:?} narrow {:?}\ngolden wide {:?} narrow {:?}",
            par.wide, par.narrow, golden.wide, golden.narrow
        ));
    }
    if par != golden {
        return Err(format!("{what}: observable state diverged (memory/DMA/barrier)"));
    }
    Ok(())
}

#[test]
fn soc_parallel_matches_sequential_property() {
    let cfg = SocConfig::tiny(8);
    check(
        "soc-parallel-parity",
        Config {
            cases: 6,
            ..Config::default()
        },
        |g| random_soc_programs(g, &cfg),
        |progs| {
            let golden = run_soc(&cfg, progs, false, 1, &[]);
            for threads in [2usize, 4] {
                let par = run_soc(&cfg, progs, false, threads, &[]);
                compare(&format!("opt/threads={threads}"), &par, &golden)?;
            }
            // the naive reference engine must parallelise identically
            let golden_naive = run_soc(&cfg, progs, true, 1, &[]);
            compare("naive/golden", &golden_naive, &golden)?;
            let par_naive = run_soc(&cfg, progs, true, 4, &[]);
            compare("naive/threads=4", &par_naive, &golden_naive)
        },
    );
}

#[test]
fn soc_parallel_e2e_reservation_parity() {
    // concurrent global multicasts on the fabric-wide reservation
    // protocol: the shared ledger's first-come ordering must survive
    // partitioning (reservation-armed networks step as one atom)
    let mut cfg = SocConfig::tiny(8);
    cfg.e2e_mcast_order = true;
    let mut progs = vec![Vec::new(); 8];
    for (c, prog) in progs.iter_mut().enumerate() {
        *prog = vec![
            Cmd::Dma {
                src: cfg.cluster_base(c),
                dst: cfg.cluster_set(0, 8, 0x8000 + c as u64 * 0x800),
                bytes: 1024,
                tag: c as u64,
            },
            Cmd::WaitDma,
            Cmd::Barrier,
        ];
    }
    let golden = run_soc(&cfg, &progs, false, 1, &[]);
    assert!(
        golden.wide.resv_tickets >= 8,
        "every broadcast must take a ticket: {:?}",
        golden.wide
    );
    for threads in [2usize, 4, 8] {
        let par = run_soc(&cfg, &progs, false, threads, &[]);
        compare(&format!("e2e/threads={threads}"), &par, &golden).unwrap();
    }
    let par_naive = run_soc(&cfg, &progs, true, 4, &[]);
    let golden_naive = run_soc(&cfg, &progs, true, 1, &[]);
    compare("e2e/naive/threads=4", &par_naive, &golden_naive).unwrap();
}

#[test]
fn soc_parallel_e2e_random_property() {
    let mut cfg = SocConfig::tiny(8);
    cfg.e2e_mcast_order = true;
    check(
        "soc-parallel-e2e-parity",
        Config {
            cases: 4,
            ..Config::default()
        },
        |g| random_soc_programs(g, &cfg),
        |progs| {
            let golden = run_soc(&cfg, progs, false, 1, &[]);
            for threads in [2usize, 4] {
                let par = run_soc(&cfg, progs, false, threads, &[]);
                compare(&format!("e2e-rand/threads={threads}"), &par, &golden)?;
            }
            Ok(())
        },
    );
}

#[test]
fn soc_parallel_fabric_reduce_parity() {
    // in-network reduction: converging tagged writes combine at join
    // points; the red_* counters and the f64 sums in functional memory
    // must be bit-identical under partitioning
    let mut cfg = SocConfig::tiny(8);
    cfg.fabric_reduce = true;
    let dst = cfg.cluster_base(0) + 0x8000;
    let members: Vec<usize> = (1..8).collect();
    let groups = vec![(1u32, members, dst)];
    let mut progs = vec![Vec::new(); 8];
    for (c, prog) in progs.iter_mut().enumerate().skip(1) {
        *prog = vec![
            Cmd::DmaReduce {
                src: cfg.cluster_base(c),
                dst,
                bytes: 512,
                tag: c as u64,
                group: 1,
                op: ReduceOp::Sum,
            },
            Cmd::WaitDma,
        ];
    }
    let golden = run_soc(&cfg, &progs, false, 1, &groups);
    assert!(
        golden.wide.red_joins >= 2,
        "the combining path must engage: {:?}",
        golden.wide
    );
    for threads in [2usize, 4] {
        let par = run_soc(&cfg, &progs, false, threads, &groups);
        compare(&format!("reduce/threads={threads}"), &par, &golden).unwrap();
    }
    let par_naive = run_soc(&cfg, &progs, true, 4, &groups);
    let golden_naive = run_soc(&cfg, &progs, true, 1, &groups);
    compare("reduce/naive/threads=4", &par_naive, &golden_naive).unwrap();
}

#[test]
fn soc_parallel_horizon_stagger_parity() {
    // the event-horizon showcase: the composed horizon (min over all
    // shards' next events) must fast-forward to exactly the cycles the
    // sequential engine lands on, at 8 threads too
    let cfg = SocConfig::tiny(8);
    let progs: Vec<Vec<Cmd>> = (0..8)
        .map(|i| {
            vec![
                Cmd::Delay {
                    cycles: 100 + (i as u64) * 500,
                },
                Cmd::Barrier,
                Cmd::Compute {
                    macs: 4096,
                    op: 1,
                    arg: 0,
                },
            ]
        })
        .collect();
    let golden = run_soc(&cfg, &progs, false, 1, &[]);
    assert!(golden.cycles > 3_600, "stagger run suspiciously short");
    for threads in [2usize, 4, 8] {
        let par = run_soc(&cfg, &progs, false, threads, &[]);
        compare(&format!("stagger/threads={threads}"), &par, &golden).unwrap();
    }
}

#[test]
fn soc_threads_zero_resolves_and_matches() {
    // --threads 0 = one worker per core; still bit-identical
    let cfg = SocConfig::tiny(4);
    let progs: Vec<Vec<Cmd>> = (0..4)
        .map(|c| {
            vec![
                Cmd::Dma {
                    src: cfg.cluster_base(c),
                    dst: cfg.cluster_set(0, 4, 0x4000),
                    bytes: 2048,
                    tag: 7,
                },
                Cmd::WaitDma,
            ]
        })
        .collect();
    let golden = run_soc(&cfg, &progs, false, 1, &[]);
    let par = run_soc(&cfg, &progs, false, 0, &[]);
    compare("threads=0", &par, &golden).unwrap();
}

// ---------------------------------------------------------------- topo

/// Random single-source write scripts over the sweep's endpoint
/// layout (which shares the cluster base/stride of `common`): unicast
/// and aligned mask-form multicast bursts.
fn random_topo_script(g: &mut Gen, n: usize) -> Vec<(AddrSet, u32)> {
    let len = 1 + g.len(10);
    (0..len)
        .map(|i| {
            let beats = 1 + g.u64_below(8) as u32;
            let off = TOPO_DST_OFF + 0x40 * i as u64;
            if g.bool(0.5) {
                let t = g.u64_below(n as u64) as usize;
                (AddrSet::unicast(cluster_addr(t, off)), beats)
            } else {
                let max_log = u64::from((n as u64).trailing_zeros());
                let log = 1 + g.u64_below(max_log);
                let count = 1usize << log;
                let first = (g.u64_below(n as u64) as usize / count) * count;
                let mask = (count as u64 - 1) * CLUSTER_STRIDE;
                (AddrSet::new(cluster_addr(first, off), mask), beats)
            }
        })
        .collect()
}

fn run_topo(
    shape: &TopoShape,
    n: usize,
    script: &[(AddrSet, u32)],
    e2e: bool,
    threads: usize,
) -> (u64, XbarStats, Vec<Vec<(u64, u32)>>) {
    let params = FabricParams {
        mcast_enabled: true,
        e2e_mcast_order: e2e,
        threads,
        ..FabricParams::default()
    };
    let (res, _) = run_topo_script_with(shape, n, script.to_vec(), params)
        .unwrap_or_else(|e| panic!("{}/threads={threads}: {e:?}", shape.label()));
    (res.cycles, res.stats, res.deliveries)
}

#[test]
fn topo_parallel_random_scripts_property() {
    const N_EP: usize = 16;
    let shapes = [
        TopoShape::Flat,
        TopoShape::Tree { arity: vec![4, 4] },
        TopoShape::Tree {
            arity: vec![2, 2, 4],
        },
        TopoShape::Mesh { tiles: 4 },
        TopoShape::Ring { nodes: 4 },
        TopoShape::Torus { cols: 2, rows: 2 },
        TopoShape::RingMesh { groups: 2, tiles: 2 },
    ];
    check(
        "topo-parallel-parity",
        Config {
            cases: 8,
            ..Config::default()
        },
        |g| random_topo_script(g, N_EP),
        |script| {
            for shape in &shapes {
                let golden = run_topo(shape, N_EP, script, false, 1);
                for threads in [2usize, 4] {
                    let par = run_topo(shape, N_EP, script, false, threads);
                    if par != golden {
                        return Err(format!(
                            "{}/threads={threads}: diverged (cycles {} vs {})",
                            shape.label(),
                            par.0,
                            golden.0
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn topo_parallel_e2e_armed_fabric_parity() {
    // with the reservation ledger armed the whole fabric steps as one
    // atom — the parallel win shrinks to master/slave overlap, but the
    // result must stay bit-identical
    const N_EP: usize = 16;
    let script: Vec<(AddrSet, u32)> = (0..6)
        .map(|i| {
            (
                AddrSet::new(
                    cluster_addr(0, TOPO_DST_OFF + 0x40 * i),
                    (N_EP as u64 - 1) * CLUSTER_STRIDE,
                ),
                8,
            )
        })
        .collect();
    for shape in [TopoShape::Flat, TopoShape::Tree { arity: vec![4, 4] }] {
        let golden = run_topo(&shape, N_EP, &script, true, 1);
        for threads in [2usize, 4] {
            let par = run_topo(&shape, N_EP, &script, true, threads);
            assert_eq!(
                par,
                golden,
                "{}/e2e/threads={threads}: diverged",
                shape.label()
            );
        }
    }
}

//! Rust half of the AOT interchange contract: load every HLO-text
//! artifact produced by `python/compile/aot.py`, execute on the PJRT
//! CPU client, and cross-check numerics against the Rust reference.
//! Skips (with a note) when artifacts haven't been built.

use axi_mcast::runtime::{ArtifactDir, PjrtTileExec, Runtime};
use axi_mcast::workloads::matmul::{RustTileExec, TileExec};

fn runtime() -> Option<Runtime> {
    let dir = ArtifactDir::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Runtime::load(&dir).expect("runtime load"))
}

#[test]
fn all_six_artifacts_compile() {
    let Some(rt) = runtime() else { return };
    assert_eq!(rt.graph_names().len(), 6, "{:?}", rt.graph_names());
}

#[test]
fn rowblock_graph_matches_reference() {
    let Some(rt) = runtime() else { return };
    let (m, n, k) = (8usize, 256usize, 256usize);
    let a: Vec<f64> = (0..m * k).map(|i| ((i * 31 % 17) as f64) - 8.0).collect();
    let b: Vec<f64> = (0..k * n).map(|i| ((i * 13 % 9) as f64) - 4.0).collect();
    let got = rt.exec_f64("rowblock_f64", &[&a, &b]).unwrap();
    let mut want = vec![0.0; m * n];
    RustTileExec.tile(&a, &b, &mut want, m, n, k);
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert!((g - w).abs() < 1e-9, "elem {i}: {g} vs {w}");
    }
}

#[test]
fn pjrt_tile_exec_paper_shape_and_fallback() {
    let Some(rt) = runtime() else { return };
    let mut exec = PjrtTileExec::new(&rt).unwrap();
    // paper shape → PJRT
    let (m, n, k) = (8, 16, 256);
    let a = vec![1.0; m * k];
    let b = vec![2.0; k * n];
    let mut c = vec![3.0; m * n];
    exec.tile(&a, &b, &mut c, m, n, k);
    assert_eq!(exec.calls, 1);
    assert!(c.iter().all(|&v| (v - (3.0 + 512.0)).abs() < 1e-9));
    // other shape → Rust fallback
    let mut c2 = vec![0.0; 4];
    exec.tile(&[1.0, 0.0, 0.0, 1.0], &[5.0, 6.0, 7.0, 8.0], &mut c2, 2, 2, 2);
    assert_eq!(exec.fallback_calls, 1);
    assert_eq!(c2, vec![5.0, 6.0, 7.0, 8.0]);
}

#[test]
fn f32_artifacts_also_execute() {
    let Some(rt) = runtime() else { return };
    // f32 graphs exist and compile; execution path is f64-typed in the
    // runtime helper, so just assert presence + arg metadata here.
    let g = rt.artifacts.graph("tile_f32").expect("tile_f32");
    assert_eq!(g.args[0].1, "f32");
}

/// The full-stack sanity loop the paper's fig. 3d describes, in
/// miniature: 16 iterations of the tile graph accumulate one cluster's
/// row block; the result must equal the rowblock graph's output.
#[test]
fn iterated_tiles_equal_rowblock() {
    let Some(rt) = runtime() else { return };
    let (m, n, k, tiles) = (8usize, 16usize, 256usize, 16usize);
    let a: Vec<f64> = (0..m * k).map(|i| ((i % 23) as f64) * 0.25 - 2.0).collect();
    let b_full: Vec<f64> = (0..k * k).map(|i| ((i % 19) as f64) * 0.5 - 4.0).collect();
    let rowblock = rt.exec_f64("rowblock_f64", &[&a, &b_full]).unwrap();
    for t in 0..tiles {
        // B tile t: columns t*16..(t+1)*16
        let mut b_tile = Vec::with_capacity(k * n);
        for row in 0..k {
            for col in 0..n {
                b_tile.push(b_full[row * k + t * n + col]);
            }
        }
        let c0 = vec![0.0; m * n];
        let got = rt.exec_f64("tile_f64", &[&a, &b_tile, &c0]).unwrap();
        for i in 0..m {
            for j in 0..n {
                let w = rowblock[i * k + t * n + j];
                let g = got[i * n + j];
                assert!((g - w).abs() < 1e-9, "tile {t} [{i}][{j}]: {g} vs {w}");
            }
        }
    }
}

//! Schema-shape sanity for the committed `BENCH_*.json` seeds at the
//! repo root: every seed must parse, name its bench, carry the schema
//! version its EXPERIMENTS.md section documents, and any measured rows
//! must carry the documented columns — so a bench regeneration (the CI
//! perf jobs) can never silently drift from the documented schema.

use axi_mcast::util::json::Json;

fn load(name: &str) -> Json {
    let path = format!("{}/../{}", env!("CARGO_MANIFEST_DIR"), name);
    let text = std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("{path}: {e}"));
    Json::parse(&text).unwrap_or_else(|e| panic!("{path}: invalid JSON: {e}"))
}

#[test]
fn collectives_seed_has_schema_v4_shape() {
    let j = load("BENCH_collectives.json");
    let o = j.as_obj().unwrap();
    assert_eq!(o["bench"].as_str(), Some("collectives"));
    assert_eq!(o["schema"].as_f64().unwrap() as u64, 4);
    for key in ["config", "rows", "summaries"] {
        assert!(o.contains_key(key), "BENCH_collectives.json missing {key}");
    }
    // measured rows (once a toolchain run replaces the seed) must carry
    // the v4 auto-tuner columns next to the v3 reduce columns
    for row in o["rows"].as_arr().unwrap() {
        let r = row.as_obj().unwrap();
        for key in [
            "op",
            "shape",
            "cycles_sw",
            "cycles_hw",
            "cycles_conc",
            "cycles_red",
            "mode_auto",
            "cycles_auto",
            "regret",
            "numerics_ok",
        ] {
            assert!(r.contains_key(key), "collectives row missing {key}");
        }
    }
}

#[test]
fn sim_perf_seed_has_documented_schema_shape() {
    let j = load("BENCH_sim_perf.json");
    let o = j.as_obj().unwrap();
    assert_eq!(o["bench"].as_str(), Some("sim_perf"));
    // the committed seed is v1 (no toolchain in the authoring
    // container); `cargo bench --bench sim_perf` regenerates at v2,
    // folding a v1 file in as `baseline` — both shapes are legal here
    let schema = o["schema"].as_f64().unwrap() as u64;
    assert!((1..=2).contains(&schema), "sim_perf schema {schema}");
    let scenarios = o["scenarios"].as_arr().unwrap();
    assert!(!scenarios.is_empty(), "sim_perf seed lists no scenarios");
    for s in scenarios {
        let s = s.as_obj().unwrap();
        for key in ["scenario", "variant", "mcycle_per_s", "sim_cycles"] {
            assert!(s.contains_key(key), "sim_perf scenario missing {key}");
        }
    }
}

#[test]
fn serving_seed_has_schema_v1_shape() {
    let j = load("BENCH_serving.json");
    let o = j.as_obj().unwrap();
    assert_eq!(o["bench"].as_str(), Some("serving"));
    assert_eq!(o["schema"].as_f64().unwrap() as u64, 1);
    for key in ["config", "rows", "summaries"] {
        assert!(o.contains_key(key), "BENCH_serving.json missing {key}");
    }
    // measured rows (once a toolchain run replaces the seed) must carry
    // the documented v1 columns: one object per (shape, mode) with
    // throughput, the tail-latency triple and the budget columns
    for row in o["rows"].as_arr().unwrap() {
        let r = row.as_obj().unwrap();
        for key in [
            "shape",
            "mode",
            "requests",
            "layers",
            "cycles",
            "throughput_rpmc",
            "lat_p50",
            "lat_p95",
            "lat_max",
            "budget",
            "retired_in_budget",
            "numerics_ok",
        ] {
            assert!(r.contains_key(key), "serving row missing {key}");
        }
    }
}

/// `BENCH_topo_shapes.json` is bench output, not a committed seed — but
/// when present (e.g. in a CI workspace after `cargo bench`) it must
/// match its documented schema too.
#[test]
fn topo_shapes_output_when_present_has_schema_v1_shape() {
    let path = format!("{}/../BENCH_topo_shapes.json", env!("CARGO_MANIFEST_DIR"));
    let Ok(text) = std::fs::read_to_string(&path) else {
        return;
    };
    let j = Json::parse(&text).unwrap_or_else(|e| panic!("{path}: invalid JSON: {e}"));
    let o = j.as_obj().unwrap();
    assert_eq!(o["bench"].as_str(), Some("topo_shapes"));
    assert_eq!(o["schema"].as_f64().unwrap() as u64, 1);
    for row in o["timing"].as_arr().unwrap() {
        let r = row.as_obj().unwrap();
        for key in ["shape", "sim_cycles", "mcycle_per_s"] {
            assert!(r.contains_key(key), "topo_shapes timing row missing {key}");
        }
    }
}

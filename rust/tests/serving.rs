//! Integration suite for the serving-scale transformer traffic
//! generator: N concurrent requests, each a dependency-released chain
//! of per-layer all-gather -> all-reduce (-> MoE all-to-all)
//! collectives. Checks the load-bearing properties end to end:
//!
//! * dependency ordering — no request touches layer k's collectives
//!   before its layer k-1 all-reduce retired;
//! * determinism — the full result (cycles, latencies, stats, payload
//!   checks) is bit-identical across worker-thread counts and across
//!   the optimised vs `force_naive` parallel stepping paths;
//! * degenerate shapes — 1 request, 1 layer, and the 2-cluster system
//!   where the hw modes legally collapse onto the unicast exchange;
//! * tail-latency ordering — p50 <= p95 <= max on every run;
//! * the `coordinator::experiments::serving` row invariants, with the
//!   `CollMode::Auto` row present and its resolution recorded.

use axi_mcast::coordinator::experiments::{assert_serving_row_invariants, serving};
use axi_mcast::occamy::{SocConfig, WideShape};
use axi_mcast::workloads::collectives::CollMode;
use axi_mcast::workloads::serving::{run_serving, ServingParams};

fn params4() -> ServingParams {
    ServingParams {
        requests: 4,
        layers: 3,
        bytes: 1024, // 4 clusters => 256 B (4-beat) chunks
        moe_every: 2,
        compute_macs: 64,
    }
}

/// No layer-k collective may start before the same request's layer-k-1
/// all-reduce retired: the first ATTN timestamp of layer k (fed by the
/// layer-k all-gather) must come strictly after the last MLP timestamp
/// of layer k-1 (which consumed the layer-k-1 all-reduce), on every
/// request, in every mode. Retirement order also follows the staggered
/// admission order.
#[test]
fn dependency_chain_is_honored_in_every_mode() {
    let cfg = SocConfig::tiny(4);
    let p = params4();
    for mode in [CollMode::Sw, CollMode::HwConc, CollMode::HwReduce] {
        let r = run_serving(&cfg, &p, mode);
        assert!(r.numerics_ok);
        for q in 0..p.requests {
            for layer in 1..p.layers {
                assert!(
                    r.attn_first[q][layer] > r.mlp_last[q][layer - 1],
                    "{}: request {q} layer {layer} started (cy {}) before layer {} \
                     retired (cy {})",
                    mode.name(),
                    r.attn_first[q][layer],
                    layer - 1,
                    r.mlp_last[q][layer - 1]
                );
            }
        }
        assert!(
            r.retired_at.windows(2).all(|w| w[0] < w[1]),
            "{}: staggered requests must retire in admission order: {:?}",
            mode.name(),
            r.retired_at
        );
    }
}

/// The whole result — cycles, per-request latencies, crossbar stats,
/// payload validation — is bit-identical across worker-thread counts
/// and across the optimised vs force-naive parallel stepping paths.
#[test]
fn results_are_bit_identical_across_engines() {
    let p = params4();
    let base = run_serving(&SocConfig::tiny(4), &p, CollMode::HwReduce);
    for threads in [2, 4] {
        let mut cfg = SocConfig::tiny(4);
        cfg.threads = threads;
        assert_eq!(run_serving(&cfg, &p, CollMode::HwReduce), base, "threads={threads}");
        cfg.force_naive = true;
        assert_eq!(
            run_serving(&cfg, &p, CollMode::HwReduce),
            base,
            "threads={threads} force_naive"
        );
    }
}

/// Degenerate batch: a single request with a single layer still
/// produces a validated result with one latency sample in every mode.
#[test]
fn single_request_single_layer_works() {
    let cfg = SocConfig::tiny(4);
    let p = ServingParams {
        requests: 1,
        layers: 1,
        bytes: 256,
        moe_every: 0,
        compute_macs: 16,
    };
    for mode in [CollMode::Sw, CollMode::HwConc, CollMode::HwReduce] {
        let r = run_serving(&cfg, &p, mode);
        assert!(r.numerics_ok, "{}", mode.name());
        assert_eq!(r.latencies.len(), 1);
        assert_eq!(r.lat_p50, r.lat_max);
        assert_eq!(r.moe_folds, 0);
    }
}

/// On 2 clusters a multicast has no fan-out to amortise the
/// reservation handshake, so the hw modes deliberately emit the same
/// unicast exchange as sw. hw-concurrent (flags armed, never
/// exercised) collapses onto sw exactly — equal cycles, latencies and
/// injected traffic. hw-reduce still arms in-fabric reduction for the
/// converging DmaReduce rounds, so only its injection-side traffic and
/// numerics must match.
#[test]
fn two_cluster_hw_modes_collapse_onto_sw() {
    let cfg = SocConfig::tiny(2);
    let p = ServingParams {
        requests: 2,
        layers: 2,
        bytes: 128,
        moe_every: 1,
        compute_macs: 16,
    };
    let sw = run_serving(&cfg, &p, CollMode::Sw);
    assert!(sw.numerics_ok);

    let conc = run_serving(&cfg, &p, CollMode::HwConc);
    assert!(conc.numerics_ok);
    assert_eq!(conc.cycles, sw.cycles);
    assert_eq!(conc.latencies, sw.latencies);
    assert_eq!(conc.dma_w_beats, sw.dma_w_beats);

    let red = run_serving(&cfg, &p, CollMode::HwReduce);
    assert!(red.numerics_ok);
    assert_eq!(red.dma_w_beats, sw.dma_w_beats);
}

/// Tail statistics are ordered on every mode and throughput is the
/// declared requests-per-megacycle ratio.
#[test]
fn tail_latencies_are_ordered() {
    let cfg = SocConfig::tiny(4);
    let p = params4();
    for mode in [CollMode::Sw, CollMode::HwConc, CollMode::HwReduce, CollMode::Auto] {
        let r = run_serving(&cfg, &p, mode);
        assert!(r.lat_p50 <= r.lat_p95, "{}", mode.name());
        assert!(r.lat_p95 <= r.lat_max, "{}", mode.name());
        let expect = p.requests as f64 * 1e6 / r.cycles as f64;
        assert!((r.throughput_rpmc - expect).abs() < 1e-9, "{}", mode.name());
    }
}

/// The experiment harness: every (shape, mode) row holds the serving
/// invariants (hw never slower or chattier than sw at equal work,
/// ledgers drained, tails ordered) and the auto row records what the
/// cost model resolved it to.
#[test]
fn experiment_rows_hold_invariants_with_auto_present() {
    let cfg = SocConfig::tiny(4);
    let p = ServingParams {
        requests: 3,
        layers: 2,
        bytes: 1024,
        moe_every: 2,
        compute_macs: 64,
    };
    let shapes = [WideShape::Groups, WideShape::Flat];
    let (rows, _table, json) = serving(&cfg, &shapes, &p);
    assert_eq!(rows.len(), shapes.len());
    for row in &rows {
        assert_serving_row_invariants(row);
        assert_eq!(row.auto.mode, CollMode::Auto);
        assert!(row.auto.auto_resolved.is_some());
    }
    // one JSON object per (shape, mode)
    assert_eq!(json.as_arr().unwrap().len(), shapes.len() * 4);
}

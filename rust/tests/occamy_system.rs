//! Full-system integration tests on the Occamy model: hierarchical
//! multicast routing, synchronisation, microbenchmark invariants and
//! feature ablations.

use axi_mcast::occamy::{Cmd, NopCompute, Soc, SocConfig};
use axi_mcast::workloads::microbench::{run_microbench, McastMode};

#[test]
fn mcast_crosses_hierarchy_exactly_once_per_cluster() {
    // 32 clusters, broadcast from cluster 5 (group 1) — exercises the
    // exclude-scope pruning: group 1 must not receive an echo from top.
    let cfg = SocConfig::default();
    let mut soc = Soc::new(cfg.clone());
    for i in 0..64 {
        soc.mem.l1[5][i] = (i * 3 % 251) as u8;
    }
    let mut progs = vec![Vec::new(); 32];
    progs[5] = vec![
        Cmd::Dma {
            src: cfg.cluster_base(5),
            dst: cfg.cluster_set(0, 32, 0x8000),
            bytes: 64,
            tag: 1,
        },
        Cmd::WaitDma,
    ];
    soc.load_programs(progs);
    soc.run_default(&mut NopCompute).unwrap();
    let expect: Vec<u8> = (0..64).map(|i| (i * 3 % 251) as u8).collect();
    for c in 0..32 {
        assert_eq!(
            &soc.mem.l1[c][0x8000..0x8040],
            &expect[..],
            "cluster {c} payload"
        );
    }
    // top xbar forked to 8 groups; source group got it locally, so the
    // top-level fork count per AW is 7 (echo pruned)
    let top = soc.wide.top();
    assert_eq!(top.stats.aw_mcast, 1);
    assert_eq!(top.stats.aw_forks, 7, "source group must be pruned at top");
}

#[test]
fn topology_built_network_stats_invariants() {
    // The Occamy networks are TopologyBuilder trees now; after a full
    // hierarchical broadcast every crossbar must satisfy the beat
    // accounting invariants: W replication is exactly the fork extra,
    // and an mcast-enabled fabric never DECERRs well-formed traffic.
    let cfg = SocConfig::default();
    let mut soc = Soc::new(cfg.clone());
    let mut progs = vec![Vec::new(); 32];
    progs[3] = vec![
        Cmd::Dma {
            src: cfg.cluster_base(3),
            dst: cfg.cluster_set(0, 32, 0x4000),
            bytes: 2048,
            tag: 1,
        },
        Cmd::WaitDma,
    ];
    soc.load_programs(progs);
    soc.run_default(&mut NopCompute).unwrap();
    for net in [&soc.wide, &soc.narrow] {
        for x in &net.xbars {
            assert_eq!(
                x.stats.w_beats_out,
                x.stats.w_beats_in + x.stats.w_fork_extra,
                "{}: W fork accounting broken",
                x.cfg.name
            );
            assert_eq!(x.stats.decerr, 0, "{}: unexpected DECERR", x.cfg.name);
        }
        let sum = net.stats_sum();
        assert_eq!(sum.w_beats_out, sum.w_beats_in + sum.w_fork_extra);
    }
    // the broadcast actually replicated beats somewhere in the fabric
    assert!(soc.wide.stats_sum().w_fork_extra > 0);
}

#[test]
fn unicast_traffic_unaffected_by_mcast_extension() {
    // same unicast workload on baseline and extended fabric → identical
    // cycle counts (backward compatibility claim)
    let run = |wide_mcast: bool| {
        let mut cfg = SocConfig::tiny(8);
        cfg.wide_mcast = wide_mcast;
        let mut soc = Soc::new(cfg.clone());
        let mut progs = vec![Vec::new(); 8];
        for c in 0..8usize {
            progs[c] = vec![
                Cmd::Dma {
                    src: cfg.cluster_base(c),
                    dst: axi_mcast::axi::mcast::AddrSet::unicast(
                        cfg.cluster_base((c + 3) % 8) + 0x4000,
                    ),
                    bytes: 4096,
                    tag: 1,
                },
                Cmd::WaitDma,
            ];
        }
        soc.load_programs(progs);
        soc.run_default(&mut NopCompute).unwrap()
    };
    assert_eq!(run(false), run(true));
}

#[test]
fn microbench_delivers_and_ranks_modes() {
    let cfg = SocConfig::default();
    let uni = run_microbench(&cfg, McastMode::Unicast, 32, 4096);
    let sw = run_microbench(&cfg, McastMode::SwHier, 32, 4096);
    let hw = run_microbench(&cfg, McastMode::Hw, 32, 4096);
    assert!(hw.cycles < sw.cycles && sw.cycles < uni.cycles);
    let speedup = uni.cycles as f64 / hw.cycles as f64;
    assert!(
        (10.0..25.0).contains(&speedup),
        "32-cluster hw speedup {speedup} out of plausible band"
    );
}

#[test]
fn fig3b_speedup_band_paper() {
    // the paper's quoted band: 13.5x (small) to 16.2x (32 KiB) on 32
    // clusters; accept ±1.5x of model noise
    let cfg = SocConfig::default();
    for (bytes, lo, hi) in [(1024u64, 12.0, 15.5), (32 * 1024, 14.0, 17.5)] {
        let uni = run_microbench(&cfg, McastMode::Unicast, 32, bytes);
        let hw = run_microbench(&cfg, McastMode::Hw, 32, bytes);
        let s = uni.cycles as f64 / hw.cycles as f64;
        assert!(
            (lo..hi).contains(&s),
            "{bytes}B speedup {s} outside [{lo},{hi}]"
        );
    }
}

#[test]
fn barrier_scales_with_narrow_mcast() {
    let run = |mcast: bool, n: usize| {
        let mut cfg = SocConfig::tiny(n);
        cfg.narrow_mcast = mcast;
        let mut soc = Soc::new(cfg);
        soc.load_programs((0..n).map(|_| vec![Cmd::Barrier]).collect());
        soc.run_default(&mut NopCompute).unwrap()
    };
    // the release train grows with n without mcast; the advantage must
    // grow with the cluster count (at n=8 both fit in the pipeline)
    let d8 = run(false, 8) as i64 - run(true, 8) as i64;
    let d32 = run(false, 32) as i64 - run(true, 32) as i64;
    assert!(d32 > 0 && d32 > d8, "mcast advantage must grow: d8={d8} d32={d32}");
}

#[test]
fn concurrent_mcasts_disjoint_targets_no_deadlock() {
    // One source per group, each broadcasting to a *different* remote
    // group (disjoint target sets): the commit protocol handles this
    // concurrency fine.
    let cfg = SocConfig::default();
    let mut soc = Soc::new(cfg.clone());
    let mut progs = vec![Vec::new(); 32];
    for g in 0..8usize {
        let src = g * 4;
        let dst_group = (g + 1) % 8;
        progs[src] = vec![
            Cmd::Dma {
                src: cfg.cluster_base(src),
                dst: cfg.cluster_set(dst_group * 4, 4, 0x10000),
                bytes: 2048,
                tag: g as u64,
            },
            Cmd::WaitDma,
        ];
    }
    soc.load_programs(progs);
    soc.run_default(&mut NopCompute)
        .expect("disjoint-set concurrent multicasts must not deadlock");
}

#[test]
fn concurrent_global_broadcasts_serialised_by_barrier() {
    // The paper's system (like ours) supports one global broadcaster at
    // a time — concurrent all-cluster broadcasts from different sources
    // can form an inter-level W-ordering cycle (see the companion
    // `global_broadcast_contention_deadlocks` test). The supported
    // software pattern serialises them with barriers; this must always
    // complete.
    let cfg = SocConfig::default();
    let mut soc = Soc::new(cfg.clone());
    let mut progs: Vec<Vec<Cmd>> = vec![vec![Cmd::Barrier; 4]; 32];
    for g in 0..4usize {
        let src = g * 8;
        let mut p: Vec<Cmd> = Vec::new();
        for round in 0..4usize {
            if round == g {
                p.push(Cmd::Dma {
                    src: cfg.cluster_base(src),
                    dst: cfg.cluster_set(0, 32, 0x10000 + g as u64 * 0x1000),
                    bytes: 2048,
                    tag: g as u64,
                });
                p.push(Cmd::WaitDma);
            }
            p.push(Cmd::Barrier);
        }
        progs[src] = p;
    }
    soc.load_programs(progs);
    soc.run_default(&mut NopCompute)
        .expect("barrier-serialised broadcasts must complete");
}

/// The 8-source global-broadcast contention workload (one broadcaster
/// per group, every one targeting all 32 clusters at a source-distinct
/// offset), plus deterministic per-source payload seeding.
fn contention_programs(cfg: &SocConfig, soc: &mut Soc) -> Vec<(usize, Cmd)> {
    let mut dmas = Vec::new();
    for g in 0..8usize {
        let src = g * 4;
        for (i, b) in soc.mem.l1[src][..2048].iter_mut().enumerate() {
            *b = ((i * 7 + g * 13) % 251) as u8;
        }
        dmas.push((
            src,
            Cmd::Dma {
                src: cfg.cluster_base(src),
                dst: cfg.cluster_set(0, 32, 0x10000 + g as u64 * 0x1000),
                bytes: 2048,
                tag: g as u64,
            },
        ));
    }
    dmas
}

#[test]
fn global_broadcast_contention_deadlocks_documented_limitation() {
    // RTL-FAITHFUL LIMITATION (DESIGN.md §1 / EXPERIMENTS.md): with
    // `e2e_mcast_order` OFF (the default), two simultaneous all-cluster
    // broadcasts from different groups deadlock across hierarchy levels
    // — the per-crossbar commit protocol breaks intra-crossbar wait
    // cycles (fig. 2e) but not the inter-level W-order cycle. The
    // watchdog catches it; the companion test below shows the same
    // workload completing on the fabric-wide reservation protocol.
    let cfg = SocConfig::default();
    let mut soc = Soc::new(cfg.clone());
    let mut progs = vec![Vec::new(); 32];
    for (src, dma) in contention_programs(&cfg, &mut soc) {
        progs[src] = vec![dma, Cmd::WaitDma];
    }
    soc.load_programs(progs);
    let res = soc.run(
        &mut NopCompute,
        axi_mcast::sim::engine::Watchdog {
            stall_cycles: 50_000,
            max_cycles: 10_000_000,
        },
    );
    assert!(
        res.is_err(),
        "expected the documented inter-level deadlock with e2e ordering \
         off; if this now completes, the RTL-faithful reference mode \
         has been broken — check XbarCfg::e2e_mcast_order defaults"
    );
}

#[test]
fn global_broadcast_contention_completes_with_e2e_order_bit_exact() {
    // The same 8-source contention workload on the fabric-wide
    // reservation protocol: all eight concurrent global broadcasts
    // complete, and memory is bit-identical to the barrier-serialised
    // golden schedule run on the RTL-faithful fabric.
    let mut cfg = SocConfig::default();
    cfg.e2e_mcast_order = true;
    let mut soc = Soc::new(cfg.clone());
    let mut progs = vec![Vec::new(); 32];
    for (src, dma) in contention_programs(&cfg, &mut soc) {
        progs[src] = vec![dma, Cmd::WaitDma];
    }
    soc.load_programs(progs);
    soc.run_default(&mut NopCompute)
        .expect("e2e reservation protocol must break the inter-level cycle");
    let wide = soc.wide.stats_sum();
    assert!(wide.resv_tickets >= 8, "every broadcast must reserve");
    assert_eq!(
        wide.w_beats_out,
        wide.w_beats_in + wide.w_fork_extra,
        "W fork accounting must hold under concurrent multicasts"
    );
    for net in [&soc.wide, &soc.narrow] {
        if let Some(h) = &net.resv {
            assert_eq!(
                h.lock().unwrap().live_tickets(),
                0,
                "all reservation claims must drain"
            );
        }
    }

    // golden: one broadcaster per barrier round, RTL-faithful fabric
    let golden_cfg = SocConfig::default();
    let mut golden = Soc::new(golden_cfg.clone());
    let mut progs: Vec<Vec<Cmd>> = vec![vec![Cmd::Barrier; 8]; 32];
    for (src, dma) in contention_programs(&golden_cfg, &mut golden) {
        let mut p = Vec::new();
        let g = src / 4;
        for round in 0..8usize {
            if round == g {
                p.push(dma.clone());
                p.push(Cmd::WaitDma);
            }
            p.push(Cmd::Barrier);
        }
        progs[src] = p;
    }
    golden.load_programs(progs);
    golden
        .run_default(&mut NopCompute)
        .expect("barrier-serialised golden must complete");
    assert_eq!(
        soc.mem.l1, golden.mem.l1,
        "concurrent broadcasts must land bit-identically to the \
         serialised golden"
    );
}

#[test]
fn mcast_to_subset_group() {
    // multicast to a 8-cluster aligned subset (groups 2-3 only)
    let cfg = SocConfig::default();
    let mut soc = Soc::new(cfg.clone());
    soc.mem.l1[0][..128].fill(0x5A);
    let mut progs = vec![Vec::new(); 32];
    progs[0] = vec![
        Cmd::Dma {
            src: cfg.cluster_base(0),
            dst: cfg.cluster_set(8, 8, 0x2000),
            bytes: 128,
            tag: 1,
        },
        Cmd::WaitDma,
    ];
    soc.load_programs(progs);
    soc.run_default(&mut NopCompute).unwrap();
    for c in 0..32 {
        let got = &soc.mem.l1[c][0x2000..0x2080];
        if (8..16).contains(&c) {
            assert!(got.iter().all(|&b| b == 0x5A), "cluster {c} missing data");
        } else {
            assert!(got.iter().all(|&b| b == 0), "cluster {c} must not be hit");
        }
    }
}

#[test]
fn irq_fanout_and_waits() {
    // cluster 0 multicasts an IRQ; every other cluster waits on it
    let cfg = SocConfig::tiny(8);
    let mut soc = Soc::new(cfg.clone());
    let mut progs: Vec<Vec<Cmd>> = (0..8)
        .map(|_| vec![Cmd::WaitIrq { count: 1 }])
        .collect();
    progs[0] = vec![Cmd::SendIrq {
        dst: cfg.cluster_set(0, 8, axi_mcast::occamy::config::MAILBOX_OFFSET),
    }];
    soc.load_programs(progs);
    soc.run_default(&mut NopCompute).unwrap();
}

#[test]
fn watchdog_catches_missing_irq() {
    // a cluster waits for an interrupt nobody sends — the watchdog
    // must report a deadlock instead of hanging
    let cfg = SocConfig::tiny(4);
    let mut soc = Soc::new(cfg);
    let mut progs = vec![Vec::new(); 4];
    progs[2] = vec![Cmd::WaitIrq { count: 1 }];
    soc.load_programs(progs);
    let err = soc
        .run(
            &mut NopCompute,
            axi_mcast::sim::engine::Watchdog {
                stall_cycles: 2_000,
                max_cycles: 100_000,
            },
        )
        .unwrap_err();
    assert!(format!("{err}").contains("deadlock"));
}

//! Integration tests: AR/R read path (reads are unicast; they share the
//! crossbar with multicast writes).

mod common;

use axi_mcast::axi::types::Resp;
use axi_mcast::axi::xbar::{Xbar, XbarCfg};
use common::*;

fn fixture(n_m: usize, n_s: usize, scripts: Vec<Vec<Xfer>>) -> Fixture {
    let cfg = XbarCfg::new("t", n_m, n_s, cluster_map(n_s, false));
    let (xbar, pool) = Xbar::with_pool(cfg, 2);
    Fixture::new(xbar, pool, scripts)
}

#[test]
fn read_burst_roundtrip() {
    let mut f = fixture(1, 2, vec![vec![Xfer::read(cluster_addr(1, 0x80), 8, 0)]]);
    f.run(10_000).unwrap();
    assert_eq!(f.masters[0].completed_r.len(), 1);
    let (_, resp, beats) = f.masters[0].completed_r[0];
    assert_eq!(resp, Resp::Okay);
    assert_eq!(beats, 8);
    assert_eq!(f.slaves[1].reads.len(), 1);
    assert_eq!(f.slaves[1].reads[0].1, cluster_addr(1, 0x80));
}

#[test]
fn reads_from_many_masters_contend_fairly() {
    // 4 masters all read from slave 0 — RR must serve all of them
    let script = vec![Xfer::read(cluster_addr(0, 0), 4, 0); 4];
    let mut f = fixture(4, 2, vec![script.clone(), script.clone(), script.clone(), script]);
    f.run(20_000).unwrap();
    for m in &f.masters {
        assert_eq!(m.completed_r.len(), 4, "master {} starved", m.idx);
    }
    assert_eq!(f.slaves[0].reads.len(), 16);
}

#[test]
fn unroutable_read_gets_decerr_burst() {
    let mut f = fixture(1, 2, vec![vec![Xfer::read(0xDEAD_0000, 4, 1)]]);
    f.run(10_000).unwrap();
    assert_eq!(f.masters[0].completed_r.len(), 1);
    let (_, resp, beats) = f.masters[0].completed_r[0];
    assert_eq!(resp, Resp::DecErr);
    assert_eq!(beats, 4, "DECERR must still return a full R burst");
}

#[test]
fn reads_interleave_with_mcast_writes() {
    let script = vec![
        Xfer::read(cluster_addr(0, 0), 8, 0),
        Xfer::write(clusters_set(4, 0x40), 8, 1),
        Xfer::read(cluster_addr(3, 0), 8, 2),
    ];
    let mut f = fixture(2, 4, vec![script.clone(), script]);
    f.run(20_000).unwrap();
    f.assert_protocol_clean();
    for m in &f.masters {
        assert_eq!(m.completed_r.len(), 2);
        assert_eq!(m.completed_b.len(), 1);
    }
    for s in &f.slaves {
        assert_eq!(s.writes.len(), 2);
    }
}

#[test]
fn r_beats_route_to_correct_master() {
    // different masters read different slaves concurrently
    let mut f = fixture(
        2,
        2,
        vec![
            vec![Xfer::read(cluster_addr(0, 0x10), 4, 0)],
            vec![Xfer::read(cluster_addr(1, 0x20), 6, 0)],
        ],
    );
    f.run(10_000).unwrap();
    assert_eq!(f.masters[0].completed_r[0].2, 4);
    assert_eq!(f.masters[1].completed_r[0].2, 6);
}

#[test]
fn wide_fan_in_throughput_bounded_by_slave_port() {
    // 8 masters stream reads from one slave; aggregate R beats are
    // bounded by ~1 beat/cycle at the slave port.
    let script: Vec<Xfer> = (0..4).map(|_| Xfer::read(cluster_addr(0, 0), 16, 0)).collect();
    let scripts = (0..8).map(|_| script.clone()).collect();
    let cfg = XbarCfg::new("t", 8, 1, cluster_map(1, false));
    let (xbar, pool) = Xbar::with_pool(cfg, 2);
    let mut f = Fixture::new(xbar, pool, scripts);
    let cycles = f.run(50_000).unwrap();
    let total_beats = 8 * 4 * 16;
    assert!(
        cycles >= total_beats as u64,
        "{total_beats} beats can't take fewer than that many cycles ({cycles})"
    );
    assert!(cycles < total_beats as u64 * 2, "throughput collapsed: {cycles}");
}

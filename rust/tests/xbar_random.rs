//! Randomised traffic equivalence: arbitrary mixes of unicast and
//! multicast writes plus reads, checked against the address decoder's
//! own expectation (every issued write must reach exactly the decoded
//! slave set, exactly once, protocol-clean, no deadlock).

mod common;

use axi_mcast::axi::mcast::AddrSet;
use axi_mcast::axi::types::Resp;
use axi_mcast::axi::xbar::{Xbar, XbarCfg};
use axi_mcast::util::prng::Pcg;
use common::*;

/// Generate a random script for one master.
fn random_script(rng: &mut Pcg, n_slaves: usize, len: usize) -> Vec<Xfer> {
    let mut out = Vec::with_capacity(len);
    for _ in 0..len {
        let id = rng.below(4) as u16;
        let beats = rng.range(1, 16) as u32;
        let r = rng.f64();
        if r < 0.25 {
            // read
            let s = rng.below(n_slaves as u64) as usize;
            out.push(Xfer::read(cluster_addr(s, rng.below(0x1000) * 8), beats, id));
        } else if r < 0.65 {
            // unicast write
            let s = rng.below(n_slaves as u64) as usize;
            out.push(Xfer::write(
                AddrSet::unicast(cluster_addr(s, rng.below(0x1000) * 8)),
                beats,
                id,
            ));
        } else {
            // multicast write: random power-of-two cluster group, aligned
            let log = 1 + rng.below((n_slaves as u64).trailing_zeros() as u64) as u32;
            let count = 1usize << log;
            let first = (rng.below((n_slaves / count) as u64) as usize) * count;
            let mask = (count as u64 - 1) * CLUSTER_STRIDE;
            out.push(Xfer::write(
                AddrSet::new(cluster_addr(first, rng.below(64) * 8), mask),
                beats,
                id,
            ));
        }
    }
    out
}

fn run_random(seed: u64, n_masters: usize, n_slaves: usize, len: usize) {
    let mut rng = Pcg::new(seed);
    let scripts: Vec<Vec<Xfer>> = (0..n_masters)
        .map(|_| random_script(&mut rng, n_slaves, len))
        .collect();
    let cfg = XbarCfg::new("rand", n_masters, n_slaves, cluster_map(n_slaves, false));
    let (xbar, pool) = Xbar::with_pool(cfg, 2);
    let mut f = Fixture::new(xbar, pool, scripts);
    f.run(100_000)
        .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
    f.assert_protocol_clean();

    // every issued write reached exactly its decoded slave set
    let map = cluster_map(n_slaves, false);
    for m in &f.masters {
        assert!(m.done());
        for (txn, x) in &m.issued {
            if x.read {
                let ok = m.completed_r.iter().any(|(t, r, _)| t == txn && *r == Resp::Okay);
                assert!(ok, "seed {seed}: read txn {txn} incomplete");
                continue;
            }
            let d = map.decode(&x.dest);
            let expect: Vec<usize> = d.targets.iter().map(|(s, _)| *s).collect();
            for (si, s) in f.slaves.iter().enumerate() {
                let hits = s.delivered_txns().iter().filter(|t| *t == txn).count();
                let want = if expect.contains(&si) { 1 } else { 0 };
                assert_eq!(
                    hits, want,
                    "seed {seed}: txn {txn} delivered {hits}x to slave {si}, want {want}"
                );
            }
            let b = m
                .completed_b
                .iter()
                .find(|(t, _)| t == txn)
                .unwrap_or_else(|| panic!("seed {seed}: txn {txn} got no B"));
            assert_eq!(b.1, Resp::Okay);
        }
    }
}

#[test]
fn random_traffic_2x2() {
    for seed in 0..6 {
        run_random(seed, 2, 2, 24);
    }
}

#[test]
fn random_traffic_4x4() {
    for seed in 10..14 {
        run_random(seed, 4, 4, 24);
    }
}

#[test]
fn random_traffic_8x8() {
    for seed in 20..22 {
        run_random(seed, 8, 8, 20);
    }
}

#[test]
fn random_traffic_asymmetric_16_masters() {
    run_random(31, 16, 4, 10);
}

#[test]
fn random_traffic_long_bursts() {
    // stress W ordering with more outstanding transactions
    let mut rng = Pcg::new(99);
    let scripts: Vec<Vec<Xfer>> = (0..4)
        .map(|_| {
            (0..8)
                .map(|_| Xfer::write(clusters_set(4, rng.below(64) * 8), 32, 0))
                .collect()
        })
        .collect();
    let cfg = XbarCfg::new("long", 4, 4, cluster_map(4, false));
    let (xbar, pool) = Xbar::with_pool(cfg, 2);
    let mut f = Fixture::new(xbar, pool, scripts);
    f.run(200_000).unwrap();
    f.assert_protocol_clean();
    for s in &f.slaves {
        assert_eq!(s.writes.len(), 32);
    }
}

//! Topology parity suites: any multicast set routed through a
//! hierarchical topology (2-level tree, 3-level tree, mesh of tiles)
//! must deliver the *identical* beat set as the flat golden crossbar —
//! the hierarchical exclude-scope decomposition is semantically
//! invisible.
//!
//! Two layers of checking:
//!
//! * a pure-decode property (fast, many cases): decompose a random
//!   mask-form request the way a 2-level tree does — leaf decode +
//!   exclude-scoped re-decode at the root — and compare covered
//!   addresses against the flat decode;
//! * end-to-end simulation properties (fewer cases): random multicast
//!   scripts run through shape-built fabrics, comparing per-endpoint
//!   delivered `(base, beats)` sets against the flat run.

use axi_mcast::axi::addr_map::{AddrMap, AddrRule};
use axi_mcast::axi::mcast::AddrSet;
use axi_mcast::axi::topology::TopoShape;
use axi_mcast::util::proptest_mini::{check, Config, Gen};
use axi_mcast::workloads::topo_sweep::{
    run_topo_broadcast, run_topo_script, topo_endpoints, TOPO_DST_OFF,
};

const N_EP: usize = 16;
const STRIDE: u64 = 0x4_0000;

/// Occamy-like flat map over all 16 endpoints.
fn flat_map() -> AddrMap {
    let eps = topo_endpoints(N_EP);
    let rules: Vec<AddrRule> = (0..N_EP)
        .map(|i| {
            AddrRule::new(eps.addr(i), eps.addr(i + 1), i, &format!("ep{i}")).with_mcast()
        })
        .collect();
    AddrMap::new(rules, N_EP).unwrap()
}

/// Random aligned multicast set over the endpoint space: a power-of-two
/// group of endpoints at an aligned first index, plus a random offset
/// inside the window.
fn arb_mcast_set(g: &mut Gen) -> AddrSet {
    let eps = topo_endpoints(N_EP);
    let log = g.u64_below(5); // group size 1..16
    let count = 1usize << log;
    let first = (g.u64_below((N_EP / count) as u64) as usize) * count;
    let off = g.u64_below(0x1000) * 8;
    let mask = (count as u64 - 1) * STRIDE;
    AddrSet::new(eps.addr(first) + off, mask)
}

/// The satellite property: AddrSet/AddrMap hierarchical exclude-scope
/// decomposition covers exactly the flat decode, with no address
/// duplicated or dropped, for every leaf position of a 2-level tree.
#[test]
fn prop_exclude_scope_decomposition_matches_flat_decode() {
    let flat = flat_map();
    let eps = topo_endpoints(N_EP);
    // 4 leaves of 4 endpoints; leaf rules map a leaf's local endpoints
    let leaf_map = |leaf: usize| -> AddrMap {
        let first = leaf * 4;
        let rules: Vec<AddrRule> = (0..4)
            .map(|i| {
                AddrRule::new(
                    eps.addr(first + i),
                    eps.addr(first + i + 1),
                    i,
                    &format!("ep{}", first + i),
                )
                .with_mcast()
            })
            .collect();
        AddrMap::new(rules, 4).unwrap()
    };
    // root rules map leaf regions
    let root_rules: Vec<AddrRule> = (0..4)
        .map(|l| {
            let (s, e) = eps.region(l * 4, 4);
            AddrRule::new(s, e, l, &format!("leaf{l}")).with_mcast()
        })
        .collect();
    let root = AddrMap::new(root_rules, 4).unwrap();

    check(
        "exclude-scope-decomposition",
        Config::default(),
        |g| (arb_mcast_set(g), g.u64_below(4) as usize),
        |&(req, src_leaf)| {
            // ---- flat reference: the set of covered addresses ----
            let flat_dec = flat.decode(&req);
            let mut flat_addrs: Vec<u64> = flat_dec
                .targets
                .iter()
                .flat_map(|(_, sub)| sub.enumerate())
                .collect();
            flat_addrs.sort_unstable();

            // ---- hierarchical decomposition, entering at src_leaf ----
            let local = leaf_map(src_leaf).decode(&req);
            let mut tree_addrs: Vec<u64> = local
                .targets
                .iter()
                .flat_map(|(_, sub)| sub.enumerate())
                .collect();
            if local.uncovered > 0 {
                // forward up with the leaf's region as exclude scope
                let scope = eps.region(src_leaf * 4, 4);
                let up = root.decode(&req);
                for (leaf, sub) in &up.targets {
                    if sub.base() >= scope.0 && sub.top() < scope.1 {
                        continue; // pruned: already served locally
                    }
                    // down at that leaf: decode the per-leaf subset
                    let down = leaf_map(*leaf).decode(sub);
                    if down.uncovered > 0 {
                        return Err(format!(
                            "leaf {leaf}: {} addrs of {sub} unroutable",
                            down.uncovered
                        ));
                    }
                    tree_addrs.extend(down.targets.iter().flat_map(|(_, s)| s.enumerate()));
                }
            }
            tree_addrs.sort_unstable();
            let dup = tree_addrs.windows(2).any(|w| w[0] == w[1]);
            if dup {
                return Err(format!("duplicate delivery in {tree_addrs:x?}"));
            }
            if tree_addrs != flat_addrs {
                return Err(format!(
                    "tree covers {tree_addrs:x?}, flat covers {flat_addrs:x?}"
                ));
            }
            Ok(())
        },
    );
}

/// End-to-end: random multicast scripts through every hierarchical
/// shape deliver the identical beat set as the flat fabric.
#[test]
fn prop_random_mcast_scripts_match_flat_end_to_end() {
    let shapes = [
        TopoShape::Tree { arity: vec![4, 4] },
        TopoShape::Tree {
            arity: vec![2, 2, 4],
        },
        TopoShape::Mesh { tiles: 4 },
        TopoShape::Ring { nodes: 4 },
        TopoShape::Torus { cols: 2, rows: 2 },
        TopoShape::RingMesh { groups: 2, tiles: 2 },
    ];
    check(
        "topology-beat-parity",
        Config {
            cases: 12,
            ..Config::default()
        },
        |g| {
            let n = 1 + g.u64_below(4) as usize;
            (0..n)
                .map(|_| {
                    // offsets must keep bursts inside an endpoint window
                    let set = arb_mcast_set(g);
                    let beats = 1 + g.u64_below(8) as u32;
                    (set, beats)
                })
                .collect::<Vec<_>>()
        },
        |script| {
            let flat = run_topo_script(&TopoShape::Flat, N_EP, script.clone(), true)
                .map_err(|e| format!("flat: {e}"))?;
            for shape in &shapes {
                let r = run_topo_script(shape, N_EP, script.clone(), true)
                    .map_err(|e| format!("{}: {e}", shape.label()))?;
                if r.deliveries != flat.deliveries {
                    return Err(format!(
                        "{}: deliveries {:?} != flat {:?}",
                        shape.label(),
                        r.deliveries,
                        flat.deliveries
                    ));
                }
                if r.stats.w_beats_out != r.stats.w_beats_in + r.stats.w_fork_extra {
                    return Err(format!("{}: W fork accounting broken", shape.label()));
                }
                if r.stats.decerr != 0 {
                    return Err(format!("{}: unexpected DECERR", shape.label()));
                }
            }
            Ok(())
        },
    );
}

/// The broadcast microbenchmark runs end-to-end on every shape with
/// multicast beating the unicast train, and the per-xbar stats
/// invariants hold.
#[test]
fn broadcast_runs_on_all_shapes_with_invariants() {
    for shape in [
        TopoShape::Flat,
        TopoShape::Tree { arity: vec![4, 4] },
        TopoShape::Tree {
            arity: vec![2, 2, 4],
        },
        TopoShape::Mesh { tiles: 4 },
        TopoShape::Ring { nodes: 4 },
        TopoShape::Torus { cols: 2, rows: 2 },
        TopoShape::RingMesh { groups: 2, tiles: 2 },
    ] {
        let uni = run_topo_broadcast(&shape, N_EP, 2, 16, false)
            .unwrap_or_else(|e| panic!("{}: unicast: {e}", shape.label()));
        let hw = run_topo_broadcast(&shape, N_EP, 2, 16, true)
            .unwrap_or_else(|e| panic!("{}: mcast: {e}", shape.label()));
        assert!(
            hw.cycles < uni.cycles,
            "{}: mcast ({}) must beat unicast ({})",
            shape.label(),
            hw.cycles,
            uni.cycles
        );
        for r in [&uni, &hw] {
            assert_eq!(
                r.stats.w_beats_out,
                r.stats.w_beats_in + r.stats.w_fork_extra,
                "{}: W fork accounting",
                r.shape
            );
            assert_eq!(r.stats.decerr, 0, "{}: DECERR", r.shape);
        }
        // the delivered beat totals are mode-independent
        assert_eq!(uni.deliveries, hw.deliveries, "{}", shape.label());
    }
}

/// Payload bases: every delivered burst lands at its endpoint's
/// `base + DST_OFF` window regardless of shape (no address corruption
/// through the exclude-scope rewrite).
#[test]
fn delivered_bases_are_exact() {
    let eps = topo_endpoints(N_EP);
    for shape in [
        TopoShape::Tree { arity: vec![4, 4] },
        TopoShape::Mesh { tiles: 4 },
        TopoShape::Ring { nodes: 4 },
        TopoShape::Torus { cols: 2, rows: 2 },
        TopoShape::RingMesh { groups: 2, tiles: 2 },
    ] {
        let r = run_topo_broadcast(&shape, N_EP, 3, 4, true).unwrap();
        for (i, d) in r.deliveries.iter().enumerate() {
            assert_eq!(d.len(), 3);
            for (base, beats) in d {
                assert_eq!(*base, eps.addr(i) + TOPO_DST_OFF);
                assert_eq!(*beats, 4);
            }
        }
    }
}

//! End-to-end multicast ordering property suite: concurrent,
//! *overlapping* global multicasts on the fabric-wide reservation
//! protocol must deliver exactly what a barrier-serialised execution
//! delivers — bit-identical memory and the same per-slave burst set —
//! on every wide-network shape (the paper's group tree, a flat
//! crossbar, a 3-level tree, a mesh of tiles). Without the protocol
//! these workloads hit the documented inter-level W-order deadlock
//! (`tests/occamy_system.rs`).

use axi_mcast::occamy::{Cmd, NopCompute, Soc, SocConfig, WideShape};
use axi_mcast::util::proptest_mini::{check, Config, Gen};

const N: usize = 8;

fn shapes() -> Vec<WideShape> {
    vec![
        WideShape::Groups,
        WideShape::Flat,
        WideShape::Tree(vec![2, 2, 2]),
        WideShape::Mesh(2),
    ]
}

/// One multicast transfer: source cluster, aligned destination window
/// `[first, first+count)`, payload bytes. Every transfer writes a
/// transfer-distinct L1 offset, so memory is order-independent and the
/// serialised golden is bit-comparable.
#[derive(Debug, Clone, Copy)]
struct Xfer {
    src: usize,
    first: usize,
    count: usize,
    bytes: u64,
}

fn dst_off(k: usize) -> u64 {
    0x8000 + k as u64 * 0x1000
}

/// Random concurrent-multicast scenario: distinct sources, overlapping
/// power-of-two destination sets (global sets included — the case the
/// RTL-faithful fabric cannot run concurrently).
fn gen_scenario(g: &mut Gen) -> Vec<Xfer> {
    let n_src = 2 + g.u64_below(7) as usize; // 2..=8 sources
    let mut srcs: Vec<usize> = (0..N).collect();
    for i in 0..n_src {
        let j = i + g.u64_below((N - i) as u64) as usize;
        srcs.swap(i, j);
    }
    srcs[..n_src]
        .iter()
        .map(|&src| {
            let count = 1usize << (1 + g.u64_below(3)); // 2, 4 or 8
            let first = if count >= N {
                0
            } else {
                g.u64_below((N / count) as u64) as usize * count
            };
            Xfer {
                src,
                first,
                count: count.min(N),
                bytes: 64 * (1 + g.u64_below(8)),
            }
        })
        .collect()
}

fn seed_sources(soc: &mut Soc, xfers: &[Xfer]) {
    for (k, x) in xfers.iter().enumerate() {
        for (i, b) in soc.mem.l1[x.src][..x.bytes as usize].iter_mut().enumerate() {
            *b = ((i * 11 + k * 29 + x.src * 5) % 253) as u8;
        }
    }
}

fn dma(cfg: &SocConfig, k: usize, x: &Xfer) -> Cmd {
    Cmd::Dma {
        src: cfg.cluster_base(x.src),
        dst: cfg.cluster_set(x.first, x.count, dst_off(k)),
        bytes: x.bytes,
        tag: k as u64,
    }
}

struct Outcome {
    l1: Vec<Vec<u8>>,
    /// Per cluster: sorted (base, beats) of every burst its wide L1
    /// port accepted — the per-slave beat set, order erased.
    slave_bursts: Vec<Vec<(u64, u32)>>,
    dma_w_beats: u64,
}

fn outcome(soc: &Soc) -> Outcome {
    Outcome {
        l1: soc.mem.l1.clone(),
        slave_bursts: soc
            .clusters
            .iter()
            .map(|c| {
                let mut v: Vec<(u64, u32)> = c
                    .l1_port
                    .writes
                    .iter()
                    .map(|w| (w.base, w.beats))
                    .collect();
                v.sort_unstable();
                v
            })
            .collect(),
        dma_w_beats: soc.clusters.iter().map(|c| c.dma.stats.write_beats).sum(),
    }
}

/// Run the scenario with every transfer in flight at once on the e2e
/// reservation fabric.
fn run_concurrent(shape: &WideShape, xfers: &[Xfer]) -> Outcome {
    let mut cfg = SocConfig::tiny(N);
    cfg.wide_shape = shape.clone();
    cfg.e2e_mcast_order = true;
    let mut soc = Soc::new(cfg.clone());
    seed_sources(&mut soc, xfers);
    let mut progs = vec![Vec::new(); N];
    for (k, x) in xfers.iter().enumerate() {
        progs[x.src].push(dma(&cfg, k, x));
    }
    for x in xfers {
        progs[x.src].push(Cmd::WaitDma);
    }
    soc.load_programs(progs);
    soc.run_default(&mut NopCompute).unwrap_or_else(|e| {
        panic!("concurrent multicasts deadlocked on {}: {e}", shape.label())
    });
    for net in [&soc.wide, &soc.narrow] {
        if let Some(h) = &net.resv {
            assert_eq!(
                h.lock().unwrap().live_tickets(),
                0,
                "{}: undrained reservation claims",
                shape.label()
            );
        }
    }
    let wide = soc.wide.stats_sum();
    assert_eq!(
        wide.w_beats_out,
        wide.w_beats_in + wide.w_fork_extra,
        "{}: W fork accounting broken under concurrency",
        shape.label()
    );
    outcome(&soc)
}

/// The golden: identical transfers, one at a time between barriers, on
/// the RTL-faithful fabric (no reservation protocol).
fn run_serialized(shape: &WideShape, xfers: &[Xfer]) -> Outcome {
    let mut cfg = SocConfig::tiny(N);
    cfg.wide_shape = shape.clone();
    let mut soc = Soc::new(cfg.clone());
    seed_sources(&mut soc, xfers);
    let mut progs: Vec<Vec<Cmd>> = vec![Vec::new(); N];
    for (src, prog) in progs.iter_mut().enumerate() {
        for (k, x) in xfers.iter().enumerate() {
            if x.src == src {
                prog.push(dma(&cfg, k, x));
                prog.push(Cmd::WaitDma);
            }
            prog.push(Cmd::Barrier);
        }
    }
    soc.load_programs(progs);
    soc.run_default(&mut NopCompute)
        .unwrap_or_else(|e| panic!("serialised golden failed on {}: {e}", shape.label()));
    outcome(&soc)
}

#[test]
fn concurrent_overlapping_mcasts_match_serialized_golden_on_all_shapes() {
    check(
        "e2e-concurrent-vs-serialized",
        Config {
            cases: 6,
            ..Config::default()
        },
        gen_scenario,
        |xfers| {
            for shape in shapes() {
                let conc = run_concurrent(&shape, xfers);
                let ser = run_serialized(&shape, xfers);
                if conc.l1 != ser.l1 {
                    return Err(format!("{}: memory diverged", shape.label()));
                }
                if conc.slave_bursts != ser.slave_bursts {
                    return Err(format!("{}: per-slave burst sets diverged", shape.label()));
                }
                if conc.dma_w_beats != ser.dma_w_beats {
                    return Err(format!(
                        "{}: injected W beats diverged ({} vs {})",
                        shape.label(),
                        conc.dma_w_beats,
                        ser.dma_w_beats
                    ));
                }
            }
            Ok(())
        },
    );
}

/// The worst case the protocol exists for: every cluster broadcasting
/// to ALL clusters at once, on every shape.
#[test]
fn all_sources_global_broadcast_concurrently_on_all_shapes() {
    let xfers: Vec<Xfer> = (0..N)
        .map(|src| Xfer {
            src,
            first: 0,
            count: N,
            bytes: 512,
        })
        .collect();
    for shape in shapes() {
        let conc = run_concurrent(&shape, &xfers);
        let ser = run_serialized(&shape, &xfers);
        assert_eq!(conc.l1, ser.l1, "{}: memory diverged", shape.label());
        assert_eq!(
            conc.slave_bursts,
            ser.slave_bursts,
            "{}: burst sets diverged",
            shape.label()
        );
    }
}

//! Integration suite for the collectives workload family: bit-exact
//! data correctness for every op on every wide-network shape, in all
//! four strategies (sw / hw-mcast / hw-concurrent / hw-reduce), plus
//! the cost invariants (no hardware strategy injects more W beats into
//! the fabric than the unicast baseline and
//! `dma_w_beats_red <= dma_w_beats_conc <= dma_w_beats_sw` per row,
//! the per-crossbar W fork/join accounting always balances, the
//! hw-concurrent schedules — N simultaneous global multicasts on the
//! e2e reservation protocol — beat the one-multicast-in-flight
//! schedule, and the hw-reduce schedules combine converging traffic
//! inside the fabric with zero software combines).

use axi_mcast::coordinator::experiments::{assert_coll_row_invariants, collectives};
use axi_mcast::occamy::{SocConfig, WideShape};
use axi_mcast::workloads::collectives::{
    default_shapes, run_collective, CollMode, CollOp,
};

fn cfg8() -> SocConfig {
    SocConfig::tiny(8) // 2 groups of 4
}

const BYTES8: u64 = 4096; // 8 clusters => 512 B chunks

/// Every op × shape × mode (sw, hw-mcast and hw-concurrent): result
/// buffers bit-exact vs the scalar reference reduction, fork
/// accounting balanced, no DECERR, the injected-W-beat invariant per
/// (op, shape), and the reservation ledger fully drained.
#[test]
fn all_ops_all_shapes_all_modes_bit_exact() {
    let cfg = cfg8();
    let mut shapes = default_shapes(&cfg);
    assert!(
        shapes.contains(&WideShape::Groups)
            && shapes.contains(&WideShape::Flat)
            && shapes.contains(&WideShape::Mesh(2)),
        "default shape sweep must cover tree/flat/mesh, got {shapes:?}"
    );
    // the advertised deeper-tree shape gets end-to-end coverage too
    shapes.push(WideShape::Tree(vec![2, 2, 2]));
    let (rows, _table, json) = collectives(&cfg, &CollOp::ALL, &shapes, BYTES8);
    assert_eq!(rows.len(), CollOp::ALL.len() * shapes.len());
    for r in &rows {
        assert_coll_row_invariants(r);
    }
    assert_eq!(json.as_arr().unwrap().len(), rows.len());
}

/// The acceptance speedups: hardware-multicast broadcast and all-gather
/// beat the unicast software baseline on >= 8 clusters, on every shape.
#[test]
fn hw_broadcast_and_all_gather_beat_sw_on_8_clusters() {
    let cfg = cfg8();
    for shape in default_shapes(&cfg) {
        let mut cfg = cfg.clone();
        cfg.wide_shape = shape.clone();
        for op in [CollOp::Broadcast, CollOp::AllGather] {
            let sw = run_collective(&cfg, op, CollMode::Sw, BYTES8);
            let hw = run_collective(&cfg, op, CollMode::Hw, BYTES8);
            assert!(sw.numerics_ok && hw.numerics_ok);
            assert!(
                hw.cycles < sw.cycles,
                "{} on {}: hw-mcast ({}) must beat the sw baseline ({})",
                op.name(),
                shape.label(),
                hw.cycles,
                sw.cycles
            );
        }
    }
}

/// The converging N-to-1 patterns (direct reduce-scatter, hierarchical
/// reduce) deliver bit-exact sums — the first reduction traffic the
/// fabric carries — and the reduction really runs through the compute
/// handler.
#[test]
fn converging_reductions_are_exact_and_counted() {
    let cfg = cfg8();
    let rs = run_collective(&cfg, CollOp::ReduceScatter, CollMode::Hw, BYTES8);
    assert!(rs.numerics_ok);
    // one local fold per cluster
    assert_eq!(rs.combines, 8);
    let ar = run_collective(&cfg, CollOp::AllReduce, CollMode::Hw, BYTES8);
    assert!(ar.numerics_ok);
    // one partial per non-root leader + the root's final fold
    assert_eq!(ar.combines, 2);
    // the reduced result is distributed by exactly one multicast chain
    assert!(ar.wide.aw_mcast >= 1);
}

/// Ring schedules only ever use unicast transfers — the sw baseline
/// must work on a system without any multicast support at all.
#[test]
fn sw_baselines_never_multicast() {
    let cfg = cfg8();
    for op in CollOp::ALL {
        let r = run_collective(&cfg, op, CollMode::Sw, BYTES8);
        assert!(r.numerics_ok, "{} sw numerics", op.name());
        assert_eq!(r.wide.aw_mcast, 0, "{} sw multicasted", op.name());
        // no multicast => no fork amplification anywhere
        assert_eq!(r.wide.w_fork_extra, 0, "{} sw forked W beats", op.name());
    }
}

/// Scaling smoke at the paper's system size: 16 clusters (4 groups),
/// broadcast + all-gather + all-reduce, hw wins and stays exact.
#[test]
fn sixteen_cluster_scaling_smoke() {
    let cfg = SocConfig::tiny(16);
    let bytes = 8 * 1024; // 512 B chunks (16 KiB would blow the AR-hw slot budget)
    for op in [CollOp::Broadcast, CollOp::AllGather, CollOp::AllReduce] {
        let sw = run_collective(&cfg, op, CollMode::Sw, bytes);
        let hw = run_collective(&cfg, op, CollMode::Hw, bytes);
        assert!(sw.numerics_ok && hw.numerics_ok, "{} numerics", op.name());
        assert!(
            hw.cycles < sw.cycles,
            "{}: hw ({}) must beat sw ({}) at 16 clusters",
            op.name(),
            hw.cycles,
            sw.cycles
        );
        assert!(hw.dma_w_beats <= sw.dma_w_beats);
    }
}

/// ISSUE acceptance: the `hw-concurrent` all-gather — N simultaneous
/// global multicasts, one per rank, the schedule the RTL-faithful
/// fabric deadlocks on — finishes in fewer simulated cycles than the
/// one-multicast-in-flight `hw-mcast` schedule at ≥ 8 clusters while
/// injecting no more W beats, on every wide-network shape.
#[test]
fn concurrent_all_gather_beats_single_mcast_schedule() {
    for clusters in [8usize, 16] {
        let cfg = SocConfig::tiny(clusters);
        let bytes = 512 * clusters as u64;
        for shape in default_shapes(&cfg) {
            let mut cfg = cfg.clone();
            cfg.wide_shape = shape.clone();
            let hw = run_collective(&cfg, CollOp::AllGather, CollMode::Hw, bytes);
            let conc = run_collective(&cfg, CollOp::AllGather, CollMode::HwConc, bytes);
            assert!(hw.numerics_ok && conc.numerics_ok);
            assert!(
                conc.cycles < hw.cycles,
                "all-gather on {} @{clusters}cl: hw-concurrent ({}) must beat \
                 the one-multicast-in-flight schedule ({})",
                shape.label(),
                conc.cycles,
                hw.cycles
            );
            assert!(
                conc.dma_w_beats <= hw.dma_w_beats,
                "all-gather on {} @{clusters}cl: hw-concurrent injects more W \
                 beats ({} > {})",
                shape.label(),
                conc.dma_w_beats,
                hw.dma_w_beats
            );
            assert!(
                conc.wide.resv_tickets >= clusters as u64,
                "every rank's multicast must take a reservation ticket"
            );
        }
    }
}

/// The concurrent broadcast (scatter + simultaneous re-broadcast from
/// all sources) stays bit-exact and within the baseline's injection
/// budget at scale.
#[test]
fn concurrent_broadcast_pipelines_from_all_sources() {
    let cfg = SocConfig::tiny(8);
    let sw = run_collective(&cfg, CollOp::Broadcast, CollMode::Sw, BYTES8);
    let conc = run_collective(&cfg, CollOp::Broadcast, CollMode::HwConc, BYTES8);
    assert!(sw.numerics_ok && conc.numerics_ok);
    // the re-broadcast phase multicasts from every rank
    assert!(
        conc.wide.aw_mcast > sw.wide.aw_mcast && conc.wide.resv_tickets >= 8,
        "conc broadcast must issue concurrent multicasts from all ranks"
    );
    assert!(conc.dma_w_beats <= sw.dma_w_beats);
    assert!(
        conc.cycles < sw.cycles,
        "conc broadcast ({}) must beat the software tree ({})",
        conc.cycles,
        sw.cycles
    );
}

/// ISSUE acceptance: the `hw-reduce` reduce-scatter and all-reduce —
/// tagged member bursts combined inside the fabric — stay bit-exact on
/// all four shapes (groups / flat / mesh / deep tree), dispatch ZERO
/// software combines, really join in-network, and shrink the fabric's
/// upstream W traffic relative to the endpoint-resolved direct
/// scatter. (The `red <= conc <= sw` injection chain is asserted per
/// row by `assert_coll_row_invariants` in
/// `all_ops_all_shapes_all_modes_bit_exact`.)
#[test]
fn hw_reduce_joins_in_network_on_every_shape() {
    let cfg = cfg8();
    let mut shapes = default_shapes(&cfg);
    shapes.push(WideShape::Tree(vec![2, 2, 2]));
    for shape in shapes {
        let mut cfg = cfg.clone();
        cfg.wide_shape = shape.clone();
        for op in [CollOp::ReduceScatter, CollOp::AllReduce] {
            let conc = run_collective(&cfg, op, CollMode::HwConc, BYTES8);
            let red = run_collective(&cfg, op, CollMode::HwReduce, BYTES8);
            assert!(red.numerics_ok, "{} on {}", op.name(), shape.label());
            assert_eq!(
                red.combines,
                0,
                "{} on {}: hw-reduce must not round-trip through the handler",
                op.name(),
                shape.label()
            );
            assert!(
                red.wide.red_joins > 0 && red.wide.red_beats_saved > 0,
                "{} on {}: converging members never combined ({:?})",
                op.name(),
                shape.label(),
                red.wide
            );
            assert!(
                red.dma_w_beats <= conc.dma_w_beats,
                "{} on {}: hw-reduce injects more than the direct scatter",
                op.name(),
                shape.label()
            );
            // upstream saving: hop-for-hop the combining fabric moves
            // fewer W beats than the endpoint-resolved scatter phase
            assert_eq!(
                red.wide.w_beats_out,
                red.wide.w_beats_in + red.wide.w_fork_extra - red.wide.red_beats_saved,
                "{} on {}: join accounting",
                op.name(),
                shape.label()
            );
        }
    }
}

/// The wide-shape plumbing itself: the same multicast workload delivers
/// identically on a flat, tree and mesh wide network (cycle counts may
/// differ; functional results and delivery counts may not).
#[test]
fn shapes_agree_on_delivered_data() {
    let cfg = cfg8();
    let mut gathers = Vec::new();
    for shape in default_shapes(&cfg) {
        let mut cfg = cfg.clone();
        cfg.wide_shape = shape;
        let r = run_collective(&cfg, CollOp::AllGather, CollMode::Hw, BYTES8);
        assert!(r.numerics_ok);
        gathers.push((r.shape.clone(), r.dma_w_beats, r.combines));
    }
    // injected beats are a schedule property, not a topology property
    for w in gathers.windows(2) {
        assert_eq!(
            w[0].1, w[1].1,
            "injected W beats diverge between {} and {}",
            w[0].0, w[1].0
        );
    }
}

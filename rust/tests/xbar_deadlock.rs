//! The fig. 2e deadlock: without the commit protocol (and with the
//! per-mux arbitration that real naive designs would use), two
//! overlapping multicasts acquire slaves in opposite orders and the
//! all-ready W forks starve each other forever. With the commit
//! protocol the same traffic completes.

mod common;

use axi_mcast::axi::xbar::{Xbar, XbarCfg};
use axi_mcast::sim::engine::SimError;
use common::*;

fn scripts() -> Vec<Vec<Xfer>> {
    // Both masters multicast to slaves {0,1} simultaneously with long
    // bursts — exactly the AW0/AW1 + W0x/W1x interleaving of fig. 2e.
    let s = |id| {
        (0..4)
            .map(|_| Xfer::write(clusters_set(2, 0), 16, id))
            .collect::<Vec<_>>()
    };
    vec![s(0), s(1)]
}

#[test]
fn no_commit_protocol_deadlocks() {
    let mut cfg = XbarCfg::new("naive", 2, 2, cluster_map(2, false));
    cfg.commit_protocol = false;
    let (xbar, pool) = Xbar::with_pool(cfg, 2);
    let mut f = Fixture::new(xbar, pool, scripts());
    // diverge the per-mux round-robin pointers — the "unlucky but
    // perfectly legal" arbitration state of fig. 2e
    f.xbar.mux[0].rr_mcast = 0;
    f.xbar.mux[1].rr_mcast = 1;
    match f.run(2_000) {
        Err(SimError::Deadlock { .. }) => {} // expected
        Ok(cy) => panic!("expected deadlock, finished at cycle {cy}"),
        Err(e) => panic!("unexpected error: {e}"),
    }
}

#[test]
fn commit_protocol_completes_same_traffic() {
    let cfg = XbarCfg::new("commit", 2, 2, cluster_map(2, false));
    assert!(cfg.commit_protocol);
    let (xbar, pool) = Xbar::with_pool(cfg, 2);
    let mut f = Fixture::new(xbar, pool, scripts());
    f.xbar.mux[0].rr_mcast = 0;
    f.xbar.mux[1].rr_mcast = 1;
    let cycles = f.run(2_000).expect("commit protocol must complete");
    f.assert_protocol_clean();
    assert_eq!(f.masters[0].completed_b.len(), 4);
    assert_eq!(f.masters[1].completed_b.len(), 4);
    // 8 transfers × 16 beats, two slaves each; W serialised per slave
    assert!(cycles > 8 * 16, "cycles={cycles}");
}

#[test]
fn no_commit_ok_when_sets_disjoint() {
    // Disjoint target sets can't deadlock even without commit.
    let s0 = vec![Xfer::write(clusters_set(2, 0), 8, 0)];
    let s2 = vec![Xfer::write(
        axi_mcast::axi::mcast::AddrSet::new(cluster_addr(2, 0), CLUSTER_STRIDE),
        8,
        1,
    )];
    let mut cfg = XbarCfg::new("naive", 2, 4, cluster_map(4, false));
    cfg.commit_protocol = false;
    let (xbar, pool) = Xbar::with_pool(cfg, 2);
    let mut f = Fixture::new(xbar, pool, vec![s0, s2]);
    f.run(5_000).expect("disjoint sets cannot deadlock");
    f.assert_protocol_clean();
}

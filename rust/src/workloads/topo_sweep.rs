//! Topology-shape sweep: the 1-to-N distribution microbenchmark run
//! directly on fabrics built by the topology subsystem (no Occamy SoC
//! around them), across shapes — flat N×N, hierarchical trees, meshes
//! of crossbar tiles, rings, tori and rings of mesh groups — in
//! hardware-multicast vs unicast-train mode.
//!
//! The scenario reports cycles plus the aggregate [`XbarStats`] so the
//! multicast claim is visible at beat granularity: one mask-form AW in,
//! `fanout` AWs forked, `w_beats_out == w_beats_in + w_fork_extra`.
//! Used by `coordinator::experiments::topo_sweep`, the `topo_shapes`
//! bench and the `topology_parity` integration suite.

use crate::axi::golden::SimSlave;
use crate::axi::mcast::AddrSet;
use crate::axi::topology::{build_shape, BuiltTopo, EndpointMap, FabricParams, TopoShape, Topology};
use crate::axi::types::{AwBeat, LinkId, LinkPool, WBeat};
use crate::axi::xbar::{Xbar, XbarStats};
use crate::sim::engine::{Engine, SimError, StepResult, Watchdog};
use crate::sim::parallel::{
    link_homes, merge_pools, partition, split_pool, tick_link, Atom, StepFn, WorkerPool,
};
use crate::sim::sched::Scheduler;

/// Endpoint window layout used by the sweep (Occamy-like cluster map).
pub const TOPO_EP_BASE: u64 = 0x0100_0000;
pub const TOPO_EP_STRIDE: u64 = 0x4_0000;
/// Offset inside each endpoint window receiving the payload.
pub const TOPO_DST_OFF: u64 = 0x1000;

/// Endpoint map of `n` sweep endpoints.
pub fn topo_endpoints(n: usize) -> EndpointMap {
    EndpointMap {
        base: TOPO_EP_BASE,
        stride: TOPO_EP_STRIDE,
        count: n,
    }
}

/// One measured run.
#[derive(Debug, Clone)]
pub struct TopoRunResult {
    pub shape: String,
    pub n_endpoints: usize,
    pub mcast: bool,
    pub cycles: u64,
    pub n_xbars: usize,
    /// Aggregate over every crossbar in the fabric.
    pub stats: XbarStats,
    /// Per endpoint: delivered write bursts as `(base addr, beats)`.
    pub deliveries: Vec<Vec<(u64, u32)>>,
}

impl TopoRunResult {
    pub fn delivered_bursts(&self) -> u64 {
        self.deliveries.iter().map(|d| d.len() as u64).sum()
    }
}

/// The broadcast script: `bursts` rounds of sending `beats`-beat bursts
/// from endpoint 0 to every endpoint. In multicast mode each round is
/// one mask-form transfer; in unicast mode it is a train of `n`
/// transfers.
pub fn broadcast_script(n_endpoints: usize, bursts: usize, beats: u32, mcast: bool) -> Vec<(AddrSet, u32)> {
    assert!(
        n_endpoints.is_power_of_two(),
        "broadcast set must be a power of two"
    );
    let eps = topo_endpoints(n_endpoints);
    let mut script = Vec::new();
    for _ in 0..bursts {
        if mcast {
            let mask = (n_endpoints as u64 - 1) * eps.stride;
            script.push((AddrSet::new(eps.base + TOPO_DST_OFF, mask), beats));
        } else {
            for i in 0..n_endpoints {
                script.push((AddrSet::unicast(eps.addr(i) + TOPO_DST_OFF), beats));
            }
        }
    }
    script
}

/// Scripted write master driving one fabric link.
struct ScriptMaster {
    script: std::collections::VecDeque<(AddrSet, u32)>,
    sending: Option<(u64, u32)>, // (txn, beats left)
    inflight: u32,
    max_inflight: u32,
    next_txn: u64,
    next_id: u16,
}

impl ScriptMaster {
    fn new(script: Vec<(AddrSet, u32)>) -> ScriptMaster {
        ScriptMaster {
            script: script.into(),
            sending: None,
            inflight: 0,
            max_inflight: 4,
            next_txn: 1,
            next_id: 0,
        }
    }

    fn done(&self) -> bool {
        self.script.is_empty() && self.sending.is_none() && self.inflight == 0
    }

    fn step(&mut self, link: &mut crate::axi::types::AxiLink) {
        while link.b.pop().is_some() {
            self.inflight -= 1;
        }
        if let Some((txn, left)) = self.sending {
            if link.w.can_push() {
                link.w.push(WBeat {
                    last: left == 1,
                    src: 0,
                    txn,
                });
                self.sending = if left == 1 { None } else { Some((txn, left - 1)) };
            }
            return;
        }
        if self.inflight >= self.max_inflight {
            return;
        }
        let Some(&(dest, beats)) = self.script.front() else {
            return;
        };
        if link.aw.can_push() && link.w.can_push() {
            self.script.pop_front();
            let txn = self.next_txn;
            self.next_txn += 1;
            let id = self.next_id;
            self.next_id = (self.next_id + 1) % 4;
            link.aw.push(AwBeat {
                id,
                dest,
                beats,
                beat_bytes: 64,
                is_mcast: !dest.is_singleton(),
                exclude: None,
                window: None,
                src: 0,
                txn,
                ticket: None,
                reduce: None,
            });
            self.sending = Some((txn, beats));
            self.inflight += 1;
        }
    }
}

/// Wall-clock split of one scripted run (§Perf `topo_shapes` timing
/// mode): fabric construction vs the simulation loop proper, so
/// throughput numbers are not polluted by `build_shape` allocation.
#[derive(Debug, Clone, Copy, Default)]
pub struct TopoTiming {
    pub build_s: f64,
    pub run_s: f64,
}

/// Run a write script from endpoint 0 through a shape-built fabric,
/// with golden slaves on every endpoint. Fabric multicast support
/// follows `mcast` (unicast scripts run on a baseline fabric, exactly
/// like the paper's baseline comparison).
pub fn run_topo_script(
    shape: &TopoShape,
    n_endpoints: usize,
    script: Vec<(AddrSet, u32)>,
    mcast: bool,
) -> Result<TopoRunResult, SimError> {
    run_topo_script_timed(shape, n_endpoints, script, mcast).map(|(r, _)| r)
}

/// [`run_topo_script`] with the construction/run wall-clock split.
pub fn run_topo_script_timed(
    shape: &TopoShape,
    n_endpoints: usize,
    script: Vec<(AddrSet, u32)>,
    mcast: bool,
) -> Result<(TopoRunResult, TopoTiming), SimError> {
    let params = FabricParams {
        mcast_enabled: mcast,
        ..FabricParams::default()
    };
    run_topo_script_with(shape, n_endpoints, script, params)
}

/// [`run_topo_script_timed`] with explicit [`FabricParams`] — the knob
/// surface for the perf bench and the `--threads` CLI plumbing.
/// `params.threads > 1` runs the partitioned multi-threaded schedule
/// ([`crate::sim::parallel`]), bit-identical to the sequential one.
pub fn run_topo_script_with(
    shape: &TopoShape,
    n_endpoints: usize,
    script: Vec<(AddrSet, u32)>,
    params: FabricParams,
) -> Result<(TopoRunResult, TopoTiming), SimError> {
    let t_build = std::time::Instant::now();
    let threads = crate::util::resolve_threads(params.threads);
    let mut pool = LinkPool::new();
    let BuiltTopo {
        mut topo,
        endpoint_m,
        endpoint_s,
        ..
    } = build_shape(&mut pool, 2, topo_endpoints(n_endpoints), params, shape);
    let src = endpoint_m[0];
    let mut master = ScriptMaster::new(script);
    let mut slaves: Vec<SimSlave> = (0..n_endpoints).map(SimSlave::new).collect();
    let build_s = t_build.elapsed().as_secs_f64();
    let t_run = std::time::Instant::now();

    let mut eng = Engine::new(Watchdog {
        stall_cycles: 100_000,
        max_cycles: 50_000_000,
    });
    let cycles = if threads > 1 {
        run_topo_parallel(
            &mut eng,
            &mut topo,
            &mut pool,
            &mut master,
            src,
            &mut slaves,
            &endpoint_s,
            threads,
        )?
    } else {
        let mut sched = Scheduler::new(pool.len());
        eng.run(|cy| {
            sched.begin_cycle();
            // (no post-done drain needed: done() requires inflight == 0,
            // which means every B was already popped from the src link)
            if !master.done() {
                master.step(&mut pool[src]);
                sched.mark_dirty(src);
            }
            topo.step_scheduled(cy, &mut pool, &mut sched);
            for (i, s) in slaves.iter_mut().enumerate() {
                let link = endpoint_s[i];
                if !s.idle() || sched.is_active(link) {
                    s.step_on(cy, &mut pool, link);
                    sched.mark_dirty(link);
                }
            }
            sched.end_cycle(&mut pool);
            let all_done = master.done()
                && !topo.busy()
                && slaves.iter().all(|s| s.idle());
            if all_done {
                StepResult::Done
            } else {
                StepResult::Running {
                    progress: pool.moved_total(),
                }
            }
        })?
    };

    let run_s = t_run.elapsed().as_secs_f64();

    for s in &slaves {
        s.assert_clean();
    }
    let deliveries = slaves
        .iter()
        .map(|s| s.writes.iter().map(|w| (w.base, w.beats)).collect())
        .collect();
    Ok((
        TopoRunResult {
            shape: shape.label(),
            n_endpoints,
            mcast,
            cycles,
            n_xbars: topo.xbars.len(),
            stats: topo.stats_sum(),
            deliveries,
        },
        TopoTiming { build_s, run_s },
    ))
}

// ------------------------------------------------------ parallel schedule

/// One component of a [`TopoShard`], stepped with exactly the gating
/// the sequential loop applies.
enum TopoComp {
    Master { m: ScriptMaster, src: LinkId },
    /// A run of crossbars stepped in `Topology::xbars` order; `first`
    /// is the original index of `xbars[0]`. The whole fabric is one
    /// run when a shared reservation ledger is armed (its first-come
    /// seq assignment is the only in-cycle cross-crossbar order
    /// dependency); otherwise one run per crossbar.
    Xbars { first: usize, xbars: Vec<Xbar> },
    Slave { idx: usize, s: SimSlave, link: LinkId },
}

/// One worker thread's slice of the scripted harness: its components,
/// a full-size shard pool (owned links whole, cut links as one half)
/// and a shard scheduler re-synced from the master every cycle.
struct TopoShard {
    comps: Vec<TopoComp>,
    pool: LinkPool,
    sched: Scheduler,
}

fn step_topo_shard(sh: &mut TopoShard, cy: u64) {
    let TopoShard { comps, pool, sched } = sh;
    for c in comps.iter_mut() {
        match c {
            TopoComp::Master { m, src } => {
                if !m.done() {
                    m.step(&mut pool[*src]);
                    sched.mark_dirty(*src);
                }
            }
            TopoComp::Xbars { xbars, .. } => {
                for x in xbars.iter_mut() {
                    sched.step_component(cy, x, pool);
                }
            }
            TopoComp::Slave { s, link, .. } => {
                if !s.idle() || sched.is_active(*link) {
                    s.step_on(cy, pool, *link);
                    sched.mark_dirty(*link);
                }
            }
        }
    }
}

/// The multi-threaded run loop behind [`run_topo_script_with`]:
/// partition {master, crossbars, endpoint slaves} across `threads`
/// shards by link affinity, step shards concurrently, merge at the
/// clock edge — bit-identical to the sequential loop (the registered
/// ready/visibility invariant, see `sim::parallel`). On return (also
/// on watchdog errors) every component and the pool are recomposed so
/// the caller reads stats and deliveries exactly as in the sequential
/// path.
#[allow(clippy::too_many_arguments)]
fn run_topo_parallel(
    eng: &mut Engine,
    topo: &mut Topology,
    pool: &mut LinkPool,
    master: &mut ScriptMaster,
    src: LinkId,
    slaves: &mut Vec<SimSlave>,
    endpoint_s: &[LinkId],
    threads: usize,
) -> Result<u64, SimError> {
    // ---- atoms: master, crossbar runs, slaves — in that order
    let armed = topo.resv.is_some();
    let n_xb = topo.xbars.len();
    let xbar_ports = |x: &Xbar| -> Vec<(LinkId, bool)> {
        // the crossbar consumes requests on m_links (slave side) and
        // produces them into s_links (master side)
        x.m_links
            .iter()
            .map(|&l| (l, false))
            .chain(x.s_links.iter().map(|&l| (l, true)))
            .collect()
    };
    let mut atoms = vec![Atom {
        ports: vec![(src, true)],
        pin: None,
    }];
    if armed {
        atoms.push(Atom {
            ports: topo.xbars.iter().flat_map(|x| xbar_ports(x)).collect(),
            pin: None,
        });
    } else {
        for x in &topo.xbars {
            atoms.push(Atom {
                ports: xbar_ports(x),
                pin: None,
            });
        }
    }
    for &link in endpoint_s {
        atoms.push(Atom {
            ports: vec![(link, false)],
            pin: None,
        });
    }
    let n_shards = threads.min(atoms.len());
    let assign = partition(&atoms, n_shards);
    let homes = link_homes(&atoms, &assign, pool.len());

    // ---- decompose into shards (comps in atom order)
    let mut comps: Vec<TopoComp> = Vec::with_capacity(atoms.len());
    comps.push(TopoComp::Master {
        m: std::mem::replace(master, ScriptMaster::new(Vec::new())),
        src,
    });
    if armed {
        comps.push(TopoComp::Xbars {
            first: 0,
            xbars: std::mem::take(&mut topo.xbars),
        });
    } else {
        for (j, x) in std::mem::take(&mut topo.xbars).into_iter().enumerate() {
            comps.push(TopoComp::Xbars {
                first: j,
                xbars: vec![x],
            });
        }
    }
    for (i, s) in slaves.drain(..).enumerate() {
        comps.push(TopoComp::Slave {
            idx: i,
            s,
            link: endpoint_s[i],
        });
    }
    debug_assert_eq!(comps.len(), atoms.len());
    let shard_pools = split_pool(
        std::mem::replace(pool, LinkPool::new()),
        &homes,
        n_shards,
    );
    let mut shards: Vec<TopoShard> = shard_pools
        .into_iter()
        .map(|p| TopoShard {
            comps: Vec::new(),
            pool: p,
            sched: Scheduler::new_shard(homes.len()),
        })
        .collect();
    for (c, &sh) in comps.into_iter().zip(&assign) {
        shards[sh].comps.push(c);
    }

    // ---- coordinator loop
    let mut master_sched = Scheduler::new(homes.len());
    let step: StepFn<TopoShard> = std::sync::Arc::new(|s: &mut TopoShard, cy: u64| {
        step_topo_shard(s, cy);
    });
    let mut wpool = WorkerPool::new(n_shards, step);
    let mut shards_slot = Some(shards);
    let res = eng.run(|cy| {
        let mut shards = shards_slot.take().expect("shards in flight");
        master_sched.begin_cycle();
        for sh in &mut shards {
            sh.sched.copy_active_from(&master_sched);
        }
        shards = wpool.step_all(shards, cy);
        for sh in &mut shards {
            sh.sched.drain_touched_into(&mut master_sched);
        }
        {
            let mut pools: Vec<&mut LinkPool> =
                shards.iter_mut().map(|s| &mut s.pool).collect();
            master_sched.end_cycle_with(|id| tick_link(&mut pools, &homes, id));
        }
        let done = shards.iter().all(|sh| {
            sh.comps.iter().all(|c| match c {
                TopoComp::Master { m, .. } => m.done(),
                TopoComp::Xbars { xbars, .. } => !xbars.iter().any(|x| x.busy()),
                TopoComp::Slave { s, .. } => s.idle(),
            })
        });
        let progress: u64 = shards.iter().map(|sh| sh.pool.moved_total()).sum();
        shards_slot = Some(shards);
        if done {
            StepResult::Done
        } else {
            StepResult::Running { progress }
        }
    });

    // ---- recompose (also on watchdog error: coherent caller state)
    let shards = shards_slot.take().expect("shards settled");
    let mut xbar_slots: Vec<Option<Xbar>> = (0..n_xb).map(|_| None).collect();
    let mut slave_slots: Vec<Option<SimSlave>> = (0..endpoint_s.len()).map(|_| None).collect();
    let mut shard_pools = Vec::with_capacity(shards.len());
    for sh in shards {
        for c in sh.comps {
            match c {
                TopoComp::Master { m, .. } => *master = m,
                TopoComp::Xbars { first, xbars } => {
                    for (j, x) in xbars.into_iter().enumerate() {
                        xbar_slots[first + j] = Some(x);
                    }
                }
                TopoComp::Slave { idx, s, .. } => slave_slots[idx] = Some(s),
            }
        }
        shard_pools.push(sh.pool);
    }
    topo.xbars = xbar_slots
        .into_iter()
        .map(|x| x.expect("crossbar restored"))
        .collect();
    slaves.extend(slave_slots.into_iter().map(|s| s.expect("slave restored")));
    *pool = merge_pools(shard_pools, &homes);
    res
}

/// One broadcast point (see [`broadcast_script`]).
pub fn run_topo_broadcast(
    shape: &TopoShape,
    n_endpoints: usize,
    bursts: usize,
    beats: u32,
    mcast: bool,
) -> Result<TopoRunResult, SimError> {
    run_topo_broadcast_threads(
        shape,
        n_endpoints,
        bursts,
        beats,
        mcast,
        FabricParams::default().threads,
    )
}

/// [`run_topo_broadcast`] with an explicit thread count (the CLI's
/// `--threads` reaches the sweep through here).
pub fn run_topo_broadcast_threads(
    shape: &TopoShape,
    n_endpoints: usize,
    bursts: usize,
    beats: u32,
    mcast: bool,
    threads: usize,
) -> Result<TopoRunResult, SimError> {
    let script = broadcast_script(n_endpoints, bursts, beats, mcast);
    let params = FabricParams {
        mcast_enabled: mcast,
        threads,
        ..FabricParams::default()
    };
    let (res, _) = run_topo_script_with(shape, n_endpoints, script, params)?;
    // every endpoint must have received every round exactly once
    for (i, d) in res.deliveries.iter().enumerate() {
        assert_eq!(
            d.len(),
            bursts,
            "{}: endpoint {i} got {} bursts, want {bursts}",
            res.shape,
            d.len()
        );
        let want_base = topo_endpoints(n_endpoints).addr(i) + TOPO_DST_OFF;
        for (base, b) in d {
            assert_eq!(*base, want_base, "{}: endpoint {i} base", res.shape);
            assert_eq!(*b, beats, "{}: endpoint {i} beats", res.shape);
        }
    }
    Ok(res)
}

/// The default shape set swept by the experiment/bench for `n`
/// endpoints (power of two, ≥ 16 for the deeper shapes).
pub fn default_shapes(n: usize) -> Vec<TopoShape> {
    let mut shapes = vec![TopoShape::Flat];
    if n >= 16 {
        shapes.push(TopoShape::Tree {
            arity: vec![4, n / 4],
        });
        shapes.push(TopoShape::Tree {
            arity: vec![2, 2, n / 4],
        });
        shapes.push(TopoShape::Mesh { tiles: 4 });
        shapes.push(TopoShape::Ring { nodes: 4 });
        shapes.push(TopoShape::Torus { cols: 2, rows: 2 });
        shapes.push(TopoShape::RingMesh { groups: 2, tiles: 2 });
    } else if n >= 4 {
        shapes.push(TopoShape::Tree {
            arity: vec![2, n / 2],
        });
        shapes.push(TopoShape::Mesh { tiles: 2 });
        shapes.push(TopoShape::Ring { nodes: 2 });
    }
    shapes
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_broadcast_delivers_and_mcast_wins() {
        let uni = run_topo_broadcast(&TopoShape::Flat, 8, 2, 16, false).unwrap();
        let hw = run_topo_broadcast(&TopoShape::Flat, 8, 2, 16, true).unwrap();
        assert_eq!(uni.delivered_bursts(), 16);
        assert_eq!(hw.delivered_bursts(), 16);
        assert!(
            hw.cycles < uni.cycles,
            "hw mcast ({}) must beat unicast ({})",
            hw.cycles,
            uni.cycles
        );
        // one mask-form AW per round, forked to all 8 endpoints
        assert_eq!(hw.stats.aw_mcast, 2);
        assert_eq!(hw.stats.aw_forks, 16);
    }

    #[test]
    fn stats_invariant_holds_across_shapes() {
        for shape in default_shapes(16) {
            for mcast in [false, true] {
                let r = run_topo_broadcast(&shape, 16, 2, 8, mcast).unwrap();
                assert_eq!(
                    r.stats.w_beats_out,
                    r.stats.w_beats_in + r.stats.w_fork_extra,
                    "{}: W fork accounting broken",
                    r.shape
                );
                assert_eq!(r.stats.decerr, 0, "{}: unexpected DECERR", r.shape);
            }
        }
    }

    #[test]
    fn parallel_run_matches_sequential() {
        for shape in [
            TopoShape::Flat,
            TopoShape::Tree { arity: vec![4, 4] },
            TopoShape::Mesh { tiles: 4 },
            TopoShape::Ring { nodes: 4 },
            TopoShape::Torus { cols: 2, rows: 2 },
            TopoShape::RingMesh { groups: 2, tiles: 2 },
        ] {
            for mcast in [false, true] {
                let seq = run_topo_broadcast_threads(&shape, 16, 2, 8, mcast, 1).unwrap();
                for threads in [2usize, 4] {
                    let par =
                        run_topo_broadcast_threads(&shape, 16, 2, 8, mcast, threads).unwrap();
                    assert_eq!(
                        par.cycles, seq.cycles,
                        "{}/mcast={mcast}/threads={threads}: cycles diverge",
                        seq.shape
                    );
                    assert_eq!(
                        par.stats, seq.stats,
                        "{}/mcast={mcast}/threads={threads}: stats diverge",
                        seq.shape
                    );
                    assert_eq!(
                        par.deliveries, seq.deliveries,
                        "{}/mcast={mcast}/threads={threads}: deliveries diverge",
                        seq.shape
                    );
                }
            }
        }
    }

    #[test]
    fn tree_and_mesh_match_flat_deliveries() {
        let flat = run_topo_broadcast(&TopoShape::Flat, 16, 1, 4, true).unwrap();
        for shape in [
            TopoShape::Tree { arity: vec![4, 4] },
            TopoShape::Mesh { tiles: 4 },
            TopoShape::Ring { nodes: 4 },
            TopoShape::Torus { cols: 2, rows: 2 },
            TopoShape::RingMesh { groups: 2, tiles: 2 },
        ] {
            let r = run_topo_broadcast(&shape, 16, 1, 4, true).unwrap();
            assert_eq!(
                r.deliveries, flat.deliveries,
                "{} deliveries diverge from flat",
                r.shape
            );
        }
    }
}

//! Paper §III-B workloads, plus the extension suites.
//!
//! * [`microbench`] — fig. 3b: one cluster sends the same data to all
//!   other clusters (multiple-unicast vs hierarchical software multicast
//!   vs hardware multicast).
//! * [`matmul`] — fig. 3c/3d: the double-buffered 256×256 f64 tiled
//!   matrix multiplication with three B-distribution strategies.
//! * [`roofline`] — the roofline model (peak compute vs LLC-bandwidth
//!   bound) used to place fig. 3c points.
//! * [`topo_sweep`] — the 1-to-N broadcast run across topology shapes
//!   (flat / tree / mesh) built by `axi::topology`.
//! * [`collectives`] — broadcast / all-gather / reduce-scatter /
//!   all-reduce over every wide-network shape, software ring or
//!   binomial baselines vs multicast-accelerated schedules, with
//!   bit-exact reduction validation (the fabric's first converging
//!   N-to-1 traffic).
//! * [`faults`] — robustness suites: fault-injected slaves (stall /
//!   grant-then-hang / dropped completion beats) recovered through the
//!   per-channel timeout engine, and the QoS serving-load scenario that
//!   measures priority-vs-round-robin arbitration under contention.
//! * [`serving`] — the serving-scale transformer traffic generator:
//!   N concurrent requests, each a dependency-released chain of
//!   per-layer all-gather / all-reduce (/ MoE all-to-all) collectives,
//!   measured for throughput and tail latency per [`CollMode`].

pub mod collectives;
pub mod faults;
pub mod matmul;
pub mod microbench;
pub mod roofline;
pub mod serving;
pub mod topo_sweep;

pub use collectives::{
    auto_plan, run_collective, run_collective_chunked, CollMode, CollOp, CollPlan,
    CollectiveResult,
};
pub use faults::{run_fault_scenario, run_qos_load, FaultKind, FaultRunResult, QosResult};
pub use matmul::{MatmulCompute, MatmulMode, MatmulResult};
pub use microbench::{run_microbench, McastMode, MicrobenchResult};
pub use serving::{run_serving, ServingCompute, ServingLayout, ServingParams, ServingResult};
pub use topo_sweep::{
    run_topo_broadcast, run_topo_broadcast_threads, run_topo_script, run_topo_script_with,
    TopoRunResult,
};

//! Fig. 3b microbenchmark: one cluster sends the same data to all other
//! clusters using its DMA engine.
//!
//! Three strategies (paper §III-B):
//!
//! * **multiple-unicast** (baseline): the source issues one unicast DMA
//!   transfer per destination cluster — they serialise on the source
//!   cluster's single wide port;
//! * **hierarchical software multicast** (white overlays, ≥ 8
//!   clusters): the source sends to one "leader" cluster per other
//!   group, each leader forwards to the other clusters of its group —
//!   intra-group distribution proceeds in parallel;
//! * **hardware multicast** (this paper): one mask-form DMA transfer.

use crate::occamy::{Cmd, NopCompute, Soc, SocConfig};
use crate::sim::engine::Watchdog;

/// Distribution strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum McastMode {
    Unicast,
    SwHier,
    Hw,
}

impl McastMode {
    pub fn name(self) -> &'static str {
        match self {
            McastMode::Unicast => "unicast",
            McastMode::SwHier => "sw-hier",
            McastMode::Hw => "hw-mcast",
        }
    }
}

/// One measured point.
#[derive(Debug, Clone)]
pub struct MicrobenchResult {
    pub mode: McastMode,
    pub clusters: usize,
    pub bytes: u64,
    pub cycles: u64,
}

/// Offset in each destination L1 receiving the payload (distinct from
/// the source offset so self-delivery in the 32-cluster set is
/// harmless).
const SRC_OFF: u64 = 0;
const DST_OFF: u64 = 0x10000;

/// The destination set: the *last* `clusters` clusters — an aligned
/// power-of-two block that excludes the source (cluster 0) except for
/// the full-system set, reproducing the paper's "all other clusters".
pub fn dest_range(cfg: &SocConfig, clusters: usize) -> (usize, usize) {
    assert!(clusters.is_power_of_two() && clusters <= cfg.n_clusters);
    if clusters == cfg.n_clusters {
        (0, clusters)
    } else {
        (cfg.n_clusters - clusters, clusters)
    }
}

/// Destination clusters, source excluded.
fn dests(cfg: &SocConfig, clusters: usize) -> Vec<usize> {
    let (first, count) = dest_range(cfg, clusters);
    (first..first + count).filter(|&c| c != 0).collect()
}

/// Build per-cluster programs for one strategy.
fn programs(cfg: &SocConfig, mode: McastMode, clusters: usize, bytes: u64) -> Vec<Vec<Cmd>> {
    let cpg = cfg.clusters_per_group;
    let src_l1 = cfg.cluster_base(0) + SRC_OFF;
    let (first, count) = dest_range(cfg, clusters);
    let mut progs = vec![Vec::new(); cfg.n_clusters];
    match mode {
        McastMode::Unicast => {
            let mut p = Vec::new();
            for c in dests(cfg, clusters) {
                p.push(Cmd::Dma {
                    src: src_l1,
                    dst: crate::axi::mcast::AddrSet::unicast(cfg.cluster_base(c) + DST_OFF),
                    bytes,
                    tag: c as u64,
                });
            }
            p.push(Cmd::WaitDma);
            progs[0] = p;
        }
        McastMode::Hw => {
            // one mask-form transfer covering the whole destination set
            progs[0] = vec![
                Cmd::Dma {
                    src: src_l1,
                    dst: cfg.cluster_set(first, count, DST_OFF),
                    bytes,
                    tag: 1,
                },
                Cmd::WaitDma,
            ];
        }
        McastMode::SwHier => {
            assert!(
                clusters > cpg,
                "hierarchical sw multicast needs more than one group"
            );
            let src_group = 0;
            let groups = (first / cpg)..((first + count) / cpg);
            let mut p = Vec::new();
            for g in groups.clone() {
                if g == src_group {
                    continue;
                }
                let leader = g * cpg;
                p.push(Cmd::Dma {
                    src: src_l1,
                    dst: crate::axi::mcast::AddrSet::unicast(cfg.cluster_base(leader) + DST_OFF),
                    bytes,
                    tag: leader as u64,
                });
                // WaitDma after each hop so the notify IRQ is ordered
                // behind the data (B response = delivery confirmation)
                p.push(Cmd::WaitDma);
                p.push(Cmd::SendIrq {
                    dst: crate::axi::mcast::AddrSet::unicast(cfg.mailbox_addr(leader)),
                });
            }
            // the source's own group (full-system set only): direct
            if groups.contains(&src_group) {
                for c in 1..cpg {
                    p.push(Cmd::Dma {
                        src: src_l1,
                        dst: crate::axi::mcast::AddrSet::unicast(cfg.cluster_base(c) + DST_OFF),
                        bytes,
                        tag: c as u64,
                    });
                }
                p.push(Cmd::WaitDma);
            }
            progs[0] = p;
            // leaders: wait for the notify, then fan out in-group
            for g in groups {
                if g == src_group {
                    continue;
                }
                let leader = g * cpg;
                let mut lp = vec![Cmd::WaitIrq { count: 1 }];
                for i in 1..cpg {
                    lp.push(Cmd::Dma {
                        src: cfg.cluster_base(leader) + DST_OFF,
                        dst: crate::axi::mcast::AddrSet::unicast(
                            cfg.cluster_base(leader + i) + DST_OFF,
                        ),
                        bytes,
                        tag: (leader + i) as u64,
                    });
                }
                lp.push(Cmd::WaitDma);
                progs[leader] = lp;
            }
        }
    }
    progs
}

/// Run one microbenchmark point and return measured cycles.
pub fn run_microbench(
    cfg: &SocConfig,
    mode: McastMode,
    clusters: usize,
    bytes: u64,
) -> MicrobenchResult {
    let mut cfg = cfg.clone();
    // the baseline system has no multicast support at all
    if mode != McastMode::Hw {
        cfg.wide_mcast = false;
    }
    let mut soc = Soc::new(cfg.clone());
    // seed the payload so functional copies are observable
    for (i, b) in (0..bytes).enumerate() {
        let _ = b;
        soc.mem.l1[0][SRC_OFF as usize + i] = (i % 251) as u8;
    }
    soc.load_programs(programs(&cfg, mode, clusters, bytes));
    let cycles = soc
        .run(
            &mut NopCompute,
            Watchdog {
                stall_cycles: 500_000,
                max_cycles: 1_000_000_000,
            },
        )
        .unwrap_or_else(|e| panic!("{mode:?} {clusters}cl {bytes}B: {e}"));
    // verify every destination actually received the payload
    let expect: Vec<u8> = (0..bytes).map(|i| (i % 251) as u8).collect();
    for c in dests(&cfg, clusters) {
        assert_eq!(
            &soc.mem.l1[c][DST_OFF as usize..DST_OFF as usize + bytes as usize],
            &expect[..],
            "cluster {c} did not receive the payload ({mode:?})"
        );
    }
    MicrobenchResult {
        mode,
        clusters,
        bytes,
        cycles,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SocConfig {
        SocConfig::default()
    }

    #[test]
    fn unicast_baseline_delivers() {
        let r = run_microbench(&cfg(), McastMode::Unicast, 4, 2048);
        assert!(r.cycles > 0);
    }

    #[test]
    fn hw_mcast_delivers_and_beats_unicast() {
        let uni = run_microbench(&cfg(), McastMode::Unicast, 8, 8 * 1024);
        let hw = run_microbench(&cfg(), McastMode::Hw, 8, 8 * 1024);
        assert!(
            hw.cycles < uni.cycles,
            "hw mcast ({}) must beat unicast ({})",
            hw.cycles,
            uni.cycles
        );
    }

    #[test]
    fn sw_hier_between_unicast_and_hw() {
        let uni = run_microbench(&cfg(), McastMode::Unicast, 16, 8 * 1024);
        let sw = run_microbench(&cfg(), McastMode::SwHier, 16, 8 * 1024);
        let hw = run_microbench(&cfg(), McastMode::Hw, 16, 8 * 1024);
        assert!(sw.cycles < uni.cycles, "sw {} vs uni {}", sw.cycles, uni.cycles);
        assert!(hw.cycles < sw.cycles, "hw {} vs sw {}", hw.cycles, sw.cycles);
    }

    #[test]
    fn speedup_grows_with_cluster_count() {
        let s = |n| {
            let uni = run_microbench(&cfg(), McastMode::Unicast, n, 4 * 1024);
            let hw = run_microbench(&cfg(), McastMode::Hw, n, 4 * 1024);
            uni.cycles as f64 / hw.cycles as f64
        };
        let s4 = s(4);
        let s16 = s(16);
        assert!(s16 > s4, "speedup must grow with clusters: {s4} -> {s16}");
    }
}

//! Serving-scale transformer traffic on the multicast fabric.
//!
//! Every other workload in this crate runs **one collective at a time
//! from one tenant**. Real serving traffic is nothing like that: a
//! batch of concurrent requests each walks L transformer layers, and
//! every layer issues an all-gather into attention, an all-reduce out
//! of the MLP, and (on MoE models) an all-to-all every k-th layer —
//! with the *next* collective of a request released only when the
//! previous one completed. This module is that traffic generator: the
//! simulator's first heavy-traffic many-user scenario, and the payoff
//! test for the reservation protocol (PR 4) and the auto-tuner (PR 9)
//! at scale.
//!
//! **Request model.** `requests` concurrent decode chains enter the
//! system staggered one global step apart (request `q` enters at step
//! `t = q`), so at steady state up to `min(requests, layers)` requests
//! have collectives in flight *simultaneously*. Per layer each request
//! runs:
//!
//! 1. **all-gather** — every rank re-assembles the request's sharded
//!    activation (`Sw`: n−1 unicasts per rank; `HwConc`/`HwReduce`:
//!    one concurrent global multicast per rank, legal only on the
//!    reservation protocol);
//! 2. **attention** compute ([`OP_SERVE_ATTN`]) producing a
//!    per-rank contribution vector;
//! 3. **all-reduce (converging half)** — every rank issues tagged
//!    [`Cmd::DmaReduce`] bursts, chunk `j` converging on rank `j`'s
//!    per-request `acc` buffer. The *functional* endpoint combine is
//!    mode-independent (bit-identical whether the fabric combines
//!    in-network or not); only `HwReduce` arms `fabric_reduce`, which
//!    combines the converging bursts at the fabric's join points and
//!    saves upstream beats;
//! 4. **MLP** compute ([`OP_SERVE_MLP`]) consuming the reduced chunk
//!    and writing the rank's next-layer activation shard;
//! 5. every `moe_every`-th layer, a **MoE all-to-all** (expert
//!    routing: contribution chunk `j` of every rank to rank `j`) and
//!    its fold ([`OP_SERVE_MOE`]).
//!
//! **Dependency release.** The chain dependency (no layer-k collective
//! before layer-k−1 retired) is enforced by uniform notify rounds:
//! after each traffic slot every rank sends one interrupt to every
//! mailbox and waits for `n` ([`Cmd::WaitIrq`] is a blind counter, so
//! correctness *requires* all ranks to pass the same global sequence
//! of rounds in the same order — see DESIGN.md §12). A rank therefore
//! enters a slot only after every rank finished the previous one, and
//! because its own DMAs drained (`Cmd::WaitDma`) before its notify,
//! all of the previous slot's data is globally visible. The slots of
//! one step carry *all* active requests' transfers at once — the
//! overlapping-tenants traffic the reservation protocol exists for.
//!
//! **Bit-exactness.** All values are small integers stored as f64 and
//! re-compressed through [`squash`] after every combine, so every sum
//! is exact and the final activations are bit-identical to the scalar
//! reference ([`serving_reference`]) regardless of mode, thread count
//! or combine order. Per-request start/retire cycles are captured by
//! the compute handler through the engine-agnostic event-cycle
//! parameter, so latency percentiles are also bit-identical across
//! the sequential and parallel engines.

use crate::axi::mcast::AddrSet;
use crate::axi::reduce::ReduceOp;
use crate::axi::xbar::XbarStats;
use crate::occamy::config::MAILBOX_OFFSET;
use crate::occamy::{Cmd, ComputeHandler, Soc, SocConfig, SocMem};
use crate::sim::engine::Watchdog;

use super::collectives::{auto_plan, CollMode, CollOp};

/// Parameters of one serving-traffic run (the system size and topology
/// come from [`SocConfig`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServingParams {
    /// Concurrent requests in the batch (chains in flight).
    pub requests: usize,
    /// Transformer layers per request (collective chain length).
    pub layers: usize,
    /// Activation bytes per request (sharded into `n` chunks).
    pub bytes: u64,
    /// MoE all-to-all after every k-th layer; `0` = dense model.
    pub moe_every: usize,
    /// MACs per compute phase (attention / MLP / MoE fold delay).
    pub compute_macs: u64,
}

impl Default for ServingParams {
    fn default() -> Self {
        ServingParams {
            requests: 8,
            layers: 4,
            bytes: 4096,
            moe_every: 2,
            compute_macs: 256,
        }
    }
}

/// Per-cluster L1 layout: one region of `region_stride` bytes per
/// request, all offsets relative to the cluster window base.
///
/// ```text
/// gather[q]   [bytes]   activation, n chunks (AG source slot r + target)
/// contrib[q]  [bytes]   attention output (all-reduce + MoE source)
/// moe[q]      [bytes]   MoE receive slots, slot s from sender s
/// acc[q]      [chunk]   all-reduce destination chunk at this rank
/// ```
#[derive(Debug, Clone)]
pub struct ServingLayout {
    pub n: usize,
    pub requests: usize,
    pub bytes: u64,
    pub chunk: u64,
    pub region_stride: u64,
}

impl ServingLayout {
    pub fn new(cfg: &SocConfig, requests: usize, bytes: u64) -> ServingLayout {
        let n = cfg.n_clusters;
        assert!(n >= 2, "serving needs at least 2 clusters");
        assert!(
            n.is_power_of_two(),
            "serving addresses mask-form sets: n_clusters ({n}) must be a power of two"
        );
        assert!(requests >= 1, "serving needs at least 1 request");
        assert!(
            bytes > 0 && bytes % (cfg.wide_bytes as u64 * n as u64) == 0,
            "activation size ({bytes} B) must be a positive multiple of \
             bus width x clusters ({} B)",
            cfg.wide_bytes as u64 * n as u64
        );
        let chunk = bytes / n as u64;
        ServingLayout {
            n,
            requests,
            bytes,
            chunk,
            region_stride: 3 * bytes + chunk,
        }
    }

    pub fn gather(&self, q: usize) -> u64 {
        q as u64 * self.region_stride
    }
    pub fn contrib(&self, q: usize) -> u64 {
        self.gather(q) + self.bytes
    }
    pub fn moe(&self, q: usize) -> u64 {
        self.gather(q) + 2 * self.bytes
    }
    pub fn acc(&self, q: usize) -> u64 {
        self.gather(q) + 3 * self.bytes
    }
    /// Total per-cluster L1 bytes the run touches.
    pub fn footprint(&self) -> u64 {
        self.requests as u64 * self.region_stride
    }
    pub fn elems(&self) -> usize {
        (self.bytes / 8) as usize
    }
    pub fn chunk_elems(&self) -> usize {
        (self.chunk / 8) as usize
    }
}

// Compute-handler op codes (disjoint from the collectives suite's
// OP_RS_COMBINE..OP_AR_FINAL = 10..13).
pub const OP_SERVE_START: u32 = 20;
pub const OP_SERVE_ATTN: u32 = 21;
pub const OP_SERVE_MLP: u32 = 22;
pub const OP_SERVE_MOE: u32 = 23;
pub const OP_SERVE_DONE: u32 = 24;

fn pack(q: usize, layer: usize) -> u64 {
    ((q as u64) << 32) | layer as u64
}

/// Keep every value a small exact integer: all arithmetic maps through
/// `x mod 1021` (a prime, so layer keys don't collapse the value
/// space). Inputs stay well under 2^53, every sum is exact in f64, and
/// the activations cannot grow across layers — the bit-exactness
/// argument of the whole suite.
pub fn squash(x: f64) -> f64 {
    ((x as i64).rem_euclid(1021)) as f64
}

/// Deterministic initial activation shard of `(request, rank)`: small
/// integers in [−512, 511] stored as f64.
pub fn serving_values(q: usize, rank: usize, elems: usize) -> Vec<f64> {
    let mut rng = crate::util::prng::Pcg::new(
        0x5E12_71C5_0DE5 ^ ((q * 1024 + rank) as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
    );
    (0..elems)
        .map(|_| (rng.next_u64() % 1024) as i64 as f64 - 512.0)
        .collect()
}

/// The compute handler: per-phase arithmetic plus per-request timing.
/// `cy` timestamps come from the engine dispatch (identical across the
/// sequential and parallel paths), so `start`/`retire` — and every
/// latency derived from them — are bit-exact across engines.
pub struct ServingCompute {
    pub layout: ServingLayout,
    pub layers: usize,
    /// Earliest START event cycle per request (entry to layer 0).
    pub start: Vec<Option<u64>>,
    /// Latest DONE event cycle per request (last rank finished the
    /// last layer's compute) — the request's retirement.
    pub retire: Vec<Option<u64>>,
    /// `attn_first[q][l]`: earliest attention event of `(q, layer)`
    /// over all ranks — attention consumes the layer's all-gather, so
    /// this is when the layer-l collective's result was first used.
    pub attn_first: Vec<Vec<u64>>,
    /// `mlp_last[q][l]`: latest MLP / MoE-fold event of `(q, layer)`
    /// over all ranks — when the layer fully retired.
    pub mlp_last: Vec<Vec<u64>>,
    pub moe_folds: u64,
}

impl ServingCompute {
    pub fn new(layout: ServingLayout, layers: usize) -> ServingCompute {
        let r = layout.requests;
        ServingCompute {
            layout,
            layers,
            start: vec![None; r],
            retire: vec![None; r],
            attn_first: vec![vec![u64::MAX; layers]; r],
            mlp_last: vec![vec![0; layers]; r],
            moe_folds: 0,
        }
    }
}

impl ComputeHandler for ServingCompute {
    fn exec(&mut self, cluster: usize, op: u32, arg: u64, cy: u64, mem: &mut SocMem) {
        let l = &self.layout;
        let q = (arg >> 32) as usize;
        let layer = (arg & 0xffff_ffff) as usize;
        let base = crate::occamy::config::CLUSTER_BASE
            + cluster as u64 * crate::occamy::config::CLUSTER_STRIDE;
        let (se, ce) = (l.elems(), l.chunk_elems());
        let r = cluster;
        match op {
            OP_SERVE_START => {
                let s = &mut self.start[q];
                *s = Some(s.map_or(cy, |v| v.min(cy)));
            }
            OP_SERVE_ATTN => {
                // toy attention: mix the gathered activation with a
                // rank-rotated copy and a (request, layer, rank) key
                let g = mem.read_f64(base + l.gather(q), se);
                let key = (q + layer + r) as f64;
                let out: Vec<f64> = (0..se)
                    .map(|i| squash(g[i] + g[(i + r + 1) % se] + key))
                    .collect();
                mem.write_f64(base + l.contrib(q), &out);
                let c = &mut self.attn_first[q][layer];
                *c = (*c).min(cy);
            }
            OP_SERVE_MLP => {
                // consume the reduced chunk, write the rank's
                // next-layer activation shard, and re-zero acc so the
                // next layer's DmaReduce accumulates from scratch
                let acc = mem.read_f64(base + l.acc(q), ce);
                let out: Vec<f64> = acc
                    .iter()
                    .map(|&v| squash(v + (layer + 1) as f64))
                    .collect();
                mem.write_f64(base + l.gather(q) + r as u64 * l.chunk, &out);
                mem.write_f64(base + l.acc(q), &vec![0.0; ce]);
                let c = &mut self.mlp_last[q][layer];
                *c = (*c).max(cy);
            }
            OP_SERVE_MOE => {
                // fold the routed expert contributions (one slot per
                // sender) into the rank's activation shard
                let slot = base + l.gather(q) + r as u64 * l.chunk;
                let mut g = mem.read_f64(slot, ce);
                for s in 0..l.n {
                    let piece = mem.read_f64(base + l.moe(q) + s as u64 * l.chunk, ce);
                    for i in 0..ce {
                        g[i] += piece[i];
                    }
                }
                for v in &mut g {
                    *v = squash(*v);
                }
                mem.write_f64(slot, &g);
                self.moe_folds += 1;
                let c = &mut self.mlp_last[q][layer];
                *c = (*c).max(cy);
            }
            OP_SERVE_DONE => {
                let d = &mut self.retire[q];
                *d = Some(d.map_or(cy, |v| v.max(cy)));
            }
            other => panic!("serving: unknown compute op {other}"),
        }
    }
}

/// Scalar reference: replay every request's layer chain functionally on
/// one canonical activation vector. Returns the final activation per
/// request (bit-exact target for every rank's shard).
pub fn serving_reference(n: usize, p: &ServingParams) -> Vec<Vec<f64>> {
    let se = (p.bytes / 8) as usize;
    let ce = se / n;
    let mut out = Vec::with_capacity(p.requests);
    for q in 0..p.requests {
        let mut act: Vec<f64> = (0..n).flat_map(|r| serving_values(q, r, ce)).collect();
        for layer in 0..p.layers {
            let contribs: Vec<Vec<f64>> = (0..n)
                .map(|r| {
                    let key = (q + layer + r) as f64;
                    (0..se)
                        .map(|i| squash(act[i] + act[(i + r + 1) % se] + key))
                        .collect()
                })
                .collect();
            // all-reduce + MLP: chunk j of the summed contributions
            // lands on rank j, which writes its activation shard
            for j in 0..n {
                for i in 0..ce {
                    let red: f64 = contribs.iter().map(|c| c[j * ce + i]).sum();
                    act[j * ce + i] = squash(red + (layer + 1) as f64);
                }
            }
            if p.moe_every > 0 && (layer + 1) % p.moe_every == 0 {
                for j in 0..n {
                    for i in 0..ce {
                        let s: f64 = contribs.iter().map(|c| c[j * ce + i]).sum();
                        act[j * ce + i] = squash(act[j * ce + i] + s);
                    }
                }
            }
        }
        out.push(act);
    }
    out
}

/// Whether a layer index triggers the MoE all-to-all.
fn is_moe_layer(p: &ServingParams, layer: usize) -> bool {
    p.moe_every > 0 && (layer + 1) % p.moe_every == 0
}

/// Emit the per-rank command programs: the staggered request pipeline
/// over `requests + layers - 1` global steps, each step's slots
/// carrying *every* active request's traffic before one uniform
/// notify round (see the module docs for why the rounds must be
/// uniform and identically ordered at every rank).
fn programs(
    cfg: &SocConfig,
    l: &ServingLayout,
    p: &ServingParams,
    mode: CollMode,
) -> Vec<Vec<Cmd>> {
    let n = l.n;
    // Concurrent global multicasts only pay off with fan-out to
    // amortise the reservation handshake; at n = 2 the multicast
    // degenerates to one destination, so the hw modes fall back to the
    // unicast exchange (the flags stay armed but unused — the program
    // and therefore the cycle count match the sw baseline exactly).
    let use_mcast = matches!(mode, CollMode::HwConc | CollMode::HwReduce) && n >= 4;
    let steps = p.requests + p.layers - 1;
    let mut progs: Vec<Vec<Cmd>> = vec![Vec::new(); n];
    for (r, prog) in progs.iter_mut().enumerate() {
        let round = |prog: &mut Vec<Cmd>| {
            prog.push(Cmd::WaitDma);
            if use_mcast {
                prog.push(Cmd::SendIrq {
                    dst: cfg.all_mailboxes(),
                });
            } else {
                for d in 0..n {
                    prog.push(Cmd::SendIrq {
                        dst: AddrSet::unicast(cfg.mailbox_addr(d)),
                    });
                }
            }
            prog.push(Cmd::WaitIrq { count: n as u32 });
        };
        for t in 0..steps {
            let active: Vec<usize> = (0..p.requests)
                .filter(|&q| t >= q && t - q < p.layers)
                .collect();
            for &q in &active {
                if t == q {
                    prog.push(Cmd::Compute {
                        macs: 1,
                        op: OP_SERVE_START,
                        arg: pack(q, 0),
                    });
                }
            }
            // ---- all-gather slot: re-assemble every active
            // activation (concurrent global collectives, one per
            // request, all in flight together)
            for &q in &active {
                let slot = l.gather(q) + r as u64 * l.chunk;
                if use_mcast {
                    prog.push(Cmd::Dma {
                        src: cfg.cluster_base(r) + slot,
                        dst: cfg.cluster_set(0, n, slot),
                        bytes: l.chunk,
                        tag: 0x100_0000 + (q * n + r) as u64,
                    });
                } else {
                    for d in 0..n {
                        if d == r {
                            continue;
                        }
                        prog.push(Cmd::Dma {
                            src: cfg.cluster_base(r) + slot,
                            dst: AddrSet::unicast(cfg.cluster_base(d) + slot),
                            bytes: l.chunk,
                            tag: 0x100_0000 + (q * n + d) as u64,
                        });
                    }
                }
            }
            round(prog);
            for &q in &active {
                prog.push(Cmd::Compute {
                    macs: p.compute_macs,
                    op: OP_SERVE_ATTN,
                    arg: pack(q, t - q),
                });
            }
            // ---- all-reduce slot: tagged reduction bursts, chunk j
            // converging on rank j's acc (self included — the local
            // member combines at its own endpoint)
            for &q in &active {
                for j in 0..n {
                    prog.push(Cmd::DmaReduce {
                        src: cfg.cluster_base(r) + l.contrib(q) + j as u64 * l.chunk,
                        dst: cfg.cluster_base(j) + l.acc(q),
                        bytes: l.chunk,
                        tag: 0x200_0000 + (q * n + j) as u64,
                        group: (q * n + j) as u32,
                        op: ReduceOp::Sum,
                    });
                }
            }
            round(prog);
            for &q in &active {
                prog.push(Cmd::Compute {
                    macs: p.compute_macs,
                    op: OP_SERVE_MLP,
                    arg: pack(q, t - q),
                });
            }
            // ---- MoE all-to-all slot (expert routing), only on steps
            // where at least one active request hit a MoE layer — the
            // condition depends only on (t, q, moe_every), so every
            // rank sees the same round sequence
            let moe_active: Vec<usize> = active
                .iter()
                .copied()
                .filter(|&q| is_moe_layer(p, t - q))
                .collect();
            if !moe_active.is_empty() {
                for &q in &moe_active {
                    for j in 0..n {
                        prog.push(Cmd::Dma {
                            src: cfg.cluster_base(r) + l.contrib(q) + j as u64 * l.chunk,
                            dst: AddrSet::unicast(
                                cfg.cluster_base(j) + l.moe(q) + r as u64 * l.chunk,
                            ),
                            bytes: l.chunk,
                            tag: 0x300_0000 + (q * n + j) as u64,
                        });
                    }
                }
                round(prog);
                for &q in &moe_active {
                    prog.push(Cmd::Compute {
                        macs: p.compute_macs,
                        op: OP_SERVE_MOE,
                        arg: pack(q, t - q),
                    });
                }
            }
            for &q in &active {
                if t - q == p.layers - 1 {
                    prog.push(Cmd::Compute {
                        macs: 1,
                        op: OP_SERVE_DONE,
                        arg: pack(q, t - q),
                    });
                }
            }
        }
    }
    progs
}

/// One measured serving run.
#[derive(Debug, Clone, PartialEq)]
pub struct ServingResult {
    pub mode: CollMode,
    pub shape: String,
    pub clusters: usize,
    pub requests: usize,
    pub layers: usize,
    pub bytes: u64,
    pub moe_every: usize,
    /// Total run cycles (all requests retired and fabric drained).
    pub cycles: u64,
    /// Per-request latency, start → retire, indexed by request.
    pub latencies: Vec<u64>,
    /// Per-request absolute retirement cycle, indexed by request.
    pub retired_at: Vec<u64>,
    pub lat_p50: u64,
    pub lat_p95: u64,
    pub lat_max: u64,
    /// Requests retired per million cycles.
    pub throughput_rpmc: f64,
    pub wide: XbarStats,
    pub dma_w_beats: u64,
    pub moe_folds: u64,
    pub numerics_ok: bool,
    /// Earliest attention event per `(request, layer)` — consumes the
    /// layer's all-gather (tests assert the chain dependency on it).
    pub attn_first: Vec<Vec<u64>>,
    /// Latest MLP / MoE event per `(request, layer)`.
    pub mlp_last: Vec<Vec<u64>>,
    /// The concrete mode `CollMode::Auto` resolved to (`None` for
    /// concrete-mode runs).
    pub auto_resolved: Option<String>,
}

/// Nearest-rank percentile on a sorted slice (monotone in `p`, so
/// `p95 >= p50` by construction).
fn percentile(sorted: &[u64], p: f64) -> u64 {
    let idx = ((p * sorted.len() as f64).ceil() as usize).max(1) - 1;
    sorted[idx.min(sorted.len() - 1)]
}

/// Resolve `CollMode::Auto` for the serving traffic mix: score the
/// all-reduce's converging half and the all-gather on the analytic
/// cost model at the per-request activation size. In-network reduction
/// wins if the model picks it for the converging pattern; otherwise
/// any multicast pick maps to the concurrent schedule (serving always
/// has many tenants in flight — the single-mcast `Hw` schedules don't
/// apply).
pub fn resolve_serving_auto(cfg: &SocConfig, bytes: u64) -> CollMode {
    let rs = auto_plan(cfg, CollOp::ReduceScatter, bytes);
    if rs.mode == CollMode::HwReduce {
        return CollMode::HwReduce;
    }
    match auto_plan(cfg, CollOp::AllGather, bytes).mode {
        CollMode::Sw => CollMode::Sw,
        _ => CollMode::HwConc,
    }
}

/// Seed the activations, run the serving pipeline in one mode on the
/// configured system, and validate every rank's final activation shard
/// bit-exactly against the scalar reference.
pub fn run_serving(cfg: &SocConfig, p: &ServingParams, mode: CollMode) -> ServingResult {
    if mode == CollMode::Auto {
        let resolved = resolve_serving_auto(cfg, p.bytes);
        let mut r = run_serving(cfg, p, resolved);
        r.mode = CollMode::Auto;
        r.auto_resolved = Some(resolved.name().to_string());
        return r;
    }
    assert!(
        matches!(mode, CollMode::Sw | CollMode::HwConc | CollMode::HwReduce),
        "serving sweeps sw / hw-concurrent / hw-reduce / auto (got {})",
        mode.name()
    );
    assert!(p.layers >= 1, "serving needs at least 1 layer");
    let mut cfg = cfg.clone();
    match mode {
        CollMode::Sw => {
            cfg.wide_mcast = false;
            cfg.narrow_mcast = false;
        }
        CollMode::HwConc => {
            cfg.wide_mcast = true;
            cfg.narrow_mcast = true;
            cfg.e2e_mcast_order = true;
        }
        CollMode::HwReduce => {
            cfg.wide_mcast = true;
            cfg.narrow_mcast = true;
            cfg.e2e_mcast_order = true;
            cfg.fabric_reduce = true;
        }
        _ => unreachable!(),
    }
    let l = ServingLayout::new(&cfg, p.requests, p.bytes);
    let fp = l.footprint();
    assert!(
        fp <= cfg.l1_bytes && fp <= MAILBOX_OFFSET,
        "serving: L1 footprint {fp} B ({} requests x {} B regions) exceeds SPM {} \
         (fewer requests or a smaller --size)",
        p.requests,
        l.region_stride,
        cfg.l1_bytes
    );
    let n = l.n;
    let ce = l.chunk_elems();
    let mut soc = Soc::new(cfg.clone());

    // in-fabric reduction groups: one per (request, chunk owner),
    // opened once and reused every layer — each layer's converging
    // round opens a fresh combine entry per join node, and the held-B
    // completion plus the WaitDma in the round drains it before the
    // next layer reuses the group id
    if mode == CollMode::HwReduce {
        let members: Vec<usize> = (0..n).collect();
        for q in 0..p.requests {
            for j in 0..n {
                soc.open_reduce_group(
                    (q * n + j) as u32,
                    ReduceOp::Sum,
                    &members,
                    cfg.cluster_base(j) + l.acc(q),
                );
            }
        }
    }

    for q in 0..p.requests {
        for r in 0..n {
            soc.mem.write_f64(
                cfg.cluster_base(r) + l.gather(q) + r as u64 * l.chunk,
                &serving_values(q, r, ce),
            );
        }
    }

    soc.load_programs(programs(&cfg, &l, p, mode));
    let mut handler = ServingCompute::new(l.clone(), p.layers);
    let cycles = soc
        .run(
            &mut handler,
            Watchdog {
                stall_cycles: 500_000,
                max_cycles: 500_000_000,
            },
        )
        .unwrap_or_else(|e| {
            panic!(
                "serving {} on {} ({n} clusters, {} requests x {} layers, {} B): {e}",
                mode.name(),
                cfg.wide_shape.label(),
                p.requests,
                p.layers,
                p.bytes
            )
        });

    // ---- bit-exact validation against the scalar reference ----
    let reference = serving_reference(n, p);
    let mut mismatches = 0u64;
    let mut first_bad: Option<(usize, usize, usize, f64, f64)> = None;
    for q in 0..p.requests {
        for r in 0..n {
            let base = cfg.cluster_base(r);
            let got = soc.mem.read_f64(base + l.gather(q) + r as u64 * l.chunk, ce);
            let want = &reference[q][r * ce..(r + 1) * ce];
            for (i, (g, w)) in got.iter().zip(want).enumerate() {
                if g.to_bits() != w.to_bits() {
                    mismatches += 1;
                    if first_bad.is_none() {
                        first_bad = Some((q, r, i, *g, *w));
                    }
                }
            }
            // every layer's MLP re-zeroed acc after consuming it
            for (i, v) in soc.mem.read_f64(base + l.acc(q), ce).iter().enumerate() {
                if v.to_bits() != 0 {
                    mismatches += 1;
                    if first_bad.is_none() {
                        first_bad = Some((q, r, i, *v, 0.0));
                    }
                }
            }
        }
    }
    let numerics_ok = mismatches == 0;
    if let Some((q, r, i, got, want)) = first_bad {
        eprintln!(
            "serving {}: {mismatches} mismatches; first at request {q} rank {r} elem {i}: \
             got {got} want {want}",
            mode.name()
        );
    }

    let latencies: Vec<u64> = (0..p.requests)
        .map(|q| {
            let s = handler.start[q].unwrap_or_else(|| panic!("request {q} never started"));
            let d = handler.retire[q].unwrap_or_else(|| panic!("request {q} never retired"));
            assert!(d > s, "request {q}: retired at {d} before start {s}");
            d - s
        })
        .collect();
    let retired_at: Vec<u64> = (0..p.requests)
        .map(|q| handler.retire[q].unwrap())
        .collect();
    let mut sorted = latencies.clone();
    sorted.sort_unstable();
    let dma_w_beats: u64 = soc.clusters.iter().map(|c| c.dma.stats.write_beats).sum();
    ServingResult {
        mode,
        shape: cfg.wide_shape.label(),
        clusters: n,
        requests: p.requests,
        layers: p.layers,
        bytes: p.bytes,
        moe_every: p.moe_every,
        cycles,
        lat_p50: percentile(&sorted, 0.50),
        lat_p95: percentile(&sorted, 0.95),
        lat_max: *sorted.last().unwrap(),
        throughput_rpmc: p.requests as f64 * 1.0e6 / cycles as f64,
        latencies,
        retired_at,
        wide: soc.wide.stats_sum(),
        dma_w_beats,
        moe_folds: handler.moe_folds,
        numerics_ok,
        attn_first: handler.attn_first,
        mlp_last: handler.mlp_last,
        auto_resolved: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(n: usize) -> SocConfig {
        SocConfig::tiny(n)
    }

    fn small_params(n: usize) -> ServingParams {
        ServingParams {
            requests: 3,
            layers: 3,
            bytes: 64 * n as u64,
            moe_every: 2,
            compute_macs: 64,
        }
    }

    #[test]
    fn layout_regions_are_disjoint_and_fit() {
        let cfg = tiny(4);
        let l = ServingLayout::new(&cfg, 4, 1024);
        assert_eq!(l.chunk, 256);
        assert!(l.contrib(0) > l.gather(0));
        assert!(l.moe(0) > l.contrib(0));
        assert!(l.acc(0) > l.moe(0));
        assert_eq!(l.gather(1), l.region_stride);
        assert!(l.footprint() <= cfg.l1_bytes);
    }

    #[test]
    fn reference_is_mode_independent_input() {
        // the reference only depends on (n, params): same call twice
        // is bit-identical
        let p = small_params(4);
        let a = serving_reference(4, &p);
        let b = serving_reference(4, &p);
        assert_eq!(a, b);
        assert_eq!(a.len(), p.requests);
    }

    #[test]
    fn sw_run_is_bit_exact_and_tails_ordered() {
        let cfg = tiny(4);
        let r = run_serving(&cfg, &small_params(4), CollMode::Sw);
        assert!(r.numerics_ok);
        assert!(r.lat_p95 >= r.lat_p50);
        assert!(r.lat_max >= r.lat_p95);
        assert_eq!(r.latencies.len(), 3);
        assert!(r.moe_folds > 0, "moe_every=2 with 3 layers must fold");
    }

    #[test]
    fn hw_modes_match_reference_and_inject_less() {
        let cfg = tiny(4);
        let p = small_params(4);
        let sw = run_serving(&cfg, &p, CollMode::Sw);
        let conc = run_serving(&cfg, &p, CollMode::HwConc);
        let red = run_serving(&cfg, &p, CollMode::HwReduce);
        for r in [&sw, &conc, &red] {
            assert!(r.numerics_ok, "{} diverges", r.mode.name());
        }
        assert!(conc.dma_w_beats <= sw.dma_w_beats);
        assert!(red.dma_w_beats <= conc.dma_w_beats);
        assert!(red.wide.red_beats_saved > 0, "fabric combining never fired");
    }

    #[test]
    fn auto_resolves_and_records_the_pick() {
        let cfg = tiny(4);
        let r = run_serving(&cfg, &small_params(4), CollMode::Auto);
        assert_eq!(r.mode, CollMode::Auto);
        assert!(r.numerics_ok);
        let pick = r.auto_resolved.as_deref().unwrap();
        assert!(["sw", "hw-concurrent", "hw-reduce"].contains(&pick), "{pick}");
    }

    #[test]
    fn dependency_chain_is_honored() {
        let cfg = tiny(4);
        let r = run_serving(&cfg, &small_params(4), CollMode::HwConc);
        for q in 0..r.requests {
            for layer in 1..r.layers {
                assert!(
                    r.attn_first[q][layer] > r.mlp_last[q][layer - 1],
                    "request {q}: layer {layer} attention at {} before layer {} \
                     retired at {}",
                    r.attn_first[q][layer],
                    layer - 1,
                    r.mlp_last[q][layer - 1]
                );
            }
        }
    }

    #[test]
    fn degenerate_single_request_single_layer() {
        let cfg = tiny(4);
        let p = ServingParams {
            requests: 1,
            layers: 1,
            bytes: 256,
            moe_every: 0,
            compute_macs: 8,
        };
        for mode in [CollMode::Sw, CollMode::HwConc, CollMode::HwReduce] {
            let r = run_serving(&cfg, &p, mode);
            assert!(r.numerics_ok, "{}", mode.name());
            assert_eq!(r.latencies.len(), 1);
            assert_eq!(r.lat_p50, r.lat_max);
            assert_eq!(r.moe_folds, 0);
        }
    }

    #[test]
    fn degenerate_two_clusters() {
        // n = 2: the hw modes fall back to the unicast exchange (no
        // fan-out to amortise) but must stay bit-exact
        let cfg = tiny(2);
        let p = ServingParams {
            requests: 2,
            layers: 2,
            bytes: 128,
            moe_every: 1,
            compute_macs: 8,
        };
        for mode in [CollMode::Sw, CollMode::HwConc, CollMode::HwReduce] {
            let r = run_serving(&cfg, &p, mode);
            assert!(r.numerics_ok, "{}", mode.name());
            assert!(r.lat_p95 >= r.lat_p50);
        }
    }

    #[test]
    fn threads_and_force_naive_are_bit_identical() {
        let p = small_params(4);
        let base = run_serving(&tiny(4), &p, CollMode::HwReduce);
        for threads in [2usize, 4] {
            let mut cfg = tiny(4);
            cfg.threads = threads;
            assert_eq!(run_serving(&cfg, &p, CollMode::HwReduce), base, "threads {threads}");
        }
        let mut cfg = tiny(4);
        cfg.force_naive = true;
        assert_eq!(run_serving(&cfg, &p, CollMode::HwReduce), base, "force_naive");
    }
}

//! Fig. 3c/3d: double-buffered tiled matrix multiplication on Occamy.
//!
//! The largest square f64 tile fitting the 4 MiB LLC with double
//! buffering: C(256×256) = A(256×256) × B(256×256). Every cluster owns
//! an 8-row block of C and computes one 8×16 C-tile per steady-state
//! iteration (fig. 3d): the 8×256 A panel is loaded into L1 once; the
//! 256×16 B tile of each iteration is streamed in by the DMA in a
//! double-buffered fashion while the FPUs compute the previous tile.
//!
//! Three B-distribution strategies reproduce the three fig. 3c points:
//!
//! * [`MatmulMode::Baseline`] — every cluster reads every B tile from
//!   the LLC (32× read amplification ⇒ OI ≈ 1.9 FLOP/B, memory-bound);
//! * [`MatmulMode::SwMcast`] — one leader per group reads the tile and
//!   forwards it to its 3 group members (8× amplification ⇒ OI ×~3.7);
//! * [`MatmulMode::HwMcast`] — cluster 0 reads the tile once and issues
//!   a single mask-form multicast write to all clusters' L1 buffers
//!   (⇒ OI ×~16.5); the multicast B-join doubles as the delivery
//!   confirmation for the following interrupt.
//!
//! B is stored *tile-major* in the LLC (each 256×16 tile contiguous) so
//! transfers are long contiguous bursts — the layout-level equivalent of
//! the 2D DMA the silicon uses (see DESIGN.md §2).

use crate::axi::mcast::AddrSet;
use crate::occamy::{Cmd, ComputeHandler, Soc, SocConfig, SocMem};
use crate::occamy::config::LLC_BASE;
use crate::sim::engine::Watchdog;

/// B-distribution strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MatmulMode {
    Baseline,
    SwMcast,
    HwMcast,
}

impl MatmulMode {
    pub fn name(self) -> &'static str {
        match self {
            MatmulMode::Baseline => "baseline",
            MatmulMode::SwMcast => "sw-mcast",
            MatmulMode::HwMcast => "hw-mcast",
        }
    }
}

/// Geometry + memory layout of the kernel.
#[derive(Debug, Clone)]
pub struct MatmulLayout {
    pub n: usize,
    pub rows_per_cluster: usize,
    pub tile_cols: usize,
    // LLC byte offsets
    pub a_off: u64,
    pub b_off: u64,
    pub c_off: u64,
    // L1 byte offsets
    pub l1_a: u64,
    pub l1_b: [u64; 2],
    pub l1_c: u64,
}

impl MatmulLayout {
    pub fn paper(cfg: &SocConfig) -> MatmulLayout {
        let n = 256;
        let rows = n / cfg.n_clusters; // 8 for 32 clusters
        MatmulLayout::new(n, rows, 16)
    }

    pub fn new(n: usize, rows_per_cluster: usize, tile_cols: usize) -> MatmulLayout {
        let mat_bytes = (n * n * 8) as u64;
        let a_panel = (rows_per_cluster * n * 8) as u64;
        let tile = (n * tile_cols * 8) as u64;
        let l = MatmulLayout {
            n,
            rows_per_cluster,
            tile_cols,
            a_off: 0,
            b_off: mat_bytes,
            c_off: 2 * mat_bytes,
            l1_a: 0,
            l1_b: [a_panel, a_panel + tile],
            l1_c: a_panel + 2 * tile,
        };
        l
    }

    pub fn n_tiles(&self) -> usize {
        self.n / self.tile_cols
    }

    pub fn tile_bytes(&self) -> u64 {
        (self.n * self.tile_cols * 8) as u64
    }

    pub fn a_panel_bytes(&self) -> u64 {
        (self.rows_per_cluster * self.n * 8) as u64
    }

    pub fn c_block_bytes(&self) -> u64 {
        self.a_panel_bytes()
    }

    /// Total L1 footprint per cluster (must fit the SPM).
    pub fn l1_footprint(&self) -> u64 {
        self.l1_c + self.c_block_bytes()
    }

    /// MACs per steady-state iteration (8×16 tile over K=n).
    pub fn tile_macs(&self) -> u64 {
        (self.rows_per_cluster * self.tile_cols * self.n) as u64
    }

    pub fn total_flops(&self) -> u64 {
        2 * (self.n as u64).pow(3)
    }
}

/// Numeric tile executor: the end-to-end example plugs the PJRT-loaded
/// JAX/Pallas artifact in here; tests use the naive Rust fallback.
pub trait TileExec {
    /// C(m×n) += A(m×k) × B(k×n); row-major f64 slices.
    fn tile(&mut self, a: &[f64], b: &[f64], c: &mut [f64], m: usize, n: usize, k: usize);
}

/// Naive triple-loop reference executor.
pub struct RustTileExec;

impl TileExec for RustTileExec {
    fn tile(&mut self, a: &[f64], b: &[f64], c: &mut [f64], m: usize, n: usize, k: usize) {
        for i in 0..m {
            for kk in 0..k {
                let av = a[i * k + kk];
                let brow = &b[kk * n..kk * n + n];
                let crow = &mut c[i * n..i * n + n];
                for j in 0..n {
                    crow[j] += av * brow[j];
                }
            }
        }
    }
}

/// The functional compute handler: op 1 = "compute C tile `arg` from
/// the L1-resident A panel and B buffer".
pub struct MatmulCompute<'a> {
    pub layout: MatmulLayout,
    pub exec: &'a mut dyn TileExec,
    pub tiles_computed: u64,
}

impl<'a> MatmulCompute<'a> {
    pub fn new(layout: MatmulLayout, exec: &'a mut dyn TileExec) -> Self {
        MatmulCompute {
            layout,
            exec,
            tiles_computed: 0,
        }
    }
}

impl ComputeHandler for MatmulCompute<'_> {
    fn exec(&mut self, cluster: usize, op: u32, arg: u64, _cy: u64, mem: &mut SocMem) {
        assert_eq!(op, 1, "unknown compute op {op}");
        let l = &self.layout;
        let k_tile = arg as usize;
        let (m, n, k) = (l.rows_per_cluster, l.tile_cols, l.n);
        let base = crate::occamy::config::CLUSTER_BASE
            + cluster as u64 * crate::occamy::config::CLUSTER_STRIDE;
        let a = mem.read_f64(base + l.l1_a, m * k);
        let b = mem.read_f64(base + l.l1_b[k_tile % 2], k * n);
        let mut c = vec![0.0; m * n];
        self.exec.tile(&a, &b, &mut c, m, n, k);
        // scatter the 8×16 tile into the row-major 8×256 C block
        for row in 0..m {
            let addr = base + l.l1_c + ((row * l.n + k_tile * n) * 8) as u64;
            mem.write_f64(addr, &c[row * n..row * n + n]);
        }
        self.tiles_computed += 1;
    }
}

/// Per-cluster programs for one mode.
pub fn programs(cfg: &SocConfig, l: &MatmulLayout, mode: MatmulMode) -> Vec<Vec<Cmd>> {
    let nc = cfg.n_clusters;
    let cpg = cfg.clusters_per_group;
    let tiles = l.n_tiles();
    let tile_b = l.tile_bytes();
    let llc_a = |c: usize| LLC_BASE + l.a_off + c as u64 * l.a_panel_bytes();
    let llc_b = |k: usize| LLC_BASE + l.b_off + k as u64 * tile_b;
    let llc_c = |c: usize| LLC_BASE + l.c_off + c as u64 * l.c_block_bytes();
    let l1 = |c: usize, off: u64| cfg.cluster_base(c) + off;
    let mut progs: Vec<Vec<Cmd>> = vec![Vec::new(); nc];

    for c in 0..nc {
        let p = &mut progs[c];
        // ---- prologue: A panel (all modes) ----
        p.push(Cmd::Dma {
            src: llc_a(c),
            dst: AddrSet::unicast(l1(c, l.l1_a)),
            bytes: l.a_panel_bytes(),
            tag: 1000,
        });
        match mode {
            MatmulMode::Baseline => {
                p.push(Cmd::Dma {
                    src: llc_b(0),
                    dst: AddrSet::unicast(l1(c, l.l1_b[0])),
                    bytes: tile_b,
                    tag: 0,
                });
                p.push(Cmd::WaitDma);
                for k in 0..tiles {
                    if k + 1 < tiles {
                        p.push(Cmd::Dma {
                            src: llc_b(k + 1),
                            dst: AddrSet::unicast(l1(c, l.l1_b[(k + 1) % 2])),
                            bytes: tile_b,
                            tag: (k + 1) as u64,
                        });
                    }
                    p.push(Cmd::Compute {
                        macs: l.tile_macs(),
                        op: 1,
                        arg: k as u64,
                    });
                    p.push(Cmd::WaitDma);
                }
            }
            MatmulMode::SwMcast => {
                let leader = c % cpg == 0;
                let group_first = (c / cpg) * cpg;
                if leader {
                    // Leader: read the tile from the LLC, then forward
                    // it to the 3 group members. The software multicast
                    // runtime is *blocking*: the forwarding jobs are
                    // programmed only after the LLC read completed
                    // (software polls the transfer), and the notify
                    // IRQs only after the forwards completed — the
                    // serialization the paper's hardware multicast
                    // removes. The LLC *read* of the next tile is
                    // overlapped with compute (double buffering).
                    let read = |p: &mut Vec<Cmd>, k: usize| {
                        p.push(Cmd::Dma {
                            src: llc_b(k),
                            dst: AddrSet::unicast(l1(c, l.l1_b[k % 2])),
                            bytes: tile_b,
                            tag: (10 * k) as u64,
                        });
                    };
                    let fwd = |p: &mut Vec<Cmd>, k: usize| {
                        for i in 1..cpg {
                            p.push(Cmd::Dma {
                                src: l1(c, l.l1_b[k % 2]),
                                dst: AddrSet::unicast(l1(group_first + i, l.l1_b[k % 2])),
                                bytes: tile_b,
                                tag: (10 * k + i) as u64,
                            });
                        }
                    };
                    let notify = |p: &mut Vec<Cmd>| {
                        for i in 1..cpg {
                            p.push(Cmd::SendIrq {
                                dst: AddrSet::unicast(cfg.mailbox_addr(group_first + i)),
                            });
                        }
                    };
                    read(p, 0);
                    p.push(Cmd::WaitDma);
                    fwd(p, 0);
                    p.push(Cmd::WaitDma);
                    notify(p);
                    for k in 0..tiles {
                        if k + 1 < tiles {
                            if k >= 1 {
                                // buffer (k+1)%2 re-fill needs all group
                                // members done with tile k-1
                                p.push(Cmd::WaitIrq {
                                    count: (cpg - 1) as u32,
                                });
                            }
                            read(p, k + 1);
                        }
                        p.push(Cmd::Compute {
                            macs: l.tile_macs(),
                            op: 1,
                            arg: k as u64,
                        });
                        p.push(Cmd::WaitDma); // read k+1 arrived
                        if k + 1 < tiles {
                            fwd(p, k + 1);
                            p.push(Cmd::WaitDma); // forwards delivered
                            notify(p);
                        }
                    }
                    // tail ACKs from the last two tiles
                    p.push(Cmd::WaitIrq {
                        count: 2 * (cpg - 1) as u32,
                    });
                } else {
                    p.push(Cmd::WaitDma); // A panel
                    p.push(Cmd::WaitIrq { count: 1 }); // tile 0 arrived
                    for k in 0..tiles {
                        p.push(Cmd::Compute {
                            macs: l.tile_macs(),
                            op: 1,
                            arg: k as u64,
                        });
                        // release tile k's buffer to the group leader
                        p.push(Cmd::SendIrq {
                            dst: AddrSet::unicast(cfg.mailbox_addr(group_first)),
                        });
                        if k + 1 < tiles {
                            p.push(Cmd::WaitIrq { count: 1 });
                        }
                    }
                }
            }
            MatmulMode::HwMcast => {
                let all = nc.next_power_of_two();
                if c == 0 {
                    // Distributor: one multicast copy LLC → all L1s per
                    // tile. Double-buffering correctness requires the
                    // distributor to re-fill a buffer only after every
                    // consumer released it, so consumers ACK each
                    // computed tile with a narrow write to cluster 0's
                    // mailbox. Cluster 0's mailbox also receives its own
                    // broadcast notifies (the mask covers all clusters),
                    // so each steady-state wait consumes 31 ACKs + 1
                    // self-notify = 32 (see the cumulative-counting
                    // argument in the module tests).
                    let bcast = |p: &mut Vec<Cmd>, k: usize| {
                        p.push(Cmd::Dma {
                            src: llc_b(k),
                            dst: cfg.cluster_set(0, all, l.l1_b[k % 2]),
                            bytes: tile_b,
                            tag: k as u64,
                        });
                    };
                    let notify = |p: &mut Vec<Cmd>| {
                        p.push(Cmd::SendIrq {
                            dst: cfg.all_mailboxes(),
                        });
                    };
                    bcast(p, 0);
                    p.push(Cmd::WaitDma);
                    notify(p);
                    for k in 0..tiles {
                        if k + 1 < tiles {
                            if k >= 1 {
                                // buffer (k+1)%2 must be free: all
                                // consumers done with tile k-1
                                p.push(Cmd::WaitIrq {
                                    count: nc as u32,
                                });
                            }
                            bcast(p, k + 1);
                        }
                        p.push(Cmd::Compute {
                            macs: l.tile_macs(),
                            op: 1,
                            arg: k as u64,
                        });
                        // B-join of the multicast = delivery confirmation
                        p.push(Cmd::WaitDma);
                        if k + 1 < tiles {
                            notify(p);
                        }
                    }
                    // drain the remaining self-notifies + tail ACKs
                    let consumed = (tiles as u32 - 2) * nc as u32;
                    let total = tiles as u32 * nc as u32;
                    p.push(Cmd::WaitIrq {
                        count: total - consumed,
                    });
                } else {
                    p.push(Cmd::WaitDma); // A panel
                    p.push(Cmd::WaitIrq { count: 1 });
                    for k in 0..tiles {
                        p.push(Cmd::Compute {
                            macs: l.tile_macs(),
                            op: 1,
                            arg: k as u64,
                        });
                        // release the buffer of tile k to the distributor
                        p.push(Cmd::SendIrq {
                            dst: AddrSet::unicast(cfg.mailbox_addr(0)),
                        });
                        if k + 1 < tiles {
                            p.push(Cmd::WaitIrq { count: 1 });
                        }
                    }
                }
            }
        }
        // ---- epilogue: write the C row block back ----
        p.push(Cmd::Dma {
            src: l1(c, l.l1_c),
            dst: AddrSet::unicast(llc_c(c)),
            bytes: l.c_block_bytes(),
            tag: 2000,
        });
        p.push(Cmd::WaitDma);
    }
    progs
}

/// Measured result of one matmul run.
#[derive(Debug, Clone)]
pub struct MatmulResult {
    pub mode: MatmulMode,
    pub cycles: u64,
    pub flops: u64,
    /// FLOP per cycle == GFLOPS at 1 GHz.
    pub gflops: f64,
    pub llc_read_bytes: u64,
    pub llc_write_bytes: u64,
    /// Operational intensity on LLC *reads* (the paper's OI basis).
    pub oi_read: f64,
    pub pct_of_peak: f64,
    pub numerics_ok: bool,
}

/// Seed LLC with deterministic A and B (B tile-major), run, validate C.
pub fn run_matmul(cfg: &SocConfig, mode: MatmulMode, exec: &mut dyn TileExec) -> MatmulResult {
    let mut cfg = cfg.clone();
    match mode {
        MatmulMode::HwMcast => {
            cfg.wide_mcast = true;
            cfg.narrow_mcast = true;
        }
        _ => {
            cfg.wide_mcast = false;
            cfg.narrow_mcast = false;
        }
    }
    let l = MatmulLayout::paper(&cfg);
    assert!(
        l.l1_footprint() <= cfg.l1_bytes,
        "L1 footprint {} exceeds SPM {}",
        l.l1_footprint(),
        cfg.l1_bytes
    );
    let mut soc = Soc::new(cfg.clone());

    // deterministic inputs
    let n = l.n;
    let mut a = vec![0.0f64; n * n];
    let mut b = vec![0.0f64; n * n];
    let mut rng = crate::util::prng::Pcg::new(0xC0FFEE);
    for v in a.iter_mut().chain(b.iter_mut()) {
        *v = rng.normal();
    }
    soc.mem.write_f64(LLC_BASE + l.a_off, &a);
    // B tile-major: tile k holds rows 0..n of columns k*16..(k+1)*16
    for k in 0..l.n_tiles() {
        let mut tile = Vec::with_capacity(n * l.tile_cols);
        for row in 0..n {
            for col in 0..l.tile_cols {
                tile.push(b[row * n + k * l.tile_cols + col]);
            }
        }
        soc.mem
            .write_f64(LLC_BASE + l.b_off + k as u64 * l.tile_bytes(), &tile);
    }

    soc.load_programs(programs(&cfg, &l, mode));
    let mut handler = MatmulCompute::new(l.clone(), exec);
    let cycles = soc
        .run(
            &mut handler,
            Watchdog {
                stall_cycles: 500_000,
                max_cycles: 2_000_000_000,
            },
        )
        .unwrap_or_else(|e| panic!("matmul {mode:?}: {e}"));

    // validate C against a reference product
    let c_got = soc.mem.read_f64(LLC_BASE + l.c_off, n * n);
    let mut mismatches = 0u64;
    let mut first_bad: Option<(usize, usize, f64, f64)> = None;
    for i in 0..n {
        for j in 0..n {
            let mut acc = 0.0;
            for kk in 0..n {
                acc += a[i * n + kk] * b[kk * n + j];
            }
            let got = c_got[i * n + j];
            if (got - acc).abs() > 1e-9 * acc.abs().max(1.0) {
                mismatches += 1;
                if first_bad.is_none() {
                    first_bad = Some((i, j, got, acc));
                }
            }
        }
    }
    let numerics_ok = mismatches == 0;
    if let Some((i, j, got, want)) = first_bad {
        eprintln!(
            "matmul {mode:?}: {mismatches} mismatches; first C[{i}][{j}] = {got} want {want} \
             (cluster {}, col-tile {})",
            i / l.rows_per_cluster,
            j / l.tile_cols
        );
    }

    let llc_read_bytes: u64 = soc
        .llc
        .reads
        .iter()
        .map(|(_, _, beats)| *beats as u64 * cfg.wide_bytes as u64)
        .sum();
    let llc_write_bytes: u64 = soc
        .llc
        .writes
        .iter()
        .map(|w| w.beats as u64 * cfg.wide_bytes as u64)
        .sum();
    let flops = l.total_flops();
    let gflops = flops as f64 / cycles as f64 * cfg.freq_ghz;
    MatmulResult {
        mode,
        cycles,
        flops,
        gflops,
        llc_read_bytes,
        llc_write_bytes,
        oi_read: flops as f64 / llc_read_bytes as f64,
        pct_of_peak: gflops / cfg.peak_gflops() * 100.0,
        numerics_ok,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_fits_l1_and_matches_paper() {
        let cfg = SocConfig::default();
        let l = MatmulLayout::paper(&cfg);
        assert_eq!(l.rows_per_cluster, 8);
        assert_eq!(l.n_tiles(), 16);
        assert_eq!(l.tile_bytes(), 32 * 1024);
        assert_eq!(l.a_panel_bytes(), 16 * 1024);
        // A(16K) + 2×B(32K) + C(16K) = 96 KiB ≤ 128 KiB (double buffered)
        assert_eq!(l.l1_footprint(), 96 * 1024);
        // steady-state tile: 8×16×256 MACs
        assert_eq!(l.tile_macs(), 32768);
    }

    #[test]
    fn rust_tile_exec_correct() {
        let a = vec![1.0, 2.0, 3.0, 4.0]; // 2x2
        let b = vec![5.0, 6.0, 7.0, 8.0];
        let mut c = vec![0.0; 4];
        RustTileExec.tile(&a, &b, &mut c, 2, 2, 2);
        assert_eq!(c, vec![19.0, 22.0, 43.0, 50.0]);
    }

    // Full-system runs are exercised (and asserted numerically) in the
    // integration tests and benches; here a small smoke on 4 clusters.
    #[test]
    fn small_system_baseline_runs_and_validates() {
        let mut cfg = SocConfig::tiny(4);
        cfg.llc_bytes = 4 * 1024 * 1024;
        // 4 clusters × 64 rows... keep the paper geometry by scaling n
        let l = MatmulLayout::new(64, 16, 16);
        assert!(l.l1_footprint() <= cfg.l1_bytes);
        let mut soc = Soc::new(cfg.clone());
        let n = l.n;
        let a: Vec<f64> = (0..n * n).map(|i| (i % 7) as f64).collect();
        let b: Vec<f64> = (0..n * n).map(|i| ((i % 5) as f64) - 2.0).collect();
        soc.mem.write_f64(LLC_BASE + l.a_off, &a);
        for k in 0..l.n_tiles() {
            let mut tile = Vec::new();
            for row in 0..n {
                for col in 0..l.tile_cols {
                    tile.push(b[row * n + k * l.tile_cols + col]);
                }
            }
            soc.mem
                .write_f64(LLC_BASE + l.b_off + k as u64 * l.tile_bytes(), &tile);
        }
        soc.load_programs(programs(&cfg, &l, MatmulMode::Baseline));
        let mut exec = RustTileExec;
        let mut handler = MatmulCompute::new(l.clone(), &mut exec);
        soc.run_default(&mut handler).unwrap();
        assert_eq!(handler.tiles_computed, 4 * 4); // 4 clusters × 4 tiles
        let c = soc.mem.read_f64(LLC_BASE + l.c_off, n * n);
        for i in 0..n {
            for j in 0..n {
                let want: f64 = (0..n).map(|kk| a[i * n + kk] * b[kk * n + j]).sum();
                assert!(
                    (c[i * n + j] - want).abs() < 1e-9,
                    "C[{i}][{j}] = {} want {want}",
                    c[i * n + j]
                );
            }
        }
    }
}

//! Collective-communication suite on the multicast fabric.
//!
//! Four collectives — **broadcast**, **all-gather**, **reduce-scatter**
//! and **all-reduce** — run over all `n_clusters` clusters of the
//! Occamy model, on every wide-network topology shape
//! ([`WideShape`]: the paper's group/top tree, a flat crossbar, deeper
//! trees, a mesh of tiles, plus the topology zoo's rings, tori and
//! rings of mesh groups), each in several strategies:
//!
//! * [`CollMode::Sw`] — software baselines built from unicast DMA
//!   transfers: binomial-tree (recursive-doubling) broadcast, ring
//!   all-gather, ring reduce-scatter, and ring reduce-scatter +
//!   all-gather for all-reduce — with unicast mailbox interrupts for
//!   the per-step notifies (both multicast extensions disabled, the
//!   paper's baseline system);
//! * [`CollMode::Hw`] — the distribution phases use the hardware 1-to-N
//!   fork: broadcast is one mask-form multicast; all-gather gathers to
//!   a root and re-distributes the concatenated buffer with a single
//!   multicast; all-reduce reduces hierarchically (members → group
//!   leaders → root, the fabric's first *converging* N-to-1 pattern)
//!   and multicasts the result down; reduce-scatter has no distribution
//!   phase, so its `Hw` variant is the direct all-to-all scatter of
//!   contribution chunks (converging traffic, still unicast);
//! * [`CollMode::HwConc`] — concurrent global multicasts on the
//!   fabric-wide reservation protocol (`SocConfig::e2e_mcast_order`):
//!   all-gather becomes N simultaneous chunk multicasts (one per rank,
//!   no gather phase at all); broadcast scatters chunks and pipelines
//!   the re-broadcast from *all* N sources at once; all-reduce runs the
//!   direct reduce-scatter and re-assembles with N concurrent chunk
//!   multicasts. These schedules deadlock on the RTL-faithful fabric.
//! * [`CollMode::HwReduce`] — in-network reduction
//!   (`SocConfig::fabric_reduce`, the dual of the multicast fork):
//!   reduce-scatter and all-reduce issue **tagged member bursts** that
//!   the fabric combines element-wise at its join points
//!   (`Cmd::DmaReduce` → `axi::reduce`), so the converging N-to-1
//!   phase needs **no `OP_*_COMBINE` software round-trips at all** —
//!   every rank's reduced chunk materialises in its `acc` buffer
//!   directly, and the all-reduce re-assembles with PR 4's concurrent
//!   chunk multicasts down. Broadcast and all-gather have no reduction
//!   phase, so they reuse the `hw-concurrent` schedules (the mode
//!   still arms the reservation protocol for them).
//! * [`CollMode::Auto`] — the cost-model-driven auto-tuner: before the
//!   run, [`auto_plan`] scores every concrete mode plus the
//!   concurrent-multicast chunk-split ladder on the analytic fabric
//!   model ([`crate::axi::costmodel`]) for the configured shape, size
//!   and package, and the run dispatches to the winner. The
//!   `tunesweep` experiment measures the pick's regret against the
//!   measured-best mode per cell.
//!
//! The [`CollMode::Hw`] all-gather deliberately does **not** issue N
//! concurrent global multicasts: on the RTL-faithful fabric two
//! simultaneous all-cluster multicasts from different sources can form
//! the documented inter-level W-order deadlock (DESIGN.md §1,
//! `tests/occamy_system.rs::
//! global_broadcast_contention_deadlocks_documented_limitation`), so
//! that schedule keeps at most one global multicast in flight — the
//! gather-to-root phase converges over plain unicasts instead.
//! [`CollMode::HwConc`] is exactly the schedule family that limitation
//! forbade; end-to-end multicast ordering makes it legal.
//!
//! **Correctness.** The cycle-level fabric moves metadata beats; bytes
//! materialise in [`SocMem`] when a DMA job completes, and reduction
//! combining runs through the [`CollectiveCompute`] handler (op codes
//! [`OP_RS_COMBINE`]…[`OP_AR_FINAL`]) against per-cluster contribution
//! buffers ([`CollLayout`]). Contributions are small integers stored as
//! f64, so every sum is exact and the final buffers are bit-identical
//! to the scalar reference reduction regardless of combine order —
//! asserted after every run (`numerics_ok`) and in
//! `tests/collectives.rs`.
//!
//! **Cost accounting.** Each result records the W beats the cluster
//! DMAs inject into the fabric (`dma_w_beats`) — the source-port cost
//! the multicast fork amortises — plus the aggregate wide-network
//! [`XbarStats`]. The invariant asserted by the experiment rows: the
//! `Hw` strategy never injects more W beats than the `Sw` baseline.
//!
//! **Chiplet packages.** On a multi-chiplet package
//! (`SocConfig::package.chiplets > 1`) the schedules become
//! hierarchy-aware along die boundaries:
//!
//! * the leader grouping of the `Hw` all-reduce follows the **die**
//!   instead of the 4-cluster group ([`CollLayout`] picks
//!   `clusters_per_die`), so the converging phase runs members → die
//!   leaders (intra-die unicasts) → root, and only one partial vector
//!   per die crosses a D2D hop;
//! * the `Hw` all-gather gathers to the die leader first and forwards
//!   one contiguous per-die block over the D2D hop
//!   ([`hier_all_gather`]), then re-distributes with a single
//!   multicast that the gateways fork once per peer die;
//! * `HwConc`/`HwReduce` need no software change: the gateways are
//!   fork points for the concurrent chunk multicasts (one copy per
//!   D2D hop regardless of the die's population) and join points for
//!   the tagged reduction bursts (each die's contributions combine
//!   *before* the narrow D2D crossing) — intra-die hw-reduce feeding
//!   inter-die chunked multicast, entirely in fabric hardware.

use crate::axi::costmodel::{CollPattern, CostModel, D2dCost, SchedMode, ShapeKind};
use crate::axi::mcast::AddrSet;
use crate::axi::reduce::ReduceOp;
use crate::axi::xbar::XbarStats;
use crate::occamy::config::MAILBOX_OFFSET;
use crate::occamy::{Cmd, ComputeHandler, Soc, SocConfig, SocMem, WideShape};
use crate::sim::engine::Watchdog;

/// Which collective to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CollOp {
    Broadcast,
    AllGather,
    ReduceScatter,
    AllReduce,
}

impl CollOp {
    pub fn name(self) -> &'static str {
        match self {
            CollOp::Broadcast => "broadcast",
            CollOp::AllGather => "all-gather",
            CollOp::ReduceScatter => "reduce-scatter",
            CollOp::AllReduce => "all-reduce",
        }
    }

    pub fn parse(s: &str) -> Option<CollOp> {
        match s {
            "broadcast" | "bcast" => Some(CollOp::Broadcast),
            "all-gather" | "allgather" => Some(CollOp::AllGather),
            "reduce-scatter" | "reducescatter" => Some(CollOp::ReduceScatter),
            "all-reduce" | "allreduce" => Some(CollOp::AllReduce),
            _ => None,
        }
    }

    pub const ALL: [CollOp; 4] = [
        CollOp::Broadcast,
        CollOp::AllGather,
        CollOp::ReduceScatter,
        CollOp::AllReduce,
    ];
}

/// Distribution strategy (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CollMode {
    /// Unicast-only software schedule (baseline system, no multicast).
    Sw,
    /// Multicast-accelerated distribution phases, at most one global
    /// multicast in flight (legal on the RTL-faithful fabric).
    Hw,
    /// Concurrent global multicasts from many sources at once — needs
    /// the fabric-wide reservation protocol
    /// (`SocConfig::e2e_mcast_order`), which this mode switches on.
    HwConc,
    /// In-network reduction: the converging phases run as tagged
    /// member bursts combined inside the fabric
    /// (`SocConfig::fabric_reduce`, switched on by this mode together
    /// with the reservation protocol), no software combine round-trips.
    HwReduce,
    /// Cost-model-driven auto-tuning: [`auto_plan`] scores every
    /// concrete mode (and the concurrent-multicast chunk-split ladder)
    /// on the analytic fabric model ([`crate::axi::costmodel`]) and
    /// the run dispatches to the winner. Not part of [`CollMode::ALL`]
    /// — sweeps measure the concrete modes and `Auto` rides on top.
    Auto,
}

impl CollMode {
    pub fn name(self) -> &'static str {
        match self {
            CollMode::Sw => "sw",
            CollMode::Hw => "hw-mcast",
            CollMode::HwConc => "hw-concurrent",
            CollMode::HwReduce => "hw-reduce",
            CollMode::Auto => "auto",
        }
    }

    pub fn parse(s: &str) -> Option<CollMode> {
        match s {
            "sw" | "unicast" => Some(CollMode::Sw),
            "hw" | "hw-mcast" | "mcast" => Some(CollMode::Hw),
            "hw-concurrent" | "hwconc" | "concurrent" | "conc" => Some(CollMode::HwConc),
            "hw-reduce" | "hwred" | "reduce" | "red" => Some(CollMode::HwReduce),
            "auto" | "tune" | "tuned" => Some(CollMode::Auto),
            _ => None,
        }
    }

    /// The concrete measurable modes (the auto-tuner picks among
    /// these; `Auto` itself is deliberately not swept).
    pub const ALL: [CollMode; 4] = [
        CollMode::Sw,
        CollMode::Hw,
        CollMode::HwConc,
        CollMode::HwReduce,
    ];
}

/// Per-cluster L1 layout of one collective run. All offsets are
/// relative to the cluster window base; `chunk = bytes / n`.
///
/// ```text
/// data    [bytes]            rank's contribution / broadcast payload
/// acc     [bytes]            broadcast result; reduce-scatter result (chunk)
/// gather  [bytes]            all-gather / all-reduce result (n chunks)
/// work    [chunk]            ring reduce-scatter running partial
/// recv    [(n-1) * chunk]    ring staging, one slot per round (no reuse,
///                            so a lagging neighbour can never be overrun)
/// slots   [n*chunk or (cpg-1)*bytes]   contribution slots: direct
///                            reduce-scatter (indexed by sender) /
///                            group members' vectors at a leader
/// lslots  [(groups-1)*bytes] leader partial vectors at the root
/// ```
#[derive(Debug, Clone)]
pub struct CollLayout {
    pub n: usize,
    /// Leader span of the hierarchical schedules: clusters per group,
    /// or clusters per die on a chiplet package.
    pub cpg: usize,
    /// `n / cpg` — groups, or dies on a chiplet package.
    pub n_groups: usize,
    pub bytes: u64,
    pub chunk: u64,
    pub data: u64,
    pub acc: u64,
    pub gather: u64,
    pub work: u64,
    pub recv: u64,
    pub slots: u64,
    pub lslots: u64,
}

impl CollLayout {
    pub fn new(cfg: &SocConfig, bytes: u64) -> CollLayout {
        let n = cfg.n_clusters;
        assert!(n >= 2, "a collective needs at least 2 clusters");
        assert!(
            n.is_power_of_two(),
            "collectives address mask-form sets: n_clusters ({n}) must be a power of two"
        );
        assert!(
            bytes > 0 && bytes % (cfg.wide_bytes as u64 * n as u64) == 0,
            "collective size ({bytes} B) must be a positive multiple of \
             bus width x clusters ({} B)",
            cfg.wide_bytes as u64 * n as u64
        );
        let chunk = bytes / n as u64;
        // hierarchical leader grouping: on a chiplet package the
        // converging phases follow die boundaries (one leader per die,
        // one partial vector per D2D hop), otherwise the 4-cluster
        // group of the paper's tree
        let (cpg, n_groups) = if cfg.package.chiplets > 1 {
            (cfg.clusters_per_die(), cfg.package.chiplets)
        } else {
            (cfg.clusters_per_group, cfg.n_groups())
        };
        let data = 0;
        let acc = data + bytes;
        let gather = acc + bytes;
        let work = gather + bytes;
        let recv = work + chunk;
        let slots = recv + (n as u64 - 1) * chunk;
        // the slot region serves both the direct reduce-scatter
        // (n chunks = bytes) and the hierarchical reduce's member
        // vectors ((cpg-1) full vectors)
        let slot_region = bytes.max(cpg.saturating_sub(1) as u64 * bytes);
        let lslots = slots + slot_region;
        CollLayout {
            n,
            cpg,
            n_groups,
            bytes,
            chunk,
            data,
            acc,
            gather,
            work,
            recv,
            slots,
            lslots,
        }
    }

    pub fn elems(&self) -> usize {
        (self.bytes / 8) as usize
    }

    pub fn chunk_elems(&self) -> usize {
        (self.chunk / 8) as usize
    }

    /// L1 bytes one cluster needs for `(op, mode)`. `Auto` reserves
    /// the worst case over the concrete modes it may resolve to.
    pub fn footprint(&self, op: CollOp, mode: CollMode) -> u64 {
        if mode == CollMode::Auto {
            return CollMode::ALL
                .iter()
                .map(|m| self.footprint(op, *m))
                .max()
                .unwrap();
        }
        match (op, mode) {
            (_, CollMode::Auto) => unreachable!("resolved above"),
            (CollOp::Broadcast, _) => self.gather,
            (CollOp::AllGather, _) => self.work,
            (CollOp::ReduceScatter, CollMode::Sw) => self.slots,
            (CollOp::ReduceScatter, CollMode::Hw | CollMode::HwConc) => self.slots + self.bytes,
            // in-fabric combining needs no contribution slots: only
            // data + the acc result region (gather is their end bound)
            (CollOp::ReduceScatter, CollMode::HwReduce) => self.gather,
            (CollOp::AllReduce, CollMode::Sw) => self.slots,
            (CollOp::AllReduce, CollMode::Hw) => {
                self.lslots + self.n_groups.saturating_sub(1) as u64 * self.bytes
            }
            // direct reduce-scatter slots + the gather result region
            // (gather lies below slots, so the slot end bounds both)
            (CollOp::AllReduce, CollMode::HwConc) => self.slots + self.bytes,
            // data + acc + gather, no slots (work is their end bound)
            (CollOp::AllReduce, CollMode::HwReduce) => self.work,
        }
    }
}

// ---- reduction compute ops (dispatched through ComputeHandler) ----

/// Ring reduce-scatter combine of round `arg & 0xffff_ffff`; bit 32 set
/// = the final round writes into the rank's gather slot (all-reduce)
/// instead of `acc` (standalone reduce-scatter).
pub const OP_RS_COMBINE: u32 = 10;
/// Direct reduce-scatter: fold own chunk + all peer contribution slots
/// into `acc`.
pub const OP_RS_DIRECT: u32 = 11;
/// Group leader partial: own vector + member slots into `acc`.
pub const OP_AR_PARTIAL: u32 = 12;
/// Root final: own vector + member slots + leader partials into
/// `gather`.
pub const OP_AR_FINAL: u32 = 13;

/// The collectives' functional compute handler: applies the reduction
/// combining ops against the [`CollLayout`] buffers.
pub struct CollectiveCompute {
    pub layout: CollLayout,
    pub combines: u64,
}

impl CollectiveCompute {
    pub fn new(layout: CollLayout) -> CollectiveCompute {
        CollectiveCompute {
            layout,
            combines: 0,
        }
    }
}

impl ComputeHandler for CollectiveCompute {
    fn exec(&mut self, cluster: usize, op: u32, arg: u64, _cy: u64, mem: &mut SocMem) {
        let l = &self.layout;
        let base = crate::occamy::config::CLUSTER_BASE
            + cluster as u64 * crate::occamy::config::CLUSTER_STRIDE;
        let (se, ce) = (l.elems(), l.chunk_elems());
        match op {
            OP_RS_COMBINE => {
                let t = (arg & 0xffff_ffff) as usize;
                let to_gather = arg >> 32 != 0;
                let r = cluster;
                let n = l.n;
                // chunk combined this round (see `programs`: round t
                // receives partial chunk (r - t - 2) mod n)
                let c = (r + 2 * n - t - 2) % n;
                let own = mem.read_f64(base + l.data + c as u64 * l.chunk, ce);
                let dst = if t + 2 >= n {
                    // final round: the fully reduced chunk lands at its
                    // result location
                    if to_gather {
                        base + l.gather + r as u64 * l.chunk
                    } else {
                        base + l.acc
                    }
                } else {
                    base + l.work
                };
                mem.write_f64(dst, &own);
                mem.add_f64(dst, base + l.recv + t as u64 * l.chunk, ce);
            }
            OP_RS_DIRECT => {
                let r = cluster;
                let own = mem.read_f64(base + l.data + r as u64 * l.chunk, ce);
                mem.write_f64(base + l.acc, &own);
                for j in 0..l.n {
                    if j == r {
                        continue;
                    }
                    mem.add_f64(base + l.acc, base + l.slots + j as u64 * l.chunk, ce);
                }
            }
            OP_AR_PARTIAL => {
                let own = mem.read_f64(base + l.data, se);
                mem.write_f64(base + l.acc, &own);
                for i in 0..l.cpg - 1 {
                    mem.add_f64(base + l.acc, base + l.slots + i as u64 * l.bytes, se);
                }
            }
            OP_AR_FINAL => {
                let own = mem.read_f64(base + l.data, se);
                mem.write_f64(base + l.gather, &own);
                for i in 0..l.cpg - 1 {
                    mem.add_f64(base + l.gather, base + l.slots + i as u64 * l.bytes, se);
                }
                for i in 0..l.n_groups - 1 {
                    mem.add_f64(base + l.gather, base + l.lslots + i as u64 * l.bytes, se);
                }
            }
            other => panic!("collectives: unknown compute op {other}"),
        }
        self.combines += 1;
    }
}

// ---- schedules ----

/// Build per-cluster command programs for one `(op, mode)` point.
pub fn programs(cfg: &SocConfig, l: &CollLayout, op: CollOp, mode: CollMode) -> Vec<Vec<Cmd>> {
    programs_chunked(cfg, l, op, mode, 1)
}

/// [`programs`] with the auto-tuner's chunk knob: every concurrent
/// chunk multicast is split into `chunks` beat-aligned sub-chunk
/// multicasts, pipelining fork latency with injection. `chunks = 1`
/// is the classic one-multicast-per-rank schedule; a split that would
/// break beat alignment falls back to it. The bytes written are
/// identical for every split, so results stay bit-exact.
pub fn programs_chunked(
    cfg: &SocConfig,
    l: &CollLayout,
    op: CollOp,
    mode: CollMode,
    chunks: usize,
) -> Vec<Vec<Cmd>> {
    let n = l.n;
    let l1 = |c: usize, off: u64| cfg.cluster_base(c) + off;
    let uni = |c: usize, off: u64| AddrSet::unicast(l1(c, off));
    let irq = |c: usize| AddrSet::unicast(cfg.mailbox_addr(c));
    let se = l.elems() as u64;
    let mut progs: Vec<Vec<Cmd>> = vec![Vec::new(); n];

    let k = if chunks >= 1 && l.chunk % (chunks as u64 * cfg.wide_bytes as u64) == 0 {
        chunks
    } else {
        1
    };
    let piece = l.chunk / k as u64;
    // one rank's leg of the concurrent-multicast phase: k sub-chunk
    // multicasts back to back, then the usual drain
    let conc_mcast = |p: &mut Vec<Cmd>, r: usize, src_off: u64, dst_off: u64, tag_base: u64| {
        for s in 0..k {
            p.push(Cmd::Dma {
                src: l1(r, src_off + s as u64 * piece),
                dst: cfg.cluster_set(0, n, dst_off + s as u64 * piece),
                bytes: piece,
                tag: tag_base + (r * k + s) as u64,
            });
        }
        p.push(Cmd::WaitDma);
    };

    match (op, mode) {
        (_, CollMode::Auto) => {
            unreachable!("CollMode::Auto resolves to a concrete mode before scheduling")
        }
        // ---- broadcast ----
        (CollOp::Broadcast, CollMode::Sw) => {
            // binomial tree (recursive doubling): after round t, ranks
            // [0, 2^(t+1)) hold the payload in `acc`
            for (r, p) in progs.iter_mut().enumerate() {
                if r == 0 {
                    p.push(Cmd::Dma {
                        src: l1(0, l.data),
                        dst: uni(0, l.acc),
                        bytes: l.bytes,
                        tag: 0,
                    });
                    p.push(Cmd::WaitDma);
                } else {
                    p.push(Cmd::WaitIrq { count: 1 });
                }
                let mut t = 0;
                while (1usize << t) < n {
                    let d = r + (1 << t);
                    if r < (1 << t) && d < n {
                        p.push(Cmd::Dma {
                            src: l1(r, l.acc),
                            dst: uni(d, l.acc),
                            bytes: l.bytes,
                            tag: 1 + t as u64,
                        });
                        p.push(Cmd::WaitDma);
                        p.push(Cmd::SendIrq { dst: irq(d) });
                    }
                    t += 1;
                }
            }
        }
        (CollOp::Broadcast, CollMode::Hw) => {
            hw_broadcast(cfg, l, &mut progs);
        }
        (CollOp::Broadcast, CollMode::HwConc | CollMode::HwReduce) if n >= 4 => {
            // scatter + concurrent all-gather (the van-de-Geijn
            // large-message broadcast): rank 0 scatters chunk j into
            // rank j's result slot, then EVERY rank re-broadcasts its
            // chunk with a global multicast — n simultaneous
            // all-cluster multicasts pipelining through the fabric,
            // which only the end-to-end reservation protocol can order
            for (r, p) in progs.iter_mut().enumerate() {
                if r == 0 {
                    for j in 1..n {
                        p.push(Cmd::Dma {
                            src: l1(0, l.data + j as u64 * l.chunk),
                            dst: uni(j, l.acc + j as u64 * l.chunk),
                            bytes: l.chunk,
                            tag: j as u64,
                        });
                    }
                    // own chunk lands by local copy
                    p.push(Cmd::Dma {
                        src: l1(0, l.data),
                        dst: uni(0, l.acc),
                        bytes: l.chunk,
                        tag: 50,
                    });
                    p.push(Cmd::WaitDma);
                    p.push(Cmd::SendIrq {
                        dst: cfg.all_mailboxes(),
                    });
                }
                p.push(Cmd::WaitIrq { count: 1 });
                conc_mcast(p, r, l.acc + r as u64 * l.chunk, l.acc + r as u64 * l.chunk, 100);
                p.push(Cmd::SendIrq {
                    dst: cfg.all_mailboxes(),
                });
                p.push(Cmd::WaitIrq {
                    count: n as u32,
                });
            }
        }
        (CollOp::Broadcast, CollMode::HwConc | CollMode::HwReduce) => {
            // n < 4: the scatter phase has nothing to amortise — the
            // single-multicast schedule is already optimal
            hw_broadcast(cfg, l, &mut progs);
        }
        // ---- all-gather ----
        (CollOp::AllGather, CollMode::Sw) => {
            ring_all_gather(cfg, l, &mut progs, 0);
        }
        (CollOp::AllGather, CollMode::Hw) if n == 2 => {
            // degenerate pair: gather-to-root + full-buffer multicast
            // would inject 3 chunks where the ring exchange injects 2,
            // breaking the hw <= sw injection invariant — there is no
            // fan-out for the fork to amortise, so use the exchange
            ring_all_gather(cfg, l, &mut progs, 0);
        }
        (CollOp::AllGather, CollMode::Hw) if cfg.package.chiplets > 1 && l.cpg > 1 => {
            // chiplet package: gather inside each die first, cross the
            // narrow D2D hop once per die as one contiguous block,
            // multicast down (forked per die at the gateways)
            hier_all_gather(cfg, l, &mut progs);
        }
        (CollOp::AllGather, CollMode::Hw) => {
            // gather-to-root over unicasts (converging), then ONE
            // multicast of the concatenated buffer — never more than a
            // single global multicast in flight (see the module docs on
            // the documented concurrent-broadcast limitation)
            for (r, p) in progs.iter_mut().enumerate() {
                if r == 0 {
                    p.push(Cmd::WaitIrq {
                        count: (n - 1) as u32,
                    });
                    p.push(Cmd::Dma {
                        src: l1(0, l.gather),
                        dst: cfg.cluster_set(0, n, l.gather),
                        bytes: l.bytes,
                        tag: 100,
                    });
                    p.push(Cmd::WaitDma);
                    p.push(Cmd::SendIrq {
                        dst: cfg.all_mailboxes(),
                    });
                    p.push(Cmd::WaitIrq { count: 1 });
                } else {
                    p.push(Cmd::Dma {
                        src: l1(r, l.gather + r as u64 * l.chunk),
                        dst: uni(0, l.gather + r as u64 * l.chunk),
                        bytes: l.chunk,
                        tag: r as u64,
                    });
                    p.push(Cmd::WaitDma);
                    p.push(Cmd::SendIrq { dst: irq(0) });
                    p.push(Cmd::WaitIrq { count: 1 });
                }
            }
        }
        (CollOp::AllGather, CollMode::HwConc | CollMode::HwReduce) => {
            // the schedule §6 explicitly could not express before: all
            // n ranks multicast their own chunk into everyone's gather
            // slot AT ONCE — n concurrent global multicasts, no gather
            // phase, injected beats = exactly one buffer
            for (r, p) in progs.iter_mut().enumerate() {
                let slot = l.gather + r as u64 * l.chunk;
                conc_mcast(p, r, slot, slot, 0);
                p.push(Cmd::SendIrq {
                    dst: cfg.all_mailboxes(),
                });
                p.push(Cmd::WaitIrq {
                    count: n as u32,
                });
            }
        }
        // ---- reduce-scatter ----
        (CollOp::ReduceScatter, CollMode::Sw) => {
            ring_reduce_scatter(cfg, l, &mut progs, false);
        }
        (CollOp::ReduceScatter, CollMode::Hw | CollMode::HwConc) => {
            // no distribution phase to parallelise: the concurrent mode
            // is the same direct all-to-all scatter + local fold
            direct_reduce_scatter(cfg, l, &mut progs);
        }
        (CollOp::ReduceScatter, CollMode::HwReduce) => {
            // tagged member bursts combined inside the fabric — the
            // reduced chunks land in `acc` with zero software combines
            fabric_reduce_scatter(cfg, l, &mut progs);
        }
        // ---- all-reduce ----
        (CollOp::AllReduce, CollMode::Sw) => {
            // ring reduce-scatter (final combine into the gather slot)
            // followed by the ring all-gather over the reduced chunks
            ring_reduce_scatter(cfg, l, &mut progs, true);
            ring_all_gather(cfg, l, &mut progs, 1000);
        }
        (CollOp::AllReduce, CollMode::Hw) => {
            // hierarchical reduce: members → group leaders → root
            // (converging unicasts into per-sender contribution slots),
            // then one multicast of the reduced vector down
            let cpg = l.cpg;
            let n_groups = l.n_groups;
            for (r, p) in progs.iter_mut().enumerate() {
                let g = r / cpg;
                let leader = g * cpg;
                if r == 0 {
                    let expect = (cpg - 1) + (n_groups - 1);
                    if expect > 0 {
                        p.push(Cmd::WaitIrq {
                            count: expect as u32,
                        });
                    }
                    p.push(Cmd::Compute {
                        macs: expect as u64 * se,
                        op: OP_AR_FINAL,
                        arg: 0,
                    });
                    p.push(Cmd::Dma {
                        src: l1(0, l.gather),
                        dst: cfg.cluster_set(0, n, l.gather),
                        bytes: l.bytes,
                        tag: 100,
                    });
                    p.push(Cmd::WaitDma);
                    p.push(Cmd::SendIrq {
                        dst: cfg.all_mailboxes(),
                    });
                    p.push(Cmd::WaitIrq { count: 1 });
                } else if r == leader {
                    if cpg > 1 {
                        p.push(Cmd::WaitIrq {
                            count: (cpg - 1) as u32,
                        });
                    }
                    p.push(Cmd::Compute {
                        macs: (cpg as u64 - 1) * se,
                        op: OP_AR_PARTIAL,
                        arg: 0,
                    });
                    p.push(Cmd::Dma {
                        src: l1(r, l.acc),
                        dst: uni(0, l.lslots + (g as u64 - 1) * l.bytes),
                        bytes: l.bytes,
                        tag: g as u64,
                    });
                    p.push(Cmd::WaitDma);
                    p.push(Cmd::SendIrq { dst: irq(0) });
                    p.push(Cmd::WaitIrq { count: 1 });
                } else {
                    p.push(Cmd::Dma {
                        src: l1(r, l.data),
                        dst: uni(leader, l.slots + (r - leader - 1) as u64 * l.bytes),
                        bytes: l.bytes,
                        tag: r as u64,
                    });
                    p.push(Cmd::WaitDma);
                    p.push(Cmd::SendIrq { dst: irq(leader) });
                    p.push(Cmd::WaitIrq { count: 1 });
                }
            }
        }
        (CollOp::AllReduce, CollMode::HwReduce) => {
            // in-fabric reduce-scatter (every rank's reduced chunk
            // lands in `acc` — no software combines), then PR 4's n
            // concurrent chunk multicasts re-assemble the full vector
            fabric_reduce_scatter(cfg, l, &mut progs);
            for (r, p) in progs.iter_mut().enumerate() {
                conc_mcast(p, r, l.acc, l.gather + r as u64 * l.chunk, 100);
                p.push(Cmd::SendIrq {
                    dst: cfg.all_mailboxes(),
                });
                p.push(Cmd::WaitIrq {
                    count: n as u32,
                });
            }
        }
        (CollOp::AllReduce, CollMode::HwConc) => {
            // direct reduce-scatter (every rank ends with its reduced
            // chunk in `acc`), then n concurrent chunk multicasts
            // re-assemble the full vector in everyone's gather buffer —
            // the reduce-scatter + all-gather decomposition with the
            // all-gather collapsed into simultaneous global multicasts
            direct_reduce_scatter(cfg, l, &mut progs);
            for (r, p) in progs.iter_mut().enumerate() {
                conc_mcast(p, r, l.acc, l.gather + r as u64 * l.chunk, 100);
                p.push(Cmd::SendIrq {
                    dst: cfg.all_mailboxes(),
                });
                p.push(Cmd::WaitIrq {
                    count: n as u32,
                });
            }
        }
    }
    progs
}

/// The single-multicast hardware broadcast: one mask-form multicast
/// covering every cluster (self included), then one multicast notify
/// interrupt. Shared by [`CollMode::Hw`] and the degenerate small-n
/// [`CollMode::HwConc`] case.
fn hw_broadcast(cfg: &SocConfig, l: &CollLayout, progs: &mut [Vec<Cmd>]) {
    let n = l.n;
    progs[0] = vec![
        Cmd::Dma {
            src: cfg.cluster_base(0) + l.data,
            dst: cfg.cluster_set(0, n, l.acc),
            bytes: l.bytes,
            tag: 0,
        },
        Cmd::WaitDma,
        Cmd::SendIrq {
            dst: cfg.all_mailboxes(),
        },
        Cmd::WaitIrq { count: 1 }, // own copy of the notify
    ];
    for p in progs.iter_mut().skip(1) {
        p.push(Cmd::WaitIrq { count: 1 });
    }
}

/// The hierarchy-aware `Hw` all-gather of a chiplet package: every
/// rank unicasts its chunk to its **die leader** (intra-die converging
/// traffic that never touches a D2D hop), each non-root leader then
/// forwards its die's concatenated block — one contiguous transfer —
/// across the narrow D2D hop to the root, and the root re-distributes
/// the full buffer with a single multicast that each gateway forks
/// exactly once per peer die. D2D payload cost: one block per die up,
/// one buffer per die down, independent of the die's population.
fn hier_all_gather(cfg: &SocConfig, l: &CollLayout, progs: &mut [Vec<Cmd>]) {
    let n = l.n;
    let cpg = l.cpg; // clusters per die here
    let dies = l.n_groups;
    for (r, p) in progs.iter_mut().enumerate() {
        let d = r / cpg;
        let leader = d * cpg;
        if r == 0 {
            p.push(Cmd::WaitIrq {
                count: ((cpg - 1) + (dies - 1)) as u32,
            });
            p.push(Cmd::Dma {
                src: cfg.cluster_base(0) + l.gather,
                dst: cfg.cluster_set(0, n, l.gather),
                bytes: l.bytes,
                tag: 100,
            });
            p.push(Cmd::WaitDma);
            p.push(Cmd::SendIrq {
                dst: cfg.all_mailboxes(),
            });
            p.push(Cmd::WaitIrq { count: 1 });
        } else if r == leader {
            p.push(Cmd::WaitIrq {
                count: (cpg - 1) as u32,
            });
            p.push(Cmd::Dma {
                src: cfg.cluster_base(r) + l.gather + (d * cpg) as u64 * l.chunk,
                dst: AddrSet::unicast(
                    cfg.cluster_base(0) + l.gather + (d * cpg) as u64 * l.chunk,
                ),
                bytes: cpg as u64 * l.chunk,
                tag: 200 + d as u64,
            });
            p.push(Cmd::WaitDma);
            p.push(Cmd::SendIrq {
                dst: AddrSet::unicast(cfg.mailbox_addr(0)),
            });
            p.push(Cmd::WaitIrq { count: 1 });
        } else {
            p.push(Cmd::Dma {
                src: cfg.cluster_base(r) + l.gather + r as u64 * l.chunk,
                dst: AddrSet::unicast(
                    cfg.cluster_base(leader) + l.gather + r as u64 * l.chunk,
                ),
                bytes: l.chunk,
                tag: r as u64,
            });
            p.push(Cmd::WaitDma);
            p.push(Cmd::SendIrq {
                dst: AddrSet::unicast(cfg.mailbox_addr(leader)),
            });
            p.push(Cmd::WaitIrq { count: 1 });
        }
    }
}

/// Direct all-to-all reduce-scatter: rank r scatters its chunk j into
/// rank j's contribution slot r — the first converging N-to-1 pattern
/// per destination — then folds locally into `acc` (`OP_RS_DIRECT`).
/// Shared by the hw reduce-scatter and the concurrent all-reduce front
/// half.
fn direct_reduce_scatter(cfg: &SocConfig, l: &CollLayout, progs: &mut [Vec<Cmd>]) {
    let n = l.n;
    let ce = l.chunk_elems() as u64;
    for (r, p) in progs.iter_mut().enumerate() {
        for j in 0..n {
            if j == r {
                continue;
            }
            p.push(Cmd::Dma {
                src: cfg.cluster_base(r) + l.data + j as u64 * l.chunk,
                dst: AddrSet::unicast(cfg.cluster_base(j) + l.slots + r as u64 * l.chunk),
                bytes: l.chunk,
                tag: j as u64,
            });
        }
        p.push(Cmd::WaitDma);
        for j in 0..n {
            if j == r {
                continue;
            }
            p.push(Cmd::SendIrq {
                dst: AddrSet::unicast(cfg.mailbox_addr(j)),
            });
        }
        p.push(Cmd::WaitIrq {
            count: (n - 1) as u32,
        });
        p.push(Cmd::Compute {
            macs: (n as u64 - 1) * ce,
            op: OP_RS_DIRECT,
            arg: 0,
        });
    }
}

/// The in-fabric reduce-scatter (`CollMode::HwReduce`): rank r issues
/// one tagged contribution per chunk j — `Cmd::DmaReduce` into rank
/// j's `acc`, reduction group j — and the fabric combines the
/// converging bursts at its join points (`axi::reduce`). Rank j's own
/// contribution is a local accumulate (no fabric traffic), so the
/// injected-beat count equals the direct all-to-all scatter's; the
/// saving is upstream, visible as `XbarStats::red_beats_saved`. The
/// `acc` buffers start zeroed (fresh SoC memory) and every combine is
/// a commutative exact integer sum, so no ordering is needed beyond
/// the closing notify round. Zero `OP_*` compute round-trips. Shared
/// by the hw-reduce reduce-scatter and the all-reduce front half;
/// `run_collective` opens group j on the membership oracle.
fn fabric_reduce_scatter(cfg: &SocConfig, l: &CollLayout, progs: &mut [Vec<Cmd>]) {
    let n = l.n;
    for (r, p) in progs.iter_mut().enumerate() {
        for j in 0..n {
            p.push(Cmd::DmaReduce {
                src: cfg.cluster_base(r) + l.data + j as u64 * l.chunk,
                dst: cfg.cluster_base(j) + l.acc,
                bytes: l.chunk,
                tag: j as u64,
                group: j as u32,
                op: ReduceOp::Sum,
            });
        }
        p.push(Cmd::WaitDma);
        p.push(Cmd::SendIrq {
            dst: cfg.all_mailboxes(),
        });
        p.push(Cmd::WaitIrq {
            count: n as u32,
        });
    }
}

/// The shared ring all-gather schedule: round `t` forwards gather
/// chunk `(r - t) mod n` to the successor's identical slot. Each round
/// writes a distinct slot, so no staging is needed. Used by the `sw`
/// all-gather, the all-reduce back half, and the degenerate 2-cluster
/// `hw` all-gather (where a multicast has no fan-out to amortise).
fn ring_all_gather(cfg: &SocConfig, l: &CollLayout, progs: &mut [Vec<Cmd>], tag_base: u64) {
    let n = l.n;
    for (r, p) in progs.iter_mut().enumerate() {
        let succ = (r + 1) % n;
        for t in 0..n - 1 {
            let idx = (r + n - t) % n;
            p.push(Cmd::Dma {
                src: cfg.cluster_base(r) + l.gather + idx as u64 * l.chunk,
                dst: AddrSet::unicast(cfg.cluster_base(succ) + l.gather + idx as u64 * l.chunk),
                bytes: l.chunk,
                tag: tag_base + t as u64,
            });
            p.push(Cmd::WaitDma);
            p.push(Cmd::SendIrq {
                dst: AddrSet::unicast(cfg.mailbox_addr(succ)),
            });
            p.push(Cmd::WaitIrq { count: 1 });
        }
    }
}

/// The shared ring reduce-scatter schedule: `n-1` rounds, each sending
/// the running partial to the successor's round-distinct staging slot,
/// then combining the received partial with the local contribution
/// chunk. Rank `r` ends with the fully reduced chunk `r` (in `acc`, or
/// in its gather slot when `to_gather` — the all-reduce front half).
fn ring_reduce_scatter(cfg: &SocConfig, l: &CollLayout, progs: &mut [Vec<Cmd>], to_gather: bool) {
    let n = l.n;
    let ce = l.chunk_elems() as u64;
    let flag = if to_gather { 1u64 << 32 } else { 0 };
    for (r, p) in progs.iter_mut().enumerate() {
        let succ = (r + 1) % n;
        for t in 0..n - 1 {
            // round t sends partial chunk (r - t - 1) mod n; the final
            // combine (t = n-2) completes chunk r
            let c_send = (r + 2 * n - t - 1) % n;
            let src = if t == 0 {
                cfg.cluster_base(r) + l.data + c_send as u64 * l.chunk
            } else {
                cfg.cluster_base(r) + l.work
            };
            p.push(Cmd::Dma {
                src,
                dst: AddrSet::unicast(cfg.cluster_base(succ) + l.recv + t as u64 * l.chunk),
                bytes: l.chunk,
                tag: t as u64,
            });
            p.push(Cmd::WaitDma);
            p.push(Cmd::SendIrq {
                dst: AddrSet::unicast(cfg.mailbox_addr(succ)),
            });
            p.push(Cmd::WaitIrq { count: 1 });
            p.push(Cmd::Compute {
                macs: ce,
                op: OP_RS_COMBINE,
                arg: t as u64 | flag,
            });
        }
    }
}

// ---- auto-tuning ----

/// The auto-tuner's resolved plan for one `(op, size, shape)` point.
#[derive(Debug, Clone)]
pub struct CollPlan {
    /// The concrete mode the run dispatches to.
    pub mode: CollMode,
    /// Sub-chunks per concurrent multicast (see [`programs_chunked`]).
    pub chunks: usize,
    /// The model's cycle estimate for the pick.
    pub cost: f64,
    /// Full scoreboard, cheapest first: `(mode, chunks, est. cycles)`.
    pub scored: Vec<(CollMode, usize, f64)>,
}

impl CollPlan {
    /// Short human-readable form for table rows: `hw-concurrent` or
    /// `hw-concurrent/2` when the chunk knob is engaged.
    pub fn describe(&self) -> String {
        if self.chunks > 1 {
            format!("{}/{}", self.mode.name(), self.chunks)
        } else {
            self.mode.name().to_string()
        }
    }
}

fn shape_kind(cfg: &SocConfig) -> ShapeKind {
    match &cfg.wide_shape {
        WideShape::Groups => ShapeKind::Groups {
            per_group: cfg.clusters_per_group,
        },
        WideShape::Flat => ShapeKind::Flat,
        WideShape::Tree(arity) => ShapeKind::Tree {
            arity: arity.clone(),
        },
        WideShape::Mesh(tiles) => ShapeKind::Mesh { tiles: *tiles },
        WideShape::Ring(nodes) => ShapeKind::Ring { nodes: *nodes },
        WideShape::Torus(cols, rows) => ShapeKind::Torus {
            cols: *cols,
            rows: *rows,
        },
        WideShape::RingMesh(groups, tiles) => ShapeKind::RingMesh {
            groups: *groups,
            tiles: *tiles,
        },
    }
}

fn sched_to_mode(s: SchedMode) -> CollMode {
    match s {
        SchedMode::Unicast => CollMode::Sw,
        SchedMode::Mcast => CollMode::Hw,
        SchedMode::ConcMcast => CollMode::HwConc,
        SchedMode::FabricReduce => CollMode::HwReduce,
    }
}

/// Score every concrete mode × chunk-split candidate for this config's
/// fabric on the analytic cost model and return the winning plan.
pub fn auto_plan(cfg: &SocConfig, op: CollOp, bytes: u64) -> CollPlan {
    let mut model = CostModel::new(cfg.n_clusters, cfg.wide_bytes as u64, shape_kind(cfg));
    model.max_mcast_outstanding = cfg.fabric_max_mcast_outstanding;
    model.mcast_w_cooldown = cfg.mcast_w_cooldown;
    if cfg.package.chiplets > 1 {
        model.d2d = Some(D2dCost {
            dies: cfg.package.chiplets,
            width_ratio: cfg.package.d2d_width_ratio,
            latency: cfg.package.d2d_latency,
        });
    }
    let pattern = match op {
        CollOp::Broadcast => CollPattern::Broadcast,
        CollOp::AllGather => CollPattern::AllGather,
        CollOp::ReduceScatter => CollPattern::ReduceScatter,
        CollOp::AllReduce => CollPattern::AllReduce,
    };
    let plan = model.plan(pattern, bytes);
    CollPlan {
        mode: sched_to_mode(plan.best.mode),
        chunks: plan.best.chunks,
        cost: plan.best.cost,
        scored: plan
            .scored
            .iter()
            .map(|c| (sched_to_mode(c.mode), c.chunks, c.cost))
            .collect(),
    }
}

// ---- running + verification ----

/// One measured collective run.
#[derive(Debug, Clone)]
pub struct CollectiveResult {
    pub op: CollOp,
    pub mode: CollMode,
    /// Wide-network shape label (`SocConfig::wide_shape`).
    pub shape: String,
    pub clusters: usize,
    pub bytes: u64,
    pub cycles: u64,
    /// Aggregate stats over every wide-network crossbar.
    pub wide: XbarStats,
    /// W beats injected into the wide fabric by the cluster DMAs — the
    /// source-port cost the multicast fork amortises (hop counts are
    /// visible in `wide.w_beats_in` instead).
    pub dma_w_beats: u64,
    /// Reduction combines dispatched through the compute handler.
    pub combines: u64,
    pub numerics_ok: bool,
    /// The auto-tuner's resolved plan — `Some` only when the run was
    /// dispatched through [`CollMode::Auto`].
    pub plan: Option<CollPlan>,
}

/// Deterministic contribution vector of one rank: small integers stored
/// as f64 (|v| ≤ 512), so sums over ≤ 64 ranks are exact in f64 and the
/// result is bit-identical to the scalar reference regardless of the
/// combine order an algorithm uses.
pub fn rank_values(rank: usize, elems: usize) -> Vec<f64> {
    let mut rng = crate::util::prng::Pcg::new(0xC011_EC71_5EED ^ (rank as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    (0..elems)
        .map(|_| (rng.next_u64() % 1024) as i64 as f64 - 512.0)
        .collect()
}

/// Seed the contribution buffers, run one `(op, mode)` point on the
/// configured system (the wide-network shape comes from
/// `cfg.wide_shape`), and validate the result buffers bit-exactly
/// against the scalar reference reduction.
///
/// [`CollMode::Auto`] first resolves to a concrete mode + chunk split
/// through [`auto_plan`]; the result keeps `mode = Auto` and records
/// the plan.
pub fn run_collective(cfg: &SocConfig, op: CollOp, mode: CollMode, bytes: u64) -> CollectiveResult {
    if mode == CollMode::Auto {
        let plan = auto_plan(cfg, op, bytes);
        let mut r = run_collective_chunked(cfg, op, plan.mode, bytes, plan.chunks);
        r.mode = CollMode::Auto;
        r.plan = Some(plan);
        return r;
    }
    run_collective_chunked(cfg, op, mode, bytes, 1)
}

/// [`run_collective`] with an explicit concurrent-multicast chunk
/// split (see [`programs_chunked`]); `mode` must be concrete.
pub fn run_collective_chunked(
    cfg: &SocConfig,
    op: CollOp,
    mode: CollMode,
    bytes: u64,
    chunks: usize,
) -> CollectiveResult {
    let mut cfg = cfg.clone();
    match mode {
        CollMode::Hw => {
            cfg.wide_mcast = true;
            cfg.narrow_mcast = true;
        }
        CollMode::HwConc => {
            // concurrent global multicasts are only deadlock-free on
            // the fabric-wide reservation protocol
            cfg.wide_mcast = true;
            cfg.narrow_mcast = true;
            cfg.e2e_mcast_order = true;
        }
        CollMode::HwReduce => {
            // in-network combining on the wide fabric + the
            // reservation protocol for the concurrent multicast-down
            // phases and the concurrent notify interrupts
            cfg.wide_mcast = true;
            cfg.narrow_mcast = true;
            cfg.e2e_mcast_order = true;
            cfg.fabric_reduce = true;
        }
        CollMode::Sw => {
            cfg.wide_mcast = false;
            cfg.narrow_mcast = false;
        }
        CollMode::Auto => unreachable!("run_collective resolves Auto before dispatch"),
    }
    let l = CollLayout::new(&cfg, bytes);
    let fp = l.footprint(op, mode);
    assert!(
        fp <= cfg.l1_bytes && fp <= MAILBOX_OFFSET,
        "{} {}: L1 footprint {fp} exceeds SPM {} (reduce the collective size)",
        op.name(),
        mode.name(),
        cfg.l1_bytes
    );
    let n = l.n;
    let (se, ce) = (l.elems(), l.chunk_elems());
    let mut soc = Soc::new(cfg.clone());

    // in-fabric reduction groups: one per chunk, all ranks members,
    // converging on rank j's acc buffer (the membership oracle filters
    // rank j's own — local — contribution out of the fabric plan)
    if mode == CollMode::HwReduce
        && matches!(op, CollOp::ReduceScatter | CollOp::AllReduce)
    {
        let members: Vec<usize> = (0..n).collect();
        for j in 0..n {
            soc.open_reduce_group(
                j as u32,
                ReduceOp::Sum,
                &members,
                cfg.cluster_base(j) + l.acc,
            );
        }
    }

    // ---- seed contributions ----
    let vals: Vec<Vec<f64>> = (0..n).map(|r| rank_values(r, se)).collect();
    match op {
        CollOp::Broadcast => {
            soc.mem.write_f64(cfg.cluster_base(0) + l.data, &vals[0]);
        }
        CollOp::AllGather => {
            for (r, v) in vals.iter().enumerate() {
                soc.mem.write_f64(
                    cfg.cluster_base(r) + l.gather + r as u64 * l.chunk,
                    &v[..ce],
                );
            }
        }
        CollOp::ReduceScatter | CollOp::AllReduce => {
            for (r, v) in vals.iter().enumerate() {
                soc.mem.write_f64(cfg.cluster_base(r) + l.data, v);
            }
        }
    }

    soc.load_programs(programs_chunked(&cfg, &l, op, mode, chunks));
    let mut handler = CollectiveCompute::new(l.clone());
    let cycles = soc
        .run(
            &mut handler,
            Watchdog {
                stall_cycles: 500_000,
                max_cycles: 500_000_000,
            },
        )
        .unwrap_or_else(|e| {
            panic!(
                "collective {} {} on {} ({n} clusters, {bytes} B): {e}",
                op.name(),
                mode.name(),
                cfg.wide_shape.label()
            )
        });

    // ---- scalar reference + bit-exact comparison ----
    let reduced: Vec<f64> = (0..se)
        .map(|i| (0..n).map(|r| vals[r][i]).sum())
        .collect();
    let mut mismatches = 0u64;
    let mut first_bad: Option<(usize, usize, f64, f64)> = None;
    let mut check = |cl: usize, got: &[f64], want: &[f64]| {
        for (i, (g, w)) in got.iter().zip(want).enumerate() {
            if g.to_bits() != w.to_bits() {
                mismatches += 1;
                if first_bad.is_none() {
                    first_bad = Some((cl, i, *g, *w));
                }
            }
        }
    };
    for c in 0..n {
        let base = cfg.cluster_base(c);
        match op {
            CollOp::Broadcast => {
                check(c, &soc.mem.read_f64(base + l.acc, se), &vals[0]);
            }
            CollOp::AllGather => {
                for (j, v) in vals.iter().enumerate() {
                    check(
                        c,
                        &soc.mem.read_f64(base + l.gather + j as u64 * l.chunk, ce),
                        &v[..ce],
                    );
                }
            }
            CollOp::ReduceScatter => {
                check(
                    c,
                    &soc.mem.read_f64(base + l.acc, ce),
                    &reduced[c * ce..(c + 1) * ce],
                );
            }
            CollOp::AllReduce => {
                check(c, &soc.mem.read_f64(base + l.gather, se), &reduced);
            }
        }
    }
    let numerics_ok = mismatches == 0;
    if let Some((cl, i, got, want)) = first_bad {
        eprintln!(
            "collective {} {}: {mismatches} mismatches; first at cluster {cl} elem {i}: \
             got {got} want {want}",
            op.name(),
            mode.name()
        );
    }

    let dma_w_beats: u64 = soc.clusters.iter().map(|c| c.dma.stats.write_beats).sum();
    CollectiveResult {
        op,
        mode,
        shape: cfg.wide_shape.label(),
        clusters: n,
        bytes,
        cycles,
        wide: soc.wide.stats_sum(),
        dma_w_beats,
        combines: handler.combines,
        numerics_ok,
        plan: None,
    }
}

/// The wide-network shapes the collectives experiment sweeps for a
/// given config: the paper's group/top tree, a flat crossbar, (when
/// more than one group exists) a mesh with one tile per group, and —
/// on single-die configs large enough to populate them — the topology
/// zoo's ring, torus and ring-of-meshes.
pub fn default_shapes(cfg: &SocConfig) -> Vec<WideShape> {
    let n = cfg.n_clusters;
    let mut shapes = vec![WideShape::Groups, WideShape::Flat];
    if cfg.n_groups() >= 2 {
        shapes.push(WideShape::Mesh(cfg.n_groups()));
    }
    // the peer-routed shapes don't support chiplet packages (per-die
    // trees only — see SocConfig::validate)
    if cfg.package.chiplets == 1 && n >= 8 && n % 4 == 0 {
        shapes.push(WideShape::Ring(4));
        shapes.push(WideShape::Torus(2, 2));
        shapes.push(WideShape::RingMesh(2, 2));
    }
    shapes
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(n: usize) -> SocConfig {
        SocConfig::tiny(n)
    }

    const SMALL: u64 = 2048; // 4 clusters => 512 B chunks

    #[test]
    fn layout_offsets_are_disjoint_and_bus_aligned() {
        let c = cfg(4);
        let l = CollLayout::new(&c, SMALL);
        let offs = [l.data, l.acc, l.gather, l.work, l.recv, l.slots, l.lslots];
        for w in offs.windows(2) {
            assert!(w[0] < w[1], "layout regions must ascend: {offs:?}");
        }
        for o in offs {
            assert_eq!(o % c.wide_bytes as u64, 0, "offset {o:#x} misaligned");
        }
        assert!(l.footprint(CollOp::AllReduce, CollMode::Hw) <= c.l1_bytes);
        // Auto reserves the worst case over the modes it may pick
        for op in CollOp::ALL {
            let auto = l.footprint(op, CollMode::Auto);
            for mode in CollMode::ALL {
                assert!(auto >= l.footprint(op, mode), "{} auto footprint", op.name());
            }
        }
    }

    #[test]
    fn broadcast_all_modes_bit_exact() {
        for mode in CollMode::ALL {
            let r = run_collective(&cfg(4), CollOp::Broadcast, mode, SMALL);
            assert!(r.numerics_ok, "broadcast {:?} numerics", mode);
            assert!(r.cycles > 0);
        }
    }

    #[test]
    fn all_gather_all_modes_bit_exact() {
        for mode in CollMode::ALL {
            let r = run_collective(&cfg(4), CollOp::AllGather, mode, SMALL);
            assert!(r.numerics_ok, "all-gather {:?} numerics", mode);
        }
    }

    #[test]
    fn reduce_scatter_all_modes_bit_exact() {
        for mode in CollMode::ALL {
            let r = run_collective(&cfg(4), CollOp::ReduceScatter, mode, SMALL);
            assert!(r.numerics_ok, "reduce-scatter {:?} numerics", mode);
            if mode == CollMode::HwReduce {
                // the whole point: combining moved into the fabric
                assert_eq!(r.combines, 0, "hw-reduce must not round-trip");
                assert!(r.wide.red_joins > 0, "fabric must combine");
            } else {
                assert!(r.combines > 0, "reduction must run through the handler");
            }
        }
    }

    #[test]
    fn hw_reduce_combines_in_fabric_and_saves_upstream_beats() {
        for op in [CollOp::ReduceScatter, CollOp::AllReduce] {
            let conc = run_collective(&cfg(8), op, CollMode::HwConc, 4096);
            let red = run_collective(&cfg(8), op, CollMode::HwReduce, 4096);
            assert!(red.numerics_ok, "{} hw-reduce numerics", op.name());
            assert_eq!(red.combines, 0, "{}: software combines survived", op.name());
            assert!(red.wide.red_joins > 0, "{}: no fabric joins", op.name());
            assert!(red.wide.red_beats_saved > 0);
            // injection parity with the direct scatter; the saving is
            // upstream, inside the fabric
            assert!(
                red.dma_w_beats <= conc.dma_w_beats,
                "{}: hw-reduce injects more than hw-concurrent ({} > {})",
                op.name(),
                red.dma_w_beats,
                conc.dma_w_beats
            );
        }
        // broadcast has no converging phase: hw-reduce falls back to
        // the concurrent schedule and must not open any join
        let b = run_collective(&cfg(8), CollOp::Broadcast, CollMode::HwReduce, 4096);
        assert!(b.numerics_ok);
        assert_eq!(b.wide.red_joins, 0);
    }

    #[test]
    fn all_reduce_all_modes_bit_exact() {
        for mode in CollMode::ALL {
            let r = run_collective(&cfg(8), CollOp::AllReduce, mode, 4096);
            assert!(r.numerics_ok, "all-reduce {:?} numerics", mode);
        }
    }

    #[test]
    fn concurrent_all_gather_issues_n_global_mcasts() {
        let r = run_collective(&cfg(4), CollOp::AllGather, CollMode::HwConc, SMALL);
        assert!(r.numerics_ok);
        // every rank multicasts its chunk — n concurrent global
        // multicasts observed at the source crossbars
        assert!(
            r.wide.aw_mcast >= 4,
            "conc all-gather must multicast from every rank ({} mcast AWs)",
            r.wide.aw_mcast
        );
        // tickets were actually issued and drained on the wide network
        assert!(r.wide.resv_tickets >= 4);
        // injected beats: exactly one buffer (n chunks)
        let hw = run_collective(&cfg(4), CollOp::AllGather, CollMode::Hw, SMALL);
        assert!(
            r.dma_w_beats <= hw.dma_w_beats,
            "conc all-gather injects more than gather-to-root ({} > {})",
            r.dma_w_beats,
            hw.dma_w_beats
        );
    }

    #[test]
    fn hw_broadcast_uses_one_mcast_and_fewer_injected_beats() {
        let sw = run_collective(&cfg(8), CollOp::Broadcast, CollMode::Sw, 4096);
        let hw = run_collective(&cfg(8), CollOp::Broadcast, CollMode::Hw, 4096);
        assert!(hw.wide.aw_mcast >= 1, "hw broadcast must multicast");
        assert_eq!(sw.wide.aw_mcast, 0, "sw baseline must not multicast");
        assert!(
            hw.dma_w_beats < sw.dma_w_beats,
            "multicast must inject fewer W beats ({} vs {})",
            hw.dma_w_beats,
            sw.dma_w_beats
        );
        assert!(
            hw.cycles < sw.cycles,
            "hw broadcast ({}) must beat the software tree ({})",
            hw.cycles,
            sw.cycles
        );
    }

    #[test]
    fn two_cluster_degenerate_pair_holds_invariants() {
        // n=2 has no fan-out to amortise: every hw schedule must still
        // be bit-exact and inject no more W beats than the sw baseline
        // (the hw all-gather degenerates to the ring exchange and the
        // concurrent broadcast to the single multicast here)
        for op in CollOp::ALL {
            let sw = run_collective(&cfg(2), op, CollMode::Sw, 1024);
            for mode in [CollMode::Hw, CollMode::HwConc] {
                let hw = run_collective(&cfg(2), op, mode, 1024);
                assert!(
                    sw.numerics_ok && hw.numerics_ok,
                    "{} {} n=2 numerics",
                    op.name(),
                    mode.name()
                );
                assert!(
                    hw.dma_w_beats <= sw.dma_w_beats,
                    "{} {} n=2: injects more W beats ({} > {})",
                    op.name(),
                    mode.name(),
                    hw.dma_w_beats,
                    sw.dma_w_beats
                );
            }
        }
    }

    #[test]
    fn auto_resolves_and_matches_its_concrete_pick_exactly() {
        for op in CollOp::ALL {
            let r = run_collective(&cfg(8), op, CollMode::Auto, 4096);
            assert!(r.numerics_ok, "{} auto numerics", op.name());
            assert_eq!(r.mode, CollMode::Auto);
            let plan = r.plan.clone().expect("auto run must record its plan");
            assert!(plan.mode != CollMode::Auto, "the pick must be concrete");
            assert!(plan.scored.len() >= 4, "scoreboard must cover every mode");
            let direct = run_collective_chunked(&cfg(8), op, plan.mode, 4096, plan.chunks);
            assert_eq!(r.cycles, direct.cycles, "{}: auto vs direct run", op.name());
            assert_eq!(r.dma_w_beats, direct.dma_w_beats);
        }
    }

    #[test]
    fn chunked_schedules_stay_bit_exact_and_preserve_beats() {
        let base = run_collective_chunked(&cfg(8), CollOp::AllGather, CollMode::HwConc, 4096, 1);
        let split = run_collective_chunked(&cfg(8), CollOp::AllGather, CollMode::HwConc, 4096, 2);
        assert!(split.numerics_ok);
        assert_eq!(base.dma_w_beats, split.dma_w_beats, "same bytes, same beats");
        assert!(
            split.wide.aw_mcast > base.wide.aw_mcast,
            "the split must issue more multicast AWs ({} vs {})",
            split.wide.aw_mcast,
            base.wide.aw_mcast
        );
        // a split that would break beat alignment falls back to one
        // multicast per rank and must still be bit-exact
        let odd = run_collective_chunked(&cfg(4), CollOp::AllGather, CollMode::HwConc, SMALL, 3);
        assert!(odd.numerics_ok);
    }

    #[test]
    fn fork_accounting_holds_for_all_ops() {
        for op in CollOp::ALL {
            for mode in CollMode::ALL {
                let r = run_collective(&cfg(4), op, mode, SMALL);
                assert_eq!(
                    r.wide.w_beats_out,
                    r.wide.w_beats_in + r.wide.w_fork_extra - r.wide.red_beats_saved,
                    "{} {}: W fork/join accounting broken",
                    op.name(),
                    mode.name()
                );
                assert_eq!(r.wide.decerr, 0, "{} {}: DECERR", op.name(), mode.name());
            }
        }
    }
}

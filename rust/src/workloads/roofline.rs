//! Roofline model of the Occamy architecture (fig. 3c's axes).
//!
//! `attainable(OI) = min(peak_compute, OI × llc_bandwidth)` — the
//! paper's fig. 3c plots the three matmul variants against this roof;
//! the baseline sits at OI ≈ 1.9 (92% of its memory-bound limit), the
//! multicast variants climb the OI axis into the compute-bound region.

use crate::occamy::SocConfig;

/// The roofline of a configuration.
#[derive(Debug, Clone)]
pub struct Roofline {
    /// GFLOPS ceiling (compute roof).
    pub peak_gflops: f64,
    /// LLC streaming bandwidth in GB/s (one wide port at 1 beat/cycle).
    pub llc_gbps: f64,
}

impl Roofline {
    pub fn of(cfg: &SocConfig) -> Roofline {
        Roofline {
            peak_gflops: cfg.peak_gflops(),
            llc_gbps: cfg.wide_bytes as f64 * cfg.freq_ghz,
        }
    }

    /// Attainable GFLOPS at operational intensity `oi` (FLOP/byte).
    pub fn attainable(&self, oi: f64) -> f64 {
        (oi * self.llc_gbps).min(self.peak_gflops)
    }

    /// The ridge point: OI where memory-bound meets compute-bound.
    pub fn ridge_oi(&self) -> f64 {
        self.peak_gflops / self.llc_gbps
    }

    /// Fraction (%) of the attainable roof achieved by a measurement.
    pub fn pct_of_roof(&self, oi: f64, gflops: f64) -> f64 {
        gflops / self.attainable(oi) * 100.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_system_roofline() {
        let r = Roofline::of(&SocConfig::default());
        assert_eq!(r.peak_gflops, 512.0);
        assert_eq!(r.llc_gbps, 64.0);
        // ridge at 8 FLOP/B: OI 1.9 is memory-bound, OI 32 compute-bound
        assert_eq!(r.ridge_oi(), 8.0);
        assert!((r.attainable(1.9) - 121.6).abs() < 1e-9);
        assert_eq!(r.attainable(32.0), 512.0);
    }

    #[test]
    fn paper_baseline_point_is_92pct_of_roof() {
        // the paper: OI 1.9 → 114.4 GFLOPS = 92% of the mem-bound limit
        let r = Roofline::of(&SocConfig::default());
        let pct = r.pct_of_roof(1.9, 114.4);
        assert!((pct - 94.0).abs() < 3.0, "pct={pct}");
    }
}

//! Robustness workloads (DESIGN.md §9): fault-injection recovery and
//! QoS arbitration under serving load.
//!
//! **Fault scenarios** ([`run_fault_scenario`]): every cluster drives a
//! mixed traffic pattern — a concurrent global multicast (on the e2e
//! reservation protocol), a unicast write to a healthy neighbour, a
//! unicast write *at* the victim endpoint, a read *from* the victim,
//! and two in-network reductions (one converging on a healthy cluster,
//! one on the victim) — while one cluster's L1 slave port runs a
//! [`FaultPlan`]. With the per-channel deadlines armed
//! (`SocConfig::req_timeout` / `cpl_timeout`) the run must COMPLETE:
//! every transaction that touches the fault retires with a synthesised
//! SLVERR/DECERR (visible to the workload as DMA error tags), every
//! transaction that avoids it stays clean, and the fabric ledgers —
//! reservation tickets, reduction groups, completion legs — drain to
//! empty. The schedule deliberately has **no interrupt barriers**: a
//! dead slave swallows mailbox stores, so recovery is observed purely
//! through DMA completion, which the timeout engine guarantees.
//!
//! **QoS under serving load** ([`run_qos_load`]): every cluster but one
//! hammers the same destination cluster with unicast write bursts — a
//! many-to-one serving hotspot. [`ArbPolicy::Priority`] with an
//! elevated `SocConfig::qos_prio` entry must pull the hot cluster's
//! completion earlier than round-robin does, while the aging bound
//! keeps every background cluster finishing (no starvation).

use crate::axi::golden::FaultPlan;
use crate::axi::mcast::AddrSet;
use crate::axi::mux::ArbPolicy;
use crate::axi::reduce::ReduceOp;
use crate::axi::xbar::XbarStats;
use crate::occamy::config::FaultSite;
use crate::occamy::{Cmd, NopCompute, Soc, SocConfig};
use crate::sim::engine::Watchdog;

/// The injectable endpoint failure modes, as scenario labels.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Dead from reset ([`FaultPlan::StallAfter`] with `bursts = 0`):
    /// every fabric transaction at the victim times out.
    Stall,
    /// Accepts AW/AR handshakes, never consumes W or responds
    /// ([`FaultPlan::GrantThenHang`]).
    GrantHang,
    /// Swallows exactly one B response ([`FaultPlan::DropB`]).
    DropB,
    /// Swallows exactly one R burst ([`FaultPlan::DropR`]).
    DropR,
}

impl FaultKind {
    pub fn name(self) -> &'static str {
        match self {
            FaultKind::Stall => "stall",
            FaultKind::GrantHang => "grant-hang",
            FaultKind::DropB => "drop-b",
            FaultKind::DropR => "drop-r",
        }
    }

    pub fn parse(s: &str) -> Option<FaultKind> {
        match s {
            "stall" => Some(FaultKind::Stall),
            "grant-hang" | "granthang" | "hang" => Some(FaultKind::GrantHang),
            "drop-b" | "dropb" => Some(FaultKind::DropB),
            "drop-r" | "dropr" => Some(FaultKind::DropR),
            _ => None,
        }
    }

    pub fn plan(self) -> FaultPlan {
        match self {
            FaultKind::Stall => FaultPlan::StallAfter { bursts: 0 },
            FaultKind::GrantHang => FaultPlan::GrantThenHang,
            FaultKind::DropB => FaultPlan::DropB { nth: 0 },
            FaultKind::DropR => FaultPlan::DropR { nth: 0 },
        }
    }

    /// Does every fabric transaction at the victim fail (vs exactly
    /// one swallowed completion)?
    pub fn is_total(self) -> bool {
        matches!(self, FaultKind::Stall | FaultKind::GrantHang)
    }

    pub const ALL: [FaultKind; 4] = [
        FaultKind::Stall,
        FaultKind::GrantHang,
        FaultKind::DropB,
        FaultKind::DropR,
    ];
}

// L1 offsets of the scenario's buffers (disjoint, bus-aligned).
const SRC: u64 = 0;
const MC_LAND: u64 = 0x4000;
const UNI_LAND: u64 = 0x8000;
const RED_ACC: u64 = 0xC000;
const RED_ACC_V: u64 = 0xD000;
const RD_LAND: u64 = 0xE000;

// Tag bases, one family per traffic class (`tag = base + rank`).
/// Concurrent global multicast — the victim is one fork leg.
pub const TAG_MCAST: u64 = 100;
/// Unicast write to a healthy neighbour — must stay clean.
pub const TAG_CLEAN: u64 = 200;
/// Unicast write at the victim.
pub const TAG_VWRITE: u64 = 300;
/// Read from the victim's L1.
pub const TAG_VREAD: u64 = 400;
/// In-network reduction converging on healthy cluster 0.
pub const TAG_RED_OK: u64 = 500;
/// In-network reduction converging on the victim.
pub const TAG_RED_V: u64 = 600;

/// One fault-injection run.
#[derive(Debug, Clone)]
pub struct FaultRunResult {
    pub kind: Option<FaultKind>,
    pub victim: usize,
    pub clusters: usize,
    pub bytes: u64,
    pub cycles: u64,
    /// Aggregate wide-network stats (timeout counters live here).
    pub wide: XbarStats,
    /// Error responses observed by all DMA engines (B + R beats).
    pub err_resps: u64,
    /// Per-cluster tags of completed-but-errored DMA jobs, sorted.
    pub error_tags: Vec<Vec<u64>>,
    /// Per-cluster tag sets a total fault (stall / grant-hang) must
    /// error — empty vectors for drop faults and the healthy run.
    pub expected_tags: Vec<Vec<u64>>,
    /// Fabric-ledger occupancy after the run (all must be zero).
    pub resv_live: usize,
    pub resv_queued: usize,
    pub open_reductions: usize,
    pub open_cpl_legs: usize,
}

impl FaultRunResult {
    /// Completed jobs that saw at least one error response.
    pub fn errored_jobs(&self) -> usize {
        self.error_tags.iter().map(|t| t.len()).sum()
    }

    pub fn ledgers_drained(&self) -> bool {
        self.resv_live == 0
            && self.resv_queued == 0
            && self.open_reductions == 0
            && self.open_cpl_legs == 0
    }
}

/// Per-cluster command programs of the fault scenario (see the module
/// docs). `victim` is the faulted cluster's index; the schedule never
/// waits on an interrupt, so a dead victim cannot wedge it.
///
/// The two reductions go FIRST: every cluster's DMA queue is serial,
/// so leading with them makes all contributors of a group arrive at
/// the join points within a handful of cycles of each other — the
/// collecting-state eviction deadline then cannot fire on a *healthy*
/// group merely because a sibling cluster was stuck unwinding an
/// earlier faulted job.
fn fault_programs(cfg: &SocConfig, victim: usize, bytes: u64) -> Vec<Vec<Cmd>> {
    let n = cfg.n_clusters;
    let mut progs: Vec<Vec<Cmd>> = vec![Vec::new(); n];
    for (r, p) in progs.iter_mut().enumerate() {
        // reduction converging on healthy cluster 0 (group 0)
        p.push(Cmd::DmaReduce {
            src: cfg.cluster_base(r) + SRC,
            dst: cfg.cluster_base(0) + RED_ACC,
            bytes,
            tag: TAG_RED_OK + r as u64,
            group: 0,
            op: ReduceOp::Sum,
        });
        // reduction converging on the victim (group 1) — under a total
        // fault the combined burst's completion times out and SLVERR
        // fans back to every fabric contributor
        p.push(Cmd::DmaReduce {
            src: cfg.cluster_base(r) + SRC,
            dst: cfg.cluster_base(victim) + RED_ACC_V,
            bytes,
            tag: TAG_RED_V + r as u64,
            group: 1,
            op: ReduceOp::Sum,
        });
        // concurrent global multicast: rank r's chunk into every
        // cluster's MC_LAND slot r (the victim is one fork leg)
        p.push(Cmd::Dma {
            src: cfg.cluster_base(r) + SRC,
            dst: cfg.cluster_set(0, n, MC_LAND + r as u64 * bytes),
            bytes,
            tag: TAG_MCAST + r as u64,
        });
        // unicast to a healthy neighbour — the clean control
        let mut nb = (r + 1) % n;
        if nb == victim {
            nb = (r + 2) % n;
        }
        if nb != r {
            p.push(Cmd::Dma {
                src: cfg.cluster_base(r) + SRC,
                dst: AddrSet::unicast(cfg.cluster_base(nb) + UNI_LAND + r as u64 * bytes),
                bytes,
                tag: TAG_CLEAN + r as u64,
            });
        }
        // unicast write at the victim (local copy when r == victim)
        p.push(Cmd::Dma {
            src: cfg.cluster_base(r) + SRC,
            dst: AddrSet::unicast(cfg.cluster_base(victim) + UNI_LAND + r as u64 * bytes),
            bytes,
            tag: TAG_VWRITE + r as u64,
        });
        // read from the victim's L1 (local copy when r == victim)
        p.push(Cmd::Dma {
            src: cfg.cluster_base(victim) + SRC,
            dst: AddrSet::unicast(cfg.cluster_base(r) + RD_LAND),
            bytes,
            tag: TAG_VREAD + r as u64,
        });
        p.push(Cmd::WaitDma);
    }
    progs
}

/// Is `tag` in one of the victim-touching tag families? (Everything a
/// fault is *allowed* to error; the clean and healthy-reduction
/// families must never appear in an error set.)
fn tag_touches_victim(tag: u64, rank: u64) -> bool {
    [TAG_MCAST, TAG_VWRITE, TAG_VREAD, TAG_RED_V]
        .iter()
        .any(|&base| tag == base + rank)
}

/// Tags a *total* victim fault (stall / grant-hang) must error, per
/// cluster: everything whose transaction traverses the fabric to the
/// victim. The victim's own writes/reads at itself are local copies
/// (no fabric traffic, clean), and its group-1 contribution is the
/// destination-local accumulate the membership oracle keeps out of the
/// fabric plan — but its own global multicast forks back into its own
/// dead slave port, so that one errors even for the victim.
fn total_fault_expected(n: usize, victim: usize) -> Vec<Vec<u64>> {
    (0..n)
        .map(|r| {
            let mut t = vec![TAG_MCAST + r as u64];
            if r != victim {
                t.extend([
                    TAG_VWRITE + r as u64,
                    TAG_VREAD + r as u64,
                    TAG_RED_V + r as u64,
                ]);
            }
            t.sort_unstable();
            t
        })
        .collect()
}

/// Run one fault scenario: `kind = None` is the healthy baseline (must
/// be error-free), otherwise `kind.plan()` is installed on cluster
/// `victim`'s L1 slave port. Timeouts are always armed; the run must
/// complete without the watchdog firing.
pub fn run_fault_scenario(
    cfg: &SocConfig,
    kind: Option<FaultKind>,
    victim: usize,
    bytes: u64,
) -> FaultRunResult {
    let mut cfg = cfg.clone();
    let n = cfg.n_clusters;
    assert!(victim < n, "victim {victim} out of range ({n} clusters)");
    assert!(n >= 4, "the fault scenario needs >= 4 clusters");
    cfg.wide_mcast = true;
    cfg.narrow_mcast = true;
    cfg.e2e_mcast_order = true;
    cfg.fabric_reduce = true;
    // generous deadlines: far above the healthy worst-case service
    // time at this scale, far below the watchdog stall threshold
    cfg.req_timeout = Some(5_000);
    cfg.cpl_timeout = Some(2_000);
    cfg.faults = match kind {
        Some(k) => vec![(FaultSite::ClusterL1(victim), k.plan())],
        None => Vec::new(),
    };

    let mut soc = Soc::new(cfg.clone());
    let members: Vec<usize> = (0..n).collect();
    soc.open_reduce_group(0, ReduceOp::Sum, &members, cfg.cluster_base(0) + RED_ACC);
    soc.open_reduce_group(
        1,
        ReduceOp::Sum,
        &members,
        cfg.cluster_base(victim) + RED_ACC_V,
    );
    soc.load_programs(fault_programs(&cfg, victim, bytes));
    let cycles = soc
        .run(
            &mut NopCompute,
            Watchdog {
                stall_cycles: 100_000,
                max_cycles: 100_000_000,
            },
        )
        .unwrap_or_else(|e| {
            panic!(
                "fault scenario {} (victim {victim}, {n} clusters) did not recover: {e}",
                kind.map(|k| k.name()).unwrap_or("healthy"),
            )
        });

    let report = soc.deadlock_report();
    let mut error_tags: Vec<Vec<u64>> = soc
        .clusters
        .iter()
        .map(|c| c.dma_error_tags.clone())
        .collect();
    for t in &mut error_tags {
        t.sort_unstable();
    }
    let expected_tags = match kind {
        Some(k) if k.is_total() => total_fault_expected(n, victim),
        _ => vec![Vec::new(); n],
    };
    FaultRunResult {
        kind,
        victim,
        clusters: n,
        bytes,
        cycles,
        wide: soc.wide.stats_sum(),
        err_resps: soc.clusters.iter().map(|c| c.dma.stats.err_resps).sum(),
        error_tags,
        expected_tags,
        resv_live: report.resv_live_tickets,
        resv_queued: report.resv_queued_claims,
        open_reductions: report.open_reductions,
        open_cpl_legs: report.open_cpl_legs,
    }
}

/// Invariants every fault run must satisfy (shared by tests, the CLI
/// experiment and the fuzz harness).
pub fn assert_fault_run_invariants(r: &FaultRunResult) {
    let label = r.kind.map(|k| k.name()).unwrap_or("healthy");
    assert!(
        r.ledgers_drained(),
        "{label}: fabric ledgers not drained (resv {}/{}, reductions {}, cpl legs {})",
        r.resv_live,
        r.resv_queued,
        r.open_reductions,
        r.open_cpl_legs
    );
    // fork/join accounting extended by the timeout unwinding terms
    assert_eq!(
        r.wide.w_beats_out,
        r.wide.w_beats_in + r.wide.w_fork_extra - r.wide.red_beats_saved - r.wide.w_dropped,
        "{label}: W fork/join/drop accounting broken"
    );
    match r.kind {
        None => {
            assert_eq!(r.errored_jobs(), 0, "{label}: spurious DMA errors");
            assert_eq!(r.err_resps, 0, "{label}: spurious error responses");
            assert_eq!(
                r.wide.req_timeouts + r.wide.cpl_timeouts,
                0,
                "{label}: deadlines fired on healthy traffic"
            );
            assert!(r.wide.aw_mcast >= r.clusters as u64, "{label}: no multicast ran");
            assert!(r.wide.red_joins > 0, "{label}: no in-network reduction ran");
        }
        Some(k) => {
            // no fault may ever error a transaction that avoids the
            // victim: the clean-neighbour and healthy-reduction
            // families must stay out of every error set
            for (rank, tags) in r.error_tags.iter().enumerate() {
                for &t in tags {
                    assert!(
                        tag_touches_victim(t, rank as u64),
                        "{label}: cluster {rank} errored non-victim tag {t}"
                    );
                }
            }
            assert!(r.errored_jobs() > 0, "{label}: fault left no trace");
            assert!(
                r.wide.cpl_timeouts > 0,
                "{label}: no completion deadline fired"
            );
            if k.is_total() {
                assert_eq!(
                    r.error_tags, r.expected_tags,
                    "{label}: errored tag sets diverge from the faulted-transaction set"
                );
            } else {
                // one swallowed completion: either a single job (a
                // dropped unicast B / R burst) or — when the dropped B
                // belonged to a combined reduction burst — the
                // synthesized SLVERR fans back to every fabric
                // contributor of that one transaction
                let n = r.errored_jobs();
                assert!(
                    n == 1 || n == r.clusters - 1,
                    "{label}: one dropped beat errored {n} jobs ({:?})",
                    r.error_tags
                );
                assert_eq!(r.wide.req_timeouts, 0, "{label}: spurious request timeouts");
            }
        }
    }
}

// ---- QoS under serving load ----

/// One QoS run: every cluster except the destination streams unicast
/// write jobs at cluster 0; `done_at[r]` is the cycle cluster `r`'s
/// program completed.
#[derive(Debug, Clone)]
pub struct QosResult {
    pub policy: ArbPolicy,
    /// The elevated-priority cluster (`qos_prio[hot] > 0` when the
    /// policy is `Priority`).
    pub hot: usize,
    pub clusters: usize,
    pub jobs: usize,
    pub bytes: u64,
    pub cycles: u64,
    pub done_at: Vec<u64>,
    pub wide: XbarStats,
}

impl QosResult {
    pub fn policy_name(&self) -> String {
        match self.policy {
            ArbPolicy::RoundRobin => "round-robin".to_string(),
            ArbPolicy::Priority { aging } => format!("priority(aging={aging})"),
        }
    }

    pub fn hot_done(&self) -> u64 {
        self.done_at[self.hot]
    }

    /// Mean completion cycle of the background senders (excluding the
    /// hot cluster and the destination).
    pub fn rest_mean(&self) -> f64 {
        let rest: Vec<u64> = self.rest_done();
        rest.iter().sum::<u64>() as f64 / rest.len() as f64
    }

    pub fn rest_max(&self) -> u64 {
        self.rest_done().into_iter().max().unwrap_or(0)
    }

    fn rest_done(&self) -> Vec<u64> {
        (1..self.clusters)
            .filter(|&r| r != self.hot)
            .map(|r| self.done_at[r])
            .collect()
    }
}

/// Run the serving-load pattern under one arbitration policy. Cluster
/// 0 is the served destination (idle program); clusters `1..n` each
/// issue `jobs` unicast writes of `bytes` into their own slice of
/// cluster 0's L1, all at once — a many-to-one hotspot whose grant
/// order the arbiters decide. With [`ArbPolicy::Priority`], cluster
/// `hot` gets `qos_prio = 8` and everyone else 0.
pub fn run_qos_load(
    cfg: &SocConfig,
    policy: ArbPolicy,
    hot: usize,
    jobs: usize,
    bytes: u64,
) -> QosResult {
    let mut cfg = cfg.clone();
    let n = cfg.n_clusters;
    assert!(n >= 4, "the QoS load pattern needs >= 4 clusters");
    assert!(hot >= 1 && hot < n, "hot cluster must be a sender (1..{n})");
    cfg.fabric_arb = policy;
    cfg.qos_prio = match policy {
        ArbPolicy::RoundRobin => Vec::new(),
        ArbPolicy::Priority { .. } => {
            let mut p = vec![0u32; n];
            p[hot] = 8;
            p
        }
    };
    let mut progs: Vec<Vec<Cmd>> = vec![Vec::new(); n];
    for (r, p) in progs.iter_mut().enumerate().skip(1) {
        for j in 0..jobs {
            p.push(Cmd::Dma {
                src: cfg.cluster_base(r) + SRC,
                dst: AddrSet::unicast(
                    cfg.cluster_base(0) + UNI_LAND + ((r - 1) * jobs + j) as u64 * bytes,
                ),
                bytes,
                tag: (r * jobs + j) as u64,
            });
        }
        p.push(Cmd::WaitDma);
    }
    let mut soc = Soc::new(cfg.clone());
    soc.load_programs(progs);
    let cycles = soc
        .run(
            &mut NopCompute,
            Watchdog {
                stall_cycles: 200_000,
                max_cycles: 100_000_000,
            },
        )
        .unwrap_or_else(|e| panic!("QoS load run ({n} clusters): {e}"));
    QosResult {
        policy,
        hot,
        clusters: n,
        jobs,
        bytes,
        cycles,
        done_at: soc
            .clusters
            .iter()
            .map(|c| c.done_at.unwrap_or(cycles))
            .collect(),
        wide: soc.wide.stats_sum(),
    }
}

/// Invariants of a round-robin / priority result pair on the same load
/// (shared by tests and the CLI experiment): priority must actually
/// grant, must not slow the hot cluster down relative to round-robin,
/// and must serve the hot cluster no later than the background mean —
/// while aging guarantees the background still completes (the run
/// finishing at all proves no starvation; the bound itself is
/// unit-tested at the crossbar level).
pub fn assert_qos_invariants(rr: &QosResult, prio: &QosResult) {
    assert_eq!(rr.wide.prio_grants, 0, "round-robin must not prio-grant");
    assert!(prio.wide.prio_grants > 0, "priority arbiters never granted");
    assert!(
        prio.hot_done() <= rr.hot_done(),
        "priority made the hot cluster slower ({} > {})",
        prio.hot_done(),
        rr.hot_done()
    );
    assert!(
        (prio.hot_done() as f64) <= prio.rest_mean(),
        "hot cluster ({}) finished after the background mean ({:.0})",
        prio.hot_done(),
        prio.rest_mean()
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    const BYTES: u64 = 512;

    #[test]
    fn healthy_baseline_is_error_free() {
        let r = run_fault_scenario(&SocConfig::tiny(4), None, 2, BYTES);
        assert_fault_run_invariants(&r);
        assert!(r.cycles > 0);
    }

    #[test]
    fn stalled_slave_errors_exactly_the_faulted_transactions() {
        let r = run_fault_scenario(&SocConfig::tiny(4), Some(FaultKind::Stall), 2, BYTES);
        assert_fault_run_invariants(&r);
        // queued-behind requests may also DECERR; the SLVERR path must
        // have fired for the granted-then-dead legs
        assert!(r.wide.cpl_timeouts > 0);
        assert!(r.err_resps > 0);
    }

    #[test]
    fn grant_hang_recovers_via_completion_deadline() {
        let r = run_fault_scenario(&SocConfig::tiny(4), Some(FaultKind::GrantHang), 1, BYTES);
        assert_fault_run_invariants(&r);
    }

    #[test]
    fn dropped_completions_error_one_job_each() {
        for k in [FaultKind::DropB, FaultKind::DropR] {
            let r = run_fault_scenario(&SocConfig::tiny(4), Some(k), 3, BYTES);
            assert_fault_run_invariants(&r);
        }
    }

    #[test]
    fn qos_priority_pulls_hot_cluster_ahead() {
        let cfg = SocConfig::tiny(8);
        let rr = run_qos_load(&cfg, ArbPolicy::RoundRobin, 4, 4, 2048);
        let prio = run_qos_load(&cfg, ArbPolicy::Priority { aging: 64 }, 4, 4, 2048);
        assert_qos_invariants(&rr, &prio);
    }
}

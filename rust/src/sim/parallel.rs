//! Conservative lookahead-1 parallel stepping substrate (§Perf).
//!
//! The sequential engine steps every component once per cycle against
//! staged links, so a push in cycle *k* is visible in cycle *k+1* and —
//! with registered ready semantics ([`Chan::can_push`]) and
//! order-independent shared state (per-source transaction tags,
//! atomically-partitioned ledgers) — the within-cycle component order
//! cannot influence any outcome. That is exactly a lookahead of one
//! cycle: every component's cycle-*k* step depends only on state sealed
//! at the cycle-*k* clock edge, so disjoint component subsets may step
//! **concurrently** and merge at a barrier, bit-identically to the
//! sequential schedule (`tests/parallel_parity.rs`).
//!
//! This module is the graph-agnostic machinery:
//!
//! * [`Atom`]/[`partition`]: deterministic greedy partitioning of
//!   component atoms across shards by link affinity (minimise cut
//!   links), honouring pre-pinned atoms;
//! * [`LinkHome`]/[`split_pool`]/[`merge_pools`]/[`tick_link`]: the
//!   link distribution. Every shard carries a **full-size** pool so
//!   `LinkId`s stay valid; a link whose endpoints land on one shard
//!   lives there whole, a link crossing shards is split into its two
//!   directional halves ([`CutLink::split_cut`]) with the clock edge
//!   bridging them at the merge barrier ([`CutLink::tick_cut`]);
//! * [`WorkerPool`]: persistent worker threads driven by ownership
//!   ping-pong — each cycle the coordinator sends every shard to its
//!   worker and collects it back, so between cycles the coordinator
//!   owns all state (merge, horizon checks, functional side effects)
//!   with no locks on the hot path.
//!
//! Drivers (the SoC's `run_parallel`, the topology harness) own the
//! cycle loop; see DESIGN.md §8 for the correctness argument.
//!
//! [`Chan::can_push`]: super::chan::Chan::can_push

use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;

use super::link::{Link, LinkId, Pool};

/// A link that can be split into a master half (request-producer /
/// response-consumer) and a slave half for cross-shard placement.
/// The halves partition the link's queues and counters: any state
/// query summed or OR-ed over both halves equals the whole link's.
pub trait CutLink: Link + Send + Sized + 'static {
    /// Split into `(master half, slave half)`.
    fn split_cut(self) -> (Self, Self);
    /// Clock edge across a split pair (staged→visible both ways).
    fn tick_cut(master: &mut Self, slave: &mut Self);
    /// Reassemble; inverse of [`CutLink::split_cut`].
    fn join_cut(master: Self, slave: Self) -> Self;
    /// Filler for pool slots owned by other shards (never touched).
    fn dummy() -> Self;
}

/// Where a link lives across the shard pools.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkHome {
    /// Both endpoints on one shard: the whole link lives there.
    Owned(usize),
    /// Endpoints on two shards: master half on `m`, slave half on `s`.
    Cut { m: usize, s: usize },
}

/// One indivisible unit of the component graph for partitioning: a
/// component (or component group whose internal step order must be
/// preserved, e.g. a reservation-armed crossbar network) plus its
/// ports, each flagged with the side the atom plays on that link.
pub struct Atom {
    /// `(link, atom_is_master_side)` — the master side of a link sends
    /// requests into it (AW/W/AR) and consumes responses (B/R).
    pub ports: Vec<(LinkId, bool)>,
    /// Fixed shard assignment (load anchors, e.g. clusters spread in
    /// contiguous index blocks). `None` = placed greedily.
    pub pin: Option<usize>,
}

/// Deterministic greedy partition: pinned atoms first, then the rest
/// in index order, each placed on the shard sharing the most links
/// with it (ties: lighter shard, then lower shard id). Returns the
/// shard index per atom.
pub fn partition(atoms: &[Atom], n_shards: usize) -> Vec<usize> {
    assert!(n_shards >= 1);
    let mut assign = vec![usize::MAX; atoms.len()];
    let mut load = vec![0usize; n_shards];
    let mut shard_links: Vec<std::collections::HashSet<u32>> =
        (0..n_shards).map(|_| std::collections::HashSet::new()).collect();
    let mut place = |i: usize,
                     sh: usize,
                     assign: &mut Vec<usize>,
                     load: &mut Vec<usize>,
                     shard_links: &mut Vec<std::collections::HashSet<u32>>| {
        assign[i] = sh;
        load[sh] += 1;
        for &(id, _) in &atoms[i].ports {
            shard_links[sh].insert(id.index() as u32);
        }
    };
    for (i, a) in atoms.iter().enumerate() {
        if let Some(p) = a.pin {
            assert!(p < n_shards, "pin {p} out of range");
            place(i, p, &mut assign, &mut load, &mut shard_links);
        }
    }
    for (i, a) in atoms.iter().enumerate() {
        if assign[i] != usize::MAX {
            continue;
        }
        let mut best = 0usize;
        let mut best_key = (0i64, i64::MIN);
        for sh in 0..n_shards {
            let aff = a
                .ports
                .iter()
                .filter(|(id, _)| shard_links[sh].contains(&(id.index() as u32)))
                .count() as i64;
            let key = (aff, -(load[sh] as i64));
            if key > best_key {
                best_key = key;
                best = sh;
            }
        }
        place(i, best, &mut assign, &mut load, &mut shard_links);
    }
    assign
}

/// Derive each link's [`LinkHome`] from the atom assignment. A link
/// may have at most one master-side and one slave-side atom; a link
/// only one of whose sides is stepped at all (e.g. the injection port
/// of an endpoint no scripted master drives) is owned whole by the
/// side that is present, and a link nobody steps parks on shard 0.
pub fn link_homes(atoms: &[Atom], assign: &[usize], n_links: usize) -> Vec<LinkHome> {
    let mut master = vec![usize::MAX; n_links];
    let mut slave = vec![usize::MAX; n_links];
    for (ai, a) in atoms.iter().enumerate() {
        for &(id, is_m) in &a.ports {
            let side = if is_m { &mut master } else { &mut slave };
            let slot = &mut side[id.index()];
            assert_eq!(*slot, usize::MAX, "link {id:?}: duplicate side registration");
            *slot = ai;
        }
    }
    (0..n_links)
        .map(|i| match (master[i], slave[i]) {
            (usize::MAX, usize::MAX) => LinkHome::Owned(0),
            (usize::MAX, sa) => LinkHome::Owned(assign[sa]),
            (ma, usize::MAX) => LinkHome::Owned(assign[ma]),
            (ma, sa) => {
                let (m, s) = (assign[ma], assign[sa]);
                if m == s {
                    LinkHome::Owned(m)
                } else {
                    LinkHome::Cut { m, s }
                }
            }
        })
        .collect()
}

/// Distribute a pool across `n_shards` full-size shard pools: owned
/// links move whole, cut links are split, all other slots get dummies.
pub fn split_pool<L: CutLink>(pool: Pool<L>, homes: &[LinkHome], n_shards: usize) -> Vec<Pool<L>> {
    let links = pool.into_links();
    assert_eq!(links.len(), homes.len());
    let n = links.len();
    let mut shard_links: Vec<Vec<L>> = (0..n_shards)
        .map(|_| (0..n).map(|_| L::dummy()).collect())
        .collect();
    for (i, l) in links.into_iter().enumerate() {
        match homes[i] {
            LinkHome::Owned(sh) => shard_links[sh][i] = l,
            LinkHome::Cut { m, s } => {
                debug_assert_ne!(m, s);
                let (mh, sh) = l.split_cut();
                shard_links[m][i] = mh;
                shard_links[s][i] = sh;
            }
        }
    }
    shard_links.into_iter().map(Pool::from_links).collect()
}

/// Reassemble the original pool from the shard pools (inverse of
/// [`split_pool`]; dummies are dropped).
pub fn merge_pools<L: CutLink>(pools: Vec<Pool<L>>, homes: &[LinkHome]) -> Pool<L> {
    let mut vecs: Vec<Vec<L>> = pools.into_iter().map(Pool::into_links).collect();
    let mut out = Vec::with_capacity(homes.len());
    for (i, home) in homes.iter().enumerate() {
        let take = |vecs: &mut Vec<Vec<L>>, sh: usize| std::mem::replace(&mut vecs[sh][i], L::dummy());
        match *home {
            LinkHome::Owned(sh) => out.push(take(&mut vecs, sh)),
            LinkHome::Cut { m, s } => {
                let mh = take(&mut vecs, m);
                let sh = take(&mut vecs, s);
                out.push(L::join_cut(mh, sh));
            }
        }
    }
    Pool::from_links(out)
}

/// Clock edge for one link across the shard pools; returns whether the
/// link has visible beats afterwards. Plugs into
/// [`Scheduler::end_cycle_with`] on the master scheduler.
///
/// [`Scheduler::end_cycle_with`]: super::sched::Scheduler::end_cycle_with
pub fn tick_link<L: CutLink>(pools: &mut [&mut Pool<L>], homes: &[LinkHome], id: LinkId) -> bool {
    match homes[id.index()] {
        LinkHome::Owned(sh) => {
            let l = &mut pools[sh][id];
            l.tick();
            l.any_visible()
        }
        LinkHome::Cut { m, s } => {
            let (mp, sp) = two_of(pools, m, s);
            let (mh, sh) = (&mut mp[id], &mut sp[id]);
            L::tick_cut(mh, sh);
            mh.any_visible() || sh.any_visible()
        }
    }
}

/// Disjoint mutable access to two slots of a slice of borrows.
fn two_of<'a, T: ?Sized>(v: &'a mut [&mut T], i: usize, j: usize) -> (&'a mut T, &'a mut T) {
    assert_ne!(i, j);
    if i < j {
        let (a, b) = v.split_at_mut(j);
        (&mut *a[i], &mut *b[0])
    } else {
        let (a, b) = v.split_at_mut(i);
        (&mut *b[0], &mut *a[j])
    }
}

/// The per-shard step function a [`WorkerPool`] runs each cycle.
pub type StepFn<S> = Arc<dyn Fn(&mut S, u64) + Send + Sync>;

/// Persistent worker threads, one per shard, driven by ownership
/// ping-pong: [`WorkerPool::step_all`] sends each shard to its worker
/// and collects it back in slot order, so results are deterministic
/// and the coordinator owns every shard between cycles.
pub struct WorkerPool<S: Send + 'static> {
    workers: Vec<Worker<S>>,
}

struct Worker<S> {
    job_tx: Option<mpsc::Sender<(S, u64)>>,
    done_rx: mpsc::Receiver<S>,
    handle: Option<JoinHandle<()>>,
}

impl<S: Send + 'static> WorkerPool<S> {
    pub fn new(n: usize, step: StepFn<S>) -> WorkerPool<S> {
        let workers = (0..n)
            .map(|_| {
                let (job_tx, job_rx) = mpsc::channel::<(S, u64)>();
                let (done_tx, done_rx) = mpsc::channel::<S>();
                let step = Arc::clone(&step);
                let handle = std::thread::spawn(move || {
                    while let Ok((mut s, cy)) = job_rx.recv() {
                        step(&mut s, cy);
                        if done_tx.send(s).is_err() {
                            break;
                        }
                    }
                });
                Worker {
                    job_tx: Some(job_tx),
                    done_rx,
                    handle: Some(handle),
                }
            })
            .collect();
        WorkerPool { workers }
    }

    pub fn len(&self) -> usize {
        self.workers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.workers.is_empty()
    }

    /// Step every shard concurrently for cycle `cy`; blocks until all
    /// workers finish and returns the shards in their original order.
    pub fn step_all(&mut self, shards: Vec<S>, cy: u64) -> Vec<S> {
        assert_eq!(shards.len(), self.workers.len());
        for (w, s) in self.workers.iter().zip(shards) {
            w.job_tx
                .as_ref()
                .expect("worker pool shut down")
                .send((s, cy))
                .expect("worker thread died");
        }
        self.workers
            .iter()
            .map(|w| w.done_rx.recv().expect("worker thread died"))
            .collect()
    }
}

impl<S: Send + 'static> Drop for WorkerPool<S> {
    fn drop(&mut self) {
        for w in &mut self.workers {
            w.job_tx.take(); // hang up: workers exit their recv loop
        }
        for w in &mut self.workers {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimal cut-capable link: one forward (master→slave) and one
    /// reverse pipe, each a staged counter draining into a visible one.
    #[derive(Default, Debug, PartialEq, Eq)]
    struct FakeCut {
        fwd_staged: u32,
        fwd_q: u32,
        rev_staged: u32,
        rev_q: u32,
        popped: u64,
    }

    impl Link for FakeCut {
        fn tick(&mut self) {
            self.fwd_q += self.fwd_staged;
            self.fwd_staged = 0;
            self.rev_q += self.rev_staged;
            self.rev_staged = 0;
        }
        fn any_visible(&self) -> bool {
            self.fwd_q > 0 || self.rev_q > 0
        }
        fn is_idle(&self) -> bool {
            self.fwd_staged == 0 && self.fwd_q == 0 && self.rev_staged == 0 && self.rev_q == 0
        }
        fn moved(&self) -> u64 {
            self.popped
        }
    }

    impl CutLink for FakeCut {
        fn split_cut(self) -> (FakeCut, FakeCut) {
            let master = FakeCut {
                fwd_staged: self.fwd_staged,
                rev_q: self.rev_q,
                ..Default::default()
            };
            let slave = FakeCut {
                fwd_q: self.fwd_q,
                rev_staged: self.rev_staged,
                popped: self.popped,
                ..Default::default()
            };
            (master, slave)
        }
        fn tick_cut(master: &mut FakeCut, slave: &mut FakeCut) {
            slave.fwd_q += master.fwd_staged;
            master.fwd_staged = 0;
            master.rev_q += slave.rev_staged;
            slave.rev_staged = 0;
        }
        fn join_cut(master: FakeCut, slave: FakeCut) -> FakeCut {
            FakeCut {
                fwd_staged: master.fwd_staged,
                fwd_q: slave.fwd_q,
                rev_staged: slave.rev_staged,
                rev_q: master.rev_q,
                popped: master.popped + slave.popped,
            }
        }
        fn dummy() -> FakeCut {
            FakeCut::default()
        }
    }

    fn atom(links: &[(u32, bool)], pin: Option<usize>) -> Atom {
        Atom {
            ports: links.iter().map(|&(i, m)| (LinkId::from_index(i as usize), m)).collect(),
            pin,
        }
    }

    #[test]
    fn partition_honours_pins_and_affinity() {
        // atoms 0/1 pinned apart; atom 2 shares both its links with
        // atom 1 → must follow it to shard 1
        let atoms = vec![
            atom(&[(0, true)], Some(0)),
            atom(&[(1, false), (2, false)], Some(1)),
            atom(&[(1, true), (2, true)], None),
        ];
        let assign = partition(&atoms, 2);
        assert_eq!(assign, vec![0, 1, 1]);
        // deterministic across calls
        assert_eq!(assign, partition(&atoms, 2));
    }

    #[test]
    fn partition_balances_when_no_affinity() {
        let atoms: Vec<Atom> = (0..4).map(|i| atom(&[(i, true)], None)).collect();
        let assign = partition(&atoms, 2);
        // no shared links: ties break toward the lighter shard
        assert_eq!(assign.iter().filter(|&&s| s == 0).count(), 2);
        assert_eq!(assign.iter().filter(|&&s| s == 1).count(), 2);
    }

    #[test]
    fn link_homes_distinguish_owned_and_cut() {
        let atoms = vec![
            atom(&[(0, true), (1, true)], Some(0)),
            atom(&[(0, false)], Some(0)),
            atom(&[(1, false), (2, false)], Some(1)),
        ];
        let assign = partition(&atoms, 2);
        // 4 links, the last stepped by nobody (parks whole on shard 0);
        // link 2 is consumed on shard 1 but has no master-side atom —
        // it lives whole with its only user
        let homes = link_homes(&atoms, &assign, 4);
        assert_eq!(homes[0], LinkHome::Owned(0));
        assert_eq!(homes[1], LinkHome::Cut { m: 0, s: 1 });
        assert_eq!(homes[2], LinkHome::Owned(1));
        assert_eq!(homes[3], LinkHome::Owned(0));
    }

    #[test]
    fn split_tick_merge_matches_whole_pool() {
        // reference: two whole links stepped sequentially
        let mut whole: Pool<FakeCut> = Pool::new();
        let a = whole.alloc(FakeCut::default());
        let b = whole.alloc(FakeCut::default());
        // shadow: link a owned by shard 0, link b cut between 0 and 1
        let homes = vec![LinkHome::Owned(0), LinkHome::Cut { m: 0, s: 1 }];
        let mut split: Pool<FakeCut> = Pool::new();
        split.alloc(FakeCut::default());
        split.alloc(FakeCut::default());
        let mut pools = split_pool(split, &homes, 2);

        for cy in 0..6u32 {
            // producers stage on both sides; consumers drain visibles
            for (i, id) in [a, b].into_iter().enumerate() {
                // whole
                let l = &mut whole[id];
                l.fwd_staged += cy + i as u32;
                l.rev_staged += 1;
                l.popped += (l.fwd_q + l.rev_q) as u64;
                l.fwd_q = 0;
                l.rev_q = 0;
                // split halves: producer state lives master-side for
                // fwd, slave-side for rev; consumers on the opposite
                match homes[i] {
                    LinkHome::Owned(sh) => {
                        let l = &mut pools[sh][id];
                        l.fwd_staged += cy + i as u32;
                        l.rev_staged += 1;
                        l.popped += (l.fwd_q + l.rev_q) as u64;
                        l.fwd_q = 0;
                        l.rev_q = 0;
                    }
                    LinkHome::Cut { m, s } => {
                        pools[m][id].fwd_staged += cy + i as u32;
                        pools[s][id].rev_staged += 1;
                        let sl = &mut pools[s][id];
                        sl.popped += sl.fwd_q as u64;
                        sl.fwd_q = 0;
                        let ml = &mut pools[m][id];
                        ml.popped += ml.rev_q as u64;
                        ml.rev_q = 0;
                    }
                }
            }
            // clock edges
            whole[a].tick();
            whole[b].tick();
            let mut refs: Vec<&mut Pool<FakeCut>> = pools.iter_mut().collect();
            let va = tick_link(&mut refs, &homes, a);
            let vb = tick_link(&mut refs, &homes, b);
            assert_eq!(va, whole[a].any_visible(), "cycle {cy} link a");
            assert_eq!(vb, whole[b].any_visible(), "cycle {cy} link b");
        }
        let moved_split: u64 = pools.iter().map(|p| p.moved_total()).sum();
        assert_eq!(moved_split, whole.moved_total());
        let merged = merge_pools(pools, &homes);
        assert_eq!(merged[a], whole[a]);
        assert_eq!(merged[b], whole[b]);
    }

    #[test]
    fn worker_pool_preserves_slot_order() {
        let step: StepFn<Vec<u64>> = Arc::new(|s: &mut Vec<u64>, cy: u64| {
            let tag = s[0];
            s.push(tag * 1000 + cy);
        });
        let mut wp = WorkerPool::new(3, step);
        assert_eq!(wp.len(), 3);
        let mut shards: Vec<Vec<u64>> = (0..3u64).map(|i| vec![i]).collect();
        for cy in 0..5u64 {
            shards = wp.step_all(shards, cy);
        }
        for (i, s) in shards.iter().enumerate() {
            let i = i as u64;
            assert_eq!(s[0], i, "slot order lost");
            assert_eq!(s[1..], (0..5).map(|cy| i * 1000 + cy).collect::<Vec<_>>()[..]);
        }
    }

    #[test]
    fn two_of_returns_disjoint_slots() {
        let mut x = 1u32;
        let mut y = 2u32;
        let mut v: Vec<&mut u32> = vec![&mut x, &mut y];
        {
            let (a, b) = two_of(&mut v, 1, 0);
            assert_eq!((*a, *b), (2, 1));
            *a += 10;
            *b += 20;
        }
        assert_eq!((x, y), (21, 12));
    }
}

//! Staged, bounded, single-producer single-consumer channel modelling a
//! registered valid/ready handshake FIFO.
//!
//! * `push` stages an item; it becomes poppable only after the next
//!   [`Chan::tick`] (one-cycle latency, like a register slice).
//! * Capacity bounds the total occupancy (queued + staged), modelling
//!   FIFO depth / backpressure: `can_push` is the producer-visible
//!   `ready`, **registered**: it reflects the space as of the last
//!   clock edge minus this cycle's own pushes. A same-cycle pop by the
//!   consumer frees space only after the next tick — exactly the ready
//!   a registered AXI slice presents, and the property that makes the
//!   producer and consumer ends steppable on different threads within
//!   a cycle (DESIGN.md §8).
//! * `stale_space` exposes the occupancy as of the last tick — the
//!   registered ready the RTL fork/join logic sees (one cycle stale).
//! * [`Chan::split_cut`]/[`Chan::tick_cut`]/[`Chan::join_cut`] split a
//!   channel into an independent producer half (staged + registered
//!   space) and consumer half (visible queue) for links crossing a
//!   thread-partition boundary; `tick_cut` is the clock edge across
//!   the two halves and is bit-equivalent to `tick` on a whole channel.
//! * [`Chan::with_d2d`] models a die-to-die hop: `latency > 1` inserts
//!   a delay pipe between the staging register and the visible queue
//!   (a beat pushed at cycle `t` becomes visible at `t + latency`),
//!   and `rate > 1` serializes the narrow physical lanes — after a
//!   push, `can_push` stays false for `rate - 1` further cycles. Both
//!   default to 1, in which case every path below is bit-identical to
//!   the plain registered channel.

use std::collections::VecDeque;

#[derive(Debug, Clone)]
pub struct Chan<T> {
    q: VecDeque<T>,
    staged: VecDeque<T>,
    /// In-flight delay-pipe beats `(remaining ticks, item)`; only
    /// non-empty when `latency > 1`. FIFO: entries age uniformly, so
    /// the matured prefix is always the front.
    pipe: VecDeque<(u32, T)>,
    cap: usize,
    space_at_tick: usize,
    /// Delivery latency in cycles (>= 1; 1 = plain registered slice).
    latency: u32,
    /// Beat-serialization ratio (>= 1; 1 = full-width, no throttle).
    rate: u32,
    /// Cycles until the serializer frees the lanes for the next push.
    cooldown: u32,
    /// Total items ever pushed (throughput accounting).
    pub pushed: u64,
    /// Total items ever popped.
    pub popped: u64,
}

impl<T> Chan<T> {
    pub fn new(cap: usize) -> Chan<T> {
        Chan::with_d2d(cap, 1, 1)
    }

    /// A channel with D2D timing: `latency`-cycle delivery and one
    /// accepted push per `rate` cycles. `(1, 1)` is exactly
    /// [`Chan::new`].
    pub fn with_d2d(cap: usize, latency: u32, rate: u32) -> Chan<T> {
        assert!(cap >= 1);
        assert!(latency >= 1, "channel latency must be >= 1");
        assert!(rate >= 1, "serialization rate must be >= 1");
        Chan {
            q: VecDeque::new(),
            staged: VecDeque::new(),
            pipe: VecDeque::new(),
            cap,
            space_at_tick: cap,
            latency,
            rate,
            cooldown: 0,
            pushed: 0,
            popped: 0,
        }
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Occupancy (queued + in-flight + staged).
    pub fn len(&self) -> usize {
        self.q.len() + self.pipe.len() + self.staged.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Beats inside the delay pipe (pushed, not yet visible; always 0
    /// for `latency == 1` channels).
    pub fn in_flight(&self) -> usize {
        self.pipe.len()
    }

    /// Does the channel need clock edges to make progress on its own —
    /// in-flight delay-pipe beats maturing, or an armed serialization
    /// cooldown counting down? Links fold this into `any_visible` so
    /// the scheduler keeps ticking them, and into `is_idle` so
    /// `skip(k)` never fast-forwards across D2D in-flight state.
    pub fn needs_tick(&self) -> bool {
        !self.pipe.is_empty() || self.cooldown > 0
    }

    /// Idle for skip purposes: nothing queued, staged, in flight, and
    /// no cooldown still draining.
    pub fn idle(&self) -> bool {
        self.is_empty() && self.cooldown == 0
    }

    /// Producer-side ready: is there space to push this cycle?
    ///
    /// Registered: space as of the last tick minus items already staged
    /// this cycle. Same-cycle pops free space only at the next tick, so
    /// the answer never depends on whether the consumer stepped first —
    /// total occupancy stays bounded because the visible queue only
    /// shrinks between ticks (`q.len() + staged.len() ≤ q_at_tick +
    /// space_at_tick = cap`).
    pub fn can_push(&self) -> bool {
        self.cooldown == 0 && self.staged.len() < self.space_at_tick
    }

    /// Space as seen at the last clock edge (registered-ready modelling;
    /// conservative for fork logic that cannot see same-cycle pops).
    pub fn stale_space(&self) -> usize {
        self.space_at_tick
    }

    /// Stage an item for visibility next cycle. Panics on overflow —
    /// callers must check `can_push` (models a handshake violation).
    pub fn push(&mut self, item: T) {
        assert!(self.can_push(), "Chan overflow: push without ready");
        self.staged.push_back(item);
        self.pushed += 1;
        if self.rate > 1 {
            self.cooldown = self.rate;
        }
    }

    /// Consumer-side peek of the oldest *visible* item.
    pub fn front(&self) -> Option<&T> {
        self.q.front()
    }

    /// Pop the oldest visible item.
    pub fn pop(&mut self) -> Option<T> {
        let it = self.q.pop_front();
        if it.is_some() {
            self.popped += 1;
        }
        it
    }

    /// Number of currently visible (poppable) items.
    pub fn visible(&self) -> usize {
        self.q.len()
    }

    /// Clock edge: staged items become visible (or enter the delay
    /// pipe), matured in-flight beats become visible, the serializer
    /// cooldown counts down, and the ready snapshot updates.
    #[inline]
    pub fn tick(&mut self) {
        if self.latency == 1 {
            // fast path: the overwhelmingly common on-die channel
            if !self.staged.is_empty() {
                self.q.append(&mut self.staged);
            }
        } else {
            // age in-flight beats; the matured FIFO prefix delivers
            for e in self.pipe.iter_mut() {
                e.0 -= 1;
            }
            while self.pipe.front().is_some_and(|e| e.0 == 0) {
                self.q.push_back(self.pipe.pop_front().unwrap().1);
            }
            // this cycle's pushes enter the pipe un-aged: a beat
            // pushed at cycle t becomes visible at t + latency
            while let Some(it) = self.staged.pop_front() {
                self.pipe.push_back((self.latency - 1, it));
            }
        }
        if self.cooldown > 0 {
            self.cooldown -= 1;
        }
        self.space_at_tick = self.cap - self.q.len() - self.pipe.len();
    }

    /// Drop all contents (used by test harnesses between phases).
    pub fn clear(&mut self) {
        self.q.clear();
        self.staged.clear();
        self.pipe.clear();
        self.cooldown = 0;
        self.space_at_tick = self.cap;
    }

    // ---- cut-link support (sim::parallel) ----
    //
    // A channel crossing a thread-partition boundary is split into two
    // halves living in different shards: the producer half carries the
    // write-back state (staged items, the registered space snapshot,
    // the `pushed` counter), the consumer half the read-front state
    // (visible queue, `popped` counter). Because `can_push` is
    // registered and `pop`/`front` touch only the visible queue, each
    // half is completely self-contained within a cycle; `tick_cut` is
    // the clock edge across both.

    /// Split into `(producer half, consumer half)`. The delay pipe and
    /// serializer cooldown live on the producer half — `can_push`
    /// (registered space minus in-flight beats, cooldown) is entirely
    /// producer-side state, and `tick_cut` delivers matured beats into
    /// the consumer's visible queue at the shared clock edge.
    pub fn split_cut(self) -> (Chan<T>, Chan<T>) {
        let producer = Chan {
            q: VecDeque::new(),
            staged: self.staged,
            pipe: self.pipe,
            cap: self.cap,
            space_at_tick: self.space_at_tick,
            latency: self.latency,
            rate: self.rate,
            cooldown: self.cooldown,
            pushed: self.pushed,
            popped: 0,
        };
        let consumer = Chan {
            q: self.q,
            staged: VecDeque::new(),
            pipe: VecDeque::new(),
            cap: self.cap,
            space_at_tick: self.space_at_tick,
            latency: self.latency,
            rate: self.rate,
            cooldown: 0,
            pushed: 0,
            popped: self.popped,
        };
        (producer, consumer)
    }

    /// Clock edge across a split channel: staged items of the producer
    /// half become visible in the consumer half (via the producer-side
    /// delay pipe when `latency > 1`), and both halves get the fresh
    /// registered-space snapshot. Bit-equivalent to [`Chan::tick`] on
    /// the joined channel.
    pub fn tick_cut(producer: &mut Chan<T>, consumer: &mut Chan<T>) {
        debug_assert_eq!(producer.cap, consumer.cap);
        debug_assert_eq!(producer.latency, consumer.latency);
        if producer.latency == 1 {
            if !producer.staged.is_empty() {
                consumer.q.append(&mut producer.staged);
            }
        } else {
            for e in producer.pipe.iter_mut() {
                e.0 -= 1;
            }
            while producer.pipe.front().is_some_and(|e| e.0 == 0) {
                consumer.q.push_back(producer.pipe.pop_front().unwrap().1);
            }
            while let Some(it) = producer.staged.pop_front() {
                producer.pipe.push_back((producer.latency - 1, it));
            }
        }
        if producer.cooldown > 0 {
            producer.cooldown -= 1;
        }
        let space = producer.cap - consumer.q.len() - producer.pipe.len();
        producer.space_at_tick = space;
        consumer.space_at_tick = space;
    }

    /// Reassemble a split channel (inverse of [`Chan::split_cut`]).
    pub fn join_cut(producer: Chan<T>, consumer: Chan<T>) -> Chan<T> {
        debug_assert_eq!(producer.cap, consumer.cap);
        debug_assert!(consumer.staged.is_empty());
        debug_assert!(producer.q.is_empty());
        debug_assert!(consumer.pipe.is_empty());
        Chan {
            q: consumer.q,
            staged: producer.staged,
            pipe: producer.pipe,
            cap: producer.cap,
            space_at_tick: producer.space_at_tick,
            latency: producer.latency,
            rate: producer.rate,
            cooldown: producer.cooldown,
            pushed: producer.pushed,
            popped: consumer.popped,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_visible_next_tick() {
        let mut c: Chan<u32> = Chan::new(4);
        c.push(7);
        assert_eq!(c.front(), None, "staged items must not be visible");
        c.tick();
        assert_eq!(c.front(), Some(&7));
        assert_eq!(c.pop(), Some(7));
        assert_eq!(c.pop(), None);
    }

    #[test]
    fn capacity_bounds_total_occupancy() {
        let mut c: Chan<u32> = Chan::new(2);
        c.push(1);
        c.push(2);
        assert!(!c.can_push());
        c.tick();
        assert!(!c.can_push(), "queued items still occupy space");
        c.pop();
        assert!(
            !c.can_push(),
            "registered ready: a pop frees space only at the next tick"
        );
        c.tick();
        assert!(c.can_push());
    }

    #[test]
    fn ready_is_registered_against_same_cycle_pops() {
        let mut c: Chan<u32> = Chan::new(2);
        c.push(1);
        c.push(2);
        c.tick();
        // consumer drains the whole queue mid-cycle …
        assert_eq!(c.pop(), Some(1));
        assert_eq!(c.pop(), Some(2));
        // … but the producer's ready still reflects the clock edge
        assert!(!c.can_push());
        c.tick();
        assert!(c.can_push());
        c.push(3);
        assert!(c.can_push(), "one staged item against two spaces");
        c.push(4);
        assert!(!c.can_push());
    }

    #[test]
    #[should_panic(expected = "Chan overflow")]
    fn overflow_panics() {
        let mut c: Chan<u32> = Chan::new(1);
        c.push(1);
        c.push(2);
    }

    #[test]
    fn sustained_one_per_cycle() {
        // cap-2 chan with a consumer draining every cycle sustains
        // 1 item/cycle — the full-rate pipelined hop.
        let mut c: Chan<u64> = Chan::new(2);
        let mut got = Vec::new();
        for cy in 0..100u64 {
            if let Some(v) = c.pop() {
                got.push(v);
            }
            if c.can_push() {
                c.push(cy);
            }
            c.tick();
        }
        assert!(got.len() >= 98, "sustained rate broke: {}", got.len());
        for w in got.windows(2) {
            assert!(w[1] > w[0]);
        }
    }

    #[test]
    fn stale_space_lags_one_cycle() {
        let mut c: Chan<u32> = Chan::new(2);
        assert_eq!(c.stale_space(), 2);
        c.push(1);
        assert_eq!(c.stale_space(), 2, "stale view unchanged until tick");
        c.tick();
        assert_eq!(c.stale_space(), 1);
        c.pop();
        assert_eq!(c.stale_space(), 1, "pop not visible until tick");
        c.tick();
        assert_eq!(c.stale_space(), 2);
    }

    #[test]
    fn split_cut_matches_whole_channel_bit_for_bit() {
        // drive a whole channel and a split pair with the same
        // producer/consumer scripts; every observable must agree.
        let mut whole: Chan<u32> = Chan::new(2);
        let (mut prod, mut cons) = Chan::<u32>::new(2).split_cut();
        let mut got_whole = Vec::new();
        let mut got_split = Vec::new();
        for cy in 0..32u32 {
            // consumer pops every third cycle (induces backpressure)
            if cy % 3 != 0 {
                if let Some(v) = whole.pop() {
                    got_whole.push(v);
                }
                if let Some(v) = cons.pop() {
                    got_split.push(v);
                }
            }
            assert_eq!(whole.can_push(), prod.can_push(), "cycle {cy}");
            if whole.can_push() {
                whole.push(cy);
            }
            if prod.can_push() {
                prod.push(cy);
            }
            whole.tick();
            Chan::tick_cut(&mut prod, &mut cons);
            assert_eq!(whole.visible(), cons.visible(), "cycle {cy}");
            assert_eq!(whole.stale_space(), prod.stale_space(), "cycle {cy}");
        }
        assert_eq!(got_whole, got_split);
        assert!(!got_whole.is_empty());
        let joined = Chan::join_cut(prod, cons);
        assert_eq!(joined.pushed, whole.pushed);
        assert_eq!(joined.popped, whole.popped);
        assert_eq!(joined.visible(), whole.visible());
    }

    #[test]
    fn d2d_latency_delays_visibility_exactly() {
        // latency L: a beat pushed at cycle t is visible at t + L
        for lat in [1u32, 2, 3, 8] {
            let mut c: Chan<u32> = Chan::with_d2d(16, lat, 1);
            c.push(42);
            for k in 1..lat {
                c.tick();
                assert_eq!(c.front(), None, "lat={lat}: visible after {k} ticks");
                assert_eq!(c.in_flight(), usize::from(lat > 1));
            }
            c.tick();
            assert_eq!(c.front(), Some(&42), "lat={lat}: not visible after {lat} ticks");
            assert_eq!(c.in_flight(), 0);
        }
    }

    #[test]
    fn d2d_rate_serializes_pushes() {
        // rate R admits exactly one beat per R cycles: the narrow
        // physical lanes busy out for R-1 cycles after each push
        let mut c: Chan<u32> = Chan::with_d2d(16, 1, 4);
        let mut pushed = Vec::new();
        for cy in 0..16u32 {
            if c.can_push() {
                c.push(cy);
                pushed.push(cy);
            }
            c.tick();
        }
        assert_eq!(pushed, vec![0, 4, 8, 12]);
        // rate 1 never arms the cooldown — bit-identical to Chan::new
        let mut f: Chan<u32> = Chan::with_d2d(4, 1, 1);
        f.push(1);
        assert!(f.can_push());
    }

    #[test]
    fn d2d_pipe_occupancy_backpressures() {
        // in-flight beats count against capacity: a depth-2 channel
        // with latency 3 admits two beats then stalls until delivery
        let mut c: Chan<u32> = Chan::with_d2d(2, 3, 1);
        c.push(1);
        c.tick();
        assert!(c.can_push());
        c.push(2);
        c.tick();
        assert!(!c.can_push(), "pipe occupancy must hold back the producer");
        c.tick(); // beat 1 matures
        assert_eq!(c.pop(), Some(1));
        assert!(!c.can_push(), "registered: pop frees space only next tick");
        c.tick(); // beat 2 matures, space snapshot sees the pop
        assert_eq!(c.pop(), Some(2));
        assert!(c.can_push());
    }

    #[test]
    fn d2d_split_cut_matches_whole_channel_bit_for_bit() {
        // same scripted parity as the plain-channel test, with a
        // latency-3 rate-2 D2D channel cut across a thread boundary
        let mut whole: Chan<u32> = Chan::with_d2d(4, 3, 2);
        let (mut prod, mut cons) = Chan::<u32>::with_d2d(4, 3, 2).split_cut();
        let mut got_whole = Vec::new();
        let mut got_split = Vec::new();
        for cy in 0..64u32 {
            if cy % 3 != 0 {
                if let Some(v) = whole.pop() {
                    got_whole.push(v);
                }
                if let Some(v) = cons.pop() {
                    got_split.push(v);
                }
            }
            assert_eq!(whole.can_push(), prod.can_push(), "cycle {cy}");
            assert_eq!(whole.needs_tick(), prod.needs_tick(), "cycle {cy}");
            if whole.can_push() {
                whole.push(cy);
            }
            if prod.can_push() {
                prod.push(cy);
            }
            whole.tick();
            Chan::tick_cut(&mut prod, &mut cons);
            assert_eq!(whole.visible(), cons.visible(), "cycle {cy}");
            assert_eq!(whole.in_flight(), prod.in_flight(), "cycle {cy}");
            assert_eq!(whole.stale_space(), prod.stale_space(), "cycle {cy}");
        }
        assert_eq!(got_whole, got_split);
        assert!(!got_whole.is_empty());
        let joined = Chan::join_cut(prod, cons);
        assert_eq!(joined.pushed, whole.pushed);
        assert_eq!(joined.popped, whole.popped);
        assert_eq!(joined.in_flight(), whole.in_flight());
        assert_eq!(joined.visible(), whole.visible());
    }

    #[test]
    fn d2d_idle_and_needs_tick_track_inflight_state() {
        let mut c: Chan<u32> = Chan::with_d2d(4, 2, 3);
        assert!(c.idle() && !c.needs_tick());
        c.push(9);
        assert!(!c.idle());
        c.tick();
        assert!(c.needs_tick(), "in-flight beat must keep the link active");
        assert!(!c.idle(), "skip(k) must not fast-forward over the pipe");
        c.tick();
        assert_eq!(c.pop(), Some(9));
        // the serializer cooldown alone still pins the channel non-idle
        assert!(c.needs_tick() && !c.idle());
        c.tick();
        assert!(c.idle() && !c.needs_tick());
    }

    #[test]
    fn fifo_order_preserved_across_ticks() {
        let mut c: Chan<u32> = Chan::new(8);
        c.push(1);
        c.push(2);
        c.tick();
        c.push(3);
        c.tick();
        assert_eq!(c.pop(), Some(1));
        assert_eq!(c.pop(), Some(2));
        assert_eq!(c.pop(), Some(3));
    }
}

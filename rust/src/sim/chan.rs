//! Staged, bounded, single-producer single-consumer channel modelling a
//! registered valid/ready handshake FIFO.
//!
//! * `push` stages an item; it becomes poppable only after the next
//!   [`Chan::tick`] (one-cycle latency, like a register slice).
//! * Capacity bounds the total occupancy (queued + staged), modelling
//!   FIFO depth / backpressure: `can_push` is the producer-visible
//!   `ready`.
//! * `stale_space` exposes the occupancy as of the last tick — the
//!   "registered ready" some RTL fork/join logic sees (one cycle stale).

use std::collections::VecDeque;

#[derive(Debug, Clone)]
pub struct Chan<T> {
    q: VecDeque<T>,
    staged: VecDeque<T>,
    cap: usize,
    space_at_tick: usize,
    /// Total items ever pushed (throughput accounting).
    pub pushed: u64,
    /// Total items ever popped.
    pub popped: u64,
}

impl<T> Chan<T> {
    pub fn new(cap: usize) -> Chan<T> {
        assert!(cap >= 1);
        Chan {
            q: VecDeque::new(),
            staged: VecDeque::new(),
            cap,
            space_at_tick: cap,
            pushed: 0,
            popped: 0,
        }
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Occupancy (queued + staged).
    pub fn len(&self) -> usize {
        self.q.len() + self.staged.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Producer-side ready: is there space to push this cycle?
    pub fn can_push(&self) -> bool {
        self.len() < self.cap
    }

    /// Space as seen at the last clock edge (registered-ready modelling;
    /// conservative for fork logic that cannot see same-cycle pops).
    pub fn stale_space(&self) -> usize {
        self.space_at_tick
    }

    /// Stage an item for visibility next cycle. Panics on overflow —
    /// callers must check `can_push` (models a handshake violation).
    pub fn push(&mut self, item: T) {
        assert!(self.can_push(), "Chan overflow: push without ready");
        self.staged.push_back(item);
        self.pushed += 1;
    }

    /// Consumer-side peek of the oldest *visible* item.
    pub fn front(&self) -> Option<&T> {
        self.q.front()
    }

    /// Pop the oldest visible item.
    pub fn pop(&mut self) -> Option<T> {
        let it = self.q.pop_front();
        if it.is_some() {
            self.popped += 1;
        }
        it
    }

    /// Number of currently visible (poppable) items.
    pub fn visible(&self) -> usize {
        self.q.len()
    }

    /// Clock edge: staged items become visible, ready snapshot updates.
    #[inline]
    pub fn tick(&mut self) {
        // fast path: the overwhelmingly common idle-channel case
        if !self.staged.is_empty() {
            self.q.append(&mut self.staged);
        }
        self.space_at_tick = self.cap - self.q.len();
    }

    /// Drop all contents (used by test harnesses between phases).
    pub fn clear(&mut self) {
        self.q.clear();
        self.staged.clear();
        self.space_at_tick = self.cap;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_visible_next_tick() {
        let mut c: Chan<u32> = Chan::new(4);
        c.push(7);
        assert_eq!(c.front(), None, "staged items must not be visible");
        c.tick();
        assert_eq!(c.front(), Some(&7));
        assert_eq!(c.pop(), Some(7));
        assert_eq!(c.pop(), None);
    }

    #[test]
    fn capacity_bounds_total_occupancy() {
        let mut c: Chan<u32> = Chan::new(2);
        c.push(1);
        c.push(2);
        assert!(!c.can_push());
        c.tick();
        assert!(!c.can_push(), "queued items still occupy space");
        c.pop();
        assert!(c.can_push());
    }

    #[test]
    #[should_panic(expected = "Chan overflow")]
    fn overflow_panics() {
        let mut c: Chan<u32> = Chan::new(1);
        c.push(1);
        c.push(2);
    }

    #[test]
    fn sustained_one_per_cycle() {
        // cap-2 chan with a consumer draining every cycle sustains
        // 1 item/cycle — the full-rate pipelined hop.
        let mut c: Chan<u64> = Chan::new(2);
        let mut got = Vec::new();
        for cy in 0..100u64 {
            if let Some(v) = c.pop() {
                got.push(v);
            }
            if c.can_push() {
                c.push(cy);
            }
            c.tick();
        }
        assert!(got.len() >= 98, "sustained rate broke: {}", got.len());
        for w in got.windows(2) {
            assert!(w[1] > w[0]);
        }
    }

    #[test]
    fn stale_space_lags_one_cycle() {
        let mut c: Chan<u32> = Chan::new(2);
        assert_eq!(c.stale_space(), 2);
        c.push(1);
        assert_eq!(c.stale_space(), 2, "stale view unchanged until tick");
        c.tick();
        assert_eq!(c.stale_space(), 1);
        c.pop();
        assert_eq!(c.stale_space(), 1, "pop not visible until tick");
        c.tick();
        assert_eq!(c.stale_space(), 2);
    }

    #[test]
    fn fifo_order_preserved_across_ticks() {
        let mut c: Chan<u32> = Chan::new(8);
        c.push(1);
        c.push(2);
        c.tick();
        c.push(3);
        c.tick();
        assert_eq!(c.pop(), Some(1));
        assert_eq!(c.pop(), Some(2));
        assert_eq!(c.pop(), Some(3));
    }
}

//! Typed link handles and the shared link pool.
//!
//! Components exchange beats over *links* (bundles of staged channels)
//! owned by a [`Pool`]. A component never holds a link directly — it
//! holds [`LinkId`] handles and resolves them against the pool each
//! cycle. This keeps the component graph data (the topology subsystem
//! builds arbitrary graphs over one pool) while making aliasing
//! explicit: disjoint mutable access goes through
//! [`Pool::get_disjoint_mut`], everything else through indexing.
//!
//! The pool is generic over the link type so the scheduler in
//! [`super::sched`] stays independent of the AXI layer; `axi::types`
//! instantiates it as `Pool<AxiLink>` (aliased `LinkPool`).

use std::ops::{Index, IndexMut};

/// Behaviour the simulation kernel needs from a link.
pub trait Link {
    /// Advance the clock edge on every channel of the link.
    fn tick(&mut self);
    /// Any beat visible to a consumer (sampled right after [`tick`])?
    ///
    /// [`tick`]: Link::tick
    fn any_visible(&self) -> bool;
    /// All channels empty — no staged and no visible beats.
    fn is_idle(&self) -> bool;
    /// Total beats ever consumed (monotone progress for watchdogs).
    fn moved(&self) -> u64;
}

/// Timing parameters of a die-to-die (D2D) link — the narrow,
/// latency-asymmetric SerDes hop joining two chiplets of a package.
///
/// A D2D link is an ordinary link whose channels are built with
/// [`crate::sim::chan::Chan::with_d2d`]: every channel gains
/// `latency` cycles of delivery delay (the PHY pipeline), and the
/// *data* channels additionally serialize at one beat per
/// `width_ratio` cycles (an on-die wide beat occupies the narrow
/// physical lanes for `width_ratio` cycles). Address/response
/// channels keep full rate — they are narrow already.
///
/// `D2dParams::default()` models a conservative organic-substrate
/// SerDes: 4:1 width conversion, 8-cycle hop latency.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct D2dParams {
    /// Beat-serialization ratio for data channels (>= 1): cycles of
    /// lane occupancy per on-die beat. 1 = full-width (no throttle).
    pub width_ratio: u32,
    /// Pipeline latency in cycles of every channel crossing the gap
    /// (>= 1; 1 collapses to a plain registered hop).
    pub latency: u32,
    /// FIFO depth of the gateway-facing channels (the
    /// bandwidth-delay buffer on each side of the SerDes).
    pub depth: usize,
}

impl Default for D2dParams {
    fn default() -> D2dParams {
        D2dParams {
            width_ratio: 4,
            latency: 8,
            depth: 4,
        }
    }
}

impl D2dParams {
    /// Validate for topology construction.
    pub fn check(&self) -> Result<(), String> {
        if self.width_ratio < 1 || self.latency < 1 || self.depth < 1 {
            return Err(format!(
                "D2dParams out of range (width_ratio {}, latency {}, depth {} — all must be >= 1)",
                self.width_ratio, self.latency, self.depth
            ));
        }
        Ok(())
    }
}

/// Typed handle into a [`Pool`]. Replaces the raw `usize` indices the
/// pre-topology code threaded around: a `LinkId` can only be obtained
/// by allocating a link, so mixing up port numbers and link indices is
/// a type error.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct LinkId(u32);

impl LinkId {
    /// Position inside the owning pool (stable for the pool's lifetime).
    #[inline]
    pub fn index(self) -> usize {
        self.0 as usize
    }

    /// Rebuild a handle from a pool position (crate-internal: the
    /// scheduler shards and the parallel engine exchange link ids as
    /// raw indices across threads).
    #[inline]
    pub(crate) fn from_index(i: usize) -> LinkId {
        LinkId(u32::try_from(i).expect("link index overflow"))
    }
}

impl std::fmt::Display for LinkId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "link{}", self.0)
    }
}

/// Arena owning every link of a component graph. Allocation is
/// append-only; ids stay valid for the pool's lifetime.
#[derive(Debug)]
pub struct Pool<L> {
    links: Vec<L>,
}

impl<L> Default for Pool<L> {
    fn default() -> Pool<L> {
        Pool::new()
    }
}

impl<L> Pool<L> {
    pub fn new() -> Pool<L> {
        Pool { links: Vec::new() }
    }

    /// Add a link, returning its handle.
    pub fn alloc(&mut self, link: L) -> LinkId {
        let id = LinkId(u32::try_from(self.links.len()).expect("link pool overflow"));
        self.links.push(link);
        id
    }

    pub fn len(&self) -> usize {
        self.links.len()
    }

    pub fn is_empty(&self) -> bool {
        self.links.is_empty()
    }

    /// Handle of the `i`-th allocated link (panics out of range).
    pub fn id_at(&self, i: usize) -> LinkId {
        assert!(i < self.links.len(), "link index {i} out of range");
        LinkId(i as u32)
    }

    /// All handles, in allocation order. Allocation-free: ids are the
    /// positions `0..len`, so the iterator is just a counter (callers
    /// that used to receive a fresh `Vec` per call collect explicitly).
    pub fn ids(&self) -> impl Iterator<Item = LinkId> {
        let n = self.links.len() as u32;
        (0..n).map(LinkId)
    }

    /// Tear the pool apart into its links, in allocation order (the
    /// parallel engine distributes them across shard pools and rebuilds
    /// with [`Pool::from_links`]).
    pub fn into_links(self) -> Vec<L> {
        self.links
    }

    /// Rebuild a pool from links previously obtained via
    /// [`Pool::into_links`]; ids are the vector positions.
    pub fn from_links(links: Vec<L>) -> Pool<L> {
        u32::try_from(links.len()).expect("link pool overflow");
        Pool { links }
    }

    /// Disjoint mutable access to several links at once (panics if any
    /// two ids alias — the topology builder never hands out duplicate
    /// port wirings, so aliasing here is a wiring bug).
    pub fn get_disjoint_mut<const N: usize>(&mut self, ids: [LinkId; N]) -> [&mut L; N] {
        self.links
            .get_disjoint_mut(ids.map(LinkId::index))
            .expect("link ids must be distinct and in range")
    }

    pub fn iter(&self) -> std::slice::Iter<'_, L> {
        self.links.iter()
    }

    pub fn iter_mut(&mut self) -> std::slice::IterMut<'_, L> {
        self.links.iter_mut()
    }
}

impl<L: Link> Pool<L> {
    /// Clock edge on every link (test/fixture loops; the scheduler
    /// ticks selectively instead).
    pub fn tick_all(&mut self) {
        for l in &mut self.links {
            l.tick();
        }
    }

    /// Total beats moved across the pool (watchdog progress).
    pub fn moved_total(&self) -> u64 {
        self.links.iter().map(|l| l.moved()).sum()
    }
}

impl<L> Index<LinkId> for Pool<L> {
    type Output = L;
    #[inline]
    fn index(&self, id: LinkId) -> &L {
        &self.links[id.index()]
    }
}

impl<L> IndexMut<LinkId> for Pool<L> {
    #[inline]
    fn index_mut(&mut self, id: LinkId) -> &mut L {
        &mut self.links[id.index()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Default)]
    struct FakeLink {
        ticks: u64,
        visible: bool,
    }

    impl Link for FakeLink {
        fn tick(&mut self) {
            self.ticks += 1;
        }
        fn any_visible(&self) -> bool {
            self.visible
        }
        fn is_idle(&self) -> bool {
            !self.visible
        }
        fn moved(&self) -> u64 {
            self.ticks
        }
    }

    #[test]
    fn alloc_and_index() {
        let mut p: Pool<FakeLink> = Pool::new();
        let a = p.alloc(FakeLink::default());
        let b = p.alloc(FakeLink::default());
        assert_ne!(a, b);
        assert_eq!(a.index(), 0);
        assert_eq!(p.id_at(1), b);
        p[a].visible = true;
        assert!(p[a].any_visible());
        assert!(!p[b].any_visible());
        assert_eq!(p.ids().collect::<Vec<_>>(), vec![a, b]);
    }

    #[test]
    fn into_and_from_links_round_trips() {
        let mut p: Pool<FakeLink> = Pool::new();
        let a = p.alloc(FakeLink::default());
        let b = p.alloc(FakeLink::default());
        p[b].ticks = 7;
        let links = p.into_links();
        assert_eq!(links.len(), 2);
        let p2 = Pool::from_links(links);
        assert_eq!(p2[a].ticks, 0);
        assert_eq!(p2[b].ticks, 7);
        assert_eq!(p2.id_at(1), b);
    }

    #[test]
    fn disjoint_mut_gives_both() {
        let mut p: Pool<FakeLink> = Pool::new();
        let a = p.alloc(FakeLink::default());
        let b = p.alloc(FakeLink::default());
        let [la, lb] = p.get_disjoint_mut([a, b]);
        la.ticks = 3;
        lb.ticks = 5;
        assert_eq!(p.moved_total(), 8);
    }

    #[test]
    #[should_panic(expected = "distinct")]
    fn disjoint_mut_rejects_aliases() {
        let mut p: Pool<FakeLink> = Pool::new();
        let a = p.alloc(FakeLink::default());
        let _ = p.get_disjoint_mut([a, a]);
    }

    #[test]
    fn tick_all_touches_every_link() {
        let mut p: Pool<FakeLink> = Pool::new();
        for _ in 0..4 {
            p.alloc(FakeLink::default());
        }
        p.tick_all();
        assert!(p.iter().all(|l| l.ticks == 1));
    }
}

//! Component step trait + the generic idle-skip scheduler.
//!
//! Before the topology refactor the per-link `link_active`/`link_dirty`
//! bookkeeping lived ad hoc inside `Soc::step`; any other component
//! graph had to reimplement it. [`Scheduler`] extracts the machinery so
//! *every* graph built over a [`Pool`] gets the same optimisation (the
//! largest simulator-throughput win — see EXPERIMENTS.md §Perf):
//!
//! * a component is stepped only when it is not [`quiescent`] or one of
//!   its ports carried visible beats at the last clock edge;
//! * only links that were possibly touched this cycle (`dirty`) or that
//!   carried beats (`active`) pay a clock edge — everything else is
//!   provably unchanged.
//!
//! Both sets are tracked as **index lists** (not just flag vectors), so
//! a fully-idle cycle costs O(touched links), not O(all links): on the
//! 32-cluster SoC an idle edge touches ~0 of ~350 links (§Perf,
//! `benches/sim_perf.rs` "idle step" scenario).
//!
//! The trait also carries the **event horizon** hook
//! ([`Component::next_event`]): the earliest cycle at which stepping
//! the component could do anything beyond decrementing internal timers.
//! When every link is idle, a driver (e.g. `occamy::Soc::run`) can
//! fast-forward the clock to the horizon instead of stepping through
//! latency waits cycle by cycle.
//!
//! [`quiescent`]: Component::quiescent

use super::link::{Link, LinkId, Pool};
use super::Cycle;

/// A clock-stepped component attached to pool links.
///
/// Implemented by anything the scheduler can drive generically (the
/// crossbar, pooled endpoint models). Components with richer step
/// signatures (clusters need config + event plumbing) use the
/// scheduler's [`Scheduler::should_step`]/[`Scheduler::mark_dirty`]
/// primitives directly instead.
pub trait Component<L: Link> {
    /// Advance one clock cycle against the shared pool.
    fn step(&mut self, cy: Cycle, pool: &mut Pool<L>);

    /// Conservatively true when the component holds no in-flight state:
    /// stepping it without port activity would be a no-op.
    fn quiescent(&self) -> bool;

    /// External ports. Visible beats on any of these wake the
    /// component; stepping it marks all of them dirty.
    fn ports(&self) -> &[LinkId];

    /// Event horizon: the earliest cycle ≥ `now` at which stepping this
    /// component could do anything beyond pure internal timer
    /// advancement, assuming **no port activity** until then. `None`
    /// means the component is idle or waiting solely on its ports.
    ///
    /// The default is maximally conservative — a busy component claims
    /// an event every cycle, which simply disables fast-forwarding
    /// around it. Implementations that override this must also provide
    /// a matching bulk-advance (see `axi::Xbar::skip`) so skipped
    /// cycles stay bit-identical to stepped ones.
    fn next_event(&self, now: Cycle) -> Option<Cycle> {
        if self.quiescent() {
            None
        } else {
            Some(now)
        }
    }

    /// Hinted step: skip the step entirely when idle and unprompted.
    fn step_hinted(&mut self, cy: Cycle, pool: &mut Pool<L>, port_activity: bool) {
        if port_activity || !self.quiescent() {
            self.step(cy, pool);
        }
    }
}

/// Fold one event deadline into a running horizon minimum (shared by
/// every [`Component::next_event`] implementation).
#[inline]
pub fn fold_min(ev: &mut Option<Cycle>, e: Cycle) {
    *ev = Some(ev.map_or(e, |cur| cur.min(e)));
}

/// Per-link activity tracker driving the idle skips.
#[derive(Debug)]
pub struct Scheduler {
    /// Link had visible beats at the last clock edge.
    active: Vec<bool>,
    /// Link possibly pushed/popped this cycle.
    dirty: Vec<bool>,
    /// Indices with `dirty` set (unique — guarded by the flag).
    touched: Vec<u32>,
    /// Indices with `active` set (unique — rebuilt at each edge).
    active_idx: Vec<u32>,
    /// Scratch for rebuilding `active_idx` without reallocating.
    scratch: Vec<u32>,
}

impl Scheduler {
    /// All links start active so the first cycle steps everything.
    pub fn new(n_links: usize) -> Scheduler {
        Scheduler {
            active: vec![true; n_links],
            dirty: vec![false; n_links],
            touched: Vec::new(),
            active_idx: (0..n_links as u32).collect(),
            scratch: Vec::new(),
        }
    }

    /// Track links added to the pool after construction (new links
    /// start active).
    pub fn sync(&mut self, n_links: usize) {
        let old = self.active.len();
        self.active.resize(n_links, true);
        self.dirty.resize(n_links, false);
        self.active_idx.extend(old as u32..n_links as u32);
    }

    /// Start a cycle: nothing touched yet (clears the previous cycle's
    /// dirty set in O(touched)).
    pub fn begin_cycle(&mut self) {
        for i in self.touched.drain(..) {
            self.dirty[i as usize] = false;
        }
    }

    #[inline]
    pub fn is_active(&self, id: LinkId) -> bool {
        self.active[id.index()]
    }

    #[inline]
    pub fn any_active(&self, ids: &[LinkId]) -> bool {
        ids.iter().any(|&id| self.active[id.index()])
    }

    /// No link carried visible beats at the last clock edge — the
    /// entry condition for event-horizon fast-forwarding.
    #[inline]
    pub fn links_idle(&self) -> bool {
        self.active_idx.is_empty()
    }

    #[inline]
    pub fn mark_dirty(&mut self, id: LinkId) {
        let i = id.index();
        if !self.dirty[i] {
            self.dirty[i] = true;
            self.touched.push(i as u32);
        }
    }

    pub fn mark_all_dirty(&mut self, ids: &[LinkId]) {
        for &id in ids {
            self.mark_dirty(id);
        }
    }

    /// Should a component with this quiescence and port set run?
    #[inline]
    pub fn should_step(&self, quiescent: bool, ports: &[LinkId]) -> bool {
        !quiescent || self.any_active(ports)
    }

    /// Step `c` if its wake hint says so, marking its ports dirty when
    /// it ran. Returns whether it stepped.
    pub fn step_component<L, C>(&mut self, cy: Cycle, c: &mut C, pool: &mut Pool<L>) -> bool
    where
        L: Link,
        C: Component<L> + ?Sized,
    {
        if !self.should_step(c.quiescent(), c.ports()) {
            return false;
        }
        c.step(cy, pool);
        for &id in c.ports() {
            self.mark_dirty(id);
        }
        true
    }

    /// End of cycle: clock edge on touched links only, refresh the
    /// activity snapshot while each link is cache-hot. O(touched +
    /// previously-active), not O(all links).
    pub fn end_cycle<L: Link>(&mut self, pool: &mut Pool<L>) {
        debug_assert_eq!(self.active.len(), pool.len(), "scheduler out of sync");
        self.end_cycle_with(|id| {
            let l = &mut pool[id];
            l.tick();
            l.any_visible()
        });
    }

    /// [`Scheduler::end_cycle`] with the clock edge abstracted: `tick`
    /// receives each link due an edge (touched or active) exactly once
    /// and returns whether the link has visible beats afterwards. The
    /// parallel engine uses this to tick links living in shard pools —
    /// a cut link's edge is [`tick_cut`] across its two halves, with
    /// the visibility OR of both.
    ///
    /// [`tick_cut`]: crate::sim::Chan::tick_cut
    pub fn end_cycle_with(&mut self, mut tick: impl FnMut(LinkId) -> bool) {
        self.scratch.clear();
        // dirtied links that were not active (the active pass below
        // handles the overlap — each link ticks exactly once)
        for &i in &self.touched {
            let iu = i as usize;
            if self.active[iu] {
                continue;
            }
            if tick(LinkId::from_index(iu)) {
                self.active[iu] = true;
                self.scratch.push(i);
            }
        }
        for &i in &self.active_idx {
            let iu = i as usize;
            let vis = tick(LinkId::from_index(iu));
            self.active[iu] = vis;
            if vis {
                self.scratch.push(i);
            }
        }
        std::mem::swap(&mut self.active_idx, &mut self.scratch);
    }

    // ---- shard support (sim::parallel) ----
    //
    // Each worker shard carries a full-size `Scheduler` clone whose
    // `active` snapshot is re-synced from the master scheduler at the
    // start of every cycle and whose dirty set drains back into the
    // master at the merge barrier — so the per-component gating
    // (`should_step`/`step_component`) runs identical decisions on
    // every thread, and the master's `end_cycle` sees exactly the
    // union of all shards' marks, in deterministic shard order.

    /// Fresh shard scheduler: same size, nothing active or dirty (the
    /// activity snapshot arrives via [`Scheduler::copy_active_from`]).
    pub fn new_shard(n_links: usize) -> Scheduler {
        Scheduler {
            active: vec![false; n_links],
            dirty: vec![false; n_links],
            touched: Vec::new(),
            active_idx: Vec::new(),
            scratch: Vec::new(),
        }
    }

    /// Overwrite the activity snapshot from the master scheduler.
    pub fn copy_active_from(&mut self, src: &Scheduler) {
        debug_assert_eq!(self.active.len(), src.active.len());
        self.active.copy_from_slice(&src.active);
    }

    /// Drain this shard's dirty set into `dst` (the master), clearing
    /// the local flags.
    pub fn drain_touched_into(&mut self, dst: &mut Scheduler) {
        for i in self.touched.drain(..) {
            self.dirty[i as usize] = false;
            dst.mark_dirty(LinkId::from_index(i as usize));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::link::Pool;

    #[derive(Default)]
    struct FakeLink {
        staged: u32,
        visible: u32,
        ticks: u64,
        popped: u64,
    }

    impl Link for FakeLink {
        fn tick(&mut self) {
            self.ticks += 1;
            self.visible += self.staged;
            self.staged = 0;
        }
        fn any_visible(&self) -> bool {
            self.visible > 0
        }
        fn is_idle(&self) -> bool {
            self.visible == 0 && self.staged == 0
        }
        fn moved(&self) -> u64 {
            self.popped
        }
    }

    /// Copies one beat per cycle from its input to its output.
    struct Copier {
        ports: Vec<LinkId>,
        held: u32,
    }

    impl Component<FakeLink> for Copier {
        fn step(&mut self, _cy: Cycle, pool: &mut Pool<FakeLink>) {
            let [input, output] = pool.get_disjoint_mut([self.ports[0], self.ports[1]]);
            if input.visible > 0 {
                input.visible -= 1;
                input.popped += 1;
                self.held += 1;
            }
            if self.held > 0 {
                self.held -= 1;
                output.staged += 1;
            }
        }
        fn quiescent(&self) -> bool {
            self.held == 0
        }
        fn ports(&self) -> &[LinkId] {
            &self.ports
        }
    }

    #[test]
    fn idle_component_is_skipped_and_woken() {
        let mut pool: Pool<FakeLink> = Pool::new();
        let a = pool.alloc(FakeLink::default());
        let b = pool.alloc(FakeLink::default());
        let mut sched = Scheduler::new(pool.len());
        let mut c = Copier {
            ports: vec![a, b],
            held: 0,
        };
        // settle: first cycles everything is "active" by construction
        for cy in 0..3 {
            sched.begin_cycle();
            sched.step_component(cy, &mut c, &mut pool);
            sched.end_cycle(&mut pool);
        }
        // now truly idle: must be skipped
        sched.begin_cycle();
        assert!(!sched.step_component(3, &mut c, &mut pool));
        sched.end_cycle(&mut pool);
        assert!(sched.links_idle());
        // inject a beat; producer marks the link dirty
        pool[a].staged = 1;
        sched.begin_cycle();
        sched.mark_dirty(a);
        sched.step_component(4, &mut c, &mut pool); // not yet visible
        sched.end_cycle(&mut pool);
        assert!(!sched.links_idle());
        // beat visible now → component wakes and consumes it
        sched.begin_cycle();
        assert!(sched.step_component(5, &mut c, &mut pool));
        sched.end_cycle(&mut pool);
        assert_eq!(pool[a].moved(), 1);
        // and the copied beat reaches the output link
        sched.begin_cycle();
        sched.step_component(6, &mut c, &mut pool);
        sched.end_cycle(&mut pool);
        assert!(pool[b].any_visible());
    }

    #[test]
    fn sync_tracks_late_link_allocation() {
        let mut pool: Pool<FakeLink> = Pool::new();
        let _a = pool.alloc(FakeLink::default());
        let mut sched = Scheduler::new(pool.len());
        sched.begin_cycle();
        sched.end_cycle(&mut pool); // drain initial all-active state
        // a link allocated after construction starts active once synced
        let b = pool.alloc(FakeLink::default());
        sched.sync(pool.len());
        assert!(sched.is_active(b));
        pool[b].staged = 1;
        sched.begin_cycle();
        sched.mark_dirty(b);
        sched.end_cycle(&mut pool);
        assert!(sched.is_active(b));
        assert!(pool[b].any_visible());
    }

    #[test]
    fn step_hinted_skips_when_idle_and_unprompted() {
        let mut pool: Pool<FakeLink> = Pool::new();
        let a = pool.alloc(FakeLink::default());
        let b = pool.alloc(FakeLink::default());
        let mut c = Copier {
            ports: vec![a, b],
            held: 1,
        };
        // not quiescent → steps even without port activity
        c.step_hinted(0, &mut pool, false);
        assert_eq!(c.held, 0);
        assert_eq!(pool[b].staged, 1);
        // quiescent and unprompted → skipped entirely
        c.step_hinted(1, &mut pool, false);
        assert_eq!(pool[b].staged, 1, "skipped step must not touch links");
        // port activity forces a step even when quiescent
        pool[a].visible = 1;
        c.step_hinted(2, &mut pool, true);
        assert_eq!(pool[a].moved(), 1);
    }

    #[test]
    fn untouched_idle_links_skip_the_clock_edge() {
        let mut pool: Pool<FakeLink> = Pool::new();
        let a = pool.alloc(FakeLink::default());
        let b = pool.alloc(FakeLink::default());
        let mut sched = Scheduler::new(pool.len());
        // first end_cycle ticks everything (all links start active)
        sched.begin_cycle();
        sched.end_cycle(&mut pool);
        let base = pool[b].ticks;
        // steady idle state: neither dirty nor active → no tick
        for _ in 0..5 {
            sched.begin_cycle();
            sched.end_cycle(&mut pool);
        }
        assert_eq!(pool[b].ticks, base, "idle link must not be ticked");
        // dirty marking forces the edge
        sched.begin_cycle();
        sched.mark_dirty(a);
        sched.end_cycle(&mut pool);
        assert_eq!(pool[a].ticks, base + 1);
        assert_eq!(pool[b].ticks, base);
    }

    #[test]
    fn dirty_and_active_link_ticks_exactly_once() {
        let mut pool: Pool<FakeLink> = Pool::new();
        let a = pool.alloc(FakeLink::default());
        let mut sched = Scheduler::new(pool.len());
        // make `a` active (visible beat survives the edge)
        pool[a].staged = 2;
        sched.begin_cycle();
        sched.mark_dirty(a);
        sched.end_cycle(&mut pool);
        assert!(sched.is_active(a));
        let base = pool[a].ticks;
        // active AND dirtied in the same cycle: one edge only
        sched.begin_cycle();
        sched.mark_dirty(a);
        sched.mark_dirty(a); // duplicate marks are idempotent
        sched.end_cycle(&mut pool);
        assert_eq!(pool[a].ticks, base + 1);
    }

    #[test]
    fn default_next_event_is_conservative() {
        let ports = Vec::new();
        let mut c = Copier { ports, held: 1 };
        assert_eq!(c.next_event(10), Some(10), "busy → event now");
        c.held = 0;
        assert_eq!(c.next_event(10), None, "idle → no internal events");
    }
}

//! Cycle-level simulation kernel.
//!
//! The simulator is *clock-stepped*: every component implements a `step`
//! that runs once per cycle, exchanging beats through staged channels
//! ([`chan::Chan`]). A push performed in cycle *k* becomes visible to
//! the consumer in cycle *k+1*, modelling a registered (spill-register)
//! hop exactly like the `axi_multicut`-style pipelining in the RTL this
//! reproduces. Both visibility *and* ready ([`chan::Chan::can_push`])
//! are registered against the last clock edge, so simulation results
//! are fully independent of intra-cycle component order — the invariant
//! the [`parallel`] engine exploits to step disjoint component
//! partitions concurrently, bit-identically to sequential stepping.

pub mod chan;
pub mod engine;
pub mod link;
pub mod parallel;
pub mod sched;
pub mod trace;

pub use chan::Chan;
pub use engine::{Engine, Watchdog};
pub use link::{Link, LinkId, Pool};
pub use sched::{Component, Scheduler};

/// Simulation time in clock cycles.
pub type Cycle = u64;

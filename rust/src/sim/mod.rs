//! Cycle-level simulation kernel.
//!
//! The simulator is *clock-stepped*: every component implements a `step`
//! that runs once per cycle in a fixed deterministic order, exchanging
//! beats through staged channels ([`chan::Chan`]). A push performed in
//! cycle *k* becomes visible to the consumer in cycle *k+1*, modelling a
//! registered (spill-register) hop exactly like the `axi_multicut`-style
//! pipelining in the RTL this reproduces. Because visibility is staged,
//! simulation results are independent of intra-cycle component order for
//! everything except same-cycle ready evaluation, which is made
//! deterministic by the fixed step order.

pub mod chan;
pub mod engine;
pub mod link;
pub mod sched;
pub mod trace;

pub use chan::Chan;
pub use engine::{Engine, Watchdog};
pub use link::{Link, LinkId, Pool};
pub use sched::{Component, Scheduler};

/// Simulation time in clock cycles.
pub type Cycle = u64;

//! Event tracing: an optional JSON-lines event sink for debugging and
//! for the `--trace` CLI flag. Zero-cost when disabled (the hot path
//! checks a bool before formatting).

use std::fmt::Write as _;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use super::Cycle;

/// Trace event categories (stringified into the `kind` field).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Aw,
    W,
    B,
    Ar,
    R,
    Commit,
    Grant,
    Dma,
    Compute,
    Barrier,
    Irq,
}

impl Kind {
    fn as_str(self) -> &'static str {
        match self {
            Kind::Aw => "aw",
            Kind::W => "w",
            Kind::B => "b",
            Kind::Ar => "ar",
            Kind::R => "r",
            Kind::Commit => "commit",
            Kind::Grant => "grant",
            Kind::Dma => "dma",
            Kind::Compute => "compute",
            Kind::Barrier => "barrier",
            Kind::Irq => "irq",
        }
    }
}

/// A trace sink. `None` writer means tracing is disabled.
pub struct Trace {
    sink: Option<BufWriter<File>>,
    /// In-memory ring of the most recent events (test inspection).
    pub recent: Vec<String>,
    keep_recent: usize,
    pub events: u64,
}

impl Default for Trace {
    fn default() -> Self {
        Trace::disabled()
    }
}

impl Trace {
    pub fn disabled() -> Trace {
        Trace {
            sink: None,
            recent: Vec::new(),
            keep_recent: 0,
            events: 0,
        }
    }

    /// Keep the last `n` events in memory (no file) — used by tests.
    pub fn in_memory(n: usize) -> Trace {
        Trace {
            sink: None,
            recent: Vec::new(),
            keep_recent: n,
            events: 0,
        }
    }

    pub fn to_file(path: &Path) -> std::io::Result<Trace> {
        Ok(Trace {
            sink: Some(BufWriter::new(File::create(path)?)),
            recent: Vec::new(),
            keep_recent: 0,
            events: 0,
        })
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.sink.is_some() || self.keep_recent > 0
    }

    /// Record one event. `who` identifies the component (e.g. "xbar0.m3").
    pub fn event(&mut self, cy: Cycle, kind: Kind, who: &str, detail: &str) {
        if !self.enabled() {
            return;
        }
        self.events += 1;
        let mut line = String::with_capacity(64);
        let _ = write!(
            line,
            "{{\"cy\":{},\"kind\":\"{}\",\"who\":\"{}\",\"detail\":\"{}\"}}",
            cy,
            kind.as_str(),
            who,
            detail
        );
        if let Some(w) = self.sink.as_mut() {
            let _ = writeln!(w, "{line}");
        }
        if self.keep_recent > 0 {
            if self.recent.len() == self.keep_recent {
                self.recent.remove(0);
            }
            self.recent.push(line);
        }
    }

    pub fn flush(&mut self) {
        if let Some(w) = self.sink.as_mut() {
            let _ = w.flush();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_is_noop() {
        let mut t = Trace::disabled();
        t.event(1, Kind::Aw, "x", "y");
        assert_eq!(t.events, 0);
    }

    #[test]
    fn in_memory_ring() {
        let mut t = Trace::in_memory(2);
        t.event(1, Kind::Aw, "a", "");
        t.event(2, Kind::W, "b", "");
        t.event(3, Kind::B, "c", "");
        assert_eq!(t.recent.len(), 2);
        assert!(t.recent[0].contains("\"kind\":\"w\""));
        assert!(t.recent[1].contains("\"kind\":\"b\""));
        assert_eq!(t.events, 3);
    }

    #[test]
    fn file_sink_writes_jsonl() {
        let dir = std::env::temp_dir().join("axi_mcast_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.jsonl");
        {
            let mut t = Trace::to_file(&path).unwrap();
            t.event(5, Kind::Commit, "xbar.m0", "targets=3");
            t.flush();
        }
        let content = std::fs::read_to_string(&path).unwrap();
        assert!(content.contains("\"cy\":5"));
        assert!(content.contains("commit"));
        let _ = std::fs::remove_file(&path);
    }
}

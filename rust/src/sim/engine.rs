//! The clock loop: run a closure once per cycle until it reports
//! completion, with a deadlock watchdog (no observable progress for a
//! configurable number of cycles aborts the run — this is how the
//! fig. 2e deadlock scenario is *detected* when the commit protocol is
//! disabled).

use super::Cycle;

/// Outcome of stepping the system for one cycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepResult {
    /// Work remains; `progress` is a monotone counter of observable
    /// events (beats moved, commands retired) used by the watchdog.
    Running { progress: u64 },
    /// Simulation finished.
    Done,
}

/// Watchdog configuration.
#[derive(Debug, Clone, Copy)]
pub struct Watchdog {
    /// Abort if `progress` hasn't advanced for this many cycles.
    pub stall_cycles: u64,
    /// Hard cap on total cycles (safety net for runaway configs).
    pub max_cycles: u64,
}

impl Default for Watchdog {
    fn default() -> Watchdog {
        Watchdog {
            stall_cycles: 100_000,
            max_cycles: 2_000_000_000,
        }
    }
}

/// Error raised when the watchdog fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimError {
    Deadlock {
        cycle: Cycle,
        stalled: u64,
        progress: u64,
    },
    CycleLimit { max: u64 },
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SimError::Deadlock {
                cycle,
                stalled,
                progress,
            } => write!(
                f,
                "deadlock: no progress for {stalled} cycles at cycle {cycle} \
                 (progress counter {progress})"
            ),
            SimError::CycleLimit { max } => write!(f, "cycle limit exceeded ({max} cycles)"),
        }
    }
}

impl std::error::Error for SimError {}

/// The simulation engine. Owns only the clock; all state lives in the
/// stepped closure's captures (the SoC or test fixture).
pub struct Engine {
    pub now: Cycle,
    pub watchdog: Watchdog,
}

impl Engine {
    pub fn new(watchdog: Watchdog) -> Engine {
        Engine { now: 0, watchdog }
    }

    /// Run `step(cycle)` until it returns `Done`. Returns the cycle count
    /// at completion.
    pub fn run<F: FnMut(Cycle) -> StepResult>(
        &mut self,
        mut step: F,
    ) -> Result<Cycle, SimError> {
        let mut last_progress = u64::MAX;
        let mut stalled_since = self.now;
        loop {
            match step(self.now) {
                StepResult::Done => return Ok(self.now),
                StepResult::Running { progress } => {
                    if progress != last_progress {
                        last_progress = progress;
                        stalled_since = self.now;
                    } else if self.now - stalled_since >= self.watchdog.stall_cycles {
                        return Err(SimError::Deadlock {
                            cycle: self.now,
                            stalled: self.now - stalled_since,
                            progress,
                        });
                    }
                }
            }
            self.now += 1;
            if self.now >= self.watchdog.max_cycles {
                return Err(SimError::CycleLimit {
                    max: self.watchdog.max_cycles,
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_to_completion() {
        let mut eng = Engine::new(Watchdog::default());
        let mut count = 0u64;
        let end = eng
            .run(|_cy| {
                count += 1;
                if count == 100 {
                    StepResult::Done
                } else {
                    StepResult::Running { progress: count }
                }
            })
            .unwrap();
        assert_eq!(end, 99);
    }

    #[test]
    fn watchdog_detects_stall() {
        let mut eng = Engine::new(Watchdog {
            stall_cycles: 50,
            max_cycles: 10_000,
        });
        let err = eng
            .run(|_cy| StepResult::Running { progress: 7 })
            .unwrap_err();
        match err {
            SimError::Deadlock { stalled, .. } => assert!(stalled >= 50),
            other => panic!("wrong error: {other}"),
        }
    }

    #[test]
    fn cycle_limit_enforced() {
        let mut eng = Engine::new(Watchdog {
            stall_cycles: 1_000_000,
            max_cycles: 128,
        });
        let mut p = 0u64;
        let err = eng
            .run(|_cy| {
                p += 1;
                StepResult::Running { progress: p }
            })
            .unwrap_err();
        assert!(matches!(err, SimError::CycleLimit { max: 128 }));
    }

    #[test]
    fn progress_resets_watchdog() {
        let mut eng = Engine::new(Watchdog {
            stall_cycles: 10,
            max_cycles: 10_000,
        });
        let mut p = 0u64;
        let mut cycles = 0u64;
        let end = eng.run(|_cy| {
            cycles += 1;
            // advance progress only every 8 cycles — below the threshold
            if cycles % 8 == 0 {
                p += 1;
            }
            if cycles == 200 {
                StepResult::Done
            } else {
                StepResult::Running { progress: p }
            }
        });
        assert!(end.is_ok());
    }
}

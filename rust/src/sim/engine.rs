//! The clock loop: run a closure once per cycle until it reports
//! completion, with a deadlock watchdog (no observable progress for a
//! configurable number of cycles aborts the run — this is how the
//! fig. 2e deadlock scenario is *detected* when the commit protocol is
//! disabled).

use super::Cycle;

/// Outcome of stepping the system for one cycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepResult {
    /// Work remains; `progress` is a monotone counter of observable
    /// events (beats moved, commands retired) used by the watchdog.
    Running { progress: u64 },
    /// Work remains, and the stepped system proved that every cycle up
    /// to (excluding) `next` is a pure timer wait which it has already
    /// bulk-advanced — the clock jumps straight to `next` (§Perf event
    /// horizon). A skip counts as a single watchdog tick: skipped
    /// spans are productive by construction.
    SkipTo { progress: u64, next: Cycle },
    /// Simulation finished.
    Done,
}

/// Watchdog configuration.
#[derive(Debug, Clone, Copy)]
pub struct Watchdog {
    /// Abort if `progress` hasn't advanced for this many cycles.
    pub stall_cycles: u64,
    /// Hard cap on total cycles (safety net for runaway configs).
    pub max_cycles: u64,
}

impl Default for Watchdog {
    fn default() -> Watchdog {
        Watchdog {
            stall_cycles: 100_000,
            max_cycles: 2_000_000_000,
        }
    }
}

/// Post-mortem snapshot attached to a [`SimError::Deadlock`] by the
/// *stepped system* (the generic engine only owns the clock, so it
/// reports `None`; `occamy::Soc` fills this in before surfacing the
/// error). Everything here is an undrained obligation — the usual
/// wedge culprits, listed so a deadlock is diagnosable from the error
/// alone.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct DeadlockReport {
    /// Every component still busy at the stall: `(name, detail)` —
    /// e.g. its progress counter, or its undrained queue depths.
    pub busy: Vec<(String, String)>,
    /// Reservation tickets still live in the fabric ledger(s).
    pub resv_live_tickets: usize,
    /// Undrained per-node reservation claim-queue entries.
    pub resv_queued_claims: usize,
    /// Combine-table joins still open across all crossbars.
    pub open_reductions: usize,
    /// Completion-scoreboard legs still awaiting a B/R (only populated
    /// with `cpl_timeout` armed).
    pub open_cpl_legs: usize,
}

impl std::fmt::Display for DeadlockReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "  resv: {} live tickets, {} queued claims; reductions open: {}; \
             completion legs open: {}",
            self.resv_live_tickets,
            self.resv_queued_claims,
            self.open_reductions,
            self.open_cpl_legs
        )?;
        for (name, detail) in &self.busy {
            writeln!(f, "  busy: {name} ({detail})")?;
        }
        Ok(())
    }
}

/// Error raised when the watchdog fires.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SimError {
    Deadlock {
        cycle: Cycle,
        stalled: u64,
        progress: u64,
        /// Filled in by the stepped system (see [`DeadlockReport`]);
        /// `None` straight out of the engine.
        report: Option<Box<DeadlockReport>>,
    },
    CycleLimit { max: u64 },
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SimError::Deadlock {
                cycle,
                stalled,
                progress,
                report,
            } => {
                write!(
                    f,
                    "deadlock: no progress for {stalled} cycles at cycle {cycle} \
                     (progress counter {progress})"
                )?;
                if let Some(r) = report {
                    write!(f, "\n{r}")?;
                }
                Ok(())
            }
            SimError::CycleLimit { max } => write!(f, "cycle limit exceeded ({max} cycles)"),
        }
    }
}

impl std::error::Error for SimError {}

/// The simulation engine. Owns only the clock; all state lives in the
/// stepped closure's captures (the SoC or test fixture).
pub struct Engine {
    pub now: Cycle,
    pub watchdog: Watchdog,
}

impl Engine {
    pub fn new(watchdog: Watchdog) -> Engine {
        Engine { now: 0, watchdog }
    }

    /// Run `step(cycle)` until it returns `Done`. Returns the cycle count
    /// at completion.
    ///
    /// The watchdog counts *stepped* cycles without progress (for plain
    /// `Running` sequences this equals the elapsed-cycle criterion used
    /// before the event horizon existed); a `SkipTo` span counts as one
    /// tick because its cycles were proven to be pure timer waits.
    pub fn run<F: FnMut(Cycle) -> StepResult>(
        &mut self,
        mut step: F,
    ) -> Result<Cycle, SimError> {
        let mut last_progress = u64::MAX;
        let mut stall_ticks = 0u64;
        loop {
            let next = match step(self.now) {
                StepResult::Done => return Ok(self.now),
                StepResult::Running { progress } => {
                    self.watch(progress, &mut last_progress, &mut stall_ticks)?;
                    self.now + 1
                }
                StepResult::SkipTo { progress, next } => {
                    assert!(
                        next > self.now,
                        "SkipTo must advance the clock ({next} <= {})",
                        self.now
                    );
                    self.watch(progress, &mut last_progress, &mut stall_ticks)?;
                    next
                }
            };
            self.now = next;
            if self.now >= self.watchdog.max_cycles {
                return Err(SimError::CycleLimit {
                    max: self.watchdog.max_cycles,
                });
            }
        }
    }

    /// One watchdog tick: reset on progress, trip on sustained stall.
    fn watch(
        &self,
        progress: u64,
        last_progress: &mut u64,
        stall_ticks: &mut u64,
    ) -> Result<(), SimError> {
        if progress != *last_progress {
            *last_progress = progress;
            *stall_ticks = 0;
            return Ok(());
        }
        *stall_ticks += 1;
        if *stall_ticks >= self.watchdog.stall_cycles {
            return Err(SimError::Deadlock {
                cycle: self.now,
                stalled: *stall_ticks,
                progress,
                report: None,
            });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_to_completion() {
        let mut eng = Engine::new(Watchdog::default());
        let mut count = 0u64;
        let end = eng
            .run(|_cy| {
                count += 1;
                if count == 100 {
                    StepResult::Done
                } else {
                    StepResult::Running { progress: count }
                }
            })
            .unwrap();
        assert_eq!(end, 99);
    }

    #[test]
    fn watchdog_detects_stall() {
        let mut eng = Engine::new(Watchdog {
            stall_cycles: 50,
            max_cycles: 10_000,
        });
        let err = eng
            .run(|_cy| StepResult::Running { progress: 7 })
            .unwrap_err();
        match err {
            SimError::Deadlock { stalled, .. } => assert!(stalled >= 50),
            other => panic!("wrong error: {other}"),
        }
    }

    #[test]
    fn cycle_limit_enforced() {
        let mut eng = Engine::new(Watchdog {
            stall_cycles: 1_000_000,
            max_cycles: 128,
        });
        let mut p = 0u64;
        let err = eng
            .run(|_cy| {
                p += 1;
                StepResult::Running { progress: p }
            })
            .unwrap_err();
        assert!(matches!(err, SimError::CycleLimit { max: 128 }));
    }

    #[test]
    fn skip_to_jumps_the_clock() {
        let mut eng = Engine::new(Watchdog {
            stall_cycles: 10,
            max_cycles: 100_000,
        });
        let mut stepped = Vec::new();
        let end = eng
            .run(|cy| {
                stepped.push(cy);
                if cy >= 5_000 {
                    StepResult::Done
                } else if cy % 2 == 0 {
                    // pretend cycles (cy, cy+1000) are pure timer waits
                    StepResult::SkipTo {
                        progress: cy,
                        next: cy + 1_000,
                    }
                } else {
                    StepResult::Running { progress: cy }
                }
            })
            .unwrap();
        assert_eq!(end, 5_000);
        // only the stepped cycles paid wall-clock
        assert_eq!(stepped, vec![0, 1_000, 2_000, 3_000, 4_000, 5_000]);
    }

    #[test]
    fn skips_without_progress_do_not_trip_watchdog_early() {
        let mut eng = Engine::new(Watchdog {
            stall_cycles: 8,
            max_cycles: 1_000_000,
        });
        // progress never changes; each step skips 100 cycles. The
        // watchdog counts steps (8), not elapsed cycles (800).
        let err = eng
            .run(|cy| StepResult::SkipTo {
                progress: 7,
                next: cy + 100,
            })
            .unwrap_err();
        match err {
            SimError::Deadlock { stalled, cycle, .. } => {
                assert_eq!(stalled, 8);
                assert_eq!(cycle, 800);
            }
            other => panic!("wrong error: {other}"),
        }
    }

    #[test]
    fn deadlock_report_renders_in_display() {
        let err = SimError::Deadlock {
            cycle: 10,
            stalled: 5,
            progress: 0,
            report: Some(Box::new(DeadlockReport {
                busy: vec![("cluster0".into(), "progress=3".into())],
                resv_live_tickets: 2,
                resv_queued_claims: 4,
                open_reductions: 1,
                open_cpl_legs: 6,
            })),
        };
        let s = err.to_string();
        assert!(s.contains("no progress for 5 cycles"));
        assert!(s.contains("2 live tickets"));
        assert!(s.contains("4 queued claims"));
        assert!(s.contains("busy: cluster0 (progress=3)"));
    }

    #[test]
    fn progress_resets_watchdog() {
        let mut eng = Engine::new(Watchdog {
            stall_cycles: 10,
            max_cycles: 10_000,
        });
        let mut p = 0u64;
        let mut cycles = 0u64;
        let end = eng.run(|_cy| {
            cycles += 1;
            // advance progress only every 8 cycles — below the threshold
            if cycles % 8 == 0 {
                p += 1;
            }
            if cycles == 200 {
                StepResult::Done
            } else {
                StepResult::Running { progress: p }
            }
        });
        assert!(end.is_ok());
    }
}

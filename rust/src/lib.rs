//! # axi-mcast — multicast-capable AXI crossbar + Occamy SoC simulator
//!
//! Reproduction of *"A Multicast-Capable AXI Crossbar for Many-core
//! Machine Learning Accelerators"* (Colagrande & Benini, AICAS 2025).
//!
//! The crate is organised bottom-up (see `DESIGN.md`):
//!
//! * [`util`] — std-only substrates (PRNG, JSON, CLI, stats, property
//!   testing) written in-repo because the offline build only vendors the
//!   `xla` crate's dependency closure.
//! * [`sim`] — cycle-level simulation kernel: staged channels, the
//!   typed link pool, the component scheduler (generic idle-skips),
//!   the clock loop and watchdog.
//! * [`axi`] — the paper's §II-A contribution: AXI channel types, the
//!   mask-form multi-address encoding, the extended address decoder,
//!   the multicast-capable N×M crossbar (demux fork / mux commit /
//!   B-join / deadlock avoidance), and the topology subsystem building
//!   arbitrary hierarchical crossbar graphs (flat / trees / meshes).
//! * [`occamy`] — the paper's §II-B substrate: Snitch-like clusters with
//!   L1 SPM + DMA, LLC, narrow (64-bit) and wide (512-bit) two-level
//!   crossbar hierarchies, multicast interrupts and barriers.
//! * [`workloads`] — §III-B experiments: the 1-to-N DMA microbenchmark
//!   (fig. 3b) and the double-buffered tiled matmul (fig. 3c/3d).
//! * [`area`] — §III-A analytical gate-count/timing model (fig. 3a).
//! * [`runtime`] — PJRT CPU client loading the AOT JAX/Pallas artifacts
//!   (`artifacts/*.hlo.txt`) for functional numerics.
//! * [`coordinator`] — experiment orchestration, sweeps and reports.

pub mod area;
pub mod axi;
pub mod coordinator;
pub mod occamy;
pub mod runtime;
pub mod sim;
pub mod util;
pub mod workloads;

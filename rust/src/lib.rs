//! # axi-mcast — multicast-capable AXI crossbar + Occamy SoC simulator
//!
//! Reproduction of *"A Multicast-Capable AXI Crossbar for Many-core
//! Machine Learning Accelerators"* (Colagrande & Benini, AICAS 2025):
//! the mask-form multi-address AXI extension, the multicast N×M
//! crossbar with commit-based deadlock avoidance, a cycle-level model
//! of the 32-cluster Occamy accelerator built on it, and the paper's
//! full evaluation plus extension suites (topology shapes, collective
//! communication), regenerable offline via the `occamy-sim` binary.
//!
//! ## Quick start
//!
//! ```sh
//! cargo build --release
//! cargo test -q
//! cargo run --release --bin occamy-sim -- all --out results
//! ```
//!
//! ## Architecture map (bottom-up)
//!
//! The crate is layered; each module only uses the ones listed before
//! it (see `DESIGN.md` for the module map and the RTL-substitution
//! contract, `EXPERIMENTS.md` for how every number is regenerated):
//!
//! * [`util`] — std-only substrates (PRNG, JSON, CLI, stats, tables,
//!   property testing, inline vectors, dense txn tables) written
//!   in-repo because the offline build vendors no general-purpose
//!   crates.
//! * [`sim`] — cycle-level simulation kernel: staged channels, the
//!   typed link pool, the component scheduler (generic idle-skips),
//!   the clock loop, watchdog and event-horizon fast-forwarding.
//! * [`axi`] — the paper's §II-A contribution: AXI channel types, the
//!   mask-form multi-address encoding, the extended address decoder,
//!   the multicast-capable N×M crossbar (demux fork / mux commit /
//!   B-join / deadlock avoidance), the fabric-wide two-phase
//!   reservation ledger ([`axi::resv`] — end-to-end multicast ordering
//!   across hierarchy levels, unlocking concurrent global multicasts),
//!   the in-network reduction subsystem ([`axi::reduce`] — fabric-side
//!   combining of converging tagged write bursts, the dual of the
//!   multicast fork), and the topology subsystem building arbitrary
//!   crossbar graphs (flat / K-ary trees / meshes, with service
//!   windows on the root or host tile).
//! * [`occamy`] — the paper's §II-B substrate: Snitch-like clusters
//!   with L1 SPM + DMA, LLC, wide (512-bit) and narrow (64-bit)
//!   networks in any [`occamy::WideShape`], multicast interrupts and
//!   barriers, and the functional memory carrying the data half of the
//!   simulation.
//! * [`workloads`] — §III-B experiments and extensions: the 1-to-N DMA
//!   microbenchmark (fig. 3b), the double-buffered tiled matmul
//!   (fig. 3c/3d), the roofline model, the topology-shape broadcast
//!   sweep, and the collective-communication suite
//!   ([`workloads::collectives`]: broadcast / all-gather /
//!   reduce-scatter / all-reduce; software baselines vs
//!   single-multicast vs `hw-concurrent` — N simultaneous global
//!   multicasts on the reservation protocol — vs `hw-reduce` —
//!   in-network reduction, zero software combines — with bit-exact
//!   reduction validation).
//! * [`area`] — §III-A analytical gate-count/timing model (fig. 3a).
//! * [`runtime`] — PJRT CPU client loading the AOT JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`) for functional numerics
//!   (feature `pjrt`; a stub keeps the default build std-only).
//! * [`coordinator`] — experiment orchestration, sweeps and reports.

#[cfg(all(feature = "pjrt", feature = "pjrt-off-guard"))]
compile_error!(
    "`pjrt-off-guard` asserts the offline stub build: disable the `pjrt` \
     feature (the guard exists so CI can build the non-default cfg \
     combination explicitly)"
);

pub mod area;
pub mod axi;
pub mod coordinator;
pub mod occamy;
pub mod runtime;
pub mod sim;
pub mod util;
pub mod workloads;

//! Declarative topology subsystem: build arbitrary hierarchical graphs
//! of multicast crossbars over one shared [`LinkPool`].
//!
//! Before this module the two-level Occamy shape was hard-wired in
//! `occamy::noc`; the builder makes topology *data*:
//!
//! * [`TopologyBuilder`] — the low-level graph API: add crossbar nodes,
//!   wire slave→master ports with fresh pool links, expose named
//!   external ports, then [`TopologyBuilder::build`] (every port must
//!   be wired exactly once).
//! * [`build_tree`] — K-ary trees of any depth over a uniform endpoint
//!   array, with hierarchical exclude-scope multicast routing at every
//!   level. `arity = [n]` degenerates to a flat N×M crossbar;
//!   `arity = [4, 8]` is the paper's Occamy group/top pair
//!   (`occamy::noc::build_network` is one instance of it);
//!   deeper arities give 3+-level hierarchies (the scope-merge rule in
//!   `XbarCfg::decode_aw` keeps pruning exact).
//! * [`build_mesh`] — a fully-connected mesh of peer crossbar tiles
//!   with direct per-region routes (no default port, no scopes): a
//!   multicast decomposes into per-tile mask-form subsets at the source
//!   tile, one hop to every peer.
//! * [`build_ring`] — a bidirectional ring of equal nodes routed
//!   span-ordered (dateline-style, see `xbar::RingLevel`): a multicast
//!   forks into at most one descending and one ascending leg, each
//!   carrying an include *window* that shrinks hop by hop.
//! * [`build_torus2d`] — a 2-D torus, row-major with the X dimension
//!   innermost: Y legs distribute whole rows, X legs distribute within
//!   a row, dimension-ordered so every node is visited at most once.
//! * [`build_ring_mesh`] — rings of fully-connected mesh groups: each
//!   group is a [`build_mesh`]-style tile cluster whose tile 0 is the
//!   **gateway** carrying the group's ring ports; in-group traffic
//!   takes direct peer routes, everything else funnels through the
//!   gateway onto the ring.
//!
//! All shapes deliver a given multicast request to exactly the decoded
//! endpoint set, exactly once — the parity suites in
//! `tests/topology_parity.rs` check beat-set equality across shapes
//! against the flat golden reference.

use super::addr_map::{AddrMap, AddrRule};
use super::mux::ArbPolicy;
use super::reduce::{RedNode, ReduceHandle, ReduceLedger};
use super::resv::{ResvHandle, ResvLedger, ResvNode};
use super::types::{AxiLink, LinkId, LinkPool};
use super::xbar::{RingLevel, Xbar, XbarCfg, XbarStats};
use crate::sim::link::D2dParams;
use crate::sim::sched::Scheduler;
use crate::sim::Cycle;

/// Handle to a crossbar node inside a builder/topology (index into
/// `Topology::xbars`, stable across `build`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct NodeId(pub usize);

struct NodeSpec {
    cfg: XbarCfg,
    m_ports: Vec<Option<LinkId>>,
    s_ports: Vec<Option<LinkId>>,
}

/// Low-level declarative graph builder.
pub struct TopologyBuilder<'p> {
    name: String,
    pool: &'p mut LinkPool,
    link_depth: usize,
    nodes: Vec<NodeSpec>,
    ext_m: Vec<(String, LinkId)>,
    ext_s: Vec<(String, LinkId)>,
    /// Inter-node wiring `(from, from_slave_port, to)` — mirrored into
    /// the reservation ledger so its traversal oracle walks the same
    /// graph the beats do.
    edges: Vec<(NodeId, usize, NodeId)>,
    /// Links allocated by [`TopologyBuilder::connect_d2d`] — the
    /// die-to-die hops of a chiplet package.
    d2d_links: Vec<LinkId>,
}

impl<'p> TopologyBuilder<'p> {
    pub fn new(name: &str, pool: &'p mut LinkPool, link_depth: usize) -> TopologyBuilder<'p> {
        TopologyBuilder {
            name: name.to_string(),
            pool,
            link_depth,
            nodes: Vec::new(),
            ext_m: Vec::new(),
            ext_s: Vec::new(),
            edges: Vec::new(),
            d2d_links: Vec::new(),
        }
    }

    fn fresh_link(&mut self) -> LinkId {
        self.pool.alloc(AxiLink::new(self.link_depth))
    }

    /// Add a crossbar node; its ports start unwired.
    pub fn node(&mut self, cfg: XbarCfg) -> NodeId {
        let (nm, ns) = (cfg.n_masters, cfg.n_slaves);
        self.nodes.push(NodeSpec {
            cfg,
            m_ports: vec![None; nm],
            s_ports: vec![None; ns],
        });
        NodeId(self.nodes.len() - 1)
    }

    fn bind_m(&mut self, node: NodeId, port: usize, link: LinkId) {
        let slot = &mut self.nodes[node.0].m_ports[port];
        assert!(
            slot.is_none(),
            "{}: node {} master port {port} wired twice",
            self.name,
            node.0
        );
        *slot = Some(link);
    }

    fn bind_s(&mut self, node: NodeId, port: usize, link: LinkId) {
        let slot = &mut self.nodes[node.0].s_ports[port];
        assert!(
            slot.is_none(),
            "{}: node {} slave port {port} wired twice",
            self.name,
            node.0
        );
        *slot = Some(link);
    }

    /// Wire `from`'s slave port into `to`'s master port with a fresh
    /// link (requests flow from→to; responses back).
    pub fn connect(&mut self, from: NodeId, s_port: usize, to: NodeId, m_port: usize) -> LinkId {
        let l = self.fresh_link();
        self.bind_s(from, s_port, l);
        self.bind_m(to, m_port, l);
        self.edges.push((from, s_port, to));
        l
    }

    /// Wire `from`'s slave port into `to`'s master port with a
    /// die-to-die link ([`AxiLink::d2d`]): the channels carry the
    /// SerDes pipeline latency and the data channels serialize at the
    /// width-conversion rate. The edge is recorded exactly like
    /// [`TopologyBuilder::connect`], so the reservation and reduction
    /// ledgers' traversal oracles walk through D2D gateways
    /// transparently — one package-global ticket order and cross-die
    /// membership plans fall out of the shared graph.
    pub fn connect_d2d(
        &mut self,
        from: NodeId,
        s_port: usize,
        to: NodeId,
        m_port: usize,
        params: &D2dParams,
    ) -> LinkId {
        params
            .check()
            .unwrap_or_else(|e| panic!("{}: connect_d2d: {e}", self.name));
        let l = self.pool.alloc(AxiLink::d2d(params));
        self.bind_s(from, s_port, l);
        self.bind_m(to, m_port, l);
        self.edges.push((from, s_port, to));
        self.d2d_links.push(l);
        l
    }

    /// Expose a master port to an external device (the device pushes
    /// requests into the returned link).
    pub fn ext_master(&mut self, node: NodeId, m_port: usize, name: &str) -> LinkId {
        let l = self.fresh_link();
        self.bind_m(node, m_port, l);
        self.ext_m.push((name.to_string(), l));
        l
    }

    /// Expose a slave port to an external device (the fabric delivers
    /// requests on the returned link).
    pub fn ext_slave(&mut self, node: NodeId, s_port: usize, name: &str) -> LinkId {
        let l = self.fresh_link();
        self.bind_s(node, s_port, l);
        self.ext_s.push((name.to_string(), l));
        l
    }

    /// Instantiate the crossbars. Panics on any unwired port — a
    /// topology with dangling ports would deadlock silently.
    ///
    /// When any node requests `XbarCfg::e2e_mcast_order`, a shared
    /// [`ResvLedger`] is built over the whole graph (every node
    /// registered, every [`TopologyBuilder::connect`] edge mirrored)
    /// and attached to every crossbar — the fabric-wide reservation
    /// protocol needs the complete routing graph no matter where a
    /// multicast enters, for trees and meshes alike.
    pub fn build(self) -> Topology {
        let name = self.name;
        // The reservation protocol orders commits at EVERY node a
        // multicast traverses: a flag-off node would neither stamp
        // tickets nor respect claim order, wedging its neighbours.
        // Mixed flags are a misconfiguration, refused loudly.
        let n_e2e = self
            .nodes
            .iter()
            .filter(|n| n.cfg.e2e_mcast_order)
            .count();
        assert!(
            n_e2e == 0 || n_e2e == self.nodes.len(),
            "{name}: e2e_mcast_order must be uniform across the topology \
             ({n_e2e} of {} nodes set it)",
            self.nodes.len()
        );
        // Same argument for in-network reduction: a flag-off node would
        // neither combine nor know the membership plan, so a group
        // whose converging tree crosses it would over-deliver at the
        // destination's join count. Mixed flags are refused loudly.
        let n_red = self
            .nodes
            .iter()
            .filter(|n| n.cfg.fabric_reduce)
            .count();
        assert!(
            n_red == 0 || n_red == self.nodes.len(),
            "{name}: fabric_reduce must be uniform across the topology \
             ({n_red} of {} nodes set it)",
            self.nodes.len()
        );
        let mut xbars: Vec<Xbar> = self
            .nodes
            .into_iter()
            .enumerate()
            .map(|(n, spec)| {
                let m: Vec<LinkId> = spec
                    .m_ports
                    .into_iter()
                    .enumerate()
                    .map(|(p, l)| {
                        l.unwrap_or_else(|| {
                            panic!("{name}: node {n} master port {p} left unwired")
                        })
                    })
                    .collect();
                let s: Vec<LinkId> = spec
                    .s_ports
                    .into_iter()
                    .enumerate()
                    .map(|(p, l)| {
                        l.unwrap_or_else(|| panic!("{name}: node {n} slave port {p} left unwired"))
                    })
                    .collect();
                Xbar::new(spec.cfg, m, s)
            })
            .collect();
        let resv = if xbars.iter().any(|x| x.cfg.e2e_mcast_order) {
            let mut ledger = ResvLedger::new();
            let nodes: Vec<ResvNode> = xbars.iter().map(|x| ledger.register(&x.cfg)).collect();
            for &(from, s_port, to) in &self.edges {
                ledger.wire(nodes[from.0], s_port, nodes[to.0]);
            }
            let handle = ledger.into_handle();
            for (x, &node) in xbars.iter_mut().zip(&nodes) {
                x.attach_resv(handle.clone(), node);
            }
            Some(handle)
        } else {
            None
        };
        let reduce = if xbars.iter().any(|x| x.cfg.fabric_reduce) {
            // the in-network-reduction membership oracle mirrors the
            // reservation ledger's wiring: every node registered (node
            // id == crossbar index), every connect() edge declared
            let mut ledger = ReduceLedger::new();
            let nodes: Vec<RedNode> = xbars.iter().map(|x| ledger.register(&x.cfg)).collect();
            for &(from, s_port, to) in &self.edges {
                ledger.wire(nodes[from.0], s_port, nodes[to.0]);
            }
            let handle = ledger.into_handle();
            for (x, &node) in xbars.iter_mut().zip(&nodes) {
                x.attach_reduce(handle.clone(), node);
            }
            Some(handle)
        } else {
            None
        };
        Topology {
            name,
            xbars,
            ext_m: self.ext_m,
            ext_s: self.ext_s,
            resv,
            reduce,
            d2d_links: self.d2d_links,
        }
    }
}

/// A built crossbar graph.
pub struct Topology {
    pub name: String,
    pub xbars: Vec<Xbar>,
    ext_m: Vec<(String, LinkId)>,
    ext_s: Vec<(String, LinkId)>,
    /// The shared reservation ledger (present iff any node was built
    /// with `e2e_mcast_order`) — exposed for observability: live
    /// tickets, per-node claim queues, ledger stats.
    pub resv: Option<ResvHandle>,
    /// The in-network-reduction membership oracle (present iff any
    /// node was built with `fabric_reduce`): reduction groups are
    /// opened on it ([`ReduceLedger::open_group`]) before their
    /// contributors start writing.
    pub reduce: Option<ReduceHandle>,
    /// The die-to-die links of the graph, in
    /// [`TopologyBuilder::connect_d2d`] order (empty on single-die
    /// fabrics) — exposed for gateway-traffic accounting and for the
    /// parallel engine's per-die sharding.
    pub d2d_links: Vec<LinkId>,
}

impl Topology {
    pub fn ext_masters(&self) -> &[(String, LinkId)] {
        &self.ext_m
    }

    pub fn ext_slaves(&self) -> &[(String, LinkId)] {
        &self.ext_s
    }

    /// Look up a named external master link.
    pub fn ext_master(&self, name: &str) -> LinkId {
        self.ext_m
            .iter()
            .find(|(n, _)| n == name)
            .unwrap_or_else(|| panic!("{}: no external master '{name}'", self.name))
            .1
    }

    /// Look up a named external slave link.
    pub fn ext_slave(&self, name: &str) -> LinkId {
        self.ext_s
            .iter()
            .find(|(n, _)| n == name)
            .unwrap_or_else(|| panic!("{}: no external slave '{name}'", self.name))
            .1
    }

    /// Advance every crossbar one cycle (unscheduled).
    pub fn step(&mut self, pool: &mut LinkPool) {
        for x in &mut self.xbars {
            x.step(pool);
        }
    }

    /// Advance with idle-skips through the generic scheduler.
    pub fn step_scheduled(&mut self, cy: Cycle, pool: &mut LinkPool, sched: &mut Scheduler) {
        step_xbars_scheduled(&mut self.xbars, cy, pool, sched);
    }

    /// Precise in-flight check (scans crossbar state).
    pub fn busy(&self) -> bool {
        self.xbars.iter().any(|x| x.busy())
    }

    /// Cheap cached busy check (updated whenever an xbar steps).
    pub fn maybe_busy(&self) -> bool {
        self.xbars.iter().any(|x| x.maybe_busy)
    }

    /// Event horizon over all crossbars (§Perf).
    pub fn next_event(&self, now: Cycle) -> Option<Cycle> {
        self.xbars.iter().filter_map(|x| x.next_event(now)).min()
    }

    /// Bulk-advance `k` pure-wait cycles on every non-quiescent xbar.
    pub fn skip(&mut self, k: u64) {
        for x in &mut self.xbars {
            x.skip(k);
        }
    }

    /// Aggregate statistics over all crossbars.
    pub fn stats_sum(&self) -> XbarStats {
        sum_xbar_stats(&self.xbars)
    }
}

/// Step a crossbar set with idle-skips (shared by [`Topology`] and
/// `occamy::noc::Network`, which flattens a topology).
pub fn step_xbars_scheduled(
    xbars: &mut [Xbar],
    cy: Cycle,
    pool: &mut LinkPool,
    sched: &mut Scheduler,
) {
    for x in xbars {
        sched.step_component(cy, x, pool);
    }
}

/// Aggregate statistics over a crossbar set.
pub fn sum_xbar_stats(xbars: &[Xbar]) -> XbarStats {
    let mut acc = XbarStats::default();
    for x in xbars {
        acc.add(&x.stats);
    }
    acc
}

// ---------------------------------------------------------------- shapes

/// Uniform array of endpoint windows: endpoint `i` owns
/// `[base + i*stride, base + (i+1)*stride)`. `stride` must be a power
/// of two and `base` aligned to every aggregate the shapes form, so any
/// power-of-two endpoint group is one mask-form rule.
#[derive(Debug, Clone)]
pub struct EndpointMap {
    pub base: u64,
    pub stride: u64,
    pub count: usize,
}

impl EndpointMap {
    pub fn addr(&self, i: usize) -> u64 {
        self.base + i as u64 * self.stride
    }

    /// `[start, end)` region of endpoints `[first, first+count)`.
    pub fn region(&self, first: usize, count: usize) -> (u64, u64) {
        (self.addr(first), self.addr(first + count))
    }

    fn rule(&self, i: usize, slave: usize) -> AddrRule {
        AddrRule::new(self.addr(i), self.addr(i + 1), slave, &format!("ep{i}")).with_mcast()
    }
}

/// Knobs shared by the canned shape builders (a strict subset of
/// [`XbarCfg`]; everything else keeps the crossbar defaults, with a
/// per-node `tune` hook for the rest).
#[derive(Debug, Clone)]
pub struct FabricParams {
    pub mcast_enabled: bool,
    pub commit_protocol: bool,
    pub mcast_w_cooldown: u32,
    /// §Perf reference mode: build the crossbars with their worklist /
    /// dense-table fast paths disabled (see `XbarCfg::force_naive`).
    pub force_naive: bool,
    /// Fabric-wide two-phase reservation protocol
    /// (`XbarCfg::e2e_mcast_order`): [`TopologyBuilder::build`] wires a
    /// shared [`ResvLedger`] across every node, unlocking concurrent
    /// global multicasts. Off = the RTL-faithful per-crossbar protocol.
    pub e2e_mcast_order: bool,
    /// In-network reduction (`XbarCfg::fabric_reduce`):
    /// [`TopologyBuilder::build`] wires a shared [`ReduceLedger`]
    /// membership oracle across every node, so converging tagged write
    /// bursts are combined at the fabric's join points. Off = the
    /// RTL-faithful fabric (reductions resolve at the endpoints).
    pub fabric_reduce: bool,
    /// Worker threads for harnesses that step the fabric themselves
    /// (`workloads::topo_sweep`): 1 = the sequential golden schedule,
    /// 0 = one per core, N > 1 = exactly N. Purely a wall-clock knob —
    /// results stay bit-identical (see [`crate::sim::parallel`]). Not
    /// an [`XbarCfg`] field: the fabric is oblivious to how it is
    /// stepped. Defaults from `OCCAMY_THREADS`.
    pub threads: usize,
    /// Per-master outstanding cap applied to every node (`None` keeps
    /// the `XbarCfg` default). The fabric's converging point — tree
    /// root / every mesh tile — takes [`FabricParams::root_outstanding`]
    /// instead when that is set.
    pub max_outstanding: Option<u32>,
    /// Per-master same-set multicast cap, same scoping rules.
    pub max_mcast_outstanding: Option<u32>,
    /// Outstanding cap override for the converging point (tree root /
    /// every mesh tile, which is both leaf and root).
    pub root_outstanding: Option<u32>,
    /// Multicast cap override for the converging point.
    pub root_mcast_outstanding: Option<u32>,
    /// Request deadline (`XbarCfg::req_timeout`), every node.
    pub req_timeout: Option<u32>,
    /// Completion deadline (`XbarCfg::cpl_timeout`), every node.
    pub cpl_timeout: Option<u32>,
    /// Arbitration policy (`XbarCfg::arb_policy`), every node.
    pub arb_policy: ArbPolicy,
    /// Static QoS priority per *endpoint* (missing entries = 0). The
    /// builders map it onto each node's master ports: an endpoint-
    /// facing port carries its endpoint's priority; an aggregated port
    /// (tree child / mesh peer) carries the max priority of the
    /// endpoints behind it; a tree down-in port carries the max of the
    /// endpoints *outside* the node's span (descending traffic keeps
    /// its tier). Empty = all zero (pure round-robin tiebreak).
    pub endpoint_prio: Vec<u32>,
}

impl Default for FabricParams {
    fn default() -> FabricParams {
        FabricParams {
            mcast_enabled: true,
            commit_protocol: true,
            mcast_w_cooldown: 1,
            force_naive: crate::util::force_naive_env(),
            e2e_mcast_order: false,
            fabric_reduce: false,
            threads: crate::util::threads_env().unwrap_or(1),
            max_outstanding: None,
            max_mcast_outstanding: None,
            root_outstanding: None,
            root_mcast_outstanding: None,
            req_timeout: None,
            cpl_timeout: None,
            arb_policy: ArbPolicy::RoundRobin,
            endpoint_prio: Vec::new(),
        }
    }
}

impl FabricParams {
    fn apply(&self, cfg: &mut XbarCfg) {
        cfg.mcast_enabled = self.mcast_enabled;
        cfg.commit_protocol = self.commit_protocol;
        cfg.mcast_w_cooldown = self.mcast_w_cooldown;
        cfg.force_naive = self.force_naive;
        cfg.e2e_mcast_order = self.e2e_mcast_order;
        cfg.fabric_reduce = self.fabric_reduce;
        if let Some(v) = self.max_outstanding {
            cfg.max_outstanding = v;
        }
        if let Some(v) = self.max_mcast_outstanding {
            cfg.max_mcast_outstanding = v;
        }
        cfg.req_timeout = self.req_timeout;
        cfg.cpl_timeout = self.cpl_timeout;
        cfg.arb_policy = self.arb_policy;
    }

    /// Converging-point overrides (tree root / mesh tile).
    fn apply_root(&self, cfg: &mut XbarCfg) {
        if let Some(v) = self.root_outstanding {
            cfg.max_outstanding = v;
        }
        if let Some(v) = self.root_mcast_outstanding {
            cfg.max_mcast_outstanding = v;
        }
    }

    fn prio_of(&self, ep: usize) -> u32 {
        self.endpoint_prio.get(ep).copied().unwrap_or(0)
    }

    /// Max priority over endpoints `[first, first + count)`.
    fn prio_max(&self, first: usize, count: usize) -> u32 {
        (first..first + count).map(|e| self.prio_of(e)).max().unwrap_or(0)
    }

    /// Max priority over every endpoint *outside* `[first, first + count)`.
    fn prio_max_outside(&self, first: usize, count: usize, total: usize) -> u32 {
        (0..total)
            .filter(|e| *e < first || *e >= first + count)
            .map(|e| self.prio_of(e))
            .max()
            .unwrap_or(0)
    }
}

/// A K-ary tree specification. `arity` lists children-per-node bottom-up:
/// `arity[0]` endpoints per leaf crossbar, `arity[1]` leaves per next
/// level, …; the product must equal `endpoints.count` so the final
/// level is a single root. Extra root-level ports model service
/// devices (LLC, barrier peripheral) and extra injectors (barrier
/// unit's own master port).
#[derive(Debug, Clone)]
pub struct TreeSpec {
    pub name: String,
    pub endpoints: EndpointMap,
    pub arity: Vec<usize>,
    pub params: FabricParams,
    /// Root-level service windows `(start, end, name)` — plain unicast
    /// rules (not multicast-capable), one slave port each.
    pub services: Vec<(u64, u64, String)>,
    /// Extra master ports on the root node (named `top{i}-m`).
    pub n_root_masters: usize,
}

/// A tree topology plus its endpoint/service link handles.
pub struct TreeTopology {
    pub topo: Topology,
    /// Per endpoint: the link its master drives requests into.
    pub endpoint_m: Vec<LinkId>,
    /// Per endpoint: the link delivering requests to its slave port.
    pub endpoint_s: Vec<LinkId>,
    /// Per endpoint: the crossbar node its ports attach to (the
    /// endpoint's fabric entry — node ids double as `RedNode`s /
    /// `ResvNode`s, registration order being build order).
    pub endpoint_nodes: Vec<NodeId>,
    /// One per `TreeSpec::services` entry, in order.
    pub service_s: Vec<LinkId>,
    /// One per extra root master port.
    pub root_m: Vec<LinkId>,
    /// Root node (also `topo.xbars.last()`).
    pub root: NodeId,
}

/// Build a hierarchical tree; `tune(cfg, level)` may adjust each node's
/// crossbar knobs (level 0 = leaves, `arity.len() - 1` = root).
pub fn build_tree(
    pool: &mut LinkPool,
    link_depth: usize,
    spec: &TreeSpec,
    mut tune: impl FnMut(&mut XbarCfg, usize),
) -> TreeTopology {
    let eps = &spec.endpoints;
    assert!(!spec.arity.is_empty(), "{}: empty arity", spec.name);
    assert!(
        eps.stride.is_power_of_two(),
        "{}: endpoint stride must be a power of two",
        spec.name
    );
    let levels = spec.arity.len();
    // nodes per level and endpoints covered per node
    let mut n_nodes = Vec::with_capacity(levels);
    let mut span = Vec::with_capacity(levels); // endpoints per node
    let mut cover = 1usize;
    for (l, &a) in spec.arity.iter().enumerate() {
        assert!(a >= 1, "{}: arity[{l}] must be >= 1", spec.name);
        cover *= a;
        assert_eq!(
            eps.count % cover,
            0,
            "{}: arity prefix {cover} must divide {} endpoints",
            spec.name,
            eps.count
        );
        span.push(cover);
        n_nodes.push(eps.count / cover);
    }
    assert_eq!(
        n_nodes[levels - 1],
        1,
        "{}: arity product must equal the endpoint count (single root)",
        spec.name
    );

    let mut b = TopologyBuilder::new(&spec.name, pool, link_depth);

    // --- leaf level: endpoint rules ---
    let mut endpoint_m = Vec::with_capacity(eps.count);
    let mut endpoint_s = Vec::with_capacity(eps.count);
    let mut endpoint_nodes = Vec::with_capacity(eps.count);
    let a0 = spec.arity[0];
    let is_root_level = |l: usize| l == levels - 1;
    let mut level_nodes: Vec<NodeId> = Vec::new();
    for g in 0..n_nodes[0] {
        let first = g * a0;
        let rules: Vec<AddrRule> = (0..a0).map(|i| eps.rule(first + i, i)).collect();
        let root = is_root_level(0);
        let extra_s = if root { spec.services.len() } else { 1 };
        let extra_m = if root { spec.n_root_masters } else { 1 };
        let mut rules = rules;
        if root {
            for (si, (s, e, name)) in spec.services.iter().enumerate() {
                rules.push(AddrRule::new(*s, *e, a0 + si, name));
            }
        }
        let n_slaves = a0 + extra_s;
        let n_masters = a0 + extra_m;
        let map = AddrMap::new(rules, n_slaves)
            .unwrap_or_else(|e| panic!("{}: leaf {g} map: {e}", spec.name));
        let mut cfg = XbarCfg::new(&format!("{}-l0n{}", spec.name, g), n_masters, n_slaves, map);
        spec.params.apply(&mut cfg);
        if root {
            spec.params.apply_root(&mut cfg);
        }
        if !spec.params.endpoint_prio.is_empty() {
            // endpoint-facing ports carry their endpoint's priority;
            // the down-in port carries the rest of the fabric's max
            let mut prio: Vec<u32> = (0..a0).map(|i| spec.params.prio_of(first + i)).collect();
            if !root {
                prio.push(spec.params.prio_max_outside(first, a0, eps.count));
            }
            cfg.master_prio = prio;
        }
        if !root {
            cfg.default_slave = Some(a0);
            cfg.local_scope = Some(eps.region(first, a0));
        }
        tune(&mut cfg, 0);
        let node = b.node(cfg);
        for i in 0..a0 {
            endpoint_m.push(b.ext_master(node, i, &format!("ep{}-m", first + i)));
            endpoint_s.push(b.ext_slave(node, i, &format!("ep{}-s", first + i)));
            endpoint_nodes.push(node);
        }
        level_nodes.push(node);
    }

    // --- upper levels: child-region rules ---
    for l in 1..levels {
        let al = spec.arity[l];
        let child_span = span[l - 1];
        let root = is_root_level(l);
        let mut next_nodes = Vec::with_capacity(n_nodes[l]);
        for k in 0..n_nodes[l] {
            let first_ep = k * span[l];
            let mut rules: Vec<AddrRule> = (0..al)
                .map(|j| {
                    let (s, e) = eps.region(first_ep + j * child_span, child_span);
                    AddrRule::new(s, e, j, &format!("child{j}")).with_mcast()
                })
                .collect();
            let extra_s = if root { spec.services.len() } else { 1 };
            let extra_m = if root { spec.n_root_masters } else { 1 };
            if root {
                for (si, (s, e, name)) in spec.services.iter().enumerate() {
                    rules.push(AddrRule::new(*s, *e, al + si, name));
                }
            }
            let n_slaves = al + extra_s;
            let n_masters = al + extra_m;
            let map = AddrMap::new(rules, n_slaves)
                .unwrap_or_else(|e| panic!("{}: level {l} node {k} map: {e}", spec.name));
            let mut cfg =
                XbarCfg::new(&format!("{}-l{}n{}", spec.name, l, k), n_masters, n_slaves, map);
            spec.params.apply(&mut cfg);
            if root {
                spec.params.apply_root(&mut cfg);
            }
            if !spec.params.endpoint_prio.is_empty() {
                // child port j aggregates its subtree's endpoints
                let mut prio: Vec<u32> = (0..al)
                    .map(|j| spec.params.prio_max(first_ep + j * child_span, child_span))
                    .collect();
                if !root {
                    prio.push(spec.params.prio_max_outside(first_ep, span[l], eps.count));
                }
                cfg.master_prio = prio;
            }
            if !root {
                cfg.default_slave = Some(al);
                cfg.local_scope = Some(eps.region(first_ep, span[l]));
            }
            tune(&mut cfg, l);
            let node = b.node(cfg);
            // wire the children: child j's up-out slave port feeds this
            // node's master port j; this node's slave port j feeds child
            // j's down-in master port.
            let child_a = spec.arity[l - 1];
            for j in 0..al {
                let child = level_nodes[k * al + j];
                b.connect(child, child_a, node, j);
                b.connect(node, j, child, child_a);
            }
            next_nodes.push(node);
        }
        level_nodes = next_nodes;
    }

    let root = *level_nodes.last().expect("tree has a root");
    let root_al = spec.arity[levels - 1];
    let service_s: Vec<LinkId> = spec
        .services
        .iter()
        .enumerate()
        .map(|(si, (_, _, name))| b.ext_slave(root, root_al + si, name))
        .collect();
    let root_m: Vec<LinkId> = (0..spec.n_root_masters)
        .map(|i| b.ext_master(root, root_al + i, &format!("top{i}-m")))
        .collect();

    TreeTopology {
        topo: b.build(),
        endpoint_m,
        endpoint_s,
        endpoint_nodes,
        service_s,
        root_m,
        root,
    }
}

/// A fully-connected mesh of `tiles` peer crossbars, each owning a
/// contiguous aligned block of endpoints with direct point-to-point
/// routes to every other tile's region.
#[derive(Debug, Clone)]
pub struct MeshSpec {
    pub name: String,
    pub endpoints: EndpointMap,
    pub tiles: usize,
    pub params: FabricParams,
    /// Service windows `(start, end, name)` hosted on tile 0 — plain
    /// unicast rules, one extra slave port each on the host tile; every
    /// other tile routes the window through its direct link to tile 0
    /// (the mesh counterpart of the tree's root services).
    pub services: Vec<(u64, u64, String)>,
}

pub struct MeshTopology {
    pub topo: Topology,
    pub endpoint_m: Vec<LinkId>,
    pub endpoint_s: Vec<LinkId>,
    /// Per endpoint: the tile node it attaches to (see
    /// `TreeTopology::endpoint_nodes`).
    pub endpoint_nodes: Vec<NodeId>,
    /// One per [`MeshSpec::services`] entry, in order (all on tile 0).
    pub service_s: Vec<LinkId>,
}

/// Build a fully-connected mesh; `tune(cfg, tile)` may adjust each
/// tile's crossbar knobs before instantiation (mirrors [`build_tree`]'s
/// per-level hook).
pub fn build_mesh(
    pool: &mut LinkPool,
    link_depth: usize,
    spec: &MeshSpec,
    mut tune: impl FnMut(&mut XbarCfg, usize),
) -> MeshTopology {
    let eps = &spec.endpoints;
    let t = spec.tiles;
    assert!(t >= 2, "{}: a mesh needs at least 2 tiles", spec.name);
    assert_eq!(
        eps.count % t,
        0,
        "{}: tiles must divide the endpoint count",
        spec.name
    );
    let e = eps.count / t;
    let mut b = TopologyBuilder::new(&spec.name, pool, link_depth);

    // nodes first (ports: masters = e locals + t-1 peers-in;
    // slaves = e locals + t-1 peers-out [+ services on tile 0])
    let mut nodes = Vec::with_capacity(t);
    for q in 0..t {
        let first = q * e;
        let mut rules: Vec<AddrRule> = (0..e).map(|i| eps.rule(first + i, i)).collect();
        let mut port = e;
        for p in 0..t {
            if p == q {
                continue;
            }
            let (s, end) = eps.region(p * e, e);
            rules.push(AddrRule::new(s, end, port, &format!("tile{p}")).with_mcast());
            port += 1;
        }
        // service windows: dedicated slave ports on the host tile; the
        // other tiles reuse their direct route to tile 0
        let to_tile0 = e; // out_port(q, 0) for q > 0
        for (si, (s, end, name)) in spec.services.iter().enumerate() {
            let slave = if q == 0 { e + t - 1 + si } else { to_tile0 };
            rules.push(AddrRule::new(*s, *end, slave, name));
        }
        let n_slaves = e + t - 1 + if q == 0 { spec.services.len() } else { 0 };
        let n_masters = e + t - 1;
        let map = AddrMap::new(rules, n_slaves)
            .unwrap_or_else(|err| panic!("{}: tile {q} map: {err}", spec.name));
        let mut cfg = XbarCfg::new(&format!("{}-t{}", spec.name, q), n_masters, n_slaves, map);
        spec.params.apply(&mut cfg);
        // every mesh tile is both leaf and converging point
        spec.params.apply_root(&mut cfg);
        if !spec.params.endpoint_prio.is_empty() {
            // locals carry their own priority, peer ports the max of
            // the sending tile's endpoints
            let mut prio: Vec<u32> = (0..e).map(|i| spec.params.prio_of(first + i)).collect();
            for p in (0..t).filter(|&p| p != q) {
                prio.push(spec.params.prio_max(p * e, e));
            }
            cfg.master_prio = prio;
        }
        tune(&mut cfg, q);
        nodes.push(b.node(cfg));
    }

    // endpoint ports
    let mut endpoint_m = Vec::with_capacity(eps.count);
    let mut endpoint_s = Vec::with_capacity(eps.count);
    let mut endpoint_nodes = Vec::with_capacity(eps.count);
    for q in 0..t {
        for i in 0..e {
            let ep = q * e + i;
            endpoint_m.push(b.ext_master(nodes[q], i, &format!("ep{ep}-m")));
            endpoint_s.push(b.ext_slave(nodes[q], i, &format!("ep{ep}-s")));
            endpoint_nodes.push(nodes[q]);
        }
    }

    // peer wiring: q's out-port for p → p's in-port for q
    let out_port = |q: usize, p: usize| e + if p < q { p } else { p - 1 };
    let in_port = |p: usize, q: usize| e + if q < p { q } else { q - 1 };
    for q in 0..t {
        for p in 0..t {
            if p == q {
                continue;
            }
            b.connect(nodes[q], out_port(q, p), nodes[p], in_port(p, q));
        }
    }

    // service slave ports (tile 0)
    let service_s: Vec<LinkId> = spec
        .services
        .iter()
        .enumerate()
        .map(|(si, (_, _, name))| b.ext_slave(nodes[0], e + t - 1 + si, name))
        .collect();

    MeshTopology {
        topo: b.build(),
        endpoint_m,
        endpoint_s,
        endpoint_nodes,
        service_s,
    }
}

/// A multi-chiplet package: `chiplets` identical die-local K-ary trees
/// whose roots double as D2D **gateway nodes**, joined pairwise by
/// die-to-die links ([`TopologyBuilder::connect_d2d`]) into a fully
/// connected die-level mesh — a fabric of fabrics. Every die owns a
/// contiguous aligned block of `endpoints.count / chiplets` endpoints;
/// `arity` is the per-die tree (bottom-up, product = endpoints per
/// die). Service windows and extra root masters live on die 0's
/// gateway; the other gateways route service traffic through their D2D
/// hop toward die 0, exactly like mesh tiles.
///
/// The whole package is ONE [`TopologyBuilder`] graph: `build` wires
/// the reservation and reduction ledgers over all dies and all D2D
/// edges, so the package has a single global ticket order and
/// reduction-membership oracles that walk through the gateways.
#[derive(Debug, Clone)]
pub struct ChipletSpec {
    pub name: String,
    /// Package-wide endpoint array (all dies).
    pub endpoints: EndpointMap,
    /// Number of dies (>= 2; use [`build_tree`] for a single die).
    pub chiplets: usize,
    /// Per-die tree arity, bottom-up; product = endpoints per die.
    pub arity: Vec<usize>,
    /// Timing of every inter-die hop.
    pub d2d: D2dParams,
    pub params: FabricParams,
    /// Service windows `(start, end, name)` hosted on die 0's gateway.
    pub services: Vec<(u64, u64, String)>,
    /// Extra master ports on die 0's gateway (named `top{i}-m`).
    pub n_root_masters: usize,
}

/// A built chiplet package plus its handles.
pub struct ChipletTopology {
    pub topo: Topology,
    pub endpoint_m: Vec<LinkId>,
    pub endpoint_s: Vec<LinkId>,
    /// Per endpoint: its fabric entry node.
    pub endpoint_nodes: Vec<NodeId>,
    /// One per [`ChipletSpec::services`] entry (all on die 0's gateway).
    pub service_s: Vec<LinkId>,
    /// One per extra root master port (die 0's gateway).
    pub root_m: Vec<LinkId>,
    /// Per die: its gateway (die-root) node.
    pub die_roots: Vec<NodeId>,
    /// Per crossbar node: the die that owns it. Node order is
    /// die-major (all of die 0's nodes, then die 1's, …), so each die
    /// is a contiguous index range — the parallel engine shards the
    /// package by die with only D2D links as cuts.
    pub node_die: Vec<usize>,
}

/// Build a multi-chiplet package; `tune(cfg, level)` may adjust each
/// node's crossbar knobs (level 0 = leaves, `arity.len() - 1` = the
/// die gateways), uniformly across dies.
pub fn build_chiplets(
    pool: &mut LinkPool,
    link_depth: usize,
    spec: &ChipletSpec,
    mut tune: impl FnMut(&mut XbarCfg, usize),
) -> ChipletTopology {
    let eps = &spec.endpoints;
    let c = spec.chiplets;
    assert!(c >= 2, "{}: a package needs at least 2 chiplets", spec.name);
    assert!(!spec.arity.is_empty(), "{}: empty arity", spec.name);
    assert!(
        eps.stride.is_power_of_two(),
        "{}: endpoint stride must be a power of two",
        spec.name
    );
    assert_eq!(
        eps.count % c,
        0,
        "{}: chiplets must divide the endpoint count",
        spec.name
    );
    let per_die = eps.count / c;
    let levels = spec.arity.len();
    let mut n_nodes = Vec::with_capacity(levels); // per die
    let mut span = Vec::with_capacity(levels); // endpoints per node
    let mut cover = 1usize;
    for (l, &a) in spec.arity.iter().enumerate() {
        assert!(a >= 1, "{}: arity[{l}] must be >= 1", spec.name);
        cover *= a;
        assert_eq!(
            per_die % cover,
            0,
            "{}: arity prefix {cover} must divide {per_die} endpoints per die",
            spec.name
        );
        span.push(cover);
        n_nodes.push(per_die / cover);
    }
    assert_eq!(
        n_nodes[levels - 1],
        1,
        "{}: arity product must equal the per-die endpoint count (one gateway per die)",
        spec.name
    );

    let mut b = TopologyBuilder::new(&spec.name, pool, link_depth);
    let gw_arity = spec.arity[levels - 1];
    // gateway D2D port layout: children 0..gw_arity, then the C-1 peers
    let out_port = |d: usize, p: usize| gw_arity + if p < d { p } else { p - 1 };

    let mut endpoint_m = Vec::with_capacity(eps.count);
    let mut endpoint_s = Vec::with_capacity(eps.count);
    let mut endpoint_nodes = Vec::with_capacity(eps.count);
    let mut die_roots = Vec::with_capacity(c);
    let mut node_die = Vec::new();

    for d in 0..c {
        let die_first = d * per_die;
        let gateway_level = |l: usize| l == levels - 1;
        let mut level_nodes: Vec<NodeId> = Vec::new();
        for l in 0..levels {
            let al = spec.arity[l];
            let gw = gateway_level(l);
            let child_span = if l == 0 { 1 } else { span[l - 1] };
            let mut next_nodes = Vec::with_capacity(n_nodes[l]);
            for k in 0..n_nodes[l] {
                let first = die_first + k * span[l];
                // child rules: endpoints at the leaves, subtree
                // regions above — identical to build_tree
                let mut rules: Vec<AddrRule> = (0..al)
                    .map(|j| {
                        if l == 0 {
                            eps.rule(first + j, j)
                        } else {
                            let (s, e) = eps.region(first + j * child_span, child_span);
                            AddrRule::new(s, e, j, &format!("child{j}")).with_mcast()
                        }
                    })
                    .collect();
                let (n_masters, n_slaves);
                if gw {
                    // the die root is a gateway: peer-die regions ride
                    // on the D2D ports (mesh-tile style), services on
                    // die 0's dedicated ports or through the hop to it
                    for p in (0..c).filter(|&p| p != d) {
                        let (s, e) = eps.region(p * per_die, per_die);
                        rules.push(
                            AddrRule::new(s, e, out_port(d, p), &format!("die{p}")).with_mcast(),
                        );
                    }
                    for (si, (s, e, name)) in spec.services.iter().enumerate() {
                        let slave = if d == 0 {
                            gw_arity + c - 1 + si
                        } else {
                            out_port(d, 0)
                        };
                        rules.push(AddrRule::new(*s, *e, slave, name));
                    }
                    n_slaves = gw_arity + c - 1 + if d == 0 { spec.services.len() } else { 0 };
                    n_masters = gw_arity + c - 1 + if d == 0 { spec.n_root_masters } else { 0 };
                } else {
                    n_slaves = al + 1;
                    n_masters = al + 1;
                }
                let map = AddrMap::new(rules, n_slaves).unwrap_or_else(|e| {
                    panic!("{}: die {d} level {l} node {k} map: {e}", spec.name)
                });
                let mut cfg = XbarCfg::new(
                    &format!("{}-d{}l{}n{}", spec.name, d, l, k),
                    n_masters,
                    n_slaves,
                    map,
                );
                spec.params.apply(&mut cfg);
                if gw {
                    spec.params.apply_root(&mut cfg);
                }
                if !spec.params.endpoint_prio.is_empty() {
                    // child ports aggregate their subtree; gateway peer
                    // ports carry the sending die's max; the down-in
                    // port of inner nodes carries the package-wide rest
                    let mut prio: Vec<u32> = (0..al)
                        .map(|j| spec.params.prio_max(first + j * child_span, child_span))
                        .collect();
                    if gw {
                        for p in (0..c).filter(|&p| p != d) {
                            prio.push(spec.params.prio_max(p * per_die, per_die));
                        }
                    } else {
                        prio.push(spec.params.prio_max_outside(first, span[l], eps.count));
                    }
                    cfg.master_prio = prio;
                }
                if !gw {
                    cfg.default_slave = Some(al);
                    cfg.local_scope = Some(eps.region(first, span[l]));
                }
                tune(&mut cfg, l);
                let node = b.node(cfg);
                node_die.push(d);
                if l == 0 {
                    for i in 0..al {
                        let ep = first + i;
                        endpoint_m.push(b.ext_master(node, i, &format!("ep{ep}-m")));
                        endpoint_s.push(b.ext_slave(node, i, &format!("ep{ep}-s")));
                        endpoint_nodes.push(node);
                    }
                }
                if l > 0 {
                    // wire the children exactly like build_tree: the
                    // child's up-out slave port is its own arity
                    let child_a = spec.arity[l - 1];
                    for j in 0..al {
                        let child = level_nodes[k * al + j];
                        b.connect(child, child_a, node, j);
                        b.connect(node, j, child, child_a);
                    }
                }
                next_nodes.push(node);
            }
            level_nodes = next_nodes;
        }
        die_roots.push(*level_nodes.last().expect("die has a gateway"));
    }

    // pairwise D2D wiring between the gateways: q's out-port for p
    // feeds p's in-port for q, both directions, one D2D link each
    let in_port = |p: usize, q: usize| gw_arity + if q < p { q } else { q - 1 };
    for q in 0..c {
        for p in 0..c {
            if p == q {
                continue;
            }
            b.connect_d2d(die_roots[q], out_port(q, p), die_roots[p], in_port(p, q), &spec.d2d);
        }
    }

    // services + extra masters on die 0's gateway
    let service_s: Vec<LinkId> = spec
        .services
        .iter()
        .enumerate()
        .map(|(si, (_, _, name))| b.ext_slave(die_roots[0], gw_arity + c - 1 + si, name))
        .collect();
    let root_m: Vec<LinkId> = (0..spec.n_root_masters)
        .map(|i| b.ext_master(die_roots[0], gw_arity + c - 1 + i, &format!("top{i}-m")))
        .collect();

    ChipletTopology {
        topo: b.build(),
        endpoint_m,
        endpoint_s,
        endpoint_nodes,
        service_s,
        root_m,
        die_roots,
        node_die,
    }
}

/// A bidirectional ring of `nodes` equal crossbars, each owning a
/// contiguous aligned block of endpoints. Routing is span-ordered
/// (dateline at node 0, see `xbar::RingLevel`): a request for a lower
/// address leaves on the descending port, higher on the ascending one,
/// and the physical wrap links are wired but idle — which keeps the
/// W transport's waits-for chains monotone (no wormhole deadlock
/// without virtual channels) and the reservation ledger's no-revisit
/// walk trivially valid. A multicast forks into at most one leg per
/// direction, each carrying an include window that shrinks hop by hop.
#[derive(Debug, Clone)]
pub struct RingSpec {
    pub name: String,
    pub endpoints: EndpointMap,
    /// Ring stops (>= 2); must divide `endpoints.count`.
    pub nodes: usize,
    pub params: FabricParams,
    /// Service windows `(start, end, name)` hosted on node 0 — every
    /// other node sends them down its descending port (`default_slave`)
    /// toward the dateline, hop by hop.
    pub services: Vec<(u64, u64, String)>,
}

pub struct RingTopology {
    pub topo: Topology,
    pub endpoint_m: Vec<LinkId>,
    pub endpoint_s: Vec<LinkId>,
    /// Per endpoint: the ring node it attaches to.
    pub endpoint_nodes: Vec<NodeId>,
    /// One per [`RingSpec::services`] entry, in order (all on node 0).
    pub service_s: Vec<LinkId>,
}

/// Build a bidirectional ring; `tune(cfg, node)` may adjust each node's
/// crossbar knobs (mirrors [`build_mesh`]'s per-tile hook).
pub fn build_ring(
    pool: &mut LinkPool,
    link_depth: usize,
    spec: &RingSpec,
    mut tune: impl FnMut(&mut XbarCfg, usize),
) -> RingTopology {
    let eps = &spec.endpoints;
    let n = spec.nodes;
    assert!(n >= 2, "{}: a ring needs at least 2 nodes", spec.name);
    assert_eq!(
        eps.count % n,
        0,
        "{}: nodes must divide the endpoint count",
        spec.name
    );
    let e = eps.count / n;
    let span = eps.region(0, eps.count);
    let mut b = TopologyBuilder::new(&spec.name, pool, link_depth);

    // ports per node: masters = e locals + down-in + up-in;
    // slaves = e locals + down-out + up-out [+ services on node 0]
    let (down, up) = (e, e + 1);
    let mut nodes = Vec::with_capacity(n);
    for q in 0..n {
        let first = q * e;
        let mut rules: Vec<AddrRule> = (0..e).map(|i| eps.rule(first + i, i)).collect();
        if q == 0 {
            for (si, (s, end, name)) in spec.services.iter().enumerate() {
                rules.push(AddrRule::new(*s, *end, e + 2 + si, name));
            }
        }
        let n_slaves = e + 2 + if q == 0 { spec.services.len() } else { 0 };
        let n_masters = e + 2;
        let map = AddrMap::new(rules, n_slaves)
            .unwrap_or_else(|err| panic!("{}: node {q} map: {err}", spec.name));
        let mut cfg = XbarCfg::new(&format!("{}-n{}", spec.name, q), n_masters, n_slaves, map);
        spec.params.apply(&mut cfg);
        // every ring stop is both leaf and converging point
        spec.params.apply_root(&mut cfg);
        cfg.ring = vec![RingLevel {
            down_port: down,
            up_port: up,
            span,
            local: eps.region(first, e),
        }];
        if q > 0 {
            // off-span traffic (service windows) heads for the
            // dateline, span-ordered like everything else
            cfg.default_slave = Some(down);
        }
        if !spec.params.endpoint_prio.is_empty() {
            // locals carry their own priority; ring-in ports can carry
            // traffic from anywhere else on the ring
            let mut prio: Vec<u32> = (0..e).map(|i| spec.params.prio_of(first + i)).collect();
            prio.push(spec.params.prio_max_outside(first, e, eps.count));
            prio.push(spec.params.prio_max_outside(first, e, eps.count));
            cfg.master_prio = prio;
        }
        tune(&mut cfg, q);
        nodes.push(b.node(cfg));
    }

    // endpoint ports
    let mut endpoint_m = Vec::with_capacity(eps.count);
    let mut endpoint_s = Vec::with_capacity(eps.count);
    let mut endpoint_nodes = Vec::with_capacity(eps.count);
    for q in 0..n {
        for i in 0..e {
            let ep = q * e + i;
            endpoint_m.push(b.ext_master(nodes[q], i, &format!("ep{ep}-m")));
            endpoint_s.push(b.ext_slave(nodes[q], i, &format!("ep{ep}-s")));
            endpoint_nodes.push(nodes[q]);
        }
    }

    // neighbour wiring, wrap links included: q's up-out feeds q+1's
    // down-in (master port `down`), q's down-out feeds q-1's up-in
    for q in 0..n {
        b.connect(nodes[q], up, nodes[(q + 1) % n], down);
        b.connect(nodes[q], down, nodes[(q + n - 1) % n], up);
    }

    let service_s: Vec<LinkId> = spec
        .services
        .iter()
        .enumerate()
        .map(|(si, (_, _, name))| b.ext_slave(nodes[0], e + 2 + si, name))
        .collect();

    RingTopology {
        topo: b.build(),
        endpoint_m,
        endpoint_s,
        endpoint_nodes,
        service_s,
    }
}

/// A `cols`×`rows` 2-D torus, row-major (node `(x, y)` is index
/// `y*cols + x` and owns the endpoint block at that index). Each node
/// carries two ring dimensions, X innermost (span = its row) and Y
/// outermost (span = everything): requests route dimension-ordered
/// Y-then-X, multicasts distribute rows on the Y legs and fan out
/// within each row on the X legs, so every node is visited at most
/// once. Both dimensions are span-ordered like [`build_ring`] — the
/// wrap links exist but idle.
#[derive(Debug, Clone)]
pub struct Torus2dSpec {
    pub name: String,
    pub endpoints: EndpointMap,
    /// Ring size of the X dimension (>= 2).
    pub cols: usize,
    /// Ring size of the Y dimension (>= 2).
    pub rows: usize,
    pub params: FabricParams,
    /// Service windows `(start, end, name)` hosted on node (0, 0) —
    /// other nodes send them toward it dimension-ordered (Y first).
    pub services: Vec<(u64, u64, String)>,
}

pub struct TorusTopology {
    pub topo: Topology,
    pub endpoint_m: Vec<LinkId>,
    pub endpoint_s: Vec<LinkId>,
    /// Per endpoint: the torus node it attaches to.
    pub endpoint_nodes: Vec<NodeId>,
    /// One per [`Torus2dSpec::services`] entry (all on node (0, 0)).
    pub service_s: Vec<LinkId>,
}

/// Build a 2-D torus; `tune(cfg, idx)` may adjust each node's crossbar
/// knobs (`idx` row-major).
pub fn build_torus2d(
    pool: &mut LinkPool,
    link_depth: usize,
    spec: &Torus2dSpec,
    mut tune: impl FnMut(&mut XbarCfg, usize),
) -> TorusTopology {
    let eps = &spec.endpoints;
    let (cols, rows) = (spec.cols, spec.rows);
    assert!(
        cols >= 2 && rows >= 2,
        "{}: a torus needs >= 2 nodes per dimension (use build_ring)",
        spec.name
    );
    let t = cols * rows;
    assert_eq!(
        eps.count % t,
        0,
        "{}: cols*rows must divide the endpoint count",
        spec.name
    );
    let e = eps.count / t;
    let mut b = TopologyBuilder::new(&spec.name, pool, link_depth);

    // ports per node: e locals, then X down/up, then Y down/up — the
    // same indices on both sides (m-port x_down receives from the
    // descending X neighbour's ascending port, and so on)
    let (x_down, x_up, y_down, y_up) = (e, e + 1, e + 2, e + 3);
    let mut nodes = Vec::with_capacity(t);
    for idx in 0..t {
        let (x, y) = (idx % cols, idx / cols);
        let first = idx * e;
        let mut rules: Vec<AddrRule> = (0..e).map(|i| eps.rule(first + i, i)).collect();
        if idx == 0 {
            for (si, (s, end, name)) in spec.services.iter().enumerate() {
                rules.push(AddrRule::new(*s, *end, e + 4 + si, name));
            }
        }
        let n_slaves = e + 4 + if idx == 0 { spec.services.len() } else { 0 };
        let n_masters = e + 4;
        let map = AddrMap::new(rules, n_slaves)
            .unwrap_or_else(|err| panic!("{}: node {idx} map: {err}", spec.name));
        let mut cfg = XbarCfg::new(
            &format!("{}-x{}y{}", spec.name, x, y),
            n_masters,
            n_slaves,
            map,
        );
        spec.params.apply(&mut cfg);
        spec.params.apply_root(&mut cfg);
        // X innermost (span = the row), Y outermost (span = all)
        cfg.ring = vec![
            RingLevel {
                down_port: x_down,
                up_port: x_up,
                span: eps.region(y * cols * e, cols * e),
                local: eps.region(first, e),
            },
            RingLevel {
                down_port: y_down,
                up_port: y_up,
                span: eps.region(0, eps.count),
                local: eps.region(y * cols * e, cols * e),
            },
        ];
        if idx != 0 {
            // off-span traffic (service windows) descends toward node
            // (0, 0), Y dimension first
            cfg.default_slave = Some(if y > 0 { y_down } else { x_down });
        }
        if !spec.params.endpoint_prio.is_empty() {
            let mut prio: Vec<u32> = (0..e).map(|i| spec.params.prio_of(first + i)).collect();
            for _ in 0..4 {
                prio.push(spec.params.prio_max_outside(first, e, eps.count));
            }
            cfg.master_prio = prio;
        }
        tune(&mut cfg, idx);
        nodes.push(b.node(cfg));
    }

    // endpoint ports
    let mut endpoint_m = Vec::with_capacity(eps.count);
    let mut endpoint_s = Vec::with_capacity(eps.count);
    let mut endpoint_nodes = Vec::with_capacity(eps.count);
    for idx in 0..t {
        for i in 0..e {
            let ep = idx * e + i;
            endpoint_m.push(b.ext_master(nodes[idx], i, &format!("ep{ep}-m")));
            endpoint_s.push(b.ext_slave(nodes[idx], i, &format!("ep{ep}-s")));
            endpoint_nodes.push(nodes[idx]);
        }
    }

    // torus wiring, wrap links included, both dimensions
    for idx in 0..t {
        let (x, y) = (idx % cols, idx / cols);
        let right = y * cols + (x + 1) % cols;
        let left = y * cols + (x + cols - 1) % cols;
        let above = ((y + 1) % rows) * cols + x;
        let below = ((y + rows - 1) % rows) * cols + x;
        b.connect(nodes[idx], x_up, nodes[right], x_down);
        b.connect(nodes[idx], x_down, nodes[left], x_up);
        b.connect(nodes[idx], y_up, nodes[above], y_down);
        b.connect(nodes[idx], y_down, nodes[below], y_up);
    }

    let service_s: Vec<LinkId> = spec
        .services
        .iter()
        .enumerate()
        .map(|(si, (_, _, name))| b.ext_slave(nodes[0], e + 4 + si, name))
        .collect();

    TorusTopology {
        topo: b.build(),
        endpoint_m,
        endpoint_s,
        endpoint_nodes,
        service_s,
    }
}

/// Rings of fully-connected mesh groups: `groups` tile clusters on a
/// ring, each a [`build_mesh`]-style clique of `tiles` crossbars. Tile
/// 0 of every group is the **gateway**: it alone carries the group's
/// two ring ports (span-ordered like [`build_ring`]). In-group traffic
/// between the non-gateway tiles takes their direct peer links;
/// everything destined for the gateway's endpoints, another group, or
/// a service window funnels up each tile's single gateway link — the
/// non-gateway tiles deliberately have *no* direct route to the
/// gateway's endpoint block, so a multicast reaches the gateway on
/// exactly one leg (its default route, excluding the region the peer
/// rules already served) and the reservation walk visits it once.
#[derive(Debug, Clone)]
pub struct RingMeshSpec {
    pub name: String,
    pub endpoints: EndpointMap,
    /// Ring stops (>= 2); with `tiles`, must divide `endpoints.count`.
    pub groups: usize,
    /// Tiles per group (>= 2), tile 0 being the gateway.
    pub tiles: usize,
    pub params: FabricParams,
    /// Service windows `(start, end, name)` hosted on group 0's
    /// gateway; other gateways descend the ring toward it.
    pub services: Vec<(u64, u64, String)>,
}

pub struct RingMeshTopology {
    pub topo: Topology,
    pub endpoint_m: Vec<LinkId>,
    pub endpoint_s: Vec<LinkId>,
    /// Per endpoint: the tile node it attaches to.
    pub endpoint_nodes: Vec<NodeId>,
    /// One per [`RingMeshSpec::services`] entry (group 0's gateway).
    pub service_s: Vec<LinkId>,
    /// Per group: its gateway node.
    pub gateways: Vec<NodeId>,
}

/// Build rings of mesh groups; `tune(cfg, node)` may adjust each node's
/// crossbar knobs (`node` in group-major, gateway-first order).
pub fn build_ring_mesh(
    pool: &mut LinkPool,
    link_depth: usize,
    spec: &RingMeshSpec,
    mut tune: impl FnMut(&mut XbarCfg, usize),
) -> RingMeshTopology {
    let eps = &spec.endpoints;
    let (g_n, t_n) = (spec.groups, spec.tiles);
    assert!(g_n >= 2, "{}: a ring-mesh needs at least 2 groups", spec.name);
    assert!(
        t_n >= 2,
        "{}: a ring-mesh needs at least 2 tiles per group (use build_ring)",
        spec.name
    );
    assert_eq!(
        eps.count % (g_n * t_n),
        0,
        "{}: groups*tiles must divide the endpoint count",
        spec.name
    );
    let e = eps.count / (g_n * t_n);
    let span = eps.region(0, eps.count);
    let mut b = TopologyBuilder::new(&spec.name, pool, link_depth);

    // gateway ports: e locals, t_n-1 tile links, ring down/up
    let (gw_down, gw_up) = (e + t_n - 1, e + t_n);
    // non-gateway ports: e locals, t_n-2 peer links, the gateway link
    let to_gw = e + t_n - 2;
    // peer-port index on tile `t` (1-based in its group) for peer `p`
    let peer_port = |t: usize, p: usize| e + if p < t { p - 1 } else { p - 2 };

    let mut nodes = Vec::with_capacity(g_n * t_n);
    for g in 0..g_n {
        let grp_first = g * t_n * e;
        for t in 0..t_n {
            let first = grp_first + t * e;
            let mut rules: Vec<AddrRule> = (0..e).map(|i| eps.rule(first + i, i)).collect();
            let (n_masters, n_slaves);
            let mut cfg;
            if t == 0 {
                // gateway: direct routes into its group's tiles, ring
                // ports for the rest of the fabric
                for p in 1..t_n {
                    let (s, end) = eps.region(grp_first + p * e, e);
                    rules.push(
                        AddrRule::new(s, end, e + p - 1, &format!("tile{p}")).with_mcast(),
                    );
                }
                if g == 0 {
                    for (si, (s, end, name)) in spec.services.iter().enumerate() {
                        rules.push(AddrRule::new(*s, *end, gw_up + 1 + si, name));
                    }
                }
                n_slaves = e + t_n + 1 + if g == 0 { spec.services.len() } else { 0 };
                n_masters = e + t_n + 1;
                let map = AddrMap::new(rules, n_slaves)
                    .unwrap_or_else(|err| panic!("{}: gw {g} map: {err}", spec.name));
                cfg = XbarCfg::new(&format!("{}-g{}gw", spec.name, g), n_masters, n_slaves, map);
                spec.params.apply(&mut cfg);
                // the gateway is the group's converging point
                spec.params.apply_root(&mut cfg);
                cfg.ring = vec![RingLevel {
                    down_port: gw_down,
                    up_port: gw_up,
                    span,
                    // the whole group: in-group members are served by
                    // the local and tile rules, never by a ring leg
                    local: eps.region(grp_first, t_n * e),
                }];
                if g > 0 {
                    // service windows descend the ring toward group 0
                    cfg.default_slave = Some(gw_down);
                }
                if !spec.params.endpoint_prio.is_empty() {
                    let mut prio: Vec<u32> =
                        (0..e).map(|i| spec.params.prio_of(first + i)).collect();
                    for p in 1..t_n {
                        prio.push(spec.params.prio_max(grp_first + p * e, e));
                    }
                    let rest = spec.params.prio_max_outside(grp_first, t_n * e, eps.count);
                    prio.push(rest);
                    prio.push(rest);
                    cfg.master_prio = prio;
                }
            } else {
                // non-gateway tile: peers are the *other* non-gateway
                // tiles; the gateway's block and everything beyond ride
                // the single gateway link via the default route
                for p in (1..t_n).filter(|&p| p != t) {
                    let (s, end) = eps.region(grp_first + p * e, e);
                    rules.push(
                        AddrRule::new(s, end, peer_port(t, p), &format!("tile{p}")).with_mcast(),
                    );
                }
                n_slaves = e + t_n - 1;
                n_masters = e + t_n - 1;
                let map = AddrMap::new(rules, n_slaves)
                    .unwrap_or_else(|err| panic!("{}: g{g} tile {t} map: {err}", spec.name));
                cfg = XbarCfg::new(
                    &format!("{}-g{}t{}", spec.name, g, t),
                    n_masters,
                    n_slaves,
                    map,
                );
                spec.params.apply(&mut cfg);
                cfg.default_slave = Some(to_gw);
                // the non-gateway tiles' joint region: the default leg
                // tells the gateway this much is already served (the
                // interval is not mask-form alignable, which is fine —
                // the gateway's windowed decode prunes by interval)
                cfg.local_scope = Some(eps.region(grp_first + e, (t_n - 1) * e));
                if !spec.params.endpoint_prio.is_empty() {
                    let mut prio: Vec<u32> =
                        (0..e).map(|i| spec.params.prio_of(first + i)).collect();
                    for p in (1..t_n).filter(|&p| p != t) {
                        prio.push(spec.params.prio_max(grp_first + p * e, e));
                    }
                    prio.push(spec.params.prio_max_outside(
                        grp_first + e,
                        (t_n - 1) * e,
                        eps.count,
                    ));
                    cfg.master_prio = prio;
                }
            }
            tune(&mut cfg, g * t_n + t);
            nodes.push(b.node(cfg));
        }
    }

    // endpoint ports
    let mut endpoint_m = Vec::with_capacity(eps.count);
    let mut endpoint_s = Vec::with_capacity(eps.count);
    let mut endpoint_nodes = Vec::with_capacity(eps.count);
    for q in 0..g_n * t_n {
        for i in 0..e {
            let ep = q * e + i;
            endpoint_m.push(b.ext_master(nodes[q], i, &format!("ep{ep}-m")));
            endpoint_s.push(b.ext_slave(nodes[q], i, &format!("ep{ep}-s")));
            endpoint_nodes.push(nodes[q]);
        }
    }

    let gateways: Vec<NodeId> = (0..g_n).map(|g| nodes[g * t_n]).collect();

    // in-group wiring: gateway <-> every tile, tiles pairwise
    for g in 0..g_n {
        let gw = gateways[g];
        for t in 1..t_n {
            let tile = nodes[g * t_n + t];
            b.connect(gw, e + t - 1, tile, to_gw);
            b.connect(tile, to_gw, gw, e + t - 1);
            for p in t + 1..t_n {
                let peer = nodes[g * t_n + p];
                b.connect(tile, peer_port(t, p), peer, peer_port(p, t));
                b.connect(peer, peer_port(p, t), tile, peer_port(t, p));
            }
        }
    }

    // gateway ring, wrap links included (idle under span-ordering)
    for g in 0..g_n {
        b.connect(gateways[g], gw_up, gateways[(g + 1) % g_n], gw_down);
        b.connect(gateways[g], gw_down, gateways[(g + g_n - 1) % g_n], gw_up);
    }

    let service_s: Vec<LinkId> = spec
        .services
        .iter()
        .enumerate()
        .map(|(si, (_, _, name))| b.ext_slave(gateways[0], gw_up + 1 + si, name))
        .collect();

    RingMeshTopology {
        topo: b.build(),
        endpoint_m,
        endpoint_s,
        endpoint_nodes,
        service_s,
        gateways,
    }
}

/// Canned shapes for sweeps and parity tests.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TopoShape {
    /// Single N×N crossbar.
    Flat,
    /// Hierarchical tree with the given bottom-up arity.
    Tree { arity: Vec<usize> },
    /// Fully-connected mesh of peer tiles.
    Mesh { tiles: usize },
    /// Bidirectional span-ordered ring of equal nodes.
    Ring { nodes: usize },
    /// 2-D torus, row-major, X dimension innermost.
    Torus { cols: usize, rows: usize },
    /// Ring of fully-connected mesh groups joined by gateway tiles.
    RingMesh { groups: usize, tiles: usize },
}

impl TopoShape {
    pub fn label(&self) -> String {
        match self {
            TopoShape::Flat => "flat".to_string(),
            TopoShape::Tree { arity } => {
                let parts: Vec<String> = arity.iter().map(|a| a.to_string()).collect();
                format!("tree{}", parts.join("x"))
            }
            TopoShape::Mesh { tiles } => format!("mesh{tiles}"),
            TopoShape::Ring { nodes } => format!("ring{nodes}"),
            TopoShape::Torus { cols, rows } => format!("torus{cols}x{rows}"),
            TopoShape::RingMesh { groups, tiles } => format!("ringmesh{groups}x{tiles}"),
        }
    }
}

/// A shape-built fabric with uniform endpoint handles.
pub struct BuiltTopo {
    pub topo: Topology,
    pub endpoint_m: Vec<LinkId>,
    pub endpoint_s: Vec<LinkId>,
    /// Per endpoint: its fabric entry node.
    pub endpoint_nodes: Vec<NodeId>,
}

/// Instantiate one of the canned shapes over `endpoints`.
pub fn build_shape(
    pool: &mut LinkPool,
    link_depth: usize,
    endpoints: EndpointMap,
    params: FabricParams,
    shape: &TopoShape,
) -> BuiltTopo {
    match shape {
        // flat is the degenerate single-level tree
        TopoShape::Flat | TopoShape::Tree { .. } => {
            let arity = match shape {
                TopoShape::Tree { arity } => arity.clone(),
                _ => vec![endpoints.count],
            };
            let spec = TreeSpec {
                name: shape.label(),
                endpoints,
                arity,
                params,
                services: Vec::new(),
                n_root_masters: 0,
            };
            let t = build_tree(pool, link_depth, &spec, |_, _| {});
            BuiltTopo {
                topo: t.topo,
                endpoint_m: t.endpoint_m,
                endpoint_s: t.endpoint_s,
                endpoint_nodes: t.endpoint_nodes,
            }
        }
        TopoShape::Mesh { tiles } => {
            let spec = MeshSpec {
                name: format!("mesh-{tiles}"),
                endpoints,
                tiles: *tiles,
                params,
                services: Vec::new(),
            };
            let m = build_mesh(pool, link_depth, &spec, |_, _| {});
            BuiltTopo {
                topo: m.topo,
                endpoint_m: m.endpoint_m,
                endpoint_s: m.endpoint_s,
                endpoint_nodes: m.endpoint_nodes,
            }
        }
        TopoShape::Ring { nodes } => {
            let spec = RingSpec {
                name: shape.label(),
                endpoints,
                nodes: *nodes,
                params,
                services: Vec::new(),
            };
            let r = build_ring(pool, link_depth, &spec, |_, _| {});
            BuiltTopo {
                topo: r.topo,
                endpoint_m: r.endpoint_m,
                endpoint_s: r.endpoint_s,
                endpoint_nodes: r.endpoint_nodes,
            }
        }
        TopoShape::Torus { cols, rows } => {
            let spec = Torus2dSpec {
                name: shape.label(),
                endpoints,
                cols: *cols,
                rows: *rows,
                params,
                services: Vec::new(),
            };
            let t = build_torus2d(pool, link_depth, &spec, |_, _| {});
            BuiltTopo {
                topo: t.topo,
                endpoint_m: t.endpoint_m,
                endpoint_s: t.endpoint_s,
                endpoint_nodes: t.endpoint_nodes,
            }
        }
        TopoShape::RingMesh { groups, tiles } => {
            let spec = RingMeshSpec {
                name: shape.label(),
                endpoints,
                groups: *groups,
                tiles: *tiles,
                params,
                services: Vec::new(),
            };
            let r = build_ring_mesh(pool, link_depth, &spec, |_, _| {});
            BuiltTopo {
                topo: r.topo,
                endpoint_m: r.endpoint_m,
                endpoint_s: r.endpoint_s,
                endpoint_nodes: r.endpoint_nodes,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn eps(n: usize) -> EndpointMap {
        EndpointMap {
            base: 0x0100_0000,
            stride: 0x4_0000,
            count: n,
        }
    }

    #[test]
    fn flat_is_single_node() {
        let mut pool = LinkPool::new();
        let t = build_shape(
            &mut pool,
            2,
            eps(8),
            FabricParams::default(),
            &TopoShape::Flat,
        );
        assert_eq!(t.topo.xbars.len(), 1);
        assert_eq!(t.topo.xbars[0].cfg.n_masters, 8);
        assert_eq!(t.topo.xbars[0].cfg.n_slaves, 8);
        assert!(t.topo.xbars[0].cfg.default_slave.is_none());
        assert_eq!(t.endpoint_m.len(), 8);
        assert_eq!(pool.len(), 16);
    }

    #[test]
    fn two_level_tree_matches_occamy_shape() {
        let mut pool = LinkPool::new();
        let t = build_shape(
            &mut pool,
            2,
            eps(32),
            FabricParams::default(),
            &TopoShape::Tree { arity: vec![4, 8] },
        );
        // 8 leaves + 1 root
        assert_eq!(t.topo.xbars.len(), 9);
        let root = t.topo.xbars.last().unwrap();
        assert_eq!(root.cfg.n_masters, 8);
        assert_eq!(root.cfg.n_slaves, 8);
        assert!(root.cfg.default_slave.is_none());
        for leaf in &t.topo.xbars[..8] {
            assert_eq!(leaf.cfg.default_slave, Some(4));
            let (s, e) = leaf.cfg.local_scope.unwrap();
            assert!((e - s).is_power_of_two());
            assert_eq!(s % (e - s), 0);
        }
    }

    #[test]
    fn three_level_tree_builds() {
        let mut pool = LinkPool::new();
        let t = build_shape(
            &mut pool,
            2,
            eps(16),
            FabricParams::default(),
            &TopoShape::Tree {
                arity: vec![2, 4, 2],
            },
        );
        // 8 leaves of 2 + 4 mids of 2 leaves + 1 root of 4 mids
        assert_eq!(t.topo.xbars.len(), 13);
        // mids keep a default route and an aligned scope
        for mid in &t.topo.xbars[8..12] {
            assert_eq!(mid.cfg.default_slave, Some(2));
            let (s, e) = mid.cfg.local_scope.unwrap();
            assert_eq!(e - s, 4 * 0x4_0000);
            assert_eq!(s % (e - s), 0);
        }
        assert!(t.topo.xbars[12].cfg.default_slave.is_none());
    }

    #[test]
    fn mesh_is_fully_connected() {
        let mut pool = LinkPool::new();
        let t = build_shape(
            &mut pool,
            2,
            eps(16),
            FabricParams::default(),
            &TopoShape::Mesh { tiles: 4 },
        );
        assert_eq!(t.topo.xbars.len(), 4);
        for x in &t.topo.xbars {
            // 4 locals + 3 peers on both sides
            assert_eq!(x.cfg.n_masters, 7);
            assert_eq!(x.cfg.n_slaves, 7);
            assert!(x.cfg.default_slave.is_none());
            // every address in the endpoint space decodes somewhere
            assert_eq!(x.cfg.map.rules().len(), 7);
        }
        // 16 endpoint pairs + 4*3 peer links
        assert_eq!(pool.len(), 32 + 12);
    }

    #[test]
    fn mesh_hosts_services_on_tile0() {
        let mut pool = LinkPool::new();
        let spec = MeshSpec {
            name: "svc-mesh".into(),
            endpoints: eps(8),
            tiles: 2,
            params: FabricParams::default(),
            services: vec![(0x8000_0000, 0x8010_0000, "llc".into())],
        };
        let t = build_mesh(&mut pool, 2, &spec, |_, _| {});
        assert_eq!(t.service_s.len(), 1);
        // tile 0 hosts the window on a dedicated slave port; tile 1
        // reuses its direct route to tile 0 (no extra port)
        assert_eq!(t.topo.xbars[0].cfg.n_slaves, 4 + 1 + 1);
        assert_eq!(t.topo.xbars[1].cfg.n_slaves, 4 + 1);
        assert_eq!(t.topo.xbars[0].cfg.n_masters, 5);
        assert_eq!(t.topo.xbars[1].cfg.n_masters, 5);
        assert_eq!(t.topo.ext_slave("llc"), t.service_s[0]);
    }

    #[test]
    fn ring_routes_span_ordered() {
        let mut pool = LinkPool::new();
        let t = build_shape(
            &mut pool,
            2,
            eps(8),
            FabricParams::default(),
            &TopoShape::Ring { nodes: 4 },
        );
        assert_eq!(t.topo.xbars.len(), 4);
        let e = eps(8);
        for (q, x) in t.topo.xbars.iter().enumerate() {
            // 2 locals + down + up on both sides
            assert_eq!(x.cfg.n_masters, 4);
            assert_eq!(x.cfg.n_slaves, 4);
            assert_eq!(x.cfg.ring.len(), 1);
            let lvl = &x.cfg.ring[0];
            assert_eq!(lvl.span, e.region(0, 8));
            assert_eq!(lvl.local, e.region(q * 2, 2));
            // dateline: only node 0 hosts off-span traffic
            assert_eq!(x.cfg.default_slave, if q == 0 { None } else { Some(2) });
        }
        // span-ordered, never across the wrap: node 1 reaches node 3's
        // endpoints ascending even though the wrap would be shorter
        let n1 = &t.topo.xbars[1].cfg;
        assert_eq!(n1.route_unicast(e.addr(0)), Some(2)); // down
        assert_eq!(n1.route_unicast(e.addr(7)), Some(3)); // up
        assert_eq!(n1.route_unicast(e.addr(2)), Some(0)); // local
        // 8 endpoint pairs + 2 links per neighbour hop (4 hops)
        assert_eq!(pool.len(), 16 + 8);
    }

    #[test]
    fn torus_carries_two_ring_dimensions() {
        let mut pool = LinkPool::new();
        let t = build_shape(
            &mut pool,
            2,
            eps(16),
            FabricParams::default(),
            &TopoShape::Torus { cols: 2, rows: 2 },
        );
        assert_eq!(t.topo.xbars.len(), 4);
        let e = eps(16);
        for (idx, x) in t.topo.xbars.iter().enumerate() {
            let (col, row) = (idx % 2, idx / 2);
            // 4 locals + 4 ring ports
            assert_eq!(x.cfg.n_masters, 8);
            assert_eq!(x.cfg.ring.len(), 2);
            // X innermost spans the row, Y outermost spans everything
            assert_eq!(x.cfg.ring[0].span, e.region(row * 8, 8));
            assert_eq!(x.cfg.ring[0].local, e.region(idx * 4, 4));
            assert_eq!(x.cfg.ring[1].span, e.region(0, 16));
            assert_eq!(x.cfg.ring[1].local, e.region(row * 8, 8));
            // services descend dimension-ordered toward node (0, 0)
            let want = match (col, row) {
                (0, 0) => None,
                (_, 0) => Some(4),     // x-down
                (_, _) => Some(6),     // y-down
            };
            assert_eq!(x.cfg.default_slave, want);
        }
        // node 3 = (1, 1): other row via Y, own row via X, local direct
        let n3 = &t.topo.xbars[3].cfg;
        assert_eq!(n3.route_unicast(e.addr(0)), Some(6)); // y-down
        assert_eq!(n3.route_unicast(e.addr(8)), Some(4)); // x-down
        assert_eq!(n3.route_unicast(e.addr(13)), Some(1)); // local
        // 16 endpoint pairs + 4 links out of each of the 4 nodes
        assert_eq!(pool.len(), 32 + 16);
    }

    #[test]
    fn ring_mesh_gateways_carry_the_ring() {
        let mut pool = LinkPool::new();
        let t = build_shape(
            &mut pool,
            2,
            eps(8),
            FabricParams::default(),
            &TopoShape::RingMesh { groups: 2, tiles: 2 },
        );
        let e = eps(8);
        // group-major, gateway first: [gw0, g0t1, gw1, g1t1]
        assert_eq!(t.topo.xbars.len(), 4);
        for g in 0..2 {
            let gw = &t.topo.xbars[g * 2].cfg;
            // 2 locals + 1 tile link + 2 ring ports
            assert_eq!(gw.n_masters, 5);
            assert_eq!(gw.ring.len(), 1);
            assert_eq!(gw.ring[0].span, e.region(0, 8));
            assert_eq!(gw.ring[0].local, e.region(g * 4, 4));
            assert_eq!(gw.default_slave, if g == 0 { None } else { Some(3) });
            let tile = &t.topo.xbars[g * 2 + 1].cfg;
            // 2 locals + the gateway link (tiles = 2 -> no peers)
            assert_eq!(tile.n_masters, 3);
            assert!(tile.ring.is_empty());
            assert_eq!(tile.default_slave, Some(2));
            // the joint non-gateway region rides the default leg's
            // exclude so the gateway won't serve it again
            assert_eq!(tile.local_scope, Some(e.region(g * 4 + 2, 2)));
        }
        // tile -> other group goes through the gateway's default route
        let t1 = &t.topo.xbars[1].cfg;
        assert_eq!(t1.route_unicast(e.addr(6)), Some(2));
        // gateway 0 sends ascending, gateway 1 descending (span order)
        assert_eq!(t.topo.xbars[0].cfg.route_unicast(e.addr(6)), Some(4));
        assert_eq!(t.topo.xbars[2].cfg.route_unicast(e.addr(1)), Some(3));
        // 8 endpoint pairs + 2 gw<->tile links per group + 4 ring links
        assert_eq!(pool.len(), 16 + 4 + 4);
    }

    #[test]
    fn ring_services_live_on_node0() {
        let mut pool = LinkPool::new();
        let spec = RingSpec {
            name: "svc-ring".into(),
            endpoints: eps(8),
            nodes: 4,
            params: FabricParams::default(),
            services: vec![(0x8000_0000, 0x8010_0000, "llc".into())],
        };
        let t = build_ring(&mut pool, 2, &spec, |_, _| {});
        assert_eq!(t.service_s.len(), 1);
        // node 0 hosts the window on a dedicated slave port; the others
        // descend their down port toward it
        assert_eq!(t.topo.xbars[0].cfg.n_slaves, 2 + 2 + 1);
        assert_eq!(t.topo.xbars[1].cfg.n_slaves, 2 + 2);
        assert_eq!(t.topo.xbars[0].cfg.route_unicast(0x8000_0000), Some(4));
        assert_eq!(t.topo.xbars[3].cfg.route_unicast(0x8000_0000), Some(2));
        assert_eq!(t.topo.ext_slave("llc"), t.service_s[0]);
    }

    #[test]
    fn fabric_params_caps_timeouts_and_prio_reach_every_node() {
        let params = FabricParams {
            max_outstanding: Some(5),
            max_mcast_outstanding: Some(3),
            root_outstanding: Some(9),
            root_mcast_outstanding: Some(7),
            req_timeout: Some(100),
            cpl_timeout: Some(400),
            arb_policy: ArbPolicy::Priority { aging: 4 },
            endpoint_prio: vec![0, 1, 2, 3, 0, 0, 0, 5],
            ..FabricParams::default()
        };
        let mut pool = LinkPool::new();
        let t = build_shape(
            &mut pool,
            2,
            eps(8),
            params.clone(),
            &TopoShape::Tree { arity: vec![4, 2] },
        );
        let leaf = &t.topo.xbars[0].cfg;
        assert_eq!(leaf.max_outstanding, 5);
        assert_eq!(leaf.max_mcast_outstanding, 3);
        assert_eq!(leaf.req_timeout, Some(100));
        assert_eq!(leaf.cpl_timeout, Some(400));
        assert_eq!(leaf.arb_policy, ArbPolicy::Priority { aging: 4 });
        // 4 locals + down-in carrying the outside max (endpoint 7's 5)
        assert_eq!(leaf.master_prio, vec![0, 1, 2, 3, 5]);
        let root = &t.topo.xbars.last().unwrap().cfg;
        assert_eq!(root.max_outstanding, 9);
        assert_eq!(root.max_mcast_outstanding, 7);
        // each child port aggregates its subtree's max
        assert_eq!(root.master_prio, vec![3, 5]);

        // a mesh tile is both leaf and root: root caps, peer-port prios
        let m = build_shape(&mut pool, 2, eps(8), params, &TopoShape::Mesh { tiles: 2 });
        let t0 = &m.topo.xbars[0].cfg;
        assert_eq!(t0.max_outstanding, 9);
        assert_eq!(t0.max_mcast_outstanding, 7);
        assert_eq!(t0.master_prio, vec![0, 1, 2, 3, 5]);

        // defaults leave the XbarCfg caps untouched (parity guarantee)
        let mut pool = LinkPool::new();
        let d = build_shape(&mut pool, 2, eps(8), FabricParams::default(), &TopoShape::Flat);
        let base = XbarCfg::new(
            "ref",
            1,
            1,
            AddrMap::new(vec![AddrRule::new(0, 0x1000, 0, "r0")], 1).unwrap(),
        );
        assert_eq!(d.topo.xbars[0].cfg.max_outstanding, base.max_outstanding);
        assert_eq!(
            d.topo.xbars[0].cfg.max_mcast_outstanding,
            base.max_mcast_outstanding
        );
        assert!(d.topo.xbars[0].cfg.master_prio.is_empty());
    }

    #[test]
    fn e2e_params_wire_a_shared_ledger_on_trees_and_meshes() {
        for shape in [
            TopoShape::Tree { arity: vec![2, 4] },
            TopoShape::Mesh { tiles: 2 },
            TopoShape::Flat,
            TopoShape::Ring { nodes: 4 },
            TopoShape::Torus { cols: 2, rows: 2 },
            TopoShape::RingMesh { groups: 2, tiles: 2 },
        ] {
            let mut pool = LinkPool::new();
            let params = FabricParams {
                e2e_mcast_order: true,
                ..FabricParams::default()
            };
            let t = build_shape(&mut pool, 2, eps(8), params, &shape);
            let h = t.topo.resv.as_ref().expect("e2e params must build a ledger");
            assert_eq!(h.lock().unwrap().n_nodes(), t.topo.xbars.len(), "{shape:?}");
            assert!(t.topo.xbars.iter().all(|x| x.cfg.e2e_mcast_order));
        }
        // and the default stays the RTL-faithful per-crossbar protocol
        let mut pool = LinkPool::new();
        let t = build_shape(
            &mut pool,
            2,
            eps(8),
            FabricParams::default(),
            &TopoShape::Flat,
        );
        assert!(t.topo.resv.is_none());
    }

    #[test]
    fn fabric_reduce_params_wire_a_shared_oracle_on_all_shapes() {
        for shape in [
            TopoShape::Tree { arity: vec![2, 4] },
            TopoShape::Mesh { tiles: 2 },
            TopoShape::Flat,
            TopoShape::Ring { nodes: 4 },
            TopoShape::Torus { cols: 2, rows: 2 },
            TopoShape::RingMesh { groups: 2, tiles: 2 },
        ] {
            let mut pool = LinkPool::new();
            let params = FabricParams {
                fabric_reduce: true,
                ..FabricParams::default()
            };
            let t = build_shape(&mut pool, 2, eps(8), params, &shape);
            let h = t
                .topo
                .reduce
                .as_ref()
                .expect("fabric_reduce params must build the membership oracle");
            assert_eq!(h.lock().unwrap().n_nodes(), t.topo.xbars.len(), "{shape:?}");
            assert!(t.topo.xbars.iter().all(|x| x.cfg.fabric_reduce));
            // entry nodes recorded for every endpoint, and walking a
            // cross-fabric group plans at least one join
            assert_eq!(t.endpoint_nodes.len(), 8);
            let entries: Vec<crate::axi::reduce::RedNode> = (1..8)
                .map(|i| crate::axi::reduce::RedNode(t.endpoint_nodes[i].0))
                .collect();
            h.lock().unwrap().open_group(
                1,
                crate::axi::reduce::ReduceOp::Sum,
                &entries,
                eps(8).addr(0),
            );
            assert!(
                h.lock().unwrap().group_joins(1) >= 1,
                "{shape:?}: 7 converging members must meet somewhere"
            );
        }
        // and the default stays the RTL-faithful endpoint-resolved path
        let mut pool = LinkPool::new();
        let t = build_shape(
            &mut pool,
            2,
            eps(8),
            FabricParams::default(),
            &TopoShape::Flat,
        );
        assert!(t.topo.reduce.is_none());
    }

    #[test]
    #[should_panic(expected = "fabric_reduce must be uniform")]
    fn mixed_fabric_reduce_flags_are_refused() {
        let mut pool = LinkPool::new();
        let mut b = TopologyBuilder::new("mixed-red", &mut pool, 2);
        let rules = vec![AddrRule::new(0, 0x1000, 0, "r0").with_mcast()];
        let mut c0 = XbarCfg::new("a", 1, 1, AddrMap::new(rules.clone(), 1).unwrap());
        c0.fabric_reduce = true;
        let c1 = XbarCfg::new("b", 1, 1, AddrMap::new(rules, 1).unwrap());
        let n0 = b.node(c0);
        let n1 = b.node(c1);
        b.ext_master(n0, 0, "m0");
        b.connect(n0, 0, n1, 0);
        b.ext_slave(n1, 0, "s0");
        b.build();
    }

    #[test]
    #[should_panic(expected = "e2e_mcast_order must be uniform")]
    fn mixed_e2e_flags_are_refused() {
        let mut pool = LinkPool::new();
        let mut b = TopologyBuilder::new("mixed", &mut pool, 2);
        let rules = vec![AddrRule::new(0, 0x1000, 0, "r0").with_mcast()];
        let mut c0 = XbarCfg::new("a", 1, 1, AddrMap::new(rules.clone(), 1).unwrap());
        c0.e2e_mcast_order = true;
        let c1 = XbarCfg::new("b", 1, 1, AddrMap::new(rules, 1).unwrap());
        let n0 = b.node(c0);
        let n1 = b.node(c1);
        b.ext_master(n0, 0, "m0");
        b.connect(n0, 0, n1, 0);
        b.ext_slave(n1, 0, "s0");
        b.build();
    }

    #[test]
    #[should_panic(expected = "unwired")]
    fn unwired_port_panics() {
        let mut pool = LinkPool::new();
        let mut b = TopologyBuilder::new("bad", &mut pool, 2);
        let rules = vec![AddrRule::new(0, 0x1000, 0, "only")];
        let cfg = XbarCfg::new("x", 1, 1, AddrMap::new(rules, 1).unwrap());
        let n = b.node(cfg);
        b.ext_master(n, 0, "m0");
        // slave port 0 left unwired
        b.build();
    }

    #[test]
    fn ext_lookup_by_name() {
        let mut pool = LinkPool::new();
        let t = build_shape(
            &mut pool,
            2,
            eps(4),
            FabricParams::default(),
            &TopoShape::Flat,
        );
        assert_eq!(t.topo.ext_master("ep0-m"), t.endpoint_m[0]);
        assert_eq!(t.topo.ext_slave("ep3-s"), t.endpoint_s[3]);
    }
}

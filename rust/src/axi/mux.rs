//! Per-slave mux state (paper fig. 2b).
//!
//! The mux arbitrates between the unicast datapath (round-robin, blue in
//! the figure) and the multicast datapath (green), with multicast
//! prioritised because of its stricter ordering requirements. The
//! multicast path implements the *lock/commit* protocol: a requesting
//! master is tentatively **granted** by priority encoder (lzc — lowest
//! master index), and the grant only turns into a forwarded AW once the
//! demux observes grants on *all* addressed muxes and asserts
//! `aw.commit` — forcing a master to acquire all slaves at once and
//! breaking Coffman's "wait for" deadlock condition (fig. 2e).
//!
//! The mux also tracks the **W-order queue**: W bursts must reach the
//! slave in the order AWs were forwarded (AXI write-data ordering), so
//! each forwarded AW enqueues its (master, txn); only the front entry's
//! master may push W beats.

use std::collections::VecDeque;

use super::types::Txn;

/// W-order queue entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WExpect {
    pub master: usize,
    pub txn: Txn,
}

/// The mux state machine for one slave port.
#[derive(Debug)]
pub struct Mux {
    pub idx: usize,
    /// Current multicast grant (master tentatively selected by lzc).
    pub grant: Option<usize>,
    /// Round-robin pointer for the unicast AW arbiter.
    pub rr_aw: usize,
    /// Round-robin pointer for the AR arbiter.
    pub rr_ar: usize,
    /// Round-robin pointer for the *naive* (non-lzc) multicast arbiter
    /// used when the commit protocol is disabled — per-mux RR state is
    /// exactly the inconsistent-selection hazard of fig. 2e.
    pub rr_mcast: usize,
    /// W bursts expected, in AW-forward order.
    pub w_expect: VecDeque<WExpect>,
    /// Stats: cycles the mcast path held a grant without commit.
    pub grant_wait_cycles: u64,
}

impl Mux {
    pub fn new(idx: usize) -> Mux {
        Mux {
            idx,
            grant: None,
            rr_aw: 0,
            rr_ar: 0,
            rr_mcast: 0,
            w_expect: VecDeque::new(),
            grant_wait_cycles: 0,
        }
    }

    /// Recompute the multicast grant: the lowest-index master among
    /// `requesters` (priority encoder / lzc). A held grant is *not*
    /// sticky — consistent priority across muxes is what guarantees
    /// global progress, so re-evaluating each cycle is required for the
    /// case where a lower-priority master's target set overlaps a
    /// higher-priority one's only partially.
    pub fn arbitrate_mcast(&mut self, requesters: &[usize]) {
        self.grant = requesters.iter().copied().min();
        if self.grant.is_some() {
            self.grant_wait_cycles += 1;
        }
    }

    /// Naive multicast arbitration: per-mux round-robin, *without* the
    /// cross-mux consistency of the priority encoder. Used only with
    /// `commit_protocol = false` to reproduce the fig. 2e deadlock.
    pub fn arbitrate_mcast_rr(&mut self, requesters: &[usize], n_masters: usize) {
        if let Some(g) = self.grant {
            // sticky until the leg is forwarded (cleared by the xbar)
            if requesters.contains(&g) {
                self.grant_wait_cycles += 1;
                return;
            }
        }
        self.grant = rr_pick(self.rr_mcast, requesters, n_masters);
        if let Some(g) = self.grant {
            self.rr_mcast = (g + 1) % n_masters;
            self.grant_wait_cycles += 1;
        }
    }

    /// Is the multicast datapath busy enough to stall unicast AWs?
    /// (multicast is prioritised — a live grant blocks unicast issue).
    pub fn mcast_active(&self) -> bool {
        self.grant.is_some()
    }

    /// Record a forwarded AW (commit for mcast, direct for unicast):
    /// the burst's W data is now expected in order.
    pub fn push_w_order(&mut self, master: usize, txn: Txn) {
        self.w_expect.push_back(WExpect { master, txn });
    }

    /// May `master` push a W beat of `txn` to this slave now?
    pub fn w_front_is(&self, master: usize, txn: Txn) -> bool {
        self.w_expect.front() == Some(&WExpect { master, txn })
    }

    /// The burst at the front finished (WLAST forwarded).
    pub fn pop_w_order(&mut self, master: usize, txn: Txn) {
        let front = self.w_expect.pop_front();
        debug_assert_eq!(front, Some(WExpect { master, txn }), "W order violated");
    }

    /// Round-robin pick among `ready` master indices for unicast AW.
    pub fn rr_pick_aw(&mut self, ready: &[usize], n_masters: usize) -> Option<usize> {
        self.rr_pick_aw_scan(n_masters, |m| ready.contains(&m))
    }

    /// Round-robin pick for AR.
    pub fn rr_pick_ar(&mut self, ready: &[usize], n_masters: usize) -> Option<usize> {
        self.rr_pick_ar_scan(n_masters, |m| ready.contains(&m))
    }

    /// Allocation-free round-robin AW pick (hot path).
    #[inline]
    pub fn rr_pick_aw_scan(
        &mut self,
        n_masters: usize,
        mut ready: impl FnMut(usize) -> bool,
    ) -> Option<usize> {
        for off in 0..n_masters {
            let cand = (self.rr_aw + off) % n_masters;
            if ready(cand) {
                self.rr_aw = (cand + 1) % n_masters;
                return Some(cand);
            }
        }
        None
    }

    /// Allocation-free round-robin AR pick (hot path).
    #[inline]
    pub fn rr_pick_ar_scan(
        &mut self,
        n_masters: usize,
        mut ready: impl FnMut(usize) -> bool,
    ) -> Option<usize> {
        for off in 0..n_masters {
            let cand = (self.rr_ar + off) % n_masters;
            if ready(cand) {
                self.rr_ar = (cand + 1) % n_masters;
                return Some(cand);
            }
        }
        None
    }
}

/// Round-robin selection starting from `ptr`.
fn rr_pick(ptr: usize, ready: &[usize], n: usize) -> Option<usize> {
    if ready.is_empty() {
        return None;
    }
    (0..n)
        .map(|off| (ptr + off) % n)
        .find(|cand| ready.contains(cand))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mcast_grant_is_lowest_index() {
        let mut m = Mux::new(0);
        m.arbitrate_mcast(&[3, 1, 2]);
        assert_eq!(m.grant, Some(1));
        m.arbitrate_mcast(&[]);
        assert_eq!(m.grant, None);
    }

    #[test]
    fn grant_reevaluates_each_cycle() {
        let mut m = Mux::new(0);
        m.arbitrate_mcast(&[2]);
        assert_eq!(m.grant, Some(2));
        // a lower-priority master appearing steals the grant — required
        // for cross-mux consistency
        m.arbitrate_mcast(&[2, 0]);
        assert_eq!(m.grant, Some(0));
    }

    #[test]
    fn w_order_fifo() {
        let mut m = Mux::new(0);
        m.push_w_order(0, 100);
        m.push_w_order(1, 101);
        assert!(m.w_front_is(0, 100));
        assert!(!m.w_front_is(1, 101));
        m.pop_w_order(0, 100);
        assert!(m.w_front_is(1, 101));
    }

    #[test]
    #[should_panic]
    #[cfg(debug_assertions)]
    fn w_order_violation_asserts() {
        let mut m = Mux::new(0);
        m.push_w_order(0, 100);
        m.pop_w_order(1, 101);
    }

    #[test]
    fn rr_fairness() {
        let mut m = Mux::new(0);
        let all = [0usize, 1, 2, 3];
        let mut picks = Vec::new();
        for _ in 0..8 {
            picks.push(m.rr_pick_aw(&all, 4).unwrap());
        }
        assert_eq!(picks, vec![0, 1, 2, 3, 0, 1, 2, 3]);
    }

    #[test]
    fn rr_skips_not_ready() {
        let mut m = Mux::new(0);
        assert_eq!(m.rr_pick_aw(&[2], 4), Some(2));
        // pointer advanced past 2
        assert_eq!(m.rr_pick_aw(&[1, 2], 4), Some(1));
        assert_eq!(m.rr_pick_aw(&[], 4), None);
    }
}

//! Per-slave mux state (paper fig. 2b).
//!
//! The mux arbitrates between the unicast datapath (round-robin, blue in
//! the figure) and the multicast datapath (green), with multicast
//! prioritised because of its stricter ordering requirements. The
//! multicast path implements the *lock/commit* protocol: a requesting
//! master is tentatively **granted** by priority encoder (lzc — lowest
//! master index), and the grant only turns into a forwarded AW once the
//! demux observes grants on *all* addressed muxes and asserts
//! `aw.commit` — forcing a master to acquire all slaves at once and
//! breaking Coffman's "wait for" deadlock condition (fig. 2e).
//!
//! The mux also tracks the **W-order queue**: W bursts must reach the
//! slave in the order AWs were forwarded (AXI write-data ordering), so
//! each forwarded AW enqueues its (master, txn); only the front entry's
//! master may push W beats.

use std::collections::VecDeque;

use super::types::Txn;

/// Arbitration policy for the per-slave unicast AW / AR pickers (and the
/// static tier of the multicast priority encoder).
///
/// `RoundRobin` is the historical default and is bit-identical to the
/// pre-QoS fabric. `Priority { aging }` implements static per-master
/// priority with an aging boost: the effective priority of master `m`
/// is `prio[m] + waited[m] / aging`, where `waited[m]` counts arbitration
/// rounds in which `m` was ready but another master was granted. A
/// master with static priority `p` therefore waits at most
/// `aging * (p_max - p)` rounds before competing at the top tier, after
/// which the lowest-index tie-break admits it within `n_masters` further
/// grants — the starvation bound documented in DESIGN.md §9.
///
/// `aging == 0` disables the boost entirely (pure static priority, which
/// *can* starve low-priority masters — only for hard-QoS experiments).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ArbPolicy {
    /// Fair round-robin (default; bit-identical to the historical fabric).
    #[default]
    RoundRobin,
    /// Static per-master priority with an aging boost every `aging`
    /// lost arbitration rounds.
    Priority {
        /// Rounds a ready-but-skipped master waits per +1 effective
        /// priority. 0 disables aging (pure static priority).
        aging: u32,
    },
}

/// W-order queue entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WExpect {
    pub master: usize,
    pub txn: Txn,
}

/// The mux state machine for one slave port.
#[derive(Debug)]
pub struct Mux {
    pub idx: usize,
    /// Current multicast grant (master tentatively selected by lzc).
    pub grant: Option<usize>,
    /// Round-robin pointer for the unicast AW arbiter.
    pub rr_aw: usize,
    /// Round-robin pointer for the AR arbiter.
    pub rr_ar: usize,
    /// Round-robin pointer for the *naive* (non-lzc) multicast arbiter
    /// used when the commit protocol is disabled — per-mux RR state is
    /// exactly the inconsistent-selection hazard of fig. 2e.
    pub rr_mcast: usize,
    /// W bursts expected, in AW-forward order.
    pub w_expect: VecDeque<WExpect>,
    /// Stats: cycles the mcast path held a grant without commit.
    pub grant_wait_cycles: u64,
    /// Aging counters for `ArbPolicy::Priority` — rounds each master was
    /// ready at the AW arbiter but lost. Untouched under `RoundRobin`
    /// (bit parity), and never incremented across `skip()` windows:
    /// a ready-but-skipped candidate implies `next_event == now`, so the
    /// event horizon never jumps while these could tick.
    pub aw_wait: Vec<u32>,
    /// Aging counters for the AR arbiter (same rules as `aw_wait`).
    pub ar_wait: Vec<u32>,
}

impl Mux {
    pub fn new(idx: usize) -> Mux {
        Mux {
            idx,
            grant: None,
            rr_aw: 0,
            rr_ar: 0,
            rr_mcast: 0,
            w_expect: VecDeque::new(),
            grant_wait_cycles: 0,
            aw_wait: Vec::new(),
            ar_wait: Vec::new(),
        }
    }

    /// Recompute the multicast grant: the lowest-index master among
    /// `requesters` (priority encoder / lzc). A held grant is *not*
    /// sticky — consistent priority across muxes is what guarantees
    /// global progress, so re-evaluating each cycle is required for the
    /// case where a lower-priority master's target set overlaps a
    /// higher-priority one's only partially.
    pub fn arbitrate_mcast(&mut self, requesters: &[usize]) {
        self.grant = requesters.iter().copied().min();
        if self.grant.is_some() {
            self.grant_wait_cycles += 1;
        }
    }

    /// Naive multicast arbitration: per-mux round-robin, *without* the
    /// cross-mux consistency of the priority encoder. Used only with
    /// `commit_protocol = false` to reproduce the fig. 2e deadlock.
    pub fn arbitrate_mcast_rr(&mut self, requesters: &[usize], n_masters: usize) {
        if let Some(g) = self.grant {
            // sticky until the leg is forwarded (cleared by the xbar)
            if requesters.contains(&g) {
                self.grant_wait_cycles += 1;
                return;
            }
        }
        self.grant = rr_pick(self.rr_mcast, requesters, n_masters);
        if let Some(g) = self.grant {
            self.rr_mcast = (g + 1) % n_masters;
            self.grant_wait_cycles += 1;
        }
    }

    /// Is the multicast datapath busy enough to stall unicast AWs?
    /// (multicast is prioritised — a live grant blocks unicast issue).
    pub fn mcast_active(&self) -> bool {
        self.grant.is_some()
    }

    /// Record a forwarded AW (commit for mcast, direct for unicast):
    /// the burst's W data is now expected in order.
    pub fn push_w_order(&mut self, master: usize, txn: Txn) {
        self.w_expect.push_back(WExpect { master, txn });
    }

    /// May `master` push a W beat of `txn` to this slave now?
    pub fn w_front_is(&self, master: usize, txn: Txn) -> bool {
        self.w_expect.front() == Some(&WExpect { master, txn })
    }

    /// The burst at the front finished (WLAST forwarded).
    pub fn pop_w_order(&mut self, master: usize, txn: Txn) {
        let front = self.w_expect.pop_front();
        debug_assert_eq!(front, Some(WExpect { master, txn }), "W order violated");
    }

    /// Round-robin pick among `ready` master indices for unicast AW.
    pub fn rr_pick_aw(&mut self, ready: &[usize], n_masters: usize) -> Option<usize> {
        self.rr_pick_aw_scan(n_masters, |m| ready.contains(&m))
    }

    /// Round-robin pick for AR.
    pub fn rr_pick_ar(&mut self, ready: &[usize], n_masters: usize) -> Option<usize> {
        self.rr_pick_ar_scan(n_masters, |m| ready.contains(&m))
    }

    /// Allocation-free round-robin AW pick (hot path).
    #[inline]
    pub fn rr_pick_aw_scan(
        &mut self,
        n_masters: usize,
        mut ready: impl FnMut(usize) -> bool,
    ) -> Option<usize> {
        for off in 0..n_masters {
            let cand = (self.rr_aw + off) % n_masters;
            if ready(cand) {
                self.rr_aw = (cand + 1) % n_masters;
                return Some(cand);
            }
        }
        None
    }

    /// Allocation-free round-robin AR pick (hot path).
    #[inline]
    pub fn rr_pick_ar_scan(
        &mut self,
        n_masters: usize,
        mut ready: impl FnMut(usize) -> bool,
    ) -> Option<usize> {
        for off in 0..n_masters {
            let cand = (self.rr_ar + off) % n_masters;
            if ready(cand) {
                self.rr_ar = (cand + 1) % n_masters;
                return Some(cand);
            }
        }
        None
    }

    /// Policy-dispatching AW pick: round-robin or priority+aging.
    #[inline]
    pub fn pick_aw_scan(
        &mut self,
        n_masters: usize,
        policy: ArbPolicy,
        prio: &[u32],
        ready: impl FnMut(usize) -> bool,
    ) -> Option<usize> {
        match policy {
            ArbPolicy::RoundRobin => self.rr_pick_aw_scan(n_masters, ready),
            ArbPolicy::Priority { aging } => {
                prio_pick(&mut self.aw_wait, n_masters, aging, prio, ready)
            }
        }
    }

    /// Policy-dispatching AR pick: round-robin or priority+aging.
    #[inline]
    pub fn pick_ar_scan(
        &mut self,
        n_masters: usize,
        policy: ArbPolicy,
        prio: &[u32],
        ready: impl FnMut(usize) -> bool,
    ) -> Option<usize> {
        match policy {
            ArbPolicy::RoundRobin => self.rr_pick_ar_scan(n_masters, ready),
            ArbPolicy::Priority { aging } => {
                prio_pick(&mut self.ar_wait, n_masters, aging, prio, ready)
            }
        }
    }

    /// Multicast grant under static priority: highest `prio` wins, ties
    /// broken by lowest index. This stays *consistent across muxes*
    /// (the property the lock/commit protocol needs for deadlock
    /// freedom) because the ordering key is global, unlike per-mux
    /// aging — which is deliberately NOT applied to the mcast path.
    pub fn arbitrate_mcast_prio(&mut self, requesters: &[usize], prio: &[u32]) {
        self.grant = requesters
            .iter()
            .copied()
            .min_by_key(|&m| (std::cmp::Reverse(prio.get(m).copied().unwrap_or(0)), m));
        if self.grant.is_some() {
            self.grant_wait_cycles += 1;
        }
    }

    /// Remove a W-order entry *anywhere* in the queue — used when a
    /// request timeout retires a forwarded burst whose W data will never
    /// fully arrive at this slave. Unlike `pop_w_order` this does not
    /// assume the entry is at the front. Returns true if found.
    pub fn evict_w_order(&mut self, master: usize, txn: Txn) -> bool {
        if let Some(pos) = self
            .w_expect
            .iter()
            .position(|e| e.master == master && e.txn == txn)
        {
            self.w_expect.remove(pos);
            true
        } else {
            false
        }
    }
}

/// Priority + aging pick over `ready` masters: effective priority is
/// `prio[m] + wait[m] / aging`, argmax wins, ties to the lowest index.
/// Ready losers age by one round; the winner's credit resets.
#[inline]
fn prio_pick(
    wait: &mut Vec<u32>,
    n_masters: usize,
    aging: u32,
    prio: &[u32],
    mut ready: impl FnMut(usize) -> bool,
) -> Option<usize> {
    debug_assert!(n_masters <= 128, "priority arbitration supports <= 128 masters");
    if wait.len() < n_masters {
        wait.resize(n_masters, 0);
    }
    let mut mask: u128 = 0;
    let mut best: Option<(u64, usize)> = None;
    for m in 0..n_masters {
        if !ready(m) {
            continue;
        }
        mask |= 1 << m;
        let boost = if aging == 0 { 0 } else { u64::from(wait[m] / aging) };
        let eff = u64::from(prio.get(m).copied().unwrap_or(0)) + boost;
        // strictly-greater keeps the tie-break at the lowest index
        if best.is_none_or(|(b, _)| eff > b) {
            best = Some((eff, m));
        }
    }
    let (_, win) = best?;
    for m in 0..n_masters {
        if mask & (1 << m) == 0 {
            continue;
        }
        if m == win {
            wait[m] = 0;
        } else {
            wait[m] = wait[m].saturating_add(1);
        }
    }
    Some(win)
}

/// Round-robin selection starting from `ptr`.
fn rr_pick(ptr: usize, ready: &[usize], n: usize) -> Option<usize> {
    if ready.is_empty() {
        return None;
    }
    (0..n)
        .map(|off| (ptr + off) % n)
        .find(|cand| ready.contains(cand))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mcast_grant_is_lowest_index() {
        let mut m = Mux::new(0);
        m.arbitrate_mcast(&[3, 1, 2]);
        assert_eq!(m.grant, Some(1));
        m.arbitrate_mcast(&[]);
        assert_eq!(m.grant, None);
    }

    #[test]
    fn grant_reevaluates_each_cycle() {
        let mut m = Mux::new(0);
        m.arbitrate_mcast(&[2]);
        assert_eq!(m.grant, Some(2));
        // a lower-priority master appearing steals the grant — required
        // for cross-mux consistency
        m.arbitrate_mcast(&[2, 0]);
        assert_eq!(m.grant, Some(0));
    }

    #[test]
    fn w_order_fifo() {
        let mut m = Mux::new(0);
        m.push_w_order(0, 100);
        m.push_w_order(1, 101);
        assert!(m.w_front_is(0, 100));
        assert!(!m.w_front_is(1, 101));
        m.pop_w_order(0, 100);
        assert!(m.w_front_is(1, 101));
    }

    #[test]
    #[should_panic]
    #[cfg(debug_assertions)]
    fn w_order_violation_asserts() {
        let mut m = Mux::new(0);
        m.push_w_order(0, 100);
        m.pop_w_order(1, 101);
    }

    #[test]
    fn rr_fairness() {
        let mut m = Mux::new(0);
        let all = [0usize, 1, 2, 3];
        let mut picks = Vec::new();
        for _ in 0..8 {
            picks.push(m.rr_pick_aw(&all, 4).unwrap());
        }
        assert_eq!(picks, vec![0, 1, 2, 3, 0, 1, 2, 3]);
    }

    #[test]
    fn prio_pick_prefers_static_priority() {
        let mut m = Mux::new(0);
        let prio = [0u32, 5, 1];
        // aging disabled: the high-priority master wins every round
        let p = ArbPolicy::Priority { aging: 0 };
        for _ in 0..4 {
            assert_eq!(m.pick_aw_scan(3, p, &prio, |_| true), Some(1));
        }
        // ties break to the lowest index
        assert_eq!(m.pick_aw_scan(3, p, &[2, 0, 2], |_| true), Some(0));
    }

    #[test]
    fn aging_bounds_starvation() {
        let mut m = Mux::new(0);
        let prio = [0u32, 3];
        let aging = 2u32;
        // the DESIGN.md §9 bound: a ready master waits at most
        // aging * (Δprio + n_masters) rounds before winning
        let bound = aging * (3 + 2);
        let mut won = None;
        for round in 0..=bound {
            if m.pick_aw_scan(2, ArbPolicy::Priority { aging }, &prio, |_| true) == Some(0) {
                won = Some(round);
                break;
            }
        }
        assert!(won.is_some(), "low-priority master starved past the bound");
    }

    #[test]
    fn mcast_prio_grant_is_consistent_across_muxes() {
        let mut a = Mux::new(0);
        let mut b = Mux::new(1);
        let prio = [0u32, 7, 2];
        a.arbitrate_mcast_prio(&[0, 1, 2], &prio);
        b.arbitrate_mcast_prio(&[1, 2], &prio);
        // the ordering key is global, so overlapping requester sets
        // agree wherever the winner requests
        assert_eq!(a.grant, Some(1));
        assert_eq!(b.grant, Some(1));
    }

    #[test]
    fn evict_w_order_removes_mid_queue_entry() {
        let mut m = Mux::new(0);
        m.push_w_order(0, 100);
        m.push_w_order(1, 101);
        m.push_w_order(2, 102);
        assert!(m.evict_w_order(1, 101));
        assert!(!m.evict_w_order(1, 101));
        m.pop_w_order(0, 100);
        assert!(m.w_front_is(2, 102));
    }

    #[test]
    fn rr_skips_not_ready() {
        let mut m = Mux::new(0);
        assert_eq!(m.rr_pick_aw(&[2], 4), Some(2));
        // pointer advanced past 2
        assert_eq!(m.rr_pick_aw(&[1, 2], 4), Some(1));
        assert_eq!(m.rr_pick_aw(&[], 4), None);
    }
}

//! Golden slave model for crossbar tests.
//!
//! [`SimSlave`] is a well-behaved AXI subordinate: it consumes AW/W,
//! returns one B per burst after a configurable latency, serves AR with
//! R bursts, and feeds every observed beat through the protocol
//! checkers in [`monitor`](super::monitor). Tests compare crossbar
//! deliveries against expectations via the recorded transactions.

use std::collections::VecDeque;

use super::monitor::OrderChecker;
use super::types::{AxiLink, BBeat, LinkId, LinkPool, RBeat, Resp, Txn};
use crate::sim::Cycle;

/// A recorded, completed write burst.
#[derive(Debug, Clone)]
pub struct WriteRec {
    pub txn: Txn,
    pub base: u64,
    pub beats: u32,
    pub bytes: u64,
    pub done_at: Cycle,
}

/// Configurable golden slave.
#[derive(Debug)]
pub struct SimSlave {
    pub idx: usize,
    /// Cycles between WLAST and the B response.
    pub b_lat: u32,
    /// Cycles between AR and the first R beat.
    pub r_lat: u32,
    /// Response code returned for writes (inject SLVERR in tests).
    pub wresp: Resp,
    /// Accept a W beat only every `w_every` cycles (backpressure).
    pub w_every: u32,
    /// Idle cycles between consecutive R burst jobs (bank/arb gap).
    pub r_gap: u32,

    order: OrderChecker,
    /// In-progress bursts (front = active): (txn, base, beats_left, total).
    w_queue: VecDeque<(Txn, u64, u32, u32)>,
    b_sched: VecDeque<(Cycle, BBeat)>,
    r_jobs: VecDeque<(Cycle, u16, Txn, u32)>,
    pub writes: Vec<WriteRec>,
    pub reads: Vec<(Txn, u64, u32)>,
}

impl SimSlave {
    pub fn new(idx: usize) -> SimSlave {
        SimSlave {
            idx,
            b_lat: 2,
            r_lat: 4,
            wresp: Resp::Okay,
            w_every: 1,
            r_gap: 0,
            order: OrderChecker::new(),
            w_queue: VecDeque::new(),
            b_sched: VecDeque::new(),
            r_jobs: VecDeque::new(),
            writes: Vec::new(),
            reads: Vec::new(),
        }
    }

    /// One cycle on this slave's link (the xbar's slave-side port).
    pub fn step(&mut self, cy: Cycle, link: &mut AxiLink) {
        // AW: accept one request per cycle
        if let Some(aw) = link.aw.pop() {
            // leaf slaves normally see singleton dests; a multi-address
            // subset within one slave (strided SPM write) is recorded by
            // its base address.
            self.order.feed_aw(aw.txn, aw.beats);
            self.w_queue
                .push_back((aw.txn, aw.dest.base(), aw.beats, aw.beats));
        }
        // W: consume at the configured rate
        if self.w_every <= 1 || cy % self.w_every as u64 == 0 {
            if let Some(w) = link.w.pop() {
                self.order.feed_w(w.txn, w.last);
                let (txn, base, left, total) =
                    self.w_queue.front_mut().expect("W beat with no burst");
                *left -= 1;
                assert_eq!(w.last, *left == 0, "WLAST mismatch at slave {}", self.idx);
                if *left == 0 {
                    let rec = WriteRec {
                        txn: *txn,
                        base: *base,
                        beats: *total,
                        bytes: 0,
                        done_at: cy,
                    };
                    let id = 0;
                    self.b_sched.push_back((
                        cy + self.b_lat as u64,
                        BBeat {
                            id,
                            resp: self.wresp,
                            txn: *txn,
                        },
                    ));
                    self.writes.push(rec);
                    self.w_queue.pop_front();
                }
            }
        }
        // B: release when latency elapsed
        if let Some(&(ready, b)) = self.b_sched.front() {
            if cy >= ready && link.b.can_push() {
                self.b_sched.pop_front();
                link.b.push(b);
            }
        }
        // AR: accept
        if let Some(ar) = link.ar.pop() {
            self.reads.push((ar.txn, ar.addr, ar.beats));
            self.r_jobs
                .push_back((cy + self.r_lat as u64, ar.id, ar.txn, ar.beats));
        }
        // R: stream one beat per cycle from the front job
        if let Some(&mut (ready, id, txn, ref mut beats)) = self.r_jobs.front_mut() {
            if cy >= ready && link.r.can_push() {
                *beats -= 1;
                let last = *beats == 0;
                link.r.push(RBeat {
                    id,
                    last,
                    resp: Resp::Okay,
                    txn,
                });
                if last {
                    self.r_jobs.pop_front();
                    // bank-conflict/arbitration gap before the next burst
                    if let Some(next) = self.r_jobs.front_mut() {
                        next.0 = next.0.max(cy + 1 + self.r_gap as u64);
                    }
                }
            }
        }
    }

    /// One cycle against a pooled link (topology-built fabrics).
    pub fn step_on(&mut self, cy: Cycle, pool: &mut LinkPool, link: LinkId) {
        self.step(cy, &mut pool[link]);
    }

    pub fn assert_clean(&self) {
        self.order.assert_clean();
    }

    pub fn idle(&self) -> bool {
        self.w_queue.is_empty() && self.b_sched.is_empty() && self.r_jobs.is_empty()
    }

    /// Event horizon (§Perf): the earliest cycle ≥ `now` at which this
    /// slave can act without new input — its response schedule is kept
    /// in absolute cycles, so waiting costs nothing to skip (no
    /// per-cycle state to advance). In-progress W bursts wait on beats
    /// (port activity) and contribute nothing.
    pub fn next_event(&self, now: Cycle) -> Option<Cycle> {
        let mut ev: Option<Cycle> = None;
        let mut fold = |e: Cycle| crate::sim::sched::fold_min(&mut ev, e);
        if let Some(&(ready, _)) = self.b_sched.front() {
            fold(ready.max(now));
        }
        if let Some(&(ready, _, _, _)) = self.r_jobs.front() {
            fold(ready.max(now));
        }
        ev
    }

    /// Transactions delivered to this slave, in completion order.
    pub fn delivered_txns(&self) -> Vec<Txn> {
        self.writes.iter().map(|w| w.txn).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::axi::mcast::AddrSet;
    use crate::axi::types::{ArBeat, AwBeat, WBeat};

    fn aw(txn: Txn, beats: u32) -> AwBeat {
        AwBeat {
            id: 0,
            dest: AddrSet::unicast(0x1000),
            beats,
            beat_bytes: 64,
            is_mcast: false,
            exclude: None,
            src: 0,
            txn,
            ticket: None,
            reduce: None,
        }
    }

    #[test]
    fn write_burst_gets_b_after_latency() {
        let mut s = SimSlave::new(0);
        s.b_lat = 3;
        let mut link = AxiLink::new(4);
        link.aw.push(aw(1, 2));
        link.w.push(WBeat {
            last: false,
            src: 0,
            txn: 1,
        });
        link.w.push(WBeat {
            last: true,
            src: 0,
            txn: 1,
        });
        let mut b_at = None;
        for cy in 0..20 {
            link.tick();
            s.step(cy, &mut link);
            if link.b.visible() > 0 && b_at.is_none() {
                b_at = Some(cy);
                break;
            }
        }
        s.assert_clean();
        assert_eq!(s.writes.len(), 1);
        let done = s.writes[0].done_at;
        // B staged at done+3, visible one tick later
        assert!(b_at.unwrap() >= done + 3, "b_at={b_at:?} done={done}");
    }

    #[test]
    fn read_burst_streams_r_beats() {
        let mut s = SimSlave::new(0);
        s.r_lat = 2;
        let mut link = AxiLink::new(8);
        link.ar.push(ArBeat {
            id: 1,
            addr: 0x1000,
            beats: 4,
            beat_bytes: 64,
            src: 0,
            txn: 9,
        });
        let mut beats = 0;
        let mut lasts = 0;
        for cy in 0..30 {
            link.tick();
            s.step(cy, &mut link);
            while let Some(r) = link.r.pop() {
                beats += 1;
                if r.last {
                    lasts += 1;
                }
            }
        }
        assert_eq!(beats, 4);
        assert_eq!(lasts, 1);
        assert!(s.idle());
    }

    #[test]
    fn backpressured_w_still_correct() {
        let mut s = SimSlave::new(0);
        s.w_every = 3; // accept every third cycle only
        let mut link = AxiLink::new(4);
        link.aw.push(aw(5, 4));
        let mut sent = 0;
        for cy in 0..60 {
            link.tick();
            if sent < 4 && link.w.can_push() {
                sent += 1;
                link.w.push(WBeat {
                    last: sent == 4,
                    src: 0,
                    txn: 5,
                });
            }
            s.step(cy, &mut link);
        }
        s.assert_clean();
        assert_eq!(s.writes.len(), 1);
        assert_eq!(s.writes[0].beats, 4);
    }
}

//! Golden slave model for crossbar tests.
//!
//! [`SimSlave`] is a well-behaved AXI subordinate: it consumes AW/W,
//! returns one B per burst after a configurable latency, serves AR with
//! R bursts, and feeds every observed beat through the protocol
//! checkers in [`monitor`](super::monitor). Tests compare crossbar
//! deliveries against expectations via the recorded transactions.

use std::collections::VecDeque;

use super::monitor::OrderChecker;
use super::types::{AxiLink, BBeat, LinkId, LinkPool, RBeat, Resp, Txn};
use crate::sim::Cycle;

/// A recorded, completed write burst.
#[derive(Debug, Clone)]
pub struct WriteRec {
    pub txn: Txn,
    pub base: u64,
    pub beats: u32,
    pub bytes: u64,
    pub done_at: Cycle,
}

/// Endpoint fault model for the robustness layer (`XbarCfg::req_timeout`
/// / `cpl_timeout` recovery): each plan turns a [`SimSlave`] into a
/// specific kind of misbehaving subordinate. The timeouts must be set
/// well above the slave's worst-case healthy service time (burst length
/// × `w_every`, `r_lat`, `b_lat`) — like any hardware watchdog, a
/// deadline shorter than legitimate latency poisons healthy traffic.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FaultPlan {
    /// Well-behaved (the default — bit-identical to the pre-fault model).
    #[default]
    None,
    /// Die after consuming the WLAST of the `bursts`-th write burst:
    /// the first `bursts - 1` bursts complete normally, the
    /// `bursts`-th burst's B is swallowed, and from that point the
    /// slave consumes and emits nothing (`bursts == 0` ⇒ dead from
    /// reset). Exercises the completion-timeout SLVERR path and — via
    /// the backed-up AW/W channels — the request-timeout DECERR path
    /// for everything queued behind.
    StallAfter { bursts: u32 },
    /// Swallow the `nth` (0-based) B response; everything else normal.
    /// The dropped burst's WLAST was consumed, so its scoreboard leg is
    /// unconditionally eligible for the completion deadline.
    DropB { nth: u32 },
    /// Swallow the `nth` (0-based) R burst entirely (job accepted,
    /// never streamed); everything else normal.
    DropR { nth: u32 },
    /// Accept AW/AR handshakes but never consume a W beat and never
    /// respond — the pathological "granted then hung" endpoint.
    GrantThenHang,
}

/// Configurable golden slave.
#[derive(Debug)]
pub struct SimSlave {
    pub idx: usize,
    /// Cycles between WLAST and the B response.
    pub b_lat: u32,
    /// Cycles between AR and the first R beat.
    pub r_lat: u32,
    /// Response code returned for writes (inject SLVERR in tests).
    pub wresp: Resp,
    /// Accept a W beat only every `w_every` cycles (backpressure).
    pub w_every: u32,
    /// Idle cycles between consecutive R burst jobs (bank/arb gap).
    pub r_gap: u32,
    /// Fault injection plan (default: well-behaved).
    pub fault: FaultPlan,

    order: OrderChecker,
    /// In-progress bursts (front = active): (txn, base, beats_left, total).
    w_queue: VecDeque<(Txn, u64, u32, u32)>,
    b_sched: VecDeque<(Cycle, BBeat)>,
    r_jobs: VecDeque<(Cycle, u16, Txn, u32)>,
    /// B responses released (or swallowed) so far — `DropB` index base.
    b_served: u32,
    /// R bursts streamed (or swallowed) so far — `DropR` index base.
    r_served: u32,
    pub writes: Vec<WriteRec>,
    pub reads: Vec<(Txn, u64, u32)>,
}

impl SimSlave {
    pub fn new(idx: usize) -> SimSlave {
        SimSlave {
            idx,
            b_lat: 2,
            r_lat: 4,
            wresp: Resp::Okay,
            w_every: 1,
            r_gap: 0,
            fault: FaultPlan::None,
            order: OrderChecker::new(),
            w_queue: VecDeque::new(),
            b_sched: VecDeque::new(),
            r_jobs: VecDeque::new(),
            b_served: 0,
            r_served: 0,
            writes: Vec::new(),
            reads: Vec::new(),
        }
    }

    /// Is the slave permanently wedged by its fault plan? (Residue
    /// behind a dead slave never drains and is excluded from `idle`.)
    fn dead(&self) -> bool {
        matches!(self.fault, FaultPlan::StallAfter { bursts }
            if self.writes.len() as u32 >= bursts)
    }

    /// One cycle on this slave's link (the xbar's slave-side port).
    pub fn step(&mut self, cy: Cycle, link: &mut AxiLink) {
        if self.dead() {
            return;
        }
        let hang = self.fault == FaultPlan::GrantThenHang;
        // AW: accept one request per cycle
        if let Some(aw) = link.aw.pop() {
            // leaf slaves normally see singleton dests; a multi-address
            // subset within one slave (strided SPM write) is recorded by
            // its base address.
            self.order.feed_aw(aw.txn, aw.beats);
            self.w_queue
                .push_back((aw.txn, aw.dest.base(), aw.beats, aw.beats));
        }
        // W: consume at the configured rate (a hung slave never does)
        if !hang && (self.w_every <= 1 || cy % self.w_every as u64 == 0) {
            if let Some(w) = link.w.pop() {
                self.order.feed_w(w.txn, w.last);
                let (txn, base, left, total) =
                    self.w_queue.front_mut().expect("W beat with no burst");
                *left -= 1;
                assert_eq!(w.last, *left == 0, "WLAST mismatch at slave {}", self.idx);
                if *left == 0 {
                    let rec = WriteRec {
                        txn: *txn,
                        base: *base,
                        beats: *total,
                        bytes: 0,
                        done_at: cy,
                    };
                    let id = 0;
                    self.b_sched.push_back((
                        cy + self.b_lat as u64,
                        BBeat {
                            id,
                            resp: self.wresp,
                            txn: *txn,
                        },
                    ));
                    self.writes.push(rec);
                    self.w_queue.pop_front();
                }
            }
        }
        // B: release when latency elapsed (`DropB` swallows its victim)
        if let Some(&(ready, b)) = self.b_sched.front() {
            if cy >= ready && link.b.can_push() {
                self.b_sched.pop_front();
                let drop = matches!(self.fault, FaultPlan::DropB { nth } if self.b_served == nth);
                self.b_served += 1;
                if !drop {
                    link.b.push(b);
                }
            }
        }
        // AR: accept (a hung slave takes the handshake, then nothing)
        if let Some(ar) = link.ar.pop() {
            self.reads.push((ar.txn, ar.addr, ar.beats));
            if !hang {
                self.r_jobs
                    .push_back((cy + self.r_lat as u64, ar.id, ar.txn, ar.beats));
            }
        }
        // `DropR` swallows its victim burst whole at stream start
        if let Some(&(ready, _, _, _)) = self.r_jobs.front() {
            if cy >= ready
                && matches!(self.fault, FaultPlan::DropR { nth } if self.r_served == nth)
            {
                self.r_jobs.pop_front();
                self.r_served += 1;
                if let Some(next) = self.r_jobs.front_mut() {
                    next.0 = next.0.max(cy + 1 + self.r_gap as u64);
                }
            }
        }
        // R: stream one beat per cycle from the front job
        if let Some(&mut (ready, id, txn, ref mut beats)) = self.r_jobs.front_mut() {
            if cy >= ready && link.r.can_push() {
                *beats -= 1;
                let last = *beats == 0;
                link.r.push(RBeat {
                    id,
                    last,
                    resp: Resp::Okay,
                    txn,
                });
                if last {
                    self.r_jobs.pop_front();
                    self.r_served += 1;
                    // bank-conflict/arbitration gap before the next burst
                    if let Some(next) = self.r_jobs.front_mut() {
                        next.0 = next.0.max(cy + 1 + self.r_gap as u64);
                    }
                }
            }
        }
    }

    /// One cycle against a pooled link (topology-built fabrics).
    pub fn step_on(&mut self, cy: Cycle, pool: &mut LinkPool, link: LinkId) {
        self.step(cy, &mut pool[link]);
    }

    pub fn assert_clean(&self) {
        self.order.assert_clean();
    }

    pub fn idle(&self) -> bool {
        // residue wedged behind a dead/hung endpoint never drains — it
        // must not hold the run open (the xbar timeouts complete the
        // master side; the watchdog would otherwise fire on the slave)
        if self.dead() || self.fault == FaultPlan::GrantThenHang {
            return true;
        }
        self.w_queue.is_empty() && self.b_sched.is_empty() && self.r_jobs.is_empty()
    }

    /// Event horizon (§Perf): the earliest cycle ≥ `now` at which this
    /// slave can act without new input — its response schedule is kept
    /// in absolute cycles, so waiting costs nothing to skip (no
    /// per-cycle state to advance). In-progress W bursts wait on beats
    /// (port activity) and contribute nothing.
    pub fn next_event(&self, now: Cycle) -> Option<Cycle> {
        // a dead/hung slave never acts again on its own
        if self.dead() || self.fault == FaultPlan::GrantThenHang {
            return None;
        }
        let mut ev: Option<Cycle> = None;
        let mut fold = |e: Cycle| crate::sim::sched::fold_min(&mut ev, e);
        if let Some(&(ready, _)) = self.b_sched.front() {
            fold(ready.max(now));
        }
        if let Some(&(ready, _, _, _)) = self.r_jobs.front() {
            fold(ready.max(now));
        }
        ev
    }

    /// Transactions delivered to this slave, in completion order.
    pub fn delivered_txns(&self) -> Vec<Txn> {
        self.writes.iter().map(|w| w.txn).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::axi::mcast::AddrSet;
    use crate::axi::types::{ArBeat, AwBeat, WBeat};

    fn aw(txn: Txn, beats: u32) -> AwBeat {
        AwBeat {
            id: 0,
            dest: AddrSet::unicast(0x1000),
            beats,
            beat_bytes: 64,
            is_mcast: false,
            exclude: None,
            window: None,
            src: 0,
            txn,
            ticket: None,
            reduce: None,
        }
    }

    #[test]
    fn write_burst_gets_b_after_latency() {
        let mut s = SimSlave::new(0);
        s.b_lat = 3;
        let mut link = AxiLink::new(4);
        link.aw.push(aw(1, 2));
        link.w.push(WBeat {
            last: false,
            src: 0,
            txn: 1,
        });
        link.w.push(WBeat {
            last: true,
            src: 0,
            txn: 1,
        });
        let mut b_at = None;
        for cy in 0..20 {
            link.tick();
            s.step(cy, &mut link);
            if link.b.visible() > 0 && b_at.is_none() {
                b_at = Some(cy);
                break;
            }
        }
        s.assert_clean();
        assert_eq!(s.writes.len(), 1);
        let done = s.writes[0].done_at;
        // B staged at done+3, visible one tick later
        assert!(b_at.unwrap() >= done + 3, "b_at={b_at:?} done={done}");
    }

    #[test]
    fn read_burst_streams_r_beats() {
        let mut s = SimSlave::new(0);
        s.r_lat = 2;
        let mut link = AxiLink::new(8);
        link.ar.push(ArBeat {
            id: 1,
            addr: 0x1000,
            beats: 4,
            beat_bytes: 64,
            src: 0,
            txn: 9,
        });
        let mut beats = 0;
        let mut lasts = 0;
        for cy in 0..30 {
            link.tick();
            s.step(cy, &mut link);
            while let Some(r) = link.r.pop() {
                beats += 1;
                if r.last {
                    lasts += 1;
                }
            }
        }
        assert_eq!(beats, 4);
        assert_eq!(lasts, 1);
        assert!(s.idle());
    }

    #[test]
    fn stall_after_kills_the_slave_at_the_nth_wlast() {
        let mut s = SimSlave::new(0);
        s.fault = FaultPlan::StallAfter { bursts: 1 };
        s.b_lat = 1;
        let mut link = AxiLink::new(4);
        link.aw.push(aw(1, 1));
        link.w.push(WBeat {
            last: true,
            src: 0,
            txn: 1,
        });
        for cy in 0..20 {
            link.tick();
            s.step(cy, &mut link);
        }
        // the WLAST was consumed but its B is swallowed; the dead
        // slave's residue does not hold the run open
        assert_eq!(s.writes.len(), 1);
        assert_eq!(link.b.visible(), 0);
        assert!(s.idle());
        assert_eq!(s.next_event(0), None);
        s.assert_clean();
    }

    #[test]
    fn drop_b_swallows_only_its_victim() {
        let mut s = SimSlave::new(0);
        s.fault = FaultPlan::DropB { nth: 0 };
        s.b_lat = 1;
        let mut link = AxiLink::new(8);
        let mut got = Vec::new();
        for cy in 0..40 {
            link.tick();
            if cy == 0 {
                link.aw.push(aw(1, 1));
                link.w.push(WBeat {
                    last: true,
                    src: 0,
                    txn: 1,
                });
            }
            if cy == 10 {
                link.aw.push(aw(2, 1));
                link.w.push(WBeat {
                    last: true,
                    src: 0,
                    txn: 2,
                });
            }
            s.step(cy, &mut link);
            while let Some(b) = link.b.pop() {
                got.push(b.txn);
            }
        }
        // burst 1's B was dropped; burst 2 completes normally
        assert_eq!(got, vec![2]);
        assert!(s.idle());
        s.assert_clean();
    }

    #[test]
    fn grant_then_hang_accepts_handshakes_only() {
        let mut s = SimSlave::new(0);
        s.fault = FaultPlan::GrantThenHang;
        let mut link = AxiLink::new(4);
        link.aw.push(aw(7, 2));
        link.ar.push(ArBeat {
            id: 0,
            addr: 0x1000,
            beats: 2,
            beat_bytes: 64,
            src: 0,
            txn: 8,
        });
        link.w.push(WBeat {
            last: false,
            src: 0,
            txn: 7,
        });
        for cy in 0..20 {
            link.tick();
            s.step(cy, &mut link);
        }
        // handshakes taken, W beat never consumed, no responses
        assert_eq!(s.reads.len(), 1);
        assert_eq!(link.w.visible(), 1);
        assert_eq!(link.b.visible(), 0);
        assert_eq!(link.r.visible(), 0);
        assert!(s.idle());
        assert_eq!(s.next_event(0), None);
    }

    #[test]
    fn backpressured_w_still_correct() {
        let mut s = SimSlave::new(0);
        s.w_every = 3; // accept every third cycle only
        let mut link = AxiLink::new(4);
        link.aw.push(aw(5, 4));
        let mut sent = 0;
        for cy in 0..60 {
            link.tick();
            if sent < 4 && link.w.can_push() {
                sent += 1;
                link.w.push(WBeat {
                    last: sent == 4,
                    src: 0,
                    txn: 5,
                });
            }
            s.step(cy, &mut link);
        }
        s.assert_clean();
        assert_eq!(s.writes.len(), 1);
        assert_eq!(s.writes[0].beats, 4);
    }
}

//! Protocol checkers used by tests and the golden slave model.
//!
//! These encode the AXI invariants the multicast extension must
//! preserve (the properties QuestaSim assertions would check on the
//! RTL):
//!
//! * W bursts arrive at a slave in AW-forward order (fig. 2e is the
//!   scenario where violating this deadlocks).
//! * Every burst delivers exactly `AwLEN+1` beats, terminated by WLAST.
//! * Every forwarded AW eventually gets exactly one B.

use std::collections::VecDeque;

use super::types::Txn;

/// Per-slave write-order checker.
#[derive(Debug, Default)]
pub struct OrderChecker {
    /// AWs seen, in arrival order, with remaining beat count.
    queue: VecDeque<(Txn, u32)>,
    /// Completed bursts (txn, beats).
    pub completed: Vec<(Txn, u32)>,
    pub violations: Vec<String>,
}

impl OrderChecker {
    pub fn new() -> OrderChecker {
        OrderChecker::default()
    }

    pub fn feed_aw(&mut self, txn: Txn, beats: u32) {
        if beats == 0 {
            self.violations.push(format!("txn {txn}: zero-length burst"));
        }
        self.queue.push_back((txn, beats));
    }

    pub fn feed_w(&mut self, txn: Txn, last: bool) {
        match self.queue.front_mut() {
            None => self
                .violations
                .push(format!("txn {txn}: W beat with no outstanding AW")),
            Some((front_txn, left)) => {
                if *front_txn != txn {
                    self.violations.push(format!(
                        "W order violation: beat of txn {txn} while txn {front_txn} in progress"
                    ));
                    return;
                }
                if *left == 0 {
                    self.violations
                        .push(format!("txn {txn}: more W beats than AwLEN"));
                    return;
                }
                *left -= 1;
                let done = *left == 0;
                if done != last {
                    self.violations.push(format!(
                        "txn {txn}: WLAST mismatch (last={last}, beats_left={left})"
                    ));
                }
                if done {
                    let (t, _) = self.queue.pop_front().unwrap();
                    self.completed.push((t, 1));
                }
            }
        }
    }

    pub fn outstanding(&self) -> usize {
        self.queue.len()
    }

    pub fn assert_clean(&self) {
        assert!(
            self.violations.is_empty(),
            "protocol violations: {:#?}",
            self.violations
        );
    }
}

/// End-to-end delivery tracker: which slaves received which txn.
#[derive(Debug, Default)]
pub struct DeliveryTracker {
    pub delivered: Vec<(usize, Txn)>,
}

impl DeliveryTracker {
    pub fn record(&mut self, slave: usize, txn: Txn) {
        self.delivered.push((slave, txn));
    }

    /// The set of slaves a transaction reached.
    pub fn slaves_of(&self, txn: Txn) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .delivered
            .iter()
            .filter(|(_, t)| *t == txn)
            .map(|(s, _)| *s)
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Exactly-once delivery check.
    pub fn assert_exactly_once(&self, txn: Txn, expect: &[usize]) {
        let mut v: Vec<usize> = self
            .delivered
            .iter()
            .filter(|(_, t)| *t == txn)
            .map(|(s, _)| *s)
            .collect();
        v.sort_unstable();
        assert_eq!(v, expect, "txn {txn}: delivery mismatch");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clean_burst_sequence() {
        let mut c = OrderChecker::new();
        c.feed_aw(1, 2);
        c.feed_aw(2, 1);
        c.feed_w(1, false);
        c.feed_w(1, true);
        c.feed_w(2, true);
        c.assert_clean();
        assert_eq!(c.completed.len(), 2);
        assert_eq!(c.outstanding(), 0);
    }

    #[test]
    fn detects_order_violation() {
        let mut c = OrderChecker::new();
        c.feed_aw(1, 1);
        c.feed_aw(2, 1);
        c.feed_w(2, true); // out of order
        assert!(!c.violations.is_empty());
    }

    #[test]
    fn detects_wlast_mismatch() {
        let mut c = OrderChecker::new();
        c.feed_aw(1, 2);
        c.feed_w(1, true); // early WLAST
        assert_eq!(c.violations.len(), 1);
    }

    #[test]
    fn detects_orphan_w() {
        let mut c = OrderChecker::new();
        c.feed_w(9, true);
        assert_eq!(c.violations.len(), 1);
    }

    #[test]
    fn delivery_tracking() {
        let mut d = DeliveryTracker::default();
        d.record(0, 7);
        d.record(3, 7);
        d.record(1, 8);
        assert_eq!(d.slaves_of(7), vec![0, 3]);
        d.assert_exactly_once(7, &[0, 3]);
    }

    #[test]
    #[should_panic]
    fn duplicate_delivery_panics() {
        let mut d = DeliveryTracker::default();
        d.record(0, 7);
        d.record(0, 7);
        d.assert_exactly_once(7, &[0]);
    }
}

//! **In-network reduction** — fabric-side combining of converging
//! N-to-1 write traffic, the dual of the multicast fork
//! (`XbarCfg::fabric_reduce`).
//!
//! The multicast extension forks one write burst into N at the points
//! where destination paths *diverge*; this module merges N write bursts
//! into one at the points where contributor paths *converge*. Member
//! clusters issue ordinary unicast write bursts to the **same**
//! destination address, tagged with a reduction group ([`RedTag`],
//! carried in `aw_user` next to the multicast mask). Every crossbar
//! that is a **join point** of the group's converging tree absorbs the
//! arriving contributor bursts into a per-node *combine table* and
//! forwards **one** combined burst upstream once all expected
//! contributors at that node have arrived; the single B response coming
//! back from upstream is fanned out to every absorbed contributor.
//! Per join with `k` contributors of `b` beats, the fabric moves
//! `(k-1)·b` fewer W beats upstream — reported as
//! `XbarStats::red_beats_saved`, the exact mirror of the fork's
//! `w_fork_extra`.
//!
//! ## Membership oracle
//!
//! How many contributions must a node wait for? The [`ReduceLedger`]
//! answers with the same source of truth the datapath and the
//! reservation protocol already share: [`XbarCfg::decode_aw`]. When a
//! group is opened ([`ReduceLedger::open_group`]), the ledger walks the
//! unicast route of every member's entry crossbar toward the
//! destination — `decode_aw` replayed hop by hop, i.e. the multicast
//! fork oracle of [`super::resv`] run *in reverse* over the converging
//! tree — and records, per traversed node, the **expected inbound
//! burst count**: one per member entering at that node plus one per
//! distinct child crossbar feeding it (a child emits exactly one
//! combined burst, no matter how many members it absorbed). Nodes with
//! a single inbound contribution are pure pass-throughs: the tagged
//! burst rides the normal unicast datapath unchanged, tag preserved
//! for joins further up.
//!
//! ## Semantics split
//!
//! As everywhere in this simulator, the fabric moves *metadata* beats;
//! the numeric combining ([`ReduceOp`] over integer-valued f64 lanes,
//! `SocMem::reduce_f64` reusing the `add_f64` semantics) is applied
//! functionally when each member's DMA job completes. Fabric-side
//! combining is therefore purely a *timing/beat-count* optimisation:
//! with `fabric_reduce` off the tagged bursts all travel to the
//! destination individually and the memory outcome is bit-identical —
//! the property the differential fuzz suite (`tests/fabric_fuzz.rs`)
//! checks on every shape.
//!
//! ## Deadlock argument (DESIGN.md §7)
//!
//! Combining never *holds* anything another transaction can wait on: a
//! contributor burst is absorbed off its master link without taking a
//! mux grant or a W-order slot, and the combined burst enters the exit
//! mux's W-order queue only at issue time, when its data source (the
//! node itself) is unconditionally ready. The waits-for graph gains
//! only edges from a combined burst to *older* W-order entries at its
//! exit port — the same edges any unicast write has — so the PR 4
//! acyclicity proof for the reservation protocol is unchanged.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use super::types::Addr;
use super::xbar::XbarCfg;

/// Element-wise combining operator of a reduction group. `Sum` is the
/// collectives' workhorse (exact over the integer-valued f64 lanes the
/// suite uses); `Max`/`Min` cover the argmax/clamp-style collectives.
/// All three are commutative and associative, so the combine order the
/// fabric happens to realise never changes the result.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceOp {
    Sum,
    Max,
    Min,
}

impl ReduceOp {
    pub fn name(self) -> &'static str {
        match self {
            ReduceOp::Sum => "sum",
            ReduceOp::Max => "max",
            ReduceOp::Min => "min",
        }
    }

    /// Apply to one f64 lane.
    pub fn apply(self, a: f64, b: f64) -> f64 {
        match self {
            ReduceOp::Sum => a + b,
            ReduceOp::Max => a.max(b),
            ReduceOp::Min => a.min(b),
        }
    }
}

/// Reduction-group tag carried on a contributor's AW beat — the model
/// equivalent of a small side-band field in `aw_user` next to the
/// multicast mask. `None` on all non-reduction traffic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RedTag {
    /// Reduction-group id (fabric-unique per open group).
    pub group: u32,
    /// Combining operator (functional layer; the fabric itself only
    /// counts and merges beats).
    pub op: ReduceOp,
}

/// Handle to a crossbar node registered with a [`ReduceLedger`]. Node
/// indices follow registration order, which
/// `TopologyBuilder::build` keeps equal to the crossbar index — the
/// same convention as `ResvNode`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RedNode(pub usize);

/// Shared ledger handle (one per network; `Arc<Mutex<_>>` —
/// uncontended in the sequential engine, and read-only during stepping:
/// groups are opened before a run, so the parallel engine's workers
/// only ever take the lock for lookups).
pub type ReduceHandle = Arc<Mutex<ReduceLedger>>;

/// Routing snapshot of one registered crossbar (mirrors
/// `resv::NodeInfo`: the membership oracle must replay the datapath's
/// decode exactly, so it reuses [`XbarCfg::decode_aw`] on the same
/// map/scope/default data).
#[derive(Debug)]
struct NodeInfo {
    cfg: XbarCfg,
    /// Per slave port: the downstream registered node that port feeds
    /// (`None` = external endpoint).
    down: Vec<Option<RedNode>>,
}

/// What one crossbar must do for one reduction group: wait for
/// `expected` inbound contribution bursts per burst address, then
/// forward one combined burst on `exit_slave`. Only nodes with
/// `expected >= 2` get a plan — everything else passes tagged bursts
/// through the normal unicast datapath.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NodePlan {
    pub expected: u32,
    pub exit_slave: usize,
    pub op: ReduceOp,
}

/// Ledger-level observability counters.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct RedStats {
    /// Groups opened.
    pub groups: u64,
    /// Join points planned across all groups (nodes with expected ≥ 2).
    pub planned_joins: u64,
}

/// The membership oracle shared by every crossbar of one network (see
/// the module docs). Wired by `TopologyBuilder::build` exactly like the
/// reservation ledger: every node registered, every `connect()` edge
/// mirrored.
#[derive(Debug, Default)]
pub struct ReduceLedger {
    nodes: Vec<NodeInfo>,
    /// Per `(node, group)`: the node's combining duty.
    plans: HashMap<(usize, u32), NodePlan>,
    /// Open groups (duplicate ids refused: plans would double-count).
    open: HashMap<u32, ReduceOp>,
    pub stats: RedStats,
}

impl ReduceLedger {
    pub fn new() -> ReduceLedger {
        ReduceLedger::default()
    }

    /// Wrap into the shared handle the crossbars hold.
    pub fn into_handle(self) -> ReduceHandle {
        Arc::new(Mutex::new(self))
    }

    /// Register a crossbar node (its routing snapshot). Ports start
    /// unwired (= external).
    pub fn register(&mut self, cfg: &XbarCfg) -> RedNode {
        let down = vec![None; cfg.n_slaves];
        self.nodes.push(NodeInfo {
            cfg: cfg.clone(),
            down,
        });
        RedNode(self.nodes.len() - 1)
    }

    /// Declare that `from`'s slave port `s_port` feeds crossbar `to`
    /// (mirrors `TopologyBuilder::connect`).
    pub fn wire(&mut self, from: RedNode, s_port: usize, to: RedNode) {
        let slot = &mut self.nodes[from.0].down[s_port];
        assert!(
            slot.is_none(),
            "reduce: node {} slave port {s_port} wired twice",
            from.0
        );
        *slot = Some(to);
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Is `group` open?
    pub fn is_open(&self, group: u32) -> bool {
        self.open.contains_key(&group)
    }

    /// Open a reduction group: `entries` lists the entry crossbar of
    /// every *remote* member (one entry per member — repeated nodes are
    /// how co-located members are expressed), `dst` is the unicast
    /// destination address all members write. Walks every member's
    /// route with the datapath decode and plans a combine at each node
    /// where ≥ 2 contributions converge.
    pub fn open_group(&mut self, group: u32, op: ReduceOp, entries: &[RedNode], dst: Addr) {
        assert!(
            !self.open.contains_key(&group),
            "reduce: group {group} opened twice"
        );
        assert!(
            !entries.is_empty(),
            "reduce: group {group} has no fabric members"
        );
        // per node: members entering here + distinct child nodes
        // feeding it (a child forwards exactly one combined burst)
        let mut direct: HashMap<usize, u32> = HashMap::new();
        let mut preds: HashMap<usize, Vec<usize>> = HashMap::new();
        let mut exit: HashMap<usize, usize> = HashMap::new();
        for e in entries {
            *direct.entry(e.0).or_insert(0) += 1;
            let mut node = e.0;
            let mut hops = 0usize;
            loop {
                let info = &self.nodes[node];
                let (targets, resp) = info
                    .cfg
                    .decode_aw(&super::mcast::AddrSet::unicast(dst), None, None);
                assert!(
                    !resp.is_err() && targets.len() == 1,
                    "reduce: group {group} dst {dst:#x} does not decode to a \
                     single route at node {node} ({})",
                    info.cfg.name
                );
                let s = targets[0].slave;
                exit.insert(node, s);
                match info.down[s] {
                    Some(next) => {
                        let p = preds.entry(next.0).or_default();
                        if !p.contains(&node) {
                            p.push(node);
                        }
                        node = next.0;
                    }
                    None => break,
                }
                hops += 1;
                assert!(
                    hops <= self.nodes.len(),
                    "reduce: group {group} route loops — cyclic fabrics are \
                     not combinable"
                );
            }
        }
        for (&node, &s) in &exit {
            let inbound_children = preds.get(&node).map_or(0, |p| p.len() as u32);
            let expected = direct.get(&node).copied().unwrap_or(0) + inbound_children;
            if expected >= 2 {
                self.plans.insert(
                    (node, group),
                    NodePlan {
                        expected,
                        exit_slave: s,
                        op,
                    },
                );
                self.stats.planned_joins += 1;
            }
        }
        self.open.insert(group, op);
        self.stats.groups += 1;
    }

    /// The node's combining duty for `group` (`None` = pass-through).
    pub fn plan(&self, node: RedNode, group: u32) -> Option<NodePlan> {
        self.plans.get(&(node.0, group)).copied()
    }

    /// Total join points planned for one group (test observability).
    pub fn group_joins(&self, group: u32) -> usize {
        self.plans.keys().filter(|(_, g)| *g == group).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::axi::addr_map::{AddrMap, AddrRule};

    const BASE: u64 = 0x0100_0000;
    const STRIDE: u64 = 0x4_0000;

    fn ep_rule(i: usize, slave: usize) -> AddrRule {
        AddrRule::new(
            BASE + i as u64 * STRIDE,
            BASE + (i as u64 + 1) * STRIDE,
            slave,
            &format!("ep{i}"),
        )
        .with_mcast()
    }

    /// Two leaves of two endpoints each under one root (the same
    /// smallest inter-level fabric the resv tests use).
    fn tree_ledger() -> (ReduceLedger, [RedNode; 3]) {
        let mut led = ReduceLedger::new();
        let mut leaves = Vec::new();
        for g in 0..2usize {
            let rules = vec![ep_rule(2 * g, 0), ep_rule(2 * g + 1, 1)];
            let mut cfg = XbarCfg::new(
                &format!("leaf{g}"),
                3,
                3,
                AddrMap::new(rules, 3).unwrap(),
            );
            cfg.default_slave = Some(2);
            cfg.local_scope = Some((
                BASE + 2 * g as u64 * STRIDE,
                BASE + 2 * (g as u64 + 1) * STRIDE,
            ));
            leaves.push(led.register(&cfg));
        }
        let rules = (0..2)
            .map(|g| {
                AddrRule::new(
                    BASE + 2 * g as u64 * STRIDE,
                    BASE + 2 * (g + 1) as u64 * STRIDE,
                    g as usize,
                    &format!("child{g}"),
                )
                .with_mcast()
            })
            .collect();
        let root = led.register(&XbarCfg::new("root", 2, 2, AddrMap::new(rules, 2).unwrap()));
        led.wire(leaves[0], 2, root);
        led.wire(leaves[1], 2, root);
        led.wire(root, 0, leaves[0]);
        led.wire(root, 1, leaves[1]);
        (led, [leaves[0], leaves[1], root])
    }

    #[test]
    fn op_apply_semantics() {
        assert_eq!(ReduceOp::Sum.apply(2.0, 3.0), 5.0);
        assert_eq!(ReduceOp::Max.apply(2.0, 3.0), 3.0);
        assert_eq!(ReduceOp::Min.apply(2.0, -3.0), -3.0);
    }

    #[test]
    fn cross_level_group_plans_joins_along_the_converging_tree() {
        let (mut led, [l0, l1, root]) = tree_ledger();
        // members on endpoints 1 (leaf 0), 2 and 3 (leaf 1), reducing
        // into endpoint 0 (leaf 0)
        led.open_group(7, ReduceOp::Sum, &[l0, l1, l1], BASE);
        // leaf 1: two direct members -> join, exits up (port 2)
        assert_eq!(
            led.plan(l1, 7),
            Some(NodePlan {
                expected: 2,
                exit_slave: 2,
                op: ReduceOp::Sum
            })
        );
        // root: one combined burst from leaf 1 only -> pass-through
        assert_eq!(led.plan(root, 7), None);
        // leaf 0: one direct member + one burst from the root -> join,
        // exits on endpoint 0's port
        assert_eq!(
            led.plan(l0, 7),
            Some(NodePlan {
                expected: 2,
                exit_slave: 0,
                op: ReduceOp::Sum
            })
        );
        assert_eq!(led.group_joins(7), 2);
    }

    #[test]
    fn single_member_group_is_all_pass_through() {
        let (mut led, [l0, l1, root]) = tree_ledger();
        led.open_group(1, ReduceOp::Sum, &[l1], BASE);
        for n in [l0, l1, root] {
            assert_eq!(led.plan(n, 1), None);
        }
        assert_eq!(led.group_joins(1), 0);
    }

    #[test]
    fn same_leaf_members_combine_once_at_the_shared_leaf() {
        let (mut led, [l0, l1, root]) = tree_ledger();
        // both members and the destination under leaf 1: the route
        // never leaves the leaf
        led.open_group(3, ReduceOp::Max, &[l1, l1], BASE + 2 * STRIDE);
        let p = led.plan(l1, 3).expect("leaf 1 must combine");
        assert_eq!(p.expected, 2);
        assert_eq!(p.exit_slave, 0); // endpoint 2's local port
        assert_eq!(led.plan(root, 3), None);
        assert_eq!(led.plan(l0, 3), None);
    }

    #[test]
    fn groups_are_independent() {
        let (mut led, [l0, l1, _root]) = tree_ledger();
        led.open_group(1, ReduceOp::Sum, &[l0, l1], BASE);
        led.open_group(2, ReduceOp::Sum, &[l1, l1], BASE);
        assert!(led.is_open(1) && led.is_open(2));
        assert_ne!(led.plan(l0, 1), led.plan(l0, 2));
        assert_eq!(led.stats.groups, 2);
    }

    #[test]
    #[should_panic(expected = "opened twice")]
    fn duplicate_group_refused() {
        let (mut led, [l0, _l1, _root]) = tree_ledger();
        led.open_group(5, ReduceOp::Sum, &[l0], BASE);
        led.open_group(5, ReduceOp::Sum, &[l0], BASE);
    }
}

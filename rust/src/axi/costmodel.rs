//! Analytic cost model driving `CollMode::Auto` (DESIGN.md §11).
//!
//! Given a collective pattern, a transfer size, and the wide-fabric
//! shape, the model scores every schedule family the workload layer
//! knows how to emit — software unicast trees/rings, one global
//! multicast, concurrent per-rank chunk multicasts, and in-network
//! fabric reduction — crossed with a small chunk-split ladder, and
//! returns the cheapest plan. Costs are cycle *estimates* built from
//! first principles: injected beats, hop distance, the hottest-link
//! all-to-all cut of the shape, multicast fork cooldown, commit
//! serialization against `max_mcast_outstanding`, and D2D beat
//! serialization for multi-die packages. The absolute numbers are
//! deliberately coarse; what the tuner needs is the *ordering*, and
//! the `tunesweep` experiment measures the residual regret against
//! ground truth per cell (EXPERIMENTS.md).
//!
//! Bias policy: the software baseline is scored optimistically (no
//! contention cut, a 0.9 trim) while the fabric schedules carry every
//! pessimistic term, so `Auto` only leaves `Sw` when a hardware
//! schedule wins by a margin. A small per-reservation tax breaks
//! schedule ties toward the mode with less machinery (e.g. plain
//! `Mcast` over `ConcMcast` for the identical direct reduce-scatter
//! schedule, and `ConcMcast` over `FabricReduce` when no reduction
//! happens).
//!
//! The model deliberately mirrors the workload layer's fallbacks
//! (concurrent broadcast below 4 ranks degenerates to one global
//! multicast; the 2-rank all-gather is a ring exchange) so that the
//! predicted schedule and the emitted schedule never diverge.

/// Extra cost used to break ties between modes whose emitted
/// schedules are identical — the simpler mode must win.
const TIE_EPS: f64 = 1.0;

/// Optimism factor applied to the software baseline (see module docs).
const SW_TRIM: f64 = 0.9;

/// Wide-fabric shape as the cost model sees it: just enough structure
/// to compute hop depth and the hottest-link all-to-all cut. Built
/// from `occamy::WideShape` by the workload layer.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ShapeKind {
    /// Single crossbar over all ranks.
    Flat,
    /// Two-level hierarchy, `per_group` ranks under each group xbar.
    Groups { per_group: usize },
    /// Bottom-up arity tree (product of arities = ranks).
    Tree { arity: Vec<usize> },
    /// Fully-connected mesh of `tiles` peer crossbars.
    Mesh { tiles: usize },
    /// Span-ordered (dateline) ring of `nodes` crossbars — wrap links
    /// idle, so the worst path walks the whole span.
    Ring { nodes: usize },
    /// `cols`×`rows` torus, Y-first inter-row routing, datelined.
    Torus { cols: usize, rows: usize },
    /// Ring of `groups` mesh groups of `tiles` crossbars each, joined
    /// through per-group gateway tiles.
    RingMesh { groups: usize, tiles: usize },
}

impl ShapeKind {
    /// Network diameter in crossbar hops (pipe-fill latency term).
    pub fn depth(&self) -> f64 {
        match self {
            ShapeKind::Flat => 1.0,
            ShapeKind::Groups { .. } => 3.0,
            ShapeKind::Tree { arity } => (2 * arity.len()).saturating_sub(1) as f64,
            ShapeKind::Mesh { .. } => 2.0,
            ShapeKind::Ring { nodes } => nodes.saturating_sub(1) as f64,
            ShapeKind::Torus { cols, rows } => (cols + rows - 1) as f64,
            ShapeKind::RingMesh { groups, .. } => (2 * (groups - 1) + 2) as f64,
        }
    }

    /// Hottest directed-link load of a *unicast* all-to-all over `n`
    /// ranks, counted in pair-paths (flat = the destination ingress,
    /// `n - 1`). Multicast phases don't pay this — forks replicate a
    /// stream instead of sending per-pair, which is the whole point of
    /// the fabric — but the direct reduce-scatter schedule does.
    pub fn a2a_cut(&self, n: usize) -> f64 {
        let nf = n as f64;
        let dest = nf - 1.0;
        match self {
            ShapeKind::Flat => dest,
            ShapeKind::Groups { per_group } => {
                let m = (*per_group).min(n) as f64;
                dest.max(m * (nf - m))
            }
            ShapeKind::Tree { arity } => {
                // cut above a subtree of s ranks carries s*(n-s) pairs
                let mut s = 1usize;
                let mut worst = dest;
                for a in arity {
                    s *= a;
                    if s < n {
                        worst = worst.max((s as f64) * (nf - s as f64));
                    }
                }
                worst
            }
            ShapeKind::Mesh { tiles } => {
                // dedicated tile-pair links each carry m*m pairs
                let m = (n / (*tiles).max(1)) as f64;
                dest.max(m * m)
            }
            ShapeKind::Ring { nodes } => {
                // dateline routing: the middle span link carries every
                // left-half -> right-half pair (no wrap relief)
                let m = (n / (*nodes).max(1)) as f64;
                let mut worst = dest;
                for j in 1..*nodes {
                    worst = worst.max((j as f64 * m) * ((nodes - j) as f64 * m));
                }
                worst
            }
            ShapeKind::Torus { cols, rows } => {
                // Y-first: a column's Y cut carries (j nodes of that
                // column) x (every dest row beyond it); then X within
                // the dest row
                let m = (n / (cols * rows).max(1)) as f64;
                let mut worst = dest;
                for j in 1..*rows {
                    worst = worst.max((j as f64 * m) * ((rows - j) as f64 * *cols as f64 * m));
                }
                for x in 1..*cols {
                    worst = worst.max((x as f64 * *rows as f64 * m) * ((cols - x) as f64 * m));
                }
                worst
            }
            ShapeKind::RingMesh { groups, tiles } => {
                let e = (n / (groups * tiles).max(1)) as f64;
                let grp = (*tiles as f64) * e;
                let mut worst = dest.max(e * e).max(grp * (nf - grp));
                for j in 1..*groups {
                    worst = worst.max((j as f64 * grp) * ((groups - j) as f64 * grp));
                }
                worst
            }
        }
    }
}

/// Collective pattern, mirroring `workloads::CollOp`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CollPattern {
    Broadcast,
    AllGather,
    ReduceScatter,
    AllReduce,
}

impl CollPattern {
    pub const ALL: [CollPattern; 4] = [
        CollPattern::Broadcast,
        CollPattern::AllGather,
        CollPattern::ReduceScatter,
        CollPattern::AllReduce,
    ];
}

/// Schedule family, mirroring the concrete `workloads::CollMode`s.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedMode {
    /// Software unicast baseline (binomial tree / rings).
    Unicast,
    /// One global multicast (plus a root gather where needed).
    Mcast,
    /// Concurrent per-rank chunk multicasts (van de Geijn).
    ConcMcast,
    /// In-network reduction joins plus concurrent multicasts.
    FabricReduce,
}

impl SchedMode {
    pub const ALL: [SchedMode; 4] = [
        SchedMode::Unicast,
        SchedMode::Mcast,
        SchedMode::ConcMcast,
        SchedMode::FabricReduce,
    ];

    /// Same labels as the workload layer's `CollMode::name`.
    pub fn name(&self) -> &'static str {
        match self {
            SchedMode::Unicast => "sw",
            SchedMode::Mcast => "hw-mcast",
            SchedMode::ConcMcast => "hw-concurrent",
            SchedMode::FabricReduce => "hw-reduce",
        }
    }
}

/// D2D package terms for a multi-die SoC.
#[derive(Clone, Copy, Debug)]
pub struct D2dCost {
    pub dies: usize,
    /// Cycles of narrow-lane occupancy per wide beat crossing a die gap.
    pub width_ratio: u32,
    /// Per-crossing latency in cycles.
    pub latency: u32,
}

/// One scored (mode, chunk-split) candidate.
#[derive(Clone, Debug)]
pub struct PlanChoice {
    pub mode: SchedMode,
    /// Sub-chunks each concurrent multicast is split into (1 = the
    /// classic one-chunk-per-rank schedule).
    pub chunks: usize,
    /// Estimated cycles.
    pub cost: f64,
}

/// The tuner's output: the winning candidate plus the full scoreboard
/// (sorted ascending by cost) for reporting.
#[derive(Clone, Debug)]
pub struct Plan {
    pub best: PlanChoice,
    pub scored: Vec<PlanChoice>,
}

/// Analytic fabric model. Build one per (config, shape); score with
/// [`CostModel::plan`].
#[derive(Clone, Debug)]
pub struct CostModel {
    pub n_ranks: usize,
    /// Wide-bus beat width in bytes.
    pub beat_bytes: u64,
    pub shape: ShapeKind,
    /// Concurrent multicast commit slots (`XbarCfg::max_mcast_outstanding`).
    pub max_mcast_outstanding: u32,
    /// Multicast commit handshake latency (`XbarCfg::mcast_commit_lat`).
    pub mcast_commit_lat: u32,
    /// W-fork cooldown cycles (`XbarCfg::mcast_w_cooldown`).
    pub mcast_w_cooldown: u32,
    /// Per-hop pipeline latency estimate (cycles).
    pub hop_lat: f64,
    /// Cost of one mailbox-IRQ synchronization round (cycles).
    pub sync_lat: f64,
    /// Per-reservation-ticket bookkeeping tax (cycles); breaks ties
    /// toward modes with less ledger machinery.
    pub resv_tax: f64,
    pub d2d: Option<D2dCost>,
}

impl CostModel {
    /// Model with the simulator's default timing estimates; override
    /// the public fields for non-default fabrics.
    pub fn new(n_ranks: usize, beat_bytes: u64, shape: ShapeKind) -> CostModel {
        assert!(n_ranks >= 2 && beat_bytes > 0);
        CostModel {
            n_ranks,
            beat_bytes,
            shape,
            max_mcast_outstanding: 4,
            mcast_commit_lat: 8,
            mcast_w_cooldown: 1,
            hop_lat: 4.0,
            sync_lat: 150.0,
            resv_tax: 2.0,
            d2d: None,
        }
    }

    /// Score every (mode, chunk-split) candidate for `pattern` over
    /// `bytes` total payload and return the sorted scoreboard.
    pub fn plan(&self, pattern: CollPattern, bytes: u64) -> Plan {
        let chunk = bytes / self.n_ranks as u64;
        let mut scored = Vec::new();
        for mode in SchedMode::ALL {
            for k in self.chunk_candidates(pattern, mode, chunk) {
                scored.push(PlanChoice {
                    mode,
                    chunks: k,
                    cost: self.cost(pattern, mode, bytes, k),
                });
            }
        }
        scored.sort_by(|a, b| a.cost.total_cmp(&b.cost));
        Plan {
            best: scored[0].clone(),
            scored,
        }
    }

    /// Sub-chunk ladder for schedules that emit concurrent multicasts;
    /// everything else runs unsplit. Splits must keep every sub-chunk
    /// beat-aligned.
    fn chunk_candidates(&self, pattern: CollPattern, mode: SchedMode, chunk: u64) -> Vec<usize> {
        let has_conc_phase = matches!(mode, SchedMode::ConcMcast | SchedMode::FabricReduce)
            && pattern != CollPattern::ReduceScatter
            && !(pattern == CollPattern::Broadcast && self.n_ranks < 4);
        if !has_conc_phase {
            return vec![1];
        }
        [1usize, 2, 4]
            .into_iter()
            .filter(|&k| chunk % (k as u64 * self.beat_bytes) == 0)
            .collect()
    }

    /// Estimated cycles for one (pattern, mode, split) candidate.
    pub fn cost(&self, pattern: CollPattern, mode: SchedMode, bytes: u64, k: usize) -> f64 {
        let n = self.n_ranks as f64;
        let chunk = bytes / self.n_ranks as u64;
        match (pattern, mode) {
            (CollPattern::Broadcast, SchedMode::Unicast) => {
                let rounds = n.log2().ceil();
                let round = self.bb(bytes) * self.wr() + self.base() + self.sync_lat;
                SW_TRIM * rounds * round
            }
            (CollPattern::Broadcast, SchedMode::Mcast) => self.mcast_xfer(bytes) + self.sync_lat,
            (CollPattern::Broadcast, SchedMode::ConcMcast) => {
                if self.n_ranks < 4 {
                    // schedule degenerates to one global multicast
                    self.mcast_xfer(bytes) + self.sync_lat + TIE_EPS
                } else {
                    self.root_fan(chunk) + self.conc_phase(bytes, k)
                }
            }
            (CollPattern::Broadcast, SchedMode::FabricReduce) => {
                // identical schedule to ConcMcast, plus armed ledgers
                self.cost(pattern, SchedMode::ConcMcast, bytes, k) + 2.0 * TIE_EPS
            }
            (CollPattern::AllGather, SchedMode::Unicast) => {
                let round = self.bb(chunk) * self.wr() + self.neighbor_lat() + self.sync_lat;
                SW_TRIM * (n - 1.0) * round
            }
            (CollPattern::AllGather, SchedMode::Mcast) => {
                if self.n_ranks == 2 {
                    self.bb(chunk) * self.wr() + self.neighbor_lat() + self.sync_lat
                } else {
                    self.root_fan(chunk) + self.mcast_xfer(bytes) + self.sync_lat
                }
            }
            (CollPattern::AllGather, SchedMode::ConcMcast) => self.conc_phase(bytes, k),
            (CollPattern::AllGather, SchedMode::FabricReduce) => {
                self.conc_phase(bytes, k) + 2.0 * TIE_EPS
            }
            (CollPattern::ReduceScatter, SchedMode::Unicast) => {
                // each ring round moves a slice and combines it locally
                let xfer = self.bb(chunk) * (self.wr() + 1.0);
                SW_TRIM * (n - 1.0) * (xfer + self.neighbor_lat() + self.sync_lat)
            }
            (CollPattern::ReduceScatter, SchedMode::Mcast) => self.direct_rs(chunk),
            (CollPattern::ReduceScatter, SchedMode::ConcMcast) => self.direct_rs(chunk) + TIE_EPS,
            (CollPattern::ReduceScatter, SchedMode::FabricReduce) => self.fabric_rs(chunk),
            (CollPattern::AllReduce, SchedMode::Unicast) => {
                self.cost(CollPattern::ReduceScatter, SchedMode::Unicast, bytes, 1)
                    + self.cost(CollPattern::AllGather, SchedMode::Unicast, bytes, 1)
            }
            (CollPattern::AllReduce, SchedMode::Mcast) => {
                // hierarchical leaders: full vectors up, combine,
                // leader exchange, one multicast down
                2.0 * self.bb(bytes) * self.wr()
                    + self.bb(bytes)
                    + self.mcast_xfer(bytes)
                    + 3.0 * self.sync_lat
            }
            (CollPattern::AllReduce, SchedMode::ConcMcast) => {
                self.direct_rs(chunk) + self.conc_phase(bytes, k)
            }
            (CollPattern::AllReduce, SchedMode::FabricReduce) => {
                self.fabric_rs(chunk) + self.conc_phase(bytes, k)
            }
        }
    }

    // ---- primitive terms -------------------------------------------------

    /// Beats for `bytes` on the wide bus.
    fn bb(&self, bytes: u64) -> f64 {
        bytes.div_ceil(self.beat_bytes) as f64
    }

    /// D2D serialization factor on data beats (1 on a single die).
    fn wr(&self) -> f64 {
        self.d2d.map_or(1.0, |d| d.width_ratio as f64)
    }

    /// Cycles each forked beat occupies the fork engine.
    fn cool(&self) -> f64 {
        (1 + self.mcast_w_cooldown) as f64
    }

    /// Pipe-fill latency across the diameter (plus D2D crossings).
    fn base(&self) -> f64 {
        let dies = self.d2d.map_or(1, |d| d.dies);
        let lat = self.d2d.map_or(0, |d| d.latency as usize);
        self.shape.depth() * self.hop_lat + ((dies - 1) * lat) as f64
    }

    /// Latency of a nearest-neighbor hop (software ring rounds).
    fn neighbor_lat(&self) -> f64 {
        2.0 * self.hop_lat + self.d2d.map_or(0.0, |d| d.latency as f64)
    }

    /// Commit-handshake serialization for `mcasts` concurrent
    /// multicasts against the outstanding-commit cap.
    fn commit(&self, mcasts: usize) -> f64 {
        ((mcasts as u64).div_ceil(self.max_mcast_outstanding.max(1) as u64)
            * self.mcast_commit_lat as u64) as f64
    }

    /// One global multicast of `bytes`: commit handshake, then a beat
    /// stream bound by the fork cooldown (or D2D serialization,
    /// whichever is slower), plus pipe fill.
    fn mcast_xfer(&self, bytes: u64) -> f64 {
        self.commit(1) + self.bb(bytes) * self.cool().max(self.wr()) + self.base()
    }

    /// Root-centred fan (scatter from, or gather to, rank 0) of n-1
    /// slices: bound by the root link, or the root die's D2D links.
    fn root_fan(&self, chunk: u64) -> f64 {
        let n = self.n_ranks as f64;
        let moved = self.bb(chunk) * (n - 1.0);
        let d2d = self.d2d.map_or(0.0, |d| {
            let off_die = n - (self.n_ranks / d.dies) as f64;
            off_die * self.bb(chunk) * d.width_ratio as f64
        });
        moved.max(d2d) + self.base() + self.sync_lat
    }

    /// The concurrent-multicast phase: every rank multicasts its slice
    /// (split into `k` sub-chunks) to all ranks. Each link carries at
    /// most one copy of every stream, so the bound is total beats at
    /// the fork/D2D rate — not the unicast all-to-all cut. Splitting
    /// overlaps fork pipe-fill with injection but costs extra commits.
    fn conc_phase(&self, bytes: u64, k: usize) -> f64 {
        let commits = self.commit(self.n_ranks * k);
        let depth_fill = (self.shape.depth() - 1.0).max(0.0) * self.hop_lat;
        let overlap_gain = (1.0 - 1.0 / k as f64) * depth_fill * 0.5;
        let stream = self.bb(bytes) * self.cool().max(self.wr());
        commits + stream + self.base() + self.sync_lat - overlap_gain
    }

    /// Direct reduce-scatter: unicast all-to-all of slices (pays the
    /// shape's hottest-link cut) plus a software combine of n-1
    /// incoming slices at every destination.
    fn direct_rs(&self, chunk: u64) -> f64 {
        let n = self.n_ranks as f64;
        let cut = self.shape.a2a_cut(self.n_ranks).max(self.d2d_a2a_cut());
        cut * self.bb(chunk) + (n - 1.0) * self.bb(chunk) + self.base() + self.sync_lat
    }

    /// In-network reduce-scatter: sources still inject n-1 slices each,
    /// but joins collapse the stream en route, so no software combine
    /// and no destination pile-up — just the reservation-ledger tax.
    fn fabric_rs(&self, chunk: u64) -> f64 {
        let n = self.n_ranks as f64;
        let inject = (n - 1.0) * self.bb(chunk) * self.wr();
        inject + self.base() + self.sync_lat + n * self.resv_tax
    }

    /// Unicast all-to-all pair-paths over the hottest D2D link,
    /// scaled by the serialization ratio.
    fn d2d_a2a_cut(&self) -> f64 {
        self.d2d.map_or(0.0, |d| {
            let q = (self.n_ranks / d.dies) as f64;
            q * (self.n_ranks as f64 - q) * d.width_ratio as f64
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shapes8() -> Vec<ShapeKind> {
        vec![
            ShapeKind::Flat,
            ShapeKind::Groups { per_group: 4 },
            ShapeKind::Tree { arity: vec![2, 2, 2] },
            ShapeKind::Mesh { tiles: 2 },
            ShapeKind::Ring { nodes: 2 },
        ]
    }

    fn shapes16() -> Vec<ShapeKind> {
        vec![
            ShapeKind::Flat,
            ShapeKind::Groups { per_group: 4 },
            ShapeKind::Mesh { tiles: 4 },
            ShapeKind::Ring { nodes: 4 },
            ShapeKind::Torus { cols: 2, rows: 2 },
            ShapeKind::RingMesh { groups: 2, tiles: 2 },
        ]
    }

    #[test]
    fn single_mcast_wins_broadcast_on_every_shape() {
        for shape in shapes8() {
            let m = CostModel::new(8, 64, shape.clone());
            let plan = m.plan(CollPattern::Broadcast, 4096);
            assert_eq!(plan.best.mode, SchedMode::Mcast, "{shape:?}: {:?}", plan.scored);
        }
        for shape in shapes16() {
            let m = CostModel::new(16, 64, shape.clone());
            let plan = m.plan(CollPattern::Broadcast, 8192);
            assert_eq!(plan.best.mode, SchedMode::Mcast, "{shape:?}");
        }
    }

    #[test]
    fn concurrent_mcasts_win_all_gather_on_every_shape() {
        for shape in shapes8() {
            let m = CostModel::new(8, 64, shape.clone());
            let plan = m.plan(CollPattern::AllGather, 4096);
            assert_eq!(plan.best.mode, SchedMode::ConcMcast, "{shape:?}");
        }
        for shape in shapes16() {
            let m = CostModel::new(16, 64, shape.clone());
            let plan = m.plan(CollPattern::AllGather, 8192);
            assert_eq!(plan.best.mode, SchedMode::ConcMcast, "{shape:?}");
        }
    }

    #[test]
    fn fabric_reduce_wins_reduce_scatter_and_all_reduce() {
        for shape in shapes16() {
            let m = CostModel::new(16, 64, shape.clone());
            for pat in [CollPattern::ReduceScatter, CollPattern::AllReduce] {
                let plan = m.plan(pat, 8192);
                assert_eq!(plan.best.mode, SchedMode::FabricReduce, "{shape:?} {pat:?}");
            }
        }
    }

    #[test]
    fn identical_rs_schedules_tie_toward_plain_mcast() {
        let m = CostModel::new(8, 64, ShapeKind::Flat);
        let hw = m.cost(CollPattern::ReduceScatter, SchedMode::Mcast, 4096, 1);
        let conc = m.cost(CollPattern::ReduceScatter, SchedMode::ConcMcast, 4096, 1);
        assert!(hw < conc, "tie must break toward the simpler mode");
    }

    #[test]
    fn chunk_ladder_is_scored_but_single_chunk_wins_by_default() {
        let m = CostModel::new(8, 64, ShapeKind::Ring { nodes: 2 });
        let plan = m.plan(CollPattern::AllGather, 32 * 1024);
        let deep = |c: &PlanChoice| c.mode == SchedMode::ConcMcast && c.chunks == 4;
        assert!(plan.scored.iter().any(deep));
        assert_eq!(plan.best.chunks, 1, "{:?}", plan.best);
        // chunk candidates stay beat-aligned: 8 ranks x 64B chunk has
        // only the k=1 split
        let tiny = CostModel::new(8, 64, ShapeKind::Flat).plan(CollPattern::AllGather, 512);
        assert!(tiny.scored.iter().all(|c| c.chunks == 1));
    }

    #[test]
    fn ring_cut_dominates_flat_and_scales_with_span() {
        let flat = ShapeKind::Flat.a2a_cut(16);
        let ring = ShapeKind::Ring { nodes: 4 }.a2a_cut(16);
        assert!(ring > flat, "ring middle cut {ring} vs flat {flat}");
        assert_eq!(ShapeKind::Ring { nodes: 4 }.a2a_cut(16), 64.0);
        assert_eq!(ShapeKind::Torus { cols: 2, rows: 2 }.a2a_cut(16), 32.0);
        assert_eq!(ShapeKind::RingMesh { groups: 2, tiles: 2 }.a2a_cut(16), 64.0);
        for s in shapes16() {
            assert!(s.a2a_cut(16) >= 15.0, "{s:?} cut below dest ingress");
        }
    }

    #[test]
    fn scoreboard_is_sorted_and_covers_all_modes() {
        let m = CostModel::new(16, 64, ShapeKind::Torus { cols: 2, rows: 2 });
        for pat in CollPattern::ALL {
            let plan = m.plan(pat, 16 * 1024);
            assert!(plan.scored.windows(2).all(|w| w[0].cost <= w[1].cost));
            for mode in SchedMode::ALL {
                assert!(plan.scored.iter().any(|c| c.mode == mode), "{pat:?} {mode:?}");
            }
            for c in &plan.scored {
                assert!(c.cost.is_finite() && c.cost > 0.0, "{pat:?} {c:?}");
            }
        }
    }

    #[test]
    fn two_rank_fallbacks_mirror_the_emitted_schedules() {
        let m = CostModel::new(2, 64, ShapeKind::Flat);
        let mc = m.cost(CollPattern::Broadcast, SchedMode::Mcast, 1024, 1);
        let conc = m.cost(CollPattern::Broadcast, SchedMode::ConcMcast, 1024, 1);
        assert!((conc - mc - TIE_EPS).abs() < 1e-9, "n<4 falls back to one mcast");
        // the 2-rank all-gather degenerates to a neighbor exchange on
        // both paths; the optimism trim keeps Auto on the software side
        let ag = m.plan(CollPattern::AllGather, 1024);
        assert_eq!(ag.best.mode, SchedMode::Unicast);
    }

    #[test]
    fn d2d_serialization_raises_every_fabric_schedule() {
        let on_die = CostModel::new(8, 64, ShapeKind::Flat);
        let mut pkg = CostModel::new(8, 64, ShapeKind::Flat);
        pkg.d2d = Some(D2dCost {
            dies: 2,
            width_ratio: 4,
            latency: 8,
        });
        for pat in CollPattern::ALL {
            for mode in [SchedMode::Mcast, SchedMode::ConcMcast, SchedMode::FabricReduce] {
                assert!(
                    pkg.cost(pat, mode, 4096, 1) > on_die.cost(pat, mode, 4096, 1),
                    "{pat:?} {mode:?}"
                );
            }
        }
    }
}

//! The N×M multicast-capable AXI crossbar (paper fig. 2a).
//!
//! Composition: one [`Demux`] per master port, one [`Mux`] per slave
//! port, wired through external [`AxiLink`]s held in a shared
//! [`LinkPool`] (the SoC or topology owns the pool; the xbar stores
//! typed [`LinkId`] handles). Each call to [`Xbar::step`] advances one
//! clock cycle through the phases:
//!
//! 1. **B join/drain** — collect B beats from slaves, fold into the
//!    per-demux joins, release merged responses to masters.
//! 2. **R/AR routing** — reads are unicast: round-robin AR arbitration
//!    per slave, R beats routed back by transaction tag.
//! 3. **AW accept** — pop+decode master AWs subject to the multicast
//!    ordering stalls (fig. 2d orange logic).
//! 4. **Grant** — per-slave priority-encoder (lzc) arbitration of
//!    multicast requesters; consistent cross-mux priority.
//! 5. **Commit** — a master holding grants on *all* addressed slaves
//!    (and space on all their AW channels) forks its AW atomically;
//!    with `commit_protocol = false` the fork happens per-slave as
//!    grants arrive, reproducing the fig. 2e deadlock.
//! 6. **Unicast AW forward** — round-robin, stalled while the mcast
//!    datapath holds a grant (multicast is prioritised).
//! 7. **W transport** — front-of-order W bursts move; a multicast W
//!    beat requires *all* destination channels ready (all-ready fork).
//!
//! ## Hierarchical multicast routing
//!
//! A request whose address set extends beyond this crossbar's local
//! rules is forwarded on the `default_slave` port carrying the original
//! set plus an **exclude scope** — the aligned region already served
//! locally. The next hop prunes rules inside the scope. This is the
//! model equivalent of the RTL's decomposition of the "rest of world"
//! route into log₂-many aligned mask-form rules; deliveries and beat
//! counts are identical (see DESIGN.md §2).

use std::collections::HashMap;

use super::addr_map::AddrMap;
use super::demux::{Demux, PendingAw, Stall, TargetAw};
use super::mcast::AddrSet;
use super::mux::Mux;
use super::types::{AwBeat, AxiLink, LinkId, LinkPool, RBeat, Resp, Txn, WBeat};
use crate::sim::sched::Component;
use crate::sim::Cycle;

/// Crossbar configuration.
#[derive(Debug)]
pub struct XbarCfg {
    pub name: String,
    pub n_masters: usize,
    pub n_slaves: usize,
    pub map: AddrMap,
    /// Port receiving traffic not matching any rule (hierarchy "up").
    pub default_slave: Option<usize>,
    /// Aligned region covered by this xbar's local rules; attached as
    /// the exclude scope on default-routed multicasts.
    pub local_scope: Option<(u64, u64)>,
    /// Paper's extension on/off (off = baseline XBAR; multicast AWs are
    /// rejected with DECERR).
    pub mcast_enabled: bool,
    /// Deadlock-avoidance commit protocol (fig. 2e). Disable only to
    /// demonstrate the deadlock.
    pub commit_protocol: bool,
    pub max_mcast_outstanding: u32,
    pub max_outstanding: u32,
    /// Minimum cycles a multicast AW spends in the grant/commit
    /// handshake before forking (the RTL's grant-settle + "releasing
    /// the muxes in the following cycle" sequence across all addressed
    /// muxes). Calibrated against fig. 3b's round-trip amortisation
    /// behaviour; unicast AWs are unaffected.
    pub mcast_commit_lat: u32,
    /// Idle cycles inserted after every multicast W fork beat.
    ///
    /// The RTL's `stream_fork` fans a W beat out through registered
    /// spill slices whose ready is one cycle stale; with more than one
    /// destination the all-ready condition is met every other cycle, so
    /// the sustained fork rate is ~½ beat/cycle. `1` reproduces that
    /// measured behaviour (calibrated against fig. 3b, see
    /// EXPERIMENTS.md); `0` is an idealised single-cycle fork
    /// (ablation).
    pub mcast_w_cooldown: u32,
}

impl XbarCfg {
    pub fn new(name: &str, n_masters: usize, n_slaves: usize, map: AddrMap) -> XbarCfg {
        XbarCfg {
            name: name.to_string(),
            n_masters,
            n_slaves,
            map,
            default_slave: None,
            local_scope: None,
            mcast_enabled: true,
            commit_protocol: true,
            max_mcast_outstanding: 4,
            max_outstanding: 16,
            mcast_commit_lat: 8,
            mcast_w_cooldown: 1,
        }
    }
}

/// Aggregate statistics (read by benches and EXPERIMENTS.md harnesses).
#[derive(Debug, Default, Clone)]
pub struct XbarStats {
    pub aw_unicast: u64,
    pub aw_mcast: u64,
    pub aw_forks: u64,
    pub w_beats_in: u64,
    pub w_beats_out: u64,
    pub w_fork_stalls: u64,
    pub b_joined: u64,
    pub commit_waits: u64,
    pub ar_forwarded: u64,
    pub r_beats: u64,
    pub decerr: u64,
    pub stall_id_conflict: u64,
    pub stall_mcast_order: u64,
    /// Extra W beats produced by multicast forking: for every W beat
    /// entering, `fanout - 1` additional beats leave. Invariant checked
    /// by the integration suites: `w_beats_out == w_beats_in + w_fork_extra`.
    pub w_fork_extra: u64,
}

impl XbarStats {
    /// Accumulate another crossbar's counters (network/topology sums).
    pub fn add(&mut self, o: &XbarStats) {
        self.aw_unicast += o.aw_unicast;
        self.aw_mcast += o.aw_mcast;
        self.aw_forks += o.aw_forks;
        self.w_beats_in += o.w_beats_in;
        self.w_beats_out += o.w_beats_out;
        self.w_fork_stalls += o.w_fork_stalls;
        self.b_joined += o.b_joined;
        self.commit_waits += o.commit_waits;
        self.ar_forwarded += o.ar_forwarded;
        self.r_beats += o.r_beats;
        self.decerr += o.decerr;
        self.stall_id_conflict += o.stall_id_conflict;
        self.stall_mcast_order += o.stall_mcast_order;
        self.w_fork_extra += o.w_fork_extra;
    }
}

/// In-flight pending AW extended with per-target forward flags (used in
/// the no-commit mode to reproduce the deadlock).
#[derive(Debug)]
struct PendingEntry {
    pend: PendingAw,
    forwarded: Vec<bool>,
    /// Cycles spent pending (commit handshake modelling).
    age: u32,
}

/// The crossbar.
pub struct Xbar {
    pub cfg: XbarCfg,
    pub demux: Vec<Demux>,
    pub mux: Vec<Mux>,
    /// Master-side links (masters push AW/W/AR). Read-only after
    /// construction: `Component::ports()` serves a cached copy, so
    /// rewiring a built xbar would desync the scheduler's wake hints.
    pub m_links: Vec<LinkId>,
    /// Slave-side links (xbar pushes AW/W/AR). Read-only after
    /// construction (see `m_links`).
    pub s_links: Vec<LinkId>,
    /// All external ports (`m_links` then `s_links`), cached for the
    /// scheduler's wake/dirty bookkeeping.
    ports: Vec<LinkId>,
    pending: Vec<Option<PendingEntry>>,
    /// Per-master cooldown countdown for multicast W forks.
    w_cooldown: Vec<u32>,
    /// Reused per-cycle scratch (per-master decoded target), avoiding
    /// hot-loop allocation.
    scratch_want: Vec<Option<usize>>,
    /// Cached busy state from the last stepped cycle (idle-skip).
    pub maybe_busy: bool,
    wr_owner: HashMap<Txn, usize>,
    rd_owner: HashMap<Txn, usize>,
    /// DECERR read responses being generated: (master, id, txn, beats).
    decerr_r: Vec<(usize, u16, Txn, u32)>,
    pub stats: XbarStats,
}

impl Xbar {
    /// Build a crossbar whose ports use the given pool links.
    pub fn new(cfg: XbarCfg, m_links: Vec<LinkId>, s_links: Vec<LinkId>) -> Xbar {
        assert_eq!(m_links.len(), cfg.n_masters);
        assert_eq!(s_links.len(), cfg.n_slaves);
        let demux = (0..cfg.n_masters)
            .map(|i| Demux::new(i, cfg.max_mcast_outstanding, cfg.max_outstanding))
            .collect();
        let mux = (0..cfg.n_slaves).map(Mux::new).collect();
        let pending = (0..cfg.n_masters).map(|_| None).collect();
        let w_cooldown = vec![0; cfg.n_masters];
        let scratch_want = vec![None; cfg.n_masters];
        let ports: Vec<LinkId> = m_links.iter().chain(s_links.iter()).copied().collect();
        Xbar {
            cfg,
            demux,
            mux,
            m_links,
            s_links,
            ports,
            pending,
            w_cooldown,
            scratch_want,
            maybe_busy: false,
            wr_owner: HashMap::new(),
            rd_owner: HashMap::new(),
            decerr_r: Vec::new(),
            stats: XbarStats::default(),
        }
    }

    /// Convenience for tests: allocate a fresh pool with one link per
    /// port (masters first, then slaves).
    pub fn with_pool(cfg: XbarCfg, depth: usize) -> (Xbar, LinkPool) {
        let nm = cfg.n_masters;
        let ns = cfg.n_slaves;
        let mut pool = LinkPool::new();
        let m_links: Vec<LinkId> = (0..nm).map(|_| pool.alloc(AxiLink::new(depth))).collect();
        let s_links: Vec<LinkId> = (0..ns).map(|_| pool.alloc(AxiLink::new(depth))).collect();
        (Xbar::new(cfg, m_links, s_links), pool)
    }

    /// Decode an AW's destination set into fork targets, honouring the
    /// exclude scope and the default route.
    fn decode_aw(&self, dest: &AddrSet, exclude: Option<(u64, u64)>) -> (Vec<TargetAw>, Resp) {
        // fast path: plain unicast
        if dest.is_singleton() {
            if let Some(s) = self.cfg.map.decode_unicast(dest.addr) {
                return (
                    vec![TargetAw {
                        slave: s,
                        dest: *dest,
                        exclude: None,
                    }],
                    Resp::Okay,
                );
            }
            if let Some(up) = self.cfg.default_slave {
                return (
                    vec![TargetAw {
                        slave: up,
                        dest: *dest,
                        exclude: None,
                    }],
                    Resp::Okay,
                );
            }
            return (Vec::new(), Resp::DecErr);
        }

        if !self.cfg.mcast_enabled {
            // baseline XBAR: masked requests are illegal
            return (Vec::new(), Resp::DecErr);
        }

        let d = self.cfg.map.decode(dest);
        let mut targets = Vec::with_capacity(d.targets.len() + 1);
        let mut excl_in_rules = 0u64;
        for (s, sub) in &d.targets {
            if let Some((es, ee)) = exclude {
                if sub.base() >= es && sub.top() < ee {
                    // already served upstream of this hop
                    excl_in_rules += sub.count();
                    continue;
                }
            }
            targets.push(TargetAw {
                slave: *s,
                dest: *sub,
                exclude: None,
            });
        }
        // addresses excluded but not matched by local rules
        let n_excl = match exclude {
            Some((es, ee)) => AddrSet::from_interval(es, ee)
                .ok()
                .and_then(|e| dest.intersect(&e))
                .map(|i| i.count())
                .unwrap_or(0),
            None => 0,
        };
        let excl_unmatched = n_excl.saturating_sub(excl_in_rules);
        let remainder = d.uncovered.saturating_sub(excl_unmatched);
        let mut resp0 = Resp::Okay;
        if remainder > 0 {
            match self.cfg.default_slave {
                Some(up) => {
                    // Forward the original set up, extending the scope.
                    // Nested scopes merge to the outer region: in a
                    // well-formed hierarchy the incoming exclude (served
                    // at a lower level) is contained in this crossbar's
                    // local scope, and the union of "already served"
                    // addresses is exactly the outer aligned region.
                    // Disjoint scopes (a malformed topology) stay
                    // unrepresentable.
                    let scope = match (exclude, self.cfg.local_scope) {
                        (None, s) => s,
                        (e @ Some(_), None) => e,
                        (Some((es, ee)), Some((ls, le))) => {
                            if ls <= es && ee <= le {
                                Some((ls, le))
                            } else if es <= ls && le <= ee {
                                Some((es, ee))
                            } else {
                                panic!(
                                    "xbar {}: disjoint exclude scopes \
                                     [{es:#x},{ee:#x}) vs local [{ls:#x},{le:#x}) \
                                     are not representable (scopes must nest)",
                                    self.cfg.name
                                )
                            }
                        }
                    };
                    targets.push(TargetAw {
                        slave: up,
                        dest: *dest,
                        exclude: scope,
                    });
                }
                None => resp0 = Resp::DecErr,
            }
        }
        targets.sort_by_key(|t| t.slave);
        (targets, resp0)
    }

    /// One clock cycle. `pool` is the shared link pool.
    pub fn step(&mut self, pool: &mut LinkPool) {
        self.phase_b(pool);
        self.phase_r(pool);
        self.phase_ar(pool);
        self.phase_aw_accept(pool);
        self.phase_grant();
        self.phase_commit(pool);
        self.phase_unicast_aw(pool);
        self.phase_w(pool);
        // cached for the scheduler's idle-skip (§Perf): an idle xbar is
        // only re-woken by visible beats on its ports (activity hints)
        self.maybe_busy = self.busy();
    }

    /// Phase 1 — B collection + joined-B drain.
    fn phase_b(&mut self, pool: &mut LinkPool) {
        for s in 0..self.cfg.n_slaves {
            if let Some(b) = pool[self.s_links[s]].b.pop() {
                let m = *self
                    .wr_owner
                    .get(&b.txn)
                    .unwrap_or_else(|| panic!("{}: B for unknown txn {}", self.cfg.name, b.txn));
                if let Some(joined) = self.demux[m].join_b(b.txn, b.resp, b.id) {
                    self.wr_owner.remove(&b.txn);
                    self.stats.b_joined += 1;
                    self.demux[m].b_out.push_back(joined);
                }
            }
        }
        for m in 0..self.cfg.n_masters {
            if let Some(&b) = self.demux[m].b_out.front() {
                if pool[self.m_links[m]].b.can_push() {
                    self.demux[m].b_out.pop_front();
                    pool[self.m_links[m]].b.push(b);
                }
            }
        }
    }

    /// Phase 2 — R routing (slave→master) + DECERR R generation.
    fn phase_r(&mut self, pool: &mut LinkPool) {
        for s in 0..self.cfg.n_slaves {
            let link = self.s_links[s];
            let Some(r) = pool[link].r.front().copied() else {
                continue;
            };
            let m = *self
                .rd_owner
                .get(&r.txn)
                .unwrap_or_else(|| panic!("{}: R for unknown txn {}", self.cfg.name, r.txn));
            if pool[self.m_links[m]].r.can_push() {
                pool[link].r.pop();
                if r.last {
                    self.rd_owner.remove(&r.txn);
                }
                pool[self.m_links[m]].r.push(r);
                self.stats.r_beats += 1;
            }
        }
        // synthesize DECERR read data for unroutable ARs
        let mut i = 0;
        while i < self.decerr_r.len() {
            let (m, id, txn, ref mut beats) = self.decerr_r[i];
            if pool[self.m_links[m]].r.can_push() {
                *beats -= 1;
                let last = *beats == 0;
                pool[self.m_links[m]].r.push(RBeat {
                    id,
                    last,
                    resp: Resp::DecErr,
                    txn,
                });
                if last {
                    self.decerr_r.remove(i);
                    continue;
                }
            }
            i += 1;
        }
    }

    /// Phase 3 — AR arbitration and forwarding (reads are unicast).
    fn phase_ar(&mut self, pool: &mut LinkPool) {
        // decode every master's front AR once (into reusable scratch)
        let mut any = false;
        for m in 0..self.cfg.n_masters {
            let dec = pool[self.m_links[m]].ar.front().map(|ar| {
                self.cfg
                    .map
                    .decode_unicast(ar.addr)
                    .or(self.cfg.default_slave)
            });
            self.scratch_want[m] = match dec {
                Some(Some(s)) => {
                    any = true;
                    Some(s)
                }
                Some(None) => {
                    // unroutable read → DECERR R burst
                    let ar = pool[self.m_links[m]].ar.pop().unwrap();
                    self.stats.decerr += 1;
                    self.decerr_r.push((m, ar.id, ar.txn, ar.beats));
                    None
                }
                None => None,
            };
        }
        if !any {
            return;
        }
        for s in 0..self.cfg.n_slaves {
            if !pool[self.s_links[s]].ar.can_push() {
                continue;
            }
            let want = &self.scratch_want;
            if let Some(m) = self.mux[s].rr_pick_ar_scan(self.cfg.n_masters, |m| want[m] == Some(s))
            {
                let mut ar = pool[self.m_links[m]].ar.pop().unwrap();
                ar.src = m;
                self.rd_owner.insert(ar.txn, m);
                pool[self.s_links[s]].ar.push(ar);
                self.stats.ar_forwarded += 1;
                self.scratch_want[m] = None;
            }
        }
    }

    /// Phase 4 — AW acceptance + decode (fig. 2d ordering stalls).
    fn phase_aw_accept(&mut self, pool: &mut LinkPool) {
        for m in 0..self.cfg.n_masters {
            if self.pending[m].is_some() {
                continue;
            }
            let Some(front) = pool[self.m_links[m]].aw.front() else {
                continue;
            };
            let (targets, resp0) = self.decode_aw(&front.dest, front.exclude);
            let slaves: Vec<usize> = targets.iter().map(|t| t.slave).collect();
            let is_mcast = front.is_mcast && slaves.len() != 1;
            match self.demux[m].admit(is_mcast, front.id, &slaves) {
                Stall::None => {}
                Stall::IdConflict => {
                    self.stats.stall_id_conflict += 1;
                    continue;
                }
                Stall::McastAfterUnicast
                | Stall::UnicastAfterMcast
                | Stall::McastSetMismatch
                | Stall::McastLimit => {
                    self.stats.stall_mcast_order += 1;
                    continue;
                }
                _ => continue,
            }
            let mut beat = pool[self.m_links[m]].aw.pop().unwrap();
            beat.src = m;
            beat.is_mcast = is_mcast;
            if is_mcast {
                self.stats.aw_mcast += 1;
            } else {
                self.stats.aw_unicast += 1;
            }
            if resp0 == Resp::DecErr && targets.is_empty() {
                self.stats.decerr += 1;
            }
            let forwarded = vec![false; targets.len()];
            self.pending[m] = Some(PendingEntry {
                pend: PendingAw {
                    beat,
                    targets,
                    resp0,
                },
                forwarded,
                age: 0,
            });
        }
    }

    /// Does master `m` have an unforwarded multicast leg for slave `s`?
    #[inline]
    fn wants_mcast(&self, m: usize, s: usize) -> bool {
        self.pending[m]
            .as_ref()
            .map(|p| {
                p.pend.beat.is_mcast
                    && p.pend
                        .targets
                        .iter()
                        .zip(&p.forwarded)
                        .any(|(t, f)| t.slave == s && !f)
            })
            .unwrap_or(false)
    }

    /// Phase 5 — per-slave multicast grant (priority encoder).
    fn phase_grant(&mut self) {
        // hot path: no pending multicast anywhere → clear grants cheaply
        if !self
            .pending
            .iter()
            .any(|p| p.as_ref().map(|p| p.pend.beat.is_mcast).unwrap_or(false))
        {
            for s in 0..self.cfg.n_slaves {
                self.mux[s].grant = None;
            }
            return;
        }
        if self.cfg.commit_protocol && self.cfg.n_slaves <= 64 {
            // bitmask fast path: one unforwarded-target mask per master,
            // then per-slave priority encode over single bits (O(N²)
            // bit tests instead of O(N²·targets) scans)
            let mut masks = [0u64; 64];
            let nm = self.cfg.n_masters.min(64);
            for (m, mask) in masks.iter_mut().enumerate().take(nm) {
                if let Some(p) = &self.pending[m] {
                    if p.pend.beat.is_mcast {
                        for (t, f) in p.pend.targets.iter().zip(&p.forwarded) {
                            if !f {
                                *mask |= 1u64 << t.slave;
                            }
                        }
                    }
                }
            }
            for s in 0..self.cfg.n_slaves {
                let grant = (0..nm).find(|&m| masks[m] >> s & 1 == 1);
                self.mux[s].grant = grant;
                if grant.is_some() {
                    self.mux[s].grant_wait_cycles += 1;
                }
            }
            return;
        }
        for s in 0..self.cfg.n_slaves {
            if self.cfg.commit_protocol {
                // lzc: lowest-index requesting master, allocation-free
                let grant = (0..self.cfg.n_masters).find(|&m| self.wants_mcast(m, s));
                self.mux[s].grant = grant;
                if grant.is_some() {
                    self.mux[s].grant_wait_cycles += 1;
                }
            } else {
                let requesters: Vec<usize> = (0..self.cfg.n_masters)
                    .filter(|&m| self.wants_mcast(m, s))
                    .collect();
                self.mux[s].arbitrate_mcast_rr(&requesters, self.cfg.n_masters);
            }
        }
    }

    /// Fork one target of a pending AW onto its slave link.
    fn forward_target(
        wr_owner: &mut HashMap<Txn, usize>,
        stats: &mut XbarStats,
        mux: &mut Mux,
        link: &mut AxiLink,
        beat: &AwBeat,
        target: &TargetAw,
        m: usize,
    ) {
        let fwd = AwBeat {
            id: beat.id,
            dest: target.dest,
            beats: beat.beats,
            beat_bytes: beat.beat_bytes,
            is_mcast: target.dest.count() > 1 || target.exclude.is_some(),
            exclude: target.exclude,
            src: m,
            txn: beat.txn,
        };
        link.aw.push(fwd);
        mux.push_w_order(m, beat.txn);
        wr_owner.insert(beat.txn, m);
        stats.aw_forks += 1;
    }

    /// Phase 6 — multicast commit (or per-slave forward when the commit
    /// protocol is disabled, reproducing fig. 2e).
    fn phase_commit(&mut self, pool: &mut LinkPool) {
        for m in 0..self.cfg.n_masters {
            let Some(entry) = self.pending[m].as_mut() else {
                continue;
            };
            if !entry.pend.beat.is_mcast {
                continue;
            }
            entry.age += 1;
            if entry.age <= self.cfg.mcast_commit_lat {
                self.stats.commit_waits += 1;
                continue;
            }
            let entry = self.pending[m].as_ref().unwrap();
            if entry.pend.targets.is_empty() {
                // unroutable mcast: accept so W drains, B = DECERR
                let entry = self.pending[m].take().unwrap();
                self.demux[m].accept(&entry.pend.beat, &entry.pend.targets, entry.pend.resp0);
                continue;
            }
            if self.cfg.commit_protocol {
                // all-or-nothing: every target granted to m and pushable
                let all_ready = entry.pend.targets.iter().all(|t| {
                    self.mux[t.slave].grant == Some(m)
                        && pool[self.s_links[t.slave]].aw.can_push()
                });
                if !all_ready {
                    self.stats.commit_waits += 1;
                    continue;
                }
                let entry = self.pending[m].take().unwrap();
                for t in &entry.pend.targets {
                    Self::forward_target(
                        &mut self.wr_owner,
                        &mut self.stats,
                        &mut self.mux[t.slave],
                        &mut pool[self.s_links[t.slave]],
                        &entry.pend.beat,
                        t,
                        m,
                    );
                    self.mux[t.slave].grant = None;
                }
                self.demux[m].accept(&entry.pend.beat, &entry.pend.targets, entry.pend.resp0);
            } else {
                // NO deadlock avoidance: fork each leg as it is granted
                let entry = self.pending[m].as_mut().unwrap();
                let n = entry.pend.targets.len();
                for i in 0..n {
                    if entry.forwarded[i] {
                        continue;
                    }
                    let t = entry.pend.targets[i].clone();
                    if self.mux[t.slave].grant == Some(m)
                        && pool[self.s_links[t.slave]].aw.can_push()
                    {
                        Self::forward_target(
                            &mut self.wr_owner,
                            &mut self.stats,
                            &mut self.mux[t.slave],
                            &mut pool[self.s_links[t.slave]],
                            &entry.pend.beat,
                            &t,
                            m,
                        );
                        entry.forwarded[i] = true;
                        self.mux[t.slave].grant = None;
                    }
                }
                if entry.forwarded.iter().all(|&f| f) {
                    let entry = self.pending[m].take().unwrap();
                    self.demux[m].accept(&entry.pend.beat, &entry.pend.targets, entry.pend.resp0);
                }
            }
        }
    }

    /// Phase 7 — unicast AW forwarding (round-robin; multicast priority
    /// stalls unicast issue on a slave with a live grant).
    fn phase_unicast_aw(&mut self, pool: &mut LinkPool) {
        // masters with a pending unicast AW and its (single) target
        let mut any = false;
        for m in 0..self.cfg.n_masters {
            self.scratch_want[m] = self.pending[m].as_ref().and_then(|p| {
                if p.pend.beat.is_mcast {
                    None
                } else {
                    p.pend.targets.first().map(|t| t.slave)
                }
            });
            any |= self.scratch_want[m].is_some();
            // unroutable unicast: accept immediately (W drains, DECERR B)
            let unroutable = self.pending[m]
                .as_ref()
                .map(|p| !p.pend.beat.is_mcast && p.pend.targets.is_empty())
                .unwrap_or(false);
            if unroutable {
                let entry = self.pending[m].take().unwrap();
                self.demux[m].accept(&entry.pend.beat, &entry.pend.targets, entry.pend.resp0);
                self.scratch_want[m] = None;
            }
        }
        if !any {
            return;
        }
        for s in 0..self.cfg.n_slaves {
            if self.mux[s].mcast_active() || !pool[self.s_links[s]].aw.can_push() {
                continue;
            }
            let want = &self.scratch_want;
            if let Some(m) = self.mux[s].rr_pick_aw_scan(self.cfg.n_masters, |m| want[m] == Some(s))
            {
                let entry = self.pending[m].take().unwrap();
                let t = entry.pend.targets[0].clone();
                Self::forward_target(
                    &mut self.wr_owner,
                    &mut self.stats,
                    &mut self.mux[s],
                    &mut pool[self.s_links[s]],
                    &entry.pend.beat,
                    &t,
                    m,
                );
                self.demux[m].accept(&entry.pend.beat, &entry.pend.targets, entry.pend.resp0);
                self.scratch_want[m] = None;
            }
        }
    }

    /// Phase 8 — W transport with all-ready multicast fork.
    fn phase_w(&mut self, pool: &mut LinkPool) {
        for m in 0..self.cfg.n_masters {
            if self.w_cooldown[m] > 0 {
                self.w_cooldown[m] -= 1;
                continue;
            }
            let Some(route) = self.demux[m].w_queue.front().cloned() else {
                continue;
            };
            if route.slaves.is_empty() {
                // drain W of an unroutable transaction
                if route.beats_left == 0 || pool[self.m_links[m]].w.pop().is_some() {
                    let r = self.demux[m].w_queue.front_mut().unwrap();
                    r.beats_left = r.beats_left.saturating_sub(1);
                    if r.beats_left == 0 {
                        self.demux[m].w_queue.pop_front();
                        let b = self.demux[m].complete_unroutable(route.txn);
                        self.demux[m].b_out.push_back(b);
                    }
                }
                continue;
            }
            if pool[self.m_links[m]].w.front().is_none() {
                continue;
            }
            // all-ready fork condition (green logic in fig. 2d): every
            // destination must be at the front of its mux W order AND
            // have channel space.
            let all_ready = route.slaves.iter().all(|&s| {
                self.mux[s].w_front_is(m, route.txn) && pool[self.s_links[s]].w.can_push()
            });
            if !all_ready {
                if route.is_mcast {
                    self.stats.w_fork_stalls += 1;
                }
                continue;
            }
            pool[self.m_links[m]].w.pop();
            self.stats.w_beats_in += 1;
            self.stats.w_fork_extra += route.slaves.len() as u64 - 1;
            let last = route.beats_left == 1;
            for &s in &route.slaves {
                pool[self.s_links[s]].w.push(WBeat {
                    last,
                    src: m,
                    txn: route.txn,
                });
                self.stats.w_beats_out += 1;
                if last {
                    self.mux[s].pop_w_order(m, route.txn);
                }
            }
            let r = self.demux[m].w_queue.front_mut().unwrap();
            r.beats_left -= 1;
            if last {
                self.demux[m].w_queue.pop_front();
            }
            // registered all-ready fork: a >1-way fork cannot re-fire
            // the cycle after a beat (stale ready) — see XbarCfg docs
            if route.slaves.len() > 1 {
                self.w_cooldown[m] = self.cfg.mcast_w_cooldown;
            }
        }
    }

    /// Any write/read activity still in flight inside the xbar?
    pub fn busy(&self) -> bool {
        self.pending.iter().any(Option::is_some)
            || self.demux.iter().any(|d| d.busy() || !d.b_out.is_empty())
            || !self.wr_owner.is_empty()
            || !self.rd_owner.is_empty()
            || !self.decerr_r.is_empty()
    }
}

impl Component<AxiLink> for Xbar {
    fn step(&mut self, _cy: Cycle, pool: &mut LinkPool) {
        Xbar::step(self, pool);
    }

    /// Safe to skip when the last stepped cycle left nothing in flight;
    /// the scheduler re-wakes the xbar on port activity.
    fn quiescent(&self) -> bool {
        !self.maybe_busy
    }

    fn ports(&self) -> &[LinkId] {
        &self.ports
    }
}

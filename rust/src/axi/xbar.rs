//! The N×M multicast-capable AXI crossbar (paper fig. 2a).
//!
//! Composition: one [`Demux`] per master port, one [`Mux`] per slave
//! port, wired through external [`AxiLink`]s held in a shared
//! [`LinkPool`] (the SoC or topology owns the pool; the xbar stores
//! typed [`LinkId`] handles). Each call to [`Xbar::step`] advances one
//! clock cycle through the phases:
//!
//! 1. **B join/drain** — collect B beats from slaves, fold into the
//!    per-demux joins, release merged responses to masters.
//! 2. **R/AR routing** — reads are unicast: round-robin AR arbitration
//!    per slave, R beats routed back by transaction tag.
//! 3. **AW accept** — pop+decode master AWs subject to the multicast
//!    ordering stalls (fig. 2d orange logic).
//! 4. **Grant** — per-slave priority-encoder (lzc) arbitration of
//!    multicast requesters; consistent cross-mux priority.
//! 5. **Commit** — a master holding grants on *all* addressed slaves
//!    (and space on all their AW channels) forks its AW atomically;
//!    with `commit_protocol = false` the fork happens per-slave as
//!    grants arrive, reproducing the fig. 2e deadlock.
//! 6. **Unicast AW forward** — round-robin, stalled while the mcast
//!    datapath holds a grant (multicast is prioritised).
//! 7. **W transport** — front-of-order W bursts move; a multicast W
//!    beat requires *all* destination channels ready (all-ready fork).
//!
//! ## Hierarchical multicast routing
//!
//! A request whose address set extends beyond this crossbar's local
//! rules is forwarded on the `default_slave` port carrying the original
//! set plus an **exclude scope** — the aligned region already served
//! locally. The next hop prunes rules inside the scope. This is the
//! model equivalent of the RTL's decomposition of the "rest of world"
//! route into log₂-many aligned mask-form rules; deliveries and beat
//! counts are identical (see DESIGN.md §2).
//!
//! ## End-to-end multicast ordering (`XbarCfg::e2e_mcast_order`)
//!
//! The per-crossbar commit protocol above cannot order commits *across*
//! crossbars: two concurrent global multicasts may enter the W-order
//! queues of different hierarchy levels in opposite orders and wedge on
//! the resulting inter-level cycle (the RTL's documented limitation).
//! With `e2e_mcast_order` the lock/commit machinery becomes one leg of
//! a fabric-wide two-phase reservation protocol ([`super::resv`]): the
//! entry crossbar stamps a globally ordered ticket onto the AW and
//! claims every node of the fork tree; grant arbitration admits only
//! the node's claim-front ticket (every later requester backs off
//! instead of holding muxes); and the commit in phase 6 additionally
//! requires that same front condition — conflicting multicasts then
//! commit in the same order at every crossbar they share, the waits-for
//! relation only points from younger to older tickets, and concurrent
//! global multicasts drain deadlock-free. Blocked cycles surface as
//! [`XbarStats::resv_waits`] with exact `skip` replay.
//!
//! ## §Perf: allocation-free, O(active) hot paths
//!
//! * B/R owner lookup goes through a dense open-addressed
//!   [`TxnTable`] instead of a SipHash `HashMap`.
//! * Decoded fork-target lists live in [`InlineVec`]s
//!   ([`TargetVec`]/[`SlaveVec`]); a per-master decode cache keyed by
//!   the front AW's txn avoids re-decoding while a request stalls.
//! * Per-master **worklist bitmasks** (`mask_pending`/`mask_w`/
//!   `mask_b_out`, plus an input-visibility scan computed once per
//!   step) let every phase iterate set bits in ascending order instead
//!   of scanning `0..n_masters` — identical arbitration order, cost
//!   proportional to actual activity.
//! * `XbarCfg::force_naive` turns the worklists and the dense table
//!   off (falling back to full scans + `HashMap`): the bit-identical
//!   reference mode checked by `tests/perf_parity.rs` and measured as
//!   an ablation layer by `benches/sim_perf.rs`. Crossbars wider than
//!   64 ports use the naive scans automatically.

use std::collections::{HashSet, VecDeque};

use super::addr_map::AddrMap;
use super::demux::{Demux, PendingAw, Stall, TargetAw, TargetVec};
use super::mcast::AddrSet;
use super::mux::{ArbPolicy, Mux};
use super::reduce::{NodePlan, RedNode, RedTag, ReduceHandle};
use super::resv::{ResvHandle, ResvNode, ResvSeq};
use super::types::{
    AwBeat, AxiId, AxiLink, LinkId, LinkPool, RBeat, Resp, SlaveVec, Txn, WBeat, FORK_INLINE,
};
use crate::sim::sched::Component;
use crate::sim::Cycle;
use crate::util::dense::TxnTable;
use crate::util::inline_vec::InlineVec;

/// One ring dimension of a ring-routed crossbar node (see
/// [`XbarCfg::ring`]). The dimension's nodes own equal consecutive
/// address slots of `span`; this node's slot is `local`. Routing is
/// **span-ordered** (dateline-style deterministic): a destination
/// below `local` leaves on `down_port`, above on `up_port`, and no
/// beat ever crosses the wrap link. This keeps every waits-for chain
/// in the W transport monotone in ring position — a cyclic
/// wormhole-style request deadlock needs wrap-through traffic, which
/// the model has no virtual channels to break — and makes the
/// reservation ledger's no-revisit traversal oracle hold trivially.
/// The builders still wire the physical wrap links; they idle under
/// the default routing (the event-horizon scheduler skips them).
#[derive(Debug, Clone)]
pub struct RingLevel {
    /// Slave port toward descending addresses.
    pub down_port: usize,
    /// Slave port toward ascending addresses.
    pub up_port: usize,
    /// Address interval covered by the whole dimension.
    pub span: (u64, u64),
    /// This node's slot within `span` (served locally, or handed to
    /// inner dimensions on a torus).
    pub local: (u64, u64),
}

impl RingLevel {
    /// Span-ordered port toward `addr` — the unicast rule of the
    /// dimension (same direction rule the multicast legs use).
    pub fn port_toward(&self, addr: u64) -> usize {
        if addr < self.local.0 {
            self.down_port
        } else {
            self.up_port
        }
    }
}

/// Crossbar configuration. `Clone` so the reservation ledger
/// (`axi::resv`) can snapshot the routing data its traversal oracle
/// replays.
#[derive(Debug, Clone)]
pub struct XbarCfg {
    pub name: String,
    pub n_masters: usize,
    pub n_slaves: usize,
    pub map: AddrMap,
    /// Port receiving traffic not matching any rule (hierarchy "up").
    pub default_slave: Option<usize>,
    /// Aligned region covered by this xbar's local rules; attached as
    /// the exclude scope on default-routed multicasts.
    pub local_scope: Option<(u64, u64)>,
    /// Paper's extension on/off (off = baseline XBAR; multicast AWs are
    /// rejected with DECERR).
    pub mcast_enabled: bool,
    /// Deadlock-avoidance commit protocol (fig. 2e). Disable only to
    /// demonstrate the deadlock.
    pub commit_protocol: bool,
    pub max_mcast_outstanding: u32,
    pub max_outstanding: u32,
    /// Minimum cycles a multicast AW spends in the grant/commit
    /// handshake before forking (the RTL's grant-settle + "releasing
    /// the muxes in the following cycle" sequence across all addressed
    /// muxes). Calibrated against fig. 3b's round-trip amortisation
    /// behaviour; unicast AWs are unaffected.
    pub mcast_commit_lat: u32,
    /// Idle cycles inserted after every multicast W fork beat.
    ///
    /// The RTL's `stream_fork` fans a W beat out through registered
    /// spill slices whose ready is one cycle stale; with more than one
    /// destination the all-ready condition is met every other cycle, so
    /// the sustained fork rate is ~½ beat/cycle. `1` reproduces that
    /// measured behaviour (calibrated against fig. 3b, see
    /// EXPERIMENTS.md); `0` is an idealised single-cycle fork
    /// (ablation).
    pub mcast_w_cooldown: u32,
    /// Reference/ablation mode (§Perf): disable the worklist bitmasks
    /// and the dense txn table, restoring the scan-everything PR-1
    /// behaviour. Simulated cycles and stats are bit-identical either
    /// way (`tests/perf_parity.rs`).
    pub force_naive: bool,
    /// End-to-end multicast ordering: lift the lock/commit protocol
    /// from a per-crossbar mechanism to the fabric-wide two-phase
    /// reservation protocol (`axi::resv`), which orders conflicting
    /// multicasts consistently across hierarchy levels and thereby
    /// allows *concurrent global* multicasts the RTL-faithful fabric
    /// must serialise. Off by default (the paper's reference
    /// behaviour). The flag only takes effect once a ledger is
    /// attached ([`Xbar::attach_resv`], done by
    /// `TopologyBuilder::build` for every shape) and requires
    /// `commit_protocol`.
    pub e2e_mcast_order: bool,
    /// In-network reduction (`axi::reduce`) — the dual of the
    /// multicast fork: converging write bursts tagged with a reduction
    /// group are absorbed at every join point of the fabric and
    /// forwarded upstream as ONE combined burst per join, saving
    /// `(contributors - 1) x beats` W beats per hop
    /// ([`XbarStats::red_beats_saved`]). Off by default (the
    /// RTL-faithful fabric, where converging traffic resolves at the
    /// endpoints); the flag only takes effect once a membership oracle
    /// is attached ([`Xbar::attach_reduce`], done by
    /// `TopologyBuilder::build` for every shape). With the flag off,
    /// tagged bursts travel individually and behavior is bit-identical
    /// to a fabric that never heard of reductions.
    pub fabric_reduce: bool,
    /// Request timeout (robustness layer, DESIGN.md §9): a decoded AW
    /// that cannot forward a single leg within this many cycles — or a
    /// front AR that cannot be granted — retires with **DECERR**
    /// instead of waiting forever. A retired multicast releases its
    /// fabric-wide reservation ticket (nothing was committed for a
    /// never-forwarded entry), so the claim queues keep advancing.
    /// `None` (default) disables the deadline; behavior is then
    /// bit-identical to the pre-robustness fabric.
    pub req_timeout: Option<u32>,
    /// Completion timeout (robustness layer, DESIGN.md §9): a *shared
    /// per-node* no-response counter arms whenever forwarded legs are
    /// outstanding and resets on every B/R beat any slave returns.
    /// When it reaches this deadline the oldest *eligible* leg — a
    /// read, a write whose WLAST was delivered, or a write whose slave
    /// stopped consuming its input — is synthesized as **SLVERR**: the
    /// fork leg still participates in the B-join, a timed-out read gets
    /// its exact remaining beats as an error burst, and a hung
    /// reduction contributor is evicted from the combine table so the
    /// combined burst still issues with an error-poisoned fan-back.
    /// `None` (default) disables the deadline (bit-identical when off).
    pub cpl_timeout: Option<u32>,
    /// QoS arbitration policy for the unicast AW/AR pickers and the
    /// static tier of the multicast priority encoder
    /// (`ArbPolicy::RoundRobin` is the historical, bit-identical
    /// default). Aging applies only to the unicast pickers — the
    /// multicast encoder needs a *globally consistent* order for
    /// deadlock freedom, so it uses the static priorities alone.
    pub arb_policy: ArbPolicy,
    /// Static per-master priorities for `ArbPolicy::Priority` (indexed
    /// by master port; missing entries default to 0). Ignored under
    /// `RoundRobin`.
    pub master_prio: Vec<u32>,
    /// Ring dimensions of this node, innermost-first (a 2D torus lists
    /// its X ring — span = the node's row — before its Y ring — span =
    /// the full endpoint space). Empty (the default) on every non-ring
    /// fabric: [`XbarCfg::decode_aw`] then runs the classic scope-based
    /// path verbatim, keeping flat/tree/mesh decode bit-identical.
    pub ring: Vec<RingLevel>,
}

impl XbarCfg {
    pub fn new(name: &str, n_masters: usize, n_slaves: usize, map: AddrMap) -> XbarCfg {
        XbarCfg {
            name: name.to_string(),
            n_masters,
            n_slaves,
            map,
            default_slave: None,
            local_scope: None,
            mcast_enabled: true,
            commit_protocol: true,
            max_mcast_outstanding: 4,
            max_outstanding: 16,
            mcast_commit_lat: 8,
            mcast_w_cooldown: 1,
            force_naive: crate::util::force_naive_env(),
            e2e_mcast_order: false,
            fabric_reduce: false,
            req_timeout: None,
            cpl_timeout: None,
            arb_policy: ArbPolicy::RoundRobin,
            master_prio: Vec::new(),
            ring: Vec::new(),
        }
    }

    /// Is any robustness deadline armed?
    #[inline]
    pub fn timeouts_armed(&self) -> bool {
        self.req_timeout.is_some() || self.cpl_timeout.is_some()
    }

    /// Route one unicast address: the address map first, then the ring
    /// dimensions innermost-first (span-ordered, never across the wrap
    /// link — see [`RingLevel`]), then the default route. With `ring`
    /// empty this is exactly the historical map-then-default rule.
    pub fn route_unicast(&self, addr: u64) -> Option<usize> {
        if let Some(s) = self.map.decode_unicast(addr) {
            return Some(s);
        }
        for lvl in &self.ring {
            if addr >= lvl.span.0
                && addr < lvl.span.1
                && !(addr >= lvl.local.0 && addr < lvl.local.1)
            {
                return Some(lvl.port_toward(addr));
            }
        }
        self.default_slave
    }

    /// Decode an AW's destination set into fork targets, honouring the
    /// exclude scope, the include window, the ring dimensions and the
    /// default route. Lives on the config (pure in the routing data) so
    /// the reservation ledger's traversal oracle (`axi::resv`) replays
    /// *exactly* the datapath's decode.
    pub fn decode_aw(
        &self,
        dest: &AddrSet,
        exclude: Option<(u64, u64)>,
        window: Option<(u64, u64)>,
    ) -> (TargetVec, Resp) {
        // fast path: plain unicast
        if dest.is_singleton() {
            if let Some(s) = self.route_unicast(dest.addr) {
                let mut t = TargetVec::new();
                t.push(TargetAw {
                    slave: s,
                    dest: *dest,
                    exclude: None,
                    window: None,
                });
                return (t, Resp::Okay);
            }
            return (TargetVec::new(), Resp::DecErr);
        }

        if !self.mcast_enabled {
            // baseline XBAR: masked requests are illegal
            return (TargetVec::new(), Resp::DecErr);
        }

        // non-ring fabrics with no window take the historical scoped
        // path verbatim — flat/tree/mesh decode stays bit-identical
        if self.ring.is_empty() && window.is_none() {
            return self.decode_aw_scoped(dest, exclude);
        }
        self.decode_aw_windowed(dest, exclude, window)
    }

    /// The historical scope-based multicast decode (trees, meshes,
    /// flat): mask-form subset arithmetic with one aligned exclude.
    fn decode_aw_scoped(&self, dest: &AddrSet, exclude: Option<(u64, u64)>) -> (TargetVec, Resp) {
        let d = self.map.decode(dest);
        let mut targets = TargetVec::new();
        let mut excl_in_rules = 0u64;
        for (s, sub) in &d.targets {
            if let Some((es, ee)) = exclude {
                if sub.base() >= es && sub.top() < ee {
                    // already served upstream of this hop
                    excl_in_rules += sub.count();
                    continue;
                }
            }
            targets.push(TargetAw {
                slave: *s,
                dest: *sub,
                exclude: None,
                window: None,
            });
        }
        // addresses excluded but not matched by local rules
        let n_excl = match exclude {
            Some((es, ee)) => AddrSet::from_interval(es, ee)
                .ok()
                .and_then(|e| dest.intersect(&e))
                .map(|i| i.count())
                .unwrap_or(0),
            None => 0,
        };
        let excl_unmatched = n_excl.saturating_sub(excl_in_rules);
        let remainder = d.uncovered.saturating_sub(excl_unmatched);
        let mut resp0 = Resp::Okay;
        if remainder > 0 {
            match self.default_slave {
                Some(up) => {
                    // Forward the original set up, extending the scope.
                    // Nested scopes merge to the outer region: in a
                    // well-formed hierarchy the incoming exclude (served
                    // at a lower level) is contained in this crossbar's
                    // local scope, and the union of "already served"
                    // addresses is exactly the outer aligned region.
                    // Disjoint scopes (a malformed topology) stay
                    // unrepresentable.
                    let scope = match (exclude, self.local_scope) {
                        (None, s) => s,
                        (e @ Some(_), None) => e,
                        (Some((es, ee)), Some((ls, le))) => {
                            if ls <= es && ee <= le {
                                Some((ls, le))
                            } else if es <= ls && le <= ee {
                                Some((es, ee))
                            } else {
                                panic!(
                                    "xbar {}: disjoint exclude scopes \
                                     [{es:#x},{ee:#x}) vs local [{ls:#x},{le:#x}) \
                                     are not representable (scopes must nest)",
                                    self.name
                                )
                            }
                        }
                    };
                    targets.push(TargetAw {
                        slave: up,
                        dest: *dest,
                        exclude: scope,
                        window: None,
                    });
                }
                None => resp0 = Resp::DecErr,
            }
        }
        targets.sort_by_key(|t| t.slave);
        (targets, resp0)
    }

    /// The ring/window multicast decode: map-matched subsets inside the
    /// window are served here (or through peer rules); every other live
    /// member rides a ring leg whose window is the leg's directional
    /// range clipped to the incoming window. Windows only shrink by
    /// interval intersection, so they stay single intervals where
    /// accumulated excludes would go disjoint; the incoming exclude is
    /// passed through unchanged on ring legs (a tile-served aligned
    /// region stays prunable anywhere on the ring). Accounting is by
    /// member enumeration — window clipping makes the scoped path's
    /// mask-form arithmetic inapplicable.
    fn decode_aw_windowed(
        &self,
        dest: &AddrSet,
        exclude: Option<(u64, u64)>,
        window: Option<(u64, u64)>,
    ) -> (TargetVec, Resp) {
        let in_win = |a: u64| window.map_or(true, |(ws, we)| a >= ws && a < we);
        let excl = |a: u64| exclude.is_some_and(|(es, ee)| a >= es && a < ee);
        let d = self.map.decode(dest);
        let mut targets = TargetVec::new();
        for (s, sub) in &d.targets {
            // ring windows are node-region aligned, so a decoded subset
            // is wholly in or wholly out
            debug_assert_eq!(
                in_win(sub.base()),
                in_win(sub.top()),
                "xbar {}: window straddles a decoded subset",
                self.name
            );
            if !in_win(sub.base()) {
                continue;
            }
            if let Some((es, ee)) = exclude {
                if sub.base() >= es && sub.top() < ee {
                    // already served upstream of this hop
                    continue;
                }
            }
            targets.push(TargetAw {
                slave: *s,
                dest: *sub,
                exclude: None,
                window: None,
            });
        }
        let members = dest.enumerate();
        for lvl in &self.ring {
            for (port, rs, re) in [
                (lvl.down_port, lvl.span.0, lvl.local.0),
                (lvl.up_port, lvl.local.1, lvl.span.1),
            ] {
                let ws = window.map_or(rs, |(w, _)| w.max(rs));
                let we = window.map_or(re, |(_, w)| w.min(re));
                if ws >= we {
                    continue;
                }
                if members.iter().any(|&a| a >= ws && a < we && !excl(a)) {
                    targets.push(TargetAw {
                        slave: port,
                        dest: *dest,
                        exclude,
                        window: Some((ws, we)),
                    });
                }
            }
        }
        // every live member must sit in a kept subset or a leg window;
        // anything else decode-errors at the source, exactly like the
        // flat crossbar's uncovered count
        let mut resp0 = Resp::Okay;
        'members: for &a in &members {
            if !in_win(a) || excl(a) {
                continue;
            }
            for t in targets.iter() {
                let hit = match t.window {
                    Some((ws, we)) => a >= ws && a < we,
                    None => t.dest.contains(a),
                };
                if hit {
                    continue 'members;
                }
            }
            resp0 = Resp::DecErr;
            break;
        }
        targets.sort_by_key(|t| t.slave);
        (targets, resp0)
    }
}

/// Aggregate statistics (read by benches and EXPERIMENTS.md harnesses).
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct XbarStats {
    pub aw_unicast: u64,
    pub aw_mcast: u64,
    pub aw_forks: u64,
    pub w_beats_in: u64,
    pub w_beats_out: u64,
    pub w_fork_stalls: u64,
    pub b_joined: u64,
    pub commit_waits: u64,
    pub ar_forwarded: u64,
    pub r_beats: u64,
    pub decerr: u64,
    pub stall_id_conflict: u64,
    pub stall_mcast_order: u64,
    /// Extra W beats produced by multicast forking: for every W beat
    /// entering, `fanout - 1` additional beats leave. Invariant checked
    /// by the integration suites: `w_beats_out == w_beats_in + w_fork_extra`.
    pub w_fork_extra: u64,
    /// Fabric-wide reservation tickets issued at this crossbar (it was
    /// the multicast's entry node). Only nonzero with
    /// `XbarCfg::e2e_mcast_order`.
    pub resv_tickets: u64,
    /// Cycles a pending ticketed AW spent blocked on the fabric-wide
    /// reservation order (its ticket not yet at the front of this
    /// node's claim queue) — the new stall reason of the two-phase
    /// protocol, replayed bit-identically by `Xbar::skip`.
    pub resv_waits: u64,
    /// Claims retired at this crossbar (ticketed AWs committed here).
    pub resv_commits: u64,
    /// In-network reduction (`XbarCfg::fabric_reduce`): combined
    /// bursts this crossbar forwarded upstream — one per fully-arrived
    /// combine-table entry, the converging dual of `aw_forks`.
    pub red_joins: u64,
    /// W beats the combining removed from this crossbar's upstream
    /// traffic: per join of `k` contributor bursts of `b` beats,
    /// `(k-1)*b`. The mirror of `w_fork_extra`; the balanced fork/join
    /// accounting is `w_beats_out == w_beats_in + w_fork_extra -
    /// red_beats_saved`. Combining acts only on beat arrivals and
    /// channel pushes — no per-cycle wait counter exists, so
    /// `Xbar::skip` has nothing to replay and event-horizon parity
    /// holds by construction (`tests/perf_parity.rs`).
    pub red_beats_saved: u64,
    /// Requests retired with DECERR by the request deadline
    /// (`XbarCfg::req_timeout`): never-forwarded AWs plus starved front
    /// ARs. Event counter — fires are events, so `Xbar::skip` has
    /// nothing to replay (the *deadline counters* are what skip
    /// advances).
    pub req_timeouts: u64,
    /// Forwarded legs synthesized as SLVERR by the completion deadline
    /// (`XbarCfg::cpl_timeout`), including evicted reduction joins.
    pub cpl_timeouts: u64,
    /// Reduction contributors evicted from combine-table entries by the
    /// completion deadline (the combined burst then issues with an
    /// error-poisoned B fan-back).
    pub red_evictions: u64,
    /// W beats dropped by timeout unwinding: beats of fully-evicted
    /// routes plus unsent beats of a cancelled combined burst. Extends
    /// the fork/join accounting to `w_beats_out == w_beats_in +
    /// w_fork_extra - red_beats_saved - w_dropped` under faults.
    pub w_dropped: u64,
    /// Late B/R beats from already-timed-out legs, dropped via the
    /// zombie set instead of corrupting a completed join.
    pub late_drops: u64,
    /// Forwards granted by the `ArbPolicy::Priority` arbiters (unicast
    /// AW/AR picks and multicast commits). Event counter — no skip
    /// replay needed.
    pub prio_grants: u64,
}

impl XbarStats {
    /// Accumulate another crossbar's counters (network/topology sums).
    pub fn add(&mut self, o: &XbarStats) {
        self.aw_unicast += o.aw_unicast;
        self.aw_mcast += o.aw_mcast;
        self.aw_forks += o.aw_forks;
        self.w_beats_in += o.w_beats_in;
        self.w_beats_out += o.w_beats_out;
        self.w_fork_stalls += o.w_fork_stalls;
        self.b_joined += o.b_joined;
        self.commit_waits += o.commit_waits;
        self.ar_forwarded += o.ar_forwarded;
        self.r_beats += o.r_beats;
        self.decerr += o.decerr;
        self.stall_id_conflict += o.stall_id_conflict;
        self.stall_mcast_order += o.stall_mcast_order;
        self.w_fork_extra += o.w_fork_extra;
        self.resv_tickets += o.resv_tickets;
        self.resv_waits += o.resv_waits;
        self.resv_commits += o.resv_commits;
        self.red_joins += o.red_joins;
        self.red_beats_saved += o.red_beats_saved;
        self.req_timeouts += o.req_timeouts;
        self.cpl_timeouts += o.cpl_timeouts;
        self.red_evictions += o.red_evictions;
        self.w_dropped += o.w_dropped;
        self.late_drops += o.late_drops;
        self.prio_grants += o.prio_grants;
    }
}

/// In-flight pending AW extended with per-target forward flags (used in
/// the no-commit mode to reproduce the deadlock).
#[derive(Debug)]
struct PendingEntry {
    pend: PendingAw,
    forwarded: InlineVec<bool, FORK_INLINE>,
    /// Cycles spent pending (commit handshake modelling).
    age: u32,
    /// Cycles spent with *no* leg forwarded — the request-deadline
    /// counter (`XbarCfg::req_timeout`). Separate from `age` so the
    /// commit-handshake replay in `Xbar::skip` stays bit-identical
    /// with timeouts off.
    wait: u32,
}

/// Memoised decode of one master's front AW (§Perf): a stalled request
/// is re-examined every cycle, but its decode is pure in the beat, so
/// it is computed once per transaction instead of once per cycle.
#[derive(Debug)]
struct DecCache {
    txn: Txn,
    targets: TargetVec,
    resp0: Resp,
}

/// Virtual master index the combine table uses in the exit mux's
/// W-order queue (in-network reduction): the combined burst is sourced
/// by the crossbar itself, not by any external master port.
const RED_MASTER: usize = usize::MAX;

/// Upstream progress of one combine-table entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum RedState {
    /// Waiting for contributor bursts (`arrived < expected`).
    Collecting,
    /// All contributors absorbed; the combined AW awaits channel space.
    Ready,
    /// Combined AW issued; `left` W beats still to stream.
    Streaming { left: u32 },
    /// Combined burst fully sent; waiting for the upstream B to fan
    /// back to the absorbed contributors.
    AwaitB,
}

/// One in-flight join of the per-node combine table (in-network
/// reduction, `axi::reduce`): the contributions of one reduction group
/// to one burst address converging at this crossbar. Kept in a plain
/// `Vec` in creation order — iteration order is part of the simulated
/// behavior, and a randomized-hash map would diverge between runs.
#[derive(Debug)]
struct CombineEntry {
    group: u32,
    /// Burst base address (all members write the same split).
    addr: u64,
    beats: u32,
    beat_bytes: u32,
    exit_slave: usize,
    expected: u32,
    /// Contributor bursts fully drained into this entry.
    arrived: u32,
    /// Absorbed contributors awaiting the fanned B: (master, id, txn).
    waiters: Vec<(usize, AxiId, Txn)>,
    state: RedState,
    /// Transaction tag of the combined upstream burst — the first
    /// contributor's (globally unique; its original burst was absorbed
    /// here, so the tag is free to travel on).
    up_txn: Txn,
    id: AxiId,
    tag: RedTag,
    /// Completion-deadline counter: cycles spent collecting while at
    /// least one *expected* contributor has not even arrived (reset by
    /// every new contribution). Only ticks with `XbarCfg::cpl_timeout`.
    wait: u32,
    /// Contributors were evicted by the completion deadline: the
    /// fanned-back B is error-poisoned (joined with SLVERR).
    poisoned: bool,
}

/// One forwarded leg awaiting its completion (B or last R) — the
/// completion-timeout scoreboard, kept in forward order. Only
/// maintained when `XbarCfg::cpl_timeout` is armed.
#[derive(Debug, Clone, Copy)]
struct CplLeg {
    slave: usize,
    /// Source master port (`RED_MASTER` for a combined reduction burst).
    master: usize,
    txn: Txn,
    id: AxiId,
    read: bool,
    /// Reads: R beats not yet delivered to the master (the synthesized
    /// SLVERR burst must carry *exactly* this many — DMA engines drain
    /// by beat count).
    beats_left: u32,
    /// Writes: the WLAST beat reached the slave's W channel, so the
    /// slave owes a B — the leg is then always eligible to fire.
    wlast_sent: bool,
}

/// The crossbar.
pub struct Xbar {
    pub cfg: XbarCfg,
    pub demux: Vec<Demux>,
    pub mux: Vec<Mux>,
    /// Master-side links (masters push AW/W/AR). Read-only after
    /// construction: `Component::ports()` serves a cached copy, so
    /// rewiring a built xbar would desync the scheduler's wake hints.
    pub m_links: Vec<LinkId>,
    /// Slave-side links (xbar pushes AW/W/AR). Read-only after
    /// construction (see `m_links`).
    pub s_links: Vec<LinkId>,
    /// All external ports (`m_links` then `s_links`), cached for the
    /// scheduler's wake/dirty bookkeeping.
    ports: Vec<LinkId>,
    pending: Vec<Option<PendingEntry>>,
    /// Per-master cooldown countdown for multicast W forks.
    w_cooldown: Vec<u32>,
    /// Reused per-cycle scratch (per-master decoded target), avoiding
    /// hot-loop allocation. Invariant: all `None` between phases.
    scratch_want: Vec<Option<usize>>,
    /// Per-master decode memo for the front AW (§Perf).
    dec_cache: Vec<Option<DecCache>>,
    /// Cached busy state from the last stepped cycle (idle-skip).
    pub maybe_busy: bool,
    wr_owner: TxnTable,
    rd_owner: TxnTable,
    /// Error read responses being generated: (master, id, txn, beats,
    /// resp) — DECERR for unroutable/timed-out requests, SLVERR for
    /// completion-timeout synthesis. VecDeque so the common
    /// front-completion removal is O(1).
    err_r: VecDeque<(usize, u16, Txn, u32, Resp)>,
    /// Fabric-wide reservation ledger handle + this crossbar's node id
    /// (end-to-end multicast ordering; `None` = per-crossbar protocol
    /// only, the RTL-faithful default).
    resv: Option<(ResvHandle, ResvNode)>,
    /// In-network-reduction membership oracle + this crossbar's node id
    /// (`None` = reductions resolve at the endpoints, the RTL-faithful
    /// default).
    red: Option<(ReduceHandle, RedNode)>,
    /// Live joins of the per-node combine table (creation order).
    red_entries: Vec<CombineEntry>,
    /// Completion-timeout scoreboard: forwarded legs in forward order
    /// (empty unless `XbarCfg::cpl_timeout` is armed).
    cpl_legs: VecDeque<CplLeg>,
    /// The shared per-node no-response counter: cycles since the last
    /// B/R beat any slave returned, ticking only while legs are
    /// outstanding. Bulk-advanced by `Xbar::skip`.
    cpl_age: u32,
    /// (slave, txn) legs whose completion was synthesized — a late real
    /// beat from the (typically hung) slave is dropped, not joined.
    zombie: HashSet<(usize, Txn)>,
    /// Per-master request-deadline tracker for the front AR:
    /// (txn, cycles waited). Visible ARs keep links busy, so skips
    /// never span a ticking tracker and no replay is needed.
    ar_front_wait: Vec<Option<(Txn, u32)>>,
    pub stats: XbarStats,

    // ---- worklists (§Perf) ----
    /// Bitmasks valid when `use_masks`: masters with a decoded pending
    /// AW / a live W route or fork cooldown / queued joined Bs.
    mask_pending: u64,
    mask_w: u64,
    mask_b_out: u64,
    /// Pending multicast count (O(1) grant-phase early-out).
    n_pending_mcast: u32,
    /// Any mux may hold a stale grant (cleared once after the last
    /// pending multicast retires).
    grants_live: bool,
    /// Worklists enabled: `!force_naive` and ≤64 ports per side.
    use_masks: bool,
}

impl Xbar {
    /// Build a crossbar whose ports use the given pool links.
    pub fn new(cfg: XbarCfg, m_links: Vec<LinkId>, s_links: Vec<LinkId>) -> Xbar {
        assert_eq!(m_links.len(), cfg.n_masters);
        assert_eq!(s_links.len(), cfg.n_slaves);
        // a zero cap can admit nothing — the fabric would wedge on the
        // first write, which the config layer must reject loudly
        // (SocConfig::validate) rather than silently hang
        assert!(
            cfg.max_outstanding > 0 && cfg.max_mcast_outstanding > 0,
            "{}: outstanding-request caps must be nonzero \
             (max_outstanding={}, max_mcast_outstanding={})",
            cfg.name,
            cfg.max_outstanding,
            cfg.max_mcast_outstanding
        );
        let demux = (0..cfg.n_masters)
            .map(|i| Demux::new(i, cfg.max_mcast_outstanding, cfg.max_outstanding))
            .collect();
        let mux = (0..cfg.n_slaves).map(Mux::new).collect();
        let pending = (0..cfg.n_masters).map(|_| None).collect();
        let w_cooldown = vec![0; cfg.n_masters];
        let scratch_want = vec![None; cfg.n_masters];
        let dec_cache = (0..cfg.n_masters).map(|_| None).collect();
        let ports: Vec<LinkId> = m_links.iter().chain(s_links.iter()).copied().collect();
        let use_masks = !cfg.force_naive && cfg.n_masters <= 64 && cfg.n_slaves <= 64;
        let force_naive = cfg.force_naive;
        let ar_front_wait = vec![None; cfg.n_masters];
        Xbar {
            cfg,
            demux,
            mux,
            m_links,
            s_links,
            ports,
            pending,
            w_cooldown,
            scratch_want,
            dec_cache,
            maybe_busy: false,
            wr_owner: TxnTable::new(force_naive),
            rd_owner: TxnTable::new(force_naive),
            err_r: VecDeque::new(),
            resv: None,
            red: None,
            red_entries: Vec::new(),
            cpl_legs: VecDeque::new(),
            cpl_age: 0,
            zombie: HashSet::new(),
            ar_front_wait,
            stats: XbarStats::default(),
            mask_pending: 0,
            mask_w: 0,
            mask_b_out: 0,
            n_pending_mcast: 0,
            grants_live: false,
            use_masks,
        }
    }

    /// Convenience for tests: allocate a fresh pool with one link per
    /// port (masters first, then slaves).
    pub fn with_pool(cfg: XbarCfg, depth: usize) -> (Xbar, LinkPool) {
        let nm = cfg.n_masters;
        let ns = cfg.n_slaves;
        let mut pool = LinkPool::new();
        let m_links: Vec<LinkId> = (0..nm).map(|_| pool.alloc(AxiLink::new(depth))).collect();
        let s_links: Vec<LinkId> = (0..ns).map(|_| pool.alloc(AxiLink::new(depth))).collect();
        (Xbar::new(cfg, m_links, s_links), pool)
    }

    // ---- worklist bookkeeping (no-ops semantically; the masks are
    // pure accelerators and ignored in naive mode) ----

    #[inline]
    fn note_pending(&mut self, m: usize, set: bool) {
        if m < 64 {
            if set {
                self.mask_pending |= 1u64 << m;
            } else {
                self.mask_pending &= !(1u64 << m);
            }
        }
    }

    #[inline]
    fn note_w(&mut self, m: usize) {
        if m < 64 {
            self.mask_w |= 1u64 << m;
        }
    }

    #[inline]
    fn note_b_out(&mut self, m: usize) {
        if m < 64 {
            self.mask_b_out |= 1u64 << m;
        }
    }

    /// Run `f` for each index in `mask` (ascending — the same order as
    /// the naive scan, so arbitration is unaffected), or for `0..n`
    /// when the worklists are disabled.
    #[inline]
    fn for_each(
        &mut self,
        mask: u64,
        n: usize,
        pool: &mut LinkPool,
        mut f: impl FnMut(&mut Xbar, usize, &mut LinkPool),
    ) {
        if self.use_masks {
            let mut bits = mask;
            while bits != 0 {
                let i = bits.trailing_zeros() as usize;
                bits &= bits - 1;
                f(self, i, pool);
            }
        } else {
            for i in 0..n {
                f(self, i, pool);
            }
        }
    }

    /// Attach the fabric-wide reservation ledger (end-to-end multicast
    /// ordering). `node` is this crossbar's identity inside the shared
    /// ledger; `TopologyBuilder::build` wires this for every node of a
    /// tree or mesh when any node requests `e2e_mcast_order`.
    pub fn attach_resv(&mut self, handle: ResvHandle, node: ResvNode) {
        self.resv = Some((handle, node));
    }

    /// Attach the in-network-reduction membership oracle. `node` is
    /// this crossbar's identity inside the shared ledger;
    /// `TopologyBuilder::build` wires this for every node when any
    /// node requests `XbarCfg::fabric_reduce`.
    pub fn attach_reduce(&mut self, handle: ReduceHandle, node: RedNode) {
        self.red = Some((handle, node));
    }

    /// This node's combining duty for `group`, if in-network reduction
    /// is armed and the node is a join point of the group's converging
    /// tree (`None` ⇒ the tagged burst rides the plain unicast
    /// datapath).
    #[inline]
    fn red_plan(&self, group: u32) -> Option<NodePlan> {
        match &self.red {
            Some((h, node)) if self.cfg.fabric_reduce => h.lock().unwrap().plan(*node, group),
            _ => None,
        }
    }

    /// Static QoS priority of master `m` (missing entries are 0).
    #[inline]
    fn master_prio_of(&self, m: usize) -> u32 {
        self.cfg.master_prio.get(m).copied().unwrap_or(0)
    }

    /// Enrol a just-forwarded write leg on the completion-timeout
    /// scoreboard (no-op when `cpl_timeout` is unarmed). Legs are kept
    /// in forward order so the shared counter always fires the oldest
    /// eligible one.
    #[inline]
    fn cpl_track_write(&mut self, slave: usize, master: usize, txn: Txn, id: AxiId) {
        if self.cfg.cpl_timeout.is_some() {
            self.cpl_legs.push_back(CplLeg {
                slave,
                master,
                txn,
                id,
                read: false,
                beats_left: 0,
                wlast_sent: false,
            });
        }
    }

    /// Is the end-to-end reservation protocol active on this crossbar?
    #[inline]
    fn e2e(&self) -> bool {
        self.cfg.e2e_mcast_order && self.cfg.commit_protocol && self.resv.is_some()
    }

    /// Is this (possibly absent) ticket at the front of this node's
    /// fabric-wide claim queue? Unticketed requests are never gated.
    #[inline]
    fn resv_front(&self, ticket: Option<ResvSeq>) -> bool {
        match (&self.resv, ticket) {
            (Some((h, node)), Some(seq)) => h.lock().unwrap().is_front(*node, seq),
            _ => true,
        }
    }

    /// Retire this node's claim of a committed ticket.
    fn resv_commit(&mut self, ticket: Option<ResvSeq>) {
        if let Some(seq) = ticket {
            let (h, node) = self.resv.clone().expect("ticketed beat without a ledger");
            h.lock().unwrap().commit(node, seq);
            self.stats.resv_commits += 1;
        }
    }

    /// One clock cycle. `pool` is the shared link pool.
    pub fn step(&mut self, pool: &mut LinkPool) {
        // one consolidated input-visibility scan (§Perf): which ports
        // carry beats this cycle; the phases then iterate set bits only
        let (mut in_aw, mut in_ar, mut in_b, mut in_r) = (0u64, 0u64, 0u64, 0u64);
        if self.use_masks {
            for (m, &l) in self.m_links.iter().enumerate() {
                let link = &pool[l];
                if link.aw.visible() > 0 {
                    in_aw |= 1u64 << m;
                }
                if link.ar.visible() > 0 {
                    in_ar |= 1u64 << m;
                }
            }
            for (s, &l) in self.s_links.iter().enumerate() {
                let link = &pool[l];
                if link.b.visible() > 0 {
                    in_b |= 1u64 << s;
                }
                if link.r.visible() > 0 {
                    in_r |= 1u64 << s;
                }
            }
        }
        self.phase_b(pool, in_b);
        self.phase_r(pool, in_r);
        if self.cfg.timeouts_armed() {
            self.phase_timeouts(pool);
        }
        self.phase_ar(pool, in_ar);
        self.phase_aw_accept(pool, in_aw);
        self.phase_grant();
        self.phase_commit(pool);
        self.phase_unicast_aw(pool);
        self.phase_w(pool);
        self.phase_reduce(pool);
        // cached for the scheduler's idle-skip (§Perf): an idle xbar is
        // only re-woken by visible beats on its ports (activity hints)
        self.maybe_busy = self.busy();
    }

    /// Phase 1 — B collection + joined-B drain.
    fn phase_b(&mut self, pool: &mut LinkPool, in_b: u64) {
        let ns = self.cfg.n_slaves;
        self.for_each(in_b, ns, pool, |xb, s, pool| {
            if let Some(b) = pool[xb.s_links[s]].b.pop() {
                // completion-timeout scoreboard: any response is
                // progress (shared counter resets), and the leg retires
                if xb.cfg.cpl_timeout.is_some() {
                    xb.cpl_age = 0;
                    if let Some(i) = xb
                        .cpl_legs
                        .iter()
                        .position(|l| l.slave == s && l.txn == b.txn && !l.read)
                    {
                        xb.cpl_legs.remove(i);
                    }
                }
                // a late B for an already-synthesized leg: drop it —
                // the join completed with SLVERR when the leg fired
                if xb.zombie.remove(&(s, b.txn)) {
                    xb.stats.late_drops += 1;
                    return;
                }
                // combined reduction burst: fan the single upstream B
                // out to every absorbed contributor — the converging
                // dual of the multicast B-join
                if let Some(i) = xb
                    .red_entries
                    .iter()
                    .position(|e| e.state == RedState::AwaitB && e.up_txn == b.txn)
                {
                    let e = xb.red_entries.remove(i);
                    // evicted contributors poison the fan-back
                    let resp = if e.poisoned {
                        b.resp.join(Resp::SlvErr)
                    } else {
                        b.resp
                    };
                    for (m, id, txn) in e.waiters {
                        let joined = xb.demux[m]
                            .join_b(txn, resp, id)
                            .expect("sink join must complete on the fanned B");
                        xb.stats.b_joined += 1;
                        xb.demux[m].b_out.push_back(joined);
                        xb.note_b_out(m);
                    }
                    return;
                }
                let m = xb
                    .wr_owner
                    .get(b.txn)
                    .unwrap_or_else(|| panic!("{}: B for unknown txn {}", xb.cfg.name, b.txn));
                if let Some(joined) = xb.demux[m].join_b(b.txn, b.resp, b.id) {
                    xb.wr_owner.remove(b.txn);
                    xb.stats.b_joined += 1;
                    xb.demux[m].b_out.push_back(joined);
                    xb.note_b_out(m);
                }
            }
        });
        let nm = self.cfg.n_masters;
        self.for_each(self.mask_b_out, nm, pool, |xb, m, pool| {
            if let Some(&b) = xb.demux[m].b_out.front() {
                if pool[xb.m_links[m]].b.can_push() {
                    xb.demux[m].b_out.pop_front();
                    pool[xb.m_links[m]].b.push(b);
                }
            }
            if m < 64 && xb.demux[m].b_out.is_empty() {
                xb.mask_b_out &= !(1u64 << m);
            }
        });
    }

    /// Phase 2 — R routing (slave→master) + DECERR R generation.
    fn phase_r(&mut self, pool: &mut LinkPool, in_r: u64) {
        let ns = self.cfg.n_slaves;
        self.for_each(in_r, ns, pool, |xb, s, pool| {
            let link = xb.s_links[s];
            let Some(r) = pool[link].r.front().copied() else {
                return;
            };
            // late beats of an already-synthesized read leg: drain and
            // drop — the master received its SLVERR burst long ago
            if xb.zombie.contains(&(s, r.txn)) {
                pool[link].r.pop();
                xb.stats.late_drops += 1;
                if xb.cfg.cpl_timeout.is_some() {
                    xb.cpl_age = 0;
                }
                if r.last {
                    xb.zombie.remove(&(s, r.txn));
                }
                return;
            }
            let m = xb
                .rd_owner
                .get(r.txn)
                .unwrap_or_else(|| panic!("{}: R for unknown txn {}", xb.cfg.name, r.txn));
            if pool[xb.m_links[m]].r.can_push() {
                pool[link].r.pop();
                if r.last {
                    xb.rd_owner.remove(r.txn);
                }
                pool[xb.m_links[m]].r.push(r);
                xb.stats.r_beats += 1;
                // completion-timeout scoreboard: delivered beats are
                // progress; the leg retires on its last beat
                if xb.cfg.cpl_timeout.is_some() {
                    xb.cpl_age = 0;
                    if let Some(i) = xb
                        .cpl_legs
                        .iter()
                        .position(|l| l.slave == s && l.txn == r.txn && l.read)
                    {
                        if r.last {
                            xb.cpl_legs.remove(i);
                        } else {
                            xb.cpl_legs[i].beats_left -= 1;
                        }
                    }
                }
            }
        });
        // synthesize error read data: DECERR for unroutable/timed-out
        // ARs, SLVERR for completion-timeout remainders
        let mut i = 0;
        while i < self.err_r.len() {
            let (m, id, txn, ref mut beats, resp) = self.err_r[i];
            if pool[self.m_links[m]].r.can_push() {
                *beats -= 1;
                let last = *beats == 0;
                pool[self.m_links[m]].r.push(RBeat { id, last, resp, txn });
                if last {
                    let _ = self.err_r.remove(i);
                    continue;
                }
            }
            i += 1;
        }
    }

    /// Phase 2.5 — request/completion deadlines (`XbarCfg::req_timeout`
    /// / `cpl_timeout`). Gated on [`XbarCfg::timeouts_armed`] so the
    /// default configuration never executes a single instruction of it.
    ///
    /// Mirrors the production-crossbar scheme: *request* deadlines are
    /// per-request (a request that cannot win arbitration or clear
    /// backpressure within `req_timeout` retires with DECERR), while
    /// the *completion* deadline is one shared per-node counter — any
    /// B/R beat from any slave is progress and resets it; when it
    /// expires, the oldest leg that provably owes a response is
    /// synthesized as SLVERR. A write leg whose WLAST has not reached
    /// the slave only counts as owing once the slave's input channels
    /// are backed up — otherwise the leg is still in flight through the
    /// fabric and firing it would poison a healthy slave.
    fn phase_timeouts(&mut self, pool: &mut LinkPool) {
        let nm = self.cfg.n_masters;
        if let Some(reqt) = self.cfg.req_timeout {
            // (a) pending AWs: tick while any leg has yet to fork. At
            // the deadline a fully-unforwarded entry retires whole
            // (DECERR); a partially-forwarded no-commit fork instead
            // evicts its stuck legs so the forwarded ones can accept —
            // without this, a fork wedged on a dead slave's backed-up
            // AW channel would never resolve (commit-protocol forks are
            // atomic, so partial entries only exist in no-commit mode,
            // where tickets never occur)
            for m in 0..nm {
                let fire = match self.pending[m].as_mut() {
                    Some(e) if !e.forwarded.iter().all(|&f| f) => {
                        e.wait += 1;
                        if e.wait < reqt {
                            0
                        } else if e.forwarded.iter().all(|&f| !f) {
                            1
                        } else {
                            2
                        }
                    }
                    _ => 0,
                };
                match fire {
                    1 => self.retire_pending_decerr(m),
                    2 => self.evict_unforwarded_legs(m),
                    _ => {}
                }
            }
            // (b) front ARs: a read stuck at the head of its master
            // port (slave AR backpressure, or starvation under pure
            // static priority) retires as a DECERR R burst
            for m in 0..nm {
                let front = pool[self.m_links[m]].ar.front().map(|ar| ar.txn);
                self.ar_front_wait[m] = match (front, self.ar_front_wait[m]) {
                    (None, _) => None,
                    (Some(txn), Some((prev, w))) if prev == txn => {
                        if w + 1 >= reqt {
                            let ar = pool[self.m_links[m]].ar.pop().unwrap();
                            self.stats.req_timeouts += 1;
                            self.stats.decerr += 1;
                            self.err_r
                                .push_back((m, ar.id, ar.txn, ar.beats, Resp::DecErr));
                            None
                        } else {
                            Some((txn, w + 1))
                        }
                    }
                    (Some(txn), _) => Some((txn, 1)),
                };
            }
        }
        let Some(cplt) = self.cfg.cpl_timeout else {
            return;
        };
        // (c) collecting reduction groups: tick while at least one
        // expected contributor has not even arrived; at the deadline
        // the missing contributors are evicted — the group closes over
        // the ones present and the fanned-back B is error-poisoned
        for e in self.red_entries.iter_mut() {
            if e.state == RedState::Collecting
                && !e.waiters.is_empty()
                && (e.waiters.len() as u32) < e.expected
            {
                e.wait += 1;
                if e.wait >= cplt {
                    self.stats.red_evictions += (e.expected - e.waiters.len() as u32) as u64;
                    self.stats.cpl_timeouts += 1;
                    e.expected = e.waiters.len() as u32;
                    e.poisoned = true;
                    e.wait = 0;
                    if e.arrived == e.expected {
                        e.state = RedState::Ready;
                    }
                }
            }
        }
        // (d) granted legs: the shared completion counter
        if self.cpl_legs.is_empty() {
            self.cpl_age = 0;
            return;
        }
        self.cpl_age += 1;
        if self.cpl_age < cplt {
            return;
        }
        self.cpl_age = 0;
        let idx = self.cpl_legs.iter().position(|l| {
            l.read || l.wlast_sent || {
                let link = &pool[self.s_links[l.slave]];
                link.w.visible() > 0 || link.aw.visible() > 0
            }
        });
        // no leg provably owes a response yet (everything still in
        // flight through the fabric): re-arm and keep waiting
        if let Some(i) = idx {
            let leg = self.cpl_legs.remove(i).unwrap();
            self.fire_cpl(leg);
        }
    }

    /// Completion-timeout synthesis for one scoreboard leg (cold path).
    /// The slave is presumed dead: the master's side of the transaction
    /// completes with SLVERR, the leg's residual fabric state (mux
    /// W-order entry, demux W route, reduction entry) unwinds, and the
    /// transaction is zombie-marked so a late real response from the
    /// slave is dropped instead of corrupting a completed join.
    fn fire_cpl(&mut self, leg: CplLeg) {
        self.stats.cpl_timeouts += 1;
        let CplLeg {
            slave: s,
            master: m,
            txn,
            id,
            read,
            beats_left,
            wlast_sent,
        } = leg;
        self.zombie.insert((s, txn));
        if read {
            // the synthesized burst carries exactly the undelivered
            // remainder — DMA engines drain by beat count
            self.rd_owner.remove(txn);
            self.err_r.push_back((m, id, txn, beats_left, Resp::SlvErr));
            return;
        }
        if m == RED_MASTER {
            // the *combined* reduction burst timed out at its exit:
            // fan the synthesized SLVERR back to every contributor
            if let Some(i) = self.red_entries.iter().position(|e| {
                e.up_txn == txn
                    && matches!(e.state, RedState::Streaming { .. } | RedState::AwaitB)
            }) {
                let e = self.red_entries.remove(i);
                if let RedState::Streaming { left } = e.state {
                    self.mux[s].evict_w_order(RED_MASTER, txn);
                    self.stats.w_dropped += left as u64;
                }
                for (wm, wid, wtxn) in e.waiters {
                    let joined = self.demux[wm]
                        .join_b(wtxn, Resp::SlvErr, wid)
                        .expect("sink join must complete on the synthesized B");
                    self.stats.b_joined += 1;
                    self.demux[wm].b_out.push_back(joined);
                    self.note_b_out(wm);
                }
            }
            return;
        }
        // a forwarded write leg: fold SLVERR into its fork join — the
        // timed-out leg still participates, so healthy sibling legs
        // complete the multicast normally
        if !wlast_sent {
            self.mux[s].evict_w_order(m, txn);
        }
        self.demux[m].evict_route_slave(txn, s);
        if self.demux[m].joins.contains_key(&txn) {
            if let Some(joined) = self.demux[m].join_b(txn, Resp::SlvErr, id) {
                self.wr_owner.remove(txn);
                self.stats.b_joined += 1;
                self.demux[m].b_out.push_back(joined);
                self.note_b_out(m);
            }
        } else {
            // no-commit mode forks leg-by-leg, so the join does not
            // exist until the whole fork is accepted: unwind the leg
            // inside the still-pending entry instead
            self.evict_pending_leg(m, s, txn);
        }
    }

    /// Request-timeout retire (cold path): the pending AW at master `m`
    /// could not fork a single leg within `req_timeout`. Accept it with
    /// an empty target set — its W beats then drain through the
    /// unroutable path and the master receives a DECERR B — and release
    /// the reservation claims of its never-forwarded subtree so the
    /// fabric-wide claim queues advance. Stale mux grants need no
    /// manual clearing: both grant modes re-arbitrate every cycle.
    fn retire_pending_decerr(&mut self, m: usize) {
        let entry = self.pending[m].take().unwrap();
        self.note_pending(m, false);
        if entry.pend.beat.is_mcast {
            self.n_pending_mcast -= 1;
        }
        if let Some(seq) = entry.pend.beat.ticket {
            let (h, node) = self.resv.clone().expect("ticketed beat without a ledger");
            h.lock().unwrap().release_subtree(
                node,
                seq,
                &entry.pend.beat.dest,
                entry.pend.beat.exclude,
                entry.pend.beat.window,
            );
        }
        self.stats.req_timeouts += 1;
        self.stats.decerr += 1;
        self.demux[m].accept(&entry.pend.beat, &[], Resp::DecErr);
        self.note_w(m);
    }

    /// No-commit-mode leg eviction: remove slave `s` from master `m`'s
    /// still-pending fork and poison the eventual join resp. If the
    /// eviction empties the fork, the entry retires through
    /// `phase_commit`'s empty-target path next cycle.
    fn evict_pending_leg(&mut self, m: usize, s: usize, txn: Txn) {
        let Some(entry) = self.pending[m].as_mut() else {
            return;
        };
        if entry.pend.beat.txn != txn {
            return;
        }
        let keep: Vec<usize> = (0..entry.pend.targets.len())
            .filter(|&i| entry.pend.targets[i].slave != s)
            .collect();
        entry.pend.targets = keep
            .iter()
            .map(|&i| entry.pend.targets[i].clone())
            .collect();
        entry.forwarded = keep.iter().map(|&i| entry.forwarded[i]).collect();
        entry.pend.resp0 = entry.pend.resp0.join(Resp::SlvErr);
        if entry.pend.targets.is_empty() {
            self.wr_owner.remove(txn);
        }
    }

    /// Request-deadline eviction for a partially-forwarded no-commit
    /// fork: the legs that never made it into their slave AW queues
    /// (typically wedged behind a dead slave's backed-up channel) are
    /// dropped from the fork with DECERR folded into the eventual join,
    /// so the forwarded legs can accept through `phase_commit`'s
    /// all-forwarded path. Partial forks only exist in no-commit mode,
    /// which never carries reservation tickets, so there is no subtree
    /// claim to release here.
    fn evict_unforwarded_legs(&mut self, m: usize) {
        let Some(entry) = self.pending[m].as_mut() else {
            return;
        };
        debug_assert!(entry.pend.beat.ticket.is_none());
        let keep: Vec<usize> = (0..entry.pend.targets.len())
            .filter(|&i| entry.forwarded[i])
            .collect();
        entry.pend.targets = keep
            .iter()
            .map(|&i| entry.pend.targets[i].clone())
            .collect();
        entry.forwarded = vec![true; keep.len()];
        entry.pend.resp0 = entry.pend.resp0.join(Resp::DecErr);
        entry.wait = 0;
        self.stats.req_timeouts += 1;
        self.stats.decerr += 1;
    }

    /// Phase 3 — AR arbitration and forwarding (reads are unicast).
    fn phase_ar(&mut self, pool: &mut LinkPool, in_ar: u64) {
        // decode every visible front AR once (into reusable scratch)
        let mut any = false;
        let nm = self.cfg.n_masters;
        self.for_each(in_ar, nm, pool, |xb, m, pool| {
            let dec = pool[xb.m_links[m]]
                .ar
                .front()
                .map(|ar| xb.cfg.route_unicast(ar.addr));
            xb.scratch_want[m] = match dec {
                Some(Some(s)) => {
                    any = true;
                    Some(s)
                }
                Some(None) => {
                    // unroutable read → DECERR R burst
                    let ar = pool[xb.m_links[m]].ar.pop().unwrap();
                    xb.stats.decerr += 1;
                    xb.err_r.push_back((m, ar.id, ar.txn, ar.beats, Resp::DecErr));
                    None
                }
                None => None,
            };
        });
        if any {
            let policy = self.cfg.arb_policy;
            for s in 0..self.cfg.n_slaves {
                if !pool[self.s_links[s]].ar.can_push() {
                    continue;
                }
                let want = &self.scratch_want;
                if let Some(m) = self.mux[s].pick_ar_scan(
                    self.cfg.n_masters,
                    policy,
                    &self.cfg.master_prio,
                    |m| want[m] == Some(s),
                ) {
                    let mut ar = pool[self.m_links[m]].ar.pop().unwrap();
                    ar.src = m;
                    self.rd_owner.insert(ar.txn, m);
                    if self.cfg.cpl_timeout.is_some() {
                        self.cpl_legs.push_back(CplLeg {
                            slave: s,
                            master: m,
                            txn: ar.txn,
                            id: ar.id,
                            read: true,
                            beats_left: ar.beats,
                            wlast_sent: false,
                        });
                    }
                    pool[self.s_links[s]].ar.push(ar);
                    self.stats.ar_forwarded += 1;
                    if matches!(policy, ArbPolicy::Priority { .. }) {
                        self.stats.prio_grants += 1;
                    }
                    self.scratch_want[m] = None;
                }
            }
        }
        // restore the all-None scratch invariant over the touched set
        self.for_each(in_ar, nm, pool, |xb, m, _| xb.scratch_want[m] = None);
    }

    /// Phase 4 — AW acceptance + decode (fig. 2d ordering stalls).
    fn phase_aw_accept(&mut self, pool: &mut LinkPool, in_aw: u64) {
        let nm = self.cfg.n_masters;
        self.for_each(in_aw, nm, pool, |xb, m, pool| {
            if xb.pending[m].is_some() {
                return;
            }
            let Some(front) = pool[xb.m_links[m]].aw.front() else {
                return;
            };
            let (dest, exclude, window, txn, id, mcast_req) = (
                front.dest,
                front.exclude,
                front.window,
                front.txn,
                front.id,
                front.is_mcast,
            );
            // memoised decode: a stalled front AW is re-examined every
            // cycle but decoded only once
            let hit = xb.dec_cache[m].as_ref().is_some_and(|c| c.txn == txn);
            if !hit {
                let (targets, resp0) = xb.cfg.decode_aw(&dest, exclude, window);
                xb.dec_cache[m] = Some(DecCache {
                    txn,
                    targets,
                    resp0,
                });
            }
            let cache = xb.dec_cache[m].as_ref().unwrap();
            let slaves: SlaveVec = cache.targets.iter().map(|t| t.slave).collect();
            let is_mcast = mcast_req && slaves.len() != 1;
            match xb.demux[m].admit(is_mcast, id, &slaves) {
                Stall::None => {}
                Stall::IdConflict => {
                    xb.stats.stall_id_conflict += 1;
                    return;
                }
                Stall::McastAfterUnicast
                | Stall::UnicastAfterMcast
                | Stall::McastSetMismatch
                | Stall::McastLimit => {
                    xb.stats.stall_mcast_order += 1;
                    return;
                }
                _ => return,
            }
            let mut beat = pool[xb.m_links[m]].aw.pop().unwrap();
            beat.src = m;
            beat.is_mcast = is_mcast;
            if is_mcast {
                xb.stats.aw_mcast += 1;
            } else {
                xb.stats.aw_unicast += 1;
            }
            let cache = xb.dec_cache[m].take().unwrap();
            // In-network reduction: a tagged contribution arriving at
            // one of its group's join points is absorbed into the
            // combine table instead of being forwarded — its W beats
            // drain through a sink route and ONE combined burst leaves
            // upstream once every expected contributor arrived
            // (`phase_reduce`). Non-join-point nodes fall through to
            // the plain unicast datapath, tag preserved.
            if let Some(tag) = beat.reduce {
                if let Some(plan) = xb.red_plan(tag.group) {
                    debug_assert!(
                        beat.dest.is_singleton(),
                        "reduction contributions are unicast"
                    );
                    debug_assert_eq!(
                        cache.targets.first().map(|t| t.slave),
                        Some(plan.exit_slave),
                        "membership oracle and datapath decode disagree"
                    );
                    xb.demux[m].accept_sink(&beat, plan.exit_slave);
                    xb.note_w(m);
                    xb.red_contribution(m, &beat, plan, tag);
                    return;
                }
            }
            // Fabric-wide reservation acquire (e2e ordering): the entry
            // crossbar — the first to see the multicast, before any leg
            // carries a ticket — claims every node of the fork tree and
            // stamps the globally ordered ticket onto the beat. Demoted
            // single-target requests still reserve: the set can fan out
            // again downstream. Unroutable requests stay unticketed
            // (their DECERR acceptance never forks anywhere).
            if xb.e2e()
                && beat.ticket.is_none()
                && mcast_req
                && dest.count() > 1
                && !cache.targets.is_empty()
            {
                let (h, node) = xb.resv.clone().unwrap();
                beat.ticket = Some(h.lock().unwrap().reserve(node, &dest, exclude, window));
                xb.stats.resv_tickets += 1;
            }
            if cache.resp0 == Resp::DecErr && cache.targets.is_empty() {
                xb.stats.decerr += 1;
            }
            let n_targets = cache.targets.len();
            xb.pending[m] = Some(PendingEntry {
                pend: PendingAw {
                    beat,
                    targets: cache.targets,
                    resp0: cache.resp0,
                },
                forwarded: InlineVec::from_elem(false, n_targets),
                age: 0,
                wait: 0,
            });
            xb.note_pending(m, true);
            if is_mcast {
                xb.n_pending_mcast += 1;
            }
        });
    }

    /// Does master `m` have an unforwarded multicast leg for slave `s`?
    #[inline]
    fn wants_mcast(&self, m: usize, s: usize) -> bool {
        self.pending[m]
            .as_ref()
            .map(|p| {
                p.pend.beat.is_mcast
                    && p.pend
                        .targets
                        .iter()
                        .zip(p.forwarded.iter())
                        .any(|(t, f)| t.slave == s && !f)
            })
            .unwrap_or(false)
    }

    /// Phase 5 — per-slave multicast grant (priority encoder).
    fn phase_grant(&mut self) {
        // hot path: no pending multicast anywhere → clear grants cheaply
        // (with worklists the check is O(1) and the clear runs once)
        let any_mcast = if self.use_masks {
            self.n_pending_mcast > 0
        } else {
            self.pending
                .iter()
                .any(|p| p.as_ref().map(|p| p.pend.beat.is_mcast).unwrap_or(false))
        };
        if !any_mcast {
            if self.grants_live || !self.use_masks {
                for s in 0..self.cfg.n_slaves {
                    self.mux[s].grant = None;
                }
                self.grants_live = false;
            }
            return;
        }
        self.grants_live = true;
        if self.e2e() {
            // Fabric-ordered arbitration (two-phase reservation): only
            // the ticket at the front of this node's claim queue may
            // hold muxes; every other requester *backs off* (releases
            // its tentatively acquired legs on this re-arbitration)
            // until its fabric-wide turn. Tickets are unique, so at
            // most one pending per node is front — this is the lzc
            // encoder degenerated to the global reservation order. A
            // non-front multicast holding grants would block the
            // unicast datapath (`Mux::mcast_active`) that the front
            // ticket's single-target legs ride, recreating exactly the
            // cross-path cycle end-to-end ordering exists to break.
            // One shared scan for both the optimised and `force_naive`
            // modes keeps the parity suite trivially bit-identical.
            // Tickets are unique, so the front holder is found once
            // (one ledger probe per pending master, not per (s, m)
            // pair) and then handed every mux it requests.
            let front_m = (0..self.cfg.n_masters).find(|&m| {
                let ticket = self.pending[m].as_ref().and_then(|p| p.pend.beat.ticket);
                ticket.is_some() && self.resv_front(ticket)
            });
            for s in 0..self.cfg.n_slaves {
                let grant = front_m.filter(|&m| self.wants_mcast(m, s));
                self.mux[s].grant = grant;
                if grant.is_some() {
                    self.mux[s].grant_wait_cycles += 1;
                }
            }
            return;
        }
        if self.cfg.commit_protocol && self.cfg.n_slaves <= 64 {
            // bitmask fast path: one unforwarded-target mask per master,
            // then per-slave priority encode over single bits (O(N²)
            // bit tests instead of O(N²·targets) scans)
            let mut masks = [0u64; 64];
            let nm = self.cfg.n_masters.min(64);
            for (m, mask) in masks.iter_mut().enumerate().take(nm) {
                if let Some(p) = &self.pending[m] {
                    if p.pend.beat.is_mcast {
                        for (t, f) in p.pend.targets.iter().zip(p.forwarded.iter()) {
                            if !f {
                                *mask |= 1u64 << t.slave;
                            }
                        }
                    }
                }
            }
            let prio = matches!(self.cfg.arb_policy, ArbPolicy::Priority { .. });
            for s in 0..self.cfg.n_slaves {
                // static priority reorders the encoder but stays
                // consistent across muxes (a global key), preserving
                // the commit protocol's deadlock freedom; plain lzc
                // otherwise (bit-identical default)
                let grant = if prio {
                    (0..nm)
                        .filter(|&m| masks[m] >> s & 1 == 1)
                        .min_by_key(|&m| (std::cmp::Reverse(self.master_prio_of(m)), m))
                } else {
                    (0..nm).find(|&m| masks[m] >> s & 1 == 1)
                };
                self.mux[s].grant = grant;
                if grant.is_some() {
                    self.mux[s].grant_wait_cycles += 1;
                }
            }
            return;
        }
        for s in 0..self.cfg.n_slaves {
            if self.cfg.commit_protocol {
                // lzc: lowest-index requesting master (static priority
                // first under `ArbPolicy::Priority`), allocation-free
                let grant = if matches!(self.cfg.arb_policy, ArbPolicy::Priority { .. }) {
                    (0..self.cfg.n_masters)
                        .filter(|&m| self.wants_mcast(m, s))
                        .min_by_key(|&m| (std::cmp::Reverse(self.master_prio_of(m)), m))
                } else {
                    (0..self.cfg.n_masters).find(|&m| self.wants_mcast(m, s))
                };
                self.mux[s].grant = grant;
                if grant.is_some() {
                    self.mux[s].grant_wait_cycles += 1;
                }
            } else {
                let requesters: InlineVec<usize, FORK_INLINE> = (0..self.cfg.n_masters)
                    .filter(|&m| self.wants_mcast(m, s))
                    .collect();
                self.mux[s].arbitrate_mcast_rr(&requesters, self.cfg.n_masters);
            }
        }
    }

    /// Fork one target of a pending AW onto its slave link.
    fn forward_target(
        wr_owner: &mut TxnTable,
        stats: &mut XbarStats,
        mux: &mut Mux,
        link: &mut AxiLink,
        beat: &AwBeat,
        target: &TargetAw,
        m: usize,
    ) {
        let fwd = AwBeat {
            id: beat.id,
            dest: target.dest,
            beats: beat.beats,
            beat_bytes: beat.beat_bytes,
            is_mcast: target.dest.count() > 1
                || target.exclude.is_some()
                || target.window.is_some(),
            exclude: target.exclude,
            window: target.window,
            src: m,
            txn: beat.txn,
            // the reservation ticket rides every forked leg, so each
            // downstream crossbar gates on the same fabric-wide order
            ticket: beat.ticket,
            // a pass-through reduction contribution keeps its tag so
            // join points further up still combine it
            reduce: beat.reduce,
        };
        link.aw.push(fwd);
        mux.push_w_order(m, beat.txn);
        wr_owner.insert(beat.txn, m);
        stats.aw_forks += 1;
    }

    /// Phase 6 — multicast commit (or per-slave forward when the commit
    /// protocol is disabled, reproducing fig. 2e).
    fn phase_commit(&mut self, pool: &mut LinkPool) {
        if self.use_masks && self.n_pending_mcast == 0 {
            return;
        }
        let nm = self.cfg.n_masters;
        let snapshot = self.mask_pending;
        self.for_each(snapshot, nm, pool, |xb, m, pool| {
            let (ticket, aged) = match xb.pending[m].as_mut() {
                Some(e) if e.pend.beat.is_mcast => {
                    e.age += 1;
                    (e.pend.beat.ticket, e.age > xb.cfg.mcast_commit_lat)
                }
                _ => return,
            };
            // e2e ordering: one reservation wait per cycle while this
            // node's claim front belongs to an older ticket (the
            // predicate `Xbar::skip` replays over bulk-advanced spans)
            let front = xb.resv_front(ticket);
            if ticket.is_some() && !front {
                xb.stats.resv_waits += 1;
            }
            if !aged {
                xb.stats.commit_waits += 1;
                return;
            }
            let entry = xb.pending[m].as_ref().unwrap();
            if entry.pend.targets.is_empty() {
                if !front {
                    // a ticketed leg that decodes to nothing here still
                    // takes its fabric-wide turn before the DECERR
                    // acceptance retires its claim
                    xb.stats.commit_waits += 1;
                    return;
                }
                // unroutable mcast: accept so W drains, B = DECERR
                let entry = xb.pending[m].take().unwrap();
                xb.note_pending(m, false);
                xb.n_pending_mcast -= 1;
                xb.demux[m].accept(&entry.pend.beat, &entry.pend.targets, entry.pend.resp0);
                xb.note_w(m);
                xb.resv_commit(ticket);
                return;
            }
            if xb.cfg.commit_protocol {
                // all-or-nothing: every target granted to m and pushable
                // — and, under e2e ordering, the fabric-wide claim front
                // held (commit only fires once every transitive leg of
                // the fork tree is this ticket's to take)
                let all_ready = front
                    && entry.pend.targets.iter().all(|t| {
                        xb.mux[t.slave].grant == Some(m) && pool[xb.s_links[t.slave]].aw.can_push()
                    });
                if !all_ready {
                    xb.stats.commit_waits += 1;
                    return;
                }
                let entry = xb.pending[m].take().unwrap();
                xb.note_pending(m, false);
                xb.n_pending_mcast -= 1;
                for t in entry.pend.targets.iter() {
                    Self::forward_target(
                        &mut xb.wr_owner,
                        &mut xb.stats,
                        &mut xb.mux[t.slave],
                        &mut pool[xb.s_links[t.slave]],
                        &entry.pend.beat,
                        t,
                        m,
                    );
                    xb.cpl_track_write(t.slave, m, entry.pend.beat.txn, entry.pend.beat.id);
                    xb.mux[t.slave].grant = None;
                }
                if matches!(xb.cfg.arb_policy, ArbPolicy::Priority { .. }) {
                    xb.stats.prio_grants += 1;
                }
                xb.demux[m].accept(&entry.pend.beat, &entry.pend.targets, entry.pend.resp0);
                xb.note_w(m);
                xb.resv_commit(ticket);
            } else {
                // NO deadlock avoidance: fork each leg as it is granted
                let entry = xb.pending[m].as_mut().unwrap();
                let n = entry.pend.targets.len();
                for i in 0..n {
                    if entry.forwarded[i] {
                        continue;
                    }
                    let t = entry.pend.targets[i].clone();
                    if xb.mux[t.slave].grant == Some(m)
                        && pool[xb.s_links[t.slave]].aw.can_push()
                    {
                        Self::forward_target(
                            &mut xb.wr_owner,
                            &mut xb.stats,
                            &mut xb.mux[t.slave],
                            &mut pool[xb.s_links[t.slave]],
                            &entry.pend.beat,
                            &t,
                            m,
                        );
                        if xb.cfg.cpl_timeout.is_some() {
                            xb.cpl_legs.push_back(CplLeg {
                                slave: t.slave,
                                master: m,
                                txn: entry.pend.beat.txn,
                                id: entry.pend.beat.id,
                                read: false,
                                beats_left: 0,
                                wlast_sent: false,
                            });
                        }
                        entry.forwarded[i] = true;
                        xb.mux[t.slave].grant = None;
                    }
                }
                if entry.forwarded.iter().all(|&f| f) {
                    let entry = xb.pending[m].take().unwrap();
                    xb.note_pending(m, false);
                    xb.n_pending_mcast -= 1;
                    xb.demux[m].accept(&entry.pend.beat, &entry.pend.targets, entry.pend.resp0);
                    xb.note_w(m);
                }
            }
        });
    }

    /// Phase 7 — unicast AW forwarding (round-robin; multicast priority
    /// stalls unicast issue on a slave with a live grant).
    fn phase_unicast_aw(&mut self, pool: &mut LinkPool) {
        if self.use_masks && self.mask_pending == 0 {
            return;
        }
        // masters with a pending unicast AW and its (single) target
        let mut any = false;
        let nm = self.cfg.n_masters;
        let snapshot = self.mask_pending;
        self.for_each(snapshot, nm, pool, |xb, m, _pool| {
            let (want, ticket, unroutable) = match xb.pending[m].as_ref() {
                Some(p) if !p.pend.beat.is_mcast => (
                    p.pend.targets.first().map(|t| t.slave),
                    p.pend.beat.ticket,
                    p.pend.targets.is_empty(),
                ),
                _ => (None, None, false),
            };
            // e2e ordering: a ticketed leg that degenerated to a single
            // target at this hop still rides the unicast datapath, but
            // must wait for its fabric-wide turn like any other claim —
            // otherwise two multicasts could enqueue in opposite orders
            // at a pass-through crossbar and rebuild the W-order cycle.
            let front = xb.resv_front(ticket);
            if ticket.is_some() && !front {
                xb.stats.resv_waits += 1;
            }
            xb.scratch_want[m] = if front { want } else { None };
            any |= xb.scratch_want[m].is_some();
            // unroutable unicast: accept immediately (W drains, DECERR
            // B), once any fabric-wide claim turn has come up
            if unroutable && front {
                let entry = xb.pending[m].take().unwrap();
                xb.note_pending(m, false);
                xb.demux[m].accept(&entry.pend.beat, &entry.pend.targets, entry.pend.resp0);
                xb.note_w(m);
                xb.resv_commit(ticket);
                xb.scratch_want[m] = None;
            }
        });
        if any {
            let policy = self.cfg.arb_policy;
            for s in 0..self.cfg.n_slaves {
                if self.mux[s].mcast_active() || !pool[self.s_links[s]].aw.can_push() {
                    continue;
                }
                let want = &self.scratch_want;
                if let Some(m) = self.mux[s].pick_aw_scan(
                    self.cfg.n_masters,
                    policy,
                    &self.cfg.master_prio,
                    |m| want[m] == Some(s),
                ) {
                    let entry = self.pending[m].take().unwrap();
                    self.note_pending(m, false);
                    let t = entry.pend.targets[0].clone();
                    Self::forward_target(
                        &mut self.wr_owner,
                        &mut self.stats,
                        &mut self.mux[s],
                        &mut pool[self.s_links[s]],
                        &entry.pend.beat,
                        &t,
                        m,
                    );
                    self.cpl_track_write(s, m, entry.pend.beat.txn, entry.pend.beat.id);
                    if matches!(policy, ArbPolicy::Priority { .. }) {
                        self.stats.prio_grants += 1;
                    }
                    self.demux[m].accept(&entry.pend.beat, &entry.pend.targets, entry.pend.resp0);
                    self.note_w(m);
                    self.resv_commit(entry.pend.beat.ticket);
                    self.scratch_want[m] = None;
                }
            }
        }
        // restore the all-None scratch invariant over the touched set
        self.for_each(snapshot, nm, pool, |xb, m, _| xb.scratch_want[m] = None);
    }

    /// Phase 8 — W transport with all-ready multicast fork.
    fn phase_w(&mut self, pool: &mut LinkPool) {
        let nm = self.cfg.n_masters;
        self.for_each(self.mask_w, nm, pool, |xb, m, pool| xb.w_master(m, pool));
    }

    /// Per-master W transport (one call per active master per cycle).
    fn w_master(&mut self, m: usize, pool: &mut LinkPool) {
        if self.w_cooldown[m] > 0 {
            self.w_cooldown[m] -= 1;
            return;
        }
        let Some(route) = self.demux[m].w_queue.front() else {
            // lazy worklist clear: no route and no cooldown left
            if m < 64 {
                self.mask_w &= !(1u64 << m);
            }
            return;
        };
        let txn = route.txn;
        let beats_left = route.beats_left;
        let is_mcast = route.is_mcast;
        if route.slaves.is_empty() {
            // drain W of an unroutable transaction, absorb a reduction
            // contribution into the combine table (sink), or drop the
            // remaining beats of a fully-evicted route (its SLVERR B
            // was already synthesized when the legs timed out)
            let sink = route.sink;
            let evicted = route.evicted;
            if beats_left == 0 || pool[self.m_links[m]].w.pop().is_some() {
                if sink && beats_left > 0 {
                    // an absorbed beat enters the fabric but never
                    // leaves it — the join accounting's "in" side
                    self.stats.w_beats_in += 1;
                }
                if evicted && beats_left > 0 {
                    // the beat entered the crossbar but every leg is
                    // gone — count both sides so the fork/join balance
                    // (`w_beats_out == w_beats_in + w_fork_extra −
                    // red_beats_saved − w_dropped`) stays exact
                    self.stats.w_beats_in += 1;
                    self.stats.w_dropped += 1;
                }
                let r = self.demux[m].w_queue.front_mut().unwrap();
                r.beats_left = r.beats_left.saturating_sub(1);
                if r.beats_left == 0 {
                    self.demux[m].w_queue.pop_front();
                    if sink {
                        self.red_w_drained(txn);
                    } else if !evicted {
                        let b = self.demux[m].complete_unroutable(txn);
                        self.demux[m].b_out.push_back(b);
                        self.note_b_out(m);
                    }
                }
            }
            return;
        }
        if pool[self.m_links[m]].w.front().is_none() {
            return;
        }
        // inline copy of the route's slave set (memcpy up to
        // FORK_INLINE entries — replaces the old per-cycle Vec clone,
        // and only runs when a W beat is actually present)
        let slaves: SlaveVec = self.demux[m].w_queue.front().unwrap().slaves.clone();
        // all-ready fork condition (green logic in fig. 2d): every
        // destination must be at the front of its mux W order AND
        // have channel space.
        let all_ready = slaves
            .iter()
            .all(|&s| self.mux[s].w_front_is(m, txn) && pool[self.s_links[s]].w.can_push());
        if !all_ready {
            if is_mcast {
                self.stats.w_fork_stalls += 1;
            }
            return;
        }
        pool[self.m_links[m]].w.pop();
        self.stats.w_beats_in += 1;
        self.stats.w_fork_extra += slaves.len() as u64 - 1;
        let last = beats_left == 1;
        for &s in slaves.iter() {
            pool[self.s_links[s]].w.push(WBeat { last, src: m, txn });
            self.stats.w_beats_out += 1;
            if last {
                self.mux[s].pop_w_order(m, txn);
                // the slave now owes a B: its scoreboard leg becomes
                // unconditionally eligible for the completion deadline
                if self.cfg.cpl_timeout.is_some() {
                    if let Some(l) = self
                        .cpl_legs
                        .iter_mut()
                        .find(|l| l.slave == s && l.txn == txn && !l.read)
                    {
                        l.wlast_sent = true;
                    }
                }
            }
        }
        let r = self.demux[m].w_queue.front_mut().unwrap();
        r.beats_left -= 1;
        if last {
            self.demux[m].w_queue.pop_front();
        }
        // registered all-ready fork: a >1-way fork cannot re-fire
        // the cycle after a beat (stale ready) — see XbarCfg docs
        if slaves.len() > 1 {
            self.w_cooldown[m] = self.cfg.mcast_w_cooldown;
        }
    }

    /// Register one absorbed contribution with the combine table
    /// (in-network reduction): the entry for `(group, burst address)`
    /// is created lazily on the first arrival and completed when
    /// `expected` contributor bursts have fully drained.
    fn red_contribution(&mut self, m: usize, beat: &AwBeat, plan: NodePlan, tag: RedTag) {
        // only a live, un-poisoned collecting entry may absorb more
        // contributions: a late arrival racing a timeout eviction (or a
        // new round reusing the address) opens a fresh entry instead,
        // which the eviction deadline will close out on its own if the
        // rest of its round never shows up
        let idx = self.red_entries.iter().position(|e| {
            e.group == tag.group
                && e.addr == beat.dest.addr
                && e.state == RedState::Collecting
                && !e.poisoned
        });
        let idx = match idx {
            Some(i) => i,
            None => {
                self.red_entries.push(CombineEntry {
                    group: tag.group,
                    addr: beat.dest.addr,
                    beats: beat.beats,
                    beat_bytes: beat.beat_bytes,
                    exit_slave: plan.exit_slave,
                    expected: plan.expected,
                    arrived: 0,
                    waiters: Vec::new(),
                    state: RedState::Collecting,
                    up_txn: beat.txn,
                    id: beat.id,
                    tag,
                    wait: 0,
                    poisoned: false,
                });
                self.red_entries.len() - 1
            }
        };
        let e = &mut self.red_entries[idx];
        // a new contribution is progress — the eviction deadline restarts
        e.wait = 0;
        assert_eq!(
            e.beats, beat.beats,
            "{}: reduction group {} contributions disagree on the burst split",
            self.cfg.name, tag.group
        );
        e.waiters.push((m, beat.id, beat.txn));
        assert!(
            e.waiters.len() as u32 <= e.expected,
            "{}: reduction group {} received more contributions than the \
             membership oracle planned",
            self.cfg.name,
            tag.group
        );
    }

    /// A sink route finished draining: mark its contribution arrived;
    /// the last arrival makes the entry ready to issue upstream.
    fn red_w_drained(&mut self, txn: Txn) {
        let e = self
            .red_entries
            .iter_mut()
            .find(|e| e.waiters.iter().any(|&(_, _, t)| t == txn))
            .expect("sink drain without a combine entry");
        e.arrived += 1;
        if e.arrived == e.expected {
            e.state = RedState::Ready;
        }
    }

    /// Phase 9 — in-network reduction: issue the combined burst of
    /// every fully-arrived combine entry and stream its W beats toward
    /// the destination. Combining never *holds* anything: the exit
    /// mux's W-order queue is entered only at issue time, when the
    /// burst's data source (this node) is unconditionally ready, so no
    /// new waits-for edges beyond those of an ordinary unicast write
    /// exist (DESIGN.md §7 deadlock argument).
    // (indexing loop: the body splits borrows across self.mux /
    // self.stats / pool, which `iter_mut` cannot express)
    #[allow(clippy::needless_range_loop)]
    fn phase_reduce(&mut self, pool: &mut LinkPool) {
        if self.red_entries.is_empty() {
            return;
        }
        for i in 0..self.red_entries.len() {
            let e = &self.red_entries[i];
            let (exit, up_txn) = (e.exit_slave, e.up_txn);
            match e.state {
                RedState::Ready => {
                    if pool[self.s_links[exit]].aw.can_push() {
                        let e = &self.red_entries[i];
                        pool[self.s_links[exit]].aw.push(AwBeat {
                            id: e.id,
                            dest: AddrSet::unicast(e.addr),
                            beats: e.beats,
                            beat_bytes: e.beat_bytes,
                            is_mcast: false,
                            exclude: None,
                            window: None,
                            src: RED_MASTER,
                            txn: up_txn,
                            ticket: None,
                            // the tag rides on: join points further up
                            // combine this burst with other branches
                            reduce: Some(e.tag),
                        });
                        self.mux[exit].push_w_order(RED_MASTER, up_txn);
                        self.stats.red_joins += 1;
                        self.stats.red_beats_saved +=
                            (e.expected as u64 - 1) * e.beats as u64;
                        let (beats, id) = (e.beats, e.id);
                        if self.cfg.cpl_timeout.is_some() {
                            self.cpl_legs.push_back(CplLeg {
                                slave: exit,
                                master: RED_MASTER,
                                txn: up_txn,
                                id,
                                read: false,
                                beats_left: 0,
                                wlast_sent: false,
                            });
                        }
                        self.red_entries[i].state = RedState::Streaming { left: beats };
                    }
                }
                RedState::Streaming { left } => {
                    if self.mux[exit].w_front_is(RED_MASTER, up_txn)
                        && pool[self.s_links[exit]].w.can_push()
                    {
                        let last = left == 1;
                        pool[self.s_links[exit]].w.push(WBeat {
                            last,
                            src: RED_MASTER,
                            txn: up_txn,
                        });
                        // the combined burst's beats are the join
                        // accounting's "out" side
                        self.stats.w_beats_out += 1;
                        if last {
                            self.mux[exit].pop_w_order(RED_MASTER, up_txn);
                            if self.cfg.cpl_timeout.is_some() {
                                if let Some(l) = self
                                    .cpl_legs
                                    .iter_mut()
                                    .find(|l| l.slave == exit && l.txn == up_txn && !l.read)
                                {
                                    l.wlast_sent = true;
                                }
                            }
                            self.red_entries[i].state = RedState::AwaitB;
                        } else {
                            self.red_entries[i].state = RedState::Streaming { left: left - 1 };
                        }
                    }
                }
                RedState::Collecting | RedState::AwaitB => {}
            }
        }
    }

    /// Watchdog post-mortem: combine-table joins still open.
    pub fn open_reductions(&self) -> usize {
        self.red_entries.len()
    }

    /// Watchdog post-mortem: completion-scoreboard legs still awaiting
    /// a B/R (non-empty only with `cpl_timeout` armed).
    pub fn open_cpl_legs(&self) -> usize {
        self.cpl_legs.len()
    }

    /// Watchdog post-mortem: timed-out transactions whose late beats
    /// are still being dropped.
    pub fn zombie_count(&self) -> usize {
        self.zombie.len()
    }

    /// Any write/read activity still in flight inside the xbar?
    pub fn busy(&self) -> bool {
        self.pending.iter().any(Option::is_some)
            || self.demux.iter().any(|d| d.busy() || !d.b_out.is_empty())
            || !self.wr_owner.is_empty()
            || !self.rd_owner.is_empty()
            || !self.err_r.is_empty()
            || !self.red_entries.is_empty()
    }

    /// Event horizon (§Perf): the earliest cycle ≥ `now` at which
    /// stepping this crossbar can do anything beyond the bulk timer
    /// advancement applied by [`Xbar::skip`]. `None` means the xbar is
    /// idle or waiting purely on port activity.
    ///
    /// Precondition: all pool links are idle (the SoC only consults the
    /// horizon when the scheduler reports no active links), so every
    /// channel's `can_push` holds and no beat is consumable.
    pub fn next_event(&self, now: Cycle) -> Option<Cycle> {
        if !self.maybe_busy {
            return None;
        }
        let mut ev: Option<Cycle> = None;
        let mut fold = |e: Cycle| crate::sim::sched::fold_min(&mut ev, e);
        if !self.err_r.is_empty() {
            fold(now);
        }
        // timeout deadlines: the step that ticks a counter past its
        // threshold is an action — predict it exactly (the shared
        // completion counter and each ticking reduction entry; the
        // request deadline folds inside the pending loop below, and the
        // AR tracker needs no fold — links idle ⇒ no visible front AR)
        if let Some(cplt) = self.cfg.cpl_timeout {
            if !self.cpl_legs.is_empty() {
                fold(now + cplt.saturating_sub(self.cpl_age + 1) as u64);
            }
            for e in self.red_entries.iter() {
                if e.state == RedState::Collecting
                    && !e.waiters.is_empty()
                    && (e.waiters.len() as u32) < e.expected
                {
                    fold(now + cplt.saturating_sub(e.wait + 1) as u64);
                }
            }
        }
        // a ready or streaming combine entry acts on the next step
        // (links idle ⇒ its exit channels are pushable); collecting /
        // await-B entries move only on port activity
        if self
            .red_entries
            .iter()
            .any(|e| matches!(e.state, RedState::Ready | RedState::Streaming { .. }))
        {
            fold(now);
        }
        let lat = self.cfg.mcast_commit_lat;
        for m in 0..self.cfg.n_masters {
            if !self.demux[m].b_out.is_empty() {
                fold(now);
            }
            if self.w_cooldown[m] == 0 {
                if let Some(r) = self.demux[m].w_queue.front() {
                    if r.slaves.is_empty() && r.beats_left == 0 {
                        // unroutable drain completes without any beat
                        fold(now);
                    }
                    // otherwise W transport waits on master beats
                }
            }
            // (a live cooldown alone needs no wake: it only decays, and
            // the bulk advancement handles that)
            let Some(e) = &self.pending[m] else {
                continue;
            };
            // request deadline: a not-fully-forwarded pending fires
            // (whole-entry DECERR, or stuck-leg eviction for a partial
            // no-commit fork) on the step that ticks `wait` to the
            // threshold
            if let Some(reqt) = self.cfg.req_timeout {
                if !e.forwarded.iter().all(|&f| f) {
                    fold(now + reqt.saturating_sub(e.wait + 1) as u64);
                }
            }
            let front = self.resv_front(e.pend.beat.ticket);
            if !e.pend.beat.is_mcast {
                // a unicast pending forwards (or completes) on the next
                // step — unless e2e ordering holds its ticket behind an
                // older claim, where only another crossbar's commit
                // (that crossbar's own event) or port activity unblocks
                // it
                if front {
                    fold(now);
                }
            } else if e.age < lat {
                // pure commit-handshake aging; first actionable step is
                // the one entered with age == lat
                fold(now + (lat - e.age) as u64);
            } else if e.pend.targets.is_empty() {
                // aged unroutable mcast is accepted on the next step
                // (once its fabric-wide turn, if ticketed, has come up)
                if front {
                    fold(now);
                }
            } else if self.cfg.commit_protocol {
                if self.e2e() {
                    // front-only grants: the next step's grant phase
                    // hands the claim-front ticket every mux it wants
                    // (no competitor is eligible) and the commit fires
                    // right after (links idle ⇒ AW channels pushable),
                    // so `front` alone predicts the action; the muxes'
                    // current grants may be stale by one commit.
                    if front {
                        fold(now);
                    }
                } else if e.pend.targets.iter().all(|t| self.mux[t.slave].grant == Some(m)) {
                    // grants are stable between steps: commit fires iff
                    // every target mux is granted to m (links idle ⇒
                    // all AW channels pushable)
                    fold(now);
                }
                // else: unblocked only by this node's own front moving
                // (a commit here — its own event) or port activity
            } else {
                // no-commit mode forwards any granted unforwarded leg
                let can_fork = e
                    .pend
                    .targets
                    .iter()
                    .zip(e.forwarded.iter())
                    .any(|(t, &f)| !f && self.mux[t.slave].grant == Some(m));
                if can_fork {
                    fold(now);
                }
            }
        }
        ev
    }

    /// Bulk-advance `k` pure-wait cycles (§Perf event horizon): apply
    /// exactly the per-cycle timer decrements and wait-statistics that
    /// `k` consecutive no-op steps would have applied. Must only be
    /// called for spans `next_event` declared action-free, and only on
    /// crossbars the scheduler would actually have stepped
    /// (`maybe_busy` — a quiescent xbar's timers are frozen in the
    /// per-cycle mode too).
    pub fn skip(&mut self, k: u64) {
        if k == 0 || !self.maybe_busy {
            return;
        }
        for c in self.w_cooldown.iter_mut() {
            *c = (*c as u64).saturating_sub(k) as u32;
        }
        let lat = self.cfg.mcast_commit_lat as u64;
        let e2e = self.e2e();
        let resv = self.resv.clone();
        let mut resv_blocked = 0u64;
        let mut any_mcast = false;
        for p in self.pending.iter_mut().flatten() {
            // e2e ordering: a ticketed pending (multicast or a leg that
            // degenerated to the unicast datapath) blocked behind an
            // older claim counts one reservation wait per skipped cycle
            // — the ledger is frozen over an action-free span, so the
            // per-cycle predicate is stable and replayable
            if e2e {
                if let (Some((h, node)), Some(seq)) = (&resv, p.pend.beat.ticket) {
                    if !h.lock().unwrap().is_front(*node, seq) {
                        resv_blocked += 1;
                    }
                }
            }
            // request-deadline replay: a not-fully-forwarded pending
            // ticks `wait` every skipped cycle (the span ends before
            // the deadline — `next_event` folds it in)
            if self.cfg.req_timeout.is_some() && !p.forwarded.iter().all(|&f| f) {
                p.wait = (p.wait as u64 + k).min(u32::MAX as u64) as u32;
            }
            if !p.pend.beat.is_mcast {
                continue;
            }
            any_mcast = true;
            let a0 = p.age as u64;
            p.age = (a0 + k).min(u32::MAX as u64) as u32;
            // per skipped cycle the commit phase counts one wait: while
            // aging (age ≤ lat) in both modes, and additionally while
            // blocked on grants in the commit-protocol mode
            let waits = if self.cfg.commit_protocol {
                k
            } else {
                k.min(lat.saturating_sub(a0))
            };
            self.stats.commit_waits += waits;
        }
        self.stats.resv_waits += resv_blocked * k;
        if any_mcast {
            // the grant phase re-arbitrates to the same stable grants
            // each skipped cycle, counting one wait per granted mux
            for s in 0..self.cfg.n_slaves {
                if self.mux[s].grant.is_some() {
                    self.mux[s].grant_wait_cycles += k;
                }
            }
        }
        // completion-deadline replay (the span ends before either
        // deadline fires — `next_event` folds both in)
        if self.cfg.cpl_timeout.is_some() {
            if !self.cpl_legs.is_empty() {
                self.cpl_age = (self.cpl_age as u64 + k).min(u32::MAX as u64) as u32;
            }
            for e in self.red_entries.iter_mut() {
                if e.state == RedState::Collecting
                    && !e.waiters.is_empty()
                    && (e.waiters.len() as u32) < e.expected
                {
                    e.wait = (e.wait as u64 + k).min(u32::MAX as u64) as u32;
                }
            }
        }
    }
}

impl Component<AxiLink> for Xbar {
    fn step(&mut self, _cy: Cycle, pool: &mut LinkPool) {
        Xbar::step(self, pool);
    }

    /// Safe to skip when the last stepped cycle left nothing in flight;
    /// the scheduler re-wakes the xbar on port activity.
    fn quiescent(&self) -> bool {
        !self.maybe_busy
    }

    fn ports(&self) -> &[LinkId] {
        &self.ports
    }

    fn next_event(&self, now: Cycle) -> Option<Cycle> {
        Xbar::next_event(self, now)
    }
}

//! The N×M multicast-capable AXI crossbar (paper fig. 2a).
//!
//! Composition: one [`Demux`] per master port, one [`Mux`] per slave
//! port, wired through external [`AxiLink`]s held in a shared
//! [`LinkPool`] (the SoC or topology owns the pool; the xbar stores
//! typed [`LinkId`] handles). Each call to [`Xbar::step`] advances one
//! clock cycle through the phases:
//!
//! 1. **B join/drain** — collect B beats from slaves, fold into the
//!    per-demux joins, release merged responses to masters.
//! 2. **R/AR routing** — reads are unicast: round-robin AR arbitration
//!    per slave, R beats routed back by transaction tag.
//! 3. **AW accept** — pop+decode master AWs subject to the multicast
//!    ordering stalls (fig. 2d orange logic).
//! 4. **Grant** — per-slave priority-encoder (lzc) arbitration of
//!    multicast requesters; consistent cross-mux priority.
//! 5. **Commit** — a master holding grants on *all* addressed slaves
//!    (and space on all their AW channels) forks its AW atomically;
//!    with `commit_protocol = false` the fork happens per-slave as
//!    grants arrive, reproducing the fig. 2e deadlock.
//! 6. **Unicast AW forward** — round-robin, stalled while the mcast
//!    datapath holds a grant (multicast is prioritised).
//! 7. **W transport** — front-of-order W bursts move; a multicast W
//!    beat requires *all* destination channels ready (all-ready fork).
//!
//! ## Hierarchical multicast routing
//!
//! A request whose address set extends beyond this crossbar's local
//! rules is forwarded on the `default_slave` port carrying the original
//! set plus an **exclude scope** — the aligned region already served
//! locally. The next hop prunes rules inside the scope. This is the
//! model equivalent of the RTL's decomposition of the "rest of world"
//! route into log₂-many aligned mask-form rules; deliveries and beat
//! counts are identical (see DESIGN.md §2).
//!
//! ## End-to-end multicast ordering (`XbarCfg::e2e_mcast_order`)
//!
//! The per-crossbar commit protocol above cannot order commits *across*
//! crossbars: two concurrent global multicasts may enter the W-order
//! queues of different hierarchy levels in opposite orders and wedge on
//! the resulting inter-level cycle (the RTL's documented limitation).
//! With `e2e_mcast_order` the lock/commit machinery becomes one leg of
//! a fabric-wide two-phase reservation protocol ([`super::resv`]): the
//! entry crossbar stamps a globally ordered ticket onto the AW and
//! claims every node of the fork tree; grant arbitration admits only
//! the node's claim-front ticket (every later requester backs off
//! instead of holding muxes); and the commit in phase 6 additionally
//! requires that same front condition — conflicting multicasts then
//! commit in the same order at every crossbar they share, the waits-for
//! relation only points from younger to older tickets, and concurrent
//! global multicasts drain deadlock-free. Blocked cycles surface as
//! [`XbarStats::resv_waits`] with exact `skip` replay.
//!
//! ## §Perf: allocation-free, O(active) hot paths
//!
//! * B/R owner lookup goes through a dense open-addressed
//!   [`TxnTable`] instead of a SipHash `HashMap`.
//! * Decoded fork-target lists live in [`InlineVec`]s
//!   ([`TargetVec`]/[`SlaveVec`]); a per-master decode cache keyed by
//!   the front AW's txn avoids re-decoding while a request stalls.
//! * Per-master **worklist bitmasks** (`mask_pending`/`mask_w`/
//!   `mask_b_out`, plus an input-visibility scan computed once per
//!   step) let every phase iterate set bits in ascending order instead
//!   of scanning `0..n_masters` — identical arbitration order, cost
//!   proportional to actual activity.
//! * `XbarCfg::force_naive` turns the worklists and the dense table
//!   off (falling back to full scans + `HashMap`): the bit-identical
//!   reference mode checked by `tests/perf_parity.rs` and measured as
//!   an ablation layer by `benches/sim_perf.rs`. Crossbars wider than
//!   64 ports use the naive scans automatically.

use std::collections::VecDeque;

use super::addr_map::AddrMap;
use super::demux::{Demux, PendingAw, Stall, TargetAw, TargetVec};
use super::mcast::AddrSet;
use super::mux::Mux;
use super::reduce::{NodePlan, RedNode, RedTag, ReduceHandle};
use super::resv::{ResvHandle, ResvNode, ResvSeq};
use super::types::{
    AwBeat, AxiId, AxiLink, LinkId, LinkPool, RBeat, Resp, SlaveVec, Txn, WBeat, FORK_INLINE,
};
use crate::sim::sched::Component;
use crate::sim::Cycle;
use crate::util::dense::TxnTable;
use crate::util::inline_vec::InlineVec;

/// Crossbar configuration. `Clone` so the reservation ledger
/// (`axi::resv`) can snapshot the routing data its traversal oracle
/// replays.
#[derive(Debug, Clone)]
pub struct XbarCfg {
    pub name: String,
    pub n_masters: usize,
    pub n_slaves: usize,
    pub map: AddrMap,
    /// Port receiving traffic not matching any rule (hierarchy "up").
    pub default_slave: Option<usize>,
    /// Aligned region covered by this xbar's local rules; attached as
    /// the exclude scope on default-routed multicasts.
    pub local_scope: Option<(u64, u64)>,
    /// Paper's extension on/off (off = baseline XBAR; multicast AWs are
    /// rejected with DECERR).
    pub mcast_enabled: bool,
    /// Deadlock-avoidance commit protocol (fig. 2e). Disable only to
    /// demonstrate the deadlock.
    pub commit_protocol: bool,
    pub max_mcast_outstanding: u32,
    pub max_outstanding: u32,
    /// Minimum cycles a multicast AW spends in the grant/commit
    /// handshake before forking (the RTL's grant-settle + "releasing
    /// the muxes in the following cycle" sequence across all addressed
    /// muxes). Calibrated against fig. 3b's round-trip amortisation
    /// behaviour; unicast AWs are unaffected.
    pub mcast_commit_lat: u32,
    /// Idle cycles inserted after every multicast W fork beat.
    ///
    /// The RTL's `stream_fork` fans a W beat out through registered
    /// spill slices whose ready is one cycle stale; with more than one
    /// destination the all-ready condition is met every other cycle, so
    /// the sustained fork rate is ~½ beat/cycle. `1` reproduces that
    /// measured behaviour (calibrated against fig. 3b, see
    /// EXPERIMENTS.md); `0` is an idealised single-cycle fork
    /// (ablation).
    pub mcast_w_cooldown: u32,
    /// Reference/ablation mode (§Perf): disable the worklist bitmasks
    /// and the dense txn table, restoring the scan-everything PR-1
    /// behaviour. Simulated cycles and stats are bit-identical either
    /// way (`tests/perf_parity.rs`).
    pub force_naive: bool,
    /// End-to-end multicast ordering: lift the lock/commit protocol
    /// from a per-crossbar mechanism to the fabric-wide two-phase
    /// reservation protocol (`axi::resv`), which orders conflicting
    /// multicasts consistently across hierarchy levels and thereby
    /// allows *concurrent global* multicasts the RTL-faithful fabric
    /// must serialise. Off by default (the paper's reference
    /// behaviour). The flag only takes effect once a ledger is
    /// attached ([`Xbar::attach_resv`], done by
    /// `TopologyBuilder::build` for every shape) and requires
    /// `commit_protocol`.
    pub e2e_mcast_order: bool,
    /// In-network reduction (`axi::reduce`) — the dual of the
    /// multicast fork: converging write bursts tagged with a reduction
    /// group are absorbed at every join point of the fabric and
    /// forwarded upstream as ONE combined burst per join, saving
    /// `(contributors - 1) x beats` W beats per hop
    /// ([`XbarStats::red_beats_saved`]). Off by default (the
    /// RTL-faithful fabric, where converging traffic resolves at the
    /// endpoints); the flag only takes effect once a membership oracle
    /// is attached ([`Xbar::attach_reduce`], done by
    /// `TopologyBuilder::build` for every shape). With the flag off,
    /// tagged bursts travel individually and behavior is bit-identical
    /// to a fabric that never heard of reductions.
    pub fabric_reduce: bool,
}

impl XbarCfg {
    pub fn new(name: &str, n_masters: usize, n_slaves: usize, map: AddrMap) -> XbarCfg {
        XbarCfg {
            name: name.to_string(),
            n_masters,
            n_slaves,
            map,
            default_slave: None,
            local_scope: None,
            mcast_enabled: true,
            commit_protocol: true,
            max_mcast_outstanding: 4,
            max_outstanding: 16,
            mcast_commit_lat: 8,
            mcast_w_cooldown: 1,
            force_naive: crate::util::force_naive_env(),
            e2e_mcast_order: false,
            fabric_reduce: false,
        }
    }

    /// Decode an AW's destination set into fork targets, honouring the
    /// exclude scope and the default route. Lives on the config (pure
    /// in the routing data) so the reservation ledger's traversal
    /// oracle (`axi::resv`) replays *exactly* the datapath's decode.
    pub fn decode_aw(&self, dest: &AddrSet, exclude: Option<(u64, u64)>) -> (TargetVec, Resp) {
        // fast path: plain unicast
        if dest.is_singleton() {
            if let Some(s) = self.map.decode_unicast(dest.addr) {
                let mut t = TargetVec::new();
                t.push(TargetAw {
                    slave: s,
                    dest: *dest,
                    exclude: None,
                });
                return (t, Resp::Okay);
            }
            if let Some(up) = self.default_slave {
                let mut t = TargetVec::new();
                t.push(TargetAw {
                    slave: up,
                    dest: *dest,
                    exclude: None,
                });
                return (t, Resp::Okay);
            }
            return (TargetVec::new(), Resp::DecErr);
        }

        if !self.mcast_enabled {
            // baseline XBAR: masked requests are illegal
            return (TargetVec::new(), Resp::DecErr);
        }

        let d = self.map.decode(dest);
        let mut targets = TargetVec::new();
        let mut excl_in_rules = 0u64;
        for (s, sub) in &d.targets {
            if let Some((es, ee)) = exclude {
                if sub.base() >= es && sub.top() < ee {
                    // already served upstream of this hop
                    excl_in_rules += sub.count();
                    continue;
                }
            }
            targets.push(TargetAw {
                slave: *s,
                dest: *sub,
                exclude: None,
            });
        }
        // addresses excluded but not matched by local rules
        let n_excl = match exclude {
            Some((es, ee)) => AddrSet::from_interval(es, ee)
                .ok()
                .and_then(|e| dest.intersect(&e))
                .map(|i| i.count())
                .unwrap_or(0),
            None => 0,
        };
        let excl_unmatched = n_excl.saturating_sub(excl_in_rules);
        let remainder = d.uncovered.saturating_sub(excl_unmatched);
        let mut resp0 = Resp::Okay;
        if remainder > 0 {
            match self.default_slave {
                Some(up) => {
                    // Forward the original set up, extending the scope.
                    // Nested scopes merge to the outer region: in a
                    // well-formed hierarchy the incoming exclude (served
                    // at a lower level) is contained in this crossbar's
                    // local scope, and the union of "already served"
                    // addresses is exactly the outer aligned region.
                    // Disjoint scopes (a malformed topology) stay
                    // unrepresentable.
                    let scope = match (exclude, self.local_scope) {
                        (None, s) => s,
                        (e @ Some(_), None) => e,
                        (Some((es, ee)), Some((ls, le))) => {
                            if ls <= es && ee <= le {
                                Some((ls, le))
                            } else if es <= ls && le <= ee {
                                Some((es, ee))
                            } else {
                                panic!(
                                    "xbar {}: disjoint exclude scopes \
                                     [{es:#x},{ee:#x}) vs local [{ls:#x},{le:#x}) \
                                     are not representable (scopes must nest)",
                                    self.name
                                )
                            }
                        }
                    };
                    targets.push(TargetAw {
                        slave: up,
                        dest: *dest,
                        exclude: scope,
                    });
                }
                None => resp0 = Resp::DecErr,
            }
        }
        targets.sort_by_key(|t| t.slave);
        (targets, resp0)
    }
}

/// Aggregate statistics (read by benches and EXPERIMENTS.md harnesses).
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct XbarStats {
    pub aw_unicast: u64,
    pub aw_mcast: u64,
    pub aw_forks: u64,
    pub w_beats_in: u64,
    pub w_beats_out: u64,
    pub w_fork_stalls: u64,
    pub b_joined: u64,
    pub commit_waits: u64,
    pub ar_forwarded: u64,
    pub r_beats: u64,
    pub decerr: u64,
    pub stall_id_conflict: u64,
    pub stall_mcast_order: u64,
    /// Extra W beats produced by multicast forking: for every W beat
    /// entering, `fanout - 1` additional beats leave. Invariant checked
    /// by the integration suites: `w_beats_out == w_beats_in + w_fork_extra`.
    pub w_fork_extra: u64,
    /// Fabric-wide reservation tickets issued at this crossbar (it was
    /// the multicast's entry node). Only nonzero with
    /// `XbarCfg::e2e_mcast_order`.
    pub resv_tickets: u64,
    /// Cycles a pending ticketed AW spent blocked on the fabric-wide
    /// reservation order (its ticket not yet at the front of this
    /// node's claim queue) — the new stall reason of the two-phase
    /// protocol, replayed bit-identically by `Xbar::skip`.
    pub resv_waits: u64,
    /// Claims retired at this crossbar (ticketed AWs committed here).
    pub resv_commits: u64,
    /// In-network reduction (`XbarCfg::fabric_reduce`): combined
    /// bursts this crossbar forwarded upstream — one per fully-arrived
    /// combine-table entry, the converging dual of `aw_forks`.
    pub red_joins: u64,
    /// W beats the combining removed from this crossbar's upstream
    /// traffic: per join of `k` contributor bursts of `b` beats,
    /// `(k-1)*b`. The mirror of `w_fork_extra`; the balanced fork/join
    /// accounting is `w_beats_out == w_beats_in + w_fork_extra -
    /// red_beats_saved`. Combining acts only on beat arrivals and
    /// channel pushes — no per-cycle wait counter exists, so
    /// `Xbar::skip` has nothing to replay and event-horizon parity
    /// holds by construction (`tests/perf_parity.rs`).
    pub red_beats_saved: u64,
}

impl XbarStats {
    /// Accumulate another crossbar's counters (network/topology sums).
    pub fn add(&mut self, o: &XbarStats) {
        self.aw_unicast += o.aw_unicast;
        self.aw_mcast += o.aw_mcast;
        self.aw_forks += o.aw_forks;
        self.w_beats_in += o.w_beats_in;
        self.w_beats_out += o.w_beats_out;
        self.w_fork_stalls += o.w_fork_stalls;
        self.b_joined += o.b_joined;
        self.commit_waits += o.commit_waits;
        self.ar_forwarded += o.ar_forwarded;
        self.r_beats += o.r_beats;
        self.decerr += o.decerr;
        self.stall_id_conflict += o.stall_id_conflict;
        self.stall_mcast_order += o.stall_mcast_order;
        self.w_fork_extra += o.w_fork_extra;
        self.resv_tickets += o.resv_tickets;
        self.resv_waits += o.resv_waits;
        self.resv_commits += o.resv_commits;
        self.red_joins += o.red_joins;
        self.red_beats_saved += o.red_beats_saved;
    }
}

/// In-flight pending AW extended with per-target forward flags (used in
/// the no-commit mode to reproduce the deadlock).
#[derive(Debug)]
struct PendingEntry {
    pend: PendingAw,
    forwarded: InlineVec<bool, FORK_INLINE>,
    /// Cycles spent pending (commit handshake modelling).
    age: u32,
}

/// Memoised decode of one master's front AW (§Perf): a stalled request
/// is re-examined every cycle, but its decode is pure in the beat, so
/// it is computed once per transaction instead of once per cycle.
#[derive(Debug)]
struct DecCache {
    txn: Txn,
    targets: TargetVec,
    resp0: Resp,
}

/// Virtual master index the combine table uses in the exit mux's
/// W-order queue (in-network reduction): the combined burst is sourced
/// by the crossbar itself, not by any external master port.
const RED_MASTER: usize = usize::MAX;

/// Upstream progress of one combine-table entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum RedState {
    /// Waiting for contributor bursts (`arrived < expected`).
    Collecting,
    /// All contributors absorbed; the combined AW awaits channel space.
    Ready,
    /// Combined AW issued; `left` W beats still to stream.
    Streaming { left: u32 },
    /// Combined burst fully sent; waiting for the upstream B to fan
    /// back to the absorbed contributors.
    AwaitB,
}

/// One in-flight join of the per-node combine table (in-network
/// reduction, `axi::reduce`): the contributions of one reduction group
/// to one burst address converging at this crossbar. Kept in a plain
/// `Vec` in creation order — iteration order is part of the simulated
/// behavior, and a randomized-hash map would diverge between runs.
#[derive(Debug)]
struct CombineEntry {
    group: u32,
    /// Burst base address (all members write the same split).
    addr: u64,
    beats: u32,
    beat_bytes: u32,
    exit_slave: usize,
    expected: u32,
    /// Contributor bursts fully drained into this entry.
    arrived: u32,
    /// Absorbed contributors awaiting the fanned B: (master, id, txn).
    waiters: Vec<(usize, AxiId, Txn)>,
    state: RedState,
    /// Transaction tag of the combined upstream burst — the first
    /// contributor's (globally unique; its original burst was absorbed
    /// here, so the tag is free to travel on).
    up_txn: Txn,
    id: AxiId,
    tag: RedTag,
}

/// The crossbar.
pub struct Xbar {
    pub cfg: XbarCfg,
    pub demux: Vec<Demux>,
    pub mux: Vec<Mux>,
    /// Master-side links (masters push AW/W/AR). Read-only after
    /// construction: `Component::ports()` serves a cached copy, so
    /// rewiring a built xbar would desync the scheduler's wake hints.
    pub m_links: Vec<LinkId>,
    /// Slave-side links (xbar pushes AW/W/AR). Read-only after
    /// construction (see `m_links`).
    pub s_links: Vec<LinkId>,
    /// All external ports (`m_links` then `s_links`), cached for the
    /// scheduler's wake/dirty bookkeeping.
    ports: Vec<LinkId>,
    pending: Vec<Option<PendingEntry>>,
    /// Per-master cooldown countdown for multicast W forks.
    w_cooldown: Vec<u32>,
    /// Reused per-cycle scratch (per-master decoded target), avoiding
    /// hot-loop allocation. Invariant: all `None` between phases.
    scratch_want: Vec<Option<usize>>,
    /// Per-master decode memo for the front AW (§Perf).
    dec_cache: Vec<Option<DecCache>>,
    /// Cached busy state from the last stepped cycle (idle-skip).
    pub maybe_busy: bool,
    wr_owner: TxnTable,
    rd_owner: TxnTable,
    /// DECERR read responses being generated: (master, id, txn, beats).
    /// VecDeque so the common front-completion removal is O(1).
    decerr_r: VecDeque<(usize, u16, Txn, u32)>,
    /// Fabric-wide reservation ledger handle + this crossbar's node id
    /// (end-to-end multicast ordering; `None` = per-crossbar protocol
    /// only, the RTL-faithful default).
    resv: Option<(ResvHandle, ResvNode)>,
    /// In-network-reduction membership oracle + this crossbar's node id
    /// (`None` = reductions resolve at the endpoints, the RTL-faithful
    /// default).
    red: Option<(ReduceHandle, RedNode)>,
    /// Live joins of the per-node combine table (creation order).
    red_entries: Vec<CombineEntry>,
    pub stats: XbarStats,

    // ---- worklists (§Perf) ----
    /// Bitmasks valid when `use_masks`: masters with a decoded pending
    /// AW / a live W route or fork cooldown / queued joined Bs.
    mask_pending: u64,
    mask_w: u64,
    mask_b_out: u64,
    /// Pending multicast count (O(1) grant-phase early-out).
    n_pending_mcast: u32,
    /// Any mux may hold a stale grant (cleared once after the last
    /// pending multicast retires).
    grants_live: bool,
    /// Worklists enabled: `!force_naive` and ≤64 ports per side.
    use_masks: bool,
}

impl Xbar {
    /// Build a crossbar whose ports use the given pool links.
    pub fn new(cfg: XbarCfg, m_links: Vec<LinkId>, s_links: Vec<LinkId>) -> Xbar {
        assert_eq!(m_links.len(), cfg.n_masters);
        assert_eq!(s_links.len(), cfg.n_slaves);
        let demux = (0..cfg.n_masters)
            .map(|i| Demux::new(i, cfg.max_mcast_outstanding, cfg.max_outstanding))
            .collect();
        let mux = (0..cfg.n_slaves).map(Mux::new).collect();
        let pending = (0..cfg.n_masters).map(|_| None).collect();
        let w_cooldown = vec![0; cfg.n_masters];
        let scratch_want = vec![None; cfg.n_masters];
        let dec_cache = (0..cfg.n_masters).map(|_| None).collect();
        let ports: Vec<LinkId> = m_links.iter().chain(s_links.iter()).copied().collect();
        let use_masks = !cfg.force_naive && cfg.n_masters <= 64 && cfg.n_slaves <= 64;
        let force_naive = cfg.force_naive;
        Xbar {
            cfg,
            demux,
            mux,
            m_links,
            s_links,
            ports,
            pending,
            w_cooldown,
            scratch_want,
            dec_cache,
            maybe_busy: false,
            wr_owner: TxnTable::new(force_naive),
            rd_owner: TxnTable::new(force_naive),
            decerr_r: VecDeque::new(),
            resv: None,
            red: None,
            red_entries: Vec::new(),
            stats: XbarStats::default(),
            mask_pending: 0,
            mask_w: 0,
            mask_b_out: 0,
            n_pending_mcast: 0,
            grants_live: false,
            use_masks,
        }
    }

    /// Convenience for tests: allocate a fresh pool with one link per
    /// port (masters first, then slaves).
    pub fn with_pool(cfg: XbarCfg, depth: usize) -> (Xbar, LinkPool) {
        let nm = cfg.n_masters;
        let ns = cfg.n_slaves;
        let mut pool = LinkPool::new();
        let m_links: Vec<LinkId> = (0..nm).map(|_| pool.alloc(AxiLink::new(depth))).collect();
        let s_links: Vec<LinkId> = (0..ns).map(|_| pool.alloc(AxiLink::new(depth))).collect();
        (Xbar::new(cfg, m_links, s_links), pool)
    }

    // ---- worklist bookkeeping (no-ops semantically; the masks are
    // pure accelerators and ignored in naive mode) ----

    #[inline]
    fn note_pending(&mut self, m: usize, set: bool) {
        if m < 64 {
            if set {
                self.mask_pending |= 1u64 << m;
            } else {
                self.mask_pending &= !(1u64 << m);
            }
        }
    }

    #[inline]
    fn note_w(&mut self, m: usize) {
        if m < 64 {
            self.mask_w |= 1u64 << m;
        }
    }

    #[inline]
    fn note_b_out(&mut self, m: usize) {
        if m < 64 {
            self.mask_b_out |= 1u64 << m;
        }
    }

    /// Run `f` for each index in `mask` (ascending — the same order as
    /// the naive scan, so arbitration is unaffected), or for `0..n`
    /// when the worklists are disabled.
    #[inline]
    fn for_each(
        &mut self,
        mask: u64,
        n: usize,
        pool: &mut LinkPool,
        mut f: impl FnMut(&mut Xbar, usize, &mut LinkPool),
    ) {
        if self.use_masks {
            let mut bits = mask;
            while bits != 0 {
                let i = bits.trailing_zeros() as usize;
                bits &= bits - 1;
                f(self, i, pool);
            }
        } else {
            for i in 0..n {
                f(self, i, pool);
            }
        }
    }

    /// Attach the fabric-wide reservation ledger (end-to-end multicast
    /// ordering). `node` is this crossbar's identity inside the shared
    /// ledger; `TopologyBuilder::build` wires this for every node of a
    /// tree or mesh when any node requests `e2e_mcast_order`.
    pub fn attach_resv(&mut self, handle: ResvHandle, node: ResvNode) {
        self.resv = Some((handle, node));
    }

    /// Attach the in-network-reduction membership oracle. `node` is
    /// this crossbar's identity inside the shared ledger;
    /// `TopologyBuilder::build` wires this for every node when any
    /// node requests `XbarCfg::fabric_reduce`.
    pub fn attach_reduce(&mut self, handle: ReduceHandle, node: RedNode) {
        self.red = Some((handle, node));
    }

    /// This node's combining duty for `group`, if in-network reduction
    /// is armed and the node is a join point of the group's converging
    /// tree (`None` ⇒ the tagged burst rides the plain unicast
    /// datapath).
    #[inline]
    fn red_plan(&self, group: u32) -> Option<NodePlan> {
        match &self.red {
            Some((h, node)) if self.cfg.fabric_reduce => h.lock().unwrap().plan(*node, group),
            _ => None,
        }
    }

    /// Is the end-to-end reservation protocol active on this crossbar?
    #[inline]
    fn e2e(&self) -> bool {
        self.cfg.e2e_mcast_order && self.cfg.commit_protocol && self.resv.is_some()
    }

    /// Is this (possibly absent) ticket at the front of this node's
    /// fabric-wide claim queue? Unticketed requests are never gated.
    #[inline]
    fn resv_front(&self, ticket: Option<ResvSeq>) -> bool {
        match (&self.resv, ticket) {
            (Some((h, node)), Some(seq)) => h.lock().unwrap().is_front(*node, seq),
            _ => true,
        }
    }

    /// Retire this node's claim of a committed ticket.
    fn resv_commit(&mut self, ticket: Option<ResvSeq>) {
        if let Some(seq) = ticket {
            let (h, node) = self.resv.clone().expect("ticketed beat without a ledger");
            h.lock().unwrap().commit(node, seq);
            self.stats.resv_commits += 1;
        }
    }

    /// One clock cycle. `pool` is the shared link pool.
    pub fn step(&mut self, pool: &mut LinkPool) {
        // one consolidated input-visibility scan (§Perf): which ports
        // carry beats this cycle; the phases then iterate set bits only
        let (mut in_aw, mut in_ar, mut in_b, mut in_r) = (0u64, 0u64, 0u64, 0u64);
        if self.use_masks {
            for (m, &l) in self.m_links.iter().enumerate() {
                let link = &pool[l];
                if link.aw.visible() > 0 {
                    in_aw |= 1u64 << m;
                }
                if link.ar.visible() > 0 {
                    in_ar |= 1u64 << m;
                }
            }
            for (s, &l) in self.s_links.iter().enumerate() {
                let link = &pool[l];
                if link.b.visible() > 0 {
                    in_b |= 1u64 << s;
                }
                if link.r.visible() > 0 {
                    in_r |= 1u64 << s;
                }
            }
        }
        self.phase_b(pool, in_b);
        self.phase_r(pool, in_r);
        self.phase_ar(pool, in_ar);
        self.phase_aw_accept(pool, in_aw);
        self.phase_grant();
        self.phase_commit(pool);
        self.phase_unicast_aw(pool);
        self.phase_w(pool);
        self.phase_reduce(pool);
        // cached for the scheduler's idle-skip (§Perf): an idle xbar is
        // only re-woken by visible beats on its ports (activity hints)
        self.maybe_busy = self.busy();
    }

    /// Phase 1 — B collection + joined-B drain.
    fn phase_b(&mut self, pool: &mut LinkPool, in_b: u64) {
        let ns = self.cfg.n_slaves;
        self.for_each(in_b, ns, pool, |xb, s, pool| {
            if let Some(b) = pool[xb.s_links[s]].b.pop() {
                // combined reduction burst: fan the single upstream B
                // out to every absorbed contributor — the converging
                // dual of the multicast B-join
                if let Some(i) = xb
                    .red_entries
                    .iter()
                    .position(|e| e.state == RedState::AwaitB && e.up_txn == b.txn)
                {
                    let e = xb.red_entries.remove(i);
                    for (m, id, txn) in e.waiters {
                        let joined = xb.demux[m]
                            .join_b(txn, b.resp, id)
                            .expect("sink join must complete on the fanned B");
                        xb.stats.b_joined += 1;
                        xb.demux[m].b_out.push_back(joined);
                        xb.note_b_out(m);
                    }
                    return;
                }
                let m = xb
                    .wr_owner
                    .get(b.txn)
                    .unwrap_or_else(|| panic!("{}: B for unknown txn {}", xb.cfg.name, b.txn));
                if let Some(joined) = xb.demux[m].join_b(b.txn, b.resp, b.id) {
                    xb.wr_owner.remove(b.txn);
                    xb.stats.b_joined += 1;
                    xb.demux[m].b_out.push_back(joined);
                    xb.note_b_out(m);
                }
            }
        });
        let nm = self.cfg.n_masters;
        self.for_each(self.mask_b_out, nm, pool, |xb, m, pool| {
            if let Some(&b) = xb.demux[m].b_out.front() {
                if pool[xb.m_links[m]].b.can_push() {
                    xb.demux[m].b_out.pop_front();
                    pool[xb.m_links[m]].b.push(b);
                }
            }
            if m < 64 && xb.demux[m].b_out.is_empty() {
                xb.mask_b_out &= !(1u64 << m);
            }
        });
    }

    /// Phase 2 — R routing (slave→master) + DECERR R generation.
    fn phase_r(&mut self, pool: &mut LinkPool, in_r: u64) {
        let ns = self.cfg.n_slaves;
        self.for_each(in_r, ns, pool, |xb, s, pool| {
            let link = xb.s_links[s];
            let Some(r) = pool[link].r.front().copied() else {
                return;
            };
            let m = xb
                .rd_owner
                .get(r.txn)
                .unwrap_or_else(|| panic!("{}: R for unknown txn {}", xb.cfg.name, r.txn));
            if pool[xb.m_links[m]].r.can_push() {
                pool[link].r.pop();
                if r.last {
                    xb.rd_owner.remove(r.txn);
                }
                pool[xb.m_links[m]].r.push(r);
                xb.stats.r_beats += 1;
            }
        });
        // synthesize DECERR read data for unroutable ARs
        let mut i = 0;
        while i < self.decerr_r.len() {
            let (m, id, txn, ref mut beats) = self.decerr_r[i];
            if pool[self.m_links[m]].r.can_push() {
                *beats -= 1;
                let last = *beats == 0;
                pool[self.m_links[m]].r.push(RBeat {
                    id,
                    last,
                    resp: Resp::DecErr,
                    txn,
                });
                if last {
                    let _ = self.decerr_r.remove(i);
                    continue;
                }
            }
            i += 1;
        }
    }

    /// Phase 3 — AR arbitration and forwarding (reads are unicast).
    fn phase_ar(&mut self, pool: &mut LinkPool, in_ar: u64) {
        // decode every visible front AR once (into reusable scratch)
        let mut any = false;
        let nm = self.cfg.n_masters;
        self.for_each(in_ar, nm, pool, |xb, m, pool| {
            let dec = pool[xb.m_links[m]].ar.front().map(|ar| {
                xb.cfg
                    .map
                    .decode_unicast(ar.addr)
                    .or(xb.cfg.default_slave)
            });
            xb.scratch_want[m] = match dec {
                Some(Some(s)) => {
                    any = true;
                    Some(s)
                }
                Some(None) => {
                    // unroutable read → DECERR R burst
                    let ar = pool[xb.m_links[m]].ar.pop().unwrap();
                    xb.stats.decerr += 1;
                    xb.decerr_r.push_back((m, ar.id, ar.txn, ar.beats));
                    None
                }
                None => None,
            };
        });
        if any {
            for s in 0..self.cfg.n_slaves {
                if !pool[self.s_links[s]].ar.can_push() {
                    continue;
                }
                let want = &self.scratch_want;
                if let Some(m) =
                    self.mux[s].rr_pick_ar_scan(self.cfg.n_masters, |m| want[m] == Some(s))
                {
                    let mut ar = pool[self.m_links[m]].ar.pop().unwrap();
                    ar.src = m;
                    self.rd_owner.insert(ar.txn, m);
                    pool[self.s_links[s]].ar.push(ar);
                    self.stats.ar_forwarded += 1;
                    self.scratch_want[m] = None;
                }
            }
        }
        // restore the all-None scratch invariant over the touched set
        self.for_each(in_ar, nm, pool, |xb, m, _| xb.scratch_want[m] = None);
    }

    /// Phase 4 — AW acceptance + decode (fig. 2d ordering stalls).
    fn phase_aw_accept(&mut self, pool: &mut LinkPool, in_aw: u64) {
        let nm = self.cfg.n_masters;
        self.for_each(in_aw, nm, pool, |xb, m, pool| {
            if xb.pending[m].is_some() {
                return;
            }
            let Some(front) = pool[xb.m_links[m]].aw.front() else {
                return;
            };
            let (dest, exclude, txn, id, mcast_req) =
                (front.dest, front.exclude, front.txn, front.id, front.is_mcast);
            // memoised decode: a stalled front AW is re-examined every
            // cycle but decoded only once
            let hit = xb.dec_cache[m].as_ref().is_some_and(|c| c.txn == txn);
            if !hit {
                let (targets, resp0) = xb.cfg.decode_aw(&dest, exclude);
                xb.dec_cache[m] = Some(DecCache {
                    txn,
                    targets,
                    resp0,
                });
            }
            let cache = xb.dec_cache[m].as_ref().unwrap();
            let slaves: SlaveVec = cache.targets.iter().map(|t| t.slave).collect();
            let is_mcast = mcast_req && slaves.len() != 1;
            match xb.demux[m].admit(is_mcast, id, &slaves) {
                Stall::None => {}
                Stall::IdConflict => {
                    xb.stats.stall_id_conflict += 1;
                    return;
                }
                Stall::McastAfterUnicast
                | Stall::UnicastAfterMcast
                | Stall::McastSetMismatch
                | Stall::McastLimit => {
                    xb.stats.stall_mcast_order += 1;
                    return;
                }
                _ => return,
            }
            let mut beat = pool[xb.m_links[m]].aw.pop().unwrap();
            beat.src = m;
            beat.is_mcast = is_mcast;
            if is_mcast {
                xb.stats.aw_mcast += 1;
            } else {
                xb.stats.aw_unicast += 1;
            }
            let cache = xb.dec_cache[m].take().unwrap();
            // In-network reduction: a tagged contribution arriving at
            // one of its group's join points is absorbed into the
            // combine table instead of being forwarded — its W beats
            // drain through a sink route and ONE combined burst leaves
            // upstream once every expected contributor arrived
            // (`phase_reduce`). Non-join-point nodes fall through to
            // the plain unicast datapath, tag preserved.
            if let Some(tag) = beat.reduce {
                if let Some(plan) = xb.red_plan(tag.group) {
                    debug_assert!(
                        beat.dest.is_singleton(),
                        "reduction contributions are unicast"
                    );
                    debug_assert_eq!(
                        cache.targets.first().map(|t| t.slave),
                        Some(plan.exit_slave),
                        "membership oracle and datapath decode disagree"
                    );
                    xb.demux[m].accept_sink(&beat, plan.exit_slave);
                    xb.note_w(m);
                    xb.red_contribution(m, &beat, plan, tag);
                    return;
                }
            }
            // Fabric-wide reservation acquire (e2e ordering): the entry
            // crossbar — the first to see the multicast, before any leg
            // carries a ticket — claims every node of the fork tree and
            // stamps the globally ordered ticket onto the beat. Demoted
            // single-target requests still reserve: the set can fan out
            // again downstream. Unroutable requests stay unticketed
            // (their DECERR acceptance never forks anywhere).
            if xb.e2e()
                && beat.ticket.is_none()
                && mcast_req
                && dest.count() > 1
                && !cache.targets.is_empty()
            {
                let (h, node) = xb.resv.clone().unwrap();
                beat.ticket = Some(h.lock().unwrap().reserve(node, &dest, exclude));
                xb.stats.resv_tickets += 1;
            }
            if cache.resp0 == Resp::DecErr && cache.targets.is_empty() {
                xb.stats.decerr += 1;
            }
            let n_targets = cache.targets.len();
            xb.pending[m] = Some(PendingEntry {
                pend: PendingAw {
                    beat,
                    targets: cache.targets,
                    resp0: cache.resp0,
                },
                forwarded: InlineVec::from_elem(false, n_targets),
                age: 0,
            });
            xb.note_pending(m, true);
            if is_mcast {
                xb.n_pending_mcast += 1;
            }
        });
    }

    /// Does master `m` have an unforwarded multicast leg for slave `s`?
    #[inline]
    fn wants_mcast(&self, m: usize, s: usize) -> bool {
        self.pending[m]
            .as_ref()
            .map(|p| {
                p.pend.beat.is_mcast
                    && p.pend
                        .targets
                        .iter()
                        .zip(p.forwarded.iter())
                        .any(|(t, f)| t.slave == s && !f)
            })
            .unwrap_or(false)
    }

    /// Phase 5 — per-slave multicast grant (priority encoder).
    fn phase_grant(&mut self) {
        // hot path: no pending multicast anywhere → clear grants cheaply
        // (with worklists the check is O(1) and the clear runs once)
        let any_mcast = if self.use_masks {
            self.n_pending_mcast > 0
        } else {
            self.pending
                .iter()
                .any(|p| p.as_ref().map(|p| p.pend.beat.is_mcast).unwrap_or(false))
        };
        if !any_mcast {
            if self.grants_live || !self.use_masks {
                for s in 0..self.cfg.n_slaves {
                    self.mux[s].grant = None;
                }
                self.grants_live = false;
            }
            return;
        }
        self.grants_live = true;
        if self.e2e() {
            // Fabric-ordered arbitration (two-phase reservation): only
            // the ticket at the front of this node's claim queue may
            // hold muxes; every other requester *backs off* (releases
            // its tentatively acquired legs on this re-arbitration)
            // until its fabric-wide turn. Tickets are unique, so at
            // most one pending per node is front — this is the lzc
            // encoder degenerated to the global reservation order. A
            // non-front multicast holding grants would block the
            // unicast datapath (`Mux::mcast_active`) that the front
            // ticket's single-target legs ride, recreating exactly the
            // cross-path cycle end-to-end ordering exists to break.
            // One shared scan for both the optimised and `force_naive`
            // modes keeps the parity suite trivially bit-identical.
            // Tickets are unique, so the front holder is found once
            // (one ledger probe per pending master, not per (s, m)
            // pair) and then handed every mux it requests.
            let front_m = (0..self.cfg.n_masters).find(|&m| {
                let ticket = self.pending[m].as_ref().and_then(|p| p.pend.beat.ticket);
                ticket.is_some() && self.resv_front(ticket)
            });
            for s in 0..self.cfg.n_slaves {
                let grant = front_m.filter(|&m| self.wants_mcast(m, s));
                self.mux[s].grant = grant;
                if grant.is_some() {
                    self.mux[s].grant_wait_cycles += 1;
                }
            }
            return;
        }
        if self.cfg.commit_protocol && self.cfg.n_slaves <= 64 {
            // bitmask fast path: one unforwarded-target mask per master,
            // then per-slave priority encode over single bits (O(N²)
            // bit tests instead of O(N²·targets) scans)
            let mut masks = [0u64; 64];
            let nm = self.cfg.n_masters.min(64);
            for (m, mask) in masks.iter_mut().enumerate().take(nm) {
                if let Some(p) = &self.pending[m] {
                    if p.pend.beat.is_mcast {
                        for (t, f) in p.pend.targets.iter().zip(p.forwarded.iter()) {
                            if !f {
                                *mask |= 1u64 << t.slave;
                            }
                        }
                    }
                }
            }
            for s in 0..self.cfg.n_slaves {
                let grant = (0..nm).find(|&m| masks[m] >> s & 1 == 1);
                self.mux[s].grant = grant;
                if grant.is_some() {
                    self.mux[s].grant_wait_cycles += 1;
                }
            }
            return;
        }
        for s in 0..self.cfg.n_slaves {
            if self.cfg.commit_protocol {
                // lzc: lowest-index requesting master, allocation-free
                let grant = (0..self.cfg.n_masters).find(|&m| self.wants_mcast(m, s));
                self.mux[s].grant = grant;
                if grant.is_some() {
                    self.mux[s].grant_wait_cycles += 1;
                }
            } else {
                let requesters: InlineVec<usize, FORK_INLINE> = (0..self.cfg.n_masters)
                    .filter(|&m| self.wants_mcast(m, s))
                    .collect();
                self.mux[s].arbitrate_mcast_rr(&requesters, self.cfg.n_masters);
            }
        }
    }

    /// Fork one target of a pending AW onto its slave link.
    fn forward_target(
        wr_owner: &mut TxnTable,
        stats: &mut XbarStats,
        mux: &mut Mux,
        link: &mut AxiLink,
        beat: &AwBeat,
        target: &TargetAw,
        m: usize,
    ) {
        let fwd = AwBeat {
            id: beat.id,
            dest: target.dest,
            beats: beat.beats,
            beat_bytes: beat.beat_bytes,
            is_mcast: target.dest.count() > 1 || target.exclude.is_some(),
            exclude: target.exclude,
            src: m,
            txn: beat.txn,
            // the reservation ticket rides every forked leg, so each
            // downstream crossbar gates on the same fabric-wide order
            ticket: beat.ticket,
            // a pass-through reduction contribution keeps its tag so
            // join points further up still combine it
            reduce: beat.reduce,
        };
        link.aw.push(fwd);
        mux.push_w_order(m, beat.txn);
        wr_owner.insert(beat.txn, m);
        stats.aw_forks += 1;
    }

    /// Phase 6 — multicast commit (or per-slave forward when the commit
    /// protocol is disabled, reproducing fig. 2e).
    fn phase_commit(&mut self, pool: &mut LinkPool) {
        if self.use_masks && self.n_pending_mcast == 0 {
            return;
        }
        let nm = self.cfg.n_masters;
        let snapshot = self.mask_pending;
        self.for_each(snapshot, nm, pool, |xb, m, pool| {
            let (ticket, aged) = match xb.pending[m].as_mut() {
                Some(e) if e.pend.beat.is_mcast => {
                    e.age += 1;
                    (e.pend.beat.ticket, e.age > xb.cfg.mcast_commit_lat)
                }
                _ => return,
            };
            // e2e ordering: one reservation wait per cycle while this
            // node's claim front belongs to an older ticket (the
            // predicate `Xbar::skip` replays over bulk-advanced spans)
            let front = xb.resv_front(ticket);
            if ticket.is_some() && !front {
                xb.stats.resv_waits += 1;
            }
            if !aged {
                xb.stats.commit_waits += 1;
                return;
            }
            let entry = xb.pending[m].as_ref().unwrap();
            if entry.pend.targets.is_empty() {
                if !front {
                    // a ticketed leg that decodes to nothing here still
                    // takes its fabric-wide turn before the DECERR
                    // acceptance retires its claim
                    xb.stats.commit_waits += 1;
                    return;
                }
                // unroutable mcast: accept so W drains, B = DECERR
                let entry = xb.pending[m].take().unwrap();
                xb.note_pending(m, false);
                xb.n_pending_mcast -= 1;
                xb.demux[m].accept(&entry.pend.beat, &entry.pend.targets, entry.pend.resp0);
                xb.note_w(m);
                xb.resv_commit(ticket);
                return;
            }
            if xb.cfg.commit_protocol {
                // all-or-nothing: every target granted to m and pushable
                // — and, under e2e ordering, the fabric-wide claim front
                // held (commit only fires once every transitive leg of
                // the fork tree is this ticket's to take)
                let all_ready = front
                    && entry.pend.targets.iter().all(|t| {
                        xb.mux[t.slave].grant == Some(m) && pool[xb.s_links[t.slave]].aw.can_push()
                    });
                if !all_ready {
                    xb.stats.commit_waits += 1;
                    return;
                }
                let entry = xb.pending[m].take().unwrap();
                xb.note_pending(m, false);
                xb.n_pending_mcast -= 1;
                for t in entry.pend.targets.iter() {
                    Self::forward_target(
                        &mut xb.wr_owner,
                        &mut xb.stats,
                        &mut xb.mux[t.slave],
                        &mut pool[xb.s_links[t.slave]],
                        &entry.pend.beat,
                        t,
                        m,
                    );
                    xb.mux[t.slave].grant = None;
                }
                xb.demux[m].accept(&entry.pend.beat, &entry.pend.targets, entry.pend.resp0);
                xb.note_w(m);
                xb.resv_commit(ticket);
            } else {
                // NO deadlock avoidance: fork each leg as it is granted
                let entry = xb.pending[m].as_mut().unwrap();
                let n = entry.pend.targets.len();
                for i in 0..n {
                    if entry.forwarded[i] {
                        continue;
                    }
                    let t = entry.pend.targets[i].clone();
                    if xb.mux[t.slave].grant == Some(m)
                        && pool[xb.s_links[t.slave]].aw.can_push()
                    {
                        Self::forward_target(
                            &mut xb.wr_owner,
                            &mut xb.stats,
                            &mut xb.mux[t.slave],
                            &mut pool[xb.s_links[t.slave]],
                            &entry.pend.beat,
                            &t,
                            m,
                        );
                        entry.forwarded[i] = true;
                        xb.mux[t.slave].grant = None;
                    }
                }
                if entry.forwarded.iter().all(|&f| f) {
                    let entry = xb.pending[m].take().unwrap();
                    xb.note_pending(m, false);
                    xb.n_pending_mcast -= 1;
                    xb.demux[m].accept(&entry.pend.beat, &entry.pend.targets, entry.pend.resp0);
                    xb.note_w(m);
                }
            }
        });
    }

    /// Phase 7 — unicast AW forwarding (round-robin; multicast priority
    /// stalls unicast issue on a slave with a live grant).
    fn phase_unicast_aw(&mut self, pool: &mut LinkPool) {
        if self.use_masks && self.mask_pending == 0 {
            return;
        }
        // masters with a pending unicast AW and its (single) target
        let mut any = false;
        let nm = self.cfg.n_masters;
        let snapshot = self.mask_pending;
        self.for_each(snapshot, nm, pool, |xb, m, _pool| {
            let (want, ticket, unroutable) = match xb.pending[m].as_ref() {
                Some(p) if !p.pend.beat.is_mcast => (
                    p.pend.targets.first().map(|t| t.slave),
                    p.pend.beat.ticket,
                    p.pend.targets.is_empty(),
                ),
                _ => (None, None, false),
            };
            // e2e ordering: a ticketed leg that degenerated to a single
            // target at this hop still rides the unicast datapath, but
            // must wait for its fabric-wide turn like any other claim —
            // otherwise two multicasts could enqueue in opposite orders
            // at a pass-through crossbar and rebuild the W-order cycle.
            let front = xb.resv_front(ticket);
            if ticket.is_some() && !front {
                xb.stats.resv_waits += 1;
            }
            xb.scratch_want[m] = if front { want } else { None };
            any |= xb.scratch_want[m].is_some();
            // unroutable unicast: accept immediately (W drains, DECERR
            // B), once any fabric-wide claim turn has come up
            if unroutable && front {
                let entry = xb.pending[m].take().unwrap();
                xb.note_pending(m, false);
                xb.demux[m].accept(&entry.pend.beat, &entry.pend.targets, entry.pend.resp0);
                xb.note_w(m);
                xb.resv_commit(ticket);
                xb.scratch_want[m] = None;
            }
        });
        if any {
            for s in 0..self.cfg.n_slaves {
                if self.mux[s].mcast_active() || !pool[self.s_links[s]].aw.can_push() {
                    continue;
                }
                let want = &self.scratch_want;
                if let Some(m) =
                    self.mux[s].rr_pick_aw_scan(self.cfg.n_masters, |m| want[m] == Some(s))
                {
                    let entry = self.pending[m].take().unwrap();
                    self.note_pending(m, false);
                    let t = entry.pend.targets[0].clone();
                    Self::forward_target(
                        &mut self.wr_owner,
                        &mut self.stats,
                        &mut self.mux[s],
                        &mut pool[self.s_links[s]],
                        &entry.pend.beat,
                        &t,
                        m,
                    );
                    self.demux[m].accept(&entry.pend.beat, &entry.pend.targets, entry.pend.resp0);
                    self.note_w(m);
                    self.resv_commit(entry.pend.beat.ticket);
                    self.scratch_want[m] = None;
                }
            }
        }
        // restore the all-None scratch invariant over the touched set
        self.for_each(snapshot, nm, pool, |xb, m, _| xb.scratch_want[m] = None);
    }

    /// Phase 8 — W transport with all-ready multicast fork.
    fn phase_w(&mut self, pool: &mut LinkPool) {
        let nm = self.cfg.n_masters;
        self.for_each(self.mask_w, nm, pool, |xb, m, pool| xb.w_master(m, pool));
    }

    /// Per-master W transport (one call per active master per cycle).
    fn w_master(&mut self, m: usize, pool: &mut LinkPool) {
        if self.w_cooldown[m] > 0 {
            self.w_cooldown[m] -= 1;
            return;
        }
        let Some(route) = self.demux[m].w_queue.front() else {
            // lazy worklist clear: no route and no cooldown left
            if m < 64 {
                self.mask_w &= !(1u64 << m);
            }
            return;
        };
        let txn = route.txn;
        let beats_left = route.beats_left;
        let is_mcast = route.is_mcast;
        if route.slaves.is_empty() {
            // drain W of an unroutable transaction, or absorb a
            // reduction contribution into the combine table (sink)
            let sink = route.sink;
            if beats_left == 0 || pool[self.m_links[m]].w.pop().is_some() {
                if sink && beats_left > 0 {
                    // an absorbed beat enters the fabric but never
                    // leaves it — the join accounting's "in" side
                    self.stats.w_beats_in += 1;
                }
                let r = self.demux[m].w_queue.front_mut().unwrap();
                r.beats_left = r.beats_left.saturating_sub(1);
                if r.beats_left == 0 {
                    self.demux[m].w_queue.pop_front();
                    if sink {
                        self.red_w_drained(txn);
                    } else {
                        let b = self.demux[m].complete_unroutable(txn);
                        self.demux[m].b_out.push_back(b);
                        self.note_b_out(m);
                    }
                }
            }
            return;
        }
        if pool[self.m_links[m]].w.front().is_none() {
            return;
        }
        // inline copy of the route's slave set (memcpy up to
        // FORK_INLINE entries — replaces the old per-cycle Vec clone,
        // and only runs when a W beat is actually present)
        let slaves: SlaveVec = self.demux[m].w_queue.front().unwrap().slaves.clone();
        // all-ready fork condition (green logic in fig. 2d): every
        // destination must be at the front of its mux W order AND
        // have channel space.
        let all_ready = slaves
            .iter()
            .all(|&s| self.mux[s].w_front_is(m, txn) && pool[self.s_links[s]].w.can_push());
        if !all_ready {
            if is_mcast {
                self.stats.w_fork_stalls += 1;
            }
            return;
        }
        pool[self.m_links[m]].w.pop();
        self.stats.w_beats_in += 1;
        self.stats.w_fork_extra += slaves.len() as u64 - 1;
        let last = beats_left == 1;
        for &s in slaves.iter() {
            pool[self.s_links[s]].w.push(WBeat { last, src: m, txn });
            self.stats.w_beats_out += 1;
            if last {
                self.mux[s].pop_w_order(m, txn);
            }
        }
        let r = self.demux[m].w_queue.front_mut().unwrap();
        r.beats_left -= 1;
        if last {
            self.demux[m].w_queue.pop_front();
        }
        // registered all-ready fork: a >1-way fork cannot re-fire
        // the cycle after a beat (stale ready) — see XbarCfg docs
        if slaves.len() > 1 {
            self.w_cooldown[m] = self.cfg.mcast_w_cooldown;
        }
    }

    /// Register one absorbed contribution with the combine table
    /// (in-network reduction): the entry for `(group, burst address)`
    /// is created lazily on the first arrival and completed when
    /// `expected` contributor bursts have fully drained.
    fn red_contribution(&mut self, m: usize, beat: &AwBeat, plan: NodePlan, tag: RedTag) {
        let idx = self
            .red_entries
            .iter()
            .position(|e| e.group == tag.group && e.addr == beat.dest.addr);
        let idx = match idx {
            Some(i) => i,
            None => {
                self.red_entries.push(CombineEntry {
                    group: tag.group,
                    addr: beat.dest.addr,
                    beats: beat.beats,
                    beat_bytes: beat.beat_bytes,
                    exit_slave: plan.exit_slave,
                    expected: plan.expected,
                    arrived: 0,
                    waiters: Vec::new(),
                    state: RedState::Collecting,
                    up_txn: beat.txn,
                    id: beat.id,
                    tag,
                });
                self.red_entries.len() - 1
            }
        };
        let e = &mut self.red_entries[idx];
        assert_eq!(
            e.beats, beat.beats,
            "{}: reduction group {} contributions disagree on the burst split",
            self.cfg.name, tag.group
        );
        e.waiters.push((m, beat.id, beat.txn));
        assert!(
            e.waiters.len() as u32 <= e.expected,
            "{}: reduction group {} received more contributions than the \
             membership oracle planned",
            self.cfg.name,
            tag.group
        );
    }

    /// A sink route finished draining: mark its contribution arrived;
    /// the last arrival makes the entry ready to issue upstream.
    fn red_w_drained(&mut self, txn: Txn) {
        let e = self
            .red_entries
            .iter_mut()
            .find(|e| e.waiters.iter().any(|&(_, _, t)| t == txn))
            .expect("sink drain without a combine entry");
        e.arrived += 1;
        if e.arrived == e.expected {
            e.state = RedState::Ready;
        }
    }

    /// Phase 9 — in-network reduction: issue the combined burst of
    /// every fully-arrived combine entry and stream its W beats toward
    /// the destination. Combining never *holds* anything: the exit
    /// mux's W-order queue is entered only at issue time, when the
    /// burst's data source (this node) is unconditionally ready, so no
    /// new waits-for edges beyond those of an ordinary unicast write
    /// exist (DESIGN.md §7 deadlock argument).
    // (indexing loop: the body splits borrows across self.mux /
    // self.stats / pool, which `iter_mut` cannot express)
    #[allow(clippy::needless_range_loop)]
    fn phase_reduce(&mut self, pool: &mut LinkPool) {
        if self.red_entries.is_empty() {
            return;
        }
        for i in 0..self.red_entries.len() {
            let e = &self.red_entries[i];
            let (exit, up_txn) = (e.exit_slave, e.up_txn);
            match e.state {
                RedState::Ready => {
                    if pool[self.s_links[exit]].aw.can_push() {
                        let e = &self.red_entries[i];
                        pool[self.s_links[exit]].aw.push(AwBeat {
                            id: e.id,
                            dest: AddrSet::unicast(e.addr),
                            beats: e.beats,
                            beat_bytes: e.beat_bytes,
                            is_mcast: false,
                            exclude: None,
                            src: RED_MASTER,
                            txn: up_txn,
                            ticket: None,
                            // the tag rides on: join points further up
                            // combine this burst with other branches
                            reduce: Some(e.tag),
                        });
                        self.mux[exit].push_w_order(RED_MASTER, up_txn);
                        self.stats.red_joins += 1;
                        self.stats.red_beats_saved +=
                            (e.expected as u64 - 1) * e.beats as u64;
                        let beats = e.beats;
                        self.red_entries[i].state = RedState::Streaming { left: beats };
                    }
                }
                RedState::Streaming { left } => {
                    if self.mux[exit].w_front_is(RED_MASTER, up_txn)
                        && pool[self.s_links[exit]].w.can_push()
                    {
                        let last = left == 1;
                        pool[self.s_links[exit]].w.push(WBeat {
                            last,
                            src: RED_MASTER,
                            txn: up_txn,
                        });
                        // the combined burst's beats are the join
                        // accounting's "out" side
                        self.stats.w_beats_out += 1;
                        if last {
                            self.mux[exit].pop_w_order(RED_MASTER, up_txn);
                            self.red_entries[i].state = RedState::AwaitB;
                        } else {
                            self.red_entries[i].state = RedState::Streaming { left: left - 1 };
                        }
                    }
                }
                RedState::Collecting | RedState::AwaitB => {}
            }
        }
    }

    /// Any write/read activity still in flight inside the xbar?
    pub fn busy(&self) -> bool {
        self.pending.iter().any(Option::is_some)
            || self.demux.iter().any(|d| d.busy() || !d.b_out.is_empty())
            || !self.wr_owner.is_empty()
            || !self.rd_owner.is_empty()
            || !self.decerr_r.is_empty()
            || !self.red_entries.is_empty()
    }

    /// Event horizon (§Perf): the earliest cycle ≥ `now` at which
    /// stepping this crossbar can do anything beyond the bulk timer
    /// advancement applied by [`Xbar::skip`]. `None` means the xbar is
    /// idle or waiting purely on port activity.
    ///
    /// Precondition: all pool links are idle (the SoC only consults the
    /// horizon when the scheduler reports no active links), so every
    /// channel's `can_push` holds and no beat is consumable.
    pub fn next_event(&self, now: Cycle) -> Option<Cycle> {
        if !self.maybe_busy {
            return None;
        }
        let mut ev: Option<Cycle> = None;
        let mut fold = |e: Cycle| crate::sim::sched::fold_min(&mut ev, e);
        if !self.decerr_r.is_empty() {
            fold(now);
        }
        // a ready or streaming combine entry acts on the next step
        // (links idle ⇒ its exit channels are pushable); collecting /
        // await-B entries move only on port activity
        if self
            .red_entries
            .iter()
            .any(|e| matches!(e.state, RedState::Ready | RedState::Streaming { .. }))
        {
            fold(now);
        }
        let lat = self.cfg.mcast_commit_lat;
        for m in 0..self.cfg.n_masters {
            if !self.demux[m].b_out.is_empty() {
                fold(now);
            }
            if self.w_cooldown[m] == 0 {
                if let Some(r) = self.demux[m].w_queue.front() {
                    if r.slaves.is_empty() && r.beats_left == 0 {
                        // unroutable drain completes without any beat
                        fold(now);
                    }
                    // otherwise W transport waits on master beats
                }
            }
            // (a live cooldown alone needs no wake: it only decays, and
            // the bulk advancement handles that)
            let Some(e) = &self.pending[m] else {
                continue;
            };
            let front = self.resv_front(e.pend.beat.ticket);
            if !e.pend.beat.is_mcast {
                // a unicast pending forwards (or completes) on the next
                // step — unless e2e ordering holds its ticket behind an
                // older claim, where only another crossbar's commit
                // (that crossbar's own event) or port activity unblocks
                // it
                if front {
                    fold(now);
                }
            } else if e.age < lat {
                // pure commit-handshake aging; first actionable step is
                // the one entered with age == lat
                fold(now + (lat - e.age) as u64);
            } else if e.pend.targets.is_empty() {
                // aged unroutable mcast is accepted on the next step
                // (once its fabric-wide turn, if ticketed, has come up)
                if front {
                    fold(now);
                }
            } else if self.cfg.commit_protocol {
                if self.e2e() {
                    // front-only grants: the next step's grant phase
                    // hands the claim-front ticket every mux it wants
                    // (no competitor is eligible) and the commit fires
                    // right after (links idle ⇒ AW channels pushable),
                    // so `front` alone predicts the action; the muxes'
                    // current grants may be stale by one commit.
                    if front {
                        fold(now);
                    }
                } else if e.pend.targets.iter().all(|t| self.mux[t.slave].grant == Some(m)) {
                    // grants are stable between steps: commit fires iff
                    // every target mux is granted to m (links idle ⇒
                    // all AW channels pushable)
                    fold(now);
                }
                // else: unblocked only by this node's own front moving
                // (a commit here — its own event) or port activity
            } else {
                // no-commit mode forwards any granted unforwarded leg
                let can_fork = e
                    .pend
                    .targets
                    .iter()
                    .zip(e.forwarded.iter())
                    .any(|(t, &f)| !f && self.mux[t.slave].grant == Some(m));
                if can_fork {
                    fold(now);
                }
            }
        }
        ev
    }

    /// Bulk-advance `k` pure-wait cycles (§Perf event horizon): apply
    /// exactly the per-cycle timer decrements and wait-statistics that
    /// `k` consecutive no-op steps would have applied. Must only be
    /// called for spans `next_event` declared action-free, and only on
    /// crossbars the scheduler would actually have stepped
    /// (`maybe_busy` — a quiescent xbar's timers are frozen in the
    /// per-cycle mode too).
    pub fn skip(&mut self, k: u64) {
        if k == 0 || !self.maybe_busy {
            return;
        }
        for c in self.w_cooldown.iter_mut() {
            *c = (*c as u64).saturating_sub(k) as u32;
        }
        let lat = self.cfg.mcast_commit_lat as u64;
        let e2e = self.e2e();
        let resv = self.resv.clone();
        let mut resv_blocked = 0u64;
        let mut any_mcast = false;
        for p in self.pending.iter_mut().flatten() {
            // e2e ordering: a ticketed pending (multicast or a leg that
            // degenerated to the unicast datapath) blocked behind an
            // older claim counts one reservation wait per skipped cycle
            // — the ledger is frozen over an action-free span, so the
            // per-cycle predicate is stable and replayable
            if e2e {
                if let (Some((h, node)), Some(seq)) = (&resv, p.pend.beat.ticket) {
                    if !h.lock().unwrap().is_front(*node, seq) {
                        resv_blocked += 1;
                    }
                }
            }
            if !p.pend.beat.is_mcast {
                continue;
            }
            any_mcast = true;
            let a0 = p.age as u64;
            p.age = (a0 + k).min(u32::MAX as u64) as u32;
            // per skipped cycle the commit phase counts one wait: while
            // aging (age ≤ lat) in both modes, and additionally while
            // blocked on grants in the commit-protocol mode
            let waits = if self.cfg.commit_protocol {
                k
            } else {
                k.min(lat.saturating_sub(a0))
            };
            self.stats.commit_waits += waits;
        }
        self.stats.resv_waits += resv_blocked * k;
        if any_mcast {
            // the grant phase re-arbitrates to the same stable grants
            // each skipped cycle, counting one wait per granted mux
            for s in 0..self.cfg.n_slaves {
                if self.mux[s].grant.is_some() {
                    self.mux[s].grant_wait_cycles += k;
                }
            }
        }
    }
}

impl Component<AxiLink> for Xbar {
    fn step(&mut self, _cy: Cycle, pool: &mut LinkPool) {
        Xbar::step(self, pool);
    }

    /// Safe to skip when the last stepped cycle left nothing in flight;
    /// the scheduler re-wakes the xbar on port activity.
    fn quiescent(&self) -> bool {
        !self.maybe_busy
    }

    fn ports(&self) -> &[LinkId] {
        &self.ports
    }

    fn next_event(&self, now: Cycle) -> Option<Cycle> {
        Xbar::next_event(self, now)
    }
}

//! The paper's multi-address **mask-form encoding** (§II-A, fig. 1).
//!
//! A write request carries a mask in `aw_user`: mask bit *i* = 1 makes
//! address bit *i* a don't-care (X), so an `(addr, mask)` pair encodes
//! the set of `2^popcount(mask)` addresses obtained by substituting both
//! values at every masked position. The encoding size scales with the
//! address width (log of the address-space size) and is *independent of
//! the address-set size* — the property that makes it suitable for
//! massively parallel accelerators, unlike "all destination" encodings.
//!
//! Invariant kept throughout: `addr & mask == 0` (masked address bits
//! are normalised to zero; for an IFE-converted rule this holds by the
//! alignment constraint).

use super::types::Addr;

/// A set of addresses in mask-form encoding.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct AddrSet {
    pub addr: Addr,
    pub mask: u64,
}

/// Errors converting interval rules to mask form.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MfeError {
    EmptyRegion { start: Addr, end: Addr },
    NotPow2 { size: u64 },
    Misaligned { start: Addr, size: u64 },
}

impl std::fmt::Display for MfeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MfeError::EmptyRegion { start, end } => {
                write!(f, "region [{start:#x}, {end:#x}) is empty or inverted")
            }
            MfeError::NotPow2 { size } => {
                write!(f, "region size {size:#x} is not a power of two")
            }
            MfeError::Misaligned { start, size } => {
                write!(f, "region start {start:#x} is not aligned to its size {size:#x}")
            }
        }
    }
}

impl std::error::Error for MfeError {}

impl AddrSet {
    /// A singleton set — a plain unicast address.
    pub fn unicast(addr: Addr) -> AddrSet {
        AddrSet { addr, mask: 0 }
    }

    /// Construct from raw `(addr, mask)`, normalising masked bits to 0.
    pub fn new(addr: Addr, mask: u64) -> AddrSet {
        AddrSet {
            addr: addr & !mask,
            mask,
        }
    }

    /// Interval-form → mask-form conversion (paper formulas):
    ///
    /// ```text
    /// mfe.addr = ife.start_addr
    /// mfe.mask = ife.end_addr - ife.start_addr - 1
    /// ```
    ///
    /// Requires the region to 1) be a power of two in size and 2) be
    /// aligned to an integer multiple of its size.
    pub fn from_interval(start: Addr, end: Addr) -> Result<AddrSet, MfeError> {
        if end <= start {
            return Err(MfeError::EmptyRegion { start, end });
        }
        let size = end - start;
        if !size.is_power_of_two() {
            return Err(MfeError::NotPow2 { size });
        }
        if start % size != 0 {
            return Err(MfeError::Misaligned { start, size });
        }
        Ok(AddrSet {
            addr: start,
            mask: size - 1,
        })
    }

    /// Is this a plain single address?
    pub fn is_singleton(&self) -> bool {
        self.mask == 0
    }

    /// Number of addresses in the set (2^popcount(mask)).
    pub fn count(&self) -> u64 {
        1u64 << self.mask.count_ones()
    }

    /// Membership test.
    pub fn contains(&self, a: Addr) -> bool {
        (a & !self.mask) == self.addr
    }

    /// Set intersection test against another mask-form set — the
    /// paper's `aw_select` condition:
    ///
    /// ```text
    /// masked_bits = req.mask | rule.mask
    /// match_bits  = ~(req.addr ^ rule.addr)
    /// select      = &(masked_bits | match_bits)
    /// ```
    pub fn intersects(&self, other: &AddrSet) -> bool {
        let masked_bits = self.mask | other.mask;
        let match_bits = !(self.addr ^ other.addr);
        (masked_bits | match_bits) == u64::MAX
    }

    /// Set intersection: the subset of `self` (a request) that falls in
    /// `other` (a rule), resolving masked bits — bits where only one
    /// side is masked take the other side's fixed value; bits masked on
    /// both sides stay don't-care.
    pub fn intersect(&self, other: &AddrSet) -> Option<AddrSet> {
        if !self.intersects(other) {
            return None;
        }
        let mask = self.mask & other.mask;
        let addr = (self.addr & !self.mask) // request-fixed bits
            | (other.addr & self.mask & !other.mask); // rule-fixed where req masked
        debug_assert_eq!(addr & mask, 0);
        Some(AddrSet { addr, mask })
    }

    /// Enumerate every address in the set, ascending. Cost is
    /// `O(2^popcount(mask))` — callers bound the popcount.
    pub fn enumerate(&self) -> Vec<Addr> {
        let bits: Vec<u32> = (0..64).filter(|&b| self.mask >> b & 1 == 1).collect();
        let n = 1u64 << bits.len();
        let mut out = Vec::with_capacity(n as usize);
        for combo in 0..n {
            let mut a = self.addr;
            for (i, &b) in bits.iter().enumerate() {
                if combo >> i & 1 == 1 {
                    a |= 1u64 << b;
                }
            }
            out.push(a);
        }
        out.sort_unstable();
        out
    }

    /// The lowest address in the set (mask bits resolved to 0).
    pub fn base(&self) -> Addr {
        self.addr
    }

    /// Inclusive upper bound of the set.
    pub fn top(&self) -> Addr {
        self.addr | self.mask
    }
}

impl std::fmt::Display for AddrSet {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.is_singleton() {
            write!(f, "{:#x}", self.addr)
        } else {
            write!(f, "{:#x}/m{:#x}", self.addr, self.mask)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_mini::{check, Config};

    #[test]
    fn ife_to_mfe_paper_formula() {
        // Occamy: clusters at 0x0100_0000, stride 0x4_0000. A 4-cluster
        // group region:
        let s = AddrSet::from_interval(0x0100_0000, 0x0100_0000 + 4 * 0x4_0000).unwrap();
        assert_eq!(s.addr, 0x0100_0000);
        assert_eq!(s.mask, 4 * 0x4_0000 - 1);
        assert_eq!(s.count(), 0x10_0000);
    }

    #[test]
    fn ife_rejects_bad_regions() {
        assert_eq!(
            AddrSet::from_interval(0x1000, 0x1000),
            Err(MfeError::EmptyRegion {
                start: 0x1000,
                end: 0x1000
            })
        );
        assert!(matches!(
            AddrSet::from_interval(0, 0x3000),
            Err(MfeError::NotPow2 { .. })
        ));
        assert!(matches!(
            AddrSet::from_interval(0x1000, 0x3000),
            Err(MfeError::Misaligned { .. })
        ));
    }

    #[test]
    fn contiguous_set_fig1_left() {
        // fig. 1 left: masking low bits yields a contiguous set
        let s = AddrSet::new(0b1000, 0b0110);
        assert_eq!(s.enumerate(), vec![0b1000, 0b1010, 0b1100, 0b1110]);
        assert_eq!(s.count(), 4);
    }

    #[test]
    fn strided_set_fig1_right() {
        // fig. 1 right: masking non-contiguous bits yields a strided set
        let s = AddrSet::new(0b0001, 0b1010);
        assert_eq!(s.enumerate(), vec![0b0001, 0b0011, 0b1001, 0b1011]);
    }

    #[test]
    fn singleton_behaviour() {
        let s = AddrSet::unicast(0xDEAD);
        assert!(s.is_singleton());
        assert_eq!(s.count(), 1);
        assert_eq!(s.enumerate(), vec![0xDEAD]);
        assert!(s.contains(0xDEAD));
        assert!(!s.contains(0xDEAE));
    }

    #[test]
    fn intersect_request_with_rule() {
        // request: clusters {0,1,2,3} (mask over cluster-index bits)
        let req = AddrSet::new(0x0100_0000, 0x3 << 18); // stride 0x4_0000
        // rule: cluster 2's region [0x0108_0000, 0x010C_0000)
        let rule = AddrSet::from_interval(0x0108_0000, 0x010C_0000).unwrap();
        assert!(req.intersects(&rule));
        let sub = req.intersect(&rule).unwrap();
        // the subset is exactly the one address of cluster 2's base
        assert_eq!(sub.addr, 0x0108_0000);
        assert_eq!(sub.mask, 0);
    }

    #[test]
    fn intersect_mcast_offset_within_cluster() {
        // request broadcasts address offset 0x100 into all 4 clusters
        let req = AddrSet::new(0x0100_0100, 0x3 << 18);
        let rule = AddrSet::from_interval(0x0108_0000, 0x010C_0000).unwrap();
        let sub = req.intersect(&rule).unwrap();
        assert_eq!(sub.enumerate(), vec![0x0108_0100]);
    }

    #[test]
    fn no_intersection() {
        let req = AddrSet::new(0x0100_0000, 0x3 << 18);
        let rule = AddrSet::from_interval(0x8000_0000, 0x8000_1000).unwrap();
        assert!(!req.intersects(&rule));
        assert!(req.intersect(&rule).is_none());
    }

    #[test]
    fn enumerate_matches_contains() {
        let s = AddrSet::new(0x40, 0x0000_0101);
        let listed = s.enumerate();
        assert_eq!(listed.len() as u64, s.count());
        for a in &listed {
            assert!(s.contains(*a));
        }
    }

    // ------------------------------------------------------ properties

    fn arb_set(g: &mut crate::util::proptest_mini::Gen) -> AddrSet {
        // small masks so enumeration stays cheap
        let nbits = g.u64_below(6);
        let mut mask = 0u64;
        for _ in 0..nbits {
            mask |= 1u64 << g.u64_below(16);
        }
        AddrSet::new(g.u64_below(1 << 16), mask)
    }

    #[test]
    fn prop_intersection_matches_brute_force() {
        check(
            "mfe-intersection-vs-enumeration",
            Config::default(),
            |g| (arb_set(g), arb_set(g)),
            |(a, b)| {
                let ea: std::collections::BTreeSet<_> = a.enumerate().into_iter().collect();
                let eb: std::collections::BTreeSet<_> = b.enumerate().into_iter().collect();
                let brute: Vec<_> = ea.intersection(&eb).copied().collect();
                match a.intersect(b) {
                    None => {
                        if brute.is_empty() {
                            Ok(())
                        } else {
                            Err(format!("claims disjoint but share {} addrs", brute.len()))
                        }
                    }
                    Some(i) => {
                        let got = i.enumerate();
                        if got == brute {
                            Ok(())
                        } else {
                            Err(format!("intersection {got:x?} != brute {brute:x?}"))
                        }
                    }
                }
            },
        );
    }

    #[test]
    fn prop_intersects_consistent_with_intersect() {
        check(
            "mfe-intersects-iff-intersect",
            Config::default(),
            |g| (arb_set(g), arb_set(g)),
            |(a, b)| {
                if a.intersects(b) == a.intersect(b).is_some()
                    && a.intersects(b) == b.intersects(a)
                {
                    Ok(())
                } else {
                    Err("intersects/intersect disagree or asymmetric".into())
                }
            },
        );
    }

    #[test]
    fn prop_ife_roundtrip() {
        check(
            "ife-mfe-roundtrip",
            Config::default(),
            |g| {
                let size = 1u64 << g.u64_below(20);
                let start = g.u64_below(1 << 12) * size;
                (start, size)
            },
            |&(start, size)| {
                let s = AddrSet::from_interval(start, start + size).unwrap();
                if s.count() != size {
                    return Err(format!("count {} != size {}", s.count(), size));
                }
                if s.base() != start || s.top() != start + size - 1 {
                    return Err("bounds mismatch".into());
                }
                // every member in [start, start+size)
                if size <= 64 {
                    for a in s.enumerate() {
                        if a < start || a >= start + size {
                            return Err(format!("member {a:#x} outside interval"));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}

//! Fabric-wide two-phase reservation protocol for **end-to-end
//! multicast ordering** (`XbarCfg::e2e_mcast_order`).
//!
//! The per-crossbar lock/commit protocol (fig. 2e) breaks multicast
//! wait-for cycles *inside one crossbar*: a master must hold grants on
//! every addressed mux before any leg forks. It cannot order commits
//! *across* crossbars, so two simultaneous all-endpoint broadcasts from
//! different sources may commit in opposite orders at different
//! hierarchy levels — the top crossbar enqueues `[A, B]` in its W-order
//! queues while a group crossbar enqueues `[B, A]` — and the W
//! transport wedges on the inter-level cycle (the RTL's documented
//! limitation, reproduced by `examples/deadlock_demo.rs --interlevel`).
//!
//! The [`ResvLedger`] lifts the protocol to the whole fabric:
//!
//! 1. **Acquire.** The *entry* crossbar (the first to accept a
//!    multicast AW) reserves a globally ordered ticket. The ledger
//!    walks the fork tree with the *same* routing decode the datapath
//!    uses ([`XbarCfg::decode_aw`]) and claims every crossbar node the
//!    request will traverse — the model equivalent of the acquire
//!    travelling down the fork tree leg-by-leg on a side-band channel.
//! 2. **Commit.** A crossbar may only commit (enqueue into its mux
//!    W-order queues and fork) a ticketed AW when that ticket is at the
//!    **front** of the crossbar's claim queue, i.e. when every older
//!    conflicting multicast has already passed this node. Ticket order
//!    is one global sequence, so any two multicasts that share a
//!    crossbar commit there in the same relative order — every W-order
//!    queue in the fabric agrees, the waits-for relation only points
//!    from younger to older tickets, and no cycle can form.
//! 3. **Release.** A ticket's claims are retired node-by-node as its AW
//!    commits at each crossbar; grants themselves are re-arbitrated
//!    every cycle and only the node's claim-front ticket may hold
//!    them, so a later-ticket holder *backs off* (releases its
//!    tentatively held muxes) instead of wedging the queues.
//!    [`ResvLedger::release`] additionally unwinds all remaining
//!    claims of an aborted ticket.
//!
//! The ledger is shared by every crossbar of one network through a
//! [`ResvHandle`] (`Arc<Mutex<_>>` — uncontended in the sequential
//! engine; the parallel engine keeps every crossbar of a resv-armed
//! network in one partition, so `reserve`'s sequence assignment stays
//! in the sequential issue order) wired up by `TopologyBuilder::build`
//! for trees and meshes alike.
//! Reservation timing is modelled as a zero-latency side band; the
//! per-node `mcast_commit_lat` handshake cost still applies at every
//! level the AW traverses, which is where the RTL's grant-settle
//! latency lives.

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex};

use super::mcast::AddrSet;
use super::xbar::XbarCfg;

/// Globally ordered reservation sequence number (the ticket value
/// carried in `AwBeat::ticket`).
pub type ResvSeq = u64;

/// Handle to a crossbar node registered with a [`ResvLedger`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ResvNode(pub usize);

/// Shared ledger handle (one per network).
pub type ResvHandle = Arc<Mutex<ResvLedger>>;

/// Routing snapshot of one registered crossbar.
#[derive(Debug)]
struct NodeInfo {
    /// Clone of the crossbar's configuration — the traversal oracle
    /// must mirror `Xbar`'s routing exactly, so it reuses
    /// [`XbarCfg::decode_aw`] on the same map/scope/default data.
    cfg: XbarCfg,
    /// Per slave port: the downstream registered node that port feeds
    /// (`None` = external endpoint, the fork leg leaves the fabric).
    down: Vec<Option<ResvNode>>,
}

/// Ledger-level observability counters.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct ResvStats {
    /// Tickets issued.
    pub reserved: u64,
    /// Per-node claims retired by commits.
    pub committed_claims: u64,
    /// Claims unwound by [`ResvLedger::release`].
    pub released_claims: u64,
    /// High-water mark of concurrently live tickets — the concurrency
    /// the protocol actually unlocked.
    pub max_live: u64,
}

/// The fabric-wide reservation ledger (see the module docs).
#[derive(Debug, Default)]
pub struct ResvLedger {
    nodes: Vec<NodeInfo>,
    /// Per-node claim queue. Reservations are issued in global order
    /// and claim all their nodes atomically, so every queue is sorted
    /// ascending in seq; the front is the next ticket allowed to
    /// commit at that node.
    queues: Vec<VecDeque<ResvSeq>>,
    /// Outstanding (uncommitted) claims per live ticket.
    live: HashMap<ResvSeq, Vec<usize>>,
    next_seq: ResvSeq,
    pub stats: ResvStats,
}

impl ResvLedger {
    pub fn new() -> ResvLedger {
        ResvLedger {
            next_seq: 1,
            ..ResvLedger::default()
        }
    }

    /// Wrap into the shared handle the crossbars hold.
    pub fn into_handle(self) -> ResvHandle {
        Arc::new(Mutex::new(self))
    }

    /// Register a crossbar node (its routing snapshot). Ports start
    /// unwired (= external).
    pub fn register(&mut self, cfg: &XbarCfg) -> ResvNode {
        let down = vec![None; cfg.n_slaves];
        self.nodes.push(NodeInfo {
            cfg: cfg.clone(),
            down,
        });
        self.queues.push(VecDeque::new());
        ResvNode(self.nodes.len() - 1)
    }

    /// Declare that `from`'s slave port `s_port` feeds crossbar `to`
    /// (mirrors `TopologyBuilder::connect`).
    pub fn wire(&mut self, from: ResvNode, s_port: usize, to: ResvNode) {
        let slot = &mut self.nodes[from.0].down[s_port];
        assert!(
            slot.is_none(),
            "resv: node {} slave port {s_port} wired twice",
            from.0
        );
        *slot = Some(to);
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Tickets still live (reserved, not fully committed/released).
    pub fn live_tickets(&self) -> usize {
        self.live.len()
    }

    /// Outstanding claims queued at one node.
    pub fn queue_len(&self, node: ResvNode) -> usize {
        self.queues[node.0].len()
    }

    /// Acquire: issue the next global ticket for a multicast entering
    /// the fabric at `entry` with destination set `dest` (and the
    /// incoming exclude scope, normally `None` at an entry port), and
    /// claim every crossbar its fork tree will traverse.
    pub fn reserve(
        &mut self,
        entry: ResvNode,
        dest: &AddrSet,
        exclude: Option<(u64, u64)>,
        window: Option<(u64, u64)>,
    ) -> ResvSeq {
        let seq = self.next_seq;
        self.next_seq += 1;
        let mut claims = Vec::new();
        self.walk(entry.0, dest, exclude, window, &mut claims);
        debug_assert!(!claims.is_empty());
        for &n in &claims {
            debug_assert!(
                self.queues[n].back().map(|&b| b < seq).unwrap_or(true),
                "claim queues must stay sorted"
            );
            self.queues[n].push_back(seq);
        }
        self.live.insert(seq, claims);
        self.stats.reserved += 1;
        self.stats.max_live = self.stats.max_live.max(self.live.len() as u64);
        seq
    }

    /// The traversal oracle: replay the datapath's hop-by-hop decode.
    /// Every visited node is claimed — including hops where the leg
    /// degenerates to a single target (the beat takes the unicast
    /// datapath there, which gates ticketed requests the same way) and
    /// hops where the decode comes up empty (the DECERR acceptance
    /// retires the claim).
    fn walk(
        &self,
        node: usize,
        dest: &AddrSet,
        exclude: Option<(u64, u64)>,
        window: Option<(u64, u64)>,
        out: &mut Vec<usize>,
    ) {
        assert!(
            !out.contains(&node),
            "resv: multicast route revisits node {} ({}) — cyclic fabrics \
             are not orderable",
            node,
            self.nodes[node].cfg.name
        );
        out.push(node);
        let (targets, _resp) = self.nodes[node].cfg.decode_aw(dest, exclude, window);
        for t in targets.iter() {
            if let Some(next) = self.nodes[node].down[t.slave] {
                self.walk(next.0, &t.dest, t.exclude, t.window, out);
            }
        }
    }

    /// May `seq` commit at `node` now? True iff it is the oldest
    /// uncommitted claim there.
    pub fn is_front(&self, node: ResvNode, seq: ResvSeq) -> bool {
        self.queues[node.0].front() == Some(&seq)
    }

    /// Commit: `node` forked (or DECERR-accepted) the ticketed AW;
    /// retire its claim there. Panics on out-of-order commits — the
    /// crossbar gating must only commit the front ticket.
    pub fn commit(&mut self, node: ResvNode, seq: ResvSeq) {
        let q = &mut self.queues[node.0];
        assert_eq!(
            q.front().copied(),
            Some(seq),
            "resv: out-of-order commit of ticket {seq} at node {} ({})",
            node.0,
            self.nodes[node.0].cfg.name
        );
        q.pop_front();
        self.stats.committed_claims += 1;
        let done = {
            let claims = self
                .live
                .get_mut(&seq)
                .expect("resv: commit of unknown ticket");
            claims.retain(|&n| n != node.0);
            claims.is_empty()
        };
        if done {
            self.live.remove(&seq);
        }
    }

    /// Release: unwind every remaining claim of `seq` (an aborted
    /// acquire backs off without wedging any queue). No-op for a
    /// ticket already fully committed.
    ///
    /// NOTE: the current datapath never aborts a reservation — the
    /// protocol's live back-off is the grant re-arbitration (a
    /// non-front requester simply holds nothing), and every claim
    /// retires through [`ResvLedger::commit`]. This is the teardown
    /// hook for a future abort path (e.g. reset/flush of an in-flight
    /// multicast); it is exercised only by this module's unit tests.
    /// Caution for that future caller: re-reserving after a release
    /// keeps issuing fresh (larger) sequence numbers, so the
    /// sorted-queue invariant is preserved — never re-insert a
    /// released seq.
    pub fn release(&mut self, seq: ResvSeq) {
        if let Some(claims) = self.live.remove(&seq) {
            for n in claims {
                if let Some(pos) = self.queues[n].iter().position(|&s| s == seq) {
                    self.queues[n].remove(pos);
                    self.stats.released_claims += 1;
                }
            }
        }
    }

    /// Release the claims of `seq` at `node` and every node its fork
    /// tree would traverse *below* `node` (re-walking the routing
    /// oracle with the leg's destination set as seen at `node`).
    ///
    /// This is the request-timeout unwind (`XbarCfg::req_timeout`): the
    /// timed-out crossbar retires its leg with DECERR, so its own claim
    /// and the claims of the never-to-arrive downstream legs must
    /// unwind — but sibling legs forked at an upstream node are still
    /// in flight, so a global [`ResvLedger::release`] would corrupt
    /// *their* queues. None of the subtree's claims can have committed
    /// (the AW never forked at `node`), so every one is still queued.
    pub fn release_subtree(
        &mut self,
        node: ResvNode,
        seq: ResvSeq,
        dest: &AddrSet,
        exclude: Option<(u64, u64)>,
        window: Option<(u64, u64)>,
    ) {
        let mut sub = Vec::new();
        self.walk(node.0, dest, exclude, window, &mut sub);
        for n in sub {
            if let Some(pos) = self.queues[n].iter().position(|&s| s == seq) {
                self.queues[n].remove(pos);
                self.stats.released_claims += 1;
            }
            let done = match self.live.get_mut(&seq) {
                Some(claims) => {
                    claims.retain(|&c| c != n);
                    claims.is_empty()
                }
                None => false,
            };
            if done {
                self.live.remove(&seq);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::axi::addr_map::{AddrMap, AddrRule};

    const BASE: u64 = 0x0100_0000;
    const STRIDE: u64 = 0x4_0000;

    fn ep_rule(i: usize, slave: usize) -> AddrRule {
        AddrRule::new(
            BASE + i as u64 * STRIDE,
            BASE + (i as u64 + 1) * STRIDE,
            slave,
            &format!("ep{i}"),
        )
        .with_mcast()
    }

    /// Two leaves of two endpoints each under one root — the smallest
    /// fabric with an inter-level route.
    fn tree_ledger() -> (ResvLedger, [ResvNode; 3]) {
        let mut led = ResvLedger::new();
        let mut leaves = Vec::new();
        for g in 0..2usize {
            let rules = vec![ep_rule(2 * g, 0), ep_rule(2 * g + 1, 1)];
            let mut cfg = XbarCfg::new(
                &format!("leaf{g}"),
                3,
                3,
                AddrMap::new(rules, 3).unwrap(),
            );
            cfg.default_slave = Some(2);
            cfg.local_scope = Some((
                BASE + 2 * g as u64 * STRIDE,
                BASE + 2 * (g as u64 + 1) * STRIDE,
            ));
            leaves.push(led.register(&cfg));
        }
        let rules = (0..2)
            .map(|g| {
                AddrRule::new(
                    BASE + 2 * g as u64 * STRIDE,
                    BASE + 2 * (g + 1) as u64 * STRIDE,
                    g as usize,
                    &format!("child{g}"),
                )
                .with_mcast()
            })
            .collect();
        let root = led.register(&XbarCfg::new("root", 2, 2, AddrMap::new(rules, 2).unwrap()));
        led.wire(leaves[0], 2, root);
        led.wire(leaves[1], 2, root);
        led.wire(root, 0, leaves[0]);
        led.wire(root, 1, leaves[1]);
        (led, [leaves[0], leaves[1], root])
    }

    fn all_eps() -> AddrSet {
        AddrSet::new(BASE, 3 * STRIDE)
    }

    #[test]
    fn reserve_claims_every_traversed_node() {
        let (mut led, [l0, l1, root]) = tree_ledger();
        let seq = led.reserve(l0, &all_eps(), None, None);
        // entry leaf + root + the sibling leaf; the source leaf is not
        // revisited (the exclude scope prunes the echo at the root)
        for n in [l0, root, l1] {
            assert_eq!(led.queue_len(n), 1);
            assert!(led.is_front(n, seq));
        }
        assert_eq!(led.live_tickets(), 1);
    }

    #[test]
    fn local_multicast_claims_only_its_leaf() {
        let (mut led, [l0, l1, root]) = tree_ledger();
        // endpoints {0,1} both live under leaf 0
        let seq = led.reserve(l0, &AddrSet::new(BASE, STRIDE), None, None);
        assert!(led.is_front(l0, seq));
        assert_eq!(led.queue_len(root), 0);
        assert_eq!(led.queue_len(l1), 0);
    }

    #[test]
    fn tickets_commit_in_global_order_per_node() {
        let (mut led, [l0, l1, root]) = tree_ledger();
        let a = led.reserve(l0, &all_eps(), None, None);
        let b = led.reserve(l1, &all_eps(), None, None);
        assert!(a < b, "tickets are globally ordered");
        // b is blocked everywhere a still holds the front
        assert!(!led.is_front(l1, b), "b entered after a claimed leaf 1");
        led.commit(l0, a);
        led.commit(root, a);
        assert!(!led.is_front(l1, b));
        led.commit(l1, a);
        assert_eq!(led.live_tickets(), 1);
        assert!(led.is_front(l1, b));
        led.commit(l1, b);
        led.commit(root, b);
        led.commit(l0, b);
        assert_eq!(led.live_tickets(), 0);
        assert_eq!(led.stats.reserved, 2);
        assert_eq!(led.stats.committed_claims, 6);
        assert_eq!(led.stats.max_live, 2);
    }

    #[test]
    #[should_panic(expected = "out-of-order commit")]
    fn out_of_order_commit_panics() {
        let (mut led, [l0, l1, _root]) = tree_ledger();
        let _a = led.reserve(l0, &all_eps(), None, None);
        let b = led.reserve(l1, &all_eps(), None, None);
        led.commit(l1, b); // a holds the front at leaf 1
    }

    #[test]
    fn release_subtree_unwinds_only_the_timed_out_leg() {
        let (mut led, [l0, l1, root]) = tree_ledger();
        let a = led.reserve(l0, &all_eps(), None, None);
        let b = led.reserve(l1, &all_eps(), None, None);
        led.commit(l0, a);
        led.commit(root, a);
        // a's leg into leaf 1 times out; only that claim unwinds
        led.release_subtree(l1, a, &AddrSet::new(BASE + 2 * STRIDE, STRIDE), None, None);
        assert_eq!(led.stats.released_claims, 1);
        assert_eq!(led.live_tickets(), 1);
        // b now owns every front and proceeds normally
        assert!(led.is_front(l1, b));
        led.commit(l1, b);
        led.commit(root, b);
        led.commit(l0, b);
        assert_eq!(led.live_tickets(), 0);
    }

    #[test]
    fn release_unwinds_remaining_claims() {
        let (mut led, [l0, l1, root]) = tree_ledger();
        let a = led.reserve(l0, &all_eps(), None, None);
        let b = led.reserve(l1, &all_eps(), None, None);
        led.commit(l0, a);
        led.release(a); // back off: root + leaf-1 claims unwind
        assert!(led.is_front(root, b));
        assert!(led.is_front(l1, b));
        assert_eq!(led.live_tickets(), 1);
        assert_eq!(led.stats.released_claims, 2);
    }
}

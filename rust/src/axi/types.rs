//! AXI channel payload types at beat granularity.
//!
//! The simulator models the five AXI channels the paper discusses: AW, W
//! and B for writes (where multicast lives), AR and R for reads. Beats
//! carry routing metadata only — the *functional* bytes are moved by the
//! memory substrate at transaction completion (see `occamy::mem`), which
//! keeps the cycle loop allocation-free.

use crate::axi::mcast::AddrSet;
use crate::sim::Chan;
use crate::util::inline_vec::InlineVec;

pub use crate::sim::link::LinkId;

/// Inline capacity of per-transaction fork-target lists (§Perf): sized
/// for the widest fork in the shipped topologies (the 16-endpoint flat
/// crossbar plus a default route). Wider forks spill to the heap and
/// stay correct — they just lose the allocation-free fast path.
pub const FORK_INLINE: usize = 17;

/// Slave-port set of one transaction (fork destinations), inline up to
/// [`FORK_INLINE`] entries.
pub type SlaveVec = InlineVec<usize, FORK_INLINE>;

/// Pool of AXI links shared by a component graph (crossbars, endpoint
/// models, peripherals). All link access is through typed [`LinkId`]
/// handles — see `sim::link`.
pub type LinkPool = crate::sim::link::Pool<AxiLink>;

/// Byte address in the global memory map.
pub type Addr = u64;

/// AXI transaction ID (as seen on one port).
pub type AxiId = u16;

/// Globally unique transaction tag, assigned by the issuing master.
/// Used for B/R routing in the model and for trace correlation — the
/// RTL equivalent is the ID-prepending each mux stage performs.
pub type Txn = u64;

/// AXI write/read response codes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Resp {
    Okay,
    ExOkay,
    SlvErr,
    DecErr,
}

impl Resp {
    /// The paper's B-join merge rule: any SLVERR/DECERR ⇒ SLVERR;
    /// EXOKAY is disallowed for multicast (exclusive mcast is
    /// unsupported), so the reduction is a simple OR over error bits.
    pub fn join(self, other: Resp) -> Resp {
        match (self, other) {
            (Resp::Okay, Resp::Okay) => Resp::Okay,
            (Resp::ExOkay, o) | (o, Resp::ExOkay) => {
                // exclusive responses are demoted on join
                if o == Resp::Okay || o == Resp::ExOkay {
                    Resp::Okay
                } else {
                    Resp::SlvErr
                }
            }
            _ => Resp::SlvErr,
        }
    }

    pub fn is_err(self) -> bool {
        matches!(self, Resp::SlvErr | Resp::DecErr)
    }
}

/// AW-channel beat: one write-burst request.
#[derive(Debug, Clone)]
pub struct AwBeat {
    pub id: AxiId,
    /// Destination address set. `mask == 0` ⇒ plain unicast (fully
    /// backward compatible: the mask travels in `aw_user`).
    pub dest: AddrSet,
    /// Number of data beats in the burst (AxLEN + 1).
    pub beats: u32,
    /// Bytes per beat (bus width; AxSIZE decoded).
    pub beat_bytes: u32,
    /// `aw.is_mcast` — selects the mux datapath (fig. 2b orange logic).
    pub is_mcast: bool,
    /// Hierarchical exclude scope: an aligned region of `dest` already
    /// served at an upstream hop and to be pruned downstream (see
    /// `xbar` module docs).
    pub exclude: Option<(Addr, Addr)>,
    /// Ring-routing include window: when set, only the members of
    /// `dest` inside this aligned interval are still to be served by
    /// this leg — the complement travels (or was served) on other
    /// legs. Orthogonal to `exclude` (which prunes a *subset already
    /// served upstream*): windows only ever shrink by interval
    /// intersection as a beat walks a ring, so they stay a single
    /// interval where accumulated excludes would go disjoint. `None`
    /// on every non-ring fabric — the classic decode path is taken
    /// verbatim (see `XbarCfg::ring`).
    pub window: Option<(Addr, Addr)>,
    /// Issuing master port on the current crossbar.
    pub src: usize,
    /// Global transaction tag.
    pub txn: Txn,
    /// Fabric-wide reservation ticket (end-to-end multicast ordering,
    /// `XbarCfg::e2e_mcast_order`): stamped by the entry crossbar when
    /// the two-phase reservation protocol is active and carried on
    /// every forwarded leg, so downstream crossbars gate their commit
    /// on the fabric-wide claim order (see `axi::resv`). `None` on
    /// plain unicast traffic and whenever the protocol is off — the
    /// RTL equivalent is a small side-band tag in `aw_user` next to
    /// the multicast mask.
    pub ticket: Option<u64>,
    /// In-network reduction group (`XbarCfg::fabric_reduce`): a tagged
    /// burst converges toward its (unicast) destination and is
    /// combined with its group peers at every fabric join point (see
    /// [`crate::axi::reduce`]). Like the multicast mask and the
    /// reservation ticket, the tag travels in `aw_user`; `None` on all
    /// non-reduction traffic.
    pub reduce: Option<crate::axi::reduce::RedTag>,
}

impl AwBeat {
    pub fn bytes(&self) -> u64 {
        self.beats as u64 * self.beat_bytes as u64
    }
}

/// W-channel beat. Data itself is moved functionally at completion; the
/// beat only carries the burst-position metadata the fabric needs.
#[derive(Debug, Clone, Copy)]
pub struct WBeat {
    pub last: bool,
    pub src: usize,
    pub txn: Txn,
}

/// B-channel beat: write response.
#[derive(Debug, Clone, Copy)]
pub struct BBeat {
    pub id: AxiId,
    pub resp: Resp,
    pub txn: Txn,
}

/// AR-channel beat: read-burst request (reads are always unicast).
#[derive(Debug, Clone, Copy)]
pub struct ArBeat {
    pub id: AxiId,
    pub addr: Addr,
    pub beats: u32,
    pub beat_bytes: u32,
    pub src: usize,
    pub txn: Txn,
}

/// R-channel beat: read data.
#[derive(Debug, Clone, Copy)]
pub struct RBeat {
    pub id: AxiId,
    pub last: bool,
    pub resp: Resp,
    pub txn: Txn,
}

/// One AXI link (the wire bundle between a master and a slave port):
/// request channels flow master→slave, response channels slave→master.
#[derive(Debug)]
pub struct AxiLink {
    pub aw: Chan<AwBeat>,
    pub w: Chan<WBeat>,
    pub b: Chan<BBeat>,
    pub ar: Chan<ArBeat>,
    pub r: Chan<RBeat>,
}

impl AxiLink {
    /// `depth` is the FIFO depth of every channel (2 models a standard
    /// skid-buffered register slice sustaining one beat per cycle).
    pub fn new(depth: usize) -> AxiLink {
        AxiLink {
            aw: Chan::new(depth),
            w: Chan::new(depth),
            b: Chan::new(depth),
            ar: Chan::new(depth),
            r: Chan::new(depth.max(4)),
        }
    }

    /// A die-to-die link: every channel gains the SerDes pipeline
    /// latency, and the data channels (W master→slave, R slave→master)
    /// additionally serialize at one beat per `width_ratio` cycles —
    /// the on-die wide beat occupies the narrow physical lanes for
    /// that long. Address/response channels keep full rate (they are
    /// narrow sideband signals on the PHY). Channel depths grow to
    /// cover the bandwidth-delay product so a rate-1 D2D hop can still
    /// stream, and `(width_ratio, latency) = (1, 1)` is bit-identical
    /// to [`AxiLink::new`].
    pub fn d2d(params: &crate::sim::link::D2dParams) -> AxiLink {
        let lat = params.latency;
        let depth = params.depth.max(lat as usize);
        AxiLink {
            aw: Chan::with_d2d(depth, lat, 1),
            w: Chan::with_d2d(depth, lat, params.width_ratio),
            b: Chan::with_d2d(depth, lat, 1),
            ar: Chan::with_d2d(depth, lat, 1),
            r: Chan::with_d2d(depth.max(4), lat, params.width_ratio),
        }
    }

    /// Advance all channel clock edges.
    pub fn tick(&mut self) {
        self.aw.tick();
        self.w.tick();
        self.b.tick();
        self.ar.tick();
        self.r.tick();
    }

    /// Total beats moved (progress metric for the deadlock watchdog).
    pub fn moved(&self) -> u64 {
        self.aw.popped + self.w.popped + self.b.popped + self.ar.popped + self.r.popped
    }

    /// Any beat currently visible to a consumer — or in-flight D2D
    /// state (delay-pipe beats, serializer cooldowns) that needs
    /// further clock edges to progress? (computed right after `tick`
    /// while the struct is cache-hot — drives the idle-skips; D2D
    /// in-flight state must keep the link in the active set or beats
    /// inside the PHY pipeline would never mature).
    #[inline]
    pub fn any_visible(&self) -> bool {
        self.aw.visible() > 0
            || self.w.visible() > 0
            || self.b.visible() > 0
            || self.ar.visible() > 0
            || self.r.visible() > 0
            || self.aw.needs_tick()
            || self.w.needs_tick()
            || self.b.needs_tick()
            || self.ar.needs_tick()
            || self.r.needs_tick()
    }

    pub fn is_idle(&self) -> bool {
        self.aw.idle() && self.w.idle() && self.b.idle() && self.ar.idle() && self.r.idle()
    }

    // ---- cut-link support (sim::parallel) ----
    //
    // A link whose two endpoint components land in different thread
    // partitions is split into a *master half* (what the AXI master
    // endpoint touches: AW/W/AR producer ends + B/R consumer ends) and
    // a *slave half* (the complement). Each half is a plain `AxiLink`
    // living at the same pool slot of its shard's pool, so components
    // keep indexing by the global `LinkId` transparently.

    /// Split into `(master half, slave half)`.
    pub fn split_cut(self) -> (AxiLink, AxiLink) {
        let (aw_p, aw_c) = self.aw.split_cut();
        let (w_p, w_c) = self.w.split_cut();
        let (b_p, b_c) = self.b.split_cut();
        let (ar_p, ar_c) = self.ar.split_cut();
        let (r_p, r_c) = self.r.split_cut();
        let master = AxiLink {
            aw: aw_p,
            w: w_p,
            b: b_c,
            ar: ar_p,
            r: r_c,
        };
        let slave = AxiLink {
            aw: aw_c,
            w: w_c,
            b: b_p,
            ar: ar_c,
            r: r_p,
        };
        (master, slave)
    }

    /// Clock edge across a split link — bit-equivalent to
    /// [`AxiLink::tick`] on the joined link.
    pub fn tick_cut(master: &mut AxiLink, slave: &mut AxiLink) {
        Chan::tick_cut(&mut master.aw, &mut slave.aw);
        Chan::tick_cut(&mut master.w, &mut slave.w);
        Chan::tick_cut(&mut slave.b, &mut master.b);
        Chan::tick_cut(&mut master.ar, &mut slave.ar);
        Chan::tick_cut(&mut slave.r, &mut master.r);
    }

    /// Reassemble a split link (inverse of [`AxiLink::split_cut`]).
    pub fn join_cut(master: AxiLink, slave: AxiLink) -> AxiLink {
        AxiLink {
            aw: Chan::join_cut(master.aw, slave.aw),
            w: Chan::join_cut(master.w, slave.w),
            b: Chan::join_cut(slave.b, master.b),
            ar: Chan::join_cut(master.ar, slave.ar),
            r: Chan::join_cut(slave.r, master.r),
        }
    }
}

impl crate::sim::link::Link for AxiLink {
    fn tick(&mut self) {
        AxiLink::tick(self)
    }
    fn any_visible(&self) -> bool {
        AxiLink::any_visible(self)
    }
    fn is_idle(&self) -> bool {
        AxiLink::is_idle(self)
    }
    fn moved(&self) -> u64 {
        AxiLink::moved(self)
    }
}

impl crate::sim::parallel::CutLink for AxiLink {
    fn split_cut(self) -> (AxiLink, AxiLink) {
        AxiLink::split_cut(self)
    }
    fn tick_cut(master: &mut AxiLink, slave: &mut AxiLink) {
        AxiLink::tick_cut(master, slave)
    }
    fn join_cut(master: AxiLink, slave: AxiLink) -> AxiLink {
        AxiLink::join_cut(master, slave)
    }
    fn dummy() -> AxiLink {
        // placeholder for pool slots owned by other shards; depth is
        // irrelevant — no component ever touches a dummy
        AxiLink::new(1)
    }
}

/// AXI bursts must not cross a 4 KiB address boundary (spec A3.4.1);
/// combined with the bus width this bounds the beats per burst.
pub const AXI_BOUNDARY: u64 = 4096;

/// Split a transfer `[addr, addr+bytes)` into AXI-legal bursts for a
/// `beat_bytes`-wide bus: each burst stays within a 4 KiB page and a
/// `max_beats` cap (AxLEN ≤ 255).
pub fn split_bursts(addr: Addr, bytes: u64, beat_bytes: u32, max_beats: u32) -> Vec<(Addr, u32)> {
    assert!(beat_bytes.is_power_of_two());
    let mut out = Vec::new();
    let mut cur = addr;
    let end = addr + bytes;
    while cur < end {
        let page_end = (cur / AXI_BOUNDARY + 1) * AXI_BOUNDARY;
        let chunk_end = end.min(page_end);
        let chunk = chunk_end - cur;
        let beats = chunk.div_ceil(beat_bytes as u64).min(max_beats as u64) as u32;
        let burst_bytes = (beats as u64 * beat_bytes as u64).min(chunk);
        out.push((cur, beats));
        cur += burst_bytes;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resp_join_rules() {
        use Resp::*;
        assert_eq!(Okay.join(Okay), Okay);
        assert_eq!(Okay.join(SlvErr), SlvErr);
        assert_eq!(DecErr.join(Okay), SlvErr);
        assert_eq!(SlvErr.join(DecErr), SlvErr);
        // exclusive demotion on join
        assert_eq!(ExOkay.join(Okay), Okay);
        assert_eq!(ExOkay.join(DecErr), SlvErr);
    }

    #[test]
    fn burst_split_respects_4k_boundary() {
        // 10 KiB starting 1 KiB below a boundary, 64-byte beats
        let bursts = split_bursts(0x1C00, 10 * 1024, 64, 256);
        let mut total = 0u64;
        for (addr, beats) in &bursts {
            let bytes = *beats as u64 * 64;
            assert!(
                addr / AXI_BOUNDARY == (addr + bytes - 1) / AXI_BOUNDARY,
                "burst at {addr:#x} ({bytes}B) crosses 4K"
            );
            total += bytes;
        }
        assert_eq!(total, 10 * 1024);
    }

    #[test]
    fn burst_split_max_beats() {
        let bursts = split_bursts(0, 32 * 1024, 64, 64);
        assert_eq!(bursts.len(), 8);
        assert!(bursts.iter().all(|&(_, b)| b == 64));
        assert_eq!(bursts[1].0, 4096);
    }

    #[test]
    fn burst_split_single_beat() {
        let bursts = split_bursts(0x100, 8, 8, 256);
        assert_eq!(bursts, vec![(0x100, 1)]);
    }

    #[test]
    fn split_link_routes_request_and_response_channels() {
        // master half owns the producer ends of AW/W/AR and the
        // consumer ends of B/R; responses flow the other way.
        let (mut m, mut s) = AxiLink::new(2).split_cut();
        m.aw.push(AwBeat {
            id: 0,
            dest: AddrSet::unicast(0x1000),
            beats: 1,
            beat_bytes: 64,
            is_mcast: false,
            exclude: None,
            window: None,
            src: 0,
            txn: 7,
            ticket: None,
            reduce: None,
        });
        s.b.push(BBeat {
            id: 0,
            resp: Resp::Okay,
            txn: 7,
        });
        AxiLink::tick_cut(&mut m, &mut s);
        assert_eq!(s.aw.front().map(|a| a.txn), Some(7), "AW reaches slave");
        assert_eq!(m.b.front().map(|b| b.txn), Some(7), "B reaches master");
        assert!(s.aw.pop().is_some());
        assert!(m.b.pop().is_some());
        // moved() is counted on the popping half only — the global sum
        // over both halves equals the whole-link count
        assert_eq!(m.moved() + s.moved(), 2);
        AxiLink::tick_cut(&mut m, &mut s);
        let joined = AxiLink::join_cut(m, s);
        assert_eq!(joined.moved(), 2);
        assert!(joined.is_idle());
    }

    #[test]
    fn link_moved_counts_progress() {
        let mut l = AxiLink::new(2);
        l.aw.push(AwBeat {
            id: 0,
            dest: AddrSet::unicast(0x1000),
            beats: 1,
            beat_bytes: 64,
            is_mcast: false,
            exclude: None,
            window: None,
            src: 0,
            txn: 1,
            ticket: None,
            reduce: None,
        });
        l.tick();
        assert_eq!(l.moved(), 0);
        l.aw.pop();
        assert_eq!(l.moved(), 1);
        assert!(!l.is_idle() || l.aw.is_empty());
    }
}

//! The paper's §II-A contribution: a multicast-capable AXI crossbar.
//!
//! Module map (mirrors fig. 2):
//!
//! * [`types`] — AXI channel beats (AW/W/B/AR/R), responses, links.
//! * [`mcast`] — the multi-address *mask-form encoding* (fig. 1): an
//!   `(addr, mask)` pair where mask bits are address don't-cares, plus
//!   the IFE→MFE conversion and set-intersection algebra.
//! * [`addr_map`] — address rules and the extended decoder producing
//!   `aw_select` (which slaves are targeted + the per-slave subset).
//! * [`demux`] — per-master logic (fig. 2d): ID order table, the
//!   multicast/unicast mutual-exclusion stalls, AW/W fork and B join.
//! * [`mux`] — per-slave logic (fig. 2b): unicast vs multicast datapath
//!   arbitration, the lock/commit protocol (fig. 2e deadlock avoidance).
//! * [`xbar`] — the N×M crossbar composing demuxes and muxes, the
//!   grant/commit fabric, and AR/R read routing.
//! * [`resv`] — the fabric-wide two-phase reservation ledger lifting
//!   lock/commit to end-to-end multicast ordering across hierarchy
//!   levels (`XbarCfg::e2e_mcast_order`).
//! * [`reduce`] — in-network reduction: the dual of the multicast
//!   fork. Converging write bursts tagged with a reduction group are
//!   combined at every join point of the fabric
//!   (`XbarCfg::fabric_reduce`), one burst forwarded upstream per
//!   join; membership comes from the same decode oracle the
//!   reservation ledger replays.
//! * [`monitor`] — protocol checkers used by tests.
//! * [`golden`] — reference memory model for traffic equivalence tests.
//! * [`topology`] — declarative builder instantiating arbitrary
//!   hierarchical multi-crossbar graphs (flat, trees, meshes, rings,
//!   tori, rings-of-meshes) over a shared [`types::LinkPool`].
//! * [`costmodel`] — analytic cycle estimator scoring collective
//!   schedule candidates per fabric shape; drives `CollMode::Auto`.

pub mod addr_map;
pub mod costmodel;
pub mod demux;
pub mod golden;
pub mod mcast;
pub mod monitor;
pub mod mux;
pub mod reduce;
pub mod resv;
pub mod topology;
pub mod types;
pub mod xbar;

pub use addr_map::{AddrMap, AddrRule, McastDecode};
pub use costmodel::{CollPattern, CostModel, Plan, PlanChoice, SchedMode, ShapeKind};
pub use mcast::AddrSet;
pub use reduce::{RedNode, RedTag, ReduceHandle, ReduceLedger, ReduceOp};
pub use resv::{ResvHandle, ResvLedger, ResvNode, ResvSeq};
pub use topology::{Topology, TopologyBuilder, TopoShape};
pub use types::*;
pub use xbar::{Xbar, XbarCfg, XbarStats};

//! Per-master demux state (paper fig. 2d).
//!
//! The demux owns three concerns of the multicast extension:
//!
//! * **Ordering stalls** (orange logic): a unicast AW with the same AXI
//!   ID as an outstanding transaction to a *different* slave must stall
//!   (B responses could be joined out of order). Multicast transactions
//!   stall until all outstanding unicasts complete and vice versa;
//!   multiple outstanding multicasts are allowed only when directed to
//!   the *same* master-port set, up to a configurable maximum.
//! * **AW/W forking** (blue logic): a committed multicast AW is forked
//!   to every addressed slave port; W beats are forwarded only when
//!   *all* destinations can accept (`stream_fork` all-ready semantics).
//! * **B joining** (green logic, `stream_join_dynamic`): one B response
//!   is expected per forked AW; the joined response is released to the
//!   master only after every slave responded. Response codes are merged
//!   with [`Resp::join`]; the ID is taken from the first addressed slave
//!   (priority-encoder choice — all forks share the ID anyway).

use std::collections::{HashMap, VecDeque};

use super::addr_map::McastDecode;
use super::mcast::AddrSet;
use super::types::{AwBeat, AxiId, BBeat, Resp, SlaveVec, Txn, FORK_INLINE};
use crate::util::inline_vec::InlineVec;

/// One forked AW headed to a specific slave port.
#[derive(Debug, Clone)]
pub struct TargetAw {
    pub slave: usize,
    pub dest: AddrSet,
    /// Hierarchical routing scope: addresses inside this aligned region
    /// have already been served locally and must be pruned downstream
    /// (see `xbar` docs — the model's equivalent of the RTL's up-rule
    /// decomposition).
    pub exclude: Option<(u64, u64)>,
    /// Ring-routing include window (see [`crate::axi::types::AwBeat`]):
    /// only the members of `dest` inside this interval ride this leg.
    /// `None` everywhere outside ring fabrics.
    pub window: Option<(u64, u64)>,
}

/// Fork-target list of one decoded AW, allocation-free up to
/// [`FORK_INLINE`] destinations (§Perf).
pub type TargetVec = InlineVec<TargetAw, FORK_INLINE>;

/// An AW accepted from the master, decoded, awaiting grant/commit.
#[derive(Debug, Clone)]
pub struct PendingAw {
    pub beat: AwBeat,
    pub targets: TargetVec,
    /// Initial join resp (DECERR if part of the set was unroutable).
    pub resp0: Resp,
}

/// W routing entry: where the next W burst from this master goes.
#[derive(Debug, Clone)]
pub struct WRoute {
    pub txn: Txn,
    pub slaves: SlaveVec,
    pub beats_left: u32,
    pub is_mcast: bool,
    /// In-network reduction sink (`slaves` empty): the burst's beats
    /// are absorbed into the crossbar's combine table instead of being
    /// forwarded, and the B response arrives later by fan-out from the
    /// combined upstream burst (never via `complete_unroutable`).
    pub sink: bool,
    /// One or more destinations were evicted by a completion timeout.
    /// If the slave set drained to empty this way, the remaining W
    /// beats are *dropped* (the SLVERR B was already synthesized via
    /// the join) instead of completing through `complete_unroutable`.
    pub evicted: bool,
}

/// Outcome of [`Demux::evict_route_slave`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Evict {
    /// The leg was removed; other destinations remain on the route.
    Partial,
    /// The leg was removed and the route now has zero destinations —
    /// its remaining W beats must be drained and dropped.
    Emptied,
    /// No live W route carried this slave (the burst already fully
    /// forwarded past the demux); only join/zombie state applies.
    NoRoute,
}

/// B-join bookkeeping for one outstanding write transaction.
#[derive(Debug, Clone)]
pub struct Join {
    pub id: AxiId,
    pub remaining: u32,
    pub resp: Resp,
    pub is_mcast: bool,
    /// Slave set (for the ordering table release).
    pub slaves: SlaveVec,
}

/// Per-ID ordering entry (unicast): slave currently bound to this ID.
#[derive(Debug, Clone, Copy)]
pub struct IdBinding {
    pub slave: usize,
    pub count: u32,
}

/// Why the demux refused to accept an AW this cycle (stats/tracing).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stall {
    None,
    /// unicast blocked: same ID bound to a different slave
    IdConflict,
    /// unicast blocked by outstanding multicast(s)
    UnicastAfterMcast,
    /// multicast blocked by outstanding unicast(s)
    McastAfterUnicast,
    /// multicast blocked: different target set than outstanding mcasts
    McastSetMismatch,
    /// multicast blocked: max outstanding multicasts reached
    McastLimit,
    /// a decoded AW is already waiting for grants
    Pending,
    /// too many outstanding writes overall
    Outstanding,
}

/// The demux state machine for one master port.
#[derive(Debug)]
pub struct Demux {
    pub idx: usize,
    pub max_mcast_outstanding: u32,
    pub max_outstanding: u32,

    pub pending: Option<PendingAw>,
    pub w_queue: VecDeque<WRoute>,
    pub joins: HashMap<Txn, Join>,
    /// Completed joined B responses waiting for the master's B ready.
    pub b_out: VecDeque<BBeat>,

    // ordering state
    pub id_table: HashMap<AxiId, IdBinding>,
    pub outstanding_unicast: u32,
    pub outstanding_mcast: u32,
    /// Target-port set shared by all outstanding multicasts.
    pub mcast_set: SlaveVec,
}

impl Demux {
    pub fn new(idx: usize, max_mcast_outstanding: u32, max_outstanding: u32) -> Demux {
        Demux {
            idx,
            max_mcast_outstanding,
            max_outstanding,
            pending: None,
            w_queue: VecDeque::new(),
            joins: HashMap::new(),
            b_out: VecDeque::new(),
            id_table: HashMap::new(),
            outstanding_unicast: 0,
            outstanding_mcast: 0,
            mcast_set: SlaveVec::new(),
        }
    }

    /// Can a new AW with this shape be accepted this cycle?
    pub fn admit(&self, is_mcast: bool, id: AxiId, slaves: &[usize]) -> Stall {
        if self.pending.is_some() {
            return Stall::Pending;
        }
        if self.outstanding_unicast + self.outstanding_mcast >= self.max_outstanding {
            return Stall::Outstanding;
        }
        if is_mcast {
            if self.outstanding_unicast > 0 {
                return Stall::McastAfterUnicast;
            }
            if self.outstanding_mcast > 0 {
                if self.mcast_set.as_slice() != slaves {
                    return Stall::McastSetMismatch;
                }
                if self.outstanding_mcast >= self.max_mcast_outstanding {
                    return Stall::McastLimit;
                }
            }
        } else {
            if self.outstanding_mcast > 0 {
                return Stall::UnicastAfterMcast;
            }
            if let [slave] = slaves {
                if let Some(b) = self.id_table.get(&id) {
                    if b.slave != *slave {
                        return Stall::IdConflict;
                    }
                }
            }
        }
        Stall::None
    }

    /// Record acceptance of an AW (ordering tables + W route + join).
    pub fn accept(&mut self, beat: &AwBeat, targets: &[TargetAw], resp0: Resp) {
        let slaves: SlaveVec = targets.iter().map(|t| t.slave).collect();
        if beat.is_mcast {
            self.outstanding_mcast += 1;
            self.mcast_set = slaves.clone();
        } else if let Some(&s) = slaves.first() {
            self.outstanding_unicast += 1;
            self.id_table
                .entry(beat.id)
                .and_modify(|b| b.count += 1)
                .or_insert(IdBinding { slave: s, count: 1 });
        } else {
            // fully unroutable unicast still occupies a W slot
            self.outstanding_unicast += 1;
        }
        self.w_queue.push_back(WRoute {
            txn: beat.txn,
            slaves: slaves.clone(),
            beats_left: beat.beats,
            is_mcast: beat.is_mcast,
            sink: false,
            evicted: false,
        });
        self.joins.insert(
            beat.txn,
            Join {
                id: beat.id,
                remaining: slaves.len() as u32,
                resp: resp0,
                is_mcast: beat.is_mcast,
                slaves,
            },
        );
    }

    /// Record acceptance of an AW absorbed by the crossbar's combine
    /// table (in-network reduction, `crate::axi::reduce`): the W burst
    /// drains into the combiner through a sink route and exactly one B
    /// — fanned out from the combined upstream burst — completes the
    /// join. Ordering-wise the transaction is a plain unicast bound to
    /// the group's exit slave, so the ID table and the
    /// multicast/unicast mutual-exclusion stalls behave as if it had
    /// been forwarded there.
    pub fn accept_sink(&mut self, beat: &AwBeat, exit_slave: usize) {
        debug_assert!(!beat.is_mcast, "reduction contributions are unicast");
        self.outstanding_unicast += 1;
        self.id_table
            .entry(beat.id)
            .and_modify(|b| b.count += 1)
            .or_insert(IdBinding {
                slave: exit_slave,
                count: 1,
            });
        self.w_queue.push_back(WRoute {
            txn: beat.txn,
            slaves: SlaveVec::new(),
            beats_left: beat.beats,
            is_mcast: false,
            sink: true,
            evicted: false,
        });
        self.joins.insert(
            beat.txn,
            Join {
                id: beat.id,
                remaining: 1,
                resp: Resp::Okay,
                is_mcast: false,
                slaves: [exit_slave].into_iter().collect(),
            },
        );
    }

    /// Fold one slave's B response into the join; returns the merged B
    /// when all expected responses arrived.
    pub fn join_b(&mut self, txn: Txn, resp: Resp, id: AxiId) -> Option<BBeat> {
        let j = self
            .joins
            .get_mut(&txn)
            .unwrap_or_else(|| panic!("B for unknown txn {txn}"));
        j.resp = j.resp.join(resp);
        debug_assert!(j.remaining > 0);
        j.remaining -= 1;
        let _ = id;
        if j.remaining > 0 {
            return None;
        }
        let j = self.joins.remove(&txn).unwrap();
        // release ordering state
        if j.is_mcast {
            debug_assert!(self.outstanding_mcast > 0);
            self.outstanding_mcast -= 1;
            if self.outstanding_mcast == 0 {
                self.mcast_set.clear();
            }
        } else {
            debug_assert!(self.outstanding_unicast > 0);
            self.outstanding_unicast -= 1;
            if let Some(b) = self.id_table.get_mut(&j.id) {
                b.count -= 1;
                if b.count == 0 {
                    self.id_table.remove(&j.id);
                }
            }
        }
        Some(BBeat {
            id: j.id,
            resp: j.resp,
            txn,
        })
    }

    /// A transaction with zero targets completes immediately with DECERR
    /// (after its W beats are drained).
    pub fn complete_unroutable(&mut self, txn: Txn) -> BBeat {
        let j = self.joins.remove(&txn).expect("unroutable txn must join");
        debug_assert_eq!(j.remaining, 0);
        if j.is_mcast {
            self.outstanding_mcast -= 1;
            if self.outstanding_mcast == 0 {
                self.mcast_set.clear();
            }
        } else {
            self.outstanding_unicast -= 1;
        }
        BBeat {
            id: j.id,
            resp: Resp::DecErr,
            txn,
        }
    }

    /// Completion-timeout unwinding: stop routing the in-flight W burst
    /// of `txn` to `slave`. The caller is responsible for folding the
    /// synthesized SLVERR into the join (via [`Demux::join_b`]), for
    /// removing the mux-side W-order entry, and for zombie-marking the
    /// transaction so a late real B from the slave is dropped.
    ///
    /// Cold path — only runs when a timeout fires.
    pub fn evict_route_slave(&mut self, txn: Txn, slave: usize) -> Evict {
        let Some(r) = self.w_queue.iter_mut().find(|r| r.txn == txn) else {
            return Evict::NoRoute;
        };
        if r.sink || !r.slaves.iter().any(|&s| s == slave) {
            return Evict::NoRoute;
        }
        r.slaves = r.slaves.iter().copied().filter(|&s| s != slave).collect();
        r.evicted = true;
        if r.slaves.is_empty() {
            Evict::Emptied
        } else {
            Evict::Partial
        }
    }

    /// Total writes in flight (for idle checks).
    pub fn busy(&self) -> bool {
        self.pending.is_some() || !self.w_queue.is_empty() || !self.joins.is_empty()
    }
}

/// Build fork targets from a decode result (pure helper shared by the
/// xbar and its tests).
pub fn targets_from_decode(d: &McastDecode) -> Vec<TargetAw> {
    d.targets
        .iter()
        .map(|(s, sub)| TargetAw {
            slave: *s,
            dest: *sub,
            exclude: None,
            window: None,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn aw(txn: Txn, id: AxiId, is_mcast: bool, beats: u32) -> AwBeat {
        AwBeat {
            id,
            dest: AddrSet::unicast(0x1000),
            beats,
            beat_bytes: 64,
            is_mcast,
            exclude: None,
            window: None,
            src: 0,
            txn,
            ticket: None,
            reduce: None,
        }
    }

    fn tgts(slaves: &[usize]) -> Vec<TargetAw> {
        slaves
            .iter()
            .map(|&s| TargetAw {
                slave: s,
                dest: AddrSet::unicast(0x1000),
                exclude: None,
                window: None,
            })
            .collect()
    }

    #[test]
    fn unicast_same_id_same_slave_ok() {
        let mut d = Demux::new(0, 2, 16);
        d.accept(&aw(1, 5, false, 4), &tgts(&[2]), Resp::Okay);
        assert_eq!(d.admit(false, 5, &[2]), Stall::None);
        assert_eq!(d.admit(false, 5, &[3]), Stall::IdConflict);
        assert_eq!(d.admit(false, 6, &[3]), Stall::None);
    }

    #[test]
    fn mcast_blocks_until_unicast_drains() {
        let mut d = Demux::new(0, 2, 16);
        d.accept(&aw(1, 0, false, 1), &tgts(&[1]), Resp::Okay);
        assert_eq!(d.admit(true, 0, &[0, 1]), Stall::McastAfterUnicast);
        let b = d.join_b(1, Resp::Okay, 0).expect("single B completes");
        assert_eq!(b.resp, Resp::Okay);
        assert_eq!(d.admit(true, 0, &[0, 1]), Stall::None);
    }

    #[test]
    fn unicast_blocks_while_mcast_outstanding() {
        let mut d = Demux::new(0, 2, 16);
        d.accept(&aw(1, 0, true, 1), &tgts(&[0, 1]), Resp::Okay);
        assert_eq!(d.admit(false, 1, &[0]), Stall::UnicastAfterMcast);
        assert!(d.join_b(1, Resp::Okay, 0).is_none());
        let b = d.join_b(1, Resp::Okay, 0).unwrap();
        assert_eq!(b.resp, Resp::Okay);
        assert_eq!(d.admit(false, 1, &[0]), Stall::None);
    }

    #[test]
    fn concurrent_mcast_same_set_only() {
        let mut d = Demux::new(0, 2, 16);
        d.accept(&aw(1, 0, true, 1), &tgts(&[0, 1]), Resp::Okay);
        assert_eq!(d.admit(true, 0, &[0, 1]), Stall::None);
        assert_eq!(d.admit(true, 0, &[0, 2]), Stall::McastSetMismatch);
        d.accept(&aw(2, 0, true, 1), &tgts(&[0, 1]), Resp::Okay);
        assert_eq!(d.admit(true, 0, &[0, 1]), Stall::McastLimit);
    }

    #[test]
    fn b_join_merges_errors_to_slverr() {
        let mut d = Demux::new(0, 2, 16);
        d.accept(&aw(9, 3, true, 1), &tgts(&[0, 1, 2]), Resp::Okay);
        assert!(d.join_b(9, Resp::Okay, 3).is_none());
        assert!(d.join_b(9, Resp::DecErr, 3).is_none());
        let b = d.join_b(9, Resp::Okay, 3).unwrap();
        assert_eq!(b.resp, Resp::SlvErr);
        assert_eq!(b.id, 3);
        assert!(!d.busy() || d.w_queue.len() > 0);
    }

    #[test]
    fn decerr_seed_from_partial_decode() {
        let mut d = Demux::new(0, 2, 16);
        d.accept(&aw(4, 1, true, 1), &tgts(&[0]), Resp::DecErr);
        let b = d.join_b(4, Resp::Okay, 1).unwrap();
        assert_eq!(b.resp, Resp::SlvErr);
    }

    #[test]
    fn unroutable_completes_decerr() {
        let mut d = Demux::new(0, 2, 16);
        d.accept(&aw(7, 2, false, 2), &tgts(&[]), Resp::DecErr);
        let b = d.complete_unroutable(7);
        assert_eq!(b.resp, Resp::DecErr);
        assert_eq!(d.outstanding_unicast, 0);
    }

    #[test]
    fn sink_accept_joins_on_the_fanned_b() {
        let mut d = Demux::new(0, 2, 16);
        d.accept_sink(&aw(11, 4, false, 3), 2);
        assert_eq!(d.outstanding_unicast, 1);
        // ordering: the sink binds its ID to the exit slave
        assert_eq!(d.admit(false, 4, &[2]), Stall::None);
        assert_eq!(d.admit(false, 4, &[1]), Stall::IdConflict);
        let route = d.w_queue.front().unwrap();
        assert!(route.sink && route.slaves.is_empty());
        assert_eq!(route.beats_left, 3);
        // exactly one B (the fan-out from the combined burst) completes
        let b = d.join_b(11, Resp::Okay, 4).expect("sink joins on one B");
        assert_eq!(b.resp, Resp::Okay);
        assert_eq!(b.id, 4);
        assert_eq!(d.outstanding_unicast, 0);
        assert!(d.id_table.is_empty());
    }

    #[test]
    fn evict_route_slave_unwinds_fork_leg() {
        let mut d = Demux::new(0, 2, 16);
        d.accept(&aw(9, 3, true, 4), &tgts(&[0, 1, 2]), Resp::Okay);
        assert_eq!(d.evict_route_slave(9, 1), Evict::Partial);
        let r = d.w_queue.front().unwrap();
        assert_eq!(r.slaves.as_slice(), &[0, 2]);
        assert!(r.evicted);
        // the timed-out leg still participates in the join with SLVERR
        assert!(d.join_b(9, Resp::SlvErr, 3).is_none());
        assert!(d.join_b(9, Resp::Okay, 3).is_none());
        let b = d.join_b(9, Resp::Okay, 3).unwrap();
        assert_eq!(b.resp, Resp::SlvErr);
        // a slave that never carried the route reports NoRoute
        assert_eq!(d.evict_route_slave(9, 5), Evict::NoRoute);
    }

    #[test]
    fn evict_to_empty_drops_remaining_beats() {
        let mut d = Demux::new(0, 2, 16);
        d.accept(&aw(4, 1, false, 2), &tgts(&[3]), Resp::Okay);
        assert_eq!(d.evict_route_slave(4, 3), Evict::Emptied);
        let r = d.w_queue.front().unwrap();
        assert!(r.slaves.is_empty() && r.evicted && !r.sink);
        // the join is completed by the synthesized SLVERR, not by
        // complete_unroutable (which the evicted flag must bypass)
        let b = d.join_b(4, Resp::SlvErr, 1).unwrap();
        assert_eq!(b.resp, Resp::SlvErr);
        assert_eq!(d.outstanding_unicast, 0);
    }

    #[test]
    fn outstanding_cap() {
        let mut d = Demux::new(0, 2, 2);
        d.accept(&aw(1, 0, false, 1), &tgts(&[0]), Resp::Okay);
        d.accept(&aw(2, 1, false, 1), &tgts(&[1]), Resp::Okay);
        assert_eq!(d.admit(false, 2, &[2]), Stall::Outstanding);
    }
}

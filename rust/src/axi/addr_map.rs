//! Address map + the extended (multicast-capable) address decoder.
//!
//! A crossbar is associated with a set of address rules, each mapping an
//! address interval to a slave port. The paper extends the decoder so a
//! mask-form request produces `aw_select`: the set of slave ports whose
//! rules intersect the request's address set, together with the subset
//! of destination addresses falling within each slave (§II-A).

use super::mcast::{AddrSet, MfeError};
use super::types::Addr;

/// One address rule: `[start, end)` → slave port `slave`.
#[derive(Debug, Clone)]
pub struct AddrRule {
    pub start: Addr,
    pub end: Addr,
    pub slave: usize,
    /// Whether this region may be targeted by multicast requests; such
    /// rules must be power-of-two sized and size-aligned (convertible to
    /// mask form).
    pub mcast: bool,
    pub name: String,
}

impl AddrRule {
    pub fn new(start: Addr, end: Addr, slave: usize, name: &str) -> AddrRule {
        AddrRule {
            start,
            end,
            slave,
            mcast: false,
            name: name.to_string(),
        }
    }

    pub fn with_mcast(mut self) -> AddrRule {
        self.mcast = true;
        self
    }

    pub fn contains(&self, a: Addr) -> bool {
        a >= self.start && a < self.end
    }
}

/// Errors building an address map.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MapError {
    BadMcastRule {
        name: String,
        source: MfeError,
    },
    Overlap { a: String, b: String },
    BadSlave {
        name: String,
        slave: usize,
        n_slaves: usize,
    },
}

impl std::fmt::Display for MapError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MapError::BadMcastRule { name, source } => write!(f, "rule '{name}': {source}"),
            MapError::Overlap { a, b } => write!(f, "rules '{a}' and '{b}' overlap"),
            MapError::BadSlave {
                name,
                slave,
                n_slaves,
            } => write!(f, "rule '{name}' targets slave {slave} >= {n_slaves}"),
        }
    }
}

impl std::error::Error for MapError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            MapError::BadMcastRule { source, .. } => Some(source),
            _ => None,
        }
    }
}

/// Result of multicast decode: the `aw_select` vector.
#[derive(Debug, Clone, Default)]
pub struct McastDecode {
    /// `(slave port, subset of the request's addresses inside it)`,
    /// ordered by slave port index (the priority-encoder order used to
    /// pick the B ID source).
    pub targets: Vec<(usize, AddrSet)>,
    /// Number of requested addresses not covered by any matching rule
    /// (⇒ DECERR contribution on the B join).
    pub uncovered: u64,
}

impl McastDecode {
    pub fn slave_set(&self) -> Vec<usize> {
        self.targets.iter().map(|(s, _)| *s).collect()
    }
}

/// The validated address map of one crossbar.
#[derive(Debug, Clone)]
pub struct AddrMap {
    rules: Vec<AddrRule>,
    /// Mask-form representation of every mcast-capable rule
    /// (precomputed by the "convert all multicast rules to mask form"
    /// logic in the paper).
    mfe: Vec<Option<AddrSet>>,
}

impl AddrMap {
    pub fn new(rules: Vec<AddrRule>, n_slaves: usize) -> Result<AddrMap, MapError> {
        // validate slaves
        for r in &rules {
            if r.slave >= n_slaves {
                return Err(MapError::BadSlave {
                    name: r.name.clone(),
                    slave: r.slave,
                    n_slaves,
                });
            }
        }
        // validate non-overlap (O(n²), maps are small)
        for (i, a) in rules.iter().enumerate() {
            for b in rules.iter().skip(i + 1) {
                if a.start < b.end && b.start < a.end {
                    return Err(MapError::Overlap {
                        a: a.name.clone(),
                        b: b.name.clone(),
                    });
                }
            }
        }
        // precompute MFE for mcast rules
        let mut mfe = Vec::with_capacity(rules.len());
        for r in &rules {
            if r.mcast {
                let s = AddrSet::from_interval(r.start, r.end).map_err(|e| {
                    MapError::BadMcastRule {
                        name: r.name.clone(),
                        source: e,
                    }
                })?;
                mfe.push(Some(s));
            } else {
                mfe.push(None);
            }
        }
        Ok(AddrMap { rules, mfe })
    }

    pub fn rules(&self) -> &[AddrRule] {
        &self.rules
    }

    /// Classic unicast decode: the slave whose rule contains `addr`.
    pub fn decode_unicast(&self, addr: Addr) -> Option<usize> {
        self.rules.iter().find(|r| r.contains(addr)).map(|r| r.slave)
    }

    /// Extended decode (fig. 2a "address decoder" + §II-A): compute
    /// `aw_select` and per-slave subsets for a mask-form request.
    ///
    /// Unicast requests (singleton sets) also pass through here — they
    /// match exactly one rule, multicast-capable or not.
    pub fn decode(&self, req: &AddrSet) -> McastDecode {
        if req.is_singleton() {
            return match self.decode_unicast(req.addr) {
                Some(slave) => McastDecode {
                    targets: vec![(slave, *req)],
                    uncovered: 0,
                },
                None => McastDecode {
                    targets: Vec::new(),
                    uncovered: 1,
                },
            };
        }
        let mut covered = 0u64;
        // collect per-slave subsets; a slave may own several rules, so
        // aggregate by slave index
        let mut per_slave: Vec<(usize, AddrSet)> = Vec::new();
        for (r, mfe) in self.rules.iter().zip(&self.mfe) {
            let Some(rule_set) = mfe else {
                // Non-mcast rule: a multicast request must not target it.
                // Count any overlap as uncovered (⇒ DECERR), matching
                // hardware where only mcast rules enter the extended
                // decoder.
                continue;
            };
            if let Some(sub) = req.intersect(rule_set) {
                covered += sub.count();
                per_slave.push((r.slave, sub));
            }
        }
        per_slave.sort_by_key(|(s, _)| *s);
        // merge subsets landing on the same slave via different rules:
        // keep them as separate entries only if addresses differ; the
        // demux forks one AW per *slave*, so collapse to the union's
        // bounding set is not generally mask-representable — instead we
        // keep the first subset and fold counts. In practice Occamy maps
        // one rule per slave, so this path is exercised only in tests.
        let mut targets: Vec<(usize, AddrSet)> = Vec::new();
        for (s, sub) in per_slave {
            match targets.last() {
                Some((ls, _)) if *ls == s => { /* keep first subset */ }
                _ => targets.push((s, sub)),
            }
        }
        McastDecode {
            targets,
            uncovered: req.count().saturating_sub(covered),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_mini::{check, Config};

    /// Occamy-like map: 4 clusters with 0x4_0000 stride at 0x0100_0000.
    fn occamy4() -> AddrMap {
        let stride = 0x4_0000u64;
        let base = 0x0100_0000u64;
        let mut rules: Vec<AddrRule> = (0..4)
            .map(|i| {
                AddrRule::new(
                    base + i as u64 * stride,
                    base + (i as u64 + 1) * stride,
                    i,
                    &format!("cluster{i}"),
                )
                .with_mcast()
            })
            .collect();
        rules.push(AddrRule::new(0x8000_0000, 0x8040_0000, 4, "llc"));
        AddrMap::new(rules, 5).unwrap()
    }

    #[test]
    fn unicast_decode() {
        let m = occamy4();
        assert_eq!(m.decode_unicast(0x0100_0000), Some(0));
        assert_eq!(m.decode_unicast(0x010C_0004), Some(3));
        assert_eq!(m.decode_unicast(0x8000_0000), Some(4));
        assert_eq!(m.decode_unicast(0x0), None);
    }

    #[test]
    fn mcast_decode_all_clusters() {
        let m = occamy4();
        // broadcast offset 0x40 into all 4 clusters: mask the two
        // cluster-index bits (18 and 19)
        let req = AddrSet::new(0x0100_0040, 0x3 << 18);
        let d = m.decode(&req);
        assert_eq!(d.slave_set(), vec![0, 1, 2, 3]);
        assert_eq!(d.uncovered, 0);
        for (i, (s, sub)) in d.targets.iter().enumerate() {
            assert_eq!(*s, i);
            assert_eq!(sub.enumerate(), vec![0x0100_0040 + (i as u64) * 0x4_0000]);
        }
    }

    #[test]
    fn mcast_decode_subset_of_clusters() {
        let m = occamy4();
        // clusters 2 and 3 only: fix bit 19, mask bit 18
        let req = AddrSet::new(0x0108_0000, 1 << 18);
        let d = m.decode(&req);
        assert_eq!(d.slave_set(), vec![2, 3]);
        assert_eq!(d.uncovered, 0);
    }

    #[test]
    fn mcast_to_nonmcast_region_is_uncovered() {
        let m = occamy4();
        // a masked request in LLC space (not mcast-capable)
        let req = AddrSet::new(0x8000_0000, 1 << 6);
        let d = m.decode(&req);
        assert!(d.targets.is_empty());
        assert_eq!(d.uncovered, 2);
    }

    #[test]
    fn singleton_through_mcast_decoder() {
        let m = occamy4();
        let d = m.decode(&AddrSet::unicast(0x0104_0008));
        assert_eq!(d.slave_set(), vec![1]);
        assert_eq!(d.uncovered, 0);
        let d = m.decode(&AddrSet::unicast(0x4));
        assert!(d.targets.is_empty());
        assert_eq!(d.uncovered, 1);
    }

    #[test]
    fn overlap_rejected() {
        let rules = vec![
            AddrRule::new(0x0, 0x2000, 0, "a"),
            AddrRule::new(0x1000, 0x3000, 1, "b"),
        ];
        assert!(matches!(
            AddrMap::new(rules, 2),
            Err(MapError::Overlap { .. })
        ));
    }

    #[test]
    fn bad_mcast_rule_rejected() {
        let rules = vec![AddrRule::new(0x1000, 0x4000, 0, "bad").with_mcast()];
        assert!(matches!(
            AddrMap::new(rules, 1),
            Err(MapError::BadMcastRule { .. })
        ));
    }

    #[test]
    fn bad_slave_rejected() {
        let rules = vec![AddrRule::new(0x0, 0x1000, 3, "oops")];
        assert!(matches!(AddrMap::new(rules, 2), Err(MapError::BadSlave { .. })));
    }

    #[test]
    fn prop_decode_matches_bruteforce() {
        // decoder subsets must equal brute-force membership per rule
        let m = occamy4();
        check(
            "decode-vs-bruteforce",
            Config::default(),
            |g| {
                // random request over the cluster region bit space
                let mut mask = 0u64;
                for _ in 0..g.u64_below(4) {
                    mask |= 1u64 << (6 + g.u64_below(16)); // bits 6..21
                }
                AddrSet::new(0x0100_0000 | g.u64_below(1 << 21), mask)
            },
            |req| {
                let d = m.decode(req);
                let mut brute_cov = 0u64;
                for addr in req.enumerate() {
                    let slave = m.decode_unicast(addr);
                    match slave {
                        Some(s) => {
                            brute_cov += 1;
                            let entry = d.targets.iter().find(|(ts, _)| *ts == s);
                            match entry {
                                None => return Err(format!("slave {s} missing for {addr:#x}")),
                                Some((_, sub)) => {
                                    if !sub.contains(addr) {
                                        return Err(format!(
                                            "{addr:#x} not in subset {sub} of slave {s}"
                                        ));
                                    }
                                }
                            }
                        }
                        None => {}
                    }
                }
                if req.count() - brute_cov != d.uncovered {
                    return Err(format!(
                        "uncovered {} != brute {}",
                        d.uncovered,
                        req.count() - brute_cov
                    ));
                }
                Ok(())
            },
        );
    }
}

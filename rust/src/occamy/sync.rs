//! Synchronisation peripheral on the narrow network.
//!
//! Clusters notify the barrier with a 1-beat narrow write; once all
//! participants arrived, the unit releases them with an interrupt write
//! to every mailbox — a single **multicast** write when the narrow
//! network has the paper's extension (`narrow_mcast`), or a serial train
//! of unicast writes otherwise (the baseline the paper's multicast
//! interrupts accelerate).

use std::collections::VecDeque;

use super::config::SocConfig;
use crate::axi::mcast::AddrSet;
use crate::axi::types::{AwBeat, AxiLink, Txn, WBeat};
use crate::sim::Cycle;

pub struct BarrierUnit {
    /// Arrivals so far (single barrier id is enough for the workloads;
    /// re-arming is automatic after release).
    pub arrived: u32,
    pub participants: u32,
    /// Release writes queued (destination sets).
    release_q: VecDeque<AddrSet>,
    /// In-flight release writes awaiting B.
    pub b_pending: u32,
    w_pending: Option<Txn>,
    mbox_w: VecDeque<(Txn, u32)>,
    pub releases: u64,
    narrow_bytes: u32,
    use_mcast: bool,
    all_mailboxes: AddrSet,
    mailbox_addrs: Vec<u64>,
    /// Private transaction-tag sequence (see `Cluster::txn_seq`): the
    /// unit owns the nonzero range below `1 << 40`, disjoint from every
    /// cluster's, so tag assignment is order-independent.
    txn_seq: Txn,
}

impl BarrierUnit {
    pub fn new(cfg: &SocConfig) -> BarrierUnit {
        BarrierUnit {
            arrived: 0,
            participants: cfg.n_clusters as u32,
            release_q: VecDeque::new(),
            b_pending: 0,
            w_pending: None,
            mbox_w: VecDeque::new(),
            releases: 0,
            narrow_bytes: cfg.narrow_bytes,
            use_mcast: cfg.narrow_mcast,
            all_mailboxes: cfg.all_mailboxes(),
            mailbox_addrs: (0..cfg.n_clusters).map(|i| cfg.mailbox_addr(i)).collect(),
            txn_seq: 1,
        }
    }

    /// One cycle: `slave` is the link clusters write to; `master` is the
    /// unit's own port into the narrow top crossbar for release IRQs.
    pub fn step(&mut self, _cy: Cycle, slave: &mut AxiLink, master: &mut AxiLink) {
        // collect arrivals
        if let Some(aw) = slave.aw.pop() {
            self.mbox_w.push_back((aw.txn, aw.beats));
        }
        if let Some(w) = slave.w.pop() {
            let (txn, left) = self.mbox_w.front_mut().expect("barrier W without AW");
            *left -= 1;
            debug_assert!(w.last == (*left == 0));
            if *left == 0 {
                let txn = *txn;
                self.mbox_w.pop_front();
                if slave.b.can_push() {
                    slave.b.push(crate::axi::types::BBeat {
                        id: 0,
                        resp: crate::axi::types::Resp::Okay,
                        txn,
                    });
                }
                self.arrived += 1;
                if self.arrived == self.participants {
                    self.arrived = 0;
                    self.releases += 1;
                    if self.use_mcast {
                        self.release_q.push_back(self.all_mailboxes);
                    } else {
                        for &a in &self.mailbox_addrs {
                            self.release_q.push_back(AddrSet::unicast(a));
                        }
                    }
                }
            }
        }
        // drain release-write Bs
        while master.b.pop().is_some() {
            self.b_pending -= 1;
        }
        // send W of the in-flight release
        if let Some(txn) = self.w_pending {
            if master.w.can_push() {
                master.w.push(WBeat {
                    last: true,
                    src: 0,
                    txn,
                });
                self.w_pending = None;
            }
            return;
        }
        // issue next release write
        if let Some(dst) = self.release_q.front().copied() {
            if master.aw.can_push() && master.w.can_push() {
                self.release_q.pop_front();
                let txn = self.txn_seq;
                self.txn_seq += 1;
                master.aw.push(AwBeat {
                    id: 0,
                    dest: dst,
                    beats: 1,
                    beat_bytes: self.narrow_bytes,
                    is_mcast: dst.count() > 1,
                    exclude: None,
                    window: None,
                    src: 0,
                    txn,
                    ticket: None,
                    reduce: None,
                });
                master.w.push(WBeat {
                    last: true,
                    src: 0,
                    txn,
                });
                self.b_pending += 1;
            }
        }
    }

    pub fn busy(&self) -> bool {
        !self.release_q.is_empty() || self.b_pending > 0 || self.w_pending.is_some()
    }

    /// Anything queued on the slave side mid-burst? (Wake condition for
    /// the SoC's peripheral gating — a partial burst itself only moves
    /// on new W beats, so it does not block the event horizon.)
    pub fn pending_input(&self) -> bool {
        !self.mbox_w.is_empty()
    }

    /// Event horizon (§Perf): the unit acts on its own only when it has
    /// release writes to issue; everything else is reactive.
    pub fn next_event(&self, now: Cycle) -> Option<Cycle> {
        if !self.release_q.is_empty() || self.w_pending.is_some() {
            Some(now)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arrive(link: &mut AxiLink, txn: Txn) {
        link.aw.push(AwBeat {
            id: 0,
            dest: AddrSet::unicast(super::super::config::BARRIER_BASE),
            beats: 1,
            beat_bytes: 8,
            is_mcast: false,
            exclude: None,
            window: None,
            src: 0,
            txn,
            ticket: None,
            reduce: None,
        });
        link.w.push(WBeat {
            last: true,
            src: 0,
            txn,
        });
    }

    #[test]
    fn releases_with_single_mcast_when_enabled() {
        let cfg = SocConfig::tiny(4);
        let mut b = BarrierUnit::new(&cfg);
        let mut slave = AxiLink::new(8);
        let mut master = AxiLink::new(8);
        for i in 0..4 {
            arrive(&mut slave, i);
        }
        for cy in 0..40 {
            slave.tick();
            master.tick();
            b.step(cy, &mut slave, &mut master);
        }
        assert_eq!(b.releases, 1);
        // exactly one multicast AW went out
        assert_eq!(master.aw.pushed, 1);
    }

    #[test]
    fn releases_with_unicast_train_when_disabled() {
        let mut cfg = SocConfig::tiny(4);
        cfg.narrow_mcast = false;
        let mut b = BarrierUnit::new(&cfg);
        let mut slave = AxiLink::new(8);
        let mut master = AxiLink::new(8);
        for i in 0..4 {
            arrive(&mut slave, i);
        }
        for cy in 0..200 {
            slave.tick();
            master.tick();
            b.step(cy, &mut slave, &mut master);
            // sink Bs so b_pending drains
            while let Some(aw) = master.aw.pop() {
                master.b.push(crate::axi::types::BBeat {
                    id: 0,
                    resp: crate::axi::types::Resp::Okay,
                    txn: aw.txn,
                });
            }
            let _ = master.w.pop();
        }
        assert_eq!(b.releases, 1);
        assert_eq!(master.aw.popped, 4, "one unicast per cluster");
        assert!(!b.busy());
    }

    #[test]
    fn rearms_for_next_barrier() {
        let cfg = SocConfig::tiny(2);
        let mut b = BarrierUnit::new(&cfg);
        let mut slave = AxiLink::new(8);
        let mut master = AxiLink::new(8);
        for round in 0..3u64 {
            arrive(&mut slave, round * 2);
            arrive(&mut slave, round * 2 + 1);
            for cy in 0..50 {
                slave.tick();
                master.tick();
                b.step(cy, &mut slave, &mut master);
                while let Some(aw) = master.aw.pop() {
                    master.b.push(crate::axi::types::BBeat {
                        id: 0,
                        resp: crate::axi::types::Resp::Okay,
                        txn: aw.txn,
                    });
                }
                let _ = master.w.pop();
            }
        }
        assert_eq!(b.releases, 3);
    }
}

//! Functional memory: the *data* half of the simulation.
//!
//! The cycle-level fabric moves metadata beats; bytes are materialised
//! here when a DMA job completes (or a compute op runs). This split
//! keeps the hot loop allocation-free while the end-to-end example still
//! validates bit-exact matmul results through every data-movement path.

use super::config::{SocConfig, CLUSTER_BASE, CLUSTER_STRIDE, LLC_BASE, MAILBOX_OFFSET};

/// Where a global address lands.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Loc {
    /// Cluster SPM (cluster index, byte offset).
    L1(usize, u64),
    /// Cluster mailbox region (cluster index).
    Mailbox(usize),
    /// LLC (byte offset).
    Llc(u64),
    Unmapped,
}

/// The functional memory of the whole SoC.
pub struct SocMem {
    pub l1: Vec<Vec<u8>>,
    pub llc: Vec<u8>,
    l1_bytes: u64,
    llc_bytes: u64,
    n_clusters: usize,
}

impl SocMem {
    pub fn new(cfg: &SocConfig) -> SocMem {
        SocMem {
            l1: (0..cfg.n_clusters)
                .map(|_| vec![0u8; cfg.l1_bytes as usize])
                .collect(),
            llc: vec![0u8; cfg.llc_bytes as usize],
            l1_bytes: cfg.l1_bytes,
            llc_bytes: cfg.llc_bytes,
            n_clusters: cfg.n_clusters,
        }
    }

    /// Resolve a global address.
    pub fn resolve(&self, addr: u64) -> Loc {
        if addr >= LLC_BASE && addr < LLC_BASE + self.llc_bytes {
            return Loc::Llc(addr - LLC_BASE);
        }
        if addr >= CLUSTER_BASE {
            let rel = addr - CLUSTER_BASE;
            let cl = (rel / CLUSTER_STRIDE) as usize;
            let off = rel % CLUSTER_STRIDE;
            if cl < self.n_clusters {
                if off >= MAILBOX_OFFSET {
                    return Loc::Mailbox(cl);
                }
                if off < self.l1_bytes {
                    return Loc::L1(cl, off);
                }
            }
        }
        Loc::Unmapped
    }

    /// Read `len` bytes from a global address (must be fully mapped and
    /// not cross a region boundary).
    pub fn read(&self, addr: u64, len: usize) -> &[u8] {
        match self.resolve(addr) {
            Loc::L1(cl, off) => &self.l1[cl][off as usize..off as usize + len],
            Loc::Llc(off) => &self.llc[off as usize..off as usize + len],
            other => panic!("read from {addr:#x} ({other:?})"),
        }
    }

    /// Write bytes at a global address.
    pub fn write(&mut self, addr: u64, data: &[u8]) {
        match self.resolve(addr) {
            Loc::L1(cl, off) => {
                self.l1[cl][off as usize..off as usize + data.len()].copy_from_slice(data)
            }
            Loc::Llc(off) => {
                self.llc[off as usize..off as usize + data.len()].copy_from_slice(data)
            }
            Loc::Mailbox(_) => { /* mailbox writes carry no data payload */ }
            Loc::Unmapped => panic!("write to unmapped {addr:#x}"),
        }
    }

    /// The functional effect of a (possibly multicast) DMA copy: read
    /// `bytes` from `src`, write to every address in `dsts`.
    pub fn dma_copy(&mut self, src: u64, dsts: &[u64], bytes: u64) {
        let data = self.read(src, bytes as usize).to_vec();
        for &d in dsts {
            self.write(d, &data);
        }
    }

    /// Reduction combining (the collectives' N-to-1 path): element-wise
    /// `dst[i] += src[i]` over `n` f64 values. `dst` and `src` may live
    /// in different regions; overlapping in-place ranges are a caller
    /// bug (the collective layouts keep contribution slots disjoint).
    pub fn add_f64(&mut self, dst: u64, src: u64, n: usize) {
        let s = self.read_f64(src, n);
        let mut d = self.read_f64(dst, n);
        for (dv, sv) in d.iter_mut().zip(&s) {
            *dv += *sv;
        }
        self.write_f64(dst, &d);
    }

    /// The functional effect of one in-network-reduction contribution
    /// (`axi::reduce`): element-wise `dst[i] = op(dst[i], src[i])`
    /// over `n` f64 lanes. `Sum` reuses [`SocMem::add_f64`]; all ops
    /// are commutative, so the order member contributions complete in
    /// never changes the result on the integer-valued lanes the
    /// collectives use.
    pub fn reduce_f64(&mut self, op: crate::axi::reduce::ReduceOp, dst: u64, src: u64, n: usize) {
        use crate::axi::reduce::ReduceOp;
        match op {
            ReduceOp::Sum => self.add_f64(dst, src, n),
            ReduceOp::Max | ReduceOp::Min => {
                let s = self.read_f64(src, n);
                let mut d = self.read_f64(dst, n);
                for (dv, sv) in d.iter_mut().zip(&s) {
                    *dv = op.apply(*dv, *sv);
                }
                self.write_f64(dst, &d);
            }
        }
    }

    /// Typed helpers for the matmul workload (row-major f64).
    pub fn write_f64(&mut self, addr: u64, vals: &[f64]) {
        let mut buf = Vec::with_capacity(vals.len() * 8);
        for v in vals {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        self.write(addr, &buf);
    }

    pub fn read_f64(&self, addr: u64, n: usize) -> Vec<f64> {
        let raw = self.read(addr, n * 8);
        raw.chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mem() -> SocMem {
        SocMem::new(&SocConfig::tiny(4))
    }

    #[test]
    fn resolve_regions() {
        let m = mem();
        assert_eq!(m.resolve(CLUSTER_BASE), Loc::L1(0, 0));
        assert_eq!(
            m.resolve(CLUSTER_BASE + CLUSTER_STRIDE + 0x40),
            Loc::L1(1, 0x40)
        );
        assert_eq!(
            m.resolve(CLUSTER_BASE + MAILBOX_OFFSET),
            Loc::Mailbox(0)
        );
        assert_eq!(m.resolve(LLC_BASE + 16), Loc::Llc(16));
        assert_eq!(m.resolve(0x0), Loc::Unmapped);
        // beyond configured cluster count
        assert_eq!(m.resolve(CLUSTER_BASE + 10 * CLUSTER_STRIDE), Loc::Unmapped);
    }

    #[test]
    fn rw_roundtrip() {
        let mut m = mem();
        m.write(LLC_BASE + 64, &[1, 2, 3, 4]);
        assert_eq!(m.read(LLC_BASE + 64, 4), &[1, 2, 3, 4]);
        m.write(CLUSTER_BASE + 8, &[9, 9]);
        assert_eq!(m.l1[0][8..10], [9, 9]);
    }

    #[test]
    fn dma_copy_multicast() {
        let mut m = mem();
        m.write(LLC_BASE, &[7u8; 32]);
        let dsts: Vec<u64> = (0..4).map(|i| CLUSTER_BASE + i * CLUSTER_STRIDE).collect();
        m.dma_copy(LLC_BASE, &dsts, 32);
        for i in 0..4 {
            assert_eq!(&m.l1[i][..32], &[7u8; 32]);
        }
    }

    #[test]
    fn f64_helpers() {
        let mut m = mem();
        let vals = [1.5f64, -2.25, 1e-300];
        m.write_f64(CLUSTER_BASE + 128, &vals);
        assert_eq!(m.read_f64(CLUSTER_BASE + 128, 3), vals);
    }

    #[test]
    fn reduce_f64_applies_all_ops() {
        use crate::axi::reduce::ReduceOp;
        let mut m = mem();
        m.write_f64(CLUSTER_BASE, &[1.0, 5.0, -2.0]);
        m.write_f64(LLC_BASE, &[4.0, 2.0, -3.0]);
        m.reduce_f64(ReduceOp::Sum, CLUSTER_BASE, LLC_BASE, 3);
        assert_eq!(m.read_f64(CLUSTER_BASE, 3), vec![5.0, 7.0, -5.0]);
        m.write_f64(CLUSTER_BASE, &[1.0, 5.0, -2.0]);
        m.reduce_f64(ReduceOp::Max, CLUSTER_BASE, LLC_BASE, 3);
        assert_eq!(m.read_f64(CLUSTER_BASE, 3), vec![4.0, 5.0, -2.0]);
        m.write_f64(CLUSTER_BASE, &[1.0, 5.0, -2.0]);
        m.reduce_f64(ReduceOp::Min, CLUSTER_BASE, LLC_BASE, 3);
        assert_eq!(m.read_f64(CLUSTER_BASE, 3), vec![1.0, 2.0, -3.0]);
    }

    #[test]
    fn add_f64_combines_elementwise() {
        let mut m = mem();
        m.write_f64(CLUSTER_BASE, &[1.0, 2.0, 3.0]);
        m.write_f64(LLC_BASE, &[10.0, 20.0, 30.0]);
        m.add_f64(CLUSTER_BASE, LLC_BASE, 3);
        assert_eq!(m.read_f64(CLUSTER_BASE, 3), vec![11.0, 22.0, 33.0]);
        // src untouched
        assert_eq!(m.read_f64(LLC_BASE, 3), vec![10.0, 20.0, 30.0]);
    }
}

//! Cluster DMA engine (the paper's extended Snitch cluster iDMA).
//!
//! A job copies `bytes` from a source address to a (possibly multicast)
//! destination set. The engine:
//!
//! * reads the source through the wide network (AR/R bursts) unless the
//!   source is the cluster's own L1 (read at line rate locally);
//! * streams the data out as AXI write bursts — a multicast destination
//!   produces mask-form AW beats (`aw_user` mask), the fabric forks them;
//! * respects the AXI 4 KiB rule and a configurable burst length, keeps
//!   a bounded number of bursts in flight (separately for reads, unicast
//!   writes and multicast writes — the paper's "configurable maximum
//!   number" of outstanding same-set multicasts), and pipelines
//!   read→write through a bounded staging buffer;
//! * reports completed jobs so the SoC can apply the functional copy.

use std::collections::VecDeque;

use super::config::SocConfig;
use crate::axi::mcast::AddrSet;
use crate::axi::reduce::RedTag;
use crate::axi::types::{split_bursts, ArBeat, AwBeat, AxiLink, Txn, WBeat};
use crate::sim::Cycle;

/// One DMA transfer request.
#[derive(Debug, Clone)]
pub struct DmaJob {
    pub src: u64,
    pub dst: AddrSet,
    pub bytes: u64,
    /// Workload-visible tag (completion tracking).
    pub tag: u64,
    /// In-network-reduction contribution (`axi::reduce`): the write
    /// bursts carry this group tag toward a unicast destination, the
    /// fabric combines them with the group's peers at its join points,
    /// and the functional effect at completion is `dst op= src`
    /// instead of a copy. `None` = plain DMA copy.
    pub red: Option<RedTag>,
}

#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct DmaStats {
    pub jobs: u64,
    pub bytes: u64,
    pub read_beats: u64,
    pub write_beats: u64,
    pub aw_issued: u64,
    pub busy_cycles: u64,
    pub stall_rx_empty: u64,
    pub stall_tx_backpressure: u64,
    /// Error responses (SLVERR/DECERR) observed on B or R — with
    /// fabric timeouts armed, these are the synthesised completions of
    /// faulted transactions (`XbarCfg::req_timeout` / `cpl_timeout`).
    pub err_resps: u64,
}

#[derive(Debug)]
struct Active {
    job: DmaJob,
    setup_left: u32,
    src_local: bool,
    dst_local: bool,
    // read side
    rd_bursts: Vec<(u64, u32)>,
    rd_next: usize,
    rd_inflight: u32,
    rx_bytes: u64,
    rx_total: u64,
    // write side
    wr_bursts: Vec<(u64, u32)>,
    wr_next: usize,
    w_stream: VecDeque<(Txn, u32)>,
    b_pending: u32,
    // local-to-local copy timer
    local_left: u64,
    // any B/R of this job carried SLVERR/DECERR (fault recovery)
    saw_err: bool,
}

/// The engine. One per cluster, attached to the cluster's wide master
/// port.
pub struct DmaEngine {
    pub cluster: usize,
    beat_bytes: u32,
    max_burst: u32,
    setup: u32,
    rd_out: u32,
    wr_out: u32,
    mc_out: u32,
    buf_bytes: u64,
    pub queue: VecDeque<DmaJob>,
    active: Option<Active>,
    pub completed: Vec<DmaJob>,
    /// Tags of completed jobs that saw at least one error response —
    /// the workload-visible face of fault recovery: the job *finishes*
    /// (timeouts synthesised its missing completions) but its data is
    /// not trustworthy.
    pub error_tags: Vec<u64>,
    pub stats: DmaStats,
}

impl DmaEngine {
    pub fn new(cluster: usize, cfg: &SocConfig) -> DmaEngine {
        DmaEngine {
            cluster,
            beat_bytes: cfg.wide_bytes,
            max_burst: cfg.max_burst_beats,
            setup: cfg.dma_setup,
            rd_out: cfg.dma_read_outstanding,
            wr_out: cfg.dma_write_outstanding,
            mc_out: cfg.dma_mcast_outstanding,
            buf_bytes: cfg.dma_buffer_bytes,
            queue: VecDeque::new(),
            active: None,
            completed: Vec::new(),
            error_tags: Vec::new(),
            stats: DmaStats::default(),
        }
    }

    pub fn push(&mut self, job: DmaJob) {
        assert!(
            job.bytes > 0 && job.bytes % self.beat_bytes as u64 == 0,
            "DMA job bytes ({}) must be a positive multiple of the bus width ({})",
            job.bytes,
            self.beat_bytes
        );
        assert!(
            job.red.is_none() || job.dst.is_singleton(),
            "a reduction contribution converges on ONE destination \
             (multicast + reduce on the same job is meaningless)"
        );
        self.queue.push_back(job);
    }

    pub fn busy(&self) -> bool {
        self.active.is_some() || !self.queue.is_empty()
    }

    /// Is `addr` inside this cluster's own window?
    fn is_local(&self, addr: u64) -> bool {
        use super::config::{CLUSTER_BASE, CLUSTER_STRIDE};
        addr >= CLUSTER_BASE + self.cluster as u64 * CLUSTER_STRIDE
            && addr < CLUSTER_BASE + (self.cluster as u64 + 1) * CLUSTER_STRIDE
    }

    fn start(&mut self, job: DmaJob) {
        let src_local = self.is_local(job.src);
        let dst_local = job.dst.is_singleton() && self.is_local(job.dst.addr);
        let rd_bursts = if src_local {
            Vec::new()
        } else {
            split_bursts(job.src, job.bytes, self.beat_bytes, self.max_burst)
        };
        let wr_bursts = if dst_local {
            Vec::new()
        } else {
            // offsets relative to the destination base; the mask is
            // orthogonal to the offset bits (asserted in cluster_set)
            split_bursts(job.dst.addr, job.bytes, self.beat_bytes, self.max_burst)
        };
        let local_left = if src_local && dst_local {
            job.bytes.div_ceil(self.beat_bytes as u64)
        } else {
            0
        };
        self.stats.jobs += 1;
        self.stats.bytes += job.bytes;
        self.active = Some(Active {
            setup_left: self.setup,
            src_local,
            dst_local,
            rd_bursts,
            rd_next: 0,
            rd_inflight: 0,
            rx_bytes: 0,
            rx_total: 0,
            wr_bursts,
            wr_next: 0,
            w_stream: VecDeque::new(),
            b_pending: 0,
            local_left,
            saw_err: false,
            job,
        });
    }

    /// One cycle on the cluster's wide master link.
    pub fn step(&mut self, _cy: Cycle, link: &mut AxiLink, next_txn: &mut Txn) {
        if self.active.is_none() {
            if let Some(job) = self.queue.pop_front() {
                self.start(job);
            } else {
                return;
            }
        }
        self.stats.busy_cycles += 1;
        let beat = self.beat_bytes as u64;

        // ---- responses (always drain) ----
        {
            let a = self.active.as_mut().unwrap();
            if let Some(r) = link.r.front() {
                // accept R only if staging space (bounded buffer)
                if a.rx_bytes + beat <= self.buf_bytes {
                    let r = *r;
                    link.r.pop();
                    a.rx_bytes += beat;
                    a.rx_total += beat;
                    self.stats.read_beats += 1;
                    if r.resp.is_err() {
                        a.saw_err = true;
                        self.stats.err_resps += 1;
                    }
                    if r.last {
                        a.rd_inflight -= 1;
                    }
                }
            }
            while let Some(b) = link.b.pop() {
                a.b_pending -= 1;
                if b.resp.is_err() {
                    a.saw_err = true;
                    self.stats.err_resps += 1;
                }
            }
        }

        let a = self.active.as_mut().unwrap();
        if a.setup_left > 0 {
            a.setup_left -= 1;
            return;
        }

        // ---- pure local copy ----
        if a.src_local && a.dst_local {
            if a.local_left > 0 {
                a.local_left -= 1;
            }
            if a.local_left == 0 {
                let done = self.active.take().unwrap();
                if done.saw_err {
                    self.error_tags.push(done.job.tag);
                }
                self.completed.push(done.job);
            }
            return;
        }

        // ---- read side ----
        if a.src_local {
            // local SPM read at line rate into staging
            if a.rx_total < a.job.bytes && a.rx_bytes + beat <= self.buf_bytes {
                let take = beat.min(a.job.bytes - a.rx_total);
                a.rx_bytes += take;
                a.rx_total += take;
            }
        } else if a.rd_next < a.rd_bursts.len()
            && a.rd_inflight < self.rd_out
            && link.ar.can_push()
        {
            let (addr, beats) = a.rd_bursts[a.rd_next];
            a.rd_next += 1;
            a.rd_inflight += 1;
            let txn = *next_txn;
            *next_txn += 1;
            link.ar.push(ArBeat {
                id: self.cluster as u16,
                addr,
                beats,
                beat_bytes: self.beat_bytes,
                src: 0,
                txn,
            });
        }

        // ---- write side ----
        if a.dst_local {
            // local SPM write drains the staging FIFO at line rate
            a.rx_bytes = a.rx_bytes.saturating_sub(beat);
        } else {
            let is_mcast = a.job.dst.count() > 1;
            let out_cap = if is_mcast { self.mc_out } else { self.wr_out };
            // bursts with AW issued and B not yet received
            let outstanding = a.b_pending;
            if a.wr_next < a.wr_bursts.len() && outstanding < out_cap && link.aw.can_push() {
                let (addr, beats) = a.wr_bursts[a.wr_next];
                a.wr_next += 1;
                let txn = *next_txn;
                *next_txn += 1;
                link.aw.push(AwBeat {
                    id: self.cluster as u16,
                    dest: AddrSet::new(addr, a.job.dst.mask),
                    beats,
                    beat_bytes: self.beat_bytes,
                    is_mcast,
                    exclude: None,
                    window: None,
                    src: 0,
                    txn,
                    ticket: None,
                    // every burst of a reduction contribution carries
                    // the group tag (same burst split on all members,
                    // so per-burst addresses align at the join points)
                    reduce: a.job.red,
                });
                a.w_stream.push_back((txn, beats));
                a.b_pending += 1;
                self.stats.aw_issued += 1;
            }
            // stream W beats of the oldest issued burst
            if let Some(&(txn, left)) = a.w_stream.front() {
                if a.rx_bytes >= beat.min(a.job.bytes) && link.w.can_push() {
                    a.rx_bytes = a.rx_bytes.saturating_sub(beat);
                    link.w.push(WBeat {
                        last: left == 1,
                        src: 0,
                        txn,
                    });
                    self.stats.write_beats += 1;
                    if left == 1 {
                        a.w_stream.pop_front();
                    } else {
                        a.w_stream.front_mut().unwrap().1 -= 1;
                    }
                } else if a.rx_bytes < beat {
                    self.stats.stall_rx_empty += 1;
                } else {
                    self.stats.stall_tx_backpressure += 1;
                }
            }
        }

        // ---- completion ----
        let a = self.active.as_ref().unwrap();
        let reads_done = a.src_local || (a.rd_next == a.rd_bursts.len() && a.rd_inflight == 0);
        let rx_done = a.src_local || a.rx_total >= a.job.bytes;
        let writes_done = if a.dst_local {
            rx_done
        } else {
            a.wr_next == a.wr_bursts.len() && a.w_stream.is_empty() && a.b_pending == 0
        };
        if reads_done && rx_done && writes_done {
            let done = self.active.take().unwrap();
            if done.saw_err {
                self.error_tags.push(done.job.tag);
            }
            self.completed.push(done.job);
        }
    }

    /// What would the next step do, absent any port activity? Shared
    /// classifier keeping [`DmaEngine::next_event`] and
    /// [`DmaEngine::skip`] in exact agreement (§Perf event horizon).
    fn classify(&self) -> DmaIdle {
        let Some(a) = &self.active else {
            return if self.queue.is_empty() {
                DmaIdle::Idle
            } else {
                DmaIdle::ActNow
            };
        };
        if a.setup_left > 0 {
            return DmaIdle::Setup(a.setup_left);
        }
        if a.src_local && a.dst_local {
            return DmaIdle::LocalCopy(a.local_left.max(1));
        }
        let beat = self.beat_bytes as u64;
        // read side can make progress on its own
        if a.src_local {
            if a.rx_total < a.job.bytes && a.rx_bytes + beat <= self.buf_bytes {
                return DmaIdle::ActNow;
            }
        } else if a.rd_next < a.rd_bursts.len() && a.rd_inflight < self.rd_out {
            // idle links ⇒ AR channel pushable
            return DmaIdle::ActNow;
        }
        // write side
        if a.dst_local {
            if a.rx_bytes > 0 {
                return DmaIdle::ActNow;
            }
        } else {
            let is_mcast = a.job.dst.count() > 1;
            let out_cap = if is_mcast { self.mc_out } else { self.wr_out };
            if a.wr_next < a.wr_bursts.len() && a.b_pending < out_cap {
                return DmaIdle::ActNow;
            }
            // mirror the step's send condition exactly (beat.min covers
            // sub-beat jobs, even though push() currently rejects them)
            if !a.w_stream.is_empty() && a.rx_bytes >= beat.min(a.job.bytes) {
                return DmaIdle::ActNow;
            }
        }
        // purely waiting on R data / B responses from the network
        DmaIdle::Wait {
            w_starved: !a.dst_local && !a.w_stream.is_empty(),
        }
    }

    /// Event horizon: earliest cycle ≥ `now` at which a step can do
    /// more than decrement internal timers, assuming idle links.
    pub fn next_event(&self, now: Cycle) -> Option<Cycle> {
        match self.classify() {
            DmaIdle::Idle => None,
            DmaIdle::ActNow => Some(now),
            // `setup_left` pure-decrement steps precede the first
            // actionable one
            DmaIdle::Setup(s) => Some(now + s as u64),
            // the copy completes in the step that decrements
            // `local_left` to zero
            DmaIdle::LocalCopy(l) => Some(now + l - 1),
            DmaIdle::Wait { .. } => None,
        }
    }

    /// Bulk-advance `k` pure-wait cycles: exactly the timer decrements
    /// and wait statistics `k` consecutive no-op steps would apply.
    pub fn skip(&mut self, k: u64) {
        if k == 0 {
            return;
        }
        let cls = self.classify();
        let Some(a) = self.active.as_mut() else {
            return;
        };
        match cls {
            DmaIdle::Idle | DmaIdle::ActNow => {}
            DmaIdle::Setup(_) => {
                self.stats.busy_cycles += k;
                a.setup_left = (a.setup_left as u64).saturating_sub(k) as u32;
            }
            DmaIdle::LocalCopy(_) => {
                self.stats.busy_cycles += k;
                a.local_left = a.local_left.saturating_sub(k);
            }
            DmaIdle::Wait { w_starved } => {
                self.stats.busy_cycles += k;
                if w_starved {
                    // the write pipe sits on an issued burst with an
                    // empty staging FIFO every one of those cycles
                    self.stats.stall_rx_empty += k;
                }
            }
        }
    }
}

/// Idle-classification of a [`DmaEngine`] between steps (see
/// [`DmaEngine::classify`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum DmaIdle {
    /// No job active or queued.
    Idle,
    /// The very next step performs real work — never skip over it.
    ActNow,
    /// Job-setup countdown: this many pure-decrement steps remain.
    Setup(u32),
    /// Local L1→L1 copy: this many line-rate cycles remain.
    LocalCopy(u64),
    /// Waiting on R/B beats from the network; `w_starved` when an
    /// issued write burst is stalled on the empty staging FIFO (the
    /// per-cycle `stall_rx_empty` condition).
    Wait { w_starved: bool },
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::axi::golden::SimSlave;
    use crate::occamy::config::{CLUSTER_BASE, CLUSTER_STRIDE, LLC_BASE};

    fn engine() -> DmaEngine {
        DmaEngine::new(0, &SocConfig::tiny(4))
    }

    /// Drive the engine against a directly-attached golden slave (no
    /// xbar) to unit-test burst issue and completion.
    fn run_against_slave(dma: &mut DmaEngine, cycles: u64) -> SimSlave {
        let mut slave = SimSlave::new(0);
        let mut link = AxiLink::new(2);
        let mut txn = 1;
        for cy in 0..cycles {
            dma.step(cy, &mut link, &mut txn);
            slave.step(cy, &mut link);
            link.tick();
            if !dma.busy() {
                break;
            }
        }
        slave
    }

    #[test]
    fn remote_write_job_issues_bursts_and_completes() {
        let mut dma = engine();
        // 8 KiB from local L1 to cluster 1: 2 bursts of 64 beats
        dma.push(DmaJob {
            src: CLUSTER_BASE, // cluster 0 = local
            dst: AddrSet::unicast(CLUSTER_BASE + CLUSTER_STRIDE),
            bytes: 8 * 1024,
            tag: 1,
            red: None,
        });
        let slave = run_against_slave(&mut dma, 5_000);
        slave.assert_clean();
        assert_eq!(dma.completed.len(), 1);
        assert_eq!(dma.stats.aw_issued, 2);
        assert_eq!(dma.stats.write_beats, 128);
        assert_eq!(slave.writes.len(), 2);
    }

    #[test]
    fn remote_read_job_issues_ars() {
        let mut dma = engine();
        // LLC -> local L1: read-only on the network
        dma.push(DmaJob {
            src: LLC_BASE,
            dst: AddrSet::unicast(CLUSTER_BASE + 0x1000),
            bytes: 4 * 1024,
            tag: 2,
            red: None,
        });
        let slave = run_against_slave(&mut dma, 5_000);
        assert_eq!(dma.completed.len(), 1);
        assert_eq!(slave.reads.len(), 1); // one 64-beat burst
        assert_eq!(dma.stats.read_beats, 64);
        assert_eq!(dma.stats.aw_issued, 0, "local dst needs no network write");
    }

    #[test]
    fn mcast_write_uses_mask_and_bounded_outstanding() {
        let mut dma = engine();
        let dst = AddrSet::new(CLUSTER_BASE + CLUSTER_STRIDE, 0); // placeholder
        let _ = dst;
        let mc = SocConfig::tiny(4).cluster_set(0, 4, 0x2000);
        dma.push(DmaJob {
            src: CLUSTER_BASE + 0x1000, // local (cluster 0 window)
            dst: mc,
            bytes: 16 * 1024,
            tag: 3,
            red: None,
        });
        let slave = run_against_slave(&mut dma, 10_000);
        slave.assert_clean();
        assert_eq!(dma.completed.len(), 1);
        // 16 KiB / 4 KiB page = 4 bursts, each with the multicast mask
        assert_eq!(dma.stats.aw_issued, 4);
        for w in &slave.writes {
            assert_eq!(w.beats, 64);
        }
    }

    #[test]
    fn local_copy_costs_line_rate_cycles() {
        let mut dma = engine();
        dma.push(DmaJob {
            src: CLUSTER_BASE,
            dst: AddrSet::unicast(CLUSTER_BASE + 0x8000),
            bytes: 4096,
            tag: 4,
            red: None,
        });
        let mut link = AxiLink::new(2);
        let mut txn = 1;
        let mut cycles = 0;
        for cy in 0..1_000 {
            dma.step(cy, &mut link, &mut txn);
            link.tick();
            cycles = cy;
            if !dma.busy() {
                break;
            }
        }
        assert_eq!(dma.completed.len(), 1);
        // setup (8) + 64 line cycles, small slack
        assert!(cycles >= 64 && cycles < 64 + 16, "cycles={cycles}");
    }

    #[test]
    fn jobs_serialise_with_setup_gap() {
        let mut dma = engine();
        for i in 0..3 {
            dma.push(DmaJob {
                src: CLUSTER_BASE,
                dst: AddrSet::unicast(CLUSTER_BASE + CLUSTER_STRIDE + i * 0x1000),
                bytes: 1024,
                tag: i,
                red: None,
            });
        }
        let slave = run_against_slave(&mut dma, 10_000);
        slave.assert_clean();
        assert_eq!(dma.completed.len(), 3);
        assert_eq!(
            dma.completed.iter().map(|j| j.tag).collect::<Vec<_>>(),
            vec![0, 1, 2],
            "jobs must complete in issue order"
        );
    }
}

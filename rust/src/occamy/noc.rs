//! Two-level crossbar hierarchy builder (fig. 2c).
//!
//! Each network (wide and narrow) is a tree: one group crossbar per
//! 4-cluster group plus a top-level crossbar. Per group crossbar:
//!
//! * master ports: the 4 local cluster sources + 1 "down-in" from top;
//! * slave ports:  the 4 local cluster sinks + 1 "up-out" to top;
//! * address map:  the 4 local cluster windows (multicast rules) with
//!   the up port as default route; the group's cluster region is the
//!   local exclude scope for hierarchical multicast.
//!
//! Top crossbar: one master port per group (up-in) [+ the barrier unit
//! on the narrow network]; one slave port per group (down-out) + the
//! LLC (wide) / barrier peripheral (narrow).

use super::config::{SocConfig, BARRIER_BASE, BARRIER_SIZE, LLC_BASE};
use crate::axi::addr_map::{AddrMap, AddrRule};
use crate::axi::types::AxiLink;
use crate::axi::xbar::{Xbar, XbarCfg};

/// Which of the two networks to build.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NetKind {
    Wide,
    Narrow,
}

/// One built network: group xbars + top xbar + the link indices of all
/// external ports.
pub struct Network {
    pub kind: NetKind,
    /// Group crossbars, then the top crossbar last.
    pub xbars: Vec<Xbar>,
    /// Per cluster: link the cluster pushes requests into.
    pub cluster_m: Vec<usize>,
    /// Per cluster: link delivering requests to the cluster's slave
    /// port (wide: L1 window; narrow: mailbox).
    pub cluster_s: Vec<usize>,
    /// Wide: the LLC's link. Narrow: the barrier peripheral's slave link.
    pub service_s: usize,
    /// Narrow only: the barrier unit's own master port into the top.
    pub ext_m: Option<usize>,
}

impl Network {
    /// Advance all crossbars one cycle.
    pub fn step(&mut self, pool: &mut [AxiLink]) {
        for x in &mut self.xbars {
            x.step(pool);
        }
    }

    /// Hinted step: `link_active[l]` says link `l` had visible beats at
    /// the last clock edge; idle crossbars are skipped entirely.
    pub fn step_hinted(&mut self, pool: &mut [AxiLink], link_active: &[bool]) {
        for x in &mut self.xbars {
            let hint = x.maybe_busy
                || x.m_links.iter().any(|&l| link_active[l])
                || x.s_links.iter().any(|&l| link_active[l]);
            if hint {
                x.step(pool);
            }
        }
    }

    pub fn busy(&self) -> bool {
        self.xbars.iter().any(|x| x.busy())
    }

    pub fn top(&self) -> &Xbar {
        self.xbars.last().unwrap()
    }

    /// Aggregate stats over all crossbars.
    pub fn stats_sum(&self) -> crate::axi::xbar::XbarStats {
        let mut acc = crate::axi::xbar::XbarStats::default();
        for x in &self.xbars {
            let s = &x.stats;
            acc.aw_unicast += s.aw_unicast;
            acc.aw_mcast += s.aw_mcast;
            acc.aw_forks += s.aw_forks;
            acc.w_beats_in += s.w_beats_in;
            acc.w_beats_out += s.w_beats_out;
            acc.w_fork_stalls += s.w_fork_stalls;
            acc.b_joined += s.b_joined;
            acc.commit_waits += s.commit_waits;
            acc.ar_forwarded += s.ar_forwarded;
            acc.r_beats += s.r_beats;
            acc.decerr += s.decerr;
            acc.stall_id_conflict += s.stall_id_conflict;
            acc.stall_mcast_order += s.stall_mcast_order;
        }
        acc
    }
}

fn alloc_link(pool: &mut Vec<AxiLink>, depth: usize) -> usize {
    pool.push(AxiLink::new(depth));
    pool.len() - 1
}

/// Build one network over the shared link pool.
pub fn build_network(cfg: &SocConfig, pool: &mut Vec<AxiLink>, kind: NetKind) -> Network {
    let n_groups = cfg.n_groups();
    let cpg = cfg.clusters_per_group;
    let depth = cfg.link_depth;
    let mcast = match kind {
        NetKind::Wide => cfg.wide_mcast,
        NetKind::Narrow => cfg.narrow_mcast,
    };

    let cluster_m: Vec<usize> = (0..cfg.n_clusters)
        .map(|_| alloc_link(pool, depth))
        .collect();
    let cluster_s: Vec<usize> = (0..cfg.n_clusters)
        .map(|_| alloc_link(pool, depth))
        .collect();
    let up: Vec<usize> = (0..n_groups).map(|_| alloc_link(pool, depth)).collect();
    let down: Vec<usize> = (0..n_groups).map(|_| alloc_link(pool, depth)).collect();
    let service_s = alloc_link(pool, depth);
    let ext_m = match kind {
        NetKind::Narrow => Some(alloc_link(pool, depth)),
        NetKind::Wide => None,
    };

    let mut xbars = Vec::with_capacity(n_groups + 1);

    // group crossbars
    for g in 0..n_groups {
        let first = g * cpg;
        let rules: Vec<AddrRule> = (0..cpg)
            .map(|i| {
                let c = first + i;
                AddrRule::new(
                    cfg.cluster_base(c),
                    cfg.cluster_base(c) + super::config::CLUSTER_STRIDE,
                    i,
                    &format!("cluster{c}"),
                )
                .with_mcast()
            })
            .collect();
        let map = AddrMap::new(rules, cpg + 1).expect("group map");
        let mut xcfg = XbarCfg::new(
            &format!("{:?}-g{}", kind, g),
            cpg + 1, // 4 clusters + down-in
            cpg + 1, // 4 clusters + up-out
            map,
        );
        xcfg.default_slave = Some(cpg);
        xcfg.local_scope = Some(cfg.group_region(g));
        xcfg.mcast_enabled = mcast;
        xcfg.commit_protocol = cfg.commit_protocol;
        xcfg.mcast_w_cooldown = cfg.mcast_w_cooldown;
        let m_links: Vec<usize> = (0..cpg)
            .map(|i| cluster_m[first + i])
            .chain([down[g]])
            .collect();
        let s_links: Vec<usize> = (0..cpg)
            .map(|i| cluster_s[first + i])
            .chain([up[g]])
            .collect();
        xbars.push(Xbar::new(xcfg, m_links, s_links));
    }

    // top crossbar
    {
        let mut rules: Vec<AddrRule> = (0..n_groups)
            .map(|g| {
                let (s, e) = cfg.group_region(g);
                AddrRule::new(s, e, g, &format!("group{g}")).with_mcast()
            })
            .collect();
        let service_rule = match kind {
            NetKind::Wide => AddrRule::new(LLC_BASE, LLC_BASE + cfg.llc_bytes, n_groups, "llc"),
            NetKind::Narrow => {
                AddrRule::new(BARRIER_BASE, BARRIER_BASE + BARRIER_SIZE, n_groups, "barrier")
            }
        };
        rules.push(service_rule);
        let n_slaves = n_groups + 1;
        let n_masters = n_groups + ext_m.iter().len();
        let map = AddrMap::new(rules, n_slaves).expect("top map");
        let mut xcfg = XbarCfg::new(&format!("{:?}-top", kind), n_masters, n_slaves, map);
        xcfg.mcast_enabled = mcast;
        xcfg.commit_protocol = cfg.commit_protocol;
        xcfg.mcast_w_cooldown = cfg.mcast_w_cooldown;
        // larger top xbar gets more outstanding room
        xcfg.max_outstanding = 64;
        xcfg.max_mcast_outstanding = cfg.dma_mcast_outstanding.max(2) * 2;
        let mut m_links = up.clone();
        if let Some(e) = ext_m {
            m_links.push(e);
        }
        let mut s_links = down.clone();
        s_links.push(service_s);
        xbars.push(Xbar::new(xcfg, m_links, s_links));
    }

    Network {
        kind,
        xbars,
        cluster_m,
        cluster_s,
        service_s,
        ext_m,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wide_network_shape() {
        let cfg = SocConfig::default();
        let mut pool = Vec::new();
        let net = build_network(&cfg, &mut pool, NetKind::Wide);
        assert_eq!(net.xbars.len(), 9); // 8 groups + top
        assert_eq!(net.cluster_m.len(), 32);
        let top = net.top();
        assert_eq!(top.cfg.n_masters, 8);
        assert_eq!(top.cfg.n_slaves, 9);
        assert!(net.ext_m.is_none());
    }

    #[test]
    fn narrow_network_has_barrier_master() {
        let cfg = SocConfig::default();
        let mut pool = Vec::new();
        let net = build_network(&cfg, &mut pool, NetKind::Narrow);
        assert!(net.ext_m.is_some());
        assert_eq!(net.top().cfg.n_masters, 9);
    }

    #[test]
    fn group_scope_is_aligned() {
        let cfg = SocConfig::default();
        let mut pool = Vec::new();
        let net = build_network(&cfg, &mut pool, NetKind::Wide);
        for g in 0..8 {
            let (s, e) = net.xbars[g].cfg.local_scope.unwrap();
            assert!((e - s).is_power_of_two());
            assert_eq!(s % (e - s), 0);
        }
    }
}
